"""Per-query adaptive termination over the one-pass serving pipeline.

The paper's search is *adaptive*: each query grows its window radius
``c^i·r0`` until a terminate condition fires (§IV-B/C) — C1, enough
verified candidates (``βn + k``, concretely ``2tL + k``); C2, a verified
point within ``c·r``, which certifies a c²-approximate answer.  The
batched serving core historically ran a *fixed* schedule: every query
paid all ``steps`` probes, easy queries wasted work, hard queries
silently under-recalled at whatever the hand-picked schedule reached.

This module is the subsystem face of adaptive serving.  The jit-stable
machinery itself lives *inside* the one-pass pipeline
(:class:`~repro.core.serve_search.Termination`, re-exported here): the
C1/C2 conditions become per-query ``done`` masks applied to the
per-step delta merges — terminated queries stop gathering and verifying
— plus a batch-wide ``lax.while_loop`` early exit once every query is
done.  C2 is evaluated from the per-slot admission halfwidths the
verify engines already emit (the ``window_dist`` kernel's ``hw`` plane),
so termination costs no extra DMAs on any engine.

:func:`search_batch_adaptive` is the convenience entry: a fixed-budget
batched search with termination on and stats always returned.  The
helpers below read those stats back into paper language — which step a
query stopped at, the radius ``r_i`` it certified against, whether the
C2 certificate held at exit — which is what the property tests and the
recall-frontier benchmark consume.
"""

from __future__ import annotations

import numpy as np

from ..core.serve_search import Termination, search_batch_fixed

__all__ = [
    "Termination",
    "certified_c2_mask",
    "search_batch_adaptive",
    "termination_radii",
    "termination_step_histogram",
]


def search_batch_adaptive(
    index,
    Q,
    k: int = 0,
    r0: float = 1.0,
    steps: int = 8,
    engine: str = "jnp",
    interpret=None,
    exact: bool = False,
    termination: Termination = Termination(),
):
    """Adaptive batched (c,k)-ANN: the one-pass pipeline with C1/C2 done
    masks and batch-wide early exit.  Returns ``(dists, ids, stats)`` —
    stats always included (``radius_steps`` is the per-query termination
    step, the quantity adaptivity exists to shrink)."""
    return search_batch_fixed(
        index, Q, k=k, r0=r0, steps=steps, engine=engine,
        interpret=interpret, exact=exact, with_stats=True,
        termination=termination,
    )


def termination_radii(stats, r0: float, c: float) -> np.ndarray:
    """The radius ``r_i`` each query's schedule stopped at:
    ``r0 · c^(radius_steps − 1)`` (the radius of the last step that ran;
    queries that never ran a step report ``r0``)."""
    s = np.asarray(stats["radius_steps"])
    return r0 * np.power(c, np.maximum(s, 1) - 1)


def termination_step_histogram(stats, steps: int) -> np.ndarray:
    """(steps + 1,) counts of queries by termination step; slot ``j`` is
    "stopped after j steps" (slot ``steps`` = ran the whole schedule)."""
    s = np.asarray(stats["radius_steps"])
    return np.bincount(np.clip(s, 0, steps), minlength=steps + 1)


def certified_c2_mask(dists, stats, *, r0: float, c: float, k: int,
                      steps: int) -> np.ndarray:
    """Queries that exited *early* with the C2 certificate in hand: the
    k-th returned distance is ≤ c·r_i at the termination radius.  For
    these, the paper's Theorem-2 argument guarantees the returned top-1
    is a c²-approximate NN — the property the tune test suite checks
    against a brute-force oracle."""
    d = np.asarray(dists)
    s = np.asarray(stats["radius_steps"])
    r_i = termination_radii(stats, r0, c)
    kth = d[:, k - 1]
    return (s < steps) & np.isfinite(kth) & (kth <= c * r_i * (1 + 1e-6))
