"""Offline schedule calibration: measure once, plan every query after.

DB-LSH's radius schedule has two free knobs — the initial radius ``r0``
and the schedule length ``steps`` — and both are properties of the
*collection* (its distance scale, its density), not of the query.  The
calibrator probes a held-out query sample against the index and fits a
:class:`ScheduleTable`: for every schedule length ``j = 1..steps_max``,
the expected recall@k (against a brute-force oracle on the sample), the
mean verified-slot cost, and optionally the measured per-query latency.

With a table in hand, :func:`plan` resolves an outcome-level policy
(:mod:`repro.tune.policy`) into the concrete
:class:`~repro.tune.policy.ResolvedPlan` the dispatch runs:

* ``RecallTarget(0.95)`` → the *shortest* calibrated schedule whose
  expected recall meets the target (adaptive termination rides along so
  easy queries still exit earlier than the planned worst case);
* ``LatencyBudget(ms)`` → the *longest* schedule whose measured
  per-query latency fits;
* ``FixedSchedule(...)`` → passthrough (no table needed).

**r0 derivation.**  When not given, ``r0`` comes from the sample's true
NN distances: ``r0 = q10(nn) / c``.  The first probe then lands just
under the easy decile's NN distance, so C2 (k-th ≤ c·r) can fire within
a step or two for easy queries, while ``steps_max`` radii of geometric
growth still cover the hard tail.  This is the "query-based" part of
the paper made operational: the schedule is anchored to the data's
distance scale instead of a hand-picked constant.

**Contract.**  Calibration is advisory, never load-bearing for
correctness: a plan only chooses (r0, steps, termination), and every
choice is a valid search.  Tables are sampled estimates — recall on
future queries is expected, not guaranteed; re-calibrate after heavy
updates (compaction changes K/L and block geometry).  Tables serialize
to plain dicts and ride in collection snapshots
(:meth:`repro.store.Collection.snapshot`).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core import brute_force, search_batch_fixed
from .policy import (
    FixedSchedule,
    LatencyBudget,
    RecallTarget,
    ResolvedPlan,
)

__all__ = ["ScheduleTable", "calibrate", "plan"]


@dataclasses.dataclass(frozen=True)
class ScheduleTable:
    """Per-collection calibration: schedule length -> expected outcome.

    Entry ``j`` (0-based) describes the schedule of length ``j + 1``
    starting at ``r0``: ``recall[j]`` expected recall@k on the sample,
    ``cost_slots[j]`` mean verified candidate slots per query (the
    ``with_stats`` candidates counter), ``cost_ms[j]`` measured mean
    per-query milliseconds (``nan`` when not measured)."""

    r0: float
    c: float
    k: int
    recall: tuple[float, ...]
    cost_slots: tuple[float, ...]
    cost_ms: tuple[float, ...]
    n_sample: int

    @property
    def max_steps(self) -> int:
        return len(self.recall)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ScheduleTable":
        return cls(
            r0=float(d["r0"]), c=float(d["c"]), k=int(d["k"]),
            recall=tuple(float(x) for x in d["recall"]),
            cost_slots=tuple(float(x) for x in d["cost_slots"]),
            cost_ms=tuple(float(x) for x in d["cost_ms"]),
            n_sample=int(d["n_sample"]),
        )


def _recall_at(ids, gt_ids, k: int) -> float:
    ids = np.asarray(ids)[:, :k]
    gt = np.asarray(gt_ids)[:, :k]
    return float(np.mean([
        len(set(a.tolist()) & set(b.tolist())) / k for a, b in zip(ids, gt)
    ]))


def derive_r0(nn_dists, c: float, quantile: float = 0.10) -> float:
    """Data-scale initial radius: the sample NN-distance ``quantile``
    divided by ``c`` (see module doc)."""
    nn = np.asarray(nn_dists, np.float64).reshape(-1)
    nn = nn[np.isfinite(nn) & (nn > 0)]
    if nn.size == 0:
        return 1.0
    return float(max(np.quantile(nn, quantile) / c, 1e-6))


def calibrate(
    index,
    queries,
    *,
    k: int = 0,
    r0: float | None = None,
    steps_max: int = 8,
    engine: str = "jnp",
    interpret: bool | None = None,
    measure_ms: bool = False,
    repeats: int = 2,
    search_fn=None,
    oracle_rows=None,
    oracle_ids=None,
) -> ScheduleTable:
    """Probe ``queries`` (m, d) against ``index`` and fit the table.

    One fixed-schedule search per length ``1..steps_max`` (each length is
    a distinct compiled program — keep the sample small; tens of queries
    estimate recall to a few points, which is all planning needs).
    ``measure_ms=True`` additionally times each length (min over
    ``repeats`` post-warmup runs) so :class:`LatencyBudget` can plan.

    ``search_fn(Q, r0, steps, with_stats=False)`` overrides the dispatch
    (default: ``search_batch_fixed`` on ``index``) so non-local
    placements calibrate through their own search path — e.g. a sharded
    collection probes ``search_sharded`` while ``index`` still supplies
    the params and the (global) data for the brute-force oracle.

    ``oracle_rows`` restricts the brute-force ground truth to the rows
    the search can actually return.  Without it a mutated index
    under-measures: tombstoned rows — including the per-shard dead
    replicas a sharded insert leaves behind at identical coordinates —
    would occupy ground-truth top-k slots no search result can ever
    match.  ``oracle_ids`` (same length) supplies the id each oracle row
    is *returned as* when the search's id space is not the data-row
    space — e.g. strided sharded gids — so recall overlap compares like
    with like; it defaults to ``oracle_rows`` (dense layouts, where row
    index == id).
    """
    p = index.params
    k = k or p.k
    Q = jnp.asarray(queries, jnp.float32)
    if search_fn is None:
        def search_fn(Qs, r0, steps, with_stats=False):
            return search_batch_fixed(
                index, Qs, k=k, r0=r0, steps=steps, engine=engine,
                interpret=interpret, with_stats=with_stats,
            )

    if oracle_rows is None:
        gt_d, gt_i = brute_force(index.data, Q, k=k)
    else:
        rows = jnp.asarray(np.asarray(oracle_rows), jnp.int32)
        gt_d, gt_i = brute_force(jnp.take(index.data, rows, axis=0), Q, k=k)
        ids_src = (
            rows if oracle_ids is None
            else jnp.asarray(np.asarray(oracle_ids), jnp.int32)
        )
        gt_i = jnp.take(ids_src, gt_i)
    if r0 is None:
        r0 = derive_r0(np.asarray(gt_d)[:, 0], p.c)

    recalls, slots, ms = [], [], []
    for j in range(1, steps_max + 1):
        _, ids, stats = search_fn(Q, r0, j, with_stats=True)
        jax.block_until_ready(ids)
        recalls.append(_recall_at(ids, gt_i, k))
        slots.append(float(np.asarray(stats["candidates"]).mean()))
        if measure_ms:
            best = np.inf
            for _ in range(max(1, repeats)):
                t0 = time.perf_counter()
                out = search_fn(Q, r0, j)
                jax.block_until_ready(out)
                best = min(best, time.perf_counter() - t0)
            ms.append(best * 1e3 / Q.shape[0])
        else:
            ms.append(float("nan"))

    return ScheduleTable(
        r0=float(r0), c=float(p.c), k=k,
        recall=tuple(recalls), cost_slots=tuple(slots), cost_ms=tuple(ms),
        n_sample=int(Q.shape[0]),
    )


def plan(
    table: ScheduleTable | None,
    policy,
    *,
    default_r0: float = 1.0,
    default_steps: int = 8,
) -> ResolvedPlan:
    """Resolve ``policy`` against ``table`` into a concrete plan.

    ``policy=None`` and ``FixedSchedule`` need no table.  ``RecallTarget``
    without a table degrades safely to the default schedule capped at
    ``max_steps`` — adaptive termination still trims easy queries, so the
    fallback can only over-probe, never under-recall vs the default.
    ``LatencyBudget`` raises without a measured table: guessing device
    speed would silently violate the budget it exists to honor.  With a
    measured table whose cheapest length still misses the budget, it
    floors at ``steps=1`` — the service always answers a query it
    admitted.
    """
    if policy is None:
        return ResolvedPlan(r0=default_r0, steps=default_steps)

    if isinstance(policy, FixedSchedule):
        return ResolvedPlan(
            r0=default_r0 if policy.r0 is None else float(policy.r0),
            steps=default_steps if policy.steps is None else int(policy.steps),
            termination=policy.termination,
        )

    if isinstance(policy, RecallTarget):
        if table is None:
            return ResolvedPlan(
                r0=default_r0,
                steps=max(1, min(default_steps, policy.max_steps)),
                termination=policy.termination,
            )
        steps = None
        for j, rec in enumerate(table.recall):
            if rec >= policy.recall:
                steps = j + 1
                break
        if steps is None:
            steps = table.max_steps  # best the calibration achieved
        return ResolvedPlan(
            r0=table.r0,
            steps=min(steps, policy.max_steps),
            termination=policy.termination,
        )

    if isinstance(policy, LatencyBudget):
        if table is None or not any(np.isfinite(m) for m in table.cost_ms):
            raise ValueError(
                "LatencyBudget needs a calibration table measured with "
                "measure_ms=True (Collection.calibrate(..., measure_ms=True))"
            )
        steps = 0
        for j, m in enumerate(table.cost_ms):
            if np.isfinite(m) and m <= policy.ms:
                steps = j + 1
        steps = max(1, min(steps or 1, policy.max_steps))
        return ResolvedPlan(
            r0=table.r0, steps=steps, termination=policy.termination,
        )

    raise TypeError(f"unknown policy {policy!r}")
