"""Query-planning policies: ask for outcomes, not radii.

The serving core takes mechanism-level knobs — initial radius ``r0``,
schedule length ``steps``, a :class:`~repro.core.serve_search.Termination`
— but callers think in outcomes: "95% recall", "under 2 ms", "exactly
the schedule I measured".  A *policy* names the outcome; the planner
(:mod:`repro.tune.planner`) resolves it against a collection's
calibration table into a :class:`ResolvedPlan`, the concrete (r0, steps,
termination) triple the dispatch actually runs.

Three policies:

* :class:`FixedSchedule` — pin the mechanism directly.  The default
  ``FixedSchedule()`` resolves to the caller's own (r0, steps) with no
  adaptive termination, which makes it *bit-equal* to a plain
  ``search_batch_fixed`` call (the tune test suite asserts this).
* :class:`RecallTarget` — the planner picks the shortest calibrated
  schedule whose expected recall meets the target, and runs it with
  adaptive termination so easy queries still stop early.
* :class:`LatencyBudget` — the planner picks the longest calibrated
  schedule whose measured per-query latency fits the budget.

**Resolution order** mirrors the engine-default resolution from the
store layer (request > collection > service): :func:`resolve_policy`
returns the first non-``None`` of the explicit request policy, the
collection's ``search_policy``, and the service default.  ``None``
everywhere means "no planning" — the service dispatches its own
(r0, steps) with no termination, exactly the pre-tune behavior.

Policies and plans are frozen dataclasses: hashable (a ResolvedPlan is
part of the dispatch's static jit signature and the result-cache key)
and serializable (:func:`policy_to_dict` / :func:`policy_from_dict` ride
in collection snapshots).
"""

from __future__ import annotations

import dataclasses

from ..core.serve_search import Termination

__all__ = [
    "FixedSchedule",
    "LatencyBudget",
    "POLICY_SOURCES",
    "RecallTarget",
    "ResolvedPlan",
    "policy_from_dict",
    "policy_to_dict",
    "resolve_policy",
    "resolve_policy_with_source",
]


@dataclasses.dataclass(frozen=True)
class FixedSchedule:
    """Run exactly this schedule.  ``None`` fields defer to the caller's
    defaults (the service's r0/steps).  ``termination=None`` (default)
    keeps the plain fixed path — bit-equal to ``search_batch_fixed``;
    supplying one layers adaptive termination on a pinned schedule."""

    r0: float | None = None
    steps: int | None = None
    termination: Termination | None = None


@dataclasses.dataclass(frozen=True)
class RecallTarget:
    """Meet an expected recall@k.  Needs a calibrated collection to pick
    the schedule; uncalibrated it falls back to the full default
    schedule (never *shorter* than asked) with adaptive termination.
    ``max_steps`` caps the planner even when the table says recall is
    still below target (the calibration reports what was achieved)."""

    recall: float = 0.95
    max_steps: int = 12
    termination: Termination = Termination()


@dataclasses.dataclass(frozen=True)
class LatencyBudget:
    """Fit a per-query latency budget (milliseconds).  Requires a
    calibration table measured with ``measure_ms=True`` — the planner
    refuses to guess device speed.  A budget no measured schedule fits
    floors at ``steps=1`` (the cheapest valid search): the service
    always answers, it never refuses a query at admission time."""

    ms: float = 1.0
    max_steps: int = 12
    termination: Termination = Termination()


@dataclasses.dataclass(frozen=True)
class ResolvedPlan:
    """The concrete schedule a policy resolved to: what the dispatch
    runs, what the result cache keys on, and what batches group by."""

    r0: float
    steps: int
    termination: Termination | None = None


def resolve_policy(*candidates):
    """First non-``None`` of (request, collection, service) — the same
    three-level precedence as the store layer's engine resolution."""
    for c in candidates:
        if c is not None:
            return c
    return None


#: provenance names for the three resolution rungs, by candidate index;
#: past the end (all ``None``) the plan came from the service's raw
#: (r0, steps) with no policy at all.
POLICY_SOURCES = ("request", "collection", "service")


def resolve_policy_with_source(*candidates):
    """Like :func:`resolve_policy` but also names the rung that won —
    ``(policy, "request"|"collection"|"service")``, or
    ``(None, "default")`` when no rung supplied a policy.  This is what
    the EXPLAIN path records as the plan-resolution chain."""
    for c, source in zip(candidates, POLICY_SOURCES):
        if c is not None:
            return c, source
    return None, "default"


# --------------------------------------------------------------- persistence
_POLICY_TYPES = {
    "FixedSchedule": FixedSchedule,
    "RecallTarget": RecallTarget,
    "LatencyBudget": LatencyBudget,
}


def policy_to_dict(policy) -> dict | None:
    """JSON-able form for snapshot metadata (None passes through)."""
    if policy is None:
        return None
    d = dataclasses.asdict(policy)
    return {"type": type(policy).__name__, **d}


def policy_from_dict(d: dict | None):
    if d is None:
        return None
    d = dict(d)
    cls = _POLICY_TYPES[d.pop("type")]
    t = d.get("termination")
    if t is not None:
        d["termination"] = Termination(**t)
    return cls(**d)
