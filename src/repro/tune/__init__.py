"""repro.tune — adaptive termination and recall-target query planning.

The subsystem that makes the serving story match the paper's central
claim: DB-LSH's window radius is *query-driven*, growing until the
terminate conditions fire, while a production batch wants lockstep
shapes.  ``tune`` reconciles the two:

* ``adaptive``  — per-query C1/C2 termination inside the one-pass
  serving pipeline (jit-stable ``done`` masks on the delta merges +
  batch-wide ``lax.while_loop`` early exit; the mechanism lives in
  ``core.serve_search.Termination``, this module is its API surface and
  stats-analysis toolkit).
* ``planner``   — offline calibration of a per-collection schedule
  table (r0 anchored to the data's NN-distance scale; per-length
  expected recall / slot cost / measured latency) and the policy → plan
  resolution.
* ``policy``    — outcome-level policies (``RecallTarget``,
  ``LatencyBudget``, ``FixedSchedule``) with request > collection >
  service resolution, mirroring the store layer's engine defaults.

Integration points: ``core.serve_search.search_batch_fixed(...,
termination=)`` (all three verify engines), ``core.distributed.
search_sharded`` (per-shard termination), ``store.Collection``
(``search_policy`` + persisted calibration), ``store.StoreService.
submit(..., recall_target=)``.  Contracts: DESIGN.md §8.  The frontier
benchmark (``benchmarks/recall_frontier.py``) pins adaptive-vs-fixed as
a BENCH trajectory.
"""

from .adaptive import (
    Termination,
    certified_c2_mask,
    search_batch_adaptive,
    termination_radii,
    termination_step_histogram,
)
from .planner import ScheduleTable, calibrate, plan
from .policy import (
    FixedSchedule,
    LatencyBudget,
    POLICY_SOURCES,
    RecallTarget,
    ResolvedPlan,
    policy_from_dict,
    policy_to_dict,
    resolve_policy,
    resolve_policy_with_source,
)

__all__ = [
    "FixedSchedule",
    "LatencyBudget",
    "POLICY_SOURCES",
    "RecallTarget",
    "ResolvedPlan",
    "ScheduleTable",
    "Termination",
    "calibrate",
    "certified_c2_mask",
    "plan",
    "policy_from_dict",
    "policy_to_dict",
    "resolve_policy",
    "resolve_policy_with_source",
    "search_batch_adaptive",
    "termination_radii",
    "termination_step_histogram",
]
