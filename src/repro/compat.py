"""jax cross-version compatibility (0.4.x <-> >= 0.6).

Two API moves matter to this repo:

* ``shard_map`` graduated from ``jax.experimental.shard_map`` to
  ``jax.shard_map``, and its replication-check kwarg was renamed
  (``check_rep`` -> ``check_vma``);
* ``jax.make_mesh`` grew an ``axis_types`` parameter. Both versions
  default every axis to Auto, so callers that want Auto simply omit it.

Import :func:`shard_map` from here instead of from ``jax`` directly.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map"]

if hasattr(jax, "shard_map"):  # jax >= 0.6
    _shard_map = jax.shard_map
    _NOCHECK = {"check_vma": False}
else:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    _NOCHECK = {"check_rep": False}


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check: bool = False):
    """``jax.shard_map`` with the version-appropriate kwarg spellings.

    ``check=False`` (the repo default) disables the replication/VMA
    check — every call site here predates it and relies on manual spec
    correctness.  ``axis_names`` selects *partial manual* mode (manual
    over the named axes only); jax 0.4.x spells that as the complement,
    ``auto=<other axes>``.
    """
    kw = {} if check else dict(_NOCHECK)
    if axis_names is not None:
        if hasattr(jax, "shard_map"):
            kw["axis_names"] = set(axis_names)
        else:
            kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
