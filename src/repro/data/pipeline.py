"""Token data pipeline: deterministic, host-sharded, resumable, prefetched.

Design for fault tolerance/elasticity: batches are a *pure function of
the global step* (stateless indexing into a seeded generator or a memmap
corpus). Resuming from step k — on any number of hosts — reproduces the
exact global batch sequence; the only iterator state that needs to be
checkpointed is the step counter itself.
"""

from __future__ import annotations

import queue
import threading

import numpy as np

__all__ = ["SyntheticTokens", "MemmapTokens", "Prefetcher", "make_batch_fn"]


class SyntheticTokens:
    """Deterministic synthetic LM stream (counter-based RNG: independent
    of history, safe to index from any step)."""

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int, seed: int = 0):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed

    def batch_at(self, step: int, host_id: int = 0, n_hosts: int = 1):
        local = self.global_batch // n_hosts
        rng = np.random.Generator(
            np.random.Philox(key=self.seed, counter=[0, 0, host_id, step])
        )
        toks = rng.integers(
            0, self.vocab_size, size=(local, self.seq_len + 1), dtype=np.int32
        )
        # mix in structure so losses are learnable: low-order markov flavor
        toks[:, 1:] = (toks[:, 1:] + toks[:, :-1]) % self.vocab_size
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class MemmapTokens:
    """File-backed corpus of int32 tokens; step-indexed strided windows."""

    def __init__(self, path: str, seq_len: int, global_batch: int):
        self.data = np.memmap(path, dtype=np.int32, mode="r")
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.n_windows = (len(self.data) - 1) // seq_len

    def batch_at(self, step: int, host_id: int = 0, n_hosts: int = 1):
        local = self.global_batch // n_hosts
        base = (step * self.global_batch + host_id * local) % self.n_windows
        idx = (base + np.arange(local)) % self.n_windows
        starts = idx * self.seq_len
        toks = np.stack([self.data[s : s + self.seq_len + 1] for s in starts])
        return {"tokens": toks[:, :-1].astype(np.int32), "labels": toks[:, 1:].astype(np.int32)}


def make_batch_fn(source, extras=None, host_id=0, n_hosts=1):
    """-> batch_fn(step) adding any modality-stub extras (frames/images)."""

    def fn(step: int):
        b = source.batch_at(step, host_id, n_hosts)
        if extras:
            rng = np.random.Generator(np.random.Philox(key=17, counter=[0, 0, 0, step]))
            for name, shape in extras.items():
                local = b["tokens"].shape[0]
                b[name] = rng.standard_normal((local,) + tuple(shape), dtype=np.float32)
        return b

    return fn


class Prefetcher:
    """Background-thread prefetch of step-indexed batches."""

    def __init__(self, batch_fn, start_step: int = 0, depth: int = 2):
        self.batch_fn = batch_fn
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.step = start_step
        self.stop = threading.Event()
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def _run(self):
        s = self.step
        while not self.stop.is_set():
            try:
                self.q.put((s, self.batch_fn(s)), timeout=0.2)
                s += 1
            except queue.Full:
                continue

    def next(self):
        s, b = self.q.get()
        return s, b

    def close(self):
        self.stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self.thread.join(timeout=2)
