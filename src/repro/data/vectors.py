"""Vector-dataset generators standing in for the paper's 10 real datasets.

The container is offline, so we synthesize datasets that match the
*cardinality/dimension envelope* of Table III and reproduce the property
that drives LSH behaviour: clustered data with controllable local
intrinsic dimensionality (points live near a mixture of low-dimensional
Gaussian pancakes embedded in R^d). ``paper_dataset_specs`` carries the
Table III shapes; benchmarks scale them down for CPU with ``--scale``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["make_clustered", "make_uniform", "paper_dataset_specs", "normalize_scale"]

# Table III of the paper (cardinality, dimensionality).
paper_dataset_specs = {
    "audio": (54_387, 192),
    "mnist": (60_000, 784),
    "cifar": (60_000, 1024),
    "trevi": (101_120, 4096),
    "nus": (269_648, 500),
    "deep1m": (1_000_000, 256),
    "gist": (1_000_000, 960),
    "sift10m": (10_000_000, 128),
    "tiny80m": (79_302_017, 384),
    "sift100m": (100_000_000, 128),
}


def make_uniform(key, n: int, d: int) -> jax.Array:
    return jax.random.uniform(key, (n, d), jnp.float32, -1.0, 1.0)


def make_clustered(
    key,
    n: int,
    d: int,
    n_clusters: int = 32,
    intrinsic_dim: int | None = None,
    spread: float = 0.05,
) -> jax.Array:
    """Gaussian-mixture data on low-dimensional pancakes in R^d.

    Each cluster has a random center in [-1,1]^d and covariance of rank
    ``intrinsic_dim`` (default d//8) with per-axis scale ``spread`` —
    mimicking the local-intrinsic-dimensionality profile of SIFT/GIST
    style descriptors that Table III's datasets exhibit.
    """
    kid = intrinsic_dim or max(2, d // 8)
    kc, kb, ka, kx = jax.random.split(key, 4)
    centers = jax.random.uniform(kc, (n_clusters, d), jnp.float32, -1.0, 1.0)
    basis = jax.random.normal(kb, (n_clusters, kid, d), jnp.float32) / jnp.sqrt(d)
    assign = jax.random.randint(ka, (n,), 0, n_clusters)
    coeff = jax.random.normal(kx, (n, kid), jnp.float32) * spread * jnp.sqrt(d)
    pts = centers[assign] + jnp.einsum("nk,nkd->nd", coeff, basis[assign])
    return pts.astype(jnp.float32)


def normalize_scale(data: jax.Array, queries: jax.Array, target_nn: float = 1.0):
    """Rescale data so the typical NN distance is ~``target_nn`` — the
    paper assumes r0 = 1 WLOG (§III-A); this realizes that WLOG."""
    m = min(512, queries.shape[0])
    sample = queries[:m]
    d2 = (
        jnp.sum(jnp.square(sample), -1, keepdims=True)
        - 2.0 * sample @ data.T
        + jnp.sum(jnp.square(data), -1)
    )
    nn = jnp.sqrt(jnp.maximum(jnp.min(d2, axis=-1), 1e-12))
    scale = target_nn / jnp.median(nn)
    return data * scale, queries * scale, float(scale)
