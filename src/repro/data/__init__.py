"""Data substrate: vector datasets for ANN benchmarks + token pipelines for LM training."""

from .vectors import make_clustered, make_uniform, normalize_scale, paper_dataset_specs

__all__ = ["make_clustered", "make_uniform", "normalize_scale", "paper_dataset_specs"]
