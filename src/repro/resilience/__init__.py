"""repro.resilience — fault injection, degradation, and recovery.

The robustness layer for the serving + persistence stack:

* ``faults``      — deterministic, seedable :class:`FaultPlan` with
  named injection sites threaded (behind a no-op default) through the
  checkpointer, the service dispatch path, and sharded search:
  ``snapshot.write.torn@<byte>``, ``snapshot.write.crash@<stage>``,
  ``snapshot.read.corrupt``, ``dispatch.raise``, ``dispatch.delay_ms``,
  ``shard.straggle``.
* ``degrade``     — :class:`BrownoutController`: consumes ``SLOWatch``
  check outcomes and walks the degradation ladder (cap termination
  steps → force FixedSchedule → shed lowest-weight tenants), flagging
  every touched ticket ``degraded=True`` and healing automatically.
* ``stragglers``  — the EWMA :class:`StragglerMonitor`, shared by the
  training supervisor (``runtime.fault_tolerance`` re-exports it) and
  the service's per-collection batch-duration watch.

Contracts: DESIGN.md §11.  The chaos benchmark
(``benchmarks/store_throughput.py --chaos``) runs the scripted fault
matrix against the full stack and gates on "no ticket ever lost or
hung, no wrong non-flagged result, brownout holds the p99".
"""

from .degrade import BrownoutController
from .faults import (
    SNAPSHOT_CRASH_STAGES,
    SNAPSHOT_WRITE_SITES,
    FaultError,
    FaultPlan,
    FaultSpec,
    SimulatedCrash,
)
from .stragglers import StragglerMonitor

__all__ = [
    "BrownoutController",
    "FaultError",
    "FaultPlan",
    "FaultSpec",
    "SNAPSHOT_CRASH_STAGES",
    "SNAPSHOT_WRITE_SITES",
    "SimulatedCrash",
    "StragglerMonitor",
    "faults",
]

from . import faults  # noqa: E402  (the module itself is part of the API)
