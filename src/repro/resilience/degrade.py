"""Brownout: SLO-breach-driven degradation ladder for the store service.

PR 6's ``SLOWatch`` emits ``BreachEvent``s that *name* a remediation but
nothing consumed them; :class:`BrownoutController` closes the loop.  It
registers itself on a ``StoreService`` (``svc.brownout = self``) and
subscribes to the watch via ``attach(slo)`` (the ``on_check`` hook), then
walks a ladder one rung per breached check, healing one rung back per
``heal_after`` consecutive clean checks:

  level 0  healthy — plans pass through untouched
  level 1  cap termination steps: ``steps = max(floor,
           ceil(steps * step_cap_frac))``, adaptive termination kept —
           DB-LSH's window schedule is the knob, recall degrades
           continuously while C1/C2 certification still runs on the
           shorter schedule
  level 2  force a FixedSchedule at ``floor_steps`` (termination
           dropped): the cheapest deterministic plan, no adaptive
           machinery on the hot path
  level 3  shed lowest-weight tenants: ``submit`` raises
           :class:`~repro.store.service.BrownoutShed` for tenants below
           the max configured quota weight (equal weights shed nobody —
           there is no "lowest")

Every plan the controller touches marks its ticket ``degraded=True`` —
the caller always knows a result was served reduced-recall.  The
controller never mutates resolved state retroactively: it intercepts
plans at submit time only, so in-flight tickets keep the plan they were
admitted with.

This module deliberately imports nothing from ``repro.store`` (the
service imports ``repro.resilience``); the service is duck-typed —
anything with ``registry`` and a ``brownout`` slot works.
"""

from __future__ import annotations

import math

from ..tune.policy import ResolvedPlan

__all__ = ["BrownoutController"]


class BrownoutController:
    """Walks the degradation ladder on SLO breaches.

    ``hold_s`` rate-limits escalation (at most one rung per ``hold_s``
    seconds of breached checks) so a single bad window cannot slam the
    service to shedding; ``heal_after`` consecutive clean checks heal
    one rung."""

    def __init__(self, service, *, step_cap_frac: float = 0.5,
                 floor_steps: int = 1, heal_after: int = 3,
                 hold_s: float = 0.0, max_level: int = 3):
        assert 0.0 < step_cap_frac <= 1.0
        assert floor_steps >= 1 and 1 <= max_level <= 3
        self.service = service
        self.step_cap_frac = step_cap_frac
        self.floor_steps = floor_steps
        self.heal_after = heal_after
        self.hold_s = hold_s
        self.max_level = max_level
        self.level = 0
        self.transitions: list[tuple[float, int]] = []  # (t, new_level)
        self._clean_streak = 0
        self._t_escalated: float | None = None
        self._gauge = service.registry.gauge(
            "repro_store_brownout_level",
            "Current brownout ladder rung (0 = healthy)",
        )
        self._gauge.set(0)
        service.brownout = self

    # ---------------------------------------------------------- subscription
    def attach(self, slo) -> "BrownoutController":
        """Subscribe to an ``SLOWatch`` — every ``check()`` (breached or
        clean) reaches :meth:`observe`, which is what lets the ladder
        heal: breach events alone never say "the window is healthy"."""
        slo.on_check = self.observe
        return self

    def observe(self, events, now: float) -> None:
        """One SLO check's outcome: a non-empty ``events`` list is a
        breached window (escalate), an empty one is clean (heal)."""
        if events:
            self._clean_streak = 0
            held = (
                self._t_escalated is not None
                and (now - self._t_escalated) < self.hold_s
            )
            if self.level < self.max_level and not held:
                self._set_level(self.level + 1, now)
                self._t_escalated = now
        else:
            self._clean_streak += 1
            if self.level > 0 and self._clean_streak >= self.heal_after:
                self._set_level(self.level - 1, now)
                self._clean_streak = 0

    def _set_level(self, level: int, now: float) -> None:
        self.level = level
        self._gauge.set(level)
        self.transitions.append((now, level))

    # ------------------------------------------------------- plan intercepts
    def apply_plan(self, plan: ResolvedPlan) -> tuple[ResolvedPlan, bool]:
        """Degrade a freshly resolved plan per the current rung; returns
        (plan, degraded)."""
        if self.level == 0:
            return plan, False
        if self.level == 1:
            steps = max(self.floor_steps,
                        math.ceil(plan.steps * self.step_cap_frac))
            if steps >= plan.steps:
                return plan, False
            return (
                ResolvedPlan(r0=plan.r0, steps=steps,
                             termination=plan.termination),
                True,
            )
        # level >= 2: the floor plan, fixed — termination dropped so the
        # dispatch runs the plain FixedSchedule program
        if plan.steps <= self.floor_steps and plan.termination is None:
            return plan, False
        return (
            ResolvedPlan(r0=plan.r0,
                         steps=min(plan.steps, self.floor_steps)),
            True,
        )

    def should_shed(self, tenant: str) -> bool:
        """Level 3: shed tenants strictly below the max configured quota
        weight.  All-equal weights (including the no-quota default)
        shed nobody."""
        if self.level < 3:
            return False
        quotas = self.service.quotas
        if not quotas:
            return False
        top = max(q.weight for q in quotas.values())
        mine = quotas[tenant].weight if tenant in quotas else 1
        return mine < top
