"""EWMA straggler detection — shared by training and serving.

Lifted out of ``repro.runtime.fault_tolerance`` (which re-exports it for
backward compatibility) so the store can feed it batch dispatch
durations: the serving loop records each in-flight batch's
issue→complete wall time and flags batches that blow out the rolling
baseline — in a sharded deployment the classic signature of one
straggling shard holding the cross-shard merge hostage (the
``shard.straggle`` fault site in :mod:`repro.resilience.faults`
injects exactly that).
"""

from __future__ import annotations

__all__ = ["StragglerMonitor"]


class StragglerMonitor:
    """EWMA-based step-time outlier detection."""

    def __init__(self, alpha: float = 0.1, threshold: float = 2.0, warmup: int = 3):
        self.alpha = alpha
        self.threshold = threshold
        self.warmup = warmup
        self.ewma = None
        self.count = 0
        self.flagged: list[tuple[int, float]] = []

    def record(self, step: int, duration: float) -> bool:
        """Returns True if this step is a straggler."""
        self.count += 1
        if self.ewma is None:
            self.ewma = duration
            return False
        is_slow = self.count > self.warmup and duration > self.threshold * self.ewma
        if is_slow:
            self.flagged.append((step, duration))
        else:
            # only fold non-outliers into the baseline
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * duration
        return is_slow
