"""Deterministic fault injection: named sites, seedable plans, no-op default.

Production failure modes — torn snapshot writes, bit-rot on restore,
dispatch exceptions, latency spikes, straggling shards — are rare by
design, which makes the *recovery* code the least-tested code in the
stack.  This module turns them into first-class, scriptable events: the
instrumented layers (``checkpoint.checkpointer``, ``store.service``,
``store.router``, ``store.lifecycle``) call :func:`fire` at **named
injection sites**, and a :class:`FaultPlan` installed via
:func:`active` decides, deterministically, which hits do what.  With no
plan installed every site is a single ``None`` check — the default path
stays a no-op and the serving stack is bit-equal to a build without
this module (pinned in ``tests/test_resilience.py``).

Sites (the injection vocabulary):

=========================  ==================================================
``snapshot.write.torn``    truncate the in-flight snapshot file at a byte
                           offset (``arg``) and crash — a torn write
``snapshot.write.crash``   crash the snapshot writer between file
                           operations; ``stage`` ctx selects the kill point
                           (:data:`SNAPSHOT_CRASH_STAGES`)
``snapshot.read.corrupt``  flip a byte (offset ``arg``) in the bytes a
                           restore just read — bit-rot / torn read
``dispatch.raise``         raise :class:`FaultError` in the service's issue
                           stage (``transient`` controls retryability)
``dispatch.delay_ms``      sleep ``arg * ctx[scale]`` milliseconds in the
                           issue stage — an injected latency spike that
                           scales with the schedule the batch runs
``shard.straggle``         same delay, fired from the sharded search path —
                           one slow shard holding the merge hostage
=========================  ==================================================

A plan is a list of :class:`FaultSpec` triggers.  Each spec counts *its
own* matching hits: ``at`` skips the first ``at`` hits, ``count`` fires
for the next ``count`` (``math.inf`` = forever), and keyword filters
must equal the ctx the site reports (``plan.add("snapshot.write.torn",
file="arr_0.npy", arg=128)``).  Everything a plan does is recorded in
``plan.fired`` so tests and the chaos benchmark can assert the script
actually ran.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time

__all__ = [
    "FaultError",
    "FaultPlan",
    "FaultSpec",
    "SNAPSHOT_CRASH_STAGES",
    "SNAPSHOT_WRITE_SITES",
    "SimulatedCrash",
    "active",
    "active_plan",
    "fire",
    "install",
    "uninstall",
]

#: kill points inside ``Checkpointer._write`` for ``snapshot.write.crash``
#: (ctx key ``stage``), in write order.  ``pre_manifest``: arrays written,
#: manifest not; ``pre_rename``: tmp dir complete but not committed;
#: ``post_rename``: committed but LATEST still names the previous step;
#: ``post_latest``: committed + published, GC never ran.
SNAPSHOT_CRASH_STAGES = (
    "pre_manifest",
    "pre_rename",
    "post_rename",
    "post_latest",
)

#: the snapshot *write* lane — every site at which the crash-consistency
#: property test kills the writer (torn is additionally parametrized by
#: file and byte offset, crash by stage).
SNAPSHOT_WRITE_SITES = ("snapshot.write.torn", "snapshot.write.crash")


class FaultError(RuntimeError):
    """An injected failure.  ``transient`` marks it retryable — the
    service's dispatch retry loop only retries errors whose
    ``transient`` attribute is true."""

    def __init__(self, site: str, message: str = "", *,
                 transient: bool = True):
        super().__init__(message or f"injected fault at {site!r}")
        self.site = site
        self.transient = transient


class SimulatedCrash(FaultError):
    """A process-death stand-in (never retryable): the writer stops
    mid-sequence exactly as a SIGKILL would, leaving whatever bytes and
    directory entries already hit the filesystem."""

    def __init__(self, site: str, message: str = ""):
        super().__init__(site, message, transient=False)


@dataclasses.dataclass
class FaultSpec:
    """One trigger: fire at matching hits ``[at, at + count)``."""

    site: str
    at: int = 0
    count: float = 1
    arg: float | None = None      # byte offset / ms-per-scale, per site
    transient: bool = True
    match: dict = dataclasses.field(default_factory=dict)
    hits: int = 0                 # matching hits seen so far (mutates)

    def consume(self, ctx: dict) -> bool:
        """True when this hit is inside the firing window."""
        for key, want in self.match.items():
            if ctx.get(key) != want:
                return False
        n = self.hits
        self.hits += 1
        return self.at <= n < self.at + self.count


class FaultPlan:
    """A deterministic script of injected faults.

    ``sleep`` is injectable so tests can assert delay sites without
    wall-clock waits; ``seed`` is carried for plans that want to derive
    pseudo-random offsets up front (the plan itself never draws
    randomness at fire time — determinism is the point)."""

    def __init__(self, *, seed: int = 0, sleep=time.sleep):
        self.seed = seed
        self._sleep = sleep
        self.specs: list[FaultSpec] = []
        self.fired: list[tuple[str, dict]] = []

    def add(self, site: str, *, at: int = 0, count: float = 1,
            arg: float | None = None, transient: bool = True,
            **match) -> "FaultPlan":
        """Register a trigger; returns ``self`` for chaining."""
        self.specs.append(FaultSpec(
            site=site, at=at, count=count, arg=arg,
            transient=transient, match=match,
        ))
        return self

    def reset(self) -> "FaultPlan":
        """Rewind every spec's hit counter (reuse a script verbatim)."""
        for s in self.specs:
            s.hits = 0
        self.fired.clear()
        return self

    # ------------------------------------------------------------------ fire
    def fire(self, site: str, **ctx):
        """Evaluate ``site`` against the plan.

        Raise-type sites raise; delay sites sleep and return the delay
        (ms); torn/corrupt sites return the byte offset the caller must
        apply.  ``None`` means: not firing, proceed normally.

        Every matching spec consumes the hit *before* any spec acts, so
        one spec raising cannot stall another's counter — each spec's
        window is a deterministic function of the hit sequence alone."""
        firing = [
            s for s in self.specs if s.site == site and s.consume(ctx)
        ]
        result = None
        for spec in firing:
            self.fired.append((site, dict(ctx)))
            if site == "dispatch.raise":
                raise FaultError(site, transient=spec.transient)
            if site == "snapshot.write.crash":
                raise SimulatedCrash(
                    site, f"simulated crash at stage {ctx.get('stage')!r}"
                )
            if site in ("dispatch.delay_ms", "shard.straggle"):
                delay = float(spec.arg or 0.0) * float(ctx.get("scale", 1.0))
                if delay > 0:
                    self._sleep(delay / 1e3)
                result = delay
            else:  # snapshot.write.torn / snapshot.read.corrupt
                result = 0 if spec.arg is None else spec.arg
        return result


# --------------------------------------------------------------- active plan
_ACTIVE: FaultPlan | None = None


def install(plan: FaultPlan) -> FaultPlan:
    """Make ``plan`` the process-wide active plan (replaces any)."""
    global _ACTIVE
    _ACTIVE = plan
    return plan


def uninstall() -> None:
    global _ACTIVE
    _ACTIVE = None


def active_plan() -> FaultPlan | None:
    """The currently installed plan, or ``None``.  Observability reads
    this (never mutates): the service's EXPLAIN path snapshots
    ``len(plan.fired)`` around a dispatch to attribute fault sites hit
    to the batch that hit them."""
    return _ACTIVE


@contextlib.contextmanager
def active(plan: FaultPlan):
    """``with faults.active(plan):`` — install for the block, restoring
    the previous plan (usually none) on exit, even through the injected
    exceptions the block exists to raise."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = plan
    try:
        yield plan
    finally:
        _ACTIVE = prev


def fire(site: str, **ctx):
    """The site hook the instrumented layers call.  No active plan —
    the production default — is a single attribute check."""
    plan = _ACTIVE
    if plan is None:
        return None
    return plan.fire(site, **ctx)
