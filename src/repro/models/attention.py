"""GQA / MHA / sliding-window / cross attention with KV caches.

Layouts:
  activations  (B, T, D)
  q/k/v        (B, T, H|KV, hd)
  KV cache     (B, S, KV, hd)  — ring buffer of size `window` for SWA

TP: heads shard over the mesh 'model' axis; when KV-head count is
smaller than the axis, KV projections are replicated (standard GQA TP).
Softmax runs in fp32 regardless of activation dtype.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import apply_rope, constrain, dense_init

NEG = -1e30


def attn_params(key, d_model, n_heads, n_kv, head_dim, d_out=None, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    d_out = d_out or d_model
    return {
        "wq": dense_init(ks[0], (d_model, n_heads, head_dim), d_model, dtype),
        "wk": dense_init(ks[1], (d_model, n_kv, head_dim), d_model, dtype),
        "wv": dense_init(ks[2], (d_model, n_kv, head_dim), d_model, dtype),
        "wo": dense_init(ks[3], (n_heads, head_dim, d_out), n_heads * head_dim, dtype),
    }


def _qkv(x, p, kv_src=None):
    kv_src = x if kv_src is None else kv_src
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_src, p["wv"])
    return q, k, v


def _attn_internal_spec(KV, G, T, mesh):
    """Where to put the 'model' axis inside attention.

    Preferred: the joint head dim H = KV*G (standard Megatron TP — both
    forward and backward einsums partition cleanly). When H doesn't
    divide, fall back to the query-sequence dim (context parallelism; T
    is a multiple of the axis for every assigned shape)."""
    if mesh is None or "model" not in mesh.axis_names:
        return None
    tp = mesh.shape["model"]
    if tp == 1:
        return None
    if (KV * G) % tp == 0:
        return "h"
    if T % tp == 0:
        return "t"
    return None


def _h_layout_scores(q, k):
    """Scores in (B, H, T, S) layout with k broadcast to H heads — the
    joint head dim shards over 'model' without per-dim divisibility
    games on (KV, G)."""
    B, T, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    krep = jnp.broadcast_to(
        k[:, :, :, None, :], (B, k.shape[1], KV, G, hd)
    ).reshape(B, k.shape[1], H, hd)
    return jnp.einsum("bthd,bshd->bhts", q, krep) / jnp.sqrt(float(hd))


def _h_layout_out(scores, v, wo):
    """scores (B,H,T,S), v (B,S,KV,hd) -> (B,T,D)."""
    B, H, T, S = scores.shape
    KV = v.shape[2]
    G = H // KV
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(v.dtype)
    vrep = jnp.broadcast_to(
        v[:, :, :, None, :], (B, S, KV, G, v.shape[-1])
    ).reshape(B, S, H, v.shape[-1])
    ctx = jnp.einsum("bhts,bshd->bthd", probs, vrep)
    return jnp.einsum("bthk,hkd->btd", ctx, wo)


def _gqa_scores(q, k):
    """q: (B,T,H,hd), k: (B,S,KV,hd) -> scores (B,KV,G,T,S), G = H/KV."""
    B, T, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, T, KV, G, hd)
    return jnp.einsum("btkgh,bskh->bkgts", qg, k) / jnp.sqrt(float(hd))


def _gqa_out(scores, v, wo):
    """scores (B,KV,G,T,S), v (B,S,KV,hd) -> (B,T,D)."""
    B, KV, G, T, S = scores.shape
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(v.dtype)
    ctx = jnp.einsum("bkgts,bskh->btkgh", probs, v)
    ctx = ctx.reshape(B, T, KV * G, v.shape[-1])
    return jnp.einsum("bthk,hkd->btd", ctx, wo)


CHUNKED_THRESHOLD = 16384  # use online-softmax KV chunking past this S


def _kv_chunked_context(q, k, v, *, causal, window, ck=1024):
    """Flash-style online-softmax attention: scan over KV chunks.

    Memory O(T * ck) instead of O(T * S) — the lever that fits the
    prefill_32k cells. q: (B,T,H,hd) (RoPE applied); k/v: (B,S,KV,hd).
    Returns ctx (B,T,H,hd). fp32 running (max, denom, acc)."""
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    ck = min(ck, S)
    pad = (-S) % ck
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nk = (S + pad) // ck
    qpos = jax.lax.broadcasted_iota(jnp.int32, (T, ck), 0)
    scale = 1.0 / jnp.sqrt(float(hd))

    def step(carry, kj):
        m, l, acc = carry  # (B,H,T) f32, (B,H,T) f32, (B,T,H,hd) f32
        kb = jax.lax.dynamic_slice_in_dim(k, kj * ck, ck, 1)  # (B,ck,KV,hd)
        vb = jax.lax.dynamic_slice_in_dim(v, kj * ck, ck, 1)
        krep = jnp.broadcast_to(
            kb[:, :, :, None, :], (B, ck, KV, G, hd)
        ).reshape(B, ck, H, hd)
        vrep = jnp.broadcast_to(
            vb[:, :, :, None, :], (B, ck, KV, G, hd)
        ).reshape(B, ck, H, hd)
        s = jnp.einsum("bthd,bshd->bhts", q, krep).astype(jnp.float32) * scale
        kpos = kj * ck + jax.lax.broadcasted_iota(jnp.int32, (T, ck), 1)
        ok = kpos < S  # padding
        if causal:
            ok &= qpos >= kpos
        if window:
            ok &= (qpos - kpos) < window
        s = jnp.where(ok[None, None], s, -jnp.inf)
        mnew = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard: fully-masked rows keep m = -inf; exp(-inf - -inf) -> nan
        safe_m = jnp.where(jnp.isfinite(mnew), mnew, 0.0)
        pexp = jnp.exp(s - safe_m[..., None])
        pexp = jnp.where(ok[None, None], pexp, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - safe_m), 0.0)
        l = l * corr + jnp.sum(pexp, axis=-1)
        upd = jnp.einsum("bhts,bshd->bthd", pexp.astype(v.dtype), vrep)
        acc = acc * jnp.moveaxis(corr, 1, 2)[..., None] + upd.astype(jnp.float32)
        return (mnew, l, acc), None

    init = (
        jnp.full((B, H, T), -jnp.inf, jnp.float32),
        jnp.zeros((B, H, T), jnp.float32),
        jnp.zeros((B, T, H, hd), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(step, init, jnp.arange(nk))
    denom = jnp.maximum(jnp.moveaxis(l, 1, 2), 1e-30)[..., None]
    return (acc / denom).astype(q.dtype)


def attention(x, p, positions, *, causal=True, window=0, rope_theta=1e4,
              kv_positions=None, use_rope=True, mesh=None):
    """Full-sequence attention (train / prefill).

    x: (B, T, D); positions: (B, T) int32. Returns (B, T, D) plus the
    (k, v) tensors for cache seeding."""
    q, k, v = _qkv(x, p)
    if use_rope:
        kv_pos = positions if kv_positions is None else kv_positions
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, kv_pos, rope_theta)
    B, T, H, hd = q.shape
    KV = k.shape[2]
    where = _attn_internal_spec(KV, H // KV, T, mesh)
    dp = ("pod", "data")
    S = k.shape[1]

    if S >= CHUNKED_THRESHOLD:
        # long-context path: O(T*ck) online-softmax scan over KV chunks
        if where == "h":
            q = constrain(q, dp, None, "model", None, mesh=mesh)
        elif where == "t":
            q = constrain(q, dp, "model", None, None, mesh=mesh)
        ctx = _kv_chunked_context(q, k, v, causal=causal, window=window)
        out = jnp.einsum("bthk,hkd->btd", ctx, p["wo"])
        return constrain(out, dp, None, None, mesh=mesh), (k, v)

    i = jax.lax.broadcasted_iota(jnp.int32, (T, S), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (T, S), 1)
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= i >= j
    if window:
        mask &= (i - j) < window

    if where == "h":
        scores = _h_layout_scores(q, k)  # (B,H,T,S)
        scores = constrain(scores, dp, "model", None, None, mesh=mesh)
        scores = jnp.where(mask, scores, NEG)
        out = _h_layout_out(scores, v, p["wo"])
    else:
        if where == "t":
            q = constrain(q, dp, "model", None, None, mesh=mesh)
        scores = _gqa_scores(q, k)  # (B,KV,G,T,S)
        if where == "t":
            scores = constrain(scores, dp, None, None, "model", None, mesh=mesh)
        scores = jnp.where(mask, scores, NEG)
        out = _gqa_out(scores, v, p["wo"])
    out = constrain(out, dp, None, None, mesh=mesh)
    return out, (k, v)


def cross_attention(x, p, kv_src, mesh=None):
    """Cross attention (decoder -> encoder states / image embeddings).
    No RoPE on cross projections (Whisper / Llama-Vision convention)."""
    q, k, v = _qkv(x, p, kv_src=kv_src)
    B, T, H, hd = q.shape
    KV = k.shape[2]
    where = _attn_internal_spec(KV, H // KV, T, mesh)
    dp = ("pod", "data")
    if where == "h":
        scores = _h_layout_scores(q, k)
        scores = constrain(scores, dp, "model", None, None, mesh=mesh)
        out = _h_layout_out(scores, v, p["wo"])
    else:
        if where == "t":
            q = constrain(q, dp, "model", None, None, mesh=mesh)
        scores = _gqa_scores(q, k)
        if where == "t":
            scores = constrain(scores, dp, None, None, "model", None, mesh=mesh)
        out = _gqa_out(scores, v, p["wo"])
    out = constrain(out, dp, None, None, mesh=mesh)
    return out, (k, v)


# ---------------------------------------------------------------------------
# decode with KV cache
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CacheSpec:
    batch: int
    size: int  # cache slots (= seq len, or window for SWA)
    n_kv: int
    head_dim: int
    window: int  # 0 = full


def init_cache(spec: CacheSpec, dtype):
    shape = (spec.batch, spec.size, spec.n_kv, spec.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_attention(x1, p, cache, pos, *, window=0, rope_theta=1e4, use_rope=True):
    """Single-token decode. x1: (B, 1, D); pos: scalar int32 or (B,) int32
    (per-slot positions — continuous batching); cache k/v: (B, S, KV, hd)
    (ring buffer when SWA).

    Returns (out (B,1,D), new_cache)."""
    B = x1.shape[0]
    S = cache["k"].shape[1]
    q = jnp.einsum("btd,dhk->bthk", x1, p["wq"])
    k1 = jnp.einsum("btd,dhk->bthk", x1, p["wk"])
    v1 = jnp.einsum("btd,dhk->bthk", x1, p["wv"])
    posv = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(pos, jnp.int32)), (B,))
    posb = posv[:, None]
    if use_rope:
        q = apply_rope(q, posb, rope_theta)
        k1 = apply_rope(k1, posb, rope_theta)
    slot = jnp.mod(posv, S) if window else posv  # (B,)
    upd = jax.vmap(
        lambda c, new, s: jax.lax.dynamic_update_slice(c, new, (s, 0, 0))
    )
    ck = upd(cache["k"], k1.astype(cache["k"].dtype), slot)
    cv = upd(cache["v"], v1.astype(cache["v"].dtype), slot)

    scores = _gqa_scores(q, ck)  # (B,KV,G,1,S)
    j = jax.lax.broadcasted_iota(jnp.int32, (1, S), 1)  # (1,S)
    if window:
        # Ring buffer: slot j holds the most recent position p ≡ j (mod S)
        # with p <= pos, i.e. p_j = pos - ((slot - j) mod S). Valid iff it
        # was ever written (p_j >= 0); S == window bounds the lookback.
        p_j = posv[:, None] - jnp.mod(slot[:, None] - j, S)  # (B,S)
        mask = p_j >= 0
    else:
        mask = j <= posv[:, None]  # (B,S)
    scores = jnp.where(mask[:, None, None, None, :], scores, NEG)
    out = _gqa_out(scores, cv, p["wo"])
    return out, {"k": ck, "v": cv}


def decode_cross_attention(x1, p, cache):
    """Decode-time cross attention against a precomputed (k, v) cache."""
    q = jnp.einsum("btd,dhk->bthk", x1, p["wq"])
    scores = _gqa_scores(q, cache["k"])
    return _gqa_out(scores, cache["v"], p["wo"])
