"""Dense (SwiGLU / GELU) and Mixture-of-Experts FFN layers.

MoE design (EP over the mesh 'model' axis, DESIGN.md §6):

  * tokens stay sharded over the data axes and are *replicated* over the
    model axis inside a ``shard_map`` block;
  * each model-rank owns E/tp experts; it routes all its local tokens,
    keeps the assignments that target its own experts, and packs them
    into a fixed-capacity (E_local, C, D) buffer with a sort-based
    MegaBlocks-style dispatch (stable argsort by expert id, rank-in-group
    via cummax, fixed-capacity compaction — no data-dependent shapes);
  * after the per-expert matmuls the partial outputs are combined with a
    single psum over 'model' — the same collective profile as a
    Megatron row-parallel FFN, with no all-to-all.

The identical `_moe_local` path runs unsharded on one device (smoke
tests) — shard_map is only entered when the mesh's EP axis size > 1.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from .common import dense_init

__all__ = ["dense_ffn_params", "dense_ffn", "moe_params", "moe_ffn"]


# ---------------------------------------------------------------------------
# dense
# ---------------------------------------------------------------------------


def dense_ffn_params(key, d_model, d_ff, kind="swiglu", dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(ks[0], (d_model, d_ff), d_model, dtype),
        "w_down": dense_init(ks[1], (d_ff, d_model), d_ff, dtype),
    }
    if kind == "swiglu":
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff), d_model, dtype)
    return p


def dense_ffn(x, p, kind="swiglu"):
    up = jnp.einsum("...d,df->...f", x, p["w_up"])
    if kind == "swiglu":
        gate = jnp.einsum("...d,df->...f", x, p["w_gate"])
        h = jax.nn.silu(gate) * up
    else:  # gelu
        h = jax.nn.gelu(up)
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def moe_params(key, d_model, d_ff, n_experts, kind="swiglu", dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (d_model, n_experts), d_model, jnp.float32),
        "w_up": dense_init(ks[1], (n_experts, d_model, d_ff), d_model, dtype),
        "w_down": dense_init(ks[2], (n_experts, d_ff, d_model), d_ff, dtype),
    }
    if kind == "swiglu":
        p["w_gate"] = dense_init(ks[3], (n_experts, d_model, d_ff), d_model, dtype)
    return p


def _moe_local(x, ids, wts, w_up, w_gate, w_down, *, capacity, n_local, first_eid,
               kind="swiglu"):
    """Sort-based dispatch -> per-expert matmuls -> weighted combine.

    x: (T, D); ids/wts: (T, k) global expert assignments; the caller owns
    experts [first_eid, first_eid + n_local). Fixed shapes throughout.
    """
    T, D = x.shape
    k = ids.shape[1]
    Tk = T * k
    EC = n_local * capacity

    flat_e = ids.reshape(-1) - first_eid
    flat_w = wts.reshape(-1)
    tok = jnp.arange(Tk, dtype=jnp.int32) // k
    mine = (flat_e >= 0) & (flat_e < n_local)
    sort_key = jnp.where(mine, flat_e, n_local)
    order = jnp.argsort(sort_key, stable=True)
    e_s = jnp.take(sort_key, order)
    tok_s = jnp.take(tok, order)
    w_s = jnp.take(flat_w, order)

    idx = jnp.arange(Tk, dtype=jnp.int32)
    firsts = jnp.concatenate([jnp.ones((1,), bool), e_s[1:] != e_s[:-1]])
    group_start = jax.lax.cummax(jnp.where(firsts, idx, -1))
    rank = idx - group_start
    keep = (rank < capacity) & (e_s < n_local)

    # fixed-capacity compaction: all kept rows fit in EC slots
    sel = jnp.argsort(~keep, stable=True)[:EC]
    sel_keep = jnp.take(keep, sel)
    sel_tok = jnp.take(tok_s, sel)
    sel_slot = jnp.where(sel_keep, jnp.take(e_s, sel) * capacity + jnp.take(rank, sel), EC)
    sel_w = jnp.where(sel_keep, jnp.take(w_s, sel), 0.0)

    x_sel = jnp.take(x, sel_tok, axis=0) * sel_keep[:, None].astype(x.dtype)
    buf = jnp.zeros((EC + 1, D), x.dtype).at[sel_slot].set(x_sel, mode="drop")
    buf = buf[:EC].reshape(n_local, capacity, D)

    up = jnp.einsum("ecd,edf->ecf", buf, w_up)
    if kind == "swiglu":
        gate = jnp.einsum("ecd,edf->ecf", buf, w_gate)
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    y = jnp.einsum("ecf,efd->ecd", h, w_down).reshape(EC, D)

    y_sel = jnp.take(y, jnp.minimum(sel_slot, EC - 1), axis=0)
    contrib = y_sel * (sel_w * sel_keep)[:, None].astype(y.dtype)
    out = jnp.zeros((T, D), x.dtype).at[sel_tok].add(contrib, mode="drop")
    return out


def moe_ffn(x, p, cfg, mesh=None, dp_axes=("data",), ep_axis="model"):
    """MoE FFN. x: (B, T, D). Returns (out, aux) with the Switch
    load-balancing loss in aux."""
    B, T, D = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    xf = x.reshape(B * T, D)

    logits = (xf.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)
    top_w = (top_w / jnp.sum(top_w, axis=-1, keepdims=True)).astype(x.dtype)
    top_i = top_i.astype(jnp.int32)

    # Switch load-balance aux: E * sum_e f_e * p_e
    frac = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_i, E, dtype=jnp.float32), axis=1), axis=0
    )
    aux = {"load_balance": E * jnp.sum(frac * jnp.mean(probs, axis=0))}

    kind = "swiglu" if "w_gate" in p else "gelu"
    w_gate = p.get("w_gate")
    tp = 1 if mesh is None else mesh.shape.get(ep_axis, 1)

    if tp == 1:
        cap = max(4, math.ceil(B * T * k / E * cfg.moe_capacity_factor))
        out = _moe_local(
            xf, top_i, top_w, p["w_up"], w_gate, p["w_down"],
            capacity=cap, n_local=E, first_eid=0, kind=kind,
        )
    else:
        n_local = E // tp
        dp = math.prod(mesh.shape[a] for a in dp_axes)
        cap = max(4, math.ceil(B * T * k / dp / E * cfg.moe_capacity_factor))

        # reduce-scatter the combined output straight into the
        # sequence-sharded residual layout when divisibility allows
        # (§Perf B2): half the all-reduce wire bytes and no post-MoE
        # reshard against sp_residual.
        rows_local = B * T // dp
        use_rs = (rows_local % tp == 0) and getattr(cfg, "moe_reduce_scatter", False)

        def shard_fn(xs, ids, wts, wu, wg, wd):
            rank = jax.lax.axis_index(ep_axis)
            args = (wu, wg, wd) if kind == "swiglu" else (wu, None, wd)
            part = _moe_local(
                xs, ids, wts, args[0], args[1], args[2],
                capacity=cap, n_local=n_local, first_eid=rank * n_local,
                kind=kind,
            )
            if use_rs:
                return jax.lax.psum_scatter(part, ep_axis, scatter_dimension=0,
                                            tiled=True)
            return jax.lax.psum(part, ep_axis)

        dspec = P(dp_axes if len(dp_axes) > 1 else dp_axes[0], None)
        ospec = P(tuple(dp_axes) + (ep_axis,), None) if use_rs else dspec
        espec = P(ep_axis, None, None)
        in_specs = (dspec, dspec, dspec, espec, espec, espec)
        if kind != "swiglu":
            in_specs = (dspec, dspec, dspec, espec, P(), espec)
        out = shard_map(
            shard_fn, mesh=mesh, in_specs=in_specs, out_specs=ospec,
        )(xf, top_i, top_w, p["w_up"],
          w_gate if w_gate is not None else jnp.zeros((), x.dtype), p["w_down"])

    return out.reshape(B, T, D), aux
