"""Unified decoder-only transformer covering the dense / MoE / SSM /
hybrid families, with scan-over-layers (small HLO, fast SPMD compiles)
and per-layer remat.

Block wiring by family (pre-norm residual):

  dense : x + attn(n1(x));  h + ffn(n2(h))
  moe   : x + attn(n1(x));  h + moe(n2(h)) [+ dense_ffn(n2(h)) if
          cfg.dense_residual — Arctic's dense+MoE parallel residual]
  ssm   : x + ssd(n1(x))                        (Mamba-2: mixer-only stack)
  hybrid: x + 0.5(na(attn(n1 x)) + ns(ssd(n1 x))); h + ffn(n2 h)  (Hymba)

Hybrid models mix sliding-window and global-attention layers, whose KV
caches have different shapes — those run as a Python loop over layers;
uniform families run under ``lax.scan`` with stacked params.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from . import attention as attn
from . import ffn as ffn_mod
from . import ssm as ssm_mod
from .common import (compute_dtype, constrain, cross_entropy, dense_init,
                     embed_init, grad_cast, rmsnorm)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _zeros(shape):
    return jnp.zeros(shape, jnp.float32)


def init_block(key, cfg, kind=None):
    """One layer's params. kind defaults to cfg.family."""
    kind = kind or cfg.family
    ks = jax.random.split(key, 8)
    p = {"norm1": _zeros((cfg.d_model,))}
    if kind in ("dense", "moe", "hybrid"):
        p["attn"] = attn.attn_params(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd)
        p["norm2"] = _zeros((cfg.d_model,))
    if kind == "dense":
        p["ffn"] = ffn_mod.dense_ffn_params(ks[1], cfg.d_model, cfg.d_ff, cfg.ffn_kind)
    if kind == "moe":
        p["moe"] = ffn_mod.moe_params(ks[2], cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.ffn_kind)
        if cfg.dense_residual:
            p["ffn"] = ffn_mod.dense_ffn_params(ks[3], cfg.d_model, cfg.d_ff, cfg.ffn_kind)
    if kind in ("ssm", "hybrid"):
        p["ssm"] = ssm_mod.ssm_params(ks[4], cfg)
    if kind == "hybrid":
        p["ffn"] = ffn_mod.dense_ffn_params(ks[5], cfg.d_model, cfg.d_ff, cfg.ffn_kind)
        p["norm_a"] = _zeros((cfg.d_model,))
        p["norm_s"] = _zeros((cfg.d_model,))
    return p


def init_params(key, cfg):
    ke, kb, kh = jax.random.split(key, 3)
    blocks = jax.vmap(lambda k: init_block(k, cfg))(jax.random.split(kb, cfg.n_layers))
    p = {
        "embed": embed_init(ke, (cfg.padded_vocab, cfg.d_model)),
        "blocks": blocks,
        "final_norm": _zeros((cfg.d_model,)),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(kh, (cfg.d_model, cfg.padded_vocab), cfg.d_model)
    return p


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _layer_window(cfg, layer_idx):
    """Sliding window for a layer (0 = full attention)."""
    if not cfg.sliding_window:
        return 0
    if layer_idx in cfg.global_layers:
        return 0
    return cfg.sliding_window


def block_forward(x, bp, cfg, mesh=None, *, positions, window=0, want_cache=False):
    """Full-sequence block. Returns (x, cache, aux)."""
    aux = {}
    cache = {}
    fam = cfg.family
    if fam in ("dense", "moe", "hybrid"):
        h = rmsnorm(x, bp["norm1"], cfg.norm_eps)
        a_out, (k, v) = attn.attention(
            h, bp["attn"], positions, causal=True, window=window,
            rope_theta=cfg.rope_theta, mesh=mesh,
        )
        if want_cache:
            cache["k"], cache["v"] = k, v
    if fam == "hybrid":
        s_out, s_state, conv_tail = ssm_mod.ssm_forward(h, bp["ssm"], cfg, cfg.ssm_chunk)
        if want_cache:
            cache["ssm"], cache["conv"] = s_state, conv_tail
        mixed = 0.5 * (
            rmsnorm(a_out, bp["norm_a"], cfg.norm_eps)
            + rmsnorm(s_out, bp["norm_s"], cfg.norm_eps)
        )
        x = x + mixed
    elif fam in ("dense", "moe"):
        x = x + a_out
    elif fam == "ssm":
        h = rmsnorm(x, bp["norm1"], cfg.norm_eps)
        s_out, s_state, conv_tail = ssm_mod.ssm_forward(h, bp["ssm"], cfg, cfg.ssm_chunk)
        if want_cache:
            cache["ssm"], cache["conv"] = s_state, conv_tail
        return x + s_out, cache, aux

    h2 = rmsnorm(x, bp["norm2"], cfg.norm_eps)
    if fam == "moe":
        m_out, aux = ffn_mod.moe_ffn(h2, bp["moe"], cfg, mesh=mesh,
                                     dp_axes=_dp_axes(mesh))
        if cfg.dense_residual:
            m_out = m_out + ffn_mod.dense_ffn(h2, bp["ffn"], cfg.ffn_kind)
        x = x + m_out
    else:
        x = x + ffn_mod.dense_ffn(h2, bp["ffn"], cfg.ffn_kind)
    return x, cache, aux


def _tp_size(mesh):
    return mesh.shape.get("model", 1) if mesh is not None else 1


def _dp_axes(mesh):
    if mesh is None:
        return ("data",)
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _uniform_family(cfg):
    """scan-compatible: identical block pytree shapes across layers."""
    return not (cfg.sliding_window and cfg.global_layers)


def forward(params, tokens, cfg, mesh=None, *, want_cache=False, remat=True):
    """Token ids (B, T) -> (hidden (B,T,D), caches, aux)."""
    dt = compute_dtype(cfg)
    B, T = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    x = constrain(x, ("pod", "data"), None, None, mesh=mesh)
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

    aux_acc = {"load_balance": jnp.zeros((), jnp.float32)}
    if _uniform_family(cfg):
        window = cfg.sliding_window

        def body(carry, bp):
            x = grad_cast(carry, cfg.dtype)  # keep cross-layer grads bf16
            bp = jax.tree.map(lambda a: a.astype(dt) if a.dtype == jnp.float32 and a.ndim > 1 else a, bp)
            x, cache, aux = block_forward(
                x, bp, cfg, mesh, positions=positions, window=window,
                want_cache=want_cache,
            )
            if cfg.sp_residual and x.shape[1] % _tp_size(mesh) == 0:
                # Megatron-SP: the residual stream (and with it the remat
                # carry stack) lives sequence-sharded over 'model'; GSPMD
                # turns the surrounding psums into reduce-scatters.
                x = constrain(x, ("pod", "data"), "model", None, mesh=mesh)
            lb = aux.get("load_balance", jnp.zeros((), jnp.float32))
            return x, (cache, lb)

        if remat:
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        x, (caches, lbs) = jax.lax.scan(body, x, params["blocks"])
        aux_acc["load_balance"] = jnp.sum(lbs)
    else:
        caches = []
        for li in range(cfg.n_layers):
            bp = jax.tree.map(lambda a: a[li], params["blocks"])
            fn = partial(
                block_forward, cfg=cfg, mesh=mesh, positions=positions,
                window=_layer_window(cfg, li), want_cache=want_cache,
            )
            if remat:
                fn = jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
            x, cache, aux = fn(x, bp)
            if cfg.sp_residual and x.shape[1] % _tp_size(mesh) == 0:
                x = constrain(x, ("pod", "data"), "model", None, mesh=mesh)
            caches.append(cache)
            if "load_balance" in aux:
                aux_acc["load_balance"] += aux["load_balance"]

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, caches, aux_acc


def logits_fn(params, hidden, cfg, mesh=None):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("btd,dv->btv", hidden, w.astype(hidden.dtype))
    # vocab-sharded logits: keeps the (B,T,V) intermediate at 1/tp per
    # device through the CE (GSPMD psums the small logsumexp stats).
    return constrain(logits, ("pod", "data"), None, "model", mesh=mesh)


def loss_fn(params, batch, cfg, mesh=None):
    """Next-token CE. batch: {'tokens': (B,T), 'labels': (B,T)}."""
    hidden, _, aux = forward(params, batch["tokens"], cfg, mesh)
    logits = logits_fn(params, hidden, cfg, mesh)
    loss = cross_entropy(logits, batch["labels"], cfg.vocab_size)
    if cfg.family == "moe":
        loss = loss + 0.01 * aux["load_balance"] / cfg.n_layers
    return loss, {"ce": loss, "hidden": hidden}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def cache_spec(cfg, batch, seq_len):
    """Abstract cache structure for one layer stack (stacked when uniform)."""
    dt = compute_dtype(cfg)
    d_inner, H, P, N, conv_dim, _ = (
        ssm_mod.ssm_dims(cfg) if cfg.ssm_state else (0, 0, 0, 0, 0, 0)
    )

    def one_layer(window):
        c = {}
        if cfg.family in ("dense", "moe", "hybrid"):
            size = min(seq_len, window) if window else seq_len
            c["k"] = jax.ShapeDtypeStruct((batch, size, cfg.n_kv_heads, cfg.hd), dt)
            c["v"] = jax.ShapeDtypeStruct((batch, size, cfg.n_kv_heads, cfg.hd), dt)
        if cfg.family in ("ssm", "hybrid"):
            c["ssm"] = jax.ShapeDtypeStruct((batch, H, N, P), dt)
            c["conv"] = jax.ShapeDtypeStruct((batch, ssm_mod.CONV_W - 1, conv_dim), dt)
        return c

    if _uniform_family(cfg):
        one = one_layer(cfg.sliding_window)
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((cfg.n_layers,) + s.shape, s.dtype), one
        )
    return [one_layer(_layer_window(cfg, li)) for li in range(cfg.n_layers)]


def init_cache(cfg, batch, seq_len):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_spec(cfg, batch, seq_len)
    )


def block_decode(x1, bp, cfg, cache, pos, window=0, mesh=None):
    fam = cfg.family
    new_cache = dict(cache)
    if fam in ("dense", "moe", "hybrid"):
        h = rmsnorm(x1, bp["norm1"], cfg.norm_eps)
        a_out, kv = attn.decode_attention(
            h, bp["attn"], {"k": cache["k"], "v": cache["v"]}, pos,
            window=window, rope_theta=cfg.rope_theta,
        )
        new_cache["k"], new_cache["v"] = kv["k"], kv["v"]
    if fam == "hybrid":
        s_out, s_state, conv = ssm_mod.ssm_decode(h, bp["ssm"], cfg, cache["ssm"], cache["conv"])
        new_cache["ssm"], new_cache["conv"] = s_state, conv
        mixed = 0.5 * (
            rmsnorm(a_out, bp["norm_a"], cfg.norm_eps)
            + rmsnorm(s_out, bp["norm_s"], cfg.norm_eps)
        )
        x1 = x1 + mixed
    elif fam in ("dense", "moe"):
        x1 = x1 + a_out
    elif fam == "ssm":
        h = rmsnorm(x1, bp["norm1"], cfg.norm_eps)
        s_out, s_state, conv = ssm_mod.ssm_decode(h, bp["ssm"], cfg, cache["ssm"], cache["conv"])
        new_cache["ssm"], new_cache["conv"] = s_state, conv
        return x1 + s_out, new_cache

    h2 = rmsnorm(x1, bp["norm2"], cfg.norm_eps)
    if fam == "moe":
        m_out, _ = ffn_mod.moe_ffn(h2, bp["moe"], cfg, mesh=mesh,
                                   dp_axes=_dp_axes(mesh))
        if cfg.dense_residual:
            m_out = m_out + ffn_mod.dense_ffn(h2, bp["ffn"], cfg.ffn_kind)
        x1 = x1 + m_out
    else:
        x1 = x1 + ffn_mod.dense_ffn(h2, bp["ffn"], cfg.ffn_kind)
    return x1, new_cache


def decode(params, token, caches, pos, cfg, mesh=None):
    """One decode step. token: (B,) int32; caches from init_cache/prefill.
    Returns (logits (B, V), hidden (B, D), new caches)."""
    dt = compute_dtype(cfg)
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(dt)

    if _uniform_family(cfg):
        def body(x, inp):
            bp, cache = inp
            bp = jax.tree.map(lambda a: a.astype(dt) if a.dtype == jnp.float32 and a.ndim > 1 else a, bp)
            x, nc = block_decode(x, bp, cfg, cache, pos,
                                 window=cfg.sliding_window, mesh=mesh)
            return x, nc

        x, new_caches = jax.lax.scan(body, x, (params["blocks"], caches))
    else:
        new_caches = []
        for li in range(cfg.n_layers):
            bp = jax.tree.map(lambda a: a[li], params["blocks"])
            x, nc = block_decode(x, bp, cfg, caches[li], pos,
                                 window=_layer_window(cfg, li), mesh=mesh)
            new_caches.append(nc)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_fn(params, x, cfg, mesh)
    return logits[:, 0], x[:, 0], new_caches


def prefill(params, tokens, cfg, mesh=None, cache_len=None):
    """Prefill: forward with cache capture, padded to cache_len slots.
    Returns (logits last position (B, V), hidden (B,T,D), caches)."""
    hidden, caches, _ = forward(params, tokens, cfg, mesh, want_cache=True)
    B, T = tokens.shape
    cache_len = cache_len or T

    def expand(c, window):
        out = dict(c)
        if "k" in c:
            size = min(cache_len, window) if window else cache_len
            pad = size - T
            if pad > 0:
                out["k"] = jnp.pad(c["k"], ((0, 0), (0, pad), (0, 0), (0, 0)))
                out["v"] = jnp.pad(c["v"], ((0, 0), (0, pad), (0, 0), (0, 0)))
            elif pad < 0:
                # keep the last `size` positions; ring invariant: position
                # p lives at slot p % size
                out["k"] = jnp.roll(c["k"][:, -size:], T % size, axis=1)
                out["v"] = jnp.roll(c["v"][:, -size:], T % size, axis=1)
        return out

    if _uniform_family(cfg):
        caches = expand_stacked(caches, cfg, T, cache_len)
    else:
        caches = [expand(c, _layer_window(cfg, li)) for li, c in enumerate(caches)]
    logits = logits_fn(params, hidden[:, -1:], cfg, mesh)
    return logits[:, 0], hidden, caches


def expand_stacked(caches, cfg, T, cache_len):
    out = dict(caches)
    if "k" in caches:
        window = cfg.sliding_window
        size = min(cache_len, window) if window else cache_len
        pad = size - T
        if pad > 0:
            out["k"] = jnp.pad(caches["k"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            out["v"] = jnp.pad(caches["v"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        elif pad < 0:
            out["k"] = caches["k"][:, :, pad:]
            out["v"] = caches["v"][:, :, pad:]
    return out
