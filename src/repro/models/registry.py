"""Model assembly: family -> (init, loss, prefill, decode, input_specs).

``build_model(cfg)`` returns a Model whose functions are pure and
jit/pjit-able; ``mesh`` is threaded for layers that enter shard_map (MoE
EP). ``input_specs(shape, phase)`` produces ShapeDtypeStruct stand-ins
for the dry-run (no allocation)."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

import jax.numpy as jnp_  # noqa: F401

from . import encdec, ssm as ssm_mod, transformer, vlm
from .common import DTYPES, compute_dtype


def _cast_params(params, cfg):
    """Store >=2D weights in cfg.param_dtype (bf16 for the giant MoEs —
    the fp32 master lives in the optimizer when one is wanted)."""
    pd = DTYPES[cfg.param_dtype]
    import jax.numpy as jnp

    return jax.tree.map(
        lambda a: a.astype(pd) if (a.ndim > 1 and a.dtype == jnp.float32) else a,
        params,
    )
from ..configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[..., Any]
    loss: Callable[..., Any]  # (params, batch, mesh=None) -> (loss, metrics)
    prefill: Callable[..., Any]  # (params, batch, mesh=None, cache_len=None)
    decode: Callable[..., Any]  # (params, token, caches, pos, mesh=None)

    # -- dry-run support -----------------------------------------------------
    def input_specs(self, shape: ShapeConfig, batch_override: int = 0) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of the step
        implied by shape.phase ('train' | 'prefill' | 'decode')."""
        cfg = self.cfg
        B = batch_override or shape.global_batch
        S = shape.seq_len
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct
        dt = compute_dtype(cfg)

        def extras():
            e = {}
            if cfg.family == "encdec":
                e["frames"] = sds((B, cfg.enc_seq, cfg.d_model), dt)
            if cfg.family == "vlm":
                e["images"] = sds((B, cfg.n_img_tokens, cfg.d_vision), dt)
            return e

        if shape.phase == "train":
            return {
                "batch": {
                    "tokens": sds((B, S), i32),
                    "labels": sds((B, S), i32),
                    **extras(),
                }
            }
        if shape.phase == "prefill":
            return {"batch": {"tokens": sds((B, S), i32), **extras()}}
        if shape.phase == "decode":
            return {
                "token": sds((B,), i32),
                "caches": self.cache_specs(B, S),
                "pos": sds((), i32),
            }
        raise ValueError(shape.phase)

    def cache_specs(self, batch: int, seq_len: int):
        cfg = self.cfg
        dt = compute_dtype(cfg)
        sds = jax.ShapeDtypeStruct
        if cfg.family in ("dense", "moe", "ssm", "hybrid"):
            return transformer.cache_spec(cfg, batch, seq_len)
        if cfg.family == "encdec":
            L = cfg.n_layers
            return {
                "k": sds((L, batch, seq_len, cfg.n_kv_heads, cfg.hd), dt),
                "v": sds((L, batch, seq_len, cfg.n_kv_heads, cfg.hd), dt),
                "xk": sds((L, batch, cfg.enc_seq, cfg.n_kv_heads, cfg.hd), dt),
                "xv": sds((L, batch, cfg.enc_seq, cfg.n_kv_heads, cfg.hd), dt),
            }
        if cfg.family == "vlm":
            G = vlm.n_groups(cfg)
            E = cfg.cross_every
            return {
                "self": {
                    "k": sds((G, E, batch, seq_len, cfg.n_kv_heads, cfg.hd), dt),
                    "v": sds((G, E, batch, seq_len, cfg.n_kv_heads, cfg.hd), dt),
                },
                "cross": {
                    "xk": sds((G, batch, cfg.n_img_tokens, cfg.n_kv_heads, cfg.hd), dt),
                    "xv": sds((G, batch, cfg.n_img_tokens, cfg.n_kv_heads, cfg.hd), dt),
                },
            }
        raise ValueError(cfg.family)

    def init_cache(self, batch: int, seq_len: int):
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), self.cache_specs(batch, seq_len)
        )


def build_model(cfg: ModelConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "ssm", "hybrid"):
        return Model(
            cfg=cfg,
            init=lambda key: _cast_params(transformer.init_params(key, cfg), cfg),
            loss=lambda params, batch, mesh=None: transformer.loss_fn(params, batch, cfg, mesh),
            prefill=lambda params, batch, mesh=None, cache_len=None: transformer.prefill(
                params, batch["tokens"], cfg, mesh, cache_len
            ),
            decode=lambda params, token, caches, pos, mesh=None: transformer.decode(
                params, token, caches, pos, cfg, mesh
            ),
        )
    if fam == "encdec":
        return Model(
            cfg=cfg,
            init=lambda key: _cast_params(encdec.init_params(key, cfg), cfg),
            loss=lambda params, batch, mesh=None: encdec.loss_fn(params, batch, cfg, mesh),
            prefill=lambda params, batch, mesh=None, cache_len=None: encdec.prefill(
                params, batch, cfg, mesh, cache_len
            ),
            decode=lambda params, token, caches, pos, mesh=None: encdec.decode(
                params, token, caches, pos, cfg, mesh
            ),
        )
    if fam == "vlm":
        return Model(
            cfg=cfg,
            init=lambda key: _cast_params(vlm.init_params(key, cfg), cfg),
            loss=lambda params, batch, mesh=None: vlm.loss_fn(params, batch, cfg, mesh),
            prefill=lambda params, batch, mesh=None, cache_len=None: vlm.prefill(
                params, batch, cfg, mesh, cache_len
            ),
            decode=lambda params, token, caches, pos, mesh=None: vlm.decode(
                params, token, caches, pos, cfg, mesh
            ),
        )
    raise ValueError(fam)


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
