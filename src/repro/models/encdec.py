"""Encoder-decoder transformer (Whisper-medium backbone).

Per the assignment, the audio frontend (mel + conv downsampling) is a
STUB: the encoder consumes precomputed frame embeddings
(B, enc_seq, d_model). Whisper uses absolute sinusoidal positions (no
RoPE) and GELU FFNs; embeddings are tied with the LM head.

Decoder layers: self-attn (causal, cached) -> cross-attn (to encoder
output; during decode the cross K/V are precomputed once) -> FFN.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as attn
from . import ffn as ffn_mod
from .common import compute_dtype, constrain, cross_entropy, embed_init, rmsnorm


def _zeros(shape):
    return jnp.zeros(shape, jnp.float32)


def sinusoid(T, D, offset=0):
    pos = jnp.arange(offset, offset + T, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, D, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / D)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def sinusoid_at(pos, D):
    """Sinusoid at traced position(s): scalar or (B,) -> (B, 1, D)."""
    pos = jnp.atleast_1d(jnp.asarray(pos))
    dim = jnp.arange(0, D, 2, dtype=jnp.float32)[None, :]
    angle = pos[:, None].astype(jnp.float32) / jnp.power(10000.0, dim / D)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)[:, None, :]


def _enc_block_init(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": _zeros((cfg.d_model,)),
        "attn": attn.attn_params(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd),
        "norm2": _zeros((cfg.d_model,)),
        "ffn": ffn_mod.dense_ffn_params(k2, cfg.d_model, cfg.d_ff, cfg.ffn_kind),
    }


def _dec_block_init(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": _zeros((cfg.d_model,)),
        "attn": attn.attn_params(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd),
        "norm_x": _zeros((cfg.d_model,)),
        "xattn": attn.attn_params(k2, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd),
        "norm2": _zeros((cfg.d_model,)),
        "ffn": ffn_mod.dense_ffn_params(k3, cfg.d_model, cfg.d_ff, cfg.ffn_kind),
    }


def init_params(key, cfg):
    ke, kenc, kdec = jax.random.split(key, 3)
    return {
        "embed": embed_init(ke, (cfg.padded_vocab, cfg.d_model)),
        "enc_blocks": jax.vmap(lambda k: _enc_block_init(k, cfg))(
            jax.random.split(kenc, cfg.n_enc_layers)
        ),
        "enc_norm": _zeros((cfg.d_model,)),
        "dec_blocks": jax.vmap(lambda k: _dec_block_init(k, cfg))(
            jax.random.split(kdec, cfg.n_layers)
        ),
        "final_norm": _zeros((cfg.d_model,)),
    }


def _cast(bp, dt):
    return jax.tree.map(
        lambda a: a.astype(dt) if a.dtype == jnp.float32 and a.ndim > 1 else a, bp
    )


def encode(params, frames, cfg, mesh=None):
    """frames: (B, S_enc, D) stub embeddings -> encoder states."""
    dt = compute_dtype(cfg)
    B, S, D = frames.shape
    x = frames.astype(dt) + sinusoid(S, D).astype(dt)[None]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(x, bp):
        bp = _cast(bp, dt)
        h = rmsnorm(x, bp["norm1"], cfg.norm_eps)
        a, _ = attn.attention(h, bp["attn"], positions, causal=False, use_rope=False, mesh=mesh)
        x = x + a
        h2 = rmsnorm(x, bp["norm2"], cfg.norm_eps)
        return x + ffn_mod.dense_ffn(h2, bp["ffn"], cfg.ffn_kind), None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def dec_forward(params, tokens, enc_out, cfg, mesh=None, want_cache=False):
    """Decoder train/prefill. Returns (hidden, (self_caches, cross_caches))."""
    dt = compute_dtype(cfg)
    B, T = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    x = x + sinusoid(T, cfg.d_model).astype(dt)[None]
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

    def body(x, bp):
        bp = _cast(bp, dt)
        h = rmsnorm(x, bp["norm1"], cfg.norm_eps)
        a, kv = attn.attention(h, bp["attn"], positions, causal=True, use_rope=False, mesh=mesh)
        x = x + a
        hx = rmsnorm(x, bp["norm_x"], cfg.norm_eps)
        c, xkv = attn.cross_attention(hx, bp["xattn"], enc_out, mesh=mesh)
        x = x + c
        h2 = rmsnorm(x, bp["norm2"], cfg.norm_eps)
        x = x + ffn_mod.dense_ffn(h2, bp["ffn"], cfg.ffn_kind)
        cache = (
            {"k": kv[0], "v": kv[1], "xk": xkv[0], "xv": xkv[1]}
            if want_cache
            else {}
        )
        return x, cache

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, caches = jax.lax.scan(body, x, params["dec_blocks"])
    return rmsnorm(x, params["final_norm"], cfg.norm_eps), caches


def loss_fn(params, batch, cfg, mesh=None):
    enc_out = encode(params, batch["frames"], cfg, mesh)
    hidden, _ = dec_forward(params, batch["tokens"], enc_out, cfg, mesh)
    logits = jnp.einsum("btd,vd->btv", hidden, params["embed"].astype(hidden.dtype))
    logits = constrain(logits, ("pod", "data"), None, "model", mesh=mesh)
    loss = cross_entropy(logits, batch["labels"], cfg.vocab_size)
    return loss, {"ce": loss, "hidden": hidden}


def decode(params, token, caches, pos, cfg, mesh=None):
    """One decoder step against cached self K/V and precomputed cross K/V."""
    dt = compute_dtype(cfg)
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(dt)
    x = x + sinusoid_at(pos, cfg.d_model).astype(dt)

    def body(x, inp):
        bp, cache = inp
        bp = _cast(bp, dt)
        h = rmsnorm(x, bp["norm1"], cfg.norm_eps)
        a, kv = attn.decode_attention(
            h, bp["attn"], {"k": cache["k"], "v": cache["v"]}, pos, use_rope=False
        )
        x = x + a
        hx = rmsnorm(x, bp["norm_x"], cfg.norm_eps)
        x = x + attn.decode_cross_attention(hx, bp["xattn"], {"k": cache["xk"], "v": cache["xv"]})
        h2 = rmsnorm(x, bp["norm2"], cfg.norm_eps)
        x = x + ffn_mod.dense_ffn(h2, bp["ffn"], cfg.ffn_kind)
        return x, {**cache, "k": kv["k"], "v": kv["v"]}

    x, new_caches = jax.lax.scan(body, x, (params["dec_blocks"], caches))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("btd,vd->btv", x, params["embed"].astype(x.dtype))
    return logits[:, 0], x[:, 0], new_caches


def prefill(params, batch, cfg, mesh=None, cache_len=None):
    enc_out = encode(params, batch["frames"], cfg, mesh)
    tokens = batch["tokens"]
    hidden, caches = dec_forward(params, tokens, enc_out, cfg, mesh, want_cache=True)
    B, T = tokens.shape
    cache_len = cache_len or T
    pad = cache_len - T
    if pad > 0:
        caches = dict(caches)
        caches["k"] = jnp.pad(caches["k"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        caches["v"] = jnp.pad(caches["v"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    logits = jnp.einsum("btd,vd->btv", hidden[:, -1:], params["embed"].astype(hidden.dtype))
    return logits[:, 0], hidden, caches
