"""Shared model building blocks: norms, RoPE, initializers, dtype policy.

No flax in this container — modules are pure functions over nested-dict
param pytrees. Layer stacks are *stacked* along a leading axis and
consumed with ``lax.scan`` (small HLO -> fast SPMD compile, natural
remat boundary)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}


def compute_dtype(cfg):
    return DTYPES[cfg.dtype]


def pad_vocab(v: int, mult: int = 128) -> int:
    return -(-v // mult) * mult


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def dense_init(key, shape, in_axis_size=None, dtype=jnp.float32):
    """Truncated-normal fan-in init (LeCun-style) in fp32 master dtype."""
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    std = 1.0 / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
    return std * jax.random.truncated_normal(key, -3.0, 3.0, shape, dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * 0.02


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm(x, scale, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 1e4):
    """x: (..., T, H, hd); positions: (..., T) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., T, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# sharding helper
# ---------------------------------------------------------------------------


def constrain(x, *spec, mesh=None):
    """with_sharding_constraint by axis names; unknown axes are dropped
    when a mesh is supplied; no-op outside a mesh."""
    from jax.sharding import PartitionSpec as P

    if mesh is not None:
        names = set(mesh.axis_names)

        def clean(ax):
            if ax is None:
                return None
            axs = ax if isinstance(ax, tuple) else (ax,)
            kept = tuple(a for a in axs if a in names)
            if not kept:
                return None
            return kept if len(kept) > 1 else kept[0]

        spec = tuple(clean(a) for a in spec)
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError, KeyError):
        return x


@jax.custom_vjp
def _bf16_grad(x):
    return x


def _bf16_grad_fwd(x):
    return x, None


def _bf16_grad_bwd(_, g):
    import jax.numpy as jnp

    return (g.astype(jnp.bfloat16).astype(g.dtype) if False else g.astype(jnp.bfloat16),)


_bf16_grad.defvjp(_bf16_grad_fwd, _bf16_grad_bwd)


def grad_cast(x, dtype_name: str):
    """Identity in the forward pass; downcasts the cotangent in the
    backward pass (§Perf B3: fp32 softmax/router upcasts otherwise make
    every cross-layer gradient all-reduce run at fp32 width)."""
    if dtype_name != "bfloat16":
        return x
    return _bf16_grad(x)


def cross_entropy(logits, labels, vocab_size: int):
    """Mean CE over valid labels (< vocab_size; padded ids masked)."""
    logits = logits.astype(jnp.float32)
    valid = labels < vocab_size
    safe = jnp.where(valid, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = jnp.where(valid, logz - gold, 0.0)
    return jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1)
