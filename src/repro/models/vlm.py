"""Vision-language decoder (Llama-3.2-Vision-11B backbone).

Per the assignment, the vision encoder is a STUB: the model consumes
precomputed patch embeddings (B, n_img_tokens, d_vision), projects them
to d_model, and cross-attends to them from gated cross-attention layers
inserted after every ``cross_every``-th self-attention layer (Llama-3.2:
8 cross layers among 40 total).

Structure: n_groups = n_layers // cross_every groups, each =
(cross_every - 1) self layers + 1 [self + gated-cross] layer, consumed
with a nested scan (stacked self blocks reshaped (G, cross_every, ...)).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as attn
from . import ffn as ffn_mod
from .common import compute_dtype, cross_entropy, dense_init, embed_init, rmsnorm
from .transformer import init_block, logits_fn


def _zeros(shape):
    return jnp.zeros(shape, jnp.float32)


def n_groups(cfg):
    assert cfg.n_layers % cfg.cross_every == 0, (cfg.n_layers, cfg.cross_every)
    return cfg.n_layers // cfg.cross_every


def _cross_block_init(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": _zeros((cfg.d_model,)),
        "xattn": attn.attn_params(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd),
        "norm2": _zeros((cfg.d_model,)),
        "ffn": ffn_mod.dense_ffn_params(k2, cfg.d_model, cfg.d_ff, cfg.ffn_kind),
        "gate_attn": _zeros(()),
        "gate_ffn": _zeros(()),
    }


def init_params(key, cfg):
    ke, kb, kc, kp, kh = jax.random.split(key, 5)
    dense_cfg = cfg.scaled(family="dense")
    G = n_groups(cfg)
    return {
        "embed": embed_init(ke, (cfg.padded_vocab, cfg.d_model)),
        "blocks": jax.vmap(lambda k: init_block(k, dense_cfg, kind="dense"))(
            jax.random.split(kb, cfg.n_layers)
        ),
        "cross_blocks": jax.vmap(lambda k: _cross_block_init(k, cfg))(
            jax.random.split(kc, G)
        ),
        "img_proj": dense_init(kp, (cfg.d_vision, cfg.d_model), cfg.d_vision),
        "final_norm": _zeros((cfg.d_model,)),
        "lm_head": dense_init(kh, (cfg.d_model, cfg.padded_vocab), cfg.d_model),
    }


def _cast(bp, dt):
    return jax.tree.map(
        lambda a: a.astype(dt) if a.dtype == jnp.float32 and a.ndim > 1 else a, bp
    )


def _grouped(blocks, cfg):
    G = n_groups(cfg)
    return jax.tree.map(
        lambda a: a.reshape((G, cfg.cross_every) + a.shape[1:]), blocks
    )


def _self_block(x, bp, cfg, positions, mesh=None):
    h = rmsnorm(x, bp["norm1"], cfg.norm_eps)
    a, kv = attn.attention(h, bp["attn"], positions, causal=True,
                           rope_theta=cfg.rope_theta, mesh=mesh)
    x = x + a
    h2 = rmsnorm(x, bp["norm2"], cfg.norm_eps)
    return x + ffn_mod.dense_ffn(h2, bp["ffn"], cfg.ffn_kind), kv


def _cross_block(x, cp, cfg, img_e, mesh=None):
    h = rmsnorm(x, cp["norm1"], cfg.norm_eps)
    c, xkv = attn.cross_attention(h, cp["xattn"], img_e, mesh=mesh)
    x = x + jnp.tanh(cp["gate_attn"]).astype(x.dtype) * c
    h2 = rmsnorm(x, cp["norm2"], cfg.norm_eps)
    f = ffn_mod.dense_ffn(h2, cp["ffn"], cfg.ffn_kind)
    return x + jnp.tanh(cp["gate_ffn"]).astype(x.dtype) * f, xkv


def forward(params, tokens, images, cfg, mesh=None, want_cache=False):
    """tokens (B,T), images (B, n_img, d_vision) -> hidden, caches."""
    dt = compute_dtype(cfg)
    B, T = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    img_e = jnp.einsum("bnv,vd->bnd", images.astype(dt), params["img_proj"].astype(dt))

    def group_body(x, inp):
        selfs, cross = inp

        def inner(x, bp):
            bp = _cast(bp, dt)
            x, kv = _self_block(x, bp, cfg, positions, mesh)
            return x, ({"k": kv[0], "v": kv[1]} if want_cache else {})

        x, self_caches = jax.lax.scan(inner, x, selfs)
        cross = _cast(cross, dt)
        x, xkv = _cross_block(x, cross, cfg, img_e, mesh)
        xc = {"xk": xkv[0], "xv": xkv[1]} if want_cache else {}
        return x, (self_caches, xc)

    group_body = jax.checkpoint(
        group_body, policy=jax.checkpoint_policies.nothing_saveable
    )
    x, (self_caches, cross_caches) = jax.lax.scan(
        group_body, x, (_grouped(params["blocks"], cfg), params["cross_blocks"])
    )
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, (self_caches, cross_caches)


def loss_fn(params, batch, cfg, mesh=None):
    hidden, _ = forward(params, batch["tokens"], batch["images"], cfg, mesh)
    logits = logits_fn(params, hidden, cfg, mesh)
    loss = cross_entropy(logits, batch["labels"], cfg.vocab_size)
    return loss, {"ce": loss, "hidden": hidden}


def prefill(params, batch, cfg, mesh=None, cache_len=None):
    tokens = batch["tokens"]
    hidden, (self_caches, cross_caches) = forward(
        params, tokens, batch["images"], cfg, mesh, want_cache=True
    )
    B, T = tokens.shape
    cache_len = cache_len or T
    pad = cache_len - T
    if pad > 0:
        self_caches = {
            "k": jnp.pad(self_caches["k"], ((0, 0),) * 3 + ((0, pad), (0, 0), (0, 0))),
            "v": jnp.pad(self_caches["v"], ((0, 0),) * 3 + ((0, pad), (0, 0), (0, 0))),
        }
    logits = logits_fn(params, hidden[:, -1:], cfg, mesh)
    return logits[:, 0], hidden, {"self": self_caches, "cross": cross_caches}


def decode(params, token, caches, pos, cfg, mesh=None):
    """caches = {'self': {'k','v': (G, cross_every, B, S, KV, hd)},
    'cross': {'xk','xv': (G, B, n_img, KV, hd)}}."""
    dt = compute_dtype(cfg)
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(dt)

    def group_body(x, inp):
        selfs, cross, scache, xcache = inp

        def inner(x, inp2):
            bp, cache = inp2
            bp = _cast(bp, dt)
            h = rmsnorm(x, bp["norm1"], cfg.norm_eps)
            a, kv = attn.decode_attention(
                h, bp["attn"], {"k": cache["k"], "v": cache["v"]}, pos,
                rope_theta=cfg.rope_theta,
            )
            x = x + a
            h2 = rmsnorm(x, bp["norm2"], cfg.norm_eps)
            return x + ffn_mod.dense_ffn(h2, bp["ffn"], cfg.ffn_kind), kv

        x, new_scache = jax.lax.scan(inner, x, (selfs, scache))
        cross = _cast(cross, dt)
        h = rmsnorm(x, cross["norm1"], cfg.norm_eps)
        c = attn.decode_cross_attention(h, cross["xattn"], {"k": xcache["xk"], "v": xcache["xv"]})
        x = x + jnp.tanh(cross["gate_attn"]).astype(x.dtype) * c
        h2 = rmsnorm(x, cross["norm2"], cfg.norm_eps)
        f = ffn_mod.dense_ffn(h2, cross["ffn"], cfg.ffn_kind)
        x = x + jnp.tanh(cross["gate_ffn"]).astype(x.dtype) * f
        return x, new_scache

    x, new_self = jax.lax.scan(
        group_body,
        x,
        (
            _grouped(params["blocks"], cfg),
            params["cross_blocks"],
            caches["self"],
            caches["cross"],
        ),
    )
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_fn(params, x, cfg, mesh)
    return logits[:, 0], x[:, 0], {"self": new_self, "cross": caches["cross"]}
