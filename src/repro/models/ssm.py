"""Mamba-2 SSD (state-space duality) mixer [arXiv:2405.21060].

Chunked SSD algorithm: within chunks of length Qc the recurrence is
evaluated in its dual quadratic "attention" form (MXU-friendly), across
chunks the per-chunk end-states are propagated with a linear scan. A
single-token O(1) decode step maintains (SSM state, conv ring) — this is
what makes the `long_500k` cell sub-quadratic.

Layout: x (B, T, D); SSM state (B, H, N, P) with H heads, state dim N,
head dim P; depthwise conv window W=4 over (x, B, C) channels.

TP note (§Perf iteration A2): the input projection is stored as three
segment matrices (w_zx -> (z, x heads), w_bc -> (B, C), w_dt) instead of
one fused in_proj. The z/x segment is *column-parallel* on the
head-aligned dim and out_proj is *row-parallel* (Megatron pairing): one
psum per layer instead of two, and no resharding across fused-segment
boundaries.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, rmsnorm

CONV_W = 4


def ssm_dims(cfg):
    P = cfg.ssm_head_dim or 64
    H = cfg.ssm_heads or (2 * cfg.d_model) // P
    d_inner = H * P
    N = cfg.ssm_state
    conv_dim = d_inner + 2 * N  # ngroups = 1
    d_proj = 2 * d_inner + 2 * N + H
    return d_inner, H, P, N, conv_dim, d_proj


def ssm_params(key, cfg, dtype=jnp.float32):
    d_inner, H, P, N, conv_dim, d_proj = ssm_dims(cfg)
    ks = jax.random.split(key, 7)
    return {
        # column-parallel (head-aligned) z/x segment; small B/C + dt
        # segments replicated (their N-dim contraction must stay local)
        "w_zx": dense_init(ks[0], (cfg.d_model, 2 * d_inner), cfg.d_model, dtype),
        "w_bc": dense_init(ks[1], (cfg.d_model, 2 * N), cfg.d_model, dtype),
        "w_dt": dense_init(ks[5], (cfg.d_model, H), cfg.d_model, dtype),
        "conv_wx": dense_init(ks[2], (CONV_W, d_inner), CONV_W, dtype),
        "conv_bx": jnp.zeros((d_inner,), dtype),
        "conv_wbc": dense_init(ks[6], (CONV_W, 2 * N), CONV_W, dtype),
        "conv_bbc": jnp.zeros((2 * N,), dtype),
        "A_log": jnp.log(
            jax.random.uniform(ks[3], (H,), jnp.float32, 1.0, 16.0)
        ),
        "D_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(
            jnp.expm1(jax.random.uniform(ks[4], (H,), jnp.float32, 1e-3, 0.1))
        ),
        "norm_scale": jnp.zeros((d_inner,), dtype),
        "out_proj": dense_init(ks[4], (d_inner, cfg.d_model), d_inner, dtype),
    }


def _project(x, p, cfg):
    """x -> (z, x_raw, bc_raw, dt_raw) via the segment matrices."""
    d_inner, H, P, N, conv_dim, _ = ssm_dims(cfg)
    zx = jnp.einsum("btd,de->bte", x, p["w_zx"])  # (B,T,2*d_inner)
    bc = jnp.einsum("btd,de->bte", x, p["w_bc"])  # (B,T,2N)
    dt = jnp.einsum("btd,de->bte", x, p["w_dt"])  # (B,T,H)
    return zx[..., :d_inner], zx[..., d_inner:], bc, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv, width CONV_W. xBC: (B, T, C)."""
    pad = jnp.pad(xBC, ((0, 0), (CONV_W - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xBC.shape[1], :] * w[i][None, None, :] for i in range(CONV_W)
    )
    return jax.nn.silu(out + b)


def ssm_forward(x, p, cfg, chunk: int = 128, init_state=None):
    """Full-sequence SSD. Returns (y (B,T,D), final_state (B,H,N,P),
    conv_tail (B, CONV_W-1, conv_dim))."""
    B, T0, D = x.shape
    d_inner, H, P, N, conv_dim, _ = ssm_dims(cfg)
    Qc = min(chunk, T0)
    pad = (-T0) % Qc
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    T = T0 + pad
    nc = T // Qc

    z, x_raw, bc_raw, dt = _project(x, p, cfg)
    xconv = _causal_conv(x_raw, p["conv_wx"], p["conv_bx"]).astype(x.dtype)
    bcconv = _causal_conv(bc_raw, p["conv_wbc"], p["conv_bbc"]).astype(x.dtype)
    xh = xconv.reshape(B, T, H, P)
    Bm = bcconv[..., :N]  # (B,T,N)
    Cm = bcconv[..., N:]  # (B,T,N)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,T,H)
    if pad:
        # padded steps become identity state updates (decay 1, input 0)
        valid = (jnp.arange(T) < T0).astype(jnp.float32)
        dt = dt * valid[None, :, None]
    A = -jnp.exp(p["A_log"])  # (H,)
    dA = dt * A  # (B,T,H) negative

    # chunk views
    dA_c = dA.reshape(B, nc, Qc, H)
    dt_c = dt.reshape(B, nc, Qc, H)
    x_c = xh.reshape(B, nc, Qc, H, P)
    B_c = Bm.reshape(B, nc, Qc, N)
    C_c = Cm.reshape(B, nc, Qc, N)

    cs = jnp.cumsum(dA_c, axis=2)  # (B,nc,Qc,H) within-chunk log decay

    # intra-chunk (dual quadratic form)
    CB = jnp.einsum("bcin,bcjn->bcij", C_c, B_c)  # (B,nc,Qc,Qc)
    i_idx = jax.lax.broadcasted_iota(jnp.int32, (Qc, Qc), 0)
    j_idx = jax.lax.broadcasted_iota(jnp.int32, (Qc, Qc), 1)
    causal = (i_idx >= j_idx)[None, None, :, :, None]
    # mask inside the exponent: cs_i - cs_j > 0 for i < j would overflow
    delta = jnp.where(causal, cs[:, :, :, None, :] - cs[:, :, None, :, :], -jnp.inf)
    # exp in fp32 for range, then store the O(T*Qc*H) tensors in the
    # activation dtype — halves the SSD working set (§Perf A3)
    decay = jnp.exp(delta).astype(x.dtype)  # (B,nc,i,j,H)
    att = CB[..., None] * decay * dt_c[:, :, None, :, :].astype(x.dtype)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att, x_c)

    # per-chunk end states
    seg = jnp.exp(cs[:, :, -1:, :] - cs) * dt_c  # (B,nc,Qc,H)
    S_chunk = jnp.einsum("bcjh,bcjn,bcjhp->bchnp", seg.astype(x.dtype), B_c, x_c)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cs[:, :, -1, :])  # (B,nc,H)

    def scan_fn(S_prev, inp):
        dcy, S_c = inp  # (B,H), (B,H,N,P)
        S_new = S_prev * dcy[:, :, None, None].astype(S_prev.dtype) + S_c
        return S_new, S_prev

    S0 = (
        init_state
        if init_state is not None
        else jnp.zeros((B, H, N, P), x.dtype)
    )
    S_final, S_prevs = jax.lax.scan(
        scan_fn,
        S0,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(S_chunk, 1, 0)),
    )
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)  # (B,nc,H,N,P) state entering chunk

    y_inter = jnp.einsum("bcin,bchnp->bcihp", C_c, S_prevs) * jnp.exp(cs)[
        ..., None
    ].astype(x.dtype)

    y = (y_intra + y_inter).reshape(B, T, H, P)
    y = y + p["D_skip"][None, None, :, None].astype(x.dtype) * xh
    y = y.reshape(B, T, d_inner)

    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"])[:, :T0]
    xBC_raw = jnp.concatenate([x_raw, bc_raw], axis=-1)  # cache layout
    if T0 >= CONV_W - 1:
        conv_tail = xBC_raw[:, T0 - (CONV_W - 1) : T0, :]
    else:
        conv_tail = jnp.pad(
            xBC_raw[:, :T0, :], ((0, 0), (CONV_W - 1 - T0, 0), (0, 0))
        )
    return out, S_final, conv_tail


def ssm_decode(x1, p, cfg, state, conv_state):
    """Single-token decode. x1: (B,1,D); state: (B,H,N,P);
    conv_state: (B, CONV_W-1, conv_dim). Returns (y, state, conv_state)."""
    B = x1.shape[0]
    d_inner, H, P, N, conv_dim, _ = ssm_dims(cfg)

    z, x_raw, bc_raw, dt = _project(x1, p, cfg)
    xBC_raw = jnp.concatenate([x_raw, bc_raw], axis=-1)
    window = jnp.concatenate([conv_state, xBC_raw], axis=1)  # (B, CONV_W, C)
    conv_w = jnp.concatenate([p["conv_wx"], p["conv_wbc"]], axis=-1)
    conv_b = jnp.concatenate([p["conv_bx"], p["conv_bbc"]], axis=-1)
    xBC = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", window, conv_w) + conv_b
    )[:, None, :].astype(x1.dtype)
    new_conv = window[:, 1:, :]

    xh = xBC[..., :d_inner].reshape(B, H, P)
    Bm = xBC[..., d_inner : d_inner + N].reshape(B, N)
    Cm = xBC[..., d_inner + N :].reshape(B, N)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    dA = jnp.exp(dt * -jnp.exp(p["A_log"]))  # (B,H)

    upd = jnp.einsum("bh,bn,bhp->bhnp", dt.astype(x1.dtype), Bm, xh)
    state = state * dA[:, :, None, None].astype(state.dtype) + upd
    y = jnp.einsum("bn,bhnp->bhp", Cm, state)
    y = y + p["D_skip"][None, :, None].astype(x1.dtype) * xh
    y = y.reshape(B, 1, d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    return jnp.einsum("bte,ed->btd", y, p["out_proj"]), state, new_conv
