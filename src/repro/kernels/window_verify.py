"""Pallas TPU kernels: window-query verification for DB-LSH.

The query-phase hot spot of Algorithm 1 is verification: for each query,
stream the candidate blocks selected by the MBR pass, test K-dim box
containment against the query-centric bucket W(G_i(q), w), compute exact
squared L2 distances for in-box points, and maintain a running top-k —
all without materializing per-candidate distances in HBM.

Per-radius fused variants (the multi-pass reference path):

* ``candidate_verify_kernel`` — operates on pre-gathered candidates
  (``gather`` index layout). Grid: (Q, C/TILE_C); the top-k accumulator
  lives in the output block, revisited across the C tiles.

* ``window_verify_kernel`` — operates directly on the table via
  **scalar-prefetch block indices**: the BlockSpec index_map reads the
  per-(query, slot) STR block id and DMAs exactly that block HBM->VMEM.
  This is the zero-copy gather: the XLA-level ``jnp.take`` of blocks
  disappears entirely (``inline`` layout required). Same in-kernel fused
  verify + top-k.

One-pass schedule variants (the serving path): the fixed-schedule
search verifies each selected block **once** for the whole radius
schedule, so these kernels drop the in-kernel window mask and top-k and
instead emit, per candidate slot, the exact squared distance plus the
slot's **window halfwidth** ``hw = max_k |p_k - g_k|`` — the smallest
half window width that admits the slot.  The per-step box test then
collapses to ``hw <= w_j / 2``, evaluated host-of-kernel against the
whole schedule without touching the d-dim vectors again:

* ``candidate_dist_kernel`` — pre-gathered candidates, grid
  (Q, L, Ct/TILE_C) so each tile reads its own table's query projection.
* ``window_dist_kernel`` — scalar-prefetch block DMA over the L tables
  flattened to one (L*nb) block axis (``inline`` layout required).

Both compute distances in the MXU form ``||x||^2 - 2<q,x> + ||q||^2``
(one dot against the query instead of d diff+square lanes per slot)
using squared norms precomputed at build time, with a static
``exact=True`` escape hatch that restores the materialized-diff form
(the norm trick changes fp32 rounding).

The in-kernel top-k is a k-step vectorized selection (min + one-hot
write + mask), free of data-dependent scatters so it lowers to pure VPU
ops. Because cross-table duplicates carry identical (dist, id) pairs,
the "remove all entries equal to the selected (dist, id)" step performs
exact dedup for free.

VMEM budget (per grid step, fp32): TILE_C*(K + d + 1) + 2k floats.
With TILE_C = 256, K = 12, d = 128, k = 50: ~145 KiB — comfortably
inside the ~16 MiB v5e VMEM; TILE_C is raised by ops.py when d is small.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INF = jnp.inf
_IMAX = jnp.iinfo(jnp.int32).max


def merge_topk(cd, ci, out_d, out_i, k: int):
    """k-step vectorized selection merging candidates into (out_d, out_i).

    cd/ci: (C,) candidate squared distances / ids (masked slots = +inf).
    out_d/out_i: (k,) current top-k (ascending, +inf padded).
    Pure VPU ops: min-reduce, compare, select. No dynamic scatter.
    """
    cd = jnp.concatenate([out_d, cd])
    ci = jnp.concatenate([out_i, ci])
    idxk = jax.lax.iota(jnp.int32, k)

    def body(j, carry):
        cd, nd, ni = carry
        m = jnp.min(cd)
        finite = jnp.isfinite(m)
        eq = cd == m
        sel = jnp.min(jnp.where(eq, ci, _IMAX))
        oh = idxk == j
        nd = jnp.where(oh, m, nd)
        ni = jnp.where(oh & finite, sel, ni)
        # drop every entry with the selected (dist, id) — exact dedup of
        # cross-table duplicates, which carry identical pairs.
        cd = jnp.where(eq & (ci == sel), _INF, cd)
        return cd, nd, ni

    init = (cd, jnp.full((k,), _INF, cd.dtype), jnp.full((k,), _IMAX, jnp.int32))
    _, nd, ni = jax.lax.fori_loop(0, k, body, init)
    return nd, ni


def candidate_verify_kernel(
    w_ref, g_ref, q_ref, proj_ref, vec_ref, ids_ref, topd_ref, topi_ref, *, k: int, n: int
):
    """Grid (Q, C_tiles). Blocks: proj (1,TC,K), vec (1,TC,d), ids (1,TC);
    g (1,K), q (1,d), w (1,1) replicated; outputs (1,k) revisited over
    tiles."""
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        topd_ref[...] = jnp.full_like(topd_ref, _INF)
        topi_ref[...] = jnp.full_like(topi_ref, _IMAX)

    half = 0.5 * w_ref[0, 0]
    p = proj_ref[0]  # (TC, K)
    x = vec_ref[0]  # (TC, d)
    ids = ids_ref[0]  # (TC,)
    g = g_ref[0]  # (K,)
    q = q_ref[0]  # (d,)

    inbox = jnp.all(jnp.abs(p - g[None, :]) <= half, axis=-1)  # (TC,)
    diff = x - q[None, :]
    d2 = jnp.sum(diff * diff, axis=-1)  # (TC,)
    d2 = jnp.where(inbox & (ids < n), d2, _INF)

    nd, ni = merge_topk(d2, ids, topd_ref[0], topi_ref[0], k)
    topd_ref[0] = nd
    topi_ref[0] = ni


def window_verify_kernel(
    blk_ref,  # scalar prefetch: (Q, M) int32 block ids
    w_ref,
    g_ref,
    q_ref,
    proj_ref,  # (1, B, K) block DMA'd via blk_ref
    vec_ref,  # (1, B, d)
    ids_ref,  # (1, B)
    topd_ref,
    topi_ref,
    *,
    k: int,
    n: int,
    nb: int,
):
    """Grid (Q, M). The index_map for proj/vec/ids reads blk_ref — Pallas
    DMAs exactly the selected STR block; no gathered copy ever exists."""
    qi = pl.program_id(0)
    m = pl.program_id(1)

    @pl.when(m == 0)
    def _init():
        topd_ref[...] = jnp.full_like(topd_ref, _INF)
        topi_ref[...] = jnp.full_like(topi_ref, _IMAX)

    blk_valid = blk_ref[qi, m] < nb
    half = 0.5 * w_ref[0, 0]
    p = proj_ref[0]
    x = vec_ref[0]
    ids = ids_ref[0]
    g = g_ref[0]
    q = q_ref[0]

    inbox = jnp.all(jnp.abs(p - g[None, :]) <= half, axis=-1)
    diff = x - q[None, :]
    d2 = jnp.sum(diff * diff, axis=-1)
    d2 = jnp.where(inbox & (ids < n) & blk_valid, d2, _INF)

    nd, ni = merge_topk(d2, ids, topd_ref[0], topi_ref[0], k)
    topd_ref[0] = nd
    topi_ref[0] = ni


def candidate_dist_kernel(
    g_ref, q_ref, q2_ref, proj_ref, vec_ref, nrm_ref, d2_ref, hw_ref, *, exact: bool
):
    """One-pass distance + halfwidth over pre-gathered candidates.

    Grid (Q, L, Ct_tiles). Blocks: proj (1,1,TC,K), vec (1,1,TC,d), nrm
    (1,1,TC); g (1,1,K) per (query, table), q (1,d) / q2 (1,1) per
    query; outputs d2 / hw (1,1,TC). No window mask, no top-k: the
    radius schedule is applied outside against ``hw``."""
    p = proj_ref[0, 0]  # (TC, K)
    x = vec_ref[0, 0]  # (TC, d)
    g = g_ref[0, 0]  # (K,)
    q = q_ref[0]  # (d,)

    hw = jnp.max(jnp.abs(p - g[None, :]), axis=-1)  # (TC,)
    if exact:
        diff = x - q[None, :]
        d2 = jnp.sum(diff * diff, axis=-1)
    else:
        # MXU form: one dot against the query; +inf norms (padding,
        # tombstones) poison d2 so no id compare is needed here.
        d2 = jnp.maximum(
            nrm_ref[0, 0] - 2.0 * jnp.dot(x, q) + q2_ref[0, 0], 0.0
        )
    d2_ref[0, 0] = d2
    hw_ref[0, 0] = hw


def window_dist_kernel(
    blk_ref,  # scalar prefetch: (Q, S) int32 flattened block ids (S = L*M)
    g_ref,  # (1, 1, K): the owning table's query projection
    q_ref,  # (1, d)
    q2_ref,  # (1, 1)
    proj_ref,  # (1, B, K) block DMA'd via blk_ref
    vec_ref,  # (1, B, d)
    nrm_ref,  # (1, B)
    d2_ref,  # (1, 1, B)
    hw_ref,  # (1, 1, B)
    *,
    lnb: int,
    exact: bool,
):
    """Grid (Q, S). Scalar-prefetch twin of ``candidate_dist_kernel``:
    the index_map DMAs exactly the selected STR block of the flattened
    (L*nb) table axis — the serving path's only touch of the d-dim
    vectors for the entire radius schedule."""
    qi = pl.program_id(0)
    s = pl.program_id(1)

    blk_valid = blk_ref[qi, s] < lnb
    p = proj_ref[0]  # (B, K)
    x = vec_ref[0]  # (B, d)
    g = g_ref[0, 0]  # (K,)
    q = q_ref[0]  # (d,)

    hw = jnp.max(jnp.abs(p - g[None, :]), axis=-1)  # (B,)
    # invalid slots DMA a clamped real block: force them out of every
    # window so the schedule mask can never admit them
    hw = jnp.where(blk_valid, hw, _INF)
    if exact:
        diff = x - q[None, :]
        d2 = jnp.sum(diff * diff, axis=-1)
    else:
        d2 = jnp.maximum(
            nrm_ref[0] - 2.0 * jnp.dot(x, q) + q2_ref[0, 0], 0.0
        )
    d2_ref[0, 0] = d2
    hw_ref[0, 0] = hw
