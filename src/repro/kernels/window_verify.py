"""Pallas TPU kernels: window-query verification for DB-LSH.

The query-phase hot spot of Algorithm 1 is verification: for each query,
stream the candidate blocks selected by the MBR pass, test K-dim box
containment against the query-centric bucket W(G_i(q), w), compute exact
squared L2 distances for in-box points, and maintain a running top-k —
all without materializing per-candidate distances in HBM.

Per-radius fused variants (the multi-pass reference path):

* ``candidate_verify_kernel`` — operates on pre-gathered candidates
  (``gather`` index layout). Grid: (Q, C/TILE_C); the top-k accumulator
  lives in the output block, revisited across the C tiles.

* ``window_verify_kernel`` — operates directly on the table via
  **scalar-prefetch block indices**: the BlockSpec index_map reads the
  per-(query, slot) STR block id and DMAs exactly that block HBM->VMEM.
  This is the zero-copy gather: the XLA-level ``jnp.take`` of blocks
  disappears entirely (``inline`` layout required). Same in-kernel fused
  verify + top-k.

One-pass schedule variants (the serving path): the fixed-schedule
search verifies each selected block **once** for the whole radius
schedule, so these kernels drop the in-kernel window mask and top-k and
instead emit, per candidate slot, the exact squared distance plus the
slot's **window halfwidth** ``hw = max_k |p_k - g_k|`` — the smallest
half window width that admits the slot.  The per-step box test then
collapses to ``hw <= w_j / 2``, evaluated host-of-kernel against the
whole schedule without touching the d-dim vectors again:

* ``candidate_dist_kernel`` — pre-gathered candidates, grid
  (Q, L, Ct/TILE_C) so each tile reads its own table's query projection.
* ``window_dist_kernel`` — scalar-prefetch block DMA over the L tables
  flattened to one (L*nb) block axis (``inline`` layout required).

Both compute distances in the MXU form ``||x||^2 - 2<q,x> + ||q||^2``
(one dot against the query instead of d diff+square lanes per slot)
using squared norms precomputed at build time, with a static
``exact=True`` escape hatch that restores the materialized-diff form
(the norm trick changes fp32 rounding).

The in-kernel top-k is a k-step vectorized selection (min + one-hot
write + mask), free of data-dependent scatters so it lowers to pure VPU
ops. Because cross-table duplicates carry identical (dist, id) pairs,
the "remove all entries equal to the selected (dist, id)" step performs
exact dedup for free.

Fully fused one-pass variants (the serving path's fast lane): the
schedule masking and the per-step delta merges move *into* the kernel,
so candidates never touch HBM between block select and the final
result.  Each candidate is assigned its schedule **bin** — the first
step whose window admits it, ``binid = #{j: hw > w_j/2}`` — and folded
into a per-(query, step) top-ks accumulator plus an admitted-slot
counter (the ``with_stats``/C1 feed).  The caller recovers exact
per-step merge semantics by prefix-merging the bins (windows nest, so
bin j IS the step-j delta):

* ``fused_cand_kernel``   — pre-gathered candidates, grid (Q, L, Ct/TC).
* ``fused_window_kernel`` — scalar-prefetch block DMA, grid (Q, S).

Both take a ``mode`` in {'exact', 'norm', 'bf16', 'int8'}: the
quantized modes compute the dot against quantized blocks (per-slot
symmetric int8 scales / plain bf16 casts) while norms, halfwidths and
admission stay fp32-exact — the caller re-ranks the shortlist in fp32.

VMEM budget (per grid step, fp32): TILE_C*(K + d + 1) + 2k floats.
With TILE_C = 256, K = 12, d = 128, k = 50: ~145 KiB — comfortably
inside the ~16 MiB v5e VMEM; TILE_C is raised by ops.py when d is small.
The fused accumulators add steps*(2*ks + 1) words — 2.6 KiB at
steps = 8, ks = 40 (see DESIGN.md §13 for the full table).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INF = jnp.inf
_IMAX = jnp.iinfo(jnp.int32).max


def merge_topk(cd, ci, out_d, out_i, k: int):
    """k-step vectorized selection merging candidates into (out_d, out_i).

    cd/ci: (C,) candidate squared distances / ids (masked slots = +inf).
    out_d/out_i: (k,) current top-k (ascending, +inf padded).
    Pure VPU ops: min-reduce, compare, select. No dynamic scatter.
    """
    cd = jnp.concatenate([out_d, cd])
    ci = jnp.concatenate([out_i, ci])
    idxk = jax.lax.iota(jnp.int32, k)

    def body(j, carry):
        cd, nd, ni = carry
        m = jnp.min(cd)
        finite = jnp.isfinite(m)
        eq = cd == m
        sel = jnp.min(jnp.where(eq, ci, _IMAX))
        oh = idxk == j
        nd = jnp.where(oh, m, nd)
        ni = jnp.where(oh & finite, sel, ni)
        # drop every entry with the selected (dist, id) — exact dedup of
        # cross-table duplicates, which carry identical pairs.
        cd = jnp.where(eq & (ci == sel), _INF, cd)
        return cd, nd, ni

    init = (cd, jnp.full((k,), _INF, cd.dtype), jnp.full((k,), _IMAX, jnp.int32))
    _, nd, ni = jax.lax.fori_loop(0, k, body, init)
    return nd, ni


def candidate_verify_kernel(
    w_ref, g_ref, q_ref, proj_ref, vec_ref, ids_ref, topd_ref, topi_ref, *, k: int, n: int
):
    """Grid (Q, C_tiles). Blocks: proj (1,TC,K), vec (1,TC,d), ids (1,TC);
    g (1,K), q (1,d), w (1,1) replicated; outputs (1,k) revisited over
    tiles."""
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        topd_ref[...] = jnp.full_like(topd_ref, _INF)
        topi_ref[...] = jnp.full_like(topi_ref, _IMAX)

    half = 0.5 * w_ref[0, 0]
    p = proj_ref[0]  # (TC, K)
    x = vec_ref[0]  # (TC, d)
    ids = ids_ref[0]  # (TC,)
    g = g_ref[0]  # (K,)
    q = q_ref[0]  # (d,)

    inbox = jnp.all(jnp.abs(p - g[None, :]) <= half, axis=-1)  # (TC,)
    diff = x - q[None, :]
    d2 = jnp.sum(diff * diff, axis=-1)  # (TC,)
    d2 = jnp.where(inbox & (ids < n), d2, _INF)

    nd, ni = merge_topk(d2, ids, topd_ref[0], topi_ref[0], k)
    topd_ref[0] = nd
    topi_ref[0] = ni


def window_verify_kernel(
    blk_ref,  # scalar prefetch: (Q, M) int32 block ids
    w_ref,
    g_ref,
    q_ref,
    proj_ref,  # (1, B, K) block DMA'd via blk_ref
    vec_ref,  # (1, B, d)
    ids_ref,  # (1, B)
    topd_ref,
    topi_ref,
    *,
    k: int,
    n: int,
    nb: int,
):
    """Grid (Q, M). The index_map for proj/vec/ids reads blk_ref — Pallas
    DMAs exactly the selected STR block; no gathered copy ever exists."""
    qi = pl.program_id(0)
    m = pl.program_id(1)

    @pl.when(m == 0)
    def _init():
        topd_ref[...] = jnp.full_like(topd_ref, _INF)
        topi_ref[...] = jnp.full_like(topi_ref, _IMAX)

    # invalid slots are routed to block 0 by the index_map; skip their
    # compute entirely — the accumulator simply isn't touched
    @pl.when(blk_ref[qi, m] < nb)
    def _compute():
        half = 0.5 * w_ref[0, 0]
        p = proj_ref[0]
        x = vec_ref[0]
        ids = ids_ref[0]
        g = g_ref[0]
        q = q_ref[0]

        inbox = jnp.all(jnp.abs(p - g[None, :]) <= half, axis=-1)
        diff = x - q[None, :]
        d2 = jnp.sum(diff * diff, axis=-1)
        d2 = jnp.where(inbox & (ids < n), d2, _INF)

        nd, ni = merge_topk(d2, ids, topd_ref[0], topi_ref[0], k)
        topd_ref[0] = nd
        topi_ref[0] = ni


def candidate_dist_kernel(
    g_ref, q_ref, q2_ref, proj_ref, vec_ref, nrm_ref, d2_ref, hw_ref, *, exact: bool
):
    """One-pass distance + halfwidth over pre-gathered candidates.

    Grid (Q, L, Ct_tiles). Blocks: proj (1,1,TC,K), vec (1,1,TC,d), nrm
    (1,1,TC); g (1,1,K) per (query, table), q (1,d) / q2 (1,1) per
    query; outputs d2 / hw (1,1,TC). No window mask, no top-k: the
    radius schedule is applied outside against ``hw``."""
    p = proj_ref[0, 0]  # (TC, K)
    x = vec_ref[0, 0]  # (TC, d)
    g = g_ref[0, 0]  # (K,)
    q = q_ref[0]  # (d,)

    hw = jnp.max(jnp.abs(p - g[None, :]), axis=-1)  # (TC,)
    if exact:
        diff = x - q[None, :]
        d2 = jnp.sum(diff * diff, axis=-1)
    else:
        # MXU form: one dot against the query; +inf norms (padding,
        # tombstones) poison d2 so no id compare is needed here.
        d2 = jnp.maximum(
            nrm_ref[0, 0] - 2.0 * jnp.dot(x, q) + q2_ref[0, 0], 0.0
        )
    d2_ref[0, 0] = d2
    hw_ref[0, 0] = hw


def window_dist_kernel(
    blk_ref,  # scalar prefetch: (Q, S) int32 flattened block ids (S = L*M)
    g_ref,  # (1, 1, K): the owning table's query projection
    q_ref,  # (1, d)
    q2_ref,  # (1, 1)
    proj_ref,  # (1, B, K) block DMA'd via blk_ref
    vec_ref,  # (1, B, d)
    nrm_ref,  # (1, B)
    d2_ref,  # (1, 1, B)
    hw_ref,  # (1, 1, B)
    *,
    lnb: int,
    exact: bool,
):
    """Grid (Q, S). Scalar-prefetch twin of ``candidate_dist_kernel``:
    the index_map DMAs exactly the selected STR block of the flattened
    (L*nb) table axis — the serving path's only touch of the d-dim
    vectors for the entire radius schedule.

    Invalid slots (blk >= lnb) are routed to block 0 by the index_map
    (consecutive invalid slots therefore re-DMA nothing — Pallas skips
    the copy when the block index is unchanged) and the compute is
    ``pl.when``-skipped entirely: the slot's outputs are written as +inf
    so the schedule mask can never admit it."""
    qi = pl.program_id(0)
    s = pl.program_id(1)

    blk_valid = blk_ref[qi, s] < lnb

    @pl.when(blk_valid)
    def _compute():
        p = proj_ref[0]  # (B, K)
        x = vec_ref[0]  # (B, d)
        g = g_ref[0, 0]  # (K,)
        q = q_ref[0]  # (d,)

        hw = jnp.max(jnp.abs(p - g[None, :]), axis=-1)  # (B,)
        if exact:
            diff = x - q[None, :]
            d2 = jnp.sum(diff * diff, axis=-1)
        else:
            d2 = jnp.maximum(
                nrm_ref[0] - 2.0 * jnp.dot(x, q) + q2_ref[0, 0], 0.0
            )
        d2_ref[0, 0] = d2
        hw_ref[0, 0] = hw

    @pl.when(~blk_valid)
    def _invalid():
        d2_ref[...] = jnp.full_like(d2_ref, _INF)
        hw_ref[...] = jnp.full_like(hw_ref, _INF)


def _slot_d2(x, q, nrm, q2, *, mode: str, xscale=None, qscale=None):
    """Per-slot squared distances in the requested arithmetic mode.

    x: (C, d) candidate vectors (fp32, bf16 or int8 depending on mode);
    q: (d,) query in the matching dtype; nrm/q2: fp32 exact squared
    norms.  ``bf16``/``int8`` compute only the *dot* reduced-precision —
    norms stay fp32-exact, so the error model is confined to the cross
    term (DESIGN.md §13)."""
    if mode == "exact":
        diff = x - q[None, :]
        return jnp.sum(diff * diff, axis=-1)
    if mode == "norm":
        return jnp.maximum(nrm - 2.0 * jnp.dot(x, q) + q2, 0.0)
    if mode == "int8":
        dot = jnp.dot(x, q, preferred_element_type=jnp.int32).astype(
            jnp.float32
        )
    elif mode == "bf16":
        dot = jnp.dot(x, q, preferred_element_type=jnp.float32)
    else:  # pragma: no cover - guarded by the wrappers
        raise ValueError(f"unknown distance mode {mode!r}")
    return jnp.maximum(nrm - 2.0 * (xscale * qscale * dot) + q2, 0.0)


def _fused_slot_update(hw, d2, ids, halves, bd_ref, bi_ref, cnt_ref, *,
                       steps: int, ks: int):
    """Fold one slot's candidates into the per-step bin accumulators.

    Each candidate belongs to exactly one schedule *bin*: the first step
    whose window admits it, ``binid = #{j : hw > w_j/2}`` (``steps`` =
    never admitted; hw = +inf slots land there).  Windows nest, so the
    step-j delta slice of the radius schedule is exactly bin j — the
    epilogue recovers the per-step merge semantics by prefix-merging the
    bins.  ``cnt`` accumulates admitted candidate slots per bin; its
    cumulative sum equals the C1 admission count ``#{hw <= w_j/2}``.

    ``bd/bi`` are (1, steps, ks) accumulators revisited across the slot
    axis of the grid; ``merge_topk``'s drop-equal-(dist, id) step dedups
    cross-table duplicates within a bin exactly as the flat merge does.
    """
    c = hw.shape[0]
    binid = jnp.sum((hw[None, :] > halves[:, None]).astype(jnp.int32), axis=0)
    # 2D iota (broadcasted_iota): 1D iota does not lower on TPU
    stepv = jax.lax.broadcasted_iota(jnp.int32, (steps, c), 0)
    hits = binid[None, :] == stepv  # (steps, C)
    cnt_ref[0] = cnt_ref[0] + jnp.sum(hits.astype(jnp.int32), axis=1)
    for j in range(steps):
        m = binid == j

        @pl.when(jnp.any(m))
        def _merge(j=j, m=m):
            nd, ni = merge_topk(
                jnp.where(m, d2, _INF), ids, bd_ref[0, j], bi_ref[0, j], ks
            )
            bd_ref[0, j] = nd
            bi_ref[0, j] = ni


def fused_window_kernel(*refs, lnb: int, steps: int, ks: int, mode: str):
    """One-pass fused search over an 'inline' layout: select-slot DMA +
    halfwidth + distance + schedule binning + per-bin top-ks, one kernel.

    Grid (Q, S).  Scalar-prefetch block DMA exactly as
    ``window_dist_kernel``; candidates never reach HBM — the only
    outputs are the (1, steps, ks) bin accumulators and the (1, steps)
    admitted-slot counters, revisited across the S slot steps.

    Quantized modes take two extra refs: the per-query quant scale
    (qs, (1,1)) after q2 and the per-slot dequant scales (scl, (1,B))
    after ids."""
    quant = mode in ("bf16", "int8")
    if quant:
        (blk_ref, halves_ref, g_ref, q_ref, q2_ref, qs_ref,
         proj_ref, vec_ref, nrm_ref, ids_ref, scl_ref,
         bd_ref, bi_ref, cnt_ref) = refs
    else:
        (blk_ref, halves_ref, g_ref, q_ref, q2_ref,
         proj_ref, vec_ref, nrm_ref, ids_ref,
         bd_ref, bi_ref, cnt_ref) = refs
    qi = pl.program_id(0)
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        bd_ref[...] = jnp.full_like(bd_ref, _INF)
        bi_ref[...] = jnp.full_like(bi_ref, _IMAX)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    @pl.when(blk_ref[qi, s] < lnb)
    def _compute():
        p = proj_ref[0]  # (B, K)
        g = g_ref[0, 0]  # (K,)
        hw = jnp.max(jnp.abs(p - g[None, :]), axis=-1)  # (B,)
        d2 = _slot_d2(
            vec_ref[0], q_ref[0], nrm_ref[0], q2_ref[0, 0], mode=mode,
            xscale=scl_ref[0] if quant else None,
            qscale=qs_ref[0, 0] if quant else None,
        )
        _fused_slot_update(
            hw, d2, ids_ref[0], halves_ref[0], bd_ref, bi_ref, cnt_ref,
            steps=steps, ks=ks,
        )


def fused_cand_kernel(*refs, steps: int, ks: int, mode: str):
    """Gathered twin of ``fused_window_kernel``: grid (Q, L, Ct_tiles)
    over pre-gathered candidates (``kernel`` engine / 'gather' layout).
    Invalid slots carry +inf projections from the gather fill, so their
    hw = +inf keeps them out of every bin — no validity scalar needed."""
    quant = mode in ("bf16", "int8")
    if quant:
        (halves_ref, g_ref, q_ref, q2_ref, qs_ref,
         proj_ref, vec_ref, nrm_ref, ids_ref, scl_ref,
         bd_ref, bi_ref, cnt_ref) = refs
    else:
        (halves_ref, g_ref, q_ref, q2_ref,
         proj_ref, vec_ref, nrm_ref, ids_ref,
         bd_ref, bi_ref, cnt_ref) = refs
    li = pl.program_id(1)
    t = pl.program_id(2)

    @pl.when((li == 0) & (t == 0))
    def _init():
        bd_ref[...] = jnp.full_like(bd_ref, _INF)
        bi_ref[...] = jnp.full_like(bi_ref, _IMAX)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    p = proj_ref[0, 0]  # (TC, K)
    g = g_ref[0, 0]  # (K,)
    hw = jnp.max(jnp.abs(p - g[None, :]), axis=-1)  # (TC,)
    d2 = _slot_d2(
        vec_ref[0, 0], q_ref[0], nrm_ref[0, 0], q2_ref[0, 0], mode=mode,
        xscale=scl_ref[0, 0] if quant else None,
        qscale=qs_ref[0, 0] if quant else None,
    )
    _fused_slot_update(
        hw, d2, ids_ref[0, 0], halves_ref[0], bd_ref, bi_ref, cnt_ref,
        steps=steps, ks=ks,
    )
