"""Pallas TPU kernels for the DB-LSH query hot path.

Each kernel ships a jit'd wrapper (ops.py) and a pure-jnp oracle
(ref.py); tests sweep shapes/dtypes and assert allclose in interpret
mode (TPU is the compile target, CPU validates semantics).
"""

from .ops import (
    candidate_dist,
    candidate_verify,
    fused_cand_search,
    fused_window_search,
    pairwise_l2,
    window_dist,
    window_verify,
)
from . import ref

__all__ = [
    "candidate_dist",
    "candidate_verify",
    "fused_cand_search",
    "fused_window_search",
    "pairwise_l2",
    "window_dist",
    "window_verify",
    "ref",
]
