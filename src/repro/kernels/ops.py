"""jit'd wrappers around the Pallas kernels (padding, BlockSpecs, tiling).

``interpret=None`` auto-selects: compiled Mosaic on TPU, interpret mode
elsewhere (the kernel body runs as pure Python/XLA on CPU — this is how
the kernels are validated in this container; TPU is the target).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pairwise_l2 import pairwise_l2_kernel
from .window_verify import (
    candidate_dist_kernel,
    candidate_verify_kernel,
    fused_cand_kernel,
    fused_window_kernel,
    window_dist_kernel,
    window_verify_kernel,
)

_IMAX = jnp.iinfo(jnp.int32).max


def _interp(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _pad_to(x, mult, axis, value):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("n", "k", "tile_c", "interpret"))
def candidate_verify(cand_proj, cand_vecs, cand_ids, g, q, w, *, n, k,
                     tile_c: int = 256, interpret=None):
    """Fused box-mask + L2 + top-k over pre-gathered candidates.

    Args:
      cand_proj: (Q, C, K); cand_vecs: (Q, C, d); cand_ids: (Q, C) int32.
      g: (Q, K); q: (Q, d); w: scalar window width.
      n: sentinel id; k: top-k.

    Returns: (Q, k) squared distances ascending, (Q, k) ids (n when empty).
    """
    Qn, C, K = cand_proj.shape
    d = cand_vecs.shape[-1]
    tile_c = min(tile_c, max(8, C))
    cand_proj = _pad_to(cand_proj, tile_c, 1, jnp.inf)
    cand_vecs = _pad_to(cand_vecs, tile_c, 1, 0.0)
    cand_ids = _pad_to(cand_ids, tile_c, 1, n)
    Cp = cand_proj.shape[1]
    w_arr = jnp.asarray(w, jnp.float32).reshape(1, 1)

    grid = (Qn, Cp // tile_c)
    kern = functools.partial(candidate_verify_kernel, k=k, n=n)
    out_d, out_i = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda qi, t: (0, 0)),  # w
            pl.BlockSpec((1, K), lambda qi, t: (qi, 0)),  # g
            pl.BlockSpec((1, d), lambda qi, t: (qi, 0)),  # q
            pl.BlockSpec((1, tile_c, K), lambda qi, t: (qi, t, 0)),
            pl.BlockSpec((1, tile_c, d), lambda qi, t: (qi, t, 0)),
            pl.BlockSpec((1, tile_c), lambda qi, t: (qi, t)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda qi, t: (qi, 0)),
            pl.BlockSpec((1, k), lambda qi, t: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Qn, k), jnp.float32),
            jax.ShapeDtypeStruct((Qn, k), jnp.int32),
        ],
        interpret=_interp(interpret),
    )(w_arr, g, q, cand_proj, cand_vecs, cand_ids)
    out_i = jnp.where(out_i == _IMAX, n, out_i)
    return out_d, out_i


@functools.partial(jax.jit, static_argnames=("n", "k", "interpret"))
def window_verify(blk_idx, proj_blocks, vec_blocks, ids_blocks, g, q, w, *,
                  n, k, interpret=None):
    """Scalar-prefetch fused window verify over an 'inline' layout table.

    Args:
      blk_idx: (Q, M) int32 STR block ids (nb = invalid slot).
      proj_blocks: (nb, B, K); vec_blocks: (nb, B, d); ids_blocks: (nb, B).
      g: (Q, K); q: (Q, d); w scalar.

    The BlockSpec index_map reads blk_idx — each grid step DMAs exactly
    the selected block HBM->VMEM (zero-copy gather).
    """
    from jax.experimental.pallas import tpu as pltpu

    Qn, M = blk_idx.shape
    nb, B, K = proj_blocks.shape
    d = vec_blocks.shape[-1]
    w_arr = jnp.asarray(w, jnp.float32).reshape(1, 1)
    safe_blk = jnp.minimum(blk_idx, nb - 1).astype(jnp.int32)

    kern = functools.partial(window_verify_kernel, k=k, n=n, nb=nb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Qn, M),
        in_specs=[
            pl.BlockSpec((1, 1), lambda qi, m, blk: (0, 0)),  # w
            pl.BlockSpec((1, K), lambda qi, m, blk: (qi, 0)),  # g
            pl.BlockSpec((1, d), lambda qi, m, blk: (qi, 0)),  # q
            # invalid slots route to the fixed block 0 (not a clamped
            # *real* block): consecutive invalid slots keep the same
            # block index, so Pallas skips the re-DMA entirely, and the
            # kernel pl.when-skips their compute
            pl.BlockSpec((1, B, K), lambda qi, m, blk: (jnp.where(blk[qi, m] < nb, blk[qi, m], 0), 0, 0)),
            pl.BlockSpec((1, B, d), lambda qi, m, blk: (jnp.where(blk[qi, m] < nb, blk[qi, m], 0), 0, 0)),
            pl.BlockSpec((1, B), lambda qi, m, blk: (jnp.where(blk[qi, m] < nb, blk[qi, m], 0), 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda qi, m, blk: (qi, 0)),
            pl.BlockSpec((1, k), lambda qi, m, blk: (qi, 0)),
        ],
    )
    out_d, out_i = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((Qn, k), jnp.float32),
            jax.ShapeDtypeStruct((Qn, k), jnp.int32),
        ],
        interpret=_interp(interpret),
    )(blk_idx, w_arr, g, q, proj_blocks, vec_blocks, ids_blocks)
    out_i = jnp.where(out_i == _IMAX, n, out_i)
    return out_d, out_i


@functools.partial(jax.jit, static_argnames=("exact", "tile_c", "interpret"))
def candidate_dist(cand_proj, cand_vecs, cand_norms, g, q, *, exact: bool = False,
                   tile_c: int = 256, interpret=None):
    """One-pass fused distance + window-halfwidth over pre-gathered
    candidates, tiled per (query, table).

    Args:
      cand_proj: (Q, L, Ct, K); cand_vecs: (Q, L, Ct, d);
      cand_norms: (Q, L, Ct) squared norms (+inf = padded/invalid slot).
      g: (Q, L, K) per-table query projections; q: (Q, d).
      exact: diff-form distances (escape hatch for the ``||x||^2 -
        2<q,x> + ||q||^2`` fp32 rounding change).

    Returns: d2 (Q, L*Ct) exact squared distances (+inf on invalid
    slots in norm form), hw (Q, L*Ct) per-slot window halfwidths
    ``max_k |p_k - g_k|`` (+inf = never admitted) — flattened
    table-major to match the caller's candidate axis.
    """
    Qn, L, Ct, K = cand_proj.shape
    d = cand_vecs.shape[-1]
    tile_c = min(tile_c, max(8, Ct))
    cand_proj = _pad_to(cand_proj, tile_c, 2, jnp.inf)
    cand_vecs = _pad_to(cand_vecs, tile_c, 2, 0.0)
    cand_norms = _pad_to(cand_norms, tile_c, 2, jnp.inf)
    Cp = cand_proj.shape[2]
    q2 = jnp.sum(jnp.square(q), axis=-1, keepdims=True)  # (Q, 1)

    grid = (Qn, L, Cp // tile_c)
    kern = functools.partial(candidate_dist_kernel, exact=exact)
    d2, hw = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, K), lambda qi, l, t: (qi, l, 0)),  # g
            pl.BlockSpec((1, d), lambda qi, l, t: (qi, 0)),  # q
            pl.BlockSpec((1, 1), lambda qi, l, t: (qi, 0)),  # q2
            pl.BlockSpec((1, 1, tile_c, K), lambda qi, l, t: (qi, l, t, 0)),
            pl.BlockSpec((1, 1, tile_c, d), lambda qi, l, t: (qi, l, t, 0)),
            pl.BlockSpec((1, 1, tile_c), lambda qi, l, t: (qi, l, t)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, tile_c), lambda qi, l, t: (qi, l, t)),
            pl.BlockSpec((1, 1, tile_c), lambda qi, l, t: (qi, l, t)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Qn, L, Cp), jnp.float32),
            jax.ShapeDtypeStruct((Qn, L, Cp), jnp.float32),
        ],
        interpret=_interp(interpret),
    )(g, q, q2, cand_proj, cand_vecs, cand_norms)
    return (
        d2[:, :, :Ct].reshape(Qn, L * Ct),
        hw[:, :, :Ct].reshape(Qn, L * Ct),
    )


@functools.partial(jax.jit, static_argnames=("M", "exact", "interpret"))
def window_dist(blk_idx, proj_blocks, vec_blocks, norm_blocks, g, q, *,
                M: int, exact: bool = False, interpret=None):
    """Scalar-prefetch one-pass distance + halfwidth over an 'inline'
    layout index with all L tables flattened onto one block axis.

    Args:
      blk_idx: (Q, S) int32 flattened block ids, S = L*M, table l's
        block b stored as ``l*nb + b`` (``L*nb`` = invalid slot).
      proj_blocks: (L*nb, B, K); vec_blocks: (L*nb, B, d);
      norm_blocks: (L*nb, B) squared norms (+inf padded).
      g: (Q, L, K); q: (Q, d); M: blocks per table (maps slot -> table).

    Returns: d2 (Q, S*B), hw (Q, S*B) — same contract as
    :func:`candidate_dist`, but the block gather happens inside the
    kernel (one DMA per selected block for the whole schedule).
    """
    from jax.experimental.pallas import tpu as pltpu

    Qn, S = blk_idx.shape
    lnb, B, K = proj_blocks.shape
    d = vec_blocks.shape[-1]
    q2 = jnp.sum(jnp.square(q), axis=-1, keepdims=True)  # (Q, 1)

    kern = functools.partial(window_dist_kernel, lnb=lnb, exact=exact)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Qn, S),
        in_specs=[
            pl.BlockSpec((1, 1, K), lambda qi, s, blk: (qi, s // M, 0)),  # g
            pl.BlockSpec((1, d), lambda qi, s, blk: (qi, 0)),  # q
            pl.BlockSpec((1, 1), lambda qi, s, blk: (qi, 0)),  # q2
            # route invalid slots to fixed block 0 (see window_verify:
            # unchanged index -> no re-DMA; compute is pl.when-skipped)
            pl.BlockSpec((1, B, K),
                         lambda qi, s, blk: (jnp.where(blk[qi, s] < lnb, blk[qi, s], 0), 0, 0)),
            pl.BlockSpec((1, B, d),
                         lambda qi, s, blk: (jnp.where(blk[qi, s] < lnb, blk[qi, s], 0), 0, 0)),
            pl.BlockSpec((1, B),
                         lambda qi, s, blk: (jnp.where(blk[qi, s] < lnb, blk[qi, s], 0), 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, B), lambda qi, s, blk: (qi, s, 0)),
            pl.BlockSpec((1, 1, B), lambda qi, s, blk: (qi, s, 0)),
        ],
    )
    d2, hw = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((Qn, S, B), jnp.float32),
            jax.ShapeDtypeStruct((Qn, S, B), jnp.float32),
        ],
        interpret=_interp(interpret),
    )(blk_idx, g, q, q2, proj_blocks, vec_blocks, norm_blocks)
    return d2.reshape(Qn, S * B), hw.reshape(Qn, S * B)


def _quantize_query(q, mode: str):
    """Query-side arithmetic prep for a distance mode.

    Returns (qv, qs): the query operand in the mode's dtype and the
    (Q, 1) per-query dequant scale (all-ones when the mode has none)."""
    Qn = q.shape[0]
    if mode == "bf16":
        return q.astype(jnp.bfloat16), jnp.ones((Qn, 1), jnp.float32)
    if mode == "int8":
        amax = jnp.max(jnp.abs(q), axis=-1, keepdims=True)
        qs = jnp.where(amax > 0.0, amax / 127.0, 1.0).astype(jnp.float32)
        qv = jnp.clip(jnp.round(q / qs), -127.0, 127.0).astype(jnp.int8)
        return qv, qs
    return q, None


@functools.partial(
    jax.jit, static_argnames=("M", "ks", "n", "mode", "interpret")
)
def fused_window_search(blk_idx, halves, proj_blocks, x_blocks, norm_blocks,
                        ids_blocks, g, q, *, M, ks, n, mode: str = "norm",
                        interpret=None, x_scale=None):
    """Fully fused one-pass search over an 'inline' layout index: block
    select DMA + halfwidth + distance + schedule binning + per-bin
    top-ks, one scalar-prefetch kernel — candidates never reach HBM.

    Args:
      blk_idx: (Q, S) int32 flattened block ids, S = L*M (L*nb invalid).
      halves: (steps,) f32 schedule half window widths w_j/2, ascending.
      proj_blocks: (L*nb, B, K); x_blocks: (L*nb, B, d) fp32 vectors
        (mode 'norm'/'exact') or quantized blocks (mode 'bf16'/'int8');
      norm_blocks: (L*nb, B) fp32 squared norms (+inf padded);
      ids_blocks: (L*nb, B) int32; g: (Q, L, K); q: (Q, d) fp32.
      ks: bin accumulator width (k, or 4k for the quantized shortlist).
      x_scale: (L*nb, B) per-slot dequant scales (quant modes only).

    Returns:
      bins_d (Q, steps, ks) f32  per-bin ascending top-ks distances,
      bins_i (Q, steps, ks) i32  matching ids (n = unfilled),
      cnt    (Q, steps)     i32  admitted candidate slots per bin
                                 (cumsum = the C1 admission count).
    """
    from jax.experimental.pallas import tpu as pltpu

    Qn, S = blk_idx.shape
    lnb, B, K = proj_blocks.shape
    d = x_blocks.shape[-1]
    steps = halves.shape[0]
    halves2 = halves.reshape(1, steps).astype(jnp.float32)
    q2 = jnp.sum(jnp.square(q), axis=-1, keepdims=True)  # (Q, 1) fp32
    qv, qs = _quantize_query(q, mode)
    quant = mode in ("bf16", "int8")

    def _route(blk, qi, s):
        return jnp.where(blk[qi, s] < lnb, blk[qi, s], 0)

    in_specs = [
        pl.BlockSpec((1, steps), lambda qi, s, blk: (0, 0)),  # halves
        pl.BlockSpec((1, 1, K), lambda qi, s, blk: (qi, s // M, 0)),  # g
        pl.BlockSpec((1, d), lambda qi, s, blk: (qi, 0)),  # q
        pl.BlockSpec((1, 1), lambda qi, s, blk: (qi, 0)),  # q2
    ]
    operands = [halves2, g, qv, q2]
    if quant:
        in_specs.append(pl.BlockSpec((1, 1), lambda qi, s, blk: (qi, 0)))
        operands.append(qs)
    in_specs += [
        pl.BlockSpec((1, B, K), lambda qi, s, blk: (_route(blk, qi, s), 0, 0)),
        pl.BlockSpec((1, B, d), lambda qi, s, blk: (_route(blk, qi, s), 0, 0)),
        pl.BlockSpec((1, B), lambda qi, s, blk: (_route(blk, qi, s), 0)),
        pl.BlockSpec((1, B), lambda qi, s, blk: (_route(blk, qi, s), 0)),
    ]
    operands += [proj_blocks, x_blocks, norm_blocks, ids_blocks]
    if quant:
        in_specs.append(
            pl.BlockSpec((1, B), lambda qi, s, blk: (_route(blk, qi, s), 0))
        )
        operands.append(x_scale)

    kern = functools.partial(
        fused_window_kernel, lnb=lnb, steps=steps, ks=ks, mode=mode
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Qn, S),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, steps, ks), lambda qi, s, blk: (qi, 0, 0)),
            pl.BlockSpec((1, steps, ks), lambda qi, s, blk: (qi, 0, 0)),
            pl.BlockSpec((1, steps), lambda qi, s, blk: (qi, 0)),
        ],
    )
    bd, bi, cnt = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((Qn, steps, ks), jnp.float32),
            jax.ShapeDtypeStruct((Qn, steps, ks), jnp.int32),
            jax.ShapeDtypeStruct((Qn, steps), jnp.int32),
        ],
        interpret=_interp(interpret),
    )(blk_idx, *operands)
    return bd, jnp.where(bi == _IMAX, n, bi), cnt


@functools.partial(
    jax.jit, static_argnames=("ks", "n", "mode", "tile_c", "interpret")
)
def fused_cand_search(cand_proj, cand_x, cand_norms, cand_ids, halves, g, q,
                      *, ks, n, mode: str = "norm", tile_c: int = 256,
                      interpret=None, cand_scale=None):
    """Gathered twin of :func:`fused_window_search` ('kernel' engine):
    pre-gathered candidates, same bin-accumulator outputs.

    Args:
      cand_proj: (Q, L, Ct, K) (+inf on invalid slots — that alone keeps
        them out of every bin); cand_x: (Q, L, Ct, d) fp32 or quantized;
      cand_norms: (Q, L, Ct) fp32 (+inf padded); cand_ids: (Q, L, Ct);
      halves: (steps,); g: (Q, L, K); q: (Q, d) fp32;
      cand_scale: (Q, L, Ct) dequant scales (quant modes only).

    Returns: (bins_d, bins_i, cnt) as :func:`fused_window_search`.
    """
    Qn, L, Ct, K = cand_proj.shape
    d = cand_x.shape[-1]
    steps = halves.shape[0]
    tile_c = min(tile_c, max(8, Ct))
    cand_proj = _pad_to(cand_proj, tile_c, 2, jnp.inf)
    cand_x = _pad_to(cand_x, tile_c, 2, 0)
    cand_norms = _pad_to(cand_norms, tile_c, 2, jnp.inf)
    cand_ids = _pad_to(cand_ids, tile_c, 2, n)
    Cp = cand_proj.shape[2]
    halves2 = halves.reshape(1, steps).astype(jnp.float32)
    q2 = jnp.sum(jnp.square(q), axis=-1, keepdims=True)  # (Q, 1)
    qv, qs = _quantize_query(q, mode)
    quant = mode in ("bf16", "int8")

    in_specs = [
        pl.BlockSpec((1, steps), lambda qi, l, t: (0, 0)),  # halves
        pl.BlockSpec((1, 1, K), lambda qi, l, t: (qi, l, 0)),  # g
        pl.BlockSpec((1, d), lambda qi, l, t: (qi, 0)),  # q
        pl.BlockSpec((1, 1), lambda qi, l, t: (qi, 0)),  # q2
    ]
    operands = [halves2, g, qv, q2]
    if quant:
        in_specs.append(pl.BlockSpec((1, 1), lambda qi, l, t: (qi, 0)))
        operands.append(qs)
    in_specs += [
        pl.BlockSpec((1, 1, tile_c, K), lambda qi, l, t: (qi, l, t, 0)),
        pl.BlockSpec((1, 1, tile_c, d), lambda qi, l, t: (qi, l, t, 0)),
        pl.BlockSpec((1, 1, tile_c), lambda qi, l, t: (qi, l, t)),
        pl.BlockSpec((1, 1, tile_c), lambda qi, l, t: (qi, l, t)),
    ]
    operands += [cand_proj, cand_x, cand_norms, cand_ids]
    if quant:
        cand_scale = _pad_to(cand_scale, tile_c, 2, 1.0)
        in_specs.append(
            pl.BlockSpec((1, 1, tile_c), lambda qi, l, t: (qi, l, t))
        )
        operands.append(cand_scale)

    kern = functools.partial(fused_cand_kernel, steps=steps, ks=ks, mode=mode)
    bd, bi, cnt = pl.pallas_call(
        kern,
        grid=(Qn, L, Cp // tile_c),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, steps, ks), lambda qi, l, t: (qi, 0, 0)),
            pl.BlockSpec((1, steps, ks), lambda qi, l, t: (qi, 0, 0)),
            pl.BlockSpec((1, steps), lambda qi, l, t: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Qn, steps, ks), jnp.float32),
            jax.ShapeDtypeStruct((Qn, steps, ks), jnp.int32),
            jax.ShapeDtypeStruct((Qn, steps), jnp.int32),
        ],
        interpret=_interp(interpret),
    )(*operands)
    return bd, jnp.where(bi == _IMAX, n, bi), cnt


@functools.partial(jax.jit, static_argnames=("tile_q", "tile_n", "tile_d", "interpret"))
def pairwise_l2(Q, X, *, tile_q: int = 256, tile_n: int = 256, tile_d: int = 128,
                interpret=None):
    """Blocked squared-distance matrix (Q_n, X_n) -> (Q_n, X_n)."""
    nq, d = Q.shape
    nn = X.shape[0]
    tile_q = min(tile_q, nq)
    tile_n = min(tile_n, nn)
    tile_d = min(tile_d, d)
    Qp = _pad_to(_pad_to(Q, tile_q, 0, 0.0), tile_d, 1, 0.0)
    Xp = _pad_to(_pad_to(X, tile_n, 0, 0.0), tile_d, 1, 0.0)
    gq, gn, gd = Qp.shape[0] // tile_q, Xp.shape[0] // tile_n, Qp.shape[1] // tile_d

    out = pl.pallas_call(
        pairwise_l2_kernel,
        grid=(gq, gn, gd),
        in_specs=[
            pl.BlockSpec((tile_q, tile_d), lambda i, j, kd: (i, kd)),
            pl.BlockSpec((tile_n, tile_d), lambda i, j, kd: (j, kd)),
        ],
        out_specs=pl.BlockSpec((tile_q, tile_n), lambda i, j, kd: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Qp.shape[0], Xp.shape[0]), jnp.float32),
        interpret=_interp(interpret),
    )(Qp, Xp)
    return out[:nq, :nn]
