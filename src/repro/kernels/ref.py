"""Pure-jnp oracles for the Pallas kernels (the ``ref.py`` contract).

Every kernel in this package must match its oracle here to numerical
tolerance across the shape/dtype sweeps in tests/test_kernels.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "window_verify_ref",
    "candidate_verify_ref",
    "candidate_dist_ref",
    "window_dist_ref",
    "fused_search_ref",
    "pairwise_l2_ref",
]

_INF = jnp.inf


def candidate_verify_ref(cand_proj, cand_vecs, cand_ids, g, q, w, n, k):
    """Oracle for the pre-gathered candidate verifier.

    Args:
      cand_proj: (Q, C, K) candidate projections.
      cand_vecs: (Q, C, d) candidate vectors.
      cand_ids:  (Q, C)    candidate ids (n = invalid).
      g: (Q, K) query projections; q: (Q, d) query vectors.
      w: scalar window width.
      n: dataset size (sentinel id).
      k: top-k.

    Returns:
      (Q, k) squared distances ascending (+inf pad), (Q, k) ids.
    """
    inbox = jnp.all(jnp.abs(cand_proj - g[:, None, :]) <= 0.5 * w, axis=-1)
    valid = inbox & (cand_ids < n)
    d2 = jnp.sum(jnp.square(cand_vecs - q[:, None, :]), axis=-1)
    d2 = jnp.where(valid, d2, _INF)
    neg, idx = jax.lax.top_k(-d2, k)
    ids = jnp.take_along_axis(cand_ids, idx, axis=1)
    ids = jnp.where(jnp.isfinite(-neg), ids, n)
    return -neg, ids


def window_verify_ref(blk_idx, proj_blocks, vec_blocks, ids_blocks, g, q, w, n, k):
    """Oracle for the scalar-prefetch windowed verifier.

    Args:
      blk_idx: (Q, M) int32 block indices into the table (nb = invalid).
      proj_blocks: (nb, B, K); vec_blocks: (nb, B, d); ids_blocks: (nb, B).
      g: (Q, K); q: (Q, d); w scalar width; n sentinel; k top-k.
    """
    nb = proj_blocks.shape[0]
    pb = jnp.take(proj_blocks, blk_idx, axis=0, mode="fill", fill_value=_INF)
    vb = jnp.take(vec_blocks, blk_idx, axis=0, mode="fill", fill_value=0.0)
    ib = jnp.take(ids_blocks, blk_idx, axis=0, mode="fill", fill_value=n)
    Q, M, B, K = pb.shape
    pb = pb.reshape(Q, M * B, K)
    vb = vb.reshape(Q, M * B, -1)
    ib = ib.reshape(Q, M * B)
    # Semantics: top-k over the *set* of distinct candidates — duplicate
    # block slots (same id, identical dist) count once, like the kernel.
    inbox = jnp.all(jnp.abs(pb - g[:, None, :]) <= 0.5 * w, axis=-1)
    valid = inbox & (ib < n)
    d2 = jnp.sum(jnp.square(vb - q[:, None, :]), axis=-1)
    d2 = jnp.where(valid, d2, _INF)

    def dedup_one(d2q, ibq):
        order = jnp.lexsort((d2q, ibq))
        ids_s = jnp.take(ibq, order)
        d_s = jnp.take(d2q, order)
        first = jnp.concatenate([jnp.ones((1,), bool), ids_s[1:] != ids_s[:-1]])
        d_s = jnp.where(first, d_s, _INF)
        neg, idx = jax.lax.top_k(-d_s, k)
        ids = jnp.take(ids_s, idx)
        return -neg, jnp.where(jnp.isfinite(-neg), ids, n)

    return jax.vmap(dedup_one)(d2, ib)


def candidate_dist_ref(cand_proj, cand_vecs, cand_norms, g, q, exact=False):
    """Oracle for the one-pass distance + halfwidth kernel.

    Args:
      cand_proj: (Q, L, Ct, K); cand_vecs: (Q, L, Ct, d);
      cand_norms: (Q, L, Ct) (+inf = invalid); g: (Q, L, K); q: (Q, d).

    Returns: d2 (Q, L*Ct), hw (Q, L*Ct).
    """
    Qn, L, Ct, _ = cand_proj.shape
    hw = jnp.max(jnp.abs(cand_proj - g[:, :, None, :]), axis=-1)
    if exact:
        d2 = jnp.sum(jnp.square(cand_vecs - q[:, None, None, :]), axis=-1)
    else:
        q2 = jnp.sum(jnp.square(q), axis=-1)
        dots = jnp.einsum("qlcd,qd->qlc", cand_vecs, q)
        d2 = jnp.maximum(cand_norms - 2.0 * dots + q2[:, None, None], 0.0)
    return d2.reshape(Qn, L * Ct), hw.reshape(Qn, L * Ct)


def window_dist_ref(blk_idx, proj_blocks, vec_blocks, norm_blocks, g, q, M,
                    exact=False):
    """Oracle for the scalar-prefetch one-pass kernel: XLA-level gather
    of the flattened (L*nb) block axis, then :func:`candidate_dist_ref`
    semantics per slot."""
    lnb, B, K = proj_blocks.shape
    Qn, S = blk_idx.shape
    pb = jnp.take(proj_blocks, blk_idx, axis=0, mode="fill", fill_value=_INF)
    vb = jnp.take(vec_blocks, blk_idx, axis=0, mode="fill", fill_value=0.0)
    nb_ = jnp.take(norm_blocks, blk_idx, axis=0, mode="fill", fill_value=_INF)
    g_rep = jnp.repeat(g, M, axis=1)  # (Q, S, K)
    hw = jnp.max(jnp.abs(pb - g_rep[:, :, None, :]), axis=-1)  # (Q, S, B)
    if exact:
        d2 = jnp.sum(jnp.square(vb - q[:, None, None, :]), axis=-1)
        # exact mode computes real distances for gathered-garbage slots;
        # match the kernel contract by masking on hw only
    else:
        q2 = jnp.sum(jnp.square(q), axis=-1)
        dots = jnp.einsum("qsbd,qd->qsb", vb, q)
        d2 = jnp.maximum(nb_ - 2.0 * dots + q2[:, None, None], 0.0)
    return d2.reshape(Qn, S * B), hw.reshape(Qn, S * B)


def fused_search_ref(d2, hw, ids, halves, n, ks):
    """Oracle for the fused-search bin accumulators, from a flat pool.

    Given per-slot squared distances ``d2`` (Q, C), admission halfwidths
    ``hw`` (Q, C), ids (Q, C) and the schedule half-widths ``halves``
    (steps,), reproduce the kernel contract with plain host loops:

      * ``binid = #{j: hw > halves[j]}`` — first admitting step
        (``steps`` = never admitted);
      * per bin, the ks lexicographically-smallest *distinct* (d2, id)
        pairs with finite d2 (the kernel's merge_topk dedups identical
        pairs — cross-table duplicates count once);
      * ``cnt[q, j] = #{slots with binid == j}``.

    Returns numpy (bins_d (Q, steps, ks) f32, bins_i (Q, steps, ks) i32
    with ``n`` on unfilled slots, cnt (Q, steps) i32).  Distance mode is
    the caller's business: feed fp32 or quantized d2 pools alike.
    """
    import numpy as np

    d2 = np.asarray(d2)
    hw = np.asarray(hw)
    ids = np.asarray(ids)
    halves = np.asarray(halves)
    Qn, C = d2.shape
    steps = halves.shape[0]
    bd = np.full((Qn, steps, ks), np.inf, np.float32)
    bi = np.full((Qn, steps, ks), n, np.int32)
    cnt = np.zeros((Qn, steps), np.int32)
    for qi in range(Qn):
        binid = (hw[qi][:, None] > halves[None, :]).sum(axis=1)
        for j in range(steps):
            sel = np.nonzero(binid == j)[0]
            cnt[qi, j] = sel.size
            pairs = sorted(
                {(float(d2[qi, s]), int(ids[qi, s])) for s in sel}
            )
            pairs = [p for p in pairs if np.isfinite(p[0])][:ks]
            for r, (dd, ii) in enumerate(pairs):
                bd[qi, j, r] = dd
                bi[qi, j, r] = ii
    return bd, bi, cnt


def pairwise_l2_ref(Q, X):
    """Oracle squared-distance matrix: (q, n) -> ||Q_q - X_n||^2."""
    qn = jnp.sum(jnp.square(Q), axis=-1, keepdims=True)
    xn = jnp.sum(jnp.square(X), axis=-1)
    return jnp.maximum(qn - 2.0 * Q @ X.T + xn, 0.0)
