"""Pallas TPU kernel: blocked squared-L2 distance matrix.

``D2[i, j] = ||Q_i - X_j||^2 = ||Q_i||^2 - 2 Q_i . X_j + ||X_j||^2``

Classic matmul-shaped kernel: grid (nq/TQ, nn/TN, nd/TD) with the
contraction (d) innermost; the ``-2 Q X^T`` term runs on the MXU via
``jax.lax.dot_general`` with fp32 accumulation, the two rank-1 norm
terms are accumulated per-d-tile on the VPU (their per-tile partial sums
telescope to the full norms). Output block is revisited across d tiles.

Used by the brute-force oracle, the MQ (PM-LSH-style) baseline's
projected-space metric query, and batch re-verification.

Tile defaults (ops.py): TQ=TN=256, TD=128 -> VMEM: 2*256*128*4 (A,B) +
256*256*4 (acc) = 512 KiB.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def pairwise_l2_kernel(q_ref, x_ref, out_ref):
    """Blocks: q (TQ, TD), x (TN, TD), out (TQ, TN) revisited over d."""
    td = pl.program_id(2)

    @pl.when(td == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = q_ref[...]  # (TQ, TD)
    b = x_ref[...]  # (TN, TD)
    # MXU: -2 A B^T with fp32 accumulation
    prod = jax.lax.dot_general(
        a,
        b,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    qn = jnp.sum(a.astype(jnp.float32) ** 2, axis=1, keepdims=True)  # (TQ, 1)
    xn = jnp.sum(b.astype(jnp.float32) ** 2, axis=1, keepdims=True)  # (TN, 1)
    out_ref[...] += qn - 2.0 * prod + xn.T

    @pl.when(td == pl.num_programs(2) - 1)
    def _clamp():
        out_ref[...] = jnp.maximum(out_ref[...], 0.0)
