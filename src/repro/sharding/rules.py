"""Per-architecture PartitionSpec rules (DP / TP / EP / SP).

Conventions (mesh axes: optional 'pod', 'data', 'model'):

  * batch dims shard over ('pod', 'data') — DP everywhere;
  * attention heads / FFN features / experts shard over 'model' — TP/EP;
    GQA KV projections are replicated when n_kv < model-axis size;
  * embeddings / LM head shard the vocab over 'model';
  * SSM in/out projections shard their *contraction* dim over 'model'
    (row-parallel; SPMD inserts the psum);
  * decode KV caches shard batch over 'data' and KV heads over 'model'
    when divisible, else the *sequence* dim over 'model' (sequence
    parallelism — exact, GSPMD partitions the masked softmax);
  * optimizer state mirrors the parameter specs (Adafactor row/col
    factors drop the corresponding trailing dims).

Matching is by parameter tree path, applied to a shape tree from
jax.eval_shape — no allocation.
"""

from __future__ import annotations

import re

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["param_specs", "state_specs", "batch_specs", "cache_specs",
           "named", "dp_axes"]


def dp_axes(mesh):
    axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return axes if len(axes) > 1 else axes[0]


def _tp(mesh):
    return mesh.shape.get("model", 1)


# (path regex, fn(shape, tp) -> PartitionSpec). First match wins. Paths
# look like "blocks/attn/wq", "dec_blocks/ffn/w_up", "cross_blocks/...".
def _rules(tp):
    def heads_ok(n):
        return n % tp == 0

    return [
        # embeddings / head: vocab over model
        (r"^embed$", lambda s: P("model", None)),
        (r"^lm_head$", lambda s: P(None, "model")),
        (r"^img_proj$", lambda s: P(None, None)),
        # attention (leading dims: layer stacks) — q/o shard heads
        (r"(attn|xattn)/wq$", lambda s: P(*(None,) * (len(s) - 3), None, "model", None)),
        (r"(attn|xattn)/w[kv]$", lambda s: (
            P(*(None,) * (len(s) - 3), None, "model", None)
            if heads_ok(s[-2]) else P(*(None,) * len(s))
        )),
        (r"(attn|xattn)/wo$", lambda s: P(*(None,) * (len(s) - 3), "model", None, None)),
        # dense FFN
        (r"ffn/w_(up|gate)$", lambda s: P(*(None,) * (len(s) - 2), None, "model")),
        (r"ffn/w_down$", lambda s: P(*(None,) * (len(s) - 2), "model", None)),
        # MoE: experts over model (EP); router replicated
        (r"moe/router$", lambda s: P(*(None,) * len(s))),
        (r"moe/w_(up|gate|down)$", lambda s: P(*(None,) * (len(s) - 3), "model", None, None)),
        # SSM (§Perf A2): Megatron pairing — z/x segment column-parallel
        # on the head-aligned dim, out_proj row-parallel (one psum/layer);
        # depthwise conv weights follow the activation sharding.
        (r"ssm/w_zx$", lambda s: P(*(None,) * (len(s) - 2), None, "model")),
        (r"ssm/(conv_wx|conv_bx)$", lambda s: P(*(None,) * (len(s) - 1), "model")),
        (r"ssm/out_proj$", lambda s: P(*(None,) * (len(s) - 2), "model", None)),
        (r"ssm/", lambda s: P(*(None,) * len(s))),
        # norms, gates, scalars: replicated
        (r".*", lambda s: P(*(None,) * len(s))),
    ]


def _path_str(path):
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(params_shapes, mesh, fsdp: bool = True, fsdp_min_size: int = 1 << 20):
    """params shape-tree -> PartitionSpec tree.

    fsdp=True additionally shards every large tensor's biggest
    still-unsharded dim over the data axes (ZeRO-3 style: parameters and
    optimizer state are fully sharded; GSPMD inserts the per-layer
    all-gather at use and reduce-scatters the gradients)."""
    rules = _rules(_tp(mesh))
    dpa = dp_axes(mesh)
    dp_size = 1
    for a in (dpa if isinstance(dpa, tuple) else (dpa,)):
        dp_size *= mesh.shape.get(a, 1)

    def one(path, leaf):
        ps = _path_str(path)
        for pat, fn in rules:
            if re.search(pat, ps):
                spec = fn(leaf.shape)
                # guard: never shard a dim not divisible by the axis size
                fixed = []
                for dim, ax in zip(leaf.shape, spec):
                    if ax is not None and dim % mesh.shape.get(ax, 1) != 0:
                        fixed.append(None)
                    else:
                        fixed.append(ax)
                if fsdp and leaf.size >= fsdp_min_size and dp_size > 1:
                    # biggest unsharded, divisible dim -> data axes
                    cands = [
                        (dim, i) for i, (dim, ax) in enumerate(zip(leaf.shape, fixed))
                        if ax is None and dim % dp_size == 0
                    ]
                    if cands:
                        _, i = max(cands)
                        fixed[i] = dpa
                return P(*fixed)
        raise AssertionError(ps)

    return jax.tree_util.tree_map_with_path(one, params_shapes)


def state_specs(state_shapes, pspecs, mesh):
    """Train-state shape tree -> specs. Optimizer moments mirror params;
    Adafactor factored stats drop the corresponding dims."""
    flat_p = dict(
        (_path_str(kp), s)
        for kp, s in jax.tree_util.tree_flatten_with_path(pspecs)[0]
    )

    def one(path, leaf):
        ps = _path_str(path)
        if ps.startswith("params/"):
            return flat_p[ps[len("params/"):]]
        if ps.startswith("err/"):
            return flat_p[ps[len("err/"):]]
        m = re.match(r"^opt/(m|v)/(.*)$", ps)
        if m:
            return flat_p[m.group(2)]
        m = re.match(r"^opt/v/(.*)/(vr|vc|v)$", ps)
        if m:
            base = flat_p[m.group(1)]
            if m.group(2) == "vr":
                return P(*base[:-1])
            if m.group(2) == "vc":
                return P(*(base[:-2] + (base[-1],)))
            return base
        return P()  # step counters etc.

    def one_checked(path, leaf):
        ps = _path_str(path)
        m = re.match(r"^opt/v/(.*)/(vr|vc|v)$", ps)
        if m and m.group(1) in flat_p:
            base = flat_p[m.group(1)]
            if m.group(2) == "vr":
                return P(*base[:-1])
            if m.group(2) == "vc":
                return P(*(base[:-2] + (base[-1],)))
            return base
        return one(path, leaf)

    return jax.tree_util.tree_map_with_path(one_checked, state_shapes)


def _axes_size(mesh, ax):
    sz = 1
    if ax is not None:
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            sz *= mesh.shape.get(a, 1)
    return sz


def _guard_spec(shape, spec, mesh):
    """Drop axes whose size does not divide the dim (e.g. batch=1)."""
    fixed = []
    for dim, ax in zip(shape, spec):
        fixed.append(None if (ax is not None and dim % _axes_size(mesh, ax) != 0) else ax)
    return P(*fixed)


def batch_specs(batch_shapes, mesh):
    dp = dp_axes(mesh)
    return jax.tree.map(
        lambda s: _guard_spec(s.shape, (dp,) + (None,) * (len(s.shape) - 1), mesh),
        batch_shapes,
    )


def cache_specs(cache_shapes, mesh, batch_axis=1):
    """Decode caches: batch over data axes; KV heads over 'model' when
    divisible, else sequence over 'model' (SP). Cache leaves are either
    stacked (L, B, S, KV, hd) / (L, B, H, N, P) / (G, E, B, S, KV, hd)
    or per-layer (B, S, KV, hd)."""
    tp = _tp(mesh)
    dp = dp_axes(mesh)

    def one(path, leaf):
        shape = leaf.shape
        ps = _path_str(path)
        # find batch dim: first dim whose index matches the layout
        if ps.endswith("ssm"):  # (..., B, H, N, P)
            nb = len(shape) - 4
            spec = [None] * len(shape)
            spec[nb] = dp
            if shape[nb + 1] % tp == 0:
                spec[nb + 1] = "model"
            return P(*spec)
        if ps.endswith("conv"):  # (..., B, W, C)
            nb = len(shape) - 3
            spec = [None] * len(shape)
            spec[nb] = dp
            if shape[-1] % tp == 0:
                spec[-1] = "model"
            return P(*spec)
        # attention caches (..., B, S, KV, hd)
        nb = len(shape) - 4
        spec = [None] * len(shape)
        spec[nb] = dp
        if shape[nb + 2] % tp == 0:
            spec[nb + 2] = "model"  # KV heads
        elif shape[nb + 1] % tp == 0:
            spec[nb + 1] = "model"  # sequence parallelism
        return P(*spec)

    def guard(path, leaf):
        return _guard_spec(leaf.shape, one(path, leaf), mesh)

    return jax.tree_util.tree_map_with_path(guard, cache_shapes)


def named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
