"""GPipe pipeline parallelism over the 'pod' axis.

The layer stack is split into `n_stages = mesh.shape['pod']` contiguous
stages (stacked block params sharded P('pod') on the layer dim). The
global batch is cut into M microbatches that flow through the stages;
activations move stage-to-stage with a single `ppermute` per tick
(M + S - 1 ticks per step; bubble fraction (S-1)/(M+S-1)).

Embedding runs on stage 0, final-norm + LM head + CE on stage S-1;
the loss is broadcast back with a psum. jax.grad differentiates through
shard_map/ppermute (its transpose is the reverse permute), so this
composes with the standard train step — PP×TP×DP = ('pod','model','data').

Supports the uniform scanned families (dense/moe/ssm); layer count must
divide the stage count.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..models import transformer
from ..models.common import compute_dtype, cross_entropy, rmsnorm

__all__ = ["pp_loss_fn", "pp_param_specs"]


def pp_param_specs(params_shapes, base_specs):
    """Add P('pod') on the leading (layer) dim of every blocks/* leaf."""

    def one(path, leaf_spec, leaf_shape):
        keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        if keys and keys[0] == "blocks":
            return P(*(("pod",) + tuple(leaf_spec)[1:]))
        return leaf_spec

    return jax.tree_util.tree_map_with_path(
        lambda p, s, sh: one(p, s, sh), base_specs, params_shapes
    )


def pp_loss_fn(params, batch, cfg, mesh, microbatches: int = 8):
    """Pipeline-parallel CE loss (replaces model.loss under PP)."""
    n_stages = mesh.shape["pod"]
    assert cfg.n_layers % n_stages == 0, (cfg.n_layers, n_stages)
    dt = compute_dtype(cfg)
    M = microbatches

    def stage_fn(blocks_local, other, tokens, labels):
        stage = jax.lax.axis_index("pod")
        B, T = tokens.shape
        assert B % M == 0, (B, M)
        mb_tok = tokens.reshape(M, B // M, T)
        mb_lab = labels.reshape(M, B // M, T)
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B // M, T))

        def run_stage(x):
            def body(x, bp):
                bp = jax.tree.map(
                    lambda a: a.astype(dt) if a.dtype == jnp.float32 and a.ndim > 1 else a,
                    bp,
                )
                x, _, _ = transformer.block_forward(
                    x, bp, cfg, mesh, positions=positions,
                    window=cfg.sliding_window,
                )
                return x, None

            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
            x, _ = jax.lax.scan(body, x, blocks_local)
            return x

        def tick(carry, t):
            buf, loss_acc = carry
            mb_idx = t - stage
            active = (mb_idx >= 0) & (mb_idx < M)
            safe = jnp.clip(mb_idx, 0, M - 1)
            tok = jax.lax.dynamic_index_in_dim(mb_tok, safe, 0, keepdims=False)
            lab = jax.lax.dynamic_index_in_dim(mb_lab, safe, 0, keepdims=False)
            x0 = jnp.take(other["embed"], tok, axis=0).astype(dt)
            x_in = jnp.where(stage == 0, x0, buf)
            y = run_stage(x_in)
            # last stage: head + CE for its active microbatch
            h = rmsnorm(y, other["final_norm"], cfg.norm_eps)
            w = other["embed"].T if cfg.tie_embeddings else other["lm_head"]
            logits = jnp.einsum("btd,dv->btv", h, w.astype(h.dtype))
            ce = cross_entropy(logits, lab, cfg.vocab_size)
            is_last = stage == n_stages - 1
            loss_acc = loss_acc + jnp.where(active & is_last, ce, 0.0)
            # rotate activations: stage s -> s+1
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf_next = jax.lax.ppermute(y, "pod", perm)
            return (buf_next, loss_acc), None

        buf0 = jnp.zeros((B // M, T, cfg.d_model), dt)
        (buf, loss_acc), _ = jax.lax.scan(
            tick, (buf0, jnp.zeros((), jnp.float32)), jnp.arange(M + n_stages - 1)
        )
        # everyone returns the last stage's mean loss
        return jax.lax.psum(loss_acc, "pod") / M

    other = {k: v for k, v in params.items() if k != "blocks"}
    blocks_spec = jax.tree.map(lambda _: P("pod"), params["blocks"])
    other_spec = jax.tree.map(lambda _: P(), other)
    return shard_map(
        stage_fn,
        mesh=mesh,
        in_specs=(blocks_spec, other_spec, P(), P()),
        out_specs=P(),
        axis_names={"pod"},
    )(params["blocks"], other, batch["tokens"], batch["labels"])
