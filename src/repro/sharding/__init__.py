from . import rules
