import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell: jax.jit(step, in_shardings, out_shardings).lower(*specs)
.compile() on the production mesh — ShapeDtypeStructs only, nothing is
allocated. Records memory_analysis(), cost_analysis(), and the HLO-walk
stats (trip-count-corrected FLOPs / HBM bytes / collective bytes) to a
JSON per cell; existing results are skipped (incremental cache).

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""

import argparse
import json
import time
import traceback

import jax

from ..configs import CONFIGS, SHAPES, get_config, runnable
from ..models.registry import build_model
from .hlo_stats import analyze
from .mesh import make_production_mesh
from .steps import build_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../benchmarks/results/dryrun")


def cell_path(arch, shape, multi_pod, compress=False):
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    if compress:
        mesh_tag += "_int8pod"
    d = os.path.join(RESULTS_DIR, mesh_tag)
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, f"{arch}__{shape}.json")


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             compress_pods: bool = False, force: bool = False,
             save_hlo: bool = False) -> dict:
    path = cell_path(arch, shape_name, multi_pod, compress_pods)
    if os.path.exists(path) and not force:
        with open(path) as f:
            cached = json.load(f)
        if cached.get("status") in ("ok", "skipped"):
            return cached  # errors are retried

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "phase": shape.phase,
    }
    if not runnable(cfg, shape):
        result["status"] = "skipped"
        result["reason"] = (
            "long_500k requires sub-quadratic attention; "
            f"{arch} is full-attention (DESIGN.md §5)"
        )
        _write(path, result)
        return result

    try:
        t0 = time.time()
        mesh = make_production_mesh(multi_pod=multi_pod)
        model = build_model(cfg)
        with mesh:
            fn, args, in_sh, out_sh, donate = build_step(
                model, shape, mesh, compress_pods=compress_pods
            )
            lowered = jax.jit(
                fn, in_shardings=in_sh, out_shardings=out_sh,
                donate_argnums=donate,
            ).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis() or {}
            hlo = compiled.as_text()
            stats = analyze(hlo)

        result.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "per_device_total": (
                    mem.argument_size_in_bytes
                    + mem.output_size_in_bytes
                    + mem.temp_size_in_bytes
                    - mem.alias_size_in_bytes
                ),
            },
            cost_analysis={
                "flops_body_once": cost.get("flops", 0.0),
                "bytes_body_once": cost.get("bytes accessed", 0.0),
            },
            hlo_stats={
                "flops": stats.flops,
                "hbm_bytes": stats.hbm_bytes,
                "collective_bytes": stats.collective_bytes,
                "collective_breakdown": stats.collective_breakdown,
            },
            hlo_size=len(hlo),
        )
        if save_hlo:
            with open(path.replace(".json", ".hlo.txt"), "w") as f:
                f.write(hlo)
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        result.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
    _write(path, result)
    return result


def _write(path, result):
    with open(path + ".tmp", "w") as f:
        json.dump(result, f, indent=1)
    os.replace(path + ".tmp", path)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--compress-pods", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = sorted(CONFIGS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for mp in meshes:
        for a in archs:
            for s in shapes:
                cells.append((a, s, mp))

    for i, (a, s, mp) in enumerate(cells):
        t0 = time.time()
        r = run_cell(a, s, mp, compress_pods=args.compress_pods,
                     force=args.force, save_hlo=args.save_hlo)
        status = r.get("status")
        extra = ""
        if status == "ok":
            gb = r["memory"]["per_device_total"] / 2**30
            extra = f"mem/dev={gb:.2f}GiB compile={r['compile_s']}s"
        elif status == "error":
            extra = r["error"][:120]
        print(
            f"[{i + 1}/{len(cells)}] {a} x {s} x {'2x16x16' if mp else '16x16'}: "
            f"{status} {extra} ({time.time() - t0:.1f}s)",
            flush=True,
        )


if __name__ == "__main__":
    main()
