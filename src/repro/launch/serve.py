"""Serving launcher: continuous-batching engine (+ optional kNN-LM).

    PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --requests 16
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs import get_config
from ..data.pipeline import SyntheticTokens, make_batch_fn
from ..models.registry import build_model
from ..serve import Request, RetrievalLM, ServeEngine, build_datastore


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--retrieval", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if jax.default_backend() == "cpu":
        cfg = cfg.smoke().scaled(dtype="float32", n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    retrieval = None
    if args.retrieval:
        src = SyntheticTokens(cfg.vocab_size, 32, 2)
        batches = [make_batch_fn(src)(s) for s in range(4)]
        ds = build_datastore(model, params, batches, jax.random.key(1), t=32, k=8)
        retrieval = RetrievalLM(model, ds, r0=1.0, steps=4)

    eng = ServeEngine(model, params, slots=args.slots, cache_len=args.cache_len,
                      retrieval=retrieval)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=8).astype(np.int32),
                    max_new_tokens=16)
            for i in range(args.requests)]
    for r in reqs:
        eng.submit(r)
    steps = eng.run()
    done = sum(r.done for r in reqs)
    print(f"served {done}/{len(reqs)} requests in {steps} engine steps")


if __name__ == "__main__":
    main()
