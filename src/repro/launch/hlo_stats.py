"""Post-SPMD HLO text analyzer for the roofline terms.

XLA's ``compiled.cost_analysis()`` counts ``while`` bodies ONCE — a
scanned 48-layer model reports 1/48th of its FLOPs. This walker parses
``compiled.as_text()`` into a computation call graph, extracts per-op
stats, and aggregates with loop trip counts:

  * FLOPs: from ``dot`` ops (2 * prod(output dims) * prod(contracting
    dims)), descending into fusion bodies;
  * HBM bytes: operand + output bytes of *top-level* ops per computation
    (post-fusion HLO executes fusions as units: one read of operands,
    one write of outputs) — fusion bodies are not double counted;
  * collective bytes: operand bytes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute (+ ragged variants);
  * trip counts: for each ``while``, the largest integer literal
    compared against in its condition computation (lax.scan emits
    ``compare(iter, constant(N)), direction=LT``).

Validated against analytic FLOP counts in tests/test_hlo_stats.py.
"""

from __future__ import annotations

import dataclasses
import re
from functools import lru_cache

__all__ = ["analyze", "HloStats"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "tuple": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
)


def _shape_bytes(text: str) -> int:
    """Sum bytes over every shape literal in `text` (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(dt_dims) -> int:
    dt, dims = dt_dims
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclasses.dataclass
class OpInfo:
    kind: str
    out_bytes: int
    operand_bytes: int
    flops: float
    collective_bytes: int
    called: list  # (comp_name, role)


@dataclasses.dataclass
class HloStats:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    collective_breakdown: dict
    per_collective: list


def _split_top_level(s: str) -> list[str]:
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return out


# ops whose operand/output traffic is not real HBM movement
_FREE_OPS = {
    "parameter", "get-tuple-element", "tuple", "bitcast", "constant",
    "after-all", "partition-id", "replica-id", "while", "conditional",
    "call", "reshape", "add-dependency", "domain", "opt-barrier",
}

_DEF_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\w+\[[\d,]*\](?:\{[^}]*\})?)|\((?:[^()]|\([^()]*\))*\))\s+"
    r"([\w\-]+)\((.*)$"
)


def _parse_ops(body: str):
    # pass 1: symbol table name -> type text
    shapes = {}
    lines = []
    for raw in body.splitlines():
        line = raw.strip()
        if not line or line.startswith("//"):
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, out_type, kind, rest = m.groups()
        shapes[name] = out_type
        lines.append((name, out_type, kind, rest))

    ops = []
    for name, out_type, kind, rest in lines:
        arg_txt = rest.split("), ")[0] if "), " in rest else rest.rstrip(")")
        # Some printers write operand types inline ("dot(f32[128,256]{1,0}
        # %Arg_0.1, ...)"), so layout braces can precede the first operand
        # name — scan the whole arg list rather than stopping at a "{".
        operand_names = re.findall(r"%([\w.\-]+)", arg_txt)
        operand_bytes = sum(_shape_bytes(shapes.get(o, "")) for o in operand_names)
        out_bytes = _shape_bytes(out_type)
        flops = 0.0
        if kind == "dot":
            out_elems = _shape_bytes(out_type) // max(
                _DTYPE_BYTES.get(out_type.split("[")[0], 4), 1
            )
            lhs_type = shapes.get(operand_names[0], "") if operand_names else ""
            ms = _SHAPE_RE.search(lhs_type)
            lhs_shape = [int(x) for x in ms.group(2).split(",") if x] if ms else []
            cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
            contr = 1
            if cd and lhs_shape:
                for d in cd.group(1).split(","):
                    if d:
                        contr *= lhs_shape[int(d)]
            elif lhs_shape:
                contr = lhs_shape[-1]
            flops = 2.0 * out_elems * contr
        coll = operand_bytes if kind in _COLLECTIVES else 0
        if kind in _FREE_OPS:
            out_bytes = 0
            operand_bytes = 0
        called = []
        for role in ("condition", "body", "to_apply", "calls"):
            cm = re.search(role + r"=%?([\w.\-]+)", rest)
            if cm:
                called.append((cm.group(1), role))
        bm = re.search(r"branch_computations=\{([^}]*)\}", rest)
        if bm:
            for c in bm.group(1).split(","):
                called.append((c.strip().lstrip("%"), "branch"))
        ops.append(OpInfo(kind, out_bytes, operand_bytes, flops, coll, called))
    return ops


def _parse_computations(text: str):
    """name -> body text. Handles `%name (args) -> ret {` ... `}` blocks
    and `ENTRY %name`. Assumes XLA's 2-space indented pretty printer."""
    comps = {}
    cur_name, cur_lines = None, []
    for line in text.splitlines():
        m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$", line)
        if m and not line.startswith(" "):
            cur_name = m.group(1)
            cur_lines = []
            continue
        if cur_name is not None:
            if line.startswith("}"):
                comps[cur_name] = "\n".join(cur_lines)
                cur_name = None
            else:
                cur_lines.append(line)
    return comps


def _trip_count(cond_body: str) -> int:
    """Largest integer literal in the while condition (scan: LT compare)."""
    best = 1
    for m in re.finditer(r"constant\((\d+)\)", cond_body):
        best = max(best, int(m.group(1)))
    return best


def analyze(hlo_text: str, entry: str | None = None) -> HloStats:
    comps = _parse_computations(hlo_text)
    ops_by_comp = {name: _parse_ops(body) for name, body in comps.items()}
    if entry is None:
        m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo_text)
        entry = m.group(1) if m else next(iter(comps))

    memo = {}
    per_collective = []

    def total(name, mult):
        # (flops, hbm, coll, breakdown) for one execution of computation
        if name in memo:
            f, h, c, br = memo[name]
        else:
            f = h = c = 0.0
            br = {}

            def add(cf, ch, cc, cbr, times=1.0):
                nonlocal f, h, c
                f += cf * times
                h += ch * times
                c += cc * times
                for k, v in cbr.items():
                    br[k] = br.get(k, 0) + v * times

            for op in ops_by_comp.get(name, []):
                h += op.out_bytes + op.operand_bytes
                c += op.collective_bytes
                if op.collective_bytes:
                    br[op.kind] = br.get(op.kind, 0) + op.collective_bytes
                f += op.flops
                roles = dict((r, cn) for cn, r in op.called)
                if op.kind == "while":
                    trips = _trip_count(comps.get(roles.get("condition", ""), ""))
                    add(*total(roles["body"], 1), times=trips)
                elif op.kind == "fusion" and "calls" in roles:
                    # fusion body: flops/collectives execute; HBM traffic
                    # already counted at the fusion boundary above
                    cf, _, cc, cbr = total(roles["calls"], 1)
                    add(cf, 0.0, cc, cbr)
                elif op.kind == "conditional":
                    branches = [cn for cn, r in op.called if r == "branch"]
                    if branches:  # charge the max branch
                        add(*max((total(b, 1) for b in branches),
                                 key=lambda t: t[0] + t[1]))
                elif op.kind == "call" and "to_apply" in roles:
                    add(*total(roles["to_apply"], 1))
            memo[name] = (f, h, c, br)
        return f * mult, h * mult, c * mult, {k: v * mult for k, v in br.items()}

    f, h, c, br = total(entry, 1)
    return HloStats(
        flops=f, hbm_bytes=h, collective_bytes=c,
        collective_breakdown=br, per_collective=per_collective,
    )
