"""Step-function + sharding assembly for launcher/dry-run.

``build_step(model, shape, mesh, ...)`` returns (fn, example_args,
in_shardings, out_shardings, donate) ready for
``jax.jit(fn, ...).lower(*args)`` — args are ShapeDtypeStructs, so
nothing is allocated (the dry-run contract)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ShapeConfig
from ..sharding import rules
from ..train import make_optimizer, make_train_step
from ..train.optimizer import cosine_schedule

__all__ = ["build_step"]


def build_step(model, shape: ShapeConfig, mesh, *, compress_pods=False,
               batch_override: int = 0):
    cfg = model.cfg
    specs = model.input_specs(shape, batch_override=batch_override)

    if shape.phase == "train":
        opt = make_optimizer(cfg.optimizer, cosine_schedule(3e-4, 2000, 200_000))
        step = make_train_step(model, opt, mesh, compress_pods=compress_pods,
                               accum_steps=cfg.accum_steps)
        state_shapes = jax.eval_shape(
            lambda k: _init_state(model, opt, k, compress_pods), jax.random.key(0)
        )
        pspecs = rules.param_specs(state_shapes["params"], mesh)
        sspecs = rules.state_specs(state_shapes, pspecs, mesh)
        bspecs = rules.batch_specs(specs["batch"], mesh)
        in_sh = (rules.named(mesh, sspecs), rules.named(mesh, bspecs))
        out_sh = (rules.named(mesh, sspecs), None)
        return step, (state_shapes, specs["batch"]), in_sh, out_sh, (0,)

    params_shapes = jax.eval_shape(model.init, jax.random.key(0))
    pspecs = rules.param_specs(params_shapes, mesh)

    dp = rules.dp_axes(mesh)
    B = batch_override or shape.global_batch

    def _out_vec_specs():
        """(logits (B,V), hidden (B,D)) with divisibility guards."""
        v = model.cfg.padded_vocab
        lspec = rules._guard_spec((B, v), (dp, "model"), mesh)
        hspec = rules._guard_spec((B, model.cfg.d_model), (dp, None), mesh)
        return lspec, hspec

    if shape.phase == "prefill":
        def fn(params, batch):
            logits, hidden, caches = model.prefill(params, batch, mesh)
            # return last-position hidden (retrieval key) + caches + logits
            return logits, hidden[:, -1, :], caches

        bspecs = rules.batch_specs(specs["batch"], mesh)
        cache_shapes = jax.eval_shape(
            lambda p, b: fn(p, b)[2], params_shapes, specs["batch"]
        )
        cspecs = rules.cache_specs(cache_shapes, mesh)
        lspec, hspec = _out_vec_specs()
        in_sh = (rules.named(mesh, pspecs), rules.named(mesh, bspecs))
        out_sh = (
            rules.named(mesh, lspec),
            rules.named(mesh, hspec),
            rules.named(mesh, cspecs),
        )
        return fn, (params_shapes, specs["batch"]), in_sh, out_sh, ()

    if shape.phase == "decode":
        def fn(params, token, caches, pos):
            return model.decode(params, token, caches, pos, mesh)

        cspecs = rules.cache_specs(specs["caches"], mesh)
        lspec, hspec = _out_vec_specs()
        tok_spec = rules._guard_spec((B,), (dp,), mesh)
        in_sh = (
            rules.named(mesh, pspecs),
            rules.named(mesh, tok_spec),
            rules.named(mesh, cspecs),
            rules.named(mesh, P()),
        )
        out_sh = (
            rules.named(mesh, lspec),
            rules.named(mesh, hspec),
            rules.named(mesh, cspecs),
        )
        args = (params_shapes, specs["token"], specs["caches"], specs["pos"])
        return fn, args, in_sh, out_sh, (2,)  # donate caches

    raise ValueError(shape.phase)


def _init_state(model, opt, key, compress_pods):
    from ..train.train_step import init_train_state

    return init_train_state(model, opt, key, compress_pods=compress_pods)
