"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch yi-9b \
        [--steps N] [--scale-layers L] [--ckpt DIR] [--compress-pods]

On a real fleet this runs under `jax.distributed.initialize()`; in this
container it runs the same code on the local device with reduced
configs. The full-mesh program is exercised by launch/dryrun.py.
"""

from __future__ import annotations

import argparse

import jax

from ..configs import SHAPES, get_config
from ..data.pipeline import SyntheticTokens, make_batch_fn
from ..models.registry import build_model, param_count
from ..runtime import TrainSupervisor
from ..train import init_train_state, make_optimizer, make_train_step
from ..train.optimizer import cosine_schedule


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-sized)")
    ap.add_argument("--ckpt", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke or jax.default_backend() == "cpu":
        cfg = cfg.smoke().scaled(dtype="float32")
    model = build_model(cfg)
    opt = make_optimizer(cfg.optimizer, cosine_schedule(3e-4, 10, args.steps))
    state = init_train_state(model, opt, jax.random.key(0))
    print(f"{cfg.name}: {param_count(state['params']) / 1e6:.1f}M params, "
          f"optimizer={cfg.optimizer}")

    src = SyntheticTokens(cfg.vocab_size, args.seq, args.batch)
    extras = {}
    if cfg.family == "encdec":
        extras["frames"] = (cfg.enc_seq, cfg.d_model)
    if cfg.family == "vlm":
        extras["images"] = (cfg.n_img_tokens, cfg.d_vision)
    batch_fn = make_batch_fn(src, extras=extras)
    step_fn = jax.jit(make_train_step(model, opt))

    sup = TrainSupervisor(args.ckpt, ckpt_every=args.ckpt_every)

    def log(step, metrics, dt, slow):
        if step % 10 == 0:
            print(f"step {step:4d} loss {float(metrics['loss']):.4f} "
                  f"{dt * 1e3:.0f} ms" + (" [STRAGGLER]" if slow else ""))

    sup.run(state, step_fn, batch_fn, args.steps, log=log)
    print("training complete")


if __name__ == "__main__":
    main()
