"""Production mesh construction.

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax
import; everything else sees the real device count).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    # every axis Auto — the default on all supported jax versions (the
    # axis_types parameter does not exist on jax 0.4.x)
    return jax.make_mesh(shape, axes)


def make_local_mesh(multi_pod: bool = False):
    """Degenerate mesh over however many devices exist (tests / CPU)."""
    n = len(jax.devices())
    shape = (1, 1, n) if multi_pod else (1, n)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
