from .fault_tolerance import StragglerMonitor, TrainSupervisor
