"""Fault-tolerant training supervision: checkpoint/restart, straggler
mitigation, elastic resume.

On real fleets, failures arrive as lost hosts / NCCL-ICI timeouts; in
this single-process container they are *simulated* by a failure-injection
hook (tests raise at a chosen step). The supervisor's contract is what
matters and is fully exercised:

  * every ``ckpt_every`` steps the full train state (params, optimizer,
    step, error-feedback state) is checkpointed asynchronously+atomically;
  * on failure, ``run()`` restores the latest checkpoint and replays from
    there — data batches are a pure function of the step (pipeline.py),
    so recovery is bit-exact;
  * the straggler monitor tracks per-step wall time with an EWMA and
    flags outliers (slow replicas); in DP deployments the runner drops /
    reassigns the slow replica's shard (simulated in tests).
"""

from __future__ import annotations

import time

from ..checkpoint.checkpointer import Checkpointer
from ..resilience.stragglers import StragglerMonitor

__all__ = ["StragglerMonitor", "TrainSupervisor"]


class TrainSupervisor:
    """Run a step function with checkpoint/restart semantics.

    step_fn(state, batch) -> (state, metrics); batch_fn(step) -> batch.
    failure_hook(step) may raise to simulate a node loss.
    """

    def __init__(self, ckpt_dir: str, ckpt_every: int = 10, max_restarts: int = 10,
                 keep: int = 3):
        self.ckpt = Checkpointer(ckpt_dir, keep=keep)
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.monitor = StragglerMonitor()
        self.restarts = 0

    def run(self, init_state, step_fn, batch_fn, num_steps: int,
            failure_hook=None, state_shardings=None, log=None):
        state = init_state
        start = 0
        latest = self.ckpt.latest_step()
        if latest is not None:
            state, meta = self.ckpt.restore(shardings=state_shardings)
            start = meta["next_step"]

        step = start
        while step < num_steps:
            try:
                t0 = time.perf_counter()
                if failure_hook is not None:
                    failure_hook(step)
                batch = batch_fn(step)
                state, metrics = step_fn(state, batch)
                # block so step timing (straggler detection) sees real work,
                # not jax's async dispatch latency
                import jax as _jax

                _jax.block_until_ready(
                    _jax.tree.leaves(state)[0] if _jax.tree.leaves(state) else None
                )
                dt = time.perf_counter() - t0
                slow = self.monitor.record(step, dt)
                if log:
                    log(step, metrics, dt, slow)
                step += 1
                if step % self.ckpt_every == 0:
                    self.ckpt.save_async(step, state, meta={"next_step": step})
            except Exception:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                # an in-flight async save may itself have died (that can be
                # the very failure we are recovering from) — drain it without
                # re-raising; restore() below falls back to the newest
                # *verified* step regardless of how the write ended
                self.ckpt.wait(reraise=False)
                latest = self.ckpt.latest_step()
                if latest is None:
                    state, step = init_state, 0
                else:
                    state, meta = self.ckpt.restore(shardings=state_shardings)
                    step = meta["next_step"]
        self.ckpt.wait()
        self.ckpt.save(num_steps, state, meta={"next_step": num_steps})
        return state
