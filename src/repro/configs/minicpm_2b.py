"""MiniCPM-2B: llama-like dense MHA, WSD schedule [arXiv:2404.06395; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
    d_ff=5760, vocab_size=122753, head_dim=64,
    rope_theta=10_000.0, tie_embeddings=True,
)
# WSD (warmup-stable-decay) is the paper's training schedule; see
# repro.train.optimizer.wsd_schedule — selected by train configs.
