"""Model + shape configuration schema.

One ``ModelConfig`` instance per assigned architecture (exact numbers in
sibling modules); ``ShapeConfig`` instances in shapes.py. ``scaled()``
produces the reduced smoke-test variants."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 32000
    head_dim: int = 0  # 0 -> d_model // n_heads
    ffn_kind: str = "swiglu"  # swiglu | gelu
    rope_theta: float = 1e4
    sliding_window: int = 0  # 0 = full attention
    global_layers: tuple = ()  # full-attention layers within an SWA model
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    # --- SSM ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    # --- encoder-decoder ---
    n_enc_layers: int = 0
    enc_seq: int = 1500  # stub frontend frames (whisper 30 s @ 50 Hz)
    # --- VLM ---
    cross_every: int = 0  # cross-attn image layer every N decoder layers
    n_img_tokens: int = 0
    d_vision: int = 0
    # --- misc ---
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    param_dtype: str = "float32"  # bf16 for the 480B/1T archs (+Adafactor)
    sp_residual: bool = False  # sequence-parallel residual stream (Megatron-SP)
    tie_embeddings: bool = False
    optimizer: str = "adamw"  # adamw | adafactor
    accum_steps: int = 1  # gradient-accumulation microbatches per step
    moe_reduce_scatter: bool = False  # §Perf B2: refuted at graph level, keep off

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def padded_vocab(self) -> int:
        from ..models.common import pad_vocab

        return pad_vocab(self.vocab_size)

    def scaled(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=min(self.n_layers, 2),
            d_model=64,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            head_dim=16,
            dtype="float32",
        )
        if self.n_heads:
            kw["n_heads"] = 4
            kw["n_kv_heads"] = max(1, min(self.n_kv_heads, 2)) if self.n_kv_heads < self.n_heads else 4
        if self.n_experts:
            kw["n_experts"] = 8
            kw["experts_per_token"] = min(self.experts_per_token, 2)
        if self.ssm_state:
            kw["ssm_state"] = 8
            kw["ssm_heads"] = 4
            kw["ssm_head_dim"] = 16
            kw["ssm_chunk"] = 8
        if self.n_enc_layers:
            kw["n_enc_layers"] = 2
            kw["enc_seq"] = 16
        if self.cross_every:
            kw["cross_every"] = 2
            kw["n_img_tokens"] = 8
            kw["d_vision"] = 32
        if self.sliding_window:
            kw["sliding_window"] = 8
            kw["global_layers"] = (0,)
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    phase: str  # 'train' | 'prefill' | 'decode'

    def scaled(self, **kw) -> "ShapeConfig":
        return dataclasses.replace(self, **kw)
