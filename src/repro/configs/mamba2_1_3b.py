"""Mamba2-1.3B: attention-free SSD stack [arXiv:2405.21060]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50280,
    sp_residual=True, ssm_state=128, ssm_heads=64, ssm_head_dim=64, ssm_chunk=64,
    tie_embeddings=True,
)
