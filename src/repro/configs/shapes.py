"""Assigned input shapes (identical set for every LM arch).

``decode_*`` / ``long_*`` lower serve_step (one new token against a KV
cache / SSM state of seq_len), not train_step. ``long_500k`` runs only
for sub-quadratic archs (ssm / hybrid) — see DESIGN.md §5.
"""

from .base import ShapeConfig

SHAPES = {
    "train_4k": ShapeConfig("train_4k", seq_len=4_096, global_batch=256, phase="train"),
    "prefill_32k": ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, phase="prefill"),
    "decode_32k": ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, phase="decode"),
    "long_500k": ShapeConfig("long_500k", seq_len=524_288, global_batch=1, phase="decode"),
}

SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def runnable(cfg, shape: ShapeConfig) -> bool:
    """Cell-skip rule: long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k":
        return cfg.family in SUBQUADRATIC_FAMILIES
    return True
