"""Llama-3.2-Vision-11B: cross-attn image layers every 5th layer
[hf:meta-llama/Llama-3.2-11B-Vision]; vision encoder stubbed
(precomputed patch embeddings, 1601 tokens @ d_vision=1280)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=128256, head_dim=128,
    cross_every=5, n_img_tokens=1601, d_vision=1280,
    rope_theta=500_000.0, sp_residual=True,
)
