"""Kimi-K2-1T-A32B: trillion-param MoE, 384 experts top-8
[arXiv:2501.kimi2 (paper-table)]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab_size=163840, head_dim=112,
    n_experts=384, experts_per_token=8,
    rope_theta=50_000.0, optimizer="adafactor", accum_steps=4, param_dtype="bfloat16", sp_residual=True,
)
