"""Whisper-medium: enc-dec, conv frontend stubbed [arXiv:2212.04356].

24 encoder + 24 decoder layers (the assignment's 24L counts the
decoder); GELU FFN, sinusoidal positions, tied embeddings."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, n_enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=51865, head_dim=64,
    ffn_kind="gelu", enc_seq=1500, tie_embeddings=True,
)
