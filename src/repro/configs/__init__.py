"""Assigned architecture configs (exact hyperparameters from the
assignment) + input shapes. ``get_config(name)`` resolves by id."""

from . import (
    arctic_480b,
    hymba_1_5b,
    kimi_k2_1t_a32b,
    llama32_vision_11b,
    mamba2_1_3b,
    minicpm_2b,
    phi3_medium_14b,
    starcoder2_3b,
    whisper_medium,
    yi_9b,
)
from .base import ModelConfig, ShapeConfig
from .shapes import SHAPES, runnable

CONFIGS = {
    m.CONFIG.name: m.CONFIG
    for m in (
        yi_9b, minicpm_2b, phi3_medium_14b, starcoder2_3b, arctic_480b,
        kimi_k2_1t_a32b, mamba2_1_3b, whisper_medium, llama32_vision_11b,
        hymba_1_5b,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in CONFIGS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(CONFIGS)}")
    return CONFIGS[name]


__all__ = ["CONFIGS", "SHAPES", "get_config", "runnable", "ModelConfig", "ShapeConfig"]
