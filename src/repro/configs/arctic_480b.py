"""Snowflake Arctic-480B: 128-expert top-2 MoE + dense residual
[hf:Snowflake/snowflake-arctic-base]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab_size=32000, head_dim=128,
    n_experts=128, experts_per_token=2, dense_residual=True,
    rope_theta=10_000.0, optimizer="adafactor", accum_steps=8, param_dtype="bfloat16", sp_residual=True,
)
