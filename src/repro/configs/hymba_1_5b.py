"""Hymba-1.5B: parallel attention + mamba heads per layer
[arXiv:2411.13676; hf]. SWA everywhere except 3 global layers."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab_size=32001, head_dim=64,
    ssm_state=16, ssm_heads=25, ssm_head_dim=64, ssm_chunk=128,
    sliding_window=1024, global_layers=(0, 15, 31),
    tie_embeddings=True,
)
