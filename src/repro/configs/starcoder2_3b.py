"""StarCoder2-3B: dense GQA kv=2, RoPE [arXiv:2402.19173; hf].

Upstream ships a 4k sliding window; the assignment brackets it [dense],
so it is treated as full attention here (long_500k skipped)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2,
    d_ff=12288, vocab_size=49152, head_dim=128,
    rope_theta=100_000.0,
)
