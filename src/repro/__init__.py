"""repro: DB-LSH (Tian, Zhao, Zhou — ICDE 2022) as a production JAX/TPU
vector-search + LM training/serving framework. See README.md."""

__version__ = "1.0.0"
