"""SLO drift watch: turn the metrics registry into breach events.

Consumes the registry the serving stack already feeds (latency
histograms, termination-step counters) and emits structured
:class:`BreachEvent` records when the served traffic leaves its
objectives:

* **latency** — rolling p50 / p99 over the latency histogram's exact
  sample window vs configured ceilings;
* **recall proxy (drift)** — the paper's C1/C2 termination makes
  per-query work observable: each query reports the schedule step its
  terminate condition fired at.  The calibrated
  :class:`~repro.tune.planner.ScheduleTable` *predicts* that
  distribution (the recall curve is, normalized, the fraction of sample
  queries already certified by step j), so the total-variation distance
  between the rolling observed termination-step distribution and the
  table's prediction is a recall drift signal that needs **no ground
  truth at serving time**.  When the workload hardens (queries terminate
  later than calibration predicted) or the index decays (compaction
  debt, distribution shift), the divergence grows before recall can be
  measured — exactly the trigger ROADMAP item 5's online re-calibration
  loop needs.

The watch is pull-based and deterministic: :meth:`SLOWatch.check` reads
the registry with an injectable clock (tests script a drift and assert
the breach), :meth:`SLOWatch.maybe_check` rate-limits it for serving
loops.  Breaches append to :attr:`SLOWatch.events` (bounded), count in
the registry (``repro_store_slo_breaches_total``), mark the trace
timeline, and invoke an optional callback.

When an :class:`~repro.obs.explain.ExemplarReservoir` is attached
(``exemplars=``), each breach additionally carries the worst-k
tail-latency exemplars in ``detail["exemplars"]`` — ticket uid, latency,
and (for sampled/explain'd queries) the *rendered*
:class:`~repro.obs.explain.QueryExplain` — so a p99 page names actual
queries and their per-step window/slot story, not just a percentile.
"""

from __future__ import annotations

import dataclasses
import math
import time
from collections import deque

from .metrics import MetricsRegistry
from .trace import Tracer, get_tracer

__all__ = ["BreachEvent", "SLOWatch", "expected_step_pmf"]


@dataclasses.dataclass(frozen=True)
class BreachEvent:
    """One SLO violation observed at ``t`` (watch-clock seconds)."""

    kind: str          # "latency_p50" | "latency_p99" | "termination_drift"
    collection: str
    t: float
    observed: float    # the measured value (ms, or TV distance)
    threshold: float   # the objective it crossed
    detail: dict       # supporting numbers (window size, distributions)
    message: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def expected_step_pmf(table, steps: int | None = None) -> dict[int, float]:
    """The schedule table's predicted termination-step distribution.

    ``recall[j-1]`` estimates the fraction of queries whose true
    neighbors are already in hand after ``j`` steps; normalized by the
    final achieved recall it is the predicted CDF of the C2 certificate
    firing.  Queries the schedule never certifies run to the end and
    record the final step, which normalization folds into the last bin.
    ``steps`` caps the support when the plan runs a shorter schedule
    than the table measured (mass beyond folds into the cap)."""
    rec = list(table.recall)
    s_max = len(rec) if steps is None else max(1, min(int(steps), len(rec)))
    total = rec[s_max - 1]
    if not math.isfinite(total) or total <= 0:
        return {j: 1.0 / s_max for j in range(1, s_max + 1)}  # no signal
    pmf = {}
    prev = 0.0
    for j in range(1, s_max + 1):
        cur = min(rec[j - 1] / total, 1.0)
        pmf[j] = max(cur - prev, 0.0)
        prev = cur
    # normalization put all residual (never-certified) mass in the tail
    pmf[s_max] += max(1.0 - prev, 0.0)
    return pmf


def _tv_distance(p: dict[int, float], q: dict[int, float]) -> float:
    keys = set(p) | set(q)
    return 0.5 * sum(abs(p.get(k, 0.0) - q.get(k, 0.0)) for k in keys)


class SLOWatch:
    """Rolling SLO evaluation over one collection's registry series.

    Objectives are opt-in: pass ``latency_p50_ms`` / ``latency_p99_ms``
    ceilings and/or a calibrated ``table`` (+ ``drift_threshold``) to
    arm the corresponding checks.  ``window_s`` bounds the rolling
    termination window; ``min_samples`` suppresses verdicts on thin
    evidence."""

    def __init__(
        self,
        registry: MetricsRegistry,
        collection: str,
        *,
        table=None,
        plan_steps: int | None = None,
        latency_p50_ms: float | None = None,
        latency_p99_ms: float | None = None,
        drift_threshold: float = 0.25,
        min_samples: int = 32,
        window_s: float = 60.0,
        check_interval_s: float = 1.0,
        max_events: int = 256,
        clock=time.monotonic,
        tracer: Tracer | None = None,
        on_breach=None,
        on_check=None,
        exemplars=None,
        exemplar_k: int = 3,
    ):
        self.registry = registry
        self.collection = collection
        self.table = table
        self.plan_steps = plan_steps
        self.latency_p50_ms = latency_p50_ms
        self.latency_p99_ms = latency_p99_ms
        self.drift_threshold = drift_threshold
        self.min_samples = min_samples
        self.window_s = window_s
        self.check_interval_s = check_interval_s
        self.clock = clock
        self.tracer = tracer if tracer is not None else get_tracer()
        self.on_breach = on_breach
        # unlike on_breach (fired per event), on_check sees every check's
        # full outcome — `([], now)` for a clean window — which is what a
        # consumer that must *heal* (resilience.BrownoutController) needs
        self.on_check = on_check
        # tail-latency exemplar reservoir (repro.obs.explain): breaches
        # attach the worst-k sampled tickets with rendered explains
        self.exemplars = exemplars
        self.exemplar_k = exemplar_k
        self.events: deque[BreachEvent] = deque(maxlen=max_events)
        self._breaches = registry.counter(
            "repro_store_slo_breaches_total", "SLO breach events by kind"
        )
        self._drift_gauge = registry.gauge(
            "repro_store_termination_drift",
            "TV distance: observed vs calibrated termination-step pmf",
        )
        self._snapshots: deque[tuple[float, dict[int, int]]] = deque()
        self._last_check: float | None = None

    # ------------------------------------------------------------ readings
    def _step_counts(self) -> dict[int, int]:
        fam = self.registry.get("repro_store_termination_steps_total")
        if fam is None:
            return {}
        out = {}
        for labels, v in fam.series():
            if labels.get("collection") == self.collection:
                out[int(labels["step"])] = int(v)
        return out

    def observed_step_pmf(self, now: float) -> tuple[dict[int, float], int]:
        """Rolling-window termination distribution: the cumulative step
        counters now minus their oldest in-window snapshot."""
        cur = self._step_counts()
        self._snapshots.append((now, dict(cur)))
        while len(self._snapshots) > 1 and \
                self._snapshots[1][0] <= now - self.window_s:
            self._snapshots.popleft()
        base = self._snapshots[0][1]
        delta = {
            s: cur.get(s, 0) - base.get(s, 0)
            for s in set(cur) | set(base)
        }
        total = sum(max(v, 0) for v in delta.values())
        if total == 0:
            return {}, 0
        return {s: max(v, 0) / total for s, v in delta.items() if v > 0}, total

    # ------------------------------------------------------------- checking
    def _emit(self, kind: str, now: float, observed: float, threshold: float,
              detail: dict, message: str) -> BreachEvent:
        if self.exemplars is not None:
            # prefer sampled exemplars whose full explain is in hand; fall
            # back to bare (uid, latency) pairs only when nothing sampled
            worst = self.exemplars.worst(
                self.exemplar_k, collection=self.collection,
                with_explain_only=True,
            ) or self.exemplars.worst(
                self.exemplar_k, collection=self.collection
            )
            detail = dict(
                detail,
                exemplars=[
                    {
                        "uid": w["uid"],
                        "latency_ms": w["latency_ms"],
                        "explain": (
                            None if w["explain"] is None
                            else w["explain"].to_dict()
                        ),
                        "rendered": (
                            None if w["explain"] is None
                            else w["explain"].render()
                        ),
                    }
                    for w in worst
                ],
            )
        ev = BreachEvent(
            kind=kind, collection=self.collection, t=now, observed=observed,
            threshold=threshold, detail=detail, message=message,
        )
        self.events.append(ev)
        self._breaches.inc(collection=self.collection, kind=kind)
        self.tracer.instant(
            f"slo.breach.{kind}", cat="slo", collection=self.collection,
            t=now, observed=observed, threshold=threshold,
        )
        if self.on_breach is not None:
            self.on_breach(ev)
        return ev

    def check(self, now: float | None = None) -> list[BreachEvent]:
        """Evaluate every armed objective once; returns the new breaches
        (also appended to :attr:`events`)."""
        now = self.clock() if now is None else now
        self._last_check = now
        out: list[BreachEvent] = []

        lat = self.registry.get("repro_store_latency_ms")
        if lat is not None and (
            self.latency_p50_ms is not None or self.latency_p99_ms is not None
        ):
            n = lat.count(collection=self.collection)
            if n >= self.min_samples:
                p50, p99 = lat.percentile(
                    [50.0, 99.0], collection=self.collection
                )
                for kind, obs, thr in (
                    ("latency_p50", float(p50), self.latency_p50_ms),
                    ("latency_p99", float(p99), self.latency_p99_ms),
                ):
                    if thr is not None and obs > thr:
                        out.append(self._emit(
                            kind, now, obs, thr, {"samples": n},
                            f"{self.collection}: {kind.split('_')[1]} "
                            f"{obs:.2f}ms > {thr:.2f}ms over last {n} queries",
                        ))

        if self.table is not None:
            obs_pmf, n = self.observed_step_pmf(now)
            if n >= self.min_samples:
                exp_pmf = expected_step_pmf(self.table, self.plan_steps)
                tv = _tv_distance(obs_pmf, exp_pmf)
                self._drift_gauge.set(tv, collection=self.collection)
                if tv > self.drift_threshold:
                    out.append(self._emit(
                        "termination_drift", now, tv, self.drift_threshold,
                        {"samples": n, "observed_pmf": obs_pmf,
                         "expected_pmf": exp_pmf},
                        f"{self.collection}: termination-step distribution "
                        f"drifted TV={tv:.3f} > {self.drift_threshold:.3f} "
                        f"from the calibrated prediction over {n} queries — "
                        "re-calibrate",
                    ))
        if self.on_check is not None:
            self.on_check(out, now)
        return out

    def maybe_check(self, now: float | None = None) -> list[BreachEvent]:
        """Rate-limited :meth:`check` for serving loops (at most one
        evaluation per ``check_interval_s``)."""
        now = self.clock() if now is None else now
        if self._last_check is not None and \
                now - self._last_check < self.check_interval_s:
            return []
        return self.check(now)
