"""Process-wide metrics registry: counters, gauges, fixed-bucket
histograms, with Prometheus-text and JSON exporters.

This is the numeric half of ``repro.obs``: every quantity the serving
stack used to keep in ad-hoc per-service structs (``svc.stats()`` /
``svc.tenant_stats()``) now lives in a :class:`MetricsRegistry` the
whole process can scrape.  The service API is unchanged — its snapshot
methods *read* the registry — but the same numbers are now exportable
(``/metrics``-style text, JSON artifacts from benchmarks) and consumable
by the SLO watch (:mod:`repro.obs.slo`) without private access.

Naming scheme (DESIGN.md §10): ``repro_store_<noun>[_total]`` with
snake_case label keys (``collection``, ``tenant``, ``engine``, ``step``).
``_total`` marks monotonic counters, matching Prometheus convention.

Histograms serve two consumers at once:

* **fixed buckets** (cumulative ``le`` counts + sum + count) — the
  exportable shape, mergeable across scrapes;
* an optional bounded **sample window** (most recent ``window``
  observations) — exact rolling percentiles for ``svc.stats()`` and the
  SLO watch, because bucket-interpolated p99s are too coarse to gate on.
  The window is a ring: long-lived processes don't grow memory.

All mutators take label kwargs; a (name, sorted-labels) pair is one
series.  Metrics are get-or-create (:meth:`MetricsRegistry.counter` et
al. return the existing family when re-declared), so independent
subsystems can share one registry without import-order coupling.
"""

from __future__ import annotations

import json
import math
from bisect import bisect_left
from collections import deque

import numpy as np

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "default_registry",
    "get_registry",
    "LATENCY_MS_BUCKETS",
]

# Default latency buckets (ms): decade-ish ladder from sub-ms dispatch
# to multi-second stalls, +inf implied.
LATENCY_MS_BUCKETS = (
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0,
    1000.0, 2500.0, 5000.0,
)


def _labelkey(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


class _Family:
    """Shared series bookkeeping for one named metric."""

    kind = "untyped"

    def __init__(self, name: str, help: str):
        self.name = name
        self.help = help
        self._series: dict[tuple, object] = {}

    def series(self):
        """Yield (labels_dict, series_state) pairs, label-sorted."""
        for key in sorted(self._series):
            yield dict(key), self._series[key]

    def labels_seen(self) -> list[dict]:
        return [dict(k) for k in sorted(self._series)]


class Counter(_Family):
    """Monotonic counter (per label set)."""

    kind = "counter"

    def inc(self, value: float = 1.0, **labels) -> None:
        assert value >= 0, f"counter {self.name} cannot decrease"
        key = _labelkey(labels)
        self._series[key] = self._series.get(key, 0.0) + value

    def value(self, **labels) -> float:
        return float(self._series.get(_labelkey(labels), 0.0))


class Gauge(_Family):
    """Set-to-current-value metric (queue depth, ring occupancy)."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._series[_labelkey(labels)] = float(value)

    def inc(self, value: float = 1.0, **labels) -> None:
        key = _labelkey(labels)
        self._series[key] = self._series.get(key, 0.0) + value

    def value(self, **labels) -> float:
        return float(self._series.get(_labelkey(labels), 0.0))


class _HistSeries:
    __slots__ = ("bucket_counts", "sum", "count", "window")

    def __init__(self, n_buckets: int, window: int):
        self.bucket_counts = [0] * (n_buckets + 1)  # +1: the +inf bucket
        self.sum = 0.0
        self.count = 0
        self.window = deque(maxlen=window) if window > 0 else None


class Histogram(_Family):
    """Fixed-bucket histogram + bounded exact-percentile window.

    ``buckets`` are upper bounds (ascending, +inf implied).  Bucket
    counts are stored per-bucket and exported cumulative (Prometheus
    ``le`` semantics).
    """

    kind = "histogram"

    def __init__(self, name: str, help: str,
                 buckets=LATENCY_MS_BUCKETS, window: int = 0):
        super().__init__(name, help)
        b = tuple(float(x) for x in buckets)
        assert b == tuple(sorted(b)) and len(set(b)) == len(b), (
            f"histogram {name}: buckets must strictly ascend: {b}"
        )
        self.buckets = b
        self.window_size = int(window)

    def _get(self, labels) -> _HistSeries:
        key = _labelkey(labels)
        s = self._series.get(key)
        if s is None:
            s = self._series[key] = _HistSeries(
                len(self.buckets), self.window_size
            )
        return s

    def observe(self, value: float, **labels) -> None:
        s = self._get(labels)
        s.bucket_counts[bisect_left(self.buckets, value)] += 1
        s.sum += value
        s.count += 1
        if s.window is not None:
            s.window.append(value)

    # ----------------------------------------------------------- queries
    def count(self, **labels) -> int:
        key = _labelkey(labels)
        s = self._series.get(key)
        return 0 if s is None else s.count

    def sum(self, **labels) -> float:
        key = _labelkey(labels)
        s = self._series.get(key)
        return 0.0 if s is None else s.sum

    def mean(self, **labels) -> float:
        key = _labelkey(labels)
        s = self._series.get(key)
        return s.sum / s.count if s is not None and s.count else 0.0

    def percentile(self, q, **labels):
        """Exact percentile(s) over the rolling sample window (0 when
        the window is empty or disabled) — the ``svc.stats()`` / SLO
        consumer.  ``q`` may be a scalar or a sequence."""
        key = _labelkey(labels)
        s = self._series.get(key)
        if s is None or s.window is None or not s.window:
            return (np.zeros(len(q)) if np.ndim(q) else 0.0)
        return np.percentile(np.asarray(s.window, np.float64), q)

    def cumulative_buckets(self, **labels) -> list[tuple[float, int]]:
        """(upper_bound, cumulative_count) per bucket, +inf last."""
        key = _labelkey(labels)
        s = self._series.get(key)
        counts = (
            [0] * (len(self.buckets) + 1) if s is None else s.bucket_counts
        )
        out, acc = [], 0
        for ub, c in zip(self.buckets + (math.inf,), counts):
            acc += c
            out.append((ub, acc))
        return out


class MetricsRegistry:
    """Get-or-create registry of metric families."""

    def __init__(self):
        self._families: dict[str, _Family] = {}

    def _declare(self, cls, name: str, help: str, **kw):
        fam = self._families.get(name)
        if fam is not None:
            if not isinstance(fam, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {fam.kind}, "
                    f"not {cls.kind}"
                )
            return fam
        fam = self._families[name] = cls(name, help, **kw)
        return fam

    def counter(self, name: str, help: str = "") -> Counter:
        return self._declare(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._declare(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets=LATENCY_MS_BUCKETS, window: int = 0) -> Histogram:
        return self._declare(Histogram, name, help, buckets=buckets,
                             window=window)

    def get(self, name: str) -> _Family | None:
        return self._families.get(name)

    def families(self):
        for name in sorted(self._families):
            yield self._families[name]

    # ------------------------------------------------------------- export
    @staticmethod
    def _escape_label_value(v) -> str:
        # text exposition format: backslash, double-quote, and newline
        # must be escaped inside label values (backslash first, or the
        # other escapes get double-escaped)
        return (
            str(v)
            .replace("\\", "\\\\")
            .replace('"', '\\"')
            .replace("\n", "\\n")
        )

    @classmethod
    def _fmt_labels(cls, labels: dict, extra: dict | None = None) -> str:
        merged = dict(labels)
        if extra:
            merged.update(extra)
        if not merged:
            return ""
        inner = ",".join(
            f'{k}="{cls._escape_label_value(v)}"'
            for k, v in sorted(merged.items(), key=lambda kv: str(kv[0]))
        )
        return "{" + inner + "}"

    @staticmethod
    def _fmt_num(v: float) -> str:
        if v == math.inf:
            return "+Inf"
        f = float(v)
        return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (0.0.4)."""
        lines = []
        for fam in self.families():
            lines.append(f"# HELP {fam.name} {fam.help}")
            lines.append(f"# TYPE {fam.name} {fam.kind}")
            if isinstance(fam, Histogram):
                for labels, s in fam.series():
                    for ub, acc in fam.cumulative_buckets(**labels):
                        lab = self._fmt_labels(labels, {"le": self._fmt_num(ub)})
                        lines.append(f"{fam.name}_bucket{lab} {acc}")
                    lab = self._fmt_labels(labels)
                    lines.append(f"{fam.name}_sum{lab} {self._fmt_num(s.sum)}")
                    lines.append(f"{fam.name}_count{lab} {s.count}")
            else:
                for labels, v in fam.series():
                    lab = self._fmt_labels(labels)
                    lines.append(f"{fam.name}{lab} {self._fmt_num(v)}")
        # an empty registry exports valid (empty) text, not a bare "\n"
        return "\n".join(lines) + "\n" if lines else ""

    def to_json(self) -> dict:
        """JSON-serializable dump: the benchmark / CI artifact shape."""
        out = {}
        for fam in self.families():
            series = []
            if isinstance(fam, Histogram):
                for labels, s in fam.series():
                    series.append({
                        "labels": labels,
                        "sum": s.sum,
                        "count": s.count,
                        "buckets": [
                            {"le": ub if math.isfinite(ub) else "+Inf",
                             "count": acc}
                            for ub, acc in fam.cumulative_buckets(**labels)
                        ],
                    })
            else:
                for labels, v in fam.series():
                    series.append({"labels": labels, "value": v})
            out[fam.name] = {
                "type": fam.kind, "help": fam.help, "series": series,
            }
        return out

    def export_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2)

    def export_prometheus(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_prometheus())


# One process-wide registry for callers that want a shared scrape
# surface; services default to a private registry (deterministic tests,
# no cross-service bleed) and can be handed this one explicitly.
default_registry = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return default_registry
