"""Per-query EXPLAIN ANALYZE for the serving stack.

DB-LSH is *query-based* by construction — every query gets its own
hypercubic buckets, schedule, and C1/C2 termination point — yet the
aggregate observability of ``repro.obs`` (histograms, step pmfs, breach
counters) cannot name a single offending query.  This module is the
database-style answer:

* :class:`QueryExplain` — the structured record a ``submit(...,
  explain=True)`` ticket carries once served: the plan-resolution chain
  (request > collection > service policy → ``ResolvedPlan``), engine
  choice, cache outcome + key, queue wait / batch seq / ring slot, the
  per-step window halfwidths and admitted-delta slot counts the device
  measured, which terminate condition fired (C1 budget, C2
  certification, schedule exhaustion — or a host-side deadline
  re-plan), the final certified radius, per-shard attribution on the
  sharded path, and resilience annotations (degraded, brownout level,
  retries, fault sites hit).  ``render()`` is the human-readable text
  block; ``to_dict()`` the JSON artifact shape.

* :class:`ExemplarReservoir` — a bounded tail-latency exemplar store:
  every served ticket's (latency, uid) lands in its latency bucket's
  small ring, and sampled tickets keep their full :class:`QueryExplain`.
  ``worst(k)`` walks buckets from the tail down, which is exactly what
  :class:`~repro.obs.slo.SLOWatch` attaches to a latency breach — a p99
  breach then *names actual queries* and their step/slot story instead
  of saying "re-calibrate" into the void.

Per-query records are the input feed ROADMAP item 5's online
self-tuning loop needs: (certified radius, termination step,
admitted-slot) triples per served query, ground-truth-free.

Overhead contract: explain'd requests run a separate compiled program
(one extra static flag on ``search_batch_fixed``) and batch separately,
so the explain=False path is bit-equal to a build without this module;
at :data:`DEFAULT_EXPLAIN_SAMPLE_RATE` the QPS cost stays within the
5% obs budget (gated by ``benchmarks/store_throughput.py --obs``).
"""

from __future__ import annotations

import dataclasses
import json
from bisect import bisect_left
from collections import OrderedDict, deque

from .metrics import LATENCY_MS_BUCKETS

__all__ = [
    "DEFAULT_EXPLAIN_SAMPLE_RATE",
    "ExemplarReservoir",
    "QueryExplain",
    "TERM_CAUSE_NAMES",
]

#: names for the device-side terminate-cause codes
#: (``repro.core.serve_search.TERM_*``), plus the host-side outcomes the
#: scheduler can impose before the device ever sees the query.
TERM_CAUSE_NAMES = {
    0: "schedule_exhausted",
    1: "c1_budget",
    2: "c2_certified",
}

#: recommended auto-explain sampling: 1 in 64 submitted requests.  Rare
#: enough that the split-off explain batches hold the ≤5% QPS overhead
#: budget (DESIGN.md §12; gated by ``store_throughput.py --obs``), while
#: a latency breach window almost surely contains sampled exemplars.
#: Auto-sampling is opt-in — arm it with
#: ``Observability(explain_sample_rate=DEFAULT_EXPLAIN_SAMPLE_RATE)``;
#: ``submit(..., explain=True)`` always works regardless.
DEFAULT_EXPLAIN_SAMPLE_RATE = 1.0 / 64.0


@dataclasses.dataclass
class QueryExplain:
    """EXPLAIN ANALYZE record for one served query.

    Device-measured fields (``step_half`` … ``final_radius``) come from
    the ``with_explain`` arrays of ``search_batch_fixed`` /
    ``search_sharded``; everything else is host-side provenance the
    scheduler stamps while the ticket moves through admission, the
    queue, the in-flight ring, and completion."""

    uid: int
    collection: str
    tenant: str = "default"
    # ---------------------------------------------------- plan resolution
    engine: str = "jnp"
    plan_r0: float = 1.0
    plan_steps: int = 0
    plan_termination: str | None = None  # repr of the Termination, if any
    plan_source: str = "default"  # "request" | "collection" | "service" |
                                  # "default" (no policy anywhere)
    plan_policy: str | None = None  # repr of the winning policy
    plan_table: bool = False        # resolved against a calibration table
    replanned: str | None = None    # "deadline" | "brownout" when the
                                    # scheduler cut the schedule after
                                    # resolution (ticket flags degraded)
    # ------------------------------------------------------- cache / queue
    cache_outcome: str = "miss"  # "bypass" (explain'd reads skip the
                                 # cache), "miss", or "uncached"
    cache_key: str | None = None
    queue_wait_ms: float = 0.0
    batch_seq: int = -1   # monotonic batch number (trace correlation)
    ring_slot: int = -1   # in-flight ring lane = TID_RING0 + ring_slot
    batch_rows: int = 0   # real queries in the batch
    batch_shape: int = 0  # padded dispatch shape
    # ------------------------------------------------- device measurements
    steps_run: int = 0
    candidates: int = 0
    term_cause: str = "schedule_exhausted"
    final_radius: float = 0.0
    step_half: list = dataclasses.field(default_factory=list)
    step_slots: list = dataclasses.field(default_factory=list)
    # per-shard attribution (sharded placement only): parallel lists,
    # one entry per shard, measured before the pmax/psum collapse
    shard_steps: list | None = None
    shard_slots: list | None = None
    shard_cause: list | None = None
    # ---------------------------------------------------------- resilience
    degraded: bool = False
    brownout_level: int = 0
    retries: int = 0
    fault_sites: list = dataclasses.field(default_factory=list)
    # ------------------------------------------------------------- outcome
    latency_ms: float = 0.0
    traced: bool = False  # uid doubles as the Perfetto async-span id

    @property
    def cum_slots(self) -> list:
        """Cumulative verified slots by step (prefix sums of
        ``step_slots``)."""
        out, acc = [], 0
        for s in self.step_slots:
            acc += int(s)
            out.append(acc)
        return out

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["cum_slots"] = self.cum_slots
        return d

    def render(self) -> str:
        """The human-readable EXPLAIN ANALYZE block (one query)."""
        lines = [
            f"EXPLAIN query uid={self.uid} collection={self.collection!r} "
            f"tenant={self.tenant!r}",
            f"  plan: r0={self.plan_r0:g} steps={self.plan_steps} "
            f"engine={self.engine} source={self.plan_source}"
            + (f" policy={self.plan_policy}" if self.plan_policy else "")
            + (" table=calibrated" if self.plan_table else "")
            + (f" termination={self.plan_termination}"
               if self.plan_termination else ""),
        ]
        if self.replanned:
            lines.append(f"  replanned: {self.replanned} (degraded)")
        lines.append(
            f"  cache: {self.cache_outcome}"
            + (f" key={self.cache_key}" if self.cache_key else "")
        )
        lines.append(
            f"  queue: wait={self.queue_wait_ms:.3f}ms "
            f"batch=#{self.batch_seq} ring_slot={self.ring_slot} "
            f"rows={self.batch_rows}/{self.batch_shape}"
        )
        cum = self.cum_slots
        for j, (half, slots) in enumerate(zip(self.step_half,
                                              self.step_slots)):
            ran = j < self.steps_run
            mark = "*" if ran else " "
            lines.append(
                f"  {mark} step {j + 1}: half_window={half:.4f} "
                f"admitted_slots=+{int(slots)} cum={cum[j]}"
                + ("" if ran else "  (not reached)")
            )
        lines.append(
            f"  terminated: {self.term_cause} at step {self.steps_run} "
            f"(certified radius {self.final_radius:.4f}, "
            f"{self.candidates} verified slots)"
        )
        if self.shard_steps is not None:
            per = ", ".join(
                f"shard{i}: steps={int(st)} slots={int(sl)} "
                f"cause={TERM_CAUSE_NAMES.get(int(ca), str(ca))}"
                for i, (st, sl, ca) in enumerate(
                    zip(self.shard_steps, self.shard_slots,
                        self.shard_cause)
                )
            )
            lines.append(f"  shards: {per}")
        flags = []
        if self.degraded:
            flags.append("degraded")
        if self.brownout_level:
            flags.append(f"brownout_level={self.brownout_level}")
        if self.retries:
            flags.append(f"retries={self.retries}")
        if self.fault_sites:
            flags.append(f"fault_sites={sorted(set(self.fault_sites))}")
        if flags:
            lines.append("  resilience: " + " ".join(flags))
        lines.append(
            f"  latency: {self.latency_ms:.3f}ms"
            + ("  (trace: async span id "
               f"{self.uid})" if self.traced else "")
        )
        return "\n".join(lines)


class ExemplarReservoir:
    """Tail-latency exemplars: sampled ticket ids per latency bucket,
    full explains for the sampled tail.

    ``record`` is O(1): the (latency, uid) pair lands in its bucket's
    bounded ring, and when the ticket carries a :class:`QueryExplain`
    the record is kept in a bounded LRU so ``worst(k)`` can attach the
    *rendered* explain to an SLO breach.  Buckets reuse the latency
    histogram's upper bounds so an exemplar is always findable from the
    bucket its observation counted in."""

    def __init__(self, buckets=LATENCY_MS_BUCKETS, per_bucket: int = 8,
                 max_explains: int = 256):
        self.buckets = tuple(float(b) for b in buckets)
        self.per_bucket = int(per_bucket)
        self.max_explains = int(max_explains)
        # one ring per bucket (+inf tail last): (latency_ms, uid,
        # collection) triples, newest kept
        self._rings: list[deque] = [
            deque(maxlen=self.per_bucket)
            for _ in range(len(self.buckets) + 1)
        ]
        self._explains: OrderedDict[int, QueryExplain] = OrderedDict()

    def record(self, latency_ms: float, uid: int, collection: str,
               explain: QueryExplain | None = None) -> None:
        self._rings[bisect_left(self.buckets, latency_ms)].append(
            (float(latency_ms), int(uid), collection)
        )
        if explain is not None:
            self._explains[int(uid)] = explain
            self._explains.move_to_end(int(uid))
            while len(self._explains) > self.max_explains:
                self._explains.popitem(last=False)

    def explain_for(self, uid: int) -> QueryExplain | None:
        return self._explains.get(int(uid))

    def worst(self, k: int = 3, collection: str | None = None,
              with_explain_only: bool = False) -> list[dict]:
        """The ``k`` worst-latency exemplars, tail bucket first.

        Returns ``{"uid", "latency_ms", "collection", "explain"}`` dicts
        (``explain`` is the :class:`QueryExplain` or ``None``).  With
        ``with_explain_only`` exemplars without a stored explain are
        skipped — the SLO watch prefers a rendered story over a bare
        uid, falling back to bare uids only when nothing was sampled."""
        out = []
        for ring in reversed(self._rings):
            for lat, uid, col in sorted(ring, reverse=True):
                if collection is not None and col != collection:
                    continue
                ex = self._explains.get(uid)
                if with_explain_only and ex is None:
                    continue
                out.append({
                    "uid": uid, "latency_ms": lat, "collection": col,
                    "explain": ex,
                })
                if len(out) >= k:
                    return out
        return out

    def explains(self) -> list[QueryExplain]:
        """Every stored explain, oldest first (bounded by
        ``max_explains``)."""
        return list(self._explains.values())

    def to_json(self) -> dict:
        """The sampled-explains artifact shape (benchmark / CI upload)."""
        return {
            "exemplars": [
                {"bucket_le": ("+Inf" if i == len(self.buckets)
                               else self.buckets[i]),
                 "uid": uid, "latency_ms": lat, "collection": col}
                for i, ring in enumerate(self._rings)
                for lat, uid, col in ring
            ],
            "explains": [e.to_dict() for e in self._explains.values()],
        }

    def export_json(self, path: str) -> int:
        """Write :meth:`to_json`; returns the number of stored
        explains."""
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2)
        return len(self._explains)
