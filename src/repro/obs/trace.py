"""Low-overhead span tracing for the serving stack.

Every interesting interval in a request's life — queue wait, batch
assembly, device dispatch, the in-flight ring's pending window, host
sync, cache publication, collection lifecycle mutations — becomes a
typed :class:`Span` on one process timeline, answerable to "where did
this query's 4 ms go?" without re-running a benchmark.

Design constraints (DESIGN.md §10):

* **Cheap when off.**  The tracer is disabled by default; every hot-path
  call site guards on ``tracer.enabled`` (one attribute read) or goes
  through :meth:`Tracer.add_span`, which returns immediately when
  disabled.  Enabling must not change results — spans only *observe*
  timestamps the scheduler already reads from its injectable clock.
* **Two-phase spans.**  The scheduler's overlapped dispatch means spans
  do not nest lexically (batch N+1 is issued while batch N is still
  pending), so the recorder accepts explicit ``(t_start, t_end)``
  intervals (:meth:`add_span`) next to the context-manager form
  (:meth:`span`) used by synchronous work like lifecycle mutations.
* **Lanes.**  Each span carries a ``tid`` (track id).  The scheduler
  puts its own host work on :data:`TID_SCHEDULER` and each in-flight
  batch on ``TID_RING0 + ring-slot``, so a Perfetto render shows the
  overlap directly: the issue span of batch N+1 sits inside the pending
  window of batch N, one lane up.
* **Bounded.**  The event buffer is a ring (``maxlen``); a long-lived
  serving process can leave tracing on without growing memory.

Exports: :meth:`Tracer.export_jsonl` (one span per line, the full
record) and :meth:`Tracer.export_perfetto` (Chrome ``trace_event``
JSON — load in ``ui.perfetto.dev`` or ``chrome://tracing``).  Request
spans (``cat == "request"``) export as *async* event pairs so hundreds
of concurrently-queued requests render as overlapping slices instead of
fighting over one track.

Device correlation: the jitted dispatch is wrapped in
``jax.profiler.TraceAnnotation`` (host side) and the search stages carry
``jax.named_scope`` labels (HLO metadata), so a ``jax.profiler`` device
trace lines up with these host spans by name.
"""

from __future__ import annotations

import json
import time
from collections import deque
from contextlib import contextmanager

__all__ = [
    "Span",
    "Tracer",
    "get_tracer",
    "TID_SCHEDULER",
    "TID_RING0",
    "TID_LIFECYCLE",
]

# Track (lane) assignment for the Perfetto timeline.  Ring lanes are
# TID_RING0 + slot so a depth-d ring renders as d parallel device lanes.
TID_SCHEDULER = 0
TID_RING0 = 1
TID_LIFECYCLE = 64

_TRACK_NAMES = {
    TID_SCHEDULER: "scheduler (host)",
    TID_LIFECYCLE: "lifecycle",
}


class Span:
    """One recorded interval (or instant, when ``dur`` is 0 and
    ``ph == 'i'``).  Plain ``__slots__`` object — spans are allocated on
    the serving path and must stay cheap."""

    __slots__ = ("name", "cat", "ts", "dur", "tid", "sid", "parent", "args", "ph")

    def __init__(self, name, cat, ts, dur, tid, sid, parent, args, ph="X"):
        self.name = name
        self.cat = cat
        self.ts = ts          # seconds, tracer clock
        self.dur = dur        # seconds
        self.tid = tid
        self.sid = sid        # unique span id
        self.parent = parent  # enclosing span id (context-manager form) or None
        self.args = args
        self.ph = ph          # "X" complete | "i" instant

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "cat": self.cat,
            "ts": self.ts,
            "dur": self.dur,
            "tid": self.tid,
            "sid": self.sid,
            "parent": self.parent,
            "ph": self.ph,
            "args": self.args,
        }


class _NopSpan:
    """Handle yielded by ``span()`` when tracing is off."""

    __slots__ = ()

    def set(self, **kw) -> None:
        pass


_NOP = _NopSpan()


class _LiveSpan:
    """Handle yielded by ``span()`` while the interval is open; ``set``
    attaches args discovered mid-span (e.g. how many rows a compaction
    actually moved)."""

    __slots__ = ("args",)

    def __init__(self, args: dict):
        self.args = args

    def set(self, **kw) -> None:
        self.args.update(kw)


class Tracer:
    """Bounded span recorder with an injectable clock.

    ``enabled`` gates everything; ``sample_rate`` (0..1) additionally
    thins *request-level* spans (call sites ask :meth:`should_sample`
    once per request) with a deterministic counter-based sampler —
    batch/lifecycle spans are low-rate and always recorded while
    enabled.
    """

    def __init__(self, *, enabled: bool = False, sample_rate: float = 1.0,
                 clock=time.monotonic, maxlen: int = 65536):
        self.enabled = enabled
        self.sample_rate = float(sample_rate)
        self.clock = clock
        self.events: deque[Span] = deque(maxlen=maxlen)
        self._sid = 0
        self._stack: list[int] = []      # open context-manager span ids
        self._sample_acc = 0.0

    # ------------------------------------------------------------- control
    def enable(self, sample_rate: float | None = None) -> "Tracer":
        self.enabled = True
        if sample_rate is not None:
            self.sample_rate = float(sample_rate)
        return self

    def disable(self) -> "Tracer":
        self.enabled = False
        return self

    def clear(self) -> None:
        self.events.clear()
        self._stack.clear()
        self._sample_acc = 0.0

    def should_sample(self) -> bool:
        """Deterministic rate limiter for per-request spans: fires on the
        calls where the accumulated rate crosses an integer (rate 1.0 →
        always, 0.5 → every other, 0 → never)."""
        if not self.enabled:
            return False
        self._sample_acc += self.sample_rate
        if self._sample_acc >= 1.0:
            self._sample_acc -= 1.0
            return True
        return False

    # ----------------------------------------------------------- recording
    def _next_sid(self) -> int:
        self._sid += 1
        return self._sid

    def add_span(self, name: str, t_start: float, t_end: float, *,
                 cat: str = "host", tid: int = TID_SCHEDULER, **args) -> None:
        """Record a completed interval measured by the caller (the
        two-phase form the overlapped scheduler needs).  Timestamps must
        come from the same clock family as ``self.clock`` so the
        timeline stays coherent."""
        if not self.enabled:
            return
        self.events.append(Span(
            name, cat, t_start, max(t_end - t_start, 0.0), tid,
            self._next_sid(), None, args,
        ))

    def instant(self, name: str, *, cat: str = "host",
                tid: int = TID_SCHEDULER, t: float | None = None,
                **args) -> None:
        """A point event (quota rejection, cache put, breach)."""
        if not self.enabled:
            return
        ts = self.clock() if t is None else t
        self.events.append(Span(
            name, cat, ts, 0.0, tid, self._next_sid(), None, args, ph="i",
        ))

    @contextmanager
    def span(self, name: str, *, cat: str = "host",
             tid: int = TID_LIFECYCLE, **args):
        """Context-managed span for synchronous work (lifecycle
        mutations, benchmark phases).  Nesting is tracked: the recorded
        span carries the enclosing span's id as ``parent``."""
        if not self.enabled:
            yield _NOP
            return
        sid = self._next_sid()
        parent = self._stack[-1] if self._stack else None
        self._stack.append(sid)
        live = _LiveSpan(dict(args))
        t0 = self.clock()
        try:
            yield live
        finally:
            t1 = self.clock()
            self._stack.pop()
            self.events.append(
                Span(name, cat, t0, t1 - t0, tid, sid, parent, live.args)
            )

    # ------------------------------------------------------------- exports
    def export_jsonl(self, path: str) -> int:
        """One span per line, full record (ts/dur in seconds); returns
        the number of spans written."""
        events = sorted(self.events, key=lambda s: s.ts)
        with open(path, "w") as f:
            for s in events:
                f.write(json.dumps(s.to_dict()) + "\n")
        return len(events)

    def to_trace_events(self) -> list[dict]:
        """Chrome ``trace_event`` records (ts/dur in microseconds).
        ``cat == "request"`` spans become async begin/end pairs keyed on
        the span id (or ``args["uid"]`` when present) so overlapping
        queued requests render side by side; instants become ``ph: "i"``;
        everything else is a complete ``ph: "X"`` slice on its lane."""
        out = []
        for tid, label in sorted(_TRACK_NAMES.items()):
            out.append({
                "ph": "M", "name": "thread_name", "pid": 0, "tid": tid,
                "args": {"name": label},
            })
        ring_tids = sorted({
            s.tid for s in self.events
            if TID_RING0 <= s.tid < TID_LIFECYCLE
        })
        for tid in ring_tids:
            out.append({
                "ph": "M", "name": "thread_name", "pid": 0, "tid": tid,
                "args": {"name": f"ring slot {tid - TID_RING0}"},
            })
        for s in sorted(self.events, key=lambda x: x.ts):
            ts_us = s.ts * 1e6
            base = {"name": s.name, "cat": s.cat, "pid": 0, "tid": s.tid,
                    "args": s.args}
            if s.ph == "i":
                out.append({**base, "ph": "i", "ts": ts_us, "s": "t"})
            elif s.cat == "request":
                ev_id = str(s.args.get("uid", s.sid))
                out.append({**base, "ph": "b", "id": ev_id, "ts": ts_us})
                out.append({**base, "ph": "e", "id": ev_id,
                            "ts": ts_us + s.dur * 1e6})
            else:
                out.append({**base, "ph": "X", "ts": ts_us,
                            "dur": s.dur * 1e6})
        return out

    def export_perfetto(self, path: str) -> int:
        """Write the Chrome/Perfetto ``trace_event`` JSON; returns the
        number of trace events (metadata included)."""
        events = self.to_trace_events()
        with open(path, "w") as f:
            json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)
        return len(events)


# The process-wide tracer: collection lifecycle spans and any service
# built without an explicit Observability bundle record here, so one
# export shows mutations and serving on a single timeline.
_global_tracer = Tracer(enabled=False)


def get_tracer() -> Tracer:
    return _global_tracer
