"""repro.obs — observability for the serving stack.

Three pieces, one bundle (DESIGN.md §10):

* ``trace``   — :class:`~repro.obs.trace.Tracer`: a bounded, typed span
  recorder.  Every submitted request leaves a trace across submit →
  quota admission → queue wait → batch assembly → device dispatch →
  in-flight ring pending window → host sync → cache put, and every
  collection lifecycle mutation (add/remove/compact/calibrate/snapshot,
  local and sharded) records a span on the same timeline.  Exports
  JSONL and Chrome/Perfetto ``trace_event`` JSON; ``jax.named_scope``
  labels on the jitted search stages plus a
  ``jax.profiler.TraceAnnotation`` around dispatch let a device profile
  correlate with the host spans by name.

* ``metrics`` — :class:`~repro.obs.metrics.MetricsRegistry`: counters,
  gauges, and fixed-bucket histograms (latency, queue depth, batch
  fill, ring occupancy, verified slots, termination steps, cache
  hits/misses, quota rejections, per-tenant traffic) with Prometheus
  text + JSON exporters.  ``StoreService.stats()`` /
  ``tenant_stats()`` keep their exact keys but are *views over the
  registry* — no more private stat structs.

* ``slo``     — :class:`~repro.obs.slo.SLOWatch`: rolling p50/p99
  latency objectives and a ground-truth-free recall drift proxy (the
  observed termination-step distribution vs the calibrated
  ``ScheduleTable`` prediction), emitting structured
  :class:`~repro.obs.slo.BreachEvent` records.

Overhead contract: tracing is **off by default** and every hot-path
site guards on one attribute read; metrics are always on (plain dict
arithmetic per request).  Enabled end-to-end, the stack stays within 5%
of obs-off QPS with bit-equal results — gated by
``benchmarks/store_throughput.py --obs``.

Typical use::

    from repro.store import Collection, StoreService
    from repro.obs import Observability, SLOWatch

    obs = Observability(trace=True)           # or trace=False: metrics only
    svc = StoreService(batch_shapes=(1, 8), default_k=10, obs=obs)
    svc.attach(col)
    ... serve ...
    print(svc.stats("docs"))                  # same keys, registry-backed
    print(obs.registry.to_prometheus())       # /metrics scrape text
    obs.tracer.export_perfetto("trace.json")  # load in ui.perfetto.dev

    watch = SLOWatch(obs.registry, "docs", table=col.calibration,
                     latency_p99_ms=5.0, drift_threshold=0.25)
    for breach in watch.check():
        print(breach.message)                 # the ROADMAP-5 drift signal
"""

from .explain import (
    DEFAULT_EXPLAIN_SAMPLE_RATE,
    ExemplarReservoir,
    QueryExplain,
    TERM_CAUSE_NAMES,
)
from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_registry,
    get_registry,
)
from .slo import BreachEvent, SLOWatch, expected_step_pmf
from .trace import Span, Tracer, get_tracer

__all__ = [
    "BreachEvent",
    "Counter",
    "DEFAULT_EXPLAIN_SAMPLE_RATE",
    "ExemplarReservoir",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Observability",
    "QueryExplain",
    "SLOWatch",
    "Span",
    "TERM_CAUSE_NAMES",
    "Tracer",
    "default_registry",
    "expected_step_pmf",
    "get_registry",
    "get_tracer",
]


class Observability:
    """The bundle a service consumes: one registry + one tracer (+ an
    optional SLO watch attached after construction).

    Defaults keep surprises out: a *fresh* registry (no cross-service
    bleed; pass ``repro.obs.default_registry`` to share a process-wide
    scrape surface) and the *process-global* tracer (lifecycle spans
    from collections land on the same timeline as the service's batch
    spans).  ``trace=True`` enables that tracer; ``sample_rate`` thins
    per-request spans (batch spans always record while enabled).
    """

    def __init__(self, *, registry: MetricsRegistry | None = None,
                 tracer: Tracer | None = None, trace: bool = False,
                 sample_rate: float | None = None,
                 exemplars: ExemplarReservoir | None = None,
                 explain_sample_rate: float = 0.0):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else get_tracer()
        if trace:
            self.tracer.enable(sample_rate)
        self.slo: SLOWatch | None = None
        # tail-latency exemplars: every served ticket's (latency, uid)
        # lands here; explain'd tickets keep their full QueryExplain, and
        # SLO breaches pull the worst-k back out (obs.explain)
        self.exemplars = (
            exemplars if exemplars is not None else ExemplarReservoir()
        )
        # auto-explain sampling: submit(explain=None) explains 1 request
        # in round(1/rate), counter-based (deterministic under test, like
        # the tracer's sampler).  Off by default (rate 0) — explicit
        # submit(explain=True) always works; pass
        # explain_sample_rate=DEFAULT_EXPLAIN_SAMPLE_RATE to arm the
        # production tail-exemplar feed
        self.explain_sample_rate = explain_sample_rate
        self._explain_stride = (
            round(1.0 / explain_sample_rate) if explain_sample_rate > 0
            else 0
        )
        self._explain_seen = 0

    def should_explain(self) -> bool:
        """Deterministic counter-based sampler for auto-explain: true
        once per ``round(1/explain_sample_rate)`` calls (first call
        fires, so short tests and thin traffic still sample)."""
        if self._explain_stride <= 0:
            return False
        hit = self._explain_seen % self._explain_stride == 0
        self._explain_seen += 1
        return hit

    def watch(self, collection: str, **kw) -> SLOWatch:
        """Arm (and return) an :class:`SLOWatch` over ``collection`` on
        this bundle's registry/tracer; stored on ``self.slo`` so a
        service can drive ``maybe_check`` from its scheduler loop.
        The bundle's exemplar reservoir rides along by default, so
        breaches carry rendered tail explains."""
        kw.setdefault("tracer", self.tracer)
        kw.setdefault("exemplars", self.exemplars)
        self.slo = SLOWatch(self.registry, collection, **kw)
        return self.slo
