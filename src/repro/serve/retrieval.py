"""kNN-LM retrieval head backed by DB-LSH — the integration that makes
the paper's index a first-class feature of the serving stack.

Datastore: (key = LM hidden state at position t, value = token t+1)
pairs collected by a teacher-forced pass over a corpus (Khandelwal et
al., ICLR 2020). At decode time the current hidden state queries the
DB-LSH index ((c,k)-ANN, fixed-schedule batched path); retrieved
neighbors vote with softmax(-dist^2 / T) mass on their value tokens and
the result is interpolated with the LM distribution:

    p(y) = (1 - lam) * p_LM(y) + lam * p_kNN(y)

Distributed: the datastore shards over the mesh data axis via
``repro.core.distributed`` (each device indexes n/P keys; global top-k
merge), so the datastore scales with the fleet, not the chip.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from ..core import DBLSHParams, build, search_batch_fixed

__all__ = ["Datastore", "build_datastore", "knn_probs", "RetrievalLM"]


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["index", "values"],
    meta_fields=["temperature", "lam", "k"],
)
@dataclasses.dataclass
class Datastore:
    index: object  # DBLSHIndex over hidden-state keys
    values: jax.Array  # (N,) int32 next-token ids
    temperature: float
    lam: float
    k: int


def build_datastore(
    model,
    params,
    batches,
    key,
    *,
    c: float = 1.5,
    t: int = 64,
    k: int = 16,
    temperature: float = 10.0,
    lam: float = 0.25,
    block_size: int = 64,
) -> Datastore:
    """Teacher-forced pass over ``batches`` collecting (hidden, next_token)."""
    keys_l, vals_l = [], []
    loss_j = jax.jit(lambda p, b: model.loss(p, b)[1]["hidden"])
    for batch in batches:
        hidden = loss_j(params, batch)  # (B,T,D)
        keys_l.append(hidden.reshape(-1, hidden.shape[-1]).astype(jnp.float32))
        vals_l.append(batch["labels"].reshape(-1).astype(jnp.int32))
    keys = jnp.concatenate(keys_l)
    vals = jnp.concatenate(vals_l)
    params_lsh = DBLSHParams.derive(
        n=keys.shape[0], d=keys.shape[1], c=c, t=t, k=k, block_size=block_size
    )
    index = build(key, keys, params_lsh)
    return Datastore(index, vals, temperature, lam, k)


@partial(jax.jit, static_argnames=("vocab", "steps"))
def knn_probs(ds: Datastore, queries: jax.Array, vocab: int, r0: float = 1.0,
              steps: int = 6):
    """(B, D) hidden states -> (B, vocab) retrieval distribution."""
    dists, ids = search_batch_fixed(ds.index, queries, k=ds.k, r0=r0, steps=steps)
    w = jax.nn.softmax(
        jnp.where(jnp.isfinite(dists), -jnp.square(dists) / ds.temperature, -jnp.inf),
        axis=-1,
    )
    w = jnp.where(jnp.isfinite(dists), w, 0.0)
    toks = jnp.take(ds.values, jnp.minimum(ids, ds.values.shape[0] - 1), axis=0)
    probs = jax.vmap(
        lambda tw, tt: jnp.zeros((vocab,)).at[tt].add(tw, mode="drop")
    )(w, toks)
    return probs


def interpolate(lm_logits, knn_p, lam):
    lm_p = jax.nn.softmax(lm_logits.astype(jnp.float32), axis=-1)
    return (1.0 - lam) * lm_p + lam * knn_p


@dataclasses.dataclass
class RetrievalLM:
    """Serving wrapper: model decode + kNN-LM interpolation."""

    model: object
    datastore: Datastore
    r0: float = 1.0
    steps: int = 6

    def decode(self, params, token, caches, pos):
        logits, hidden, caches = self.model.decode(params, token, caches, pos)
        vocab = logits.shape[-1]
        knn_p = knn_probs(
            self.datastore, hidden.astype(jnp.float32), vocab, self.r0, self.steps
        )
        probs = interpolate(logits, knn_p, self.datastore.lam)
        return jnp.log(probs + 1e-20), hidden, caches
