"""kNN-LM retrieval head backed by the vector store — the integration
that makes the paper's index a first-class feature of the serving stack.

Datastore: (key = LM hidden state at position t, value = token t+1)
pairs collected by a teacher-forced pass over a corpus (Khandelwal et
al., ICLR 2020).  The pairs live in a ``repro.store.Collection`` whose
payload is the value tokens, so the datastore inherits the store
lifecycle: ``add``/``remove`` of corpus spans, auto-compaction as the
corpus grows past the built K/L sizing, and ``snapshot``/``restore``
persistence.  :class:`Datastore` is a thin client that adds the kNN-LM
math on top.  Caveat for serving: ``ServeEngine`` jit-traces its decode
closure once, baking the index arrays in as constants — mutate the
collection *before* building the engine (or rebuild the engine after
updates); mid-flight mutations are invisible to an already-traced
decode path.

At decode time the current hidden state queries the collection
((c,k)-ANN, fixed-schedule batched path); retrieved neighbors vote with
softmax(-dist^2 / T) mass on their value tokens and the result is
interpolated with the LM distribution:

    p(y) = (1 - lam) * p_LM(y) + lam * p_kNN(y)

Fleet scale: attach a ``repro.store.router.ShardedCollection`` instead —
the same client code serves a datastore sharded over the mesh data axis
(per-device local indexes, global top-k merge).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..core import DBLSHParams
from ..obs.trace import get_tracer
from ..store import CachedResult, Collection, QueryResultCache

__all__ = ["Datastore", "build_datastore", "knn_probs", "RetrievalLM"]


@dataclasses.dataclass
class Datastore:
    """Thin kNN-LM client over a Collection (payload = next-token ids).

    ``cache`` (optional, a :class:`~repro.store.cache.QueryResultCache`,
    shareable with a StoreService) short-circuits repeated hidden-state
    queries — a greedy decode loop revisits identical states whenever
    the context re-converges, and batch-of-one eval re-runs the same
    prefixes.  Entries key on the collection's mutation version, so
    ``add``/``remove``/``compact`` on the datastore invalidate them by
    construction.  The cache only engages on concrete (non-traced)
    queries: under a jitted decode closure the lookup is skipped, which
    matches the existing caveat that traced closures bake the index in.
    """

    collection: Collection
    temperature: float
    lam: float
    k: int
    cache: QueryResultCache | None = None

    # compat surface for callers that predate the store layer
    @property
    def index(self):
        return self.collection.index

    @property
    def values(self) -> jax.Array:
        return self.collection.payload

    @classmethod
    def from_index(
        cls, index, values, *, temperature: float, lam: float, k: int,
        name: str = "knnlm", cache: QueryResultCache | None = None,
    ) -> "Datastore":
        """Wrap an already-built DBLSHIndex + value array."""
        col = Collection.from_index(name, index, payload=jnp.asarray(values))
        return cls(col, temperature, lam, k, cache=cache)

    def search(self, queries, *, r0: float = 1.0, steps: int = 6):
        """(B, D) -> (dists, ids), through the query-result cache when every
        row hits; misses dispatch the whole batch (the shape menu stays
        closed) and publish their rows for the next repeat.

        Published entries are *complete* — payload rows and real probe
        stats included — because the cache is shareable with a
        StoreService over the same collection: a service hit on a
        datastore-published entry must look exactly like one the service
        published itself."""
        queries = jnp.atleast_2d(jnp.asarray(queries, jnp.float32))
        if self.cache is None or isinstance(queries, jax.core.Tracer):
            return self.collection.search(queries, k=self.k, r0=r0, steps=steps)
        col = self.collection
        rows = np.asarray(queries)
        keys = [
            self.cache.key(col.name, col.version, q, self.k, "jnp", r0, steps)
            for q in rows
        ]
        entries = [self.cache.get(kk) for kk in keys]
        if all(e is not None for e in entries):
            tracer = get_tracer()
            if tracer.enabled:  # hot decode path: guard before the span
                tracer.instant(
                    "datastore.cache_hit", cat="cache", collection=col.name,
                    rows=len(entries),
                )
            return (
                jnp.stack([jnp.asarray(e.dists) for e in entries]),
                jnp.stack([jnp.asarray(e.ids) for e in entries]),
            )
        with get_tracer().span(
            "datastore.search", cat="serve", collection=col.name,
            rows=int(rows.shape[0]),
        ):
            dists, ids, stats = col.search(
                queries, k=self.k, r0=r0, steps=steps, with_stats=True
            )
        d_np, i_np = np.asarray(dists), np.asarray(ids)
        steps_np = np.asarray(stats["radius_steps"])
        cands_np = np.asarray(stats["candidates"])
        p_np = (
            None if col.payload is None
            else np.asarray(col.get_payload(ids))
        )
        for j, kk in enumerate(keys):
            self.cache.put(kk, CachedResult(
                dists=d_np[j].copy(),
                ids=i_np[j].copy(),
                payload=None if p_np is None else p_np[j].copy(),
                radius_steps=int(steps_np[j]),
                candidates=int(cands_np[j]),
            ))
        return dists, ids


def build_datastore(
    model,
    params,
    batches,
    key,
    *,
    c: float = 1.5,
    t: int = 64,
    k: int = 16,
    temperature: float = 10.0,
    lam: float = 0.25,
    block_size: int = 64,
) -> Datastore:
    """Teacher-forced pass over ``batches`` collecting (hidden, next_token)."""
    keys_l, vals_l = [], []
    loss_j = jax.jit(lambda p, b: model.loss(p, b)[1]["hidden"])
    for batch in batches:
        hidden = loss_j(params, batch)  # (B,T,D)
        keys_l.append(hidden.reshape(-1, hidden.shape[-1]).astype(jnp.float32))
        vals_l.append(batch["labels"].reshape(-1).astype(jnp.int32))
    keys = jnp.concatenate(keys_l)
    vals = jnp.concatenate(vals_l)
    params_lsh = DBLSHParams.derive(
        n=keys.shape[0], d=keys.shape[1], c=c, t=t, k=k, block_size=block_size
    )
    col = Collection.create(
        "knnlm", key, keys, params=params_lsh, payload=vals
    )
    return Datastore(col, temperature, lam, k)


@partial(jax.jit, static_argnames=("vocab",))
def _scatter_probs(dists, toks, vocab: int, temperature):
    """(B, k) neighbor dists + value tokens -> (B, vocab) distribution."""
    w = jax.nn.softmax(
        jnp.where(jnp.isfinite(dists), -jnp.square(dists) / temperature, -jnp.inf),
        axis=-1,
    )
    w = jnp.where(jnp.isfinite(dists), w, 0.0)
    return jax.vmap(
        lambda tw, tt: jnp.zeros((vocab,)).at[tt].add(tw, mode="drop")
    )(w, toks)


def knn_probs(ds: Datastore, queries: jax.Array, vocab: int, r0: float = 1.0,
              steps: int = 6):
    """(B, D) hidden states -> (B, vocab) retrieval distribution."""
    dists, ids = ds.search(queries, r0=r0, steps=steps)
    toks = ds.collection.get_payload(ids)
    return _scatter_probs(dists, toks, vocab, ds.temperature)


def interpolate(lm_logits, knn_p, lam):
    lm_p = jax.nn.softmax(lm_logits.astype(jnp.float32), axis=-1)
    return (1.0 - lam) * lm_p + lam * knn_p


@dataclasses.dataclass
class RetrievalLM:
    """Serving wrapper: model decode + kNN-LM interpolation."""

    model: object
    datastore: Datastore
    r0: float = 1.0
    steps: int = 6

    def decode(self, params, token, caches, pos):
        logits, hidden, caches = self.model.decode(params, token, caches, pos)
        vocab = logits.shape[-1]
        knn_p = knn_probs(
            self.datastore, hidden.astype(jnp.float32), vocab, self.r0, self.steps
        )
        probs = interpolate(logits, knn_p, self.datastore.lam)
        return jnp.log(probs + 1e-20), hidden, caches
