"""Serving substrate: continuous-batching engine + kNN-LM retrieval."""
from .engine import Request, ServeEngine
from .retrieval import Datastore, RetrievalLM, build_datastore, knn_probs
