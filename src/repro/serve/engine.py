"""Continuous-batching serving engine.

Fixed pool of B decode slots over a shared stacked KV cache; requests
are admitted by prefilling (B=1) and splicing the resulting cache into a
free slot; every engine step decodes all live slots with per-slot
positions; finished sequences (EOS / max_new_tokens) retire and free
their slot. Supports the uniform-cache families (dense / moe / ssm) —
hybrid/encdec/vlm cache splicing differs per layout and is served via
the batch path instead.

Sampling: greedy or temperature top-k, per-slot PRNG streams.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ServeEngine", "Request"]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (T,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 = greedy
    top_k: int = 40
    # filled by the engine:
    output: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model, params, *, slots: int = 4, cache_len: int = 256,
                 eos_id: int = -1, retrieval=None, seed: int = 0):
        assert model.cfg.family in ("dense", "moe", "ssm"), (
            "engine supports uniform-cache families; use the batch path "
            "for hybrid/encdec/vlm"
        )
        self.model = model
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        self.eos_id = eos_id
        self.retrieval = retrieval
        self.caches = model.init_cache(slots, cache_len)
        self.pos = np.zeros((slots,), np.int32)
        self.live: list[Request | None] = [None] * slots
        self.tokens = np.zeros((slots,), np.int32)
        self.rng = jax.random.key(seed)
        self.queue: list[Request] = []

        self._decode = jax.jit(
            lambda p, tok, caches, pos: (
                self.retrieval.decode(p, tok, caches, pos)
                if self.retrieval is not None
                else model.decode(p, tok, caches, pos)
            )
        )
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cache_len=cache_len)
        )

    # ------------------------------------------------------------------ admin
    def submit(self, req: Request):
        self.queue.append(req)

    def _free_slot(self):
        for i, r in enumerate(self.live):
            if r is None:
                return i
        return None

    def _admit(self):
        while self.queue:
            slot = self._free_slot()
            if slot is None:
                return
            req = self.queue.pop(0)
            prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
            logits, _, cache1 = self._prefill(self.params, {"tokens": prompt})
            tok = self._sample(logits, req)
            # splice the (*, 1, S, ...) cache into slot `slot` (batch axis 1)
            self.caches = jax.tree.map(
                lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                    full, one.astype(full.dtype), slot, axis=1
                ),
                self.caches,
                cache1,
            )
            self.pos[slot] = len(req.prompt)
            self.tokens[slot] = int(tok)
            req.output.append(int(tok))
            self.live[slot] = req

    def _sample(self, logits, req: Request):
        logits = jnp.asarray(logits)[0]
        if req.temperature <= 0.0:
            return jnp.argmax(logits)
        self.rng, sub = jax.random.split(self.rng)
        vals, idx = jax.lax.top_k(logits / req.temperature, req.top_k)
        choice = jax.random.categorical(sub, vals)
        return idx[choice]

    # ------------------------------------------------------------------- step
    def step(self):
        """One engine iteration: admit -> decode all live slots -> retire."""
        self._admit()
        if not any(r is not None for r in self.live):
            return False
        tok = jnp.asarray(self.tokens)
        pos = jnp.asarray(self.pos)
        logits, hidden, self.caches = self._decode(self.params, tok, self.caches, pos)
        logits = np.asarray(logits, np.float32)
        for i, req in enumerate(self.live):
            if req is None:
                continue
            self.pos[i] += 1
            if req.temperature <= 0.0:
                nxt = int(np.argmax(logits[i]))
            else:
                self.rng, sub = jax.random.split(self.rng)
                vals, idx = jax.lax.top_k(
                    jnp.asarray(logits[i]) / req.temperature, req.top_k
                )
                nxt = int(idx[jax.random.categorical(sub, vals)])
            req.output.append(nxt)
            self.tokens[i] = nxt
            if (
                nxt == self.eos_id
                or len(req.output) >= req.max_new_tokens
                or self.pos[i] >= self.cache_len - 1
            ):
                req.done = True
                self.live[i] = None
        return True

    def run(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(r is not None for r in self.live)) and steps < max_steps:
            self.step()
            steps += 1
        return steps
