"""Named vector collections: index lifecycle over the DB-LSH primitives.

A :class:`Collection` owns one :class:`~repro.core.index.DBLSHIndex` plus
an optional *payload* array aligned row-for-row with the indexed vectors
(the kNN-LM "value" generalized: token ids, document ids, metadata rows —
anything that should ride along with a returned neighbor id).

It turns the stateless library calls in ``core.updates`` into a managed
lifecycle:

* ``add`` / ``remove`` delegate to ``core.updates.insert`` / ``delete``
  and keep the payload aligned;
* an **auto-compaction policy** watches index health.  K and L are sized
  for the build-time ``n`` (K ~ log n, see DESIGN.md §3), and deletes
  only tombstone slots, so the index degrades on two axes: growth
  (n past ``growth_ratio`` x the last built n) and hollowness (live
  fraction under ``min_live_ratio``).  Crossing either threshold
  triggers ``compact`` — a rebuild with freshly derived K/L — and the
  payload is permuted through the returned id map;
* ``snapshot`` / ``restore`` persist the whole state (index arrays,
  payload, PRNG key, policy, counters, version) through
  ``checkpoint.Checkpointer``'s atomic step directories.

Every mutation (``add`` / ``remove`` / ``compact``) advances a
**version** drawn from a process-wide monotonic clock.  The version is
the cache-invalidation token for the store layer (DESIGN.md §6): a
query result cached under ``(name, version, ...)`` can only ever be
served while the collection is bit-identical to the state that produced
it.  ``restore`` deliberately assigns a *fresh* version past both the
persisted one and everything the process has handed out — two
collections diverging from one snapshot (or a restore racing live
updates) must never alias each other's cache entries.

Repeated small ``add`` calls append padded STR blocks per call; the waste
is bounded by ``block_size - 1`` slots per add per table and is reclaimed
at the next compaction.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import Checkpointer
from ..core import DBLSHParams, build, search_batch_fixed, validate_engine
from ..core.index import DBLSHIndex, compute_norm_blocks
from ..core import updates as _updates
from ..tune import planner as _planner
from ..tune.planner import ScheduleTable
from ..tune.policy import (
    ResolvedPlan,
    policy_from_dict,
    policy_to_dict,
    resolve_policy,
)

__all__ = ["CompactionPolicy", "CollectionStats", "Collection", "version_clock"]


class _VersionClock:
    """Process-wide monotonic source of collection versions.

    A plain per-collection counter would alias: two collections restored
    from the same snapshot both sit at version v yet may diverge, and a
    cache keyed on (name, v) would serve one the other's results.  A
    single process-wide clock makes every (mutation, restore) event
    globally unique, so version equality implies state equality.
    """

    def __init__(self):
        self._v = 0

    def next(self) -> int:
        self._v += 1
        return self._v

    def advance_past(self, v: int) -> int:
        """A fresh version strictly greater than both ``v`` and anything
        already handed out (used by restore)."""
        self._v = max(self._v, int(v))
        return self.next()


version_clock = _VersionClock()

_INDEX_ARRAY_FIELDS = (
    "proj_vecs",
    "proj_blocks",
    "ids_blocks",
    "mbr_lo",
    "mbr_hi",
    "data",
    "vec_blocks",
    "norm_blocks",
)


@dataclasses.dataclass(frozen=True)
class CompactionPolicy:
    """When to rebuild. ``auto=False`` disables the triggers (manual
    ``compact()`` still works)."""

    growth_ratio: float = 2.0    # compact when n >= ratio * last-built n
    min_live_ratio: float = 0.5  # compact when live/n drops below this
    auto: bool = True


@dataclasses.dataclass
class CollectionStats:
    inserted: int = 0
    deleted: int = 0
    compactions: int = 0
    queries: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class Collection:
    """A named DB-LSH index + payload with a managed lifecycle."""

    def __init__(
        self,
        name: str,
        index: DBLSHIndex,
        *,
        payload: jax.Array | np.ndarray | None = None,
        policy: CompactionPolicy | None = None,
        key: jax.Array | None = None,
        built_n: int | None = None,
        stats: CollectionStats | None = None,
        version: int | None = None,
        engine: str | None = None,
        search_policy=None,
        calibration: ScheduleTable | None = None,
    ):
        if payload is not None:
            payload = jnp.asarray(payload)
            assert payload.shape[0] == index.n, (payload.shape, index.n)
        self.name = name
        self.index = index
        self.payload = payload
        self.policy = policy or CompactionPolicy()
        self._key = jax.random.key(0) if key is None else key
        self.built_n = index.n if built_n is None else built_n
        self.stats = stats or CollectionStats()
        self.version = version_clock.next() if version is None else version
        # per-collection verify-engine default: used whenever a search /
        # service dispatch doesn't name one explicitly (None = defer to
        # the caller's default)
        if engine is not None:
            validate_engine(engine)
            if engine == "inline" and not index.params.inline_vectors:
                raise ValueError(
                    f"collection {name!r}: engine='inline' needs an index "
                    "built with inline_vectors=True (the scalar-prefetch "
                    "kernel streams the per-table vector copy)"
                )
        self.default_engine = engine
        # per-collection query-planning default (repro.tune policy): used
        # by StoreService's plan resolution whenever a submit doesn't
        # name a policy (request > collection > service); the calibration
        # table backs RecallTarget/LatencyBudget planning and persists
        # through snapshot/restore.
        self.search_policy = search_policy
        self.calibration = calibration

    # ------------------------------------------------------------ construction
    @classmethod
    def create(
        cls,
        name: str,
        key: jax.Array,
        data,
        *,
        params: DBLSHParams | None = None,
        payload=None,
        policy: CompactionPolicy | None = None,
        engine: str | None = None,
        search_policy=None,
        **derive_kw,
    ) -> "Collection":
        """Build a fresh index over ``data`` (params derived if omitted).
        ``engine`` sets the collection's default verify engine;
        ``search_policy`` its default query-planning policy (a
        ``repro.tune`` ``RecallTarget`` / ``LatencyBudget`` /
        ``FixedSchedule`` — run :meth:`calibrate` to back the
        outcome-level policies with a measured table)."""
        data = jnp.asarray(data, jnp.float32)
        kb, kc = jax.random.split(key)
        if params is None:
            params = DBLSHParams.derive(
                n=data.shape[0], d=data.shape[1], **derive_kw
            )
        index = build(kb, data, params)
        return cls(name, index, payload=payload, policy=policy, key=kc,
                   engine=engine, search_policy=search_policy)

    @classmethod
    def from_index(
        cls, name: str, index: DBLSHIndex, *, payload=None,
        policy: CompactionPolicy | None = None, key=None,
        engine: str | None = None,
    ) -> "Collection":
        """Wrap an already-built index (e.g. a kNN-LM datastore)."""
        return cls(name, index, payload=payload, policy=policy, key=key,
                   engine=engine)

    # -------------------------------------------------------------- properties
    @property
    def n(self) -> int:
        """Indexed rows including tombstones and pre-compaction growth."""
        return self.index.n

    @property
    def d(self) -> int:
        return self.index.data.shape[1]

    def live_count(self) -> int:
        return _updates.live_count(self.index)

    # ----------------------------------------------------------------- writes
    def add(self, points, payload=None) -> np.ndarray:
        """Insert ``points`` (m, d); returns their ids (post-compaction ids
        if the policy fired)."""
        points = jnp.atleast_2d(jnp.asarray(points, jnp.float32))
        m = points.shape[0]
        if (payload is None) != (self.payload is None):
            raise ValueError(
                f"collection {self.name!r}: payload must be provided iff the "
                "collection carries one"
            )
        ids = np.arange(self.n, self.n + m, dtype=np.int64)
        self.index = _updates.insert(self.index, points)
        if payload is not None:
            self.payload = jnp.concatenate(
                [self.payload, jnp.asarray(payload)], axis=0
            )
        self.stats.inserted += m
        self.version = version_clock.next()
        id_map = self._maybe_compact()
        if id_map is not None:
            ids = id_map[ids]
        return ids

    def remove(self, ids) -> np.ndarray | None:
        """Tombstone ``ids``; space is reclaimed at the next compaction.

        Returns the compaction id map (old id -> new id, -1 if deleted)
        when the policy fired — every outstanding id must be remapped
        through it — or None when no compaction happened."""
        ids = jnp.atleast_1d(jnp.asarray(ids, jnp.int32))
        self.index = _updates.delete(self.index, ids)
        self.stats.deleted += int(ids.shape[0])
        self.version = version_clock.next()
        return self._maybe_compact()

    # ------------------------------------------------------------- compaction
    def should_compact(self) -> bool:
        n = self.index.n
        if n >= self.policy.growth_ratio * self.built_n and n > self.built_n:
            return True
        return self.live_count() < self.policy.min_live_ratio * n

    def compact(self) -> np.ndarray:
        """Rebuild now. Returns id_map (n_old,): old id -> new id or -1."""
        self._key, kc = jax.random.split(self._key)
        self.index, id_map = _updates.compact(self.index, kc)
        id_map = np.asarray(id_map)
        if self.payload is not None:
            live_old = np.flatnonzero(id_map >= 0)
            # compact assigns new ids in ascending old-id order, so this
            # gather lands each payload row at its new id.
            self.payload = jnp.asarray(self.payload)[live_old]
        self.built_n = self.index.n
        self.stats.compactions += 1
        self.version = version_clock.next()
        return id_map

    def _maybe_compact(self) -> np.ndarray | None:
        if self.policy.auto and self.should_compact():
            return self.compact()
        return None

    # ----------------------------------------------------------- planning
    def calibrate(
        self,
        queries,
        *,
        k: int = 0,
        r0: float | None = None,
        steps_max: int = 8,
        engine: str | None = None,
        interpret: bool | None = None,
        measure_ms: bool = False,
    ) -> ScheduleTable:
        """Fit (and store) the collection's schedule table from a
        held-out query sample — the planner backing for outcome-level
        policies.  The table persists through :meth:`snapshot` /
        :meth:`restore`.  Re-run after heavy updates: compaction changes
        K/L and block geometry, which shifts the recall/cost curves."""
        table = _planner.calibrate(
            self.index, queries, k=k, r0=r0, steps_max=steps_max,
            engine=engine or self.default_engine or "jnp",
            interpret=interpret, measure_ms=measure_ms,
        )
        self.calibration = table
        return table

    def plan(self, policy=None, *, default_r0: float = 1.0,
             default_steps: int = 8) -> ResolvedPlan:
        """Resolve a query-planning policy (explicit > collection
        default) against the stored calibration into the concrete
        (r0, steps, termination) the dispatch runs."""
        return _planner.plan(
            self.calibration,
            resolve_policy(policy, self.search_policy),
            default_r0=default_r0, default_steps=default_steps,
        )

    # ------------------------------------------------------------------ reads
    def search(
        self,
        Q,
        k: int = 0,
        *,
        r0: float = 1.0,
        steps: int = 8,
        engine: str | None = None,
        with_stats: bool = False,
        interpret: bool | None = None,
        rows: int | None = None,
        exact: bool = False,
        termination=None,
    ):
        """Batched (c,k)-ANN through the fixed-schedule serving path.

        ``engine=None`` resolves to the collection's ``default_engine``
        (falling back to 'jnp'). ``rows`` is the number of *real* query
        rows when ``Q`` carries padding (the StoreService pads to its
        fixed batch-shape menu); the query counter advances by ``rows``,
        not the padded shape.  The returned arrays are device futures —
        nothing here blocks, so a caller may overlap host work with the
        search (DESIGN.md §6).
        """
        Q = jnp.atleast_2d(jnp.asarray(Q, jnp.float32))
        self.stats.queries += int(Q.shape[0]) if rows is None else int(rows)
        return search_batch_fixed(
            self.index, Q, k=k, r0=r0, steps=steps,
            engine=engine or self.default_engine or "jnp",
            with_stats=with_stats, interpret=interpret, exact=exact,
            termination=termination,
        )

    def get_payload(self, ids):
        """Payload rows for returned neighbor ids. Invalid slots (id == n,
        the not-found sentinel) clamp to the *last* payload row — always
        mask on the distances (+inf marks unfilled slots), not on ids."""
        if self.payload is None:
            raise ValueError(f"collection {self.name!r} has no payload")
        ids = jnp.asarray(ids)
        return jnp.take(
            self.payload, jnp.minimum(ids, self.payload.shape[0] - 1), axis=0
        )

    # ------------------------------------------------------------ persistence
    def snapshot(self, directory: str, step: int | None = None) -> int:
        """Atomic checkpoint via Checkpointer; returns the step written.
        Defaults to one past the latest step already in ``directory`` so
        successive snapshots never overwrite each other (Checkpointer
        keeps the most recent few and GCs the rest)."""
        ck = Checkpointer(directory)
        if step is None:
            latest = ck.latest_step()
            step = 0 if latest is None else latest + 1
        tree = {f: np.asarray(getattr(self.index, f)) for f in _INDEX_ARRAY_FIELDS}
        tree["prng_key"] = np.asarray(jax.random.key_data(self._key))
        if self.payload is not None:
            tree["payload"] = np.asarray(self.payload)
        meta = {
            "name": self.name,
            "params": dataclasses.asdict(self.index.params),
            "policy": dataclasses.asdict(self.policy),
            "built_n": self.built_n,
            "stats": self.stats.as_dict(),
            "has_payload": self.payload is not None,
            "version": self.version,
            "engine": self.default_engine,
            "search_policy": policy_to_dict(self.search_policy),
            "calibration": (
                None if self.calibration is None else self.calibration.to_dict()
            ),
        }
        ck.save(step, tree, meta)
        return step

    @classmethod
    def restore(cls, directory: str, step: int | None = None) -> "Collection":
        tree, meta = Checkpointer(directory).restore(step)
        params = DBLSHParams(**meta["params"])
        arrays = {
            f: jnp.asarray(tree[f]) for f in _INDEX_ARRAY_FIELDS if f in tree
        }
        if "norm_blocks" not in arrays:
            # snapshots from before the MXU-verify norm cache: rebuild it
            # from the persisted data/ids (cheap, one reduction per point)
            arrays["norm_blocks"] = compute_norm_blocks(
                arrays["data"], arrays["ids_blocks"]
            )
        index = DBLSHIndex(**arrays, params=params)
        payload = jnp.asarray(tree["payload"]) if meta["has_payload"] else None
        col = cls(
            meta["name"],
            index,
            payload=payload,
            policy=CompactionPolicy(**meta["policy"]),
            key=jax.random.wrap_key_data(jnp.asarray(tree["prng_key"])),
            built_n=meta["built_n"],
            stats=CollectionStats(**meta["stats"]),
            # fresh version past the persisted one: a restored collection
            # must never alias cache entries of any live (possibly
            # diverged) collection with the same name — see module doc.
            version=version_clock.advance_past(meta.get("version", 0)),
            engine=meta.get("engine"),
            search_policy=policy_from_dict(meta.get("search_policy")),
            calibration=(
                ScheduleTable.from_dict(meta["calibration"])
                if meta.get("calibration") else None
            ),
        )
        return col
