"""Named vector collections: the local placement of the store lifecycle.

A :class:`Collection` owns one :class:`~repro.core.index.DBLSHIndex` plus
an optional *payload* array aligned row-for-row with the indexed vectors
(the kNN-LM "value" generalized: token ids, document ids, metadata rows —
anything that should ride along with a returned neighbor id).

The managed lifecycle itself — version bumping, the auto-compaction
policy, payload ride-along, calibration invalidation, snapshot/restore
plumbing — lives in :class:`~repro.store.lifecycle.CollectionLifecycle`,
shared with the sharded placement (``store.router.ShardedCollection``).
This class supplies the single-device mechanics over ``core.updates``:

* ``add`` / ``remove`` delegate to ``core.updates.insert`` / ``delete``;
* ``compact`` rebuilds through ``core.updates.compact`` with freshly
  derived K/L (K ~ log n was sized for the build-time ``n``, see
  DESIGN.md §3-§4);
* ``snapshot`` / ``restore`` persist the index arrays through
  ``checkpoint.Checkpointer``'s atomic step directories.

Every mutation advances a **version** drawn from a process-wide
monotonic clock — the cache-invalidation token for the store layer
(DESIGN.md §6); ``restore`` deliberately assigns a *fresh* version so
diverged histories can never alias each other's cache entries.

Repeated small ``add`` calls append padded STR blocks per call; the waste
is bounded by ``block_size - 1`` slots per add per table and is reclaimed
at the next compaction.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import Checkpointer
from ..core import DBLSHParams, build, search_batch_fixed, validate_engine
from ..core.index import (
    DBLSHIndex,
    compute_norm_blocks,
    empty_quant_blocks,
    quantize_blocks,
)
from ..core import updates as _updates
from ..tune import planner as _planner
from .lifecycle import (
    _INDEX_ARRAY_FIELDS,
    CollectionLifecycle,
    CollectionStats,
    CompactionPolicy,
    version_clock,
)

__all__ = ["CompactionPolicy", "CollectionStats", "Collection", "version_clock"]


class Collection(CollectionLifecycle):
    """A named DB-LSH index + payload with a managed lifecycle."""

    placement = "local"

    def __init__(self, name: str, index: DBLSHIndex, **kw):
        self.index = index
        super().__init__(name, **kw)

    def _validate_default_engine(self, engine: str | None) -> str | None:
        if engine is not None:
            validate_engine(engine)
            if engine == "inline" and not self.index.params.inline_vectors:
                raise ValueError(
                    f"collection {self.name!r}: engine='inline' needs an index "
                    "built with inline_vectors=True (the scalar-prefetch "
                    "kernel streams the per-table vector copy)"
                )
        return engine

    # ------------------------------------------------------------ construction
    @classmethod
    def create(
        cls,
        name: str,
        key: jax.Array,
        data,
        *,
        params: DBLSHParams | None = None,
        payload=None,
        policy: CompactionPolicy | None = None,
        engine: str | None = None,
        search_policy=None,
        **derive_kw,
    ) -> "Collection":
        """Build a fresh index over ``data`` (params derived if omitted).
        ``engine`` sets the collection's default verify engine;
        ``search_policy`` its default query-planning policy (a
        ``repro.tune`` ``RecallTarget`` / ``LatencyBudget`` /
        ``FixedSchedule`` — run :meth:`calibrate` to back the
        outcome-level policies with a measured table)."""
        data = jnp.asarray(data, jnp.float32)
        kb, kc = jax.random.split(key)
        if params is None:
            params = DBLSHParams.derive(
                n=data.shape[0], d=data.shape[1], **derive_kw
            )
        index = build(kb, data, params)
        return cls(name, index, payload=payload, policy=policy, key=kc,
                   engine=engine, search_policy=search_policy)

    @classmethod
    def from_index(
        cls, name: str, index: DBLSHIndex, *, payload=None,
        policy: CompactionPolicy | None = None, key=None,
        engine: str | None = None,
    ) -> "Collection":
        """Wrap an already-built index (e.g. a kNN-LM datastore)."""
        return cls(name, index, payload=payload, policy=policy, key=key,
                   engine=engine)

    # -------------------------------------------------------------- properties
    @property
    def n(self) -> int:
        """Indexed rows including tombstones and pre-compaction growth."""
        return self.index.n

    @property
    def d(self) -> int:
        return self.index.data.shape[1]

    def live_count(self) -> int:
        return _updates.live_count(self.index)

    # -------------------------------------------------------- placement hooks
    def _insert(self, points, payload) -> np.ndarray:
        m = points.shape[0]
        # int32 end to end: search results, id maps, and delete all speak
        # int32, so returned ids round-trip without re-casting
        ids = np.arange(self.n, self.n + m, dtype=np.int32)
        self.index = _updates.insert(self.index, points)
        if payload is not None:
            self.payload = jnp.concatenate([self.payload, payload], axis=0)
        return ids

    def _delete(self, ids) -> None:
        self.index = _updates.delete(self.index, ids)

    def _compact_impl(self, key) -> np.ndarray:
        self.index, id_map = _updates.compact(self.index, key)
        return id_map

    def _calibrate_impl(self, queries, *, k, r0, steps_max, engine,
                        interpret, measure_ms):
        # ground the oracle on live rows only: tombstoned rows cannot be
        # returned, so leaving them in would under-measure recall
        ids0 = np.asarray(self.index.ids_blocks[0])
        live = np.unique(ids0[ids0 < self.index.n])
        return _planner.calibrate(
            self.index, queries, k=k, r0=r0, steps_max=steps_max,
            engine=engine or self.default_engine or "jnp",
            interpret=interpret, measure_ms=measure_ms,
            oracle_rows=None if live.size == self.index.n else live,
        )

    # ------------------------------------------------------------------ reads
    def search(
        self,
        Q,
        k: int = 0,
        *,
        r0: float = 1.0,
        steps: int = 8,
        engine: str | None = None,
        with_stats: bool = False,
        interpret: bool | None = None,
        rows: int | None = None,
        exact: bool = False,
        termination=None,
        with_explain: bool = False,
        dtype: str = "fp32",
    ):
        """Batched (c,k)-ANN through the fixed-schedule serving path.

        ``engine=None`` resolves to the collection's ``default_engine``
        (falling back to 'jnp'). ``rows`` is the number of *real* query
        rows when ``Q`` carries padding (the StoreService pads to its
        fixed batch-shape menu); the query counter advances by ``rows``,
        not the padded shape.  The returned arrays are device futures —
        nothing here blocks, so a caller may overlap host work with the
        search (DESIGN.md §6).  ``with_explain`` (implies
        ``with_stats``) appends the per-query per-step EXPLAIN arrays —
        see :func:`~repro.core.serve_search.search_batch_fixed`.
        ``dtype`` ('fp32'/'bf16'/'int8') selects the distance precision;
        the quantized paths need an index built with the matching
        ``quant_dtype`` and are a shortlist + exact fp32 re-rank, so the
        returned distances are always exact fp32.
        """
        Q = jnp.atleast_2d(jnp.asarray(Q, jnp.float32))
        self._count_queries(Q, rows)
        return search_batch_fixed(
            self.index, Q, k=k, r0=r0, steps=steps,
            engine=engine or self.default_engine or "jnp",
            with_stats=with_stats, interpret=interpret, exact=exact,
            termination=termination, with_explain=with_explain,
            dtype=dtype,
        )

    # ------------------------------------------------------------ persistence
    def _snapshot_arrays(self) -> dict:
        return {
            f: np.asarray(getattr(self.index, f)) for f in _INDEX_ARRAY_FIELDS
        }

    def _snapshot_meta(self) -> dict:
        return {"params": dataclasses.asdict(self.index.params)}

    @classmethod
    def restore(cls, directory: str, step: int | None = None) -> "Collection":
        tree, meta = Checkpointer(directory).restore(step)
        if meta.get("placement", "local") != "local":
            raise ValueError(
                f"snapshot at {directory!r} is {meta['placement']!r}: "
                "restore it with ShardedCollection.restore(mesh=...) or "
                "repro.store.restore_collection(..., mesh=...)"
            )
        params = DBLSHParams(**meta["params"])
        arrays = {
            f: jnp.asarray(tree[f]) for f in _INDEX_ARRAY_FIELDS if f in tree
        }
        if "norm_blocks" not in arrays:
            # snapshots from before the MXU-verify norm cache: rebuild it
            # from the persisted data/ids (cheap, one reduction per point)
            arrays["norm_blocks"] = compute_norm_blocks(
                arrays["data"], arrays["ids_blocks"]
            )
        # quantized blocks are derived state, never persisted (bf16 does
        # not np.save round-trip): re-quantize from the fp32 truth
        if params.quant_dtype != "none":
            arrays["qvec_blocks"], arrays["qvec_scale"] = quantize_blocks(
                arrays["data"], arrays["ids_blocks"], params.quant_dtype
            )
        else:
            arrays["qvec_blocks"], arrays["qvec_scale"] = (
                empty_quant_blocks(params.quant_dtype)
            )
        index = DBLSHIndex(**arrays, params=params)
        return cls(meta["name"], index,
                   **cls._common_restore_kwargs(tree, meta))
