"""Shard-aware routing: the Collection API over ``core.distributed``.

A dataset too large for one device shards over the mesh 'data' axis:
every device builds a local DB-LSH index with the *same* LSH functions
(``core.distributed.build_sharded``), queries replicate, and per-shard
top-k merge with one all_gather into globally-id'd results.
:class:`ShardedCollection` hides all of that behind the same ``search``
/ ``get_payload`` / ``name`` surface as a local
:class:`~repro.store.collection.Collection`, so a
:class:`~repro.store.service.StoreService` can serve both through one
admission queue.

:func:`open_collection` is the router decision point: it places data on
a single device when it fits (``max_points_per_shard``), otherwise
fans out over the mesh.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core import DBLSHParams
from ..core.distributed import ShardedDBLSH, build_sharded, search_sharded
from .collection import Collection, CompactionPolicy, version_clock

__all__ = ["ShardedCollection", "open_collection"]


class ShardedCollection:
    """A collection fanned out over the mesh ``axis``; read path only.

    Updates go through per-shard rebuilds (``create`` again) — online
    insert/delete into a sharded index is a later-PR concern; the
    service only needs the query surface here.  The payload stays global
    (replicated): it is indexed by *global* ids after the top-k merge,
    which is exactly what ``search_sharded`` returns.
    """

    def __init__(self, name: str, sharded: ShardedDBLSH, mesh, *, payload=None):
        self.name = name
        self.sharded = sharded
        self.mesh = mesh
        self.payload = None if payload is None else jnp.asarray(payload)
        if self.payload is not None:
            assert self.payload.shape[0] == sharded.n_total
        # read-only collection: the version is fixed at creation but still
        # drawn from the shared clock so service-level caches key on it
        # exactly like a local Collection's.
        self.version = version_clock.next()
        # the sharded path always verifies through the jnp engine;
        # ``fixed_engine`` tells the StoreService's engine resolution to
        # ignore request/collection/service preferences entirely, so
        # tickets and cache keys reflect the engine that actually ran
        # (and a drained batch is never split over engines pointlessly)
        self.fixed_engine = "jnp"
        self.default_engine = None
        # query-planning surface parity with Collection: a sharded
        # collection may carry a policy (the service resolves it the same
        # way) but is read-only, so calibration must be supplied by the
        # caller (there are no updates to invalidate it).
        self.search_policy = None
        self.calibration = None

    @classmethod
    def create(
        cls,
        name: str,
        key: jax.Array,
        data,
        mesh,
        *,
        axis: str = "data",
        params: DBLSHParams | None = None,
        payload=None,
        **derive_kw,
    ) -> "ShardedCollection":
        data = jnp.asarray(data, jnp.float32)
        n, d = data.shape
        pn = mesh.shape[axis]
        if params is None:
            # size K/L for the per-shard n: each device answers locally.
            params = DBLSHParams.derive(n=n // pn, d=d, **derive_kw)
        sharded = build_sharded(key, data, params, mesh, axis=axis)
        return cls(name, sharded, mesh, payload=payload)

    # ---------------------------------------------------------------- surface
    @property
    def n(self) -> int:
        return self.sharded.n_total

    @property
    def d(self) -> int:
        return self.sharded.index.data.shape[1]

    def search(
        self,
        Q,
        k: int = 0,
        *,
        r0: float = 1.0,
        steps: int = 8,
        engine: str | None = None,
        with_stats: bool = False,
        interpret: bool | None = None,
        rows: int | None = None,
        exact: bool = False,
        termination=None,
    ):
        """Global (c,k)-ANN: per-shard fixed-schedule search + all_gather
        top-k merge. ``engine`` / ``interpret`` / ``exact`` are accepted
        for API parity; the sharded path always verifies through the jnp
        engine.  ``rows`` (real rows in a service-padded batch) is
        accepted for parity too — the sharded collection keeps no query
        counter.  With ``with_stats`` the per-shard probe statistics
        survive the collective merge (``search_sharded`` aggregates
        candidates by psum and radius_steps by pmax), so ``svc.stats()``
        reports real per-query probe effort for sharded collections.
        ``termination`` applies per shard (each device runs its own
        C1/C2 masks and while_loop exit — see ``search_sharded``)."""
        del engine, interpret, rows
        Q = jnp.atleast_2d(jnp.asarray(Q, jnp.float32))
        k = k or self.sharded.index.params.k
        return search_sharded(
            self.sharded, Q, k=k, r0=r0, steps=steps, mesh=self.mesh,
            with_stats=with_stats, exact=exact, termination=termination,
        )

    def get_payload(self, ids):
        """Global-id payload lookup; sentinel ids clamp to the last row —
        mask on distances, as with Collection.get_payload."""
        if self.payload is None:
            raise ValueError(f"collection {self.name!r} has no payload")
        ids = jnp.asarray(ids)
        return jnp.take(
            self.payload, jnp.minimum(ids, self.payload.shape[0] - 1), axis=0
        )


def open_collection(
    name: str,
    key: jax.Array,
    data,
    *,
    mesh=None,
    axis: str = "data",
    max_points_per_shard: int = 1_000_000,
    payload=None,
    policy: CompactionPolicy | None = None,
    **derive_kw,
):
    """Route a dataset to local or sharded placement.

    Local :class:`Collection` when ``data`` fits one device (or no mesh
    given); :class:`ShardedCollection` fan-out otherwise.  ``policy``
    only applies to the local path: the sharded collection is read-only
    (no updates, hence nothing to compact), so a supplied policy is
    ignored there.
    """
    n = np.asarray(data).shape[0]
    if mesh is not None and mesh.shape[axis] > 1 and n > max_points_per_shard:
        return ShardedCollection.create(
            name, key, data, mesh, axis=axis, payload=payload, **derive_kw
        )
    return Collection.create(
        name, key, data, payload=payload, policy=policy, **derive_kw
    )
