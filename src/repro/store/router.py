"""Shard-aware routing: the full Collection lifecycle over
``core.distributed``.

A dataset too large for one device shards over the mesh 'data' axis:
every device builds a local DB-LSH index with the *same* LSH functions
(``core.distributed.build_sharded``), queries replicate, and per-shard
top-k merge with one all_gather into globally-id'd results.
:class:`ShardedCollection` implements the same mutable lifecycle
protocol as a local :class:`~repro.store.collection.Collection`
(``store.lifecycle.CollectionLifecycle``): ``add`` routes inserts to the
least-loaded shard, ``remove`` translates global ids per shard,
``compact`` rebuilds every shard from its survivors with a gathered
global id remap, and ``snapshot`` / ``restore(mesh=...)`` persist the
whole state — so a :class:`~repro.store.service.StoreService` serves
both placements through one admission queue, one cache-invalidation
contract, and one policy/engine resolution path, with no read-only
special cases.

:func:`open_collection` is the router decision point: it places data on
a single device when it fits (``max_points_per_shard``), otherwise fans
out over the mesh — the lifecycle options (``policy``, ``engine``,
``search_policy``) apply to whichever placement wins.

**Id contract** (DESIGN.md §9): global ids are placement-relative,
``gid = rank * n_local + local``.  That keeps the merge's disjoint-id
invariant, but an ``add`` grows ``n_local`` and therefore *re-bases*
every existing global id (``g -> (g // n_old) * n_new + g % n_old``);
``compact`` renumbers like the local placement and returns the id map.
Callers that hold ids across sharded mutations should re-derive them
from search results or carry identity in the payload.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from ..checkpoint import Checkpointer
from ..core import DBLSHParams
from ..core.distributed import (
    ShardedDBLSH,
    _index_specs,
    build_sharded,
    compact_sharded,
    delete_sharded,
    insert_sharded,
    search_sharded,
    shard_live_counts,
)
from ..core.index import DBLSHIndex
from ..tune import planner as _planner
from .collection import Collection, CompactionPolicy
from .lifecycle import _INDEX_ARRAY_FIELDS, CollectionLifecycle

__all__ = ["ShardedCollection", "open_collection"]


class ShardedCollection(CollectionLifecycle):
    """A collection fanned out over the mesh ``axis`` — same mutable
    lifecycle as :class:`~repro.store.collection.Collection`.

    The payload stays global (replicated): it is indexed by *global*
    ids after the top-k merge, which is exactly what ``search_sharded``
    returns.  Mutations draw versions from the same process-wide clock
    as local collections, so the service result cache invalidates
    sharded updates identically (DESIGN.md §6).
    """

    placement = "sharded"

    def __init__(self, name: str, sharded: ShardedDBLSH, mesh, **kw):
        self.sharded = sharded
        self.mesh = mesh
        # the sharded path always verifies through the jnp engine;
        # ``fixed_engine`` tells the StoreService's engine resolution to
        # ignore request/collection/service preferences entirely, so
        # tickets and cache keys reflect the engine that actually ran
        # (and a drained batch is never split over engines pointlessly)
        self.fixed_engine = "jnp"
        super().__init__(name, **kw)

    def _validate_default_engine(self, engine: str | None) -> str | None:
        if engine not in (None, "jnp"):
            raise ValueError(
                f"collection {self.name!r}: sharded collections verify per "
                f"shard through the jnp engine; engine={engine!r} cannot be "
                "honored (fixed_engine pins service resolution)"
            )
        return engine

    @classmethod
    def create(
        cls,
        name: str,
        key: jax.Array,
        data,
        mesh,
        *,
        axis: str = "data",
        params: DBLSHParams | None = None,
        payload=None,
        policy: CompactionPolicy | None = None,
        engine: str | None = None,
        search_policy=None,
        **derive_kw,
    ) -> "ShardedCollection":
        data = jnp.asarray(data, jnp.float32)
        n, d = data.shape
        pn = mesh.shape[axis]
        if params is None:
            # size K/L for the per-shard n: each device answers locally.
            params = DBLSHParams.derive(n=n // pn, d=d, **derive_kw)
        sharded = build_sharded(key, data, params, mesh, axis=axis)
        # build consumes the caller's key whole (identical hash functions
        # on every shard); fold for the compaction key stream instead of
        # splitting so the built index matches a local build(key, ...)
        kc = jax.random.fold_in(key, 0x5EED)
        return cls(name, sharded, mesh, payload=payload, policy=policy,
                   key=kc, engine=engine, search_policy=search_policy)

    # ---------------------------------------------------------------- surface
    @property
    def n(self) -> int:
        return self.sharded.n_total

    @property
    def d(self) -> int:
        return self.sharded.index.data.shape[1]

    def live_count(self) -> int:
        return int(np.asarray(shard_live_counts(self.sharded, self.mesh)).sum())

    def shard_counts(self) -> np.ndarray:
        """Per-shard live point counts (P,) — the insert-routing signal."""
        return np.asarray(shard_live_counts(self.sharded, mesh=self.mesh))

    def _occupancy(self) -> tuple[int, int]:
        counts = self.shard_counts()  # one device read serves both
        return int(counts.sum()), int(counts.max()) * int(counts.shape[0])

    # -------------------------------------------------------- placement hooks
    def _insert(self, points, payload) -> np.ndarray:
        counts = self.shard_counts()
        target = int(np.argmin(counts))  # least-loaded shard takes the batch
        pn = int(counts.shape[0])
        m = int(points.shape[0])
        n_old = self.sharded.n_local
        self.sharded = insert_sharded(
            self.sharded, points, target, mesh=self.mesh
        )
        n_new = self.sharded.n_local
        if self.payload is not None:
            # re-base the global payload layout: rows live at
            # rank * n_local + local, so growth re-slots every shard's
            # block.  The new rows are replicated to every shard's tail
            # (only the target's are live; dead copies are never
            # returned — their ids are tombstoned).
            tail = self.payload.shape[1:]
            old = jnp.reshape(self.payload, (pn, n_old) + tail)
            rep = jnp.broadcast_to(payload[None], (pn, m) + tail)
            self.payload = jnp.concatenate([old, rep], axis=1).reshape(
                (pn * n_new,) + tail
            )
        return target * n_new + n_old + np.arange(m, dtype=np.int64)

    def _delete(self, ids) -> None:
        self.sharded = delete_sharded(self.sharded, ids, mesh=self.mesh)

    def _compact_impl(self, key) -> np.ndarray:
        self.sharded, id_map = compact_sharded(self.sharded, key, self.mesh)
        return id_map

    def _calibrate_impl(self, queries, *, k, r0, steps_max, engine,
                        interpret, measure_ms):
        del engine, interpret  # per-shard verify is pinned to jnp
        kk = k or self.sharded.index.params.k

        def search_fn(Q, r0, steps, with_stats=False):
            return search_sharded(
                self.sharded, Q, k=kk, r0=r0, steps=steps, mesh=self.mesh,
                with_stats=with_stats,
            )

        return _planner.calibrate(
            self.sharded.index, queries, k=kk, r0=r0, steps_max=steps_max,
            measure_ms=measure_ms, search_fn=search_fn,
            oracle_rows=self._live_global_rows(),
        )

    def _live_global_rows(self) -> np.ndarray | None:
        """Global data-row indices (== global ids) of live points, or
        None when every row is live.  The oracle must exclude dead rows:
        a sharded insert leaves P-1 tombstoned replicas of every point
        at identical coordinates, and per-shard compaction padding adds
        zero rows — none of them returnable."""
        s = self.sharded
        pn = int(self.mesh.shape[s.axis])
        ids0 = np.asarray(s.index.ids_blocks[0])  # (nb_global, B) local ids
        blocks = ids0.reshape(pn, -1)
        rows = []
        for r in range(pn):
            loc = np.unique(blocks[r])
            loc = loc[loc < s.n_local]
            rows.append(loc + r * s.n_local)
        live = np.concatenate(rows)
        return None if live.size == s.n_total else live

    # ------------------------------------------------------------------ reads
    def search(
        self,
        Q,
        k: int = 0,
        *,
        r0: float = 1.0,
        steps: int = 8,
        engine: str | None = None,
        with_stats: bool = False,
        interpret: bool | None = None,
        rows: int | None = None,
        exact: bool = False,
        termination=None,
    ):
        """Global (c,k)-ANN: per-shard fixed-schedule search + all_gather
        top-k merge. ``engine`` / ``interpret`` are accepted for API
        parity; the sharded path always verifies through the jnp engine.
        ``rows`` (real rows in a service-padded batch) advances the query
        counter like the local placement.  With ``with_stats`` the
        per-shard probe statistics survive the collective merge
        (``search_sharded`` aggregates candidates by psum and
        radius_steps by pmax), so ``svc.stats()`` reports real per-query
        probe effort for sharded collections.  ``termination`` applies
        per shard (each device runs its own C1/C2 masks and while_loop
        exit — see ``search_sharded``)."""
        del engine, interpret
        Q = jnp.atleast_2d(jnp.asarray(Q, jnp.float32))
        self._count_queries(Q, rows)
        k = k or self.sharded.index.params.k
        return search_sharded(
            self.sharded, Q, k=k, r0=r0, steps=steps, mesh=self.mesh,
            with_stats=with_stats, exact=exact, termination=termination,
        )

    # ------------------------------------------------------------ persistence
    def _snapshot_arrays(self) -> dict:
        # np.asarray gathers each sharded array to one host copy — the
        # manifest stores the *global* layout plus the shard geometry
        # needed to re-place it (restore requires an equal shard count:
        # the per-shard STR packing and the rank-offset id math are both
        # baked at this P).
        return {
            f: np.asarray(getattr(self.sharded.index, f))
            for f in _INDEX_ARRAY_FIELDS
        }

    def _snapshot_meta(self) -> dict:
        return {
            "params": dataclasses.asdict(self.sharded.index.params),
            "axis": self.sharded.axis,
            "shards": int(self.mesh.shape[self.sharded.axis]),
            "n_local": self.sharded.n_local,
            "n_total": self.sharded.n_total,
        }

    @classmethod
    def restore(
        cls, directory: str, *, mesh, step: int | None = None,
    ) -> "ShardedCollection":
        """Re-place a sharded snapshot onto ``mesh`` (same shard count as
        at snapshot time — elastic re-sharding means a rebuild, because
        the per-shard STR layout and rank-offset ids are P-specific)."""
        tree, meta = Checkpointer(directory).restore(step)
        if meta.get("placement", "local") != "sharded":
            raise ValueError(
                f"snapshot at {directory!r} is local: restore it with "
                "Collection.restore() or repro.store.restore_collection()"
            )
        axis = meta["axis"]
        pn = int(meta["shards"])
        if mesh.shape[axis] != pn:
            raise ValueError(
                f"snapshot was taken on {pn} shards over {axis!r} but the "
                f"mesh has {mesh.shape[axis]}: the per-shard layout cannot "
                "be re-sharded — rebuild with ShardedCollection.create"
            )
        params = DBLSHParams(**meta["params"])
        specs = _index_specs(axis, params)
        arrays = {
            f: jax.device_put(
                np.asarray(tree[f]), NamedSharding(mesh, getattr(specs, f))
            )
            for f in _INDEX_ARRAY_FIELDS
            if f in tree
        }
        index = DBLSHIndex(**arrays, params=params)
        sharded = ShardedDBLSH(
            index=index, axis=axis, n_total=int(meta["n_total"]),
            n_local=int(meta["n_local"]),
        )
        return cls(meta["name"], sharded, mesh,
                   **cls._common_restore_kwargs(tree, meta))


def open_collection(
    name: str,
    key: jax.Array,
    data,
    *,
    mesh=None,
    axis: str = "data",
    max_points_per_shard: int = 1_000_000,
    payload=None,
    policy: CompactionPolicy | None = None,
    engine: str | None = None,
    search_policy=None,
    **derive_kw,
):
    """Route a dataset to local or sharded placement.

    Local :class:`Collection` when ``data`` fits one device (or no mesh
    given); :class:`ShardedCollection` fan-out otherwise.  The lifecycle
    options apply to either placement: ``policy`` drives auto-compaction
    of sharded collections exactly as it does local ones, and
    ``search_policy`` rides into the service's plan resolution.
    ``engine`` must be None or 'jnp' on the sharded path (per-shard
    verification is pinned to jnp) — it is validated, never silently
    dropped.
    """
    n = np.asarray(data).shape[0]
    if mesh is not None and mesh.shape[axis] > 1 and n > max_points_per_shard:
        return ShardedCollection.create(
            name, key, data, mesh, axis=axis, payload=payload, policy=policy,
            engine=engine, search_policy=search_policy, **derive_kw
        )
    return Collection.create(
        name, key, data, payload=payload, policy=policy, engine=engine,
        search_policy=search_policy, **derive_kw
    )
