"""Shard-aware routing: the full Collection lifecycle over
``core.distributed``.

A dataset too large for one device shards over the mesh 'data' axis:
every device builds a local DB-LSH index with the *same* LSH functions
(``core.distributed.build_sharded``), queries replicate, and per-shard
top-k merge with one all_gather into globally-id'd results.
:class:`ShardedCollection` implements the same mutable lifecycle
protocol as a local :class:`~repro.store.collection.Collection`
(``store.lifecycle.CollectionLifecycle``): ``add`` routes inserts to the
least-loaded shard, ``remove`` translates global ids per shard,
``compact`` rebalances survivors across shards and rebuilds with a
gathered global id remap, and ``snapshot`` / ``restore(mesh=...)``
persist the whole state — elastically: a snapshot taken on P shards
restores onto any shard count — so a
:class:`~repro.store.service.StoreService` serves both placements
through one admission queue, one cache-invalidation contract, and one
policy/engine resolution path, with no read-only special cases.

:func:`open_collection` is the router decision point: it places data on
a single device when it fits (``max_points_per_shard``), otherwise fans
out over the mesh — the lifecycle options (``policy``, ``engine``,
``search_policy``) apply to whichever placement wins.

**Id contract** (DESIGN.md §9): global ids are *strided*,
``gid = rank * stride + local`` with per-shard headroom
(``stride >= n_local``, sized by the compaction policy's growth ratio).
That keeps the merge's disjoint-id invariant AND makes ids durable
handles: an ``add`` grows ``n_local`` inside the stride, so every
existing id survives untouched.  Only ``compact`` renumbers — when the
policy fires, when called explicitly, or when an ``add`` would overflow
the stride — and it returns the id map exactly like the local
placement.  Elastic ``restore`` onto a different shard count also
renumbers (the manifest's geometry is P-specific); derive fresh ids
from searches after one.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from ..checkpoint import Checkpointer
from ..core import DBLSHParams
from ..core.distributed import (
    ShardedDBLSH,
    _index_specs,
    build_sharded,
    compact_sharded,
    delete_sharded,
    id_stride,
    insert_sharded,
    search_sharded,
    shard_live_counts,
)
from ..core.index import DBLSHIndex, empty_quant_blocks, quantize_blocks
from ..resilience import faults
from ..tune import planner as _planner
from .collection import Collection, CompactionPolicy
from .lifecycle import _INDEX_ARRAY_FIELDS, CollectionLifecycle

__all__ = ["ShardedCollection", "open_collection"]


class ShardedCollection(CollectionLifecycle):
    """A collection fanned out over the mesh ``axis`` — same mutable
    lifecycle as :class:`~repro.store.collection.Collection`.

    The payload stays global (replicated): it is indexed by *global*
    ids after the top-k merge, which is exactly what ``search_sharded``
    returns.  Mutations draw versions from the same process-wide clock
    as local collections, so the service result cache invalidates
    sharded updates identically (DESIGN.md §6).
    """

    placement = "sharded"

    def __init__(self, name: str, sharded: ShardedDBLSH, mesh, **kw):
        self.sharded = sharded
        self.mesh = mesh
        # the sharded path always verifies through the jnp engine;
        # ``fixed_engine`` tells the StoreService's engine resolution to
        # ignore request/collection/service preferences entirely, so
        # tickets and cache keys reflect the engine that actually ran
        # (and a drained batch is never split over engines pointlessly)
        self.fixed_engine = "jnp"
        # set transiently by _insert when a batch would overflow the id
        # stride, so the forced compact re-strides with room for it
        self._stride_reserve = 0
        payload = kw.get("payload")
        if payload is not None:
            payload = jnp.asarray(payload)
            if (payload.shape[0] == sharded.n_total
                    and sharded.n_total != self.id_space):
                # caller handed a dense one-row-per-point payload (the
                # create() convention): expand it into the strided id
                # layout — row for gid g at buffer index g, headroom
                # holes zero
                kw = dict(kw, payload=self._expand_payload(payload))
        super().__init__(name, **kw)

    def _expand_payload(self, dense: jax.Array) -> jax.Array:
        """Dense (n_total, ...) payload -> strided (id_space, ...)."""
        s = self.sharded
        row = np.arange(s.n_total)
        gid = (row // s.n_local) * s.stride + row % s.n_local
        buf = jnp.zeros((self.id_space,) + dense.shape[1:], dense.dtype)
        return buf.at[jnp.asarray(gid)].set(dense)

    def _validate_default_engine(self, engine: str | None) -> str | None:
        if engine not in (None, "jnp"):
            raise ValueError(
                f"collection {self.name!r}: sharded collections verify per "
                f"shard through the jnp engine; engine={engine!r} cannot be "
                "honored (fixed_engine pins service resolution)"
            )
        return engine

    @classmethod
    def create(
        cls,
        name: str,
        key: jax.Array,
        data,
        mesh,
        *,
        axis: str = "data",
        params: DBLSHParams | None = None,
        payload=None,
        policy: CompactionPolicy | None = None,
        engine: str | None = None,
        search_policy=None,
        **derive_kw,
    ) -> "ShardedCollection":
        data = jnp.asarray(data, jnp.float32)
        n, d = data.shape
        pn = mesh.shape[axis]
        if params is None:
            # size K/L for the per-shard n: each device answers locally.
            params = DBLSHParams.derive(n=n // pn, d=d, **derive_kw)
        # id stride with insert headroom: the growth trigger fires at
        # growth_ratio * built n, so sizing the stride to the same ratio
        # means a well-behaved policy compacts before the stride ever
        # forces a renumber
        pol = policy or CompactionPolicy()
        stride = id_stride(n // pn, cls._headroom(pol))
        sharded = build_sharded(key, data, params, mesh, axis=axis,
                                stride=stride)
        # build consumes the caller's key whole (identical hash functions
        # on every shard); fold for the compaction key stream instead of
        # splitting so the built index matches a local build(key, ...)
        kc = jax.random.fold_in(key, 0x5EED)
        return cls(name, sharded, mesh, payload=payload, policy=policy,
                   key=kc, engine=engine, search_policy=search_policy)

    @staticmethod
    def _headroom(policy: CompactionPolicy) -> float:
        """Stride headroom factor: track the growth trigger, floored so
        a no-growth policy still leaves real insert room."""
        return max(float(policy.growth_ratio), 1.25)

    # ---------------------------------------------------------------- surface
    @property
    def n(self) -> int:
        return self.sharded.n_total

    @property
    def d(self) -> int:
        return self.sharded.index.data.shape[1]

    @property
    def id_space(self) -> int:
        return self.sharded.id_space

    def live_count(self) -> int:
        return int(np.asarray(shard_live_counts(self.sharded, self.mesh)).sum())

    def shard_counts(self) -> np.ndarray:
        """Per-shard live point counts (P,) — the insert-routing signal."""
        return np.asarray(shard_live_counts(self.sharded, mesh=self.mesh))

    def _occupancy(self) -> tuple[int, int]:
        counts = self.shard_counts()  # one device read serves both
        live = int(counts.sum())
        pn = int(counts.shape[0])
        # compaction rebalances, so the attainable n is the balanced
        # ceiling — imbalance alone now justifies a rebuild when it
        # leaves the fleet hollow enough to trip the policy
        return live, pn * -(-live // pn)

    # -------------------------------------------------------- placement hooks
    def _insert(self, points, payload) -> np.ndarray:
        m = int(points.shape[0])
        if self.sharded.n_local + m > self.sharded.stride:
            # the stride is the id contract's renumbering boundary: ids
            # are stable until the headroom is exhausted, then one
            # compact() renumbers (returning the id map through the
            # normal add/remove channels) and re-strides with room for
            # this batch
            self._stride_reserve = m
            try:
                self.compact()
            finally:
                self._stride_reserve = 0
        counts = self.shard_counts()
        target = int(np.argmin(counts))  # least-loaded shard takes the batch
        s = self.sharded
        n_old = s.n_local
        self.sharded = insert_sharded(s, points, target, mesh=self.mesh)
        base = target * s.stride + n_old
        if self.payload is not None:
            # ids are stable, so the strided payload layout is too: the
            # batch lands in the target's headroom — one in-place tail
            # write instead of re-slotting every shard's block
            self.payload = self.payload.at[base:base + m].set(
                jnp.asarray(payload)
            )
        return base + np.arange(m, dtype=np.int32)

    def _delete(self, ids) -> None:
        self.sharded = delete_sharded(self.sharded, ids, mesh=self.mesh)

    def _compact_impl(self, key) -> np.ndarray:
        self.sharded, id_map = compact_sharded(
            self.sharded, key, self.mesh,
            headroom=self._headroom(self.policy),
            reserve=self._stride_reserve,
        )
        return id_map

    def _calibrate_impl(self, queries, *, k, r0, steps_max, engine,
                        interpret, measure_ms):
        del engine, interpret  # per-shard verify is pinned to jnp
        kk = k or self.sharded.index.params.k

        def search_fn(Q, r0, steps, with_stats=False):
            return search_sharded(
                self.sharded, Q, k=kk, r0=r0, steps=steps, mesh=self.mesh,
                with_stats=with_stats,
            )

        rows, gids = self._live_rows_and_ids()
        return _planner.calibrate(
            self.sharded.index, queries, k=kk, r0=r0, steps_max=steps_max,
            measure_ms=measure_ms, search_fn=search_fn,
            oracle_rows=rows, oracle_ids=gids,
        )

    def _live_rows_and_ids(self) -> tuple[np.ndarray | None, np.ndarray | None]:
        """Live points as ``(data_rows, gids)`` — the calibration oracle
        needs both: brute force runs over data *rows* while the search
        reports strided *gids*, and the two spaces coincide only in the
        dense, fully-live case (then ``(None, None)``: use everything).
        The oracle must exclude dead rows: a sharded insert leaves P-1
        tombstoned replicas of every point at identical coordinates, and
        compaction padding adds zero rows — none of them returnable."""
        s = self.sharded
        pn = int(self.mesh.shape[s.axis])
        ids0 = np.asarray(s.index.ids_blocks[0])  # (nb_global, B) local ids
        blocks = ids0.reshape(pn, -1)
        rows, gids = [], []
        for r in range(pn):
            loc = np.unique(blocks[r])
            loc = loc[loc < s.n_local]
            rows.append(loc + r * s.n_local)
            gids.append(loc + r * s.stride)
        rows = np.concatenate(rows)
        if rows.size == s.n_total and s.stride == s.n_local:
            return None, None
        return rows, np.concatenate(gids)

    # ------------------------------------------------------------------ reads
    def search(
        self,
        Q,
        k: int = 0,
        *,
        r0: float = 1.0,
        steps: int = 8,
        engine: str | None = None,
        with_stats: bool = False,
        interpret: bool | None = None,
        rows: int | None = None,
        exact: bool = False,
        termination=None,
        with_explain: bool = False,
        dtype: str = "fp32",
    ):
        """Global (c,k)-ANN: per-shard fixed-schedule search + all_gather
        top-k merge. ``engine`` / ``interpret`` are accepted for API
        parity; the sharded path always verifies through the jnp engine.
        ``rows`` (real rows in a service-padded batch) advances the query
        counter like the local placement.  With ``with_stats`` the
        per-shard probe statistics survive the collective merge
        (``search_sharded`` aggregates candidates by psum and
        radius_steps by pmax), so ``svc.stats()`` reports real per-query
        probe effort for sharded collections.  ``termination`` applies
        per shard (each device runs its own C1/C2 masks and while_loop
        exit — see ``search_sharded``).  ``with_explain`` appends the
        per-step EXPLAIN arrays *with per-shard attribution* (steps /
        slots / cause per shard, gathered before the pmax/psum
        collapse — see ``search_sharded``).  ``dtype`` selects the
        per-shard distance precision ('fp32'/'bf16'/'int8'): each shard
        runs the quantized shortlist + exact re-rank locally, so the
        all_gather merge always compares fp32 distances."""
        del engine, interpret
        Q = jnp.atleast_2d(jnp.asarray(Q, jnp.float32))
        self._count_queries(Q, rows)
        k = k or self.sharded.index.params.k
        # shard.straggle: one slow shard stalls the all_gather merge —
        # injected here (a no-op without an installed FaultPlan) so the
        # service's EWMA straggler monitor sees it as a slow batch
        faults.fire("shard.straggle", collection=self.name, scale=steps)
        return search_sharded(
            self.sharded, Q, k=k, r0=r0, steps=steps, mesh=self.mesh,
            with_stats=with_stats, exact=exact, termination=termination,
            with_explain=with_explain, dtype=dtype,
        )

    # ------------------------------------------------------------ persistence
    def _snapshot_arrays(self) -> dict:
        # np.asarray gathers each sharded array to one host copy — the
        # manifest stores the *global* layout plus the shard geometry
        # (shards / n_local / stride) needed either to re-place it
        # bit-for-bit on an equal mesh or to migrate it onto a different
        # shard count (elastic restore).
        return {
            f: np.asarray(getattr(self.sharded.index, f))
            for f in _INDEX_ARRAY_FIELDS
        }

    def _snapshot_meta(self) -> dict:
        return {
            "params": dataclasses.asdict(self.sharded.index.params),
            "axis": self.sharded.axis,
            "shards": int(self.mesh.shape[self.sharded.axis]),
            "n_local": self.sharded.n_local,
            "n_total": self.sharded.n_total,
            "stride": self.sharded.stride,
        }

    @classmethod
    def restore(
        cls, directory: str, *, mesh, step: int | None = None,
        migrate: bool | None = None,
    ) -> "ShardedCollection":
        """Re-place a sharded snapshot onto ``mesh``.

        On an equal shard count the persisted per-shard layout is
        device_put back verbatim (bit-identical restore).  Onto a
        *different* shard count the fleet is elastic: live rows are
        extracted from the manifest, re-partitioned balanced over the
        new mesh (the same balanced-contiguous split compaction uses),
        and rebuilt per shard — which renumbers global ids and
        invalidates any fitted calibration (derive fresh ids from
        searches; re-calibrate for planning).  ``migrate=True`` forces
        the migration path even at equal shard counts (a rebalancing
        restore); ``migrate=False`` demands the bit-identical path and
        raises on a shard-count mismatch."""
        tree, meta = Checkpointer(directory).restore(step)
        if meta.get("placement", "local") != "sharded":
            raise ValueError(
                f"snapshot at {directory!r} is local: restore it with "
                "Collection.restore() or repro.store.restore_collection()"
            )
        axis = meta["axis"]
        pn = int(meta["shards"])
        if migrate is None:
            migrate = int(mesh.shape[axis]) != pn
        if migrate:
            return cls._restore_migrated(tree, meta, mesh)
        if mesh.shape[axis] != pn:
            raise ValueError(
                f"snapshot was taken on {pn} shards over {axis!r} but the "
                f"mesh has {mesh.shape[axis]} and migrate=False: the "
                "per-shard layout is P-specific — allow migration or "
                "restore onto an equal mesh"
            )
        params = DBLSHParams(**meta["params"])
        specs = _index_specs(axis, params)
        arrays = {
            f: jax.device_put(
                np.asarray(tree[f]), NamedSharding(mesh, getattr(specs, f))
            )
            for f in _INDEX_ARRAY_FIELDS
            if f in tree
        }
        # Quantized blocks are derived state (never persisted): rebuild
        # them per shard on host — ids_blocks values are *shard-local*
        # row indices, so a single global quantize_blocks over the
        # concatenated manifest would gather the wrong rows for every
        # shard past rank 0.
        if params.quant_dtype != "none":
            n_local = int(meta["n_local"])
            datah = np.asarray(tree["data"]).reshape(pn, n_local, -1)
            idsh = np.asarray(tree["ids_blocks"])  # (L, nb_global, B)
            sb = idsh.shape[1] // pn
            qb_parts, qs_parts = [], []
            for r in range(pn):
                qb, qs = quantize_blocks(
                    jnp.asarray(datah[r]),
                    jnp.asarray(idsh[:, r * sb:(r + 1) * sb]),
                    params.quant_dtype,
                )
                qb_parts.append(qb)
                qs_parts.append(qs)
            arrays["qvec_blocks"] = jax.device_put(
                jnp.concatenate(qb_parts, axis=1),
                NamedSharding(mesh, specs.qvec_blocks),
            )
            arrays["qvec_scale"] = jax.device_put(
                jnp.concatenate(qs_parts, axis=1),
                NamedSharding(mesh, specs.qvec_scale),
            )
        else:
            qb, qs = empty_quant_blocks(params.quant_dtype)
            arrays["qvec_blocks"] = jax.device_put(
                qb, NamedSharding(mesh, specs.qvec_blocks))
            arrays["qvec_scale"] = jax.device_put(
                qs, NamedSharding(mesh, specs.qvec_scale))
        index = DBLSHIndex(**arrays, params=params)
        sharded = ShardedDBLSH(
            index=index, axis=axis, n_total=int(meta["n_total"]),
            n_local=int(meta["n_local"]),
            # pre-stride snapshots carry dense ids
            stride=int(meta.get("stride", meta["n_local"])),
        )
        return cls(meta["name"], sharded, mesh,
                   **cls._common_restore_kwargs(tree, meta))

    @classmethod
    def _restore_migrated(cls, tree, meta, mesh) -> "ShardedCollection":
        """Elastic restore: manifest rows -> balanced rebuild on ``mesh``.

        Survivor extraction and re-partitioning run on host from the
        gathered manifest (restore already has the host copy); the
        balanced split is the one :func:`compact_sharded` uses, so the
        post-restore fleet meets the same imbalance bound (counts differ
        by at most 1).  Global ids are renumbered; payload rows follow
        their points through the old->new gid map."""
        axis = meta["axis"]
        pn_old = int(meta["shards"])
        n_local = int(meta["n_local"])
        stride_old = int(meta.get("stride", n_local))
        pn = int(mesh.shape[axis])
        p_old = DBLSHParams(**meta["params"])
        # live (local id, data row, gid) per old shard, from table 0 of
        # the persisted blocks — ascending gid order, like compaction
        blocks = np.asarray(tree["ids_blocks"])[0].reshape(pn_old, -1)
        data = np.asarray(tree["data"]).reshape(pn_old, n_local, -1)
        rows, old_gids = [], []
        for r in range(pn_old):
            loc = np.unique(blocks[r])
            loc = loc[loc < n_local]
            rows.append(data[r, loc])
            old_gids.append(loc + r * stride_old)
        surv = np.concatenate(rows)
        old_gids = np.concatenate(old_gids)
        total = int(surv.shape[0])
        if total == 0:
            raise ValueError("restore: snapshot holds no live points")
        base, rem = divmod(total, pn)
        targets = base + (np.arange(pn) < rem)
        n_keep = int(targets.max())
        kw = cls._common_restore_kwargs(tree, meta)
        stride = id_stride(n_keep, cls._headroom(kw["policy"]))
        dst_off = np.concatenate([[0], np.cumsum(targets)])
        padded = np.zeros((pn * n_keep, surv.shape[1]), np.float32)
        new_gids = np.empty(total, np.int64)
        for r in range(pn):
            seg = surv[dst_off[r]:dst_off[r + 1]]
            padded[r * n_keep:r * n_keep + seg.shape[0]] = seg
            new_gids[dst_off[r]:dst_off[r + 1]] = (
                r * stride + np.arange(seg.shape[0])
            )
        params = DBLSHParams.derive(
            n=n_keep, d=p_old.d, c=p_old.c, w0=p_old.w0, t=p_old.t,
            k=p_old.k, block_size=p_old.block_size,
            inline_vectors=p_old.inline_vectors,
            quant_dtype=p_old.quant_dtype,
        )
        kw["key"], kb = jax.random.split(kw["key"])
        sharded = build_sharded(kb, jnp.asarray(padded), params, mesh,
                                axis=axis, stride=stride)
        pad_gids = np.concatenate([
            r * stride + np.arange(int(targets[r]), n_keep) for r in range(pn)
        ])
        if pad_gids.size:
            sharded = delete_sharded(
                sharded, jnp.asarray(pad_gids, jnp.int32), mesh=mesh
            )
        if kw["payload"] is not None:
            pay = np.asarray(kw["payload"])
            buf = np.zeros((pn * stride,) + pay.shape[1:], pay.dtype)
            buf[new_gids] = pay[old_gids]
            kw["payload"] = jnp.asarray(buf)
        # the geometry changed: the old growth baseline and fitted
        # schedule table describe an index that no longer exists
        kw["built_n"] = pn * n_keep
        kw["calibration"] = None
        return cls(meta["name"], sharded, mesh, **kw)


def open_collection(
    name: str,
    key: jax.Array,
    data,
    *,
    mesh=None,
    axis: str = "data",
    max_points_per_shard: int = 1_000_000,
    payload=None,
    policy: CompactionPolicy | None = None,
    engine: str | None = None,
    search_policy=None,
    **derive_kw,
):
    """Route a dataset to local or sharded placement.

    Local :class:`Collection` when ``data`` fits one device (or no mesh
    given); :class:`ShardedCollection` fan-out otherwise.  The lifecycle
    options apply to either placement: ``policy`` drives auto-compaction
    of sharded collections exactly as it does local ones, and
    ``search_policy`` rides into the service's plan resolution.
    ``engine`` must be None or 'jnp' on the sharded path (per-shard
    verification is pinned to jnp) — it is validated, never silently
    dropped.
    """
    # np.shape reads the shape attribute without materializing: routing
    # must never gather a device-sharded array to host just to count it
    n = np.shape(data)[0]
    if mesh is not None and mesh.shape[axis] > 1 and n > max_points_per_shard:
        return ShardedCollection.create(
            name, key, data, mesh, axis=axis, payload=payload, policy=policy,
            engine=engine, search_policy=search_policy, **derive_kw
        )
    return Collection.create(
        name, key, data, payload=payload, policy=policy, engine=engine,
        search_policy=search_policy, **derive_kw
    )
