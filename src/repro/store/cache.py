"""Query-result cache for the store layer: version-keyed LRU.

DB-LSH queries are read-mostly and heavily repeated in real serving
traffic (the same embedding re-queried across sessions, retries, or
kNN-LM decode loops), yet every repeat re-runs the full window-query
cascade.  :class:`QueryResultCache` short-circuits exact repeats at the
service frontend.

Invalidation is by **version**, not by flushing: the cache key embeds
the collection's monotonic ``version`` (bumped by ``add`` / ``remove``
/ ``compact``, refreshed on ``restore`` — see
:mod:`repro.store.collection`), so a mutation never has to find and
evict its stale entries — they simply stop matching and age out of the
LRU.  Version equality implies state equality (the version clock is
process-wide), which gives the contract the property tests pin down: a
cache hit is bit-identical to a fresh search at the collection's
current version.

Keys quantize the query to float32 bytes — the same dtype the dispatch
path casts to — so a hit requires a bit-exact query.  Two opt-in
wideners trade exactness for hit rate on near-duplicate traffic
(re-encoded embeddings, dithered clients, retry jitter); both are **off
by default** because they break the bit-equality contract and are only
safe for readers that tolerate approximate reuse:

* ``quantize_eps`` buckets every query coordinate to a grid of pitch
  ``eps`` (``round(q / eps)`` as int64) before hashing, so any two
  queries within the same grid cell share a key — the served result is
  whichever cell member was dispatched first, i.e. *approximate* reuse
  with per-coordinate error ≤ eps/2 in the key (not in the result:
  results are always exact for the query that computed them);
* ``quantize`` (decimal places) is the older, scale-dependent variant.

Version-invalidation semantics are unchanged by either: the version sits
outside the query bytes in the key, so a collection mutation makes
bucketed entries exactly as unreachable as exact ones.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

__all__ = ["CachedResult", "QueryResultCache"]


@dataclass(frozen=True)
class CachedResult:
    """One cached service-k result row (sliced to per-request k on hit)."""

    dists: np.ndarray          # (k_service,) ascending
    ids: np.ndarray            # (k_service,)
    payload: np.ndarray | None  # (k_service, ...) when the collection has one
    radius_steps: int
    candidates: int


class QueryResultCache:
    """Bounded LRU over (collection, version, query-bytes, k, engine, r0,
    steps) -> :class:`CachedResult`."""

    def __init__(self, capacity: int = 4096, quantize: int | None = None,
                 quantize_eps: float | None = None):
        assert capacity > 0
        assert quantize_eps is None or quantize_eps > 0
        assert quantize is None or quantize_eps is None, (
            "pass at most one key widener (quantize xor quantize_eps)"
        )
        self.capacity = capacity
        self.quantize = quantize
        self.quantize_eps = quantize_eps
        self._entries: OrderedDict[tuple, CachedResult] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self._m_hits = None    # registry counters, armed by bind_metrics
        self._m_misses = None
        self._m_size = None

    def bind_metrics(self, registry) -> "QueryResultCache":
        """Mirror hit/miss/size into a :class:`~repro.obs.metrics.
        MetricsRegistry` (idempotent; the service binds its registry at
        construction).  The plain ``hits``/``misses`` attributes remain
        the source of truth for :meth:`stats`."""
        self._m_hits = registry.counter(
            "repro_store_result_cache_hits_total", "Result-cache key hits"
        )
        self._m_misses = registry.counter(
            "repro_store_result_cache_misses_total", "Result-cache key misses"
        )
        self._m_size = registry.gauge(
            "repro_store_result_cache_size", "Live result-cache entries"
        )
        return self

    # ------------------------------------------------------------------ keys
    def _qbytes(self, query: np.ndarray) -> bytes:
        q = np.ascontiguousarray(query, np.float32)
        if self.quantize_eps is not None:
            # grid bucketing: near-duplicate queries (same eps-cell in
            # every coordinate) collapse to one key
            return np.round(q / self.quantize_eps).astype(np.int64).tobytes()
        if self.quantize is not None:
            q = np.round(q, self.quantize)
        return q.tobytes()

    def key(
        self, collection: str, version: int, query, k: int, engine: str,
        r0: float, steps: int, termination=None,
    ) -> tuple:
        """``termination`` (a hashable ``core.serve_search.Termination``
        or None) joins the key because a planned adaptive dispatch can
        return different results than the fixed schedule at the same
        (r0, steps)."""
        return (collection, version, self._qbytes(query), k, engine, r0,
                steps, termination)

    # ---------------------------------------------------------------- access
    def get(self, key: tuple) -> CachedResult | None:
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            if self._m_misses is not None:
                self._m_misses.inc()
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        if self._m_hits is not None:
            self._m_hits.inc()
        return entry

    def put(self, key: tuple, entry: CachedResult) -> None:
        self._entries[key] = entry
        self._entries.move_to_end(key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
        if self._m_size is not None:
            self._m_size.set(len(self._entries))

    def invalidate(self, collection: str | None = None) -> int:
        """Drop entries for one collection (or everything).  Only needed
        for explicit teardown — version keys already make stale entries
        unreachable after a mutation."""
        if collection is None:
            n = len(self._entries)
            self._entries.clear()
        else:
            drop = [k for k in self._entries if k[0] == collection]
            for k in drop:
                del self._entries[k]
            n = len(drop)
        if self._m_size is not None:
            self._m_size.set(len(self._entries))
        return n

    def __len__(self) -> int:
        return len(self._entries)

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "size": len(self._entries),
            "capacity": self.capacity,
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / total if total else 0.0,
        }
