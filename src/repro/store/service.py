"""StoreService: the overlapped, multi-tenant query scheduler.

Single queries arrive one at a time (``submit``) and would waste the
vector units if dispatched alone, but XLA recompiles on every new batch
shape — so the service coalesces per-(collection, tenant) **admission
queues** into dynamic micro-batches padded to a small fixed menu of
batch shapes.  v2 turns the synchronous dispatch loop into an actual
serving scheduler with three independent mechanisms:

**Overlapped dispatch.**  ``_dispatch`` is split into an *issue* stage
(host-side padding + the jitted ``search_batch_fixed`` call, which
returns device futures without blocking) and a *complete* stage (the
only host sync).  Issued batches sit in an in-flight ring of depth
``inflight_depth``; while the device executes batch *i*, the host pads
and issues batch *i+1*.  ``inflight_depth=0`` recovers the synchronous
v1 behavior exactly — both paths run the same compiled program, so
results are bit-identical by construction (the scheduler tests assert
this for every batch shape, timeout drains included).

**Query-result cache.**  An LRU (:mod:`repro.store.cache`) keyed on
(collection, *version*, query bytes, k, engine, r0, steps).  The
version is the collection's monotonic mutation counter, so
``add``/``remove``/``compact``/``restore`` invalidate by construction:
stale entries stop matching rather than needing eviction.  Hits are
served at drain time without touching the device.

**Admission control.**  Per-tenant token buckets (``set_quota``) reject
over-quota ``submit`` calls with :class:`QuotaExceeded`, and ``step``
drains the per-tenant queues weighted-round-robin so one hot tenant
cannot starve the rest of a batch.  Per-tenant served/rejected/QPS
stats sit alongside the per-collection QPS/latency/probe snapshot.

Time is read exclusively through an injectable ``clock`` (defaults to
``time.monotonic``) so quota refill, timeout drains, and latency
percentiles are deterministic under test.

Top-k is a *service-level* constant (``default_k``): per-request ``k``
may be any value up to it and is sliced from the service-k result
(cached entries store the full service-k row), which keeps the dispatch
shape set closed.  The verify engine resolves per request — explicit
``submit``/``serve`` override, else the collection's ``default_engine``,
else the service default — is frozen into the ticket at admission, keys
the result cache, and splits a drained batch per engine at issue time
(one compiled program per engine).  The *schedule* resolves the same
way through ``repro.tune``: an explicit ``policy=`` / ``recall_target=``
on submit, else the collection's ``search_policy``, else the service
``default_policy``, planned against the collection's calibration table
into a ``ResolvedPlan`` (r0, steps, adaptive termination) that is
likewise frozen into the ticket, keys the cache, and splits batches
(one compiled program per (engine, plan)).  Any object with ``search(Q, k=..., r0=..., steps=...,
engine=..., with_stats=..., rows=...)``, ``name``, and ``version`` can
be attached.  Local :class:`~repro.store.collection.Collection` and
sharded :class:`~repro.store.router.ShardedCollection` implement the
same mutable lifecycle protocol (``store.lifecycle``), so the service
holds **no placement-specific branches**: mutations on either placement
bump the same process-wide version clock (cache invalidation is
identical), policies and calibration resolve identically, and the only
placement signal is the generic ``fixed_engine`` attribute a collection
may use to pin engine resolution.
"""

from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import math
import time
from collections import deque

import jax
import numpy as np

from ..core.serve_search import PendingSearch, validate_engine
from ..obs import Observability
from ..obs.explain import TERM_CAUSE_NAMES, QueryExplain
from ..obs.metrics import LATENCY_MS_BUCKETS, MetricsRegistry
from ..obs.trace import TID_RING0, TID_SCHEDULER
from ..resilience import faults
from ..resilience.stragglers import StragglerMonitor
from ..tune import planner as _planner
from ..tune.policy import (
    LatencyBudget,
    RecallTarget,
    ResolvedPlan,
    resolve_policy_with_source,
)
from .cache import CachedResult, QueryResultCache

__all__ = [
    "BrownoutShed",
    "DeadlineExceeded",
    "DispatchFailed",
    "QueryRequest",
    "QuotaExceeded",
    "StoreService",
    "TenantQuota",
]


class QuotaExceeded(RuntimeError):
    """Raised by ``submit`` when the tenant's token bucket is empty."""


class BrownoutShed(QuotaExceeded):
    """Raised by ``submit`` when the brownout controller is at its
    load-shedding rung and the tenant is below the shed line.  A
    subclass of :class:`QuotaExceeded` so existing all-or-nothing /
    rejection handling applies unchanged."""


class DeadlineExceeded(RuntimeError):
    """The ticket's ``deadline_ms`` elapsed before its batch could be
    issued; the ticket terminates with ``error`` set instead of
    dispatching work nobody can use."""


class DispatchFailed(RuntimeError):
    """A batch's dispatch (or completion) raised after exhausting the
    transient-retry budget; every ticket in the batch terminates with
    ``error`` set to this, never left pending."""


@dataclasses.dataclass
class QueryRequest:
    """One in-flight query; filled in place when its batch completes."""

    uid: int
    collection: str
    query: np.ndarray  # (d,)
    k: int
    submitted: float
    tenant: str = "default"
    engine: str = "jnp"               # resolved at submit (request ->
                                      # collection default -> service)
    plan: ResolvedPlan | None = None  # resolved schedule (r0, steps,
                                      # termination) — request policy >
                                      # collection search_policy >
                                      # service default_policy
    deadline_ms: float | None = None  # end-to-end budget from submit; the
                                      # scheduler fails (pre-issue) or flags
                                      # degraded (post-complete) past it
    degraded: bool = False            # served on a cut-down schedule (deadline
                                      # re-plan or brownout) or past deadline —
                                      # the result is real but reduced-recall
    error: Exception | None = None    # typed terminal error (DeadlineExceeded,
                                      # DispatchFailed); done=True either way
    done: bool = False
    traced: bool = False              # sampled into the span recorder
    cached: bool = False              # served from the query-result cache
    dists: np.ndarray | None = None   # (k,) ascending; +inf = unfilled slot
    ids: np.ndarray | None = None     # (k,) neighbor ids; index.n = sentinel
    payload: object = None            # payload rows when the collection has one
    latency_ms: float = 0.0
    radius_steps: int = 0
    candidates: int = 0
    explain: QueryExplain | None = None  # EXPLAIN ANALYZE record, present
                                         # when submit(..., explain=True)
                                         # asked or auto-sampling picked
                                         # this ticket; filled progressively
                                         # through drain/issue/complete and
                                         # whole once done=True


@dataclasses.dataclass(frozen=True)
class TenantQuota:
    """Admission policy for one tenant.

    ``rate`` is the sustained queries/second refill, ``burst`` the bucket
    capacity (defaults to ``rate``, min 1), ``weight`` the tenant's share
    when a batch drains multiple tenants round-robin."""

    rate: float = math.inf
    burst: float | None = None
    weight: int = 1

    @property
    def capacity(self) -> float:
        if self.burst is not None:
            return self.burst
        return self.rate if math.isfinite(self.rate) else math.inf


class _TokenBucket:
    def __init__(self, quota: TenantQuota, now: float):
        self.quota = quota
        self.tokens = max(1.0, quota.capacity) if math.isfinite(quota.capacity) else math.inf
        self.t_last = now

    def try_take(self, now: float) -> bool:
        if math.isinf(self.tokens):
            return True
        self.tokens = min(
            max(1.0, self.quota.capacity),
            self.tokens + (now - self.t_last) * self.quota.rate,
        )
        self.t_last = now
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False


class _WindowClock:
    """First-submit / last-completion timestamps for a QPS window,
    mirrored into registry gauges for export.  Min-merged on the start
    edge: a cache hit may record a later first-submit while an earlier
    batch still sits in the in-flight ring."""

    def __init__(self, start_gauge, end_gauge, **labels):
        self._g0 = start_gauge
        self._g1 = end_gauge
        self._labels = labels
        self.t_first: float | None = None
        self.t_last: float | None = None

    def record(self, submitted: float, now: float) -> None:
        if self.t_first is None or submitted < self.t_first:
            self.t_first = submitted
            self._g0.set(submitted, **self._labels)
        self.t_last = now
        self._g1.set(now, **self._labels)

    def span(self) -> float:
        if self.t_first is None or self.t_last <= self.t_first:
            return 0.0
        return self.t_last - self.t_first


class _TenantStats:
    """Per-tenant admission/serving view over the metrics registry —
    the mutators the scheduler calls, the snapshot ``tenant_stats()``
    returns.  All state lives in registry series labeled by tenant."""

    def __init__(self, registry: MetricsRegistry, tenant: str):
        self.tenant = tenant
        r = registry
        self._submitted = r.counter(
            "repro_store_tenant_submitted_total", "Requests admitted by tenant"
        )
        self._withdrawn = r.counter(
            "repro_store_tenant_withdrawn_total",
            "Admitted requests withdrawn by all-or-nothing serve()",
        )
        self._served = r.counter(
            "repro_store_tenant_served_total", "Requests completed by tenant"
        )
        self._rejected = r.counter(
            "repro_store_quota_rejections_total",
            "submit() calls rejected by the tenant token bucket",
        )
        self._hits = r.counter(
            "repro_store_tenant_cache_hits_total",
            "Tenant requests served from the query-result cache",
        )
        self._failed = r.counter(
            "repro_store_tenant_failed_total",
            "Tenant requests terminated with a typed error, by kind",
        )
        self._degraded = r.counter(
            "repro_store_tenant_degraded_total",
            "Tenant requests served flagged-degraded (cut schedule or "
            "past deadline)",
        )
        self._window = _WindowClock(
            r.gauge("repro_store_tenant_window_start_seconds",
                    "Earliest submit timestamp in the tenant QPS window"),
            r.gauge("repro_store_tenant_window_end_seconds",
                    "Latest completion timestamp in the tenant QPS window"),
            tenant=tenant,
        )

    def record_submitted(self):
        self._submitted.inc(tenant=self.tenant)

    def record_withdrawn(self):
        self._withdrawn.inc(tenant=self.tenant)

    def record_rejected(self):
        self._rejected.inc(tenant=self.tenant)

    def record_served(self, req: QueryRequest, now: float):
        self._served.inc(tenant=self.tenant)
        if req.cached:
            self._hits.inc(tenant=self.tenant)
        if req.degraded:
            self._degraded.inc(tenant=self.tenant)
        self._window.record(req.submitted, now)

    def record_failed(self, kind: str = "error"):
        self._failed.inc(tenant=self.tenant, kind=kind)

    def _failed_by_kind(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for labels, v in self._failed.series():
            if labels.get("tenant") == self.tenant:
                out[labels.get("kind", "error")] = \
                    out.get(labels.get("kind", "error"), 0) + int(v)
        return out

    def snapshot(self) -> dict:
        t = dict(tenant=self.tenant)
        served = self._served.value(**t)
        span = self._window.span()
        failed = self._failed_by_kind()
        return {
            "submitted": int(
                self._submitted.value(**t) - self._withdrawn.value(**t)
            ),
            "served": int(served),
            "rejected": int(self._rejected.value(**t)),
            "cache_hits": int(self._hits.value(**t)),
            "failed": sum(failed.values()),
            "deadline_exceeded": failed.get("deadline", 0),
            "degraded": int(self._degraded.value(**t)),
            "qps": served / span if span > 0 else 0.0,
        }


class _CollectionStats:
    """Per-collection serving view over the metrics registry.  Snapshot
    keys are the stable ``svc.stats()`` contract; every number behind
    them is a registry series labeled by collection, so the same
    quantities export through Prometheus/JSON and feed the SLO watch.
    Empty windows report ``0.0``, never NaN."""

    def __init__(self, registry: MetricsRegistry, name: str,
                 latency_window: int = 8192):
        self.name = name
        r = registry
        self._served = r.counter(
            "repro_store_queries_served_total", "Queries completed"
        )
        self._failed = r.counter(
            "repro_store_requests_failed_total",
            "Requests terminated with a typed error, by kind",
        )
        self._degraded = r.counter(
            "repro_store_degraded_total",
            "Requests served flagged-degraded (cut schedule or past deadline)",
        )
        self._straggler = r.counter(
            "repro_store_straggler_batches_total",
            "Completed batches the EWMA monitor flagged as stragglers",
        )
        self._batches = r.counter(
            "repro_store_batches_total", "Device batches dispatched"
        )
        self._overlapped = r.counter(
            "repro_store_batches_overlapped_total",
            "Batches issued while another batch was already in flight",
        )
        self._cache_hits = r.counter(
            "repro_store_cache_hits_total",
            "Queries served from the result cache",
        )
        self._padded = r.counter(
            "repro_store_padded_slots_total",
            "Batch slots filled with padding, not real queries",
        )
        # bounded window reservoir inside the histogram: percentiles over
        # the most recent `latency_window` queries (default 8192), so a
        # long-lived serving process doesn't grow memory per request.
        # Smaller windows make the p99 react faster — the chaos bench
        # shrinks it so brownout heal is observable within a soak.
        self._latency = r.histogram(
            "repro_store_latency_ms", "End-to-end request latency (ms)",
            buckets=LATENCY_MS_BUCKETS, window=latency_window,
        )
        self._fill = r.histogram(
            "repro_store_batch_fill_ratio",
            "Real rows / batch shape at dispatch",
            buckets=(0.25, 0.5, 0.75, 1.0), window=1024,
        )
        self._radius_steps = r.counter(
            "repro_store_radius_steps_total", "Schedule steps run"
        )
        self._candidates = r.counter(
            "repro_store_candidates_total", "Verified candidate slots fetched"
        )
        # per-query termination-step counters (label step=j): how much of
        # the schedule each query actually ran, which is the work the
        # planner/adaptive-termination saves — and the SLO watch's drift
        # signal.  Sharded collections feed the same counter — their
        # radius_steps arrive pmax'd across shards from the merge.
        self._steps_hist = r.counter(
            "repro_store_termination_steps_total",
            "Queries by the schedule step their termination fired at",
        )
        self._window = _WindowClock(
            r.gauge("repro_store_window_start_seconds",
                    "Earliest submit timestamp in the QPS window"),
            r.gauge("repro_store_window_end_seconds",
                    "Latest completion timestamp in the QPS window"),
            collection=name,
        )
        self._steps_fam = self._steps_hist  # series() read in snapshot

    def _record_req(self, r: QueryRequest):
        self._latency.observe(r.latency_ms, collection=self.name)
        self._radius_steps.inc(r.radius_steps, collection=self.name)
        self._candidates.inc(r.candidates, collection=self.name)
        self._steps_hist.inc(
            collection=self.name, step=int(r.radius_steps)
        )
        if r.degraded:
            self._degraded.inc(collection=self.name)

    def record_failed(self, kind: str):
        self._failed.inc(collection=self.name, kind=kind)

    def record_straggler(self):
        self._straggler.inc(collection=self.name)

    def _failed_total(self) -> int:
        total = 0
        for labels, v in self._failed.series():
            if labels.get("collection") == self.name:
                total += int(v)
        return total

    def record_batch(self, reqs, shape, now, *, overlapped: bool):
        c = dict(collection=self.name)
        self._served.inc(len(reqs), **c)
        self._batches.inc(**c)
        if overlapped:
            self._overlapped.inc(**c)
        self._padded.inc(shape - len(reqs), **c)
        self._fill.observe(len(reqs) / shape, **c)
        self._window.record(min(r.submitted for r in reqs), now)
        for r in reqs:
            self._record_req(r)

    def record_hit(self, req: QueryRequest, now: float):
        c = dict(collection=self.name)
        self._served.inc(**c)
        self._cache_hits.inc(**c)
        self._window.record(req.submitted, now)
        self._record_req(req)

    def _step_hist(self) -> dict[int, int]:
        out = {}
        for labels, v in self._steps_fam.series():
            if labels.get("collection") == self.name:
                out[int(labels["step"])] = int(v)
        return dict(sorted(out.items()))

    def snapshot(self) -> dict:
        c = dict(collection=self.name)
        served = self._served.value(**c)
        batches = self._batches.value(**c)
        hits = self._cache_hits.value(**c)
        padded = self._padded.value(**c)
        span = self._window.span()
        p50, p90, p99 = (
            float(x) for x in self._latency.percentile([50.0, 90.0, 99.0], **c)
        )
        return {
            "queries": int(served),
            "batches": int(batches),
            "qps": served / span if span > 0 else 0.0,
            "latency_ms_p50": p50,
            "latency_ms_p90": p90,
            "latency_ms_p99": p99,
            "latency_ms_mean": self._latency.mean(**c),
            "mean_radius_steps": self._radius_steps.value(**c) / max(served, 1),
            "mean_candidates": self._candidates.value(**c) / max(served, 1),
            "termination_steps_hist": self._step_hist(),
            "padding_efficiency": (
                served / (served + padded) if served else 0.0
            ),
            "cache_hits": int(hits),
            "cache_hit_rate": hits / served if served else 0.0,
            "overlap_ratio": (
                self._overlapped.value(**c) / batches if batches else 0.0
            ),
            "failed": self._failed_total(),
            "degraded": int(self._degraded.value(**c)),
            "straggler_batches": int(self._straggler.value(**c)),
        }


@dataclasses.dataclass
class _InFlight:
    """One issued-but-not-completed batch in the overlap ring."""

    name: str
    reqs: list[QueryRequest]
    shape: int
    pending: PendingSearch
    payload: object        # device future (m, k, ...) or None
    version: int | None    # version the results belong to; None = uncacheable
    overlapped: bool       # issued while another batch was in flight
    engine: str            # resolved engine the batch was dispatched with
    plan: ResolvedPlan     # resolved schedule the batch was dispatched with
    seq: int = 0           # monotonic batch number (trace correlation)
    tid: int = TID_RING0   # trace lane = TID_RING0 + ring slot at issue
    t_issued: float = 0.0  # when the issue stage handed it to the device
    retries: int = 0       # transient-dispatch retries the issue burned
    fault_sites: tuple = ()  # injected fault sites the dispatch hit


class StoreService:
    """Admission control + overlapped micro-batch scheduling over
    attached collections."""

    def __init__(
        self,
        *,
        batch_shapes: tuple[int, ...] = (1, 4, 16, 64),
        max_wait_ms: float = 2.0,
        default_k: int = 10,
        r0: float = 1.0,
        steps: int = 8,
        engine: str = "jnp",
        interpret: bool | None = None,
        inflight_depth: int = 2,
        cache: QueryResultCache | None = None,
        cache_size: int = 1024,
        cache_quantize_eps: float | None = None,
        default_policy=None,
        clock=time.monotonic,
        obs: Observability | None = None,
        retry_limit: int = 2,
        retry_backoff_ms: float = 1.0,
        retry_backoff_cap_ms: float = 50.0,
        sleep=time.sleep,
        latency_window: int = 8192,
    ):
        assert batch_shapes == tuple(sorted(batch_shapes)) and batch_shapes
        assert inflight_depth >= 0
        self.batch_shapes = batch_shapes
        self.max_wait_ms = max_wait_ms
        self.default_k = default_k
        self.r0 = r0
        self.steps = steps
        self.engine = engine
        self.interpret = interpret
        self.inflight_depth = inflight_depth
        # transient-dispatch retry budget: errors whose `transient`
        # attribute is true are re-issued up to retry_limit times with
        # capped exponential backoff before the batch fails typed
        self.retry_limit = retry_limit
        self.retry_backoff_ms = retry_backoff_ms
        self.retry_backoff_cap_ms = retry_backoff_cap_ms
        self._sleep = sleep
        self._latency_window = latency_window
        # a BrownoutController registers itself here (resilience.degrade);
        # None = no degradation ladder, submit-time behavior unchanged
        self.brownout = None
        self._stragglers: dict[str, StragglerMonitor] = {}
        # service-level query-planning default (repro.tune policy) — the
        # lowest-precedence rung of request > collection > service
        self.default_policy = default_policy
        # observability bundle: metrics always on (the stats snapshots
        # below are views over the registry), tracing opt-in via the
        # bundle's tracer (`Observability(trace=True)`)
        self.obs = obs if obs is not None else Observability()
        self.registry = self.obs.registry
        self.tracer = self.obs.tracer
        self._g_queue = self.registry.gauge(
            "repro_store_queue_depth", "Admitted, not-yet-issued requests"
        )
        self._g_ring = self.registry.gauge(
            "repro_store_inflight_batches",
            "Issued-but-not-completed batches in the overlap ring",
        )
        if cache is not None:
            self.cache = cache
        else:
            self.cache = (
                QueryResultCache(cache_size, quantize_eps=cache_quantize_eps)
                if cache_size > 0 else None
            )
        if self.cache is not None:
            self.cache.bind_metrics(self.registry)
        self._clock = clock
        self.collections: dict[str, object] = {}
        self.quotas: dict[str, TenantQuota] = {}
        self._buckets: dict[str, _TokenBucket] = {}
        self._queues: dict[str, dict[str, deque[QueryRequest]]] = {}
        self._rr_pos: dict[str, int] = {}
        self._stats: dict[str, _CollectionStats] = {}
        self._tenant_stats: dict[str, _TenantStats] = {}
        self._inflight: deque[_InFlight] = deque()
        self._uid = 0
        self._batch_seq = 0

    def _tstats(self, tenant: str) -> _TenantStats:
        s = self._tenant_stats.get(tenant)
        if s is None:
            s = self._tenant_stats[tenant] = _TenantStats(self.registry, tenant)
        return s

    # ----------------------------------------------------------------- admin
    def attach(self, collection) -> None:
        """Register a Collection (or any search-compatible object)."""
        self.collections[collection.name] = collection
        self._queues.setdefault(collection.name, {})
        if collection.name not in self._stats:
            self._stats[collection.name] = _CollectionStats(
                self.registry, collection.name, self._latency_window
            )

    def create_collection(self, name: str, key, data, **kw):
        from .collection import Collection

        col = Collection.create(name, key, data, **kw)
        self.attach(col)
        return col

    def drop_collection(self, name: str) -> None:
        if any(q for q in self._queues.get(name, {}).values()):
            raise RuntimeError(f"collection {name!r} has pending requests")
        if any(b.name == name for b in self._inflight):
            raise RuntimeError(f"collection {name!r} has in-flight batches")
        self.collections.pop(name, None)
        self._queues.pop(name, None)
        self._stats.pop(name, None)
        self._rr_pos.pop(name, None)
        if self.cache is not None:
            self.cache.invalidate(name)

    def set_quota(
        self, tenant: str, *, rate: float = math.inf,
        burst: float | None = None, weight: int = 1,
    ) -> TenantQuota:
        """Install (or replace) a tenant's admission policy; the token
        bucket restarts full at the next ``submit``."""
        assert weight >= 1
        quota = TenantQuota(rate=rate, burst=burst, weight=weight)
        self.quotas[tenant] = quota
        self._buckets.pop(tenant, None)  # rebuilt lazily from the new quota
        return quota

    def __getitem__(self, name: str):
        return self.collections[name]

    # ---------------------------------------------------------------- submit
    def resolve_engine(self, collection: str, engine: str | None = None) -> str:
        """Three-level engine resolution: explicit request override, then
        the collection's ``default_engine``, then the service default.
        A collection that cannot honor engine selection (e.g. the sharded
        router, which always verifies through jnp) declares
        ``fixed_engine``; it wins over everything so tickets and cache
        keys name the engine that actually runs."""
        col = self.collections[collection]
        fixed = getattr(col, "fixed_engine", None)
        if fixed is not None:
            return validate_engine(fixed)
        if engine is None:
            engine = getattr(col, "default_engine", None) or self.engine
        return validate_engine(engine)

    def resolve_plan(self, collection: str, policy=None) -> ResolvedPlan:
        """Three-level policy resolution (explicit request policy, then
        the collection's ``search_policy``, then the service
        ``default_policy``), planned against the collection's calibration
        table.  No policy anywhere resolves to the service's own
        (r0, steps) with no adaptive termination — the pre-tune dispatch,
        bit-for-bit."""
        return self._resolve_plan_ex(collection, policy)[0]

    def _resolve_plan_ex(self, collection: str, policy=None):
        """:meth:`resolve_plan` plus the provenance EXPLAIN records:
        ``(plan, source, policy, table_used)`` where ``source`` names the
        resolution rung that won ("request"/"collection"/"service", or
        "default" when no rung supplied a policy)."""
        col = self.collections[collection]
        policy, source = resolve_policy_with_source(
            policy, getattr(col, "search_policy", None), self.default_policy
        )
        table = getattr(col, "calibration", None)
        plan = _planner.plan(
            table, policy, default_r0=self.r0, default_steps=self.steps,
        )
        return plan, source, policy, table is not None

    def submit(
        self, collection: str, query, k: int | None = None,
        tenant: str = "default", engine: str | None = None,
        policy=None, recall_target: float | None = None,
        deadline_ms: float | None = None,
        explain: bool | None = None,
    ) -> QueryRequest:
        """Enqueue one query; returns its ticket (filled once dispatched).
        ``engine`` overrides the collection / service engine defaults for
        this request; ``policy`` (a ``repro.tune`` policy) overrides the
        collection / service planning defaults, and ``recall_target=x``
        is sugar for ``policy=RecallTarget(x)``.  ``deadline_ms`` is an
        end-to-end budget: a ticket still queued past it terminates with
        a typed :class:`DeadlineExceeded` instead of dispatching, a
        ticket that can only fit the remaining budget on a shorter
        schedule is re-planned and flagged ``degraded``.  ``explain=True``
        attaches an EXPLAIN ANALYZE record (``ticket.explain``, a
        :class:`~repro.obs.explain.QueryExplain`) filled through the
        ticket's lifetime — plan provenance, queue/batch/cache story,
        the device's per-step window/slot measurements and terminate
        cause; ``explain=None`` (default) auto-samples at the bundle's
        ``explain_sample_rate``; ``explain=False`` never explains.
        Explain'd requests bypass the result-cache read (annotated, so
        the device story is always real) and batch separately — results
        stay bit-equal either way.  Raises :class:`QuotaExceeded` when
        the tenant is over quota — rejected requests are never enqueued
        — and :class:`BrownoutShed` when the degradation ladder is
        shedding this tenant's load."""
        if collection not in self.collections:
            raise KeyError(f"unknown collection {collection!r}")
        if recall_target is not None:
            if policy is not None:
                raise ValueError("pass either policy= or recall_target=, not both")
            policy = RecallTarget(recall_target)
        engine = self.resolve_engine(collection, engine)
        plan, plan_source, plan_policy, plan_table = \
            self._resolve_plan_ex(collection, policy)
        degraded = False
        replanned = None
        if self.brownout is not None:
            if self.brownout.should_shed(tenant):
                self._tstats(tenant).record_rejected()
                raise BrownoutShed(
                    f"tenant {tenant!r} shed at brownout level "
                    f"{self.brownout.level}"
                )
            plan, degraded = self.brownout.apply_plan(plan)
            if degraded:
                replanned = "brownout"
        k = self.default_k if k is None else k
        if k > self.default_k:
            raise ValueError(
                f"k={k} exceeds service default_k={self.default_k}; raise "
                "default_k at construction (k is compiled into the dispatch)"
            )
        now = self._clock()
        tstats = self._tstats(tenant)
        bucket = self._buckets.get(tenant)
        if bucket is None:
            bucket = _TokenBucket(self.quotas.get(tenant, TenantQuota()), now)
            self._buckets[tenant] = bucket
        if not bucket.try_take(now):
            tstats.record_rejected()
            if self.tracer.enabled:
                self.tracer.instant(
                    "quota.reject", cat="request", t=now,
                    tenant=tenant, collection=collection,
                )
            raise QuotaExceeded(
                f"tenant {tenant!r} over quota "
                f"(rate={bucket.quota.rate}/s, burst={bucket.quota.capacity})"
            )
        req = QueryRequest(
            uid=self._uid,
            collection=collection,
            query=np.asarray(query, np.float32).reshape(-1),
            k=k,
            submitted=now,
            tenant=tenant,
            engine=engine,
            plan=plan,
            deadline_ms=deadline_ms,
            degraded=degraded,
            traced=self.tracer.should_sample(),
        )
        if explain or (explain is None and self.obs.should_explain()):
            req.explain = QueryExplain(
                uid=req.uid, collection=collection, tenant=tenant,
                engine=engine, plan_r0=plan.r0, plan_steps=plan.steps,
                plan_termination=(
                    None if plan.termination is None
                    else repr(plan.termination)
                ),
                plan_source=plan_source,
                plan_policy=(
                    None if plan_policy is None else repr(plan_policy)
                ),
                plan_table=plan_table,
                replanned=replanned,
                brownout_level=(
                    self.brownout.level if self.brownout is not None else 0
                ),
                degraded=degraded,
                traced=req.traced,
            )
        self._uid += 1
        self._queues[collection].setdefault(tenant, deque()).append(req)
        tstats.record_submitted()
        self._g_queue.set(self.pending())
        return req

    def pending(self) -> int:
        """Queued (not yet issued) requests."""
        return sum(
            len(q) for per in self._queues.values() for q in per.values()
        )

    def in_flight(self) -> int:
        """Requests issued to the device but not yet completed."""
        return sum(len(b.reqs) for b in self._inflight)

    # -------------------------------------------------------------- dispatch
    def step(self, force: bool = False) -> int:
        """One scheduler pass.

        Retires any in-flight batches that are already ready (never
        blocks for them), then drains every collection whose queues are
        full enough (or whose oldest request timed out, or everything
        when ``force``) — serving cache hits inline and issuing the rest
        without waiting on the device, up to ``inflight_depth`` batches
        deep.  With ``force`` the pass ends fully synchronous: every
        in-flight batch is completed before returning.  Returns the
        number of requests drained (hits + issued)."""
        self.poll()
        now = self._clock()
        drained = 0
        cap = self.batch_shapes[-1]
        for name, per_tenant in self._queues.items():
            while True:
                total = sum(len(q) for q in per_tenant.values())
                if total == 0:
                    break
                oldest = min(q[0].submitted for q in per_tenant.values() if q)
                timed_out = (now - oldest) * 1e3 >= self.max_wait_ms
                if not (force or timed_out or total >= cap):
                    break
                reqs = self._drain_wrr(name, cap)
                drained += len(reqs)
                if self.tracer.enabled or \
                        any(r.explain is not None for r in reqs):
                    t_drain = self._clock()
                    for r in reqs:
                        if r.explain is not None:
                            r.explain.queue_wait_ms = \
                                (t_drain - r.submitted) * 1e3
                        if r.traced and self.tracer.enabled:
                            self.tracer.add_span(
                                "request.queue_wait", r.submitted, t_drain,
                                cat="request", uid=r.uid, tenant=r.tenant,
                                collection=name,
                            )
                reqs = self._apply_deadlines(name, reqs)
                misses = self._serve_cached(name, reqs)
                if misses:
                    # one device program per (engine, plan, explain):
                    # split mixed batches (requests resolve engines and
                    # plans at submit, so a batch is mixed only under
                    # per-request overrides / policies / sampled
                    # explains — the explain variant is a different
                    # compiled program returning the per-step arrays)
                    by_prog: dict[tuple, list[QueryRequest]] = {}
                    for r in misses:
                        by_prog.setdefault(
                            (r.engine, r.plan, r.explain is not None), []
                        ).append(r)
                    for (eng, plan, explained), group in by_prog.items():
                        self._issue(name, group, eng, plan,
                                    with_explain=explained)
        self._g_queue.set(self.pending())
        if force:
            self._complete_all()
        if self.obs.slo is not None:
            self.obs.slo.maybe_check(self._clock())
        return drained

    def poll(self) -> int:
        """Retire ready in-flight batches without blocking; returns the
        number of batches completed. Completion stays in issue order —
        the ring head is the only candidate."""
        done = 0
        while self._inflight and self._inflight[0].pending.ready():
            self._complete(self._inflight.popleft())
            done += 1
        return done

    def flush(self) -> int:
        """Drain and complete everything pending; returns requests served."""
        total = 0
        while self.pending():
            total += self.step(force=True)
        self._complete_all()
        return total

    def _shape_for(self, m: int) -> int:
        for s in self.batch_shapes:
            if s >= m:
                return s
        return self.batch_shapes[-1]

    def _drain_wrr(self, name: str, cap: int) -> list[QueryRequest]:
        """Pop up to ``cap`` requests across the collection's tenant
        queues, weighted round-robin: each cycle visits the non-empty
        tenants in rotated order and takes up to ``quota.weight`` from
        each, so a backlogged tenant gets its share — never the whole
        batch — while light tenants pass through untouched."""
        per_tenant = self._queues[name]
        tenants = sorted(t for t, q in per_tenant.items() if q)
        if not tenants:
            return []
        start = self._rr_pos.get(name, 0) % len(tenants)
        order = tenants[start:] + tenants[:start]
        self._rr_pos[name] = self._rr_pos.get(name, 0) + 1
        out: list[QueryRequest] = []
        while len(out) < cap and any(per_tenant[t] for t in order):
            for t in order:
                weight = max(1, self.quotas.get(t, TenantQuota()).weight)
                for _ in range(weight):
                    if len(out) >= cap or not per_tenant[t]:
                        break
                    out.append(per_tenant[t].popleft())
                if len(out) >= cap:
                    break
        return out

    # --------------------------------------------- deadlines / typed failure
    def _fail_req(self, name: str, r: QueryRequest, exc: Exception,
                  kind: str, now: float) -> None:
        """Terminate one ticket with a typed error — the ticket contract
        is that ``done`` flips exactly once, result or error, never
        neither."""
        r.error = exc
        r.done = True
        r.latency_ms = (now - r.submitted) * 1e3
        self._stats[name].record_failed(kind)
        self._tstats(r.tenant).record_failed(kind)
        if r.traced:
            self.tracer.instant(
                "request.failed", cat="request", t=now,
                uid=r.uid, collection=name, kind=kind,
            )

    def _fail_batch(self, name: str, reqs: list[QueryRequest],
                    exc: Exception, kind: str) -> None:
        now = self._clock()
        for r in reqs:
            self._fail_req(name, r, exc, kind, now)

    def _apply_deadlines(
        self, name: str, reqs: list[QueryRequest]
    ) -> list[QueryRequest]:
        """Deadline gate at drain time.  Expired tickets terminate with
        :class:`DeadlineExceeded` before any device work; tickets whose
        remaining budget no longer fits their plan are re-planned through
        ``LatencyBudget(remaining)`` — DB-LSH's schedule is the knob: a
        shorter window schedule trades recall for latency continuously —
        and flagged ``degraded``.  Re-planning needs a *measured*
        calibration table (``Collection.calibrate(measure_ms=True)``);
        without one the ticket keeps its plan and simply risks finishing
        late (flagged at completion)."""
        now = self._clock()
        out: list[QueryRequest] = []
        table = None
        if any(r.deadline_ms is not None for r in reqs):
            table = getattr(self.collections[name], "calibration", None)
            if table is not None and not any(
                math.isfinite(float(m)) for m in table.cost_ms
            ):
                table = None  # unmeasured: recall-only calibration
        for r in reqs:
            if r.deadline_ms is None:
                out.append(r)
                continue
            remaining = r.deadline_ms - (now - r.submitted) * 1e3
            if remaining <= 0:
                self._fail_req(
                    name, r,
                    DeadlineExceeded(
                        f"deadline {r.deadline_ms}ms elapsed before dispatch "
                        f"(queued {(now - r.submitted) * 1e3:.3f}ms)"
                    ),
                    "deadline", now,
                )
                continue
            if table is not None:
                tight = _planner.plan(
                    table, LatencyBudget(remaining),
                    default_r0=self.r0, default_steps=self.steps,
                )
                if tight.steps < r.plan.steps:
                    r.plan = tight
                    r.degraded = True
                    if r.explain is not None:
                        # the schedule the ticket will actually run is no
                        # longer the one resolution produced: re-stamp it
                        # and name the deadline re-plan as the cause
                        r.explain.replanned = "deadline"
                        r.explain.degraded = True
                        r.explain.plan_r0 = tight.r0
                        r.explain.plan_steps = tight.steps
                        r.explain.plan_termination = (
                            None if tight.termination is None
                            else repr(tight.termination)
                        )
            out.append(r)
        return out

    # ------------------------------------------------------------- the cache
    def _cache_key(self, name: str, version: int, query: np.ndarray,
                   engine: str, plan: ResolvedPlan):
        return self.cache.key(
            name, version, query, self.default_k, engine, plan.r0,
            plan.steps, plan.termination,
        )

    @staticmethod
    def _cache_key_str(key: tuple) -> str:
        """Human-readable form of a cache key for EXPLAIN records (the
        raw key embeds the query bytes; here they become a short
        digest)."""
        name, version, qbytes, k, engine, r0, steps, term = key
        qh = hashlib.blake2b(qbytes, digest_size=6).hexdigest()
        return (
            f"{name}@v{version}/q:{qh}/k{k}/{engine}/r0={r0:g}/s{steps}"
            + ("" if term is None else "/adaptive")
        )

    def _serve_cached(self, name: str, reqs: list[QueryRequest]):
        """Fill cache hits in place; returns the misses to dispatch.
        Explain'd requests are never cache-served silently: they bypass
        the read (annotated with the key they would have probed) so the
        EXPLAIN record always carries a real device story; their results
        are still published to the cache at completion."""
        if self.cache is None:
            for r in reqs:
                if r.explain is not None:
                    r.explain.cache_outcome = "uncached"
            return reqs
        # no version attribute -> no invalidation signal: never cache
        # (serving version-0 hits forever is exactly the staleness the
        # version contract exists to prevent)
        version = getattr(self.collections[name], "version", None)
        if version is None:
            for r in reqs:
                if r.explain is not None:
                    r.explain.cache_outcome = "uncached"
            return reqs
        misses = []
        for r in reqs:
            key = self._cache_key(name, version, r.query, r.engine, r.plan)
            if r.explain is not None:
                r.explain.cache_outcome = "bypass"
                r.explain.cache_key = self._cache_key_str(key)
                misses.append(r)
                continue
            entry = self.cache.get(key)
            if entry is None:
                misses.append(r)
                continue
            now = self._clock()
            # copies: tickets are handed to callers who may mutate them
            # in place, and the cached row must stay bit-identical
            r.dists = entry.dists[: r.k].copy()
            r.ids = entry.ids[: r.k].copy()
            if entry.payload is not None:
                r.payload = entry.payload[: r.k].copy()
            r.radius_steps = entry.radius_steps
            r.candidates = entry.candidates
            r.latency_ms = (now - r.submitted) * 1e3
            r.cached = True
            r.done = True
            if r.traced:
                self.tracer.instant(
                    "request.cache_hit", cat="request", t=now,
                    uid=r.uid, collection=name,
                )
            self._stats[name].record_hit(r, now)
            self._tstats(r.tenant).record_served(r, now)
            self.obs.exemplars.record(r.latency_ms, r.uid, name)
        return misses

    # ------------------------------------------------- issue / complete stages
    def _issue(self, name: str, reqs: list[QueryRequest],
               engine: str | None = None,
               plan: ResolvedPlan | None = None,
               with_explain: bool = False) -> None:
        """Stage 1: pad host-side and put the batch on the device without
        blocking (``col.search`` returns device futures).  With
        ``with_explain`` the dispatch runs the explain variant of the
        compiled search (per-query per-step arrays ride back with the
        results) and the batch records its retry count and the fault
        sites its dispatch hit, for the tickets' EXPLAIN records."""
        col = self.collections[name]
        if engine is None:
            engine = self.resolve_engine(name)
        if plan is None:
            plan = self.resolve_plan(name)
        traced = self.tracer.enabled
        t_a0 = self._clock() if traced else 0.0
        m = len(reqs)
        shape = self._shape_for(m)
        d = reqs[0].query.shape[0]
        Q = np.zeros((shape, d), np.float32)
        for j, r in enumerate(reqs):
            Q[j] = r.query
        # termination= only travels when the plan carries one: a plain
        # (no-policy / FixedSchedule) dispatch keeps the documented
        # attachable search signature, so pre-tune attachables keep
        # working; an adaptive policy requires the attachable to accept
        # termination= (Collection and ShardedCollection both do)
        term_kw = (
            {} if plan.termination is None
            else {"termination": plan.termination}
        )
        seq = self._batch_seq
        self._batch_seq += 1
        # lane = ring slot this batch will occupy, so a Perfetto render
        # shows overlap directly: batch N+1's issue span sits one lane up,
        # inside batch N's pending window
        tid = TID_RING0 + len(self._inflight)
        t_i0 = self._clock()
        dispatch_ctx = (
            jax.profiler.TraceAnnotation(f"store.dispatch.{name}")
            if traced else contextlib.nullcontext()
        )
        # explain travels as an opt-in kwarg (like termination) so plain
        # attachables that predate it keep working on the default path
        explain_kw = {"with_explain": True} if with_explain else {}
        # fault-site attribution: anything the active plan fires between
        # here and a successful dispatch belongs to this batch
        fplan = faults.active_plan()
        fired0 = len(fplan.fired) if fplan is not None else 0
        attempts = 0
        explain_arrays = None
        while True:
            try:
                # fault sites (no-ops without an installed plan): an
                # injected latency spike scales with the schedule the
                # batch runs, like the real dispatch does
                faults.fire("dispatch.delay_ms", collection=name,
                            scale=plan.steps)
                faults.fire("dispatch.raise", collection=name, engine=engine)
                with dispatch_ctx:
                    out = col.search(
                        Q, k=self.default_k, r0=plan.r0, steps=plan.steps,
                        engine=engine, with_stats=True,
                        interpret=self.interpret,
                        rows=m,  # only m of `shape` rows are real queries
                        **term_kw, **explain_kw,
                    )
                    if with_explain:
                        dists, ids, stats, explain_arrays = out
                    else:
                        dists, ids, stats = out
                    payload = None
                    if getattr(col, "payload", None) is not None:
                        # async gather, same stream
                        payload = col.get_payload(ids[:m])
                break
            except Exception as e:
                attempts += 1
                transient = bool(getattr(e, "transient", False))
                if transient and attempts <= self.retry_limit:
                    self._sleep(
                        min(self.retry_backoff_cap_ms,
                            self.retry_backoff_ms * 2 ** (attempts - 1)) / 1e3
                    )
                    continue
                # exhausted (or non-transient): every ticket terminates
                # with a typed error — never parked in the ring forever
                err = DispatchFailed(
                    f"dispatch for collection {name!r} failed after "
                    f"{attempts} attempt(s): {e}"
                )
                err.__cause__ = e
                self._fail_batch(name, reqs, err, "dispatch")
                return
        t_i1 = self._clock()
        if traced:
            self.tracer.add_span(
                "batch.assemble", t_a0, t_i0, cat="batch", tid=TID_SCHEDULER,
                seq=seq, collection=name, rows=m, shape=shape,
            )
            self.tracer.add_span(
                "batch.issue", t_i0, t_i1, cat="batch", tid=tid,
                seq=seq, collection=name, rows=m, shape=shape,
                engine=engine, overlapped=len(self._inflight) > 0,
            )
        batch = _InFlight(
            name=name,
            reqs=reqs,
            shape=shape,
            pending=PendingSearch(dists, ids, stats, explain_arrays),
            payload=payload,
            version=getattr(col, "version", None),  # None = uncacheable
            overlapped=len(self._inflight) > 0,
            engine=engine,
            plan=plan,
            seq=seq,
            tid=tid,
            t_issued=t_i1,
            retries=attempts,
            fault_sites=(
                () if fplan is None
                else tuple(s for s, _ in fplan.fired[fired0:])
            ),
        )
        self._inflight.append(batch)
        self._g_ring.set(len(self._inflight))
        while len(self._inflight) > self.inflight_depth:
            self._complete(self._inflight.popleft())

    def _complete(self, batch: _InFlight) -> None:
        """Stage 2: the only host sync — materialize the device results,
        fill the tickets, and publish cache entries under the version the
        batch was issued at (a mutation mid-flight bumps the version, so
        those entries are born unreachable rather than stale)."""
        traced = self.tracer.enabled
        t_c0 = self._clock() if traced else 0.0
        try:
            dists, ids, stats = batch.pending.result()
            dists = np.asarray(dists)
            ids = np.asarray(ids)
            steps_taken = np.asarray(stats["radius_steps"])
            cands = np.asarray(stats["candidates"])
            payloads = (
                None if batch.payload is None else np.asarray(batch.payload)
            )
        except Exception as e:
            # the device-side computation died after issue: the tickets
            # still terminate, typed, instead of hanging in the ring
            err = DispatchFailed(
                f"completion for collection {batch.name!r} failed: {e}"
            )
            err.__cause__ = e
            self._fail_batch(batch.name, batch.reqs, err, "complete")
            self._g_ring.set(len(self._inflight))
            return
        now = self._clock()
        # issue->complete wall time feeds the EWMA straggler monitor —
        # in a sharded deployment a flagged batch is the signature of one
        # straggling shard holding the global merge hostage
        mon = self._stragglers.get(batch.name)
        if mon is None:
            mon = self._stragglers[batch.name] = StragglerMonitor()
        if mon.record(batch.seq, max(now - batch.t_issued, 0.0)):
            self._stats[batch.name].record_straggler()
        if traced:
            # pending window: issue handoff -> this host sync (batch N+1's
            # issue span lands inside it when the ring overlapped)
            self.tracer.add_span(
                "batch.pending", batch.t_issued, t_c0, cat="batch",
                tid=batch.tid, seq=batch.seq, collection=batch.name,
            )
            self.tracer.add_span(
                "batch.complete", t_c0, now, cat="batch", tid=batch.tid,
                seq=batch.seq, collection=batch.name, rows=len(batch.reqs),
            )
        ex = batch.pending.explain
        if ex is not None:
            ex = {k2: np.asarray(v) for k2, v in ex.items()}
        for j, r in enumerate(batch.reqs):
            r.dists = dists[j, : r.k]
            r.ids = ids[j, : r.k]
            if payloads is not None:
                r.payload = payloads[j, : r.k]
            r.radius_steps = int(steps_taken[j])
            r.candidates = int(cands[j])
            r.latency_ms = (now - r.submitted) * 1e3
            if r.deadline_ms is not None and r.latency_ms > r.deadline_ms:
                r.degraded = True  # served, but past its budget — flagged
            if r.explain is not None and ex is not None:
                self._fill_explain(r, batch, ex, j, now)
            r.done = True
            if self.cache is not None and batch.version is not None:
                # copies: r.dists/r.ids above are views of the same batch
                # arrays, and callers own (and may mutate) their tickets
                self.cache.put(
                    self._cache_key(batch.name, batch.version, r.query,
                                    batch.engine, batch.plan),
                    CachedResult(
                        dists=dists[j].copy(),
                        ids=ids[j].copy(),
                        payload=None if payloads is None else payloads[j].copy(),
                        radius_steps=int(steps_taken[j]),
                        candidates=int(cands[j]),
                    ),
                )
            self._tstats(r.tenant).record_served(r, now)
            # tail-exemplar feed: every served ticket's (latency, uid)
            # lands in its latency bucket's ring; explain'd tickets keep
            # the full record so SLO breaches can render the worst-k
            self.obs.exemplars.record(
                r.latency_ms, r.uid, batch.name, r.explain
            )
        if traced and self.cache is not None and batch.version is not None:
            self.tracer.instant(
                "cache.put", cat="cache", t=now, tid=batch.tid,
                seq=batch.seq, collection=batch.name, entries=len(batch.reqs),
            )
        self._stats[batch.name].record_batch(
            batch.reqs, batch.shape, now, overlapped=batch.overlapped
        )
        self._g_ring.set(len(self._inflight))  # callers popleft before calling

    def _fill_explain(self, r: QueryRequest, batch: _InFlight,
                      ex: dict, j: int, now: float) -> None:
        """Finish one ticket's EXPLAIN record at completion: the batch's
        placement in the scheduler (seq / ring slot / fill), the device's
        per-step measurements for row ``j``, per-shard attribution when
        the sharded path gathered it, and the resilience story the issue
        stage recorded."""
        e = r.explain
        e.batch_seq = batch.seq
        e.ring_slot = batch.tid - TID_RING0
        e.batch_rows = len(batch.reqs)
        e.batch_shape = batch.shape
        e.steps_run = r.radius_steps
        e.candidates = r.candidates
        e.term_cause = TERM_CAUSE_NAMES.get(
            int(ex["term_cause"][j]), str(int(ex["term_cause"][j]))
        )
        e.final_radius = float(ex["final_radius"][j])
        e.step_half = [float(x) for x in ex["step_half"]]
        e.step_slots = [int(x) for x in ex["step_slots"][j]]
        if "shard_steps" in ex:  # sharded placement: pre-collapse view
            e.shard_steps = [int(x) for x in ex["shard_steps"][:, j]]
            e.shard_slots = [int(x) for x in ex["shard_slots"][:, j]]
            e.shard_cause = [int(x) for x in ex["shard_cause"][:, j]]
        e.degraded = r.degraded
        e.retries = batch.retries
        e.fault_sites = list(batch.fault_sites)
        e.latency_ms = r.latency_ms
        if r.traced and self.tracer.enabled:
            # instant on the request's async-span timeline: a Perfetto
            # view links the rendered explain back to the request by uid
            self.tracer.instant(
                "request.explain", cat="explain", t=now, uid=r.uid,
                collection=batch.name, term_cause=e.term_cause,
                steps_run=e.steps_run,
            )

    def _complete_all(self) -> None:
        while self._inflight:
            self._complete(self._inflight.popleft())

    # ------------------------------------------------------------ convenience
    def serve(self, collection: str, Q, k: int | None = None,
              tenant: str = "default", engine: str | None = None,
              policy=None, recall_target: float | None = None,
              deadline_ms: float | None = None,
              explain: bool | None = None):
        """Submit a whole query matrix as single requests, flush, and return
        stacked (dists, ids) — the micro-batching round trip.  All-or-
        nothing under quota: if any row is rejected, the rows already
        enqueued are withdrawn before :class:`QuotaExceeded` propagates
        (no orphaned tickets dispatching work nobody observes).  A ticket
        that terminated with a typed error (deadline, failed dispatch)
        re-raises that error here — callers driving tickets individually
        check ``req.error`` instead."""
        reqs = []
        try:
            for q in np.atleast_2d(Q):
                reqs.append(
                    self.submit(collection, q, k=k, tenant=tenant,
                                engine=engine, policy=policy,
                                recall_target=recall_target,
                                deadline_ms=deadline_ms, explain=explain)
                )
        except QuotaExceeded:
            queue = self._queues[collection].get(tenant)
            for r in reqs:
                if queue is not None and r in queue:
                    queue.remove(r)
                    # counters are monotonic: withdrawal is its own counter,
                    # and the snapshot reports submitted - withdrawn
                    self._tenant_stats[tenant].record_withdrawn()
            self._g_queue.set(self.pending())
            raise
        self.flush()
        for r in reqs:
            if r.error is not None:
                raise r.error
        return (
            np.stack([r.dists for r in reqs]),
            np.stack([r.ids for r in reqs]),
            reqs,
        )

    def stats(self, collection: str | None = None) -> dict:
        if collection is not None:
            return self._stats[collection].snapshot()
        return {name: s.snapshot() for name, s in self._stats.items()}

    def tenant_stats(self, tenant: str | None = None) -> dict:
        """Per-tenant admission/serving counters (+ QPS)."""
        if tenant is not None:
            return self._tenant_stats[tenant].snapshot()
        return {t: s.snapshot() for t, s in self._tenant_stats.items()}

    def cache_stats(self) -> dict:
        return {"size": 0, "hits": 0, "misses": 0} if self.cache is None \
            else self.cache.stats()
