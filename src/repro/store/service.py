"""StoreService: the query-serving frontend over named collections.

Single queries arrive one at a time (``submit``) and would waste the
vector units if dispatched alone, but XLA recompiles on every new batch
shape — so the service coalesces an **admission queue** into dynamic
micro-batches padded to a small fixed menu of batch shapes:

* a queue drains when it can fill the largest batch shape, when its
  oldest request has waited ``max_wait_ms``, or on ``flush()``;
* the drained requests are padded (zero query rows) up to the smallest
  ``batch_shapes`` entry that fits, so every dispatch hits one of
  ``len(batch_shapes)`` compiled programs per engine;
* results are sliced back per request.  The fixed-schedule search is
  row-independent (every op in ``search_batch_fixed`` maps over the
  query axis), so padding cannot perturb a real request's result — the
  end-to-end test asserts bit-equality against a direct batched call.

Top-k is a *service-level* constant (``default_k``): per-request ``k``
may be any value up to it and is sliced from the service-k result, which
keeps the dispatch shape set closed.  Per-collection stats aggregate
QPS, latency percentiles, padding efficiency, and the per-query probe
stats (radius steps, candidates fetched) from the search engine.

Any object with ``search(Q, k=..., r0=..., steps=..., engine=...,
with_stats=...)`` and ``name`` can be attached — a local
:class:`~repro.store.collection.Collection` or the sharded router
wrapper in :mod:`repro.store.router`.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

import numpy as np

__all__ = ["QueryRequest", "StoreService"]


@dataclasses.dataclass
class QueryRequest:
    """One in-flight query; filled in place when its batch completes."""

    uid: int
    collection: str
    query: np.ndarray  # (d,)
    k: int
    submitted: float
    done: bool = False
    dists: np.ndarray | None = None   # (k,) ascending; +inf = unfilled slot
    ids: np.ndarray | None = None     # (k,) neighbor ids; index.n = sentinel
    payload: object = None            # payload rows when the collection has one
    latency_ms: float = 0.0
    radius_steps: int = 0
    candidates: int = 0


class _CollectionStats:
    def __init__(self):
        self.served = 0
        self.batches = 0
        self.padded_slots = 0
        self.latencies_ms: list[float] = []
        self.radius_steps = 0
        self.candidates = 0
        self.t_first: float | None = None
        self.t_last: float | None = None

    def record_batch(self, reqs, shape, now):
        self.served += len(reqs)
        self.batches += 1
        self.padded_slots += shape - len(reqs)
        if self.t_first is None:
            self.t_first = min(r.submitted for r in reqs)
        self.t_last = now
        for r in reqs:
            self.latencies_ms.append(r.latency_ms)
            self.radius_steps += r.radius_steps
            self.candidates += r.candidates

    def snapshot(self) -> dict:
        lat = np.asarray(self.latencies_ms, np.float64)
        span = (
            (self.t_last - self.t_first)
            if (self.t_first is not None and self.t_last > self.t_first)
            else 0.0
        )
        return {
            "queries": self.served,
            "batches": self.batches,
            "qps": self.served / span if span > 0 else float("nan"),
            "latency_ms_p50": float(np.percentile(lat, 50)) if lat.size else float("nan"),
            "latency_ms_p99": float(np.percentile(lat, 99)) if lat.size else float("nan"),
            "mean_radius_steps": self.radius_steps / max(self.served, 1),
            "mean_candidates": self.candidates / max(self.served, 1),
            "padding_efficiency": (
                self.served / (self.served + self.padded_slots)
                if self.served else float("nan")
            ),
        }


class StoreService:
    """Admission queue + dynamic micro-batching over attached collections."""

    def __init__(
        self,
        *,
        batch_shapes: tuple[int, ...] = (1, 4, 16, 64),
        max_wait_ms: float = 2.0,
        default_k: int = 10,
        r0: float = 1.0,
        steps: int = 8,
        engine: str = "jnp",
    ):
        assert batch_shapes == tuple(sorted(batch_shapes)) and batch_shapes
        self.batch_shapes = batch_shapes
        self.max_wait_ms = max_wait_ms
        self.default_k = default_k
        self.r0 = r0
        self.steps = steps
        self.engine = engine
        self.collections: dict[str, object] = {}
        self._queues: dict[str, deque[QueryRequest]] = {}
        self._stats: dict[str, _CollectionStats] = {}
        self._uid = 0

    # ----------------------------------------------------------------- admin
    def attach(self, collection) -> None:
        """Register a Collection (or any search-compatible object)."""
        self.collections[collection.name] = collection
        self._queues.setdefault(collection.name, deque())
        self._stats.setdefault(collection.name, _CollectionStats())

    def create_collection(self, name: str, key, data, **kw):
        from .collection import Collection

        col = Collection.create(name, key, data, **kw)
        self.attach(col)
        return col

    def drop_collection(self, name: str) -> None:
        if self._queues.get(name):
            raise RuntimeError(f"collection {name!r} has pending requests")
        self.collections.pop(name, None)
        self._queues.pop(name, None)
        self._stats.pop(name, None)

    def __getitem__(self, name: str):
        return self.collections[name]

    # ---------------------------------------------------------------- submit
    def submit(self, collection: str, query, k: int | None = None) -> QueryRequest:
        """Enqueue one query; returns its ticket (filled once dispatched)."""
        if collection not in self.collections:
            raise KeyError(f"unknown collection {collection!r}")
        k = self.default_k if k is None else k
        if k > self.default_k:
            raise ValueError(
                f"k={k} exceeds service default_k={self.default_k}; raise "
                "default_k at construction (k is compiled into the dispatch)"
            )
        req = QueryRequest(
            uid=self._uid,
            collection=collection,
            query=np.asarray(query, np.float32).reshape(-1),
            k=k,
            submitted=time.monotonic(),
        )
        self._uid += 1
        self._queues[collection].append(req)
        return req

    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    # -------------------------------------------------------------- dispatch
    def step(self, force: bool = False) -> int:
        """One scheduler pass: drain every queue that is full enough (or
        whose head request timed out, or everything when ``force``).
        Returns the number of requests dispatched."""
        now = time.monotonic()
        dispatched = 0
        cap = self.batch_shapes[-1]
        for name, queue in self._queues.items():
            while queue:
                timed_out = (now - queue[0].submitted) * 1e3 >= self.max_wait_ms
                if not (force or timed_out or len(queue) >= cap):
                    break
                reqs = [queue.popleft() for _ in range(min(cap, len(queue)))]
                self._dispatch(name, reqs)
                dispatched += len(reqs)
        return dispatched

    def flush(self) -> int:
        """Dispatch everything pending; returns requests served."""
        total = 0
        while self.pending():
            total += self.step(force=True)
        return total

    def _shape_for(self, m: int) -> int:
        for s in self.batch_shapes:
            if s >= m:
                return s
        return self.batch_shapes[-1]

    def _dispatch(self, name: str, reqs: list[QueryRequest]) -> None:
        col = self.collections[name]
        m = len(reqs)
        shape = self._shape_for(m)
        d = reqs[0].query.shape[0]
        Q = np.zeros((shape, d), np.float32)
        for j, r in enumerate(reqs):
            Q[j] = r.query
        dists, ids, stats = col.search(
            Q, k=self.default_k, r0=self.r0, steps=self.steps,
            engine=self.engine, with_stats=True,
        )
        dists = np.asarray(dists)
        ids = np.asarray(ids)
        steps_taken = np.asarray(stats["radius_steps"])
        cands = np.asarray(stats["candidates"])
        # the collection counted the padded batch; only m rows were real
        cstats = getattr(col, "stats", None)
        if cstats is not None:
            cstats.queries -= shape - m
        now = time.monotonic()
        has_payload = getattr(col, "payload", None) is not None
        if has_payload:
            payloads = np.asarray(col.get_payload(ids[:m]))
        for j, r in enumerate(reqs):
            r.dists = dists[j, : r.k]
            r.ids = ids[j, : r.k]
            if has_payload:
                r.payload = payloads[j, : r.k]
            r.radius_steps = int(steps_taken[j])
            r.candidates = int(cands[j])
            r.latency_ms = (now - r.submitted) * 1e3
            r.done = True
        self._stats[name].record_batch(reqs, shape, now)

    # ------------------------------------------------------------ convenience
    def serve(self, collection: str, Q, k: int | None = None):
        """Submit a whole query matrix as single requests, flush, and return
        stacked (dists, ids) — the micro-batching round trip."""
        reqs = [self.submit(collection, q, k=k) for q in np.atleast_2d(Q)]
        self.flush()
        return (
            np.stack([r.dists for r in reqs]),
            np.stack([r.ids for r in reqs]),
            reqs,
        )

    def stats(self, collection: str | None = None) -> dict:
        if collection is not None:
            return self._stats[collection].snapshot()
        return {name: s.snapshot() for name, s in self._stats.items()}
