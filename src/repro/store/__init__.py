"""repro.store — the vector-store service layer over the DB-LSH core.

Module map (and how it relates to the rest of the repo):

* ``lifecycle``   — :class:`CollectionLifecycle`: the placement-
  independent mutable-collection protocol (version bumping, the
  auto-compaction policy templates, payload ride-along, calibration
  invalidation + auto re-fit, snapshot/restore plumbing).  Both
  placements below implement it; :func:`restore_collection` dispatches
  a snapshot directory to the right one from its manifest.

* ``collection``  — :class:`Collection`: the local placement — a named
  DB-LSH index + aligned payload.  Wraps ``core.index.build`` /
  ``core.updates`` (insert/delete/compact) behind the lifecycle hooks
  and persists through ``checkpoint.Checkpointer``
  (``snapshot`` / ``restore``).

* ``service``     — :class:`StoreService`: the request scheduler.
  Per-tenant admission queues (token-bucket quotas, weighted
  round-robin draining) coalesce single queries into micro-batches
  padded to a fixed menu of batch shapes (one XLA program per shape),
  issued *overlapped* — the device executes batch i while the host pads
  batch i+1, up to ``inflight_depth`` deep — through
  ``core.serve_search.search_batch_fixed`` with engine selection
  (``jnp`` | ``kernel`` | ``inline``).  Aggregates per-collection QPS /
  latency-percentile / probe-effort / cache / overlap stats and
  per-tenant admission stats.

* ``cache``       — :class:`QueryResultCache`: LRU over
  (collection, version, query, k, engine, r0, steps).  Collection
  mutations bump the version, so invalidation is by construction; see
  DESIGN.md §6 for the contract.

* ``router``      — :class:`ShardedCollection` + :func:`open_collection`:
  the sharded placement over ``core.distributed.ShardedDBLSH``
  (per-device local indexes, replicated queries, global-id top-k merge)
  for datasets too large for one device — the *same* mutable lifecycle
  as a local collection (least-loaded insert routing, per-shard delete
  translation, per-shard compaction with a gathered global id remap,
  sharded snapshot/restore); the router picks local vs sharded
  placement and the lifecycle options apply to either.

Relation to neighbors:

* ``core.distributed`` stays the *mechanism* (shard_map build/search);
  ``store.router`` is the *policy* wrapper that gives it the Collection
  API so the service can serve local and sharded data uniformly.
* ``serve.retrieval`` (kNN-LM) is now a thin client: its ``Datastore``
  holds a Collection whose payload is the next-token values, so the LM
  retrieval head inherits updates, compaction, and persistence for free.
* ``repro.tune`` supplies query *planning*: a Collection carries a
  ``search_policy`` and a persisted calibration table
  (``Collection.calibrate``), and the service resolves
  submit-time policies / ``recall_target=`` through the planner into a
  concrete (r0, steps, adaptive-termination) plan per request —
  request > collection > service, like engine defaults (DESIGN.md §8).

Typical use::

    from repro.store import Collection, StoreService

    col = Collection.create("docs", jax.random.key(0), data, c=1.5, k=10)
    svc = StoreService(batch_shapes=(1, 8, 32), default_k=10, r0=0.5)
    svc.attach(col)
    ticket = svc.submit("docs", q)     # single query -> micro-batched
    svc.flush()
    print(ticket.dists, ticket.ids, svc.stats("docs"))
"""

from .cache import CachedResult, QueryResultCache
from .collection import Collection
from .lifecycle import (
    CollectionLifecycle,
    CollectionStats,
    CompactionPolicy,
    restore_collection,
    version_clock,
)
from .router import ShardedCollection, open_collection
from .service import (
    BrownoutShed,
    DeadlineExceeded,
    DispatchFailed,
    QueryRequest,
    QuotaExceeded,
    StoreService,
    TenantQuota,
)

__all__ = [
    "BrownoutShed",
    "CachedResult",
    "Collection",
    "CollectionLifecycle",
    "CollectionStats",
    "CompactionPolicy",
    "DeadlineExceeded",
    "DispatchFailed",
    "QueryRequest",
    "QueryResultCache",
    "QuotaExceeded",
    "ShardedCollection",
    "StoreService",
    "TenantQuota",
    "open_collection",
    "restore_collection",
    "version_clock",
]
