"""The collection lifecycle protocol: one mutable contract, two placements.

Historically ``Collection`` (single-device) owned the whole lifecycle —
add / remove / auto-compaction / calibrate / snapshot / restore — while
``ShardedCollection`` was a build-once read replica.  That split leaked
"sharded is different" branches everywhere a collection was consumed.
This module extracts the placement-independent machinery into
:class:`CollectionLifecycle`, which both placements now implement:

* **version bumping** — every mutation draws a fresh version from the
  process-wide :data:`version_clock`, the cache-invalidation token the
  service layer keys on (DESIGN.md §6);
* **compaction accounting** — :class:`CompactionPolicy` triggers
  (growth past the built K/L sizing, hollowness from tombstones) and the
  ``add``/``remove``/``compact`` templates that apply them;
* **payload ride-along** — payload rows stay aligned through inserts
  and are permuted through the compaction id map (scatter by new id, so
  placements whose id space has per-shard padding holes work the same
  as the dense local layout);
* **calibration** — :meth:`calibrate` fits and stores the
  ``repro.tune`` schedule table; ``compact`` *invalidates* it (the
  rebuild re-derives K/L and reshapes the recall/cost curves) and
  auto-refits when the calibration queries were retained
  (``calibrate(..., retain=True)``) — the ROADMAP auto re-calibration
  hook;
* **snapshot / restore plumbing** — one manifest layout for both
  placements (``meta["placement"]`` tags which), persisting index
  arrays, payload, PRNG key, policy, counters, version, engine default,
  search policy, and schedule table through
  ``checkpoint.Checkpointer``'s atomic step directories.

Placements supply only the index mechanics, via the ``_insert`` /
``_delete`` / ``_compact_impl`` / ``_calibrate_impl`` /
``_snapshot_arrays`` / ``_snapshot_meta`` hooks plus the ``n`` / ``d`` /
``live_count`` / ``search`` surface.  :func:`restore_collection`
dispatches a snapshot directory to the right placement class from the
manifest alone.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import Checkpointer, CorruptSnapshot
from ..core import validate_engine
from ..obs.trace import get_tracer
from ..tune import planner as _planner
from ..tune.planner import ScheduleTable
from ..tune.policy import (
    ResolvedPlan,
    policy_from_dict,
    policy_to_dict,
    resolve_policy,
)

__all__ = [
    "CollectionLifecycle",
    "CompactionPolicy",
    "CollectionStats",
    "restore_collection",
    "version_clock",
]


class _VersionClock:
    """Process-wide monotonic source of collection versions.

    A plain per-collection counter would alias: two collections restored
    from the same snapshot both sit at version v yet may diverge, and a
    cache keyed on (name, v) would serve one the other's results.  A
    single process-wide clock makes every (mutation, restore) event
    globally unique, so version equality implies state equality.
    """

    def __init__(self):
        self._v = 0

    def next(self) -> int:
        self._v += 1
        return self._v

    def advance_past(self, v: int) -> int:
        """A fresh version strictly greater than both ``v`` and anything
        already handed out (used by restore)."""
        self._v = max(self._v, int(v))
        return self.next()


version_clock = _VersionClock()

_INDEX_ARRAY_FIELDS = (
    "proj_vecs",
    "proj_blocks",
    "ids_blocks",
    "mbr_lo",
    "mbr_hi",
    "data",
    "vec_blocks",
    "norm_blocks",
)


@dataclasses.dataclass(frozen=True)
class CompactionPolicy:
    """When to rebuild. ``auto=False`` disables the triggers (manual
    ``compact()`` still works)."""

    growth_ratio: float = 2.0    # compact when n >= ratio * last-built n
    min_live_ratio: float = 0.5  # compact when live/n drops below this
    auto: bool = True


@dataclasses.dataclass
class CollectionStats:
    inserted: int = 0
    deleted: int = 0
    compactions: int = 0
    queries: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class CollectionLifecycle:
    """Placement-independent collection lifecycle (see module doc).

    Subclasses set their index state *before* calling ``__init__`` (the
    payload-alignment assert reads ``self.n``) and implement the
    placement hooks listed in the module docstring.
    """

    #: manifest tag restore dispatches on ("local" | "sharded")
    placement = "local"

    def __init__(
        self,
        name: str,
        *,
        payload: jax.Array | np.ndarray | None = None,
        policy: CompactionPolicy | None = None,
        key: jax.Array | None = None,
        built_n: int | None = None,
        stats: CollectionStats | None = None,
        version: int | None = None,
        engine: str | None = None,
        search_policy=None,
        calibration: ScheduleTable | None = None,
    ):
        if payload is not None:
            payload = jnp.asarray(payload)
            assert payload.shape[0] == self.id_space, (
                payload.shape, self.id_space,
            )
        self.name = name
        self.payload = payload
        self.policy = policy or CompactionPolicy()
        self._key = jax.random.key(0) if key is None else key
        self.built_n = self.n if built_n is None else built_n
        self.stats = stats or CollectionStats()
        self.version = version_clock.next() if version is None else version
        # per-collection verify-engine default: used whenever a search /
        # service dispatch doesn't name one explicitly (None = defer to
        # the caller's default); validation is placement-specific
        self.default_engine = self._validate_default_engine(engine)
        # per-collection query-planning default (repro.tune policy): used
        # by StoreService's plan resolution whenever a submit doesn't
        # name a policy (request > collection > service); the calibration
        # table backs RecallTarget/LatencyBudget planning and persists
        # through snapshot/restore.
        self.search_policy = search_policy
        self.calibration = calibration
        self._calib_queries: np.ndarray | None = None
        self._calib_kw: dict = {}

    # -------------------------------------------------------- placement hooks
    def _validate_default_engine(self, engine: str | None) -> str | None:
        if engine is not None:
            validate_engine(engine)
        return engine

    def _insert(self, points, payload) -> np.ndarray:
        """Grow the index (and payload) by ``points``; return their
        global ids (pre-compaction)."""
        raise NotImplementedError

    def _delete(self, ids) -> None:
        """Tombstone global ``ids`` in the index."""
        raise NotImplementedError

    def _compact_impl(self, key) -> np.ndarray:
        """Rebuild the index from survivors with ``key``; return the
        global id map (n_old,): old id -> new id, or -1 if deleted.  New
        ids must ascend with old ids so the payload permute in
        :meth:`compact` stays order-preserving."""
        raise NotImplementedError

    def _calibrate_impl(self, queries, **kw) -> ScheduleTable:
        raise NotImplementedError

    def _snapshot_arrays(self) -> dict:
        """Host copies of the index arrays, keyed by field name."""
        raise NotImplementedError

    def _snapshot_meta(self) -> dict:
        """Placement-specific manifest entries (params + layout)."""
        raise NotImplementedError

    def live_count(self) -> int:
        raise NotImplementedError

    @property
    def id_space(self) -> int:
        """Exclusive upper bound of the global id space — every id that
        ``add`` or ``search`` returns is below it, and the payload buffer
        has exactly this many rows.  Dense placements equal ``n``;
        strided (sharded) placements leave per-shard insert headroom, so
        it can exceed ``n``."""
        return self.n

    # ----------------------------------------------------------------- writes
    def add(self, points, payload=None) -> np.ndarray:
        """Insert ``points`` (m, d); returns their ids (post-compaction
        ids if the policy fired)."""
        points = jnp.atleast_2d(jnp.asarray(points, jnp.float32))
        if (payload is None) != (self.payload is None):
            raise ValueError(
                f"collection {self.name!r}: payload must be provided iff the "
                "collection carries one"
            )
        if payload is not None:
            payload = jnp.asarray(payload)
            if payload.shape[0] != points.shape[0]:
                raise ValueError(
                    f"collection {self.name!r}: payload rows "
                    f"({payload.shape[0]}) != inserted points "
                    f"({points.shape[0]})"
                )
        # lifecycle mutations record on the process-global trace timeline
        # (TID_LIFECYCLE lane), so a serving-stack trace shows mutations
        # interleaved with the batches they invalidate
        with get_tracer().span(
            "lifecycle.add", cat="lifecycle", collection=self.name,
            placement=self.placement, rows=int(points.shape[0]),
        ) as sp:
            ids = self._insert(points, payload)
            self.stats.inserted += int(points.shape[0])
            self.version = version_clock.next()
            sp.set(version=self.version)
            id_map = self._maybe_compact()
            if id_map is not None:
                ids = id_map[ids]
                sp.set(compacted=True)
        return ids

    def remove(self, ids) -> np.ndarray | None:
        """Tombstone ``ids``; space is reclaimed at the next compaction.

        Returns the compaction id map (old id -> new id, -1 if deleted)
        when the policy fired — every outstanding id must be remapped
        through it — or None when no compaction happened."""
        ids = jnp.atleast_1d(jnp.asarray(ids, jnp.int32))
        with get_tracer().span(
            "lifecycle.remove", cat="lifecycle", collection=self.name,
            placement=self.placement, rows=int(ids.shape[0]),
        ) as sp:
            self._delete(ids)
            self.stats.deleted += int(ids.shape[0])
            self.version = version_clock.next()
            sp.set(version=self.version)
            id_map = self._maybe_compact()
            if id_map is not None:
                sp.set(compacted=True)
        return id_map

    # ------------------------------------------------------------- compaction
    def _occupancy(self) -> tuple[int, int]:
        """``(live, attainable_n)`` from one device read — the live
        point count and the smallest ``n`` a :meth:`compact` could reach
        right now.  Local compaction shrinks to the live count; sharded
        placements floor at ``P * max_shard(live)`` (SPMD shapes stay
        uniform, so per-shard padding under the fleet max is
        structural)."""
        live = self.live_count()
        return live, live

    def should_compact(self) -> bool:
        n = self.n
        if n >= self.policy.growth_ratio * self.built_n and n > self.built_n:
            return True
        live, attainable = self._occupancy()
        if live >= self.policy.min_live_ratio * n:
            return False
        # hollow — but only rebuild if compaction can actually shrink the
        # index: a sharded fleet whose imbalance (not tombstones) causes
        # the low live ratio would otherwise re-trigger on every mutation
        # and thrash through full rebuilds that change nothing
        return attainable < n

    def compact(self) -> np.ndarray:
        """Rebuild now. Returns id_map (n_old,): old id -> new id or -1.

        Invalidates the fitted schedule table (the rebuild re-derives
        K/L, which shifts the recall/cost curves) and re-fits it when
        the calibration queries were retained (``calibrate(...,
        retain=True)``)."""
        with get_tracer().span(
            "lifecycle.compact", cat="lifecycle", collection=self.name,
            placement=self.placement, n_before=int(self.n),
        ) as sp:
            id_map = self._compact_traced(sp)
        return id_map

    def _compact_traced(self, sp) -> np.ndarray:
        self._key, kc = jax.random.split(self._key)
        id_map = np.asarray(self._compact_impl(kc))
        if self.payload is not None:
            live_old = np.flatnonzero(id_map >= 0)
            pay = np.asarray(self.payload)
            # scatter each surviving row to its new id: for the dense
            # local layout this is exactly the ascending gather
            # pay[live_old]; strided/sharded layouts leave per-shard
            # padding and headroom holes, which stay zero and are never
            # returned (their ids are tombstoned or unallocated).
            buf = np.zeros((self.id_space,) + pay.shape[1:], pay.dtype)
            buf[id_map[live_old]] = pay[live_old]
            self.payload = jnp.asarray(buf)
        self.built_n = self.n
        self.stats.compactions += 1
        self.version = version_clock.next()
        sp.set(n_after=int(self.n), version=self.version)
        if self.calibration is not None or self._calib_queries is not None:
            self.calibration = None  # stale: K/L and block geometry changed
            if self._calib_queries is not None:
                self.calibrate(self._calib_queries, retain=True,
                               **self._calib_kw)
        return id_map

    def _maybe_compact(self) -> np.ndarray | None:
        if self.policy.auto and self.should_compact():
            return self.compact()
        return None

    # ----------------------------------------------------------- planning
    def calibrate(
        self,
        queries,
        *,
        k: int = 0,
        r0: float | None = None,
        steps_max: int = 8,
        engine: str | None = None,
        interpret: bool | None = None,
        measure_ms: bool = False,
        retain: bool = False,
    ) -> ScheduleTable:
        """Fit (and store) the collection's schedule table from a
        held-out query sample — the planner backing for outcome-level
        policies.  The table persists through :meth:`snapshot` /
        :meth:`restore`.  With ``retain=True`` the queries (and fit
        settings) are kept host-side and :meth:`compact` re-fits the
        table automatically after every rebuild; without it, compaction
        just invalidates (re-run calibrate by hand).  Retained queries
        do not ride in snapshots — only the fitted table does."""
        kw = dict(k=k, r0=r0, steps_max=steps_max, engine=engine,
                  interpret=interpret, measure_ms=measure_ms)
        with get_tracer().span(
            "lifecycle.calibrate", cat="lifecycle", collection=self.name,
            placement=self.placement, steps_max=steps_max,
        ):
            table = self._calibrate_impl(queries, **kw)
        self.calibration = table
        if retain:
            self._calib_queries = np.asarray(queries, np.float32)
            self._calib_kw = kw
        return table

    def plan(self, policy=None, *, default_r0: float = 1.0,
             default_steps: int = 8) -> ResolvedPlan:
        """Resolve a query-planning policy (explicit > collection
        default) against the stored calibration into the concrete
        (r0, steps, termination) the dispatch runs."""
        return _planner.plan(
            self.calibration,
            resolve_policy(policy, self.search_policy),
            default_r0=default_r0, default_steps=default_steps,
        )

    # ------------------------------------------------------------------ reads
    def _count_queries(self, Q, rows: int | None) -> None:
        self.stats.queries += int(Q.shape[0]) if rows is None else int(rows)

    def get_payload(self, ids):
        """Payload rows for returned neighbor ids.

        Out-of-range ids clamp on *both* ends: the unfilled-slot sentinel
        (``id_space``) clamps to the last payload row and a negative id
        (e.g. -1 from a compaction id map marking a deleted point) clamps
        to row 0 instead of silently wrapping to the tail.  Clamped rows
        are arbitrary, not an error — always mask on the distances (+inf
        marks unfilled slots) or on ``id_map >= 0``, not on ids."""
        if self.payload is None:
            raise ValueError(f"collection {self.name!r} has no payload")
        ids = jnp.asarray(ids)
        return jnp.take(
            self.payload, jnp.clip(ids, 0, self.payload.shape[0] - 1), axis=0
        )

    # ------------------------------------------------------------ persistence
    def snapshot(self, directory: str, step: int | None = None) -> int:
        """Atomic checkpoint via Checkpointer; returns the step written.
        Defaults to one past the latest step already in ``directory`` so
        successive snapshots never overwrite each other (Checkpointer
        keeps the most recent few and GCs the rest)."""
        with get_tracer().span(
            "lifecycle.snapshot", cat="lifecycle", collection=self.name,
            placement=self.placement,
        ) as sp:
            step = self._snapshot_traced(directory, step, sp)
        return step

    def _snapshot_traced(self, directory, step, sp) -> int:
        ck = Checkpointer(directory)
        if step is None:
            latest = ck.latest_step()
            step = 0 if latest is None else latest + 1
        sp.set(step=step)
        tree = dict(self._snapshot_arrays())
        tree["prng_key"] = np.asarray(jax.random.key_data(self._key))
        if self.payload is not None:
            tree["payload"] = np.asarray(self.payload)
        meta = {
            "name": self.name,
            "placement": self.placement,
            "policy": dataclasses.asdict(self.policy),
            "built_n": self.built_n,
            "stats": self.stats.as_dict(),
            "has_payload": self.payload is not None,
            "version": self.version,
            "engine": self.default_engine,
            "search_policy": policy_to_dict(self.search_policy),
            "calibration": (
                None if self.calibration is None else self.calibration.to_dict()
            ),
            **self._snapshot_meta(),
        }
        ck.save(step, tree, meta)
        return step

    @staticmethod
    def _common_restore_kwargs(tree, meta) -> dict:
        """The lifecycle half of a restore: everything except the index
        arrays themselves.  The version is deliberately *fresh* — past
        both the persisted one and everything the process has handed out
        — so two collections diverging from one snapshot (or a restore
        racing live updates) can never alias each other's cache entries
        (DESIGN.md §6)."""
        return dict(
            payload=(
                jnp.asarray(tree["payload"]) if meta["has_payload"] else None
            ),
            policy=CompactionPolicy(**meta["policy"]),
            key=jax.random.wrap_key_data(jnp.asarray(tree["prng_key"])),
            built_n=meta["built_n"],
            stats=CollectionStats(**meta["stats"]),
            version=version_clock.advance_past(meta.get("version", 0)),
            engine=meta.get("engine"),
            search_policy=policy_from_dict(meta.get("search_policy")),
            calibration=(
                ScheduleTable.from_dict(meta["calibration"])
                if meta.get("calibration") else None
            ),
        )


def restore_collection(directory: str, step: int | None = None, *, mesh=None):
    """Restore whichever placement a snapshot holds.

    Reads the manifest alone (no array loads) to dispatch: local
    snapshots return a :class:`~repro.store.collection.Collection`;
    sharded ones need ``mesh=`` and return a
    :class:`~repro.store.router.ShardedCollection` placed on it — on any
    shard count: a mesh differing from the snapshot's triggers the
    elastic migration path (see ``ShardedCollection.restore``).

    Crash safety: with ``step=None`` this walks the directory's steps
    newest-first (the ``LATEST`` designee first) and falls back past any
    snapshot that fails integrity verification (torn write, bit-rot,
    garbled manifest — :class:`~repro.checkpoint.CorruptSnapshot`) to
    the newest step that restores cleanly.  An explicit ``step`` is
    strict: its corruption propagates."""
    ck = Checkpointer(directory)
    candidates = ck._candidate_steps(step)
    if not candidates:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    last_err: Exception | None = None
    for s in candidates:
        try:
            meta, s = ck.read_meta(s)
            if meta.get("placement", "local") == "sharded":
                if mesh is None:
                    raise ValueError(
                        f"snapshot at {directory!r} is sharded "
                        f"({meta.get('shards')} shards): pass mesh= to place it"
                    )
                from .router import ShardedCollection

                return ShardedCollection.restore(directory, mesh=mesh, step=s)
            from .collection import Collection

            return Collection.restore(directory, s)
        except (CorruptSnapshot, FileNotFoundError, OSError) as e:
            last_err = e
            if step is not None:
                raise
    raise last_err
