"""Training substrate: optimizers, train step, gradient compression, loop."""
from .optimizer import adafactor, adamw, cosine_schedule, make_optimizer, wsd_schedule
from .train_step import init_train_state, make_train_step
