"""Train step assembly: loss -> grads -> (optional compressed pod
reduction) -> optimizer update.

Two flavors:

* plain pjit step — gradients are reduced by XLA SPMD across all data
  axes (pod included); simplest graph, fp32/bf16 all-reduce on the wire.
* ``compress_pods=True`` — the step is shard_mapped manually over the
  'pod' axis only (data/model stay automatic); the pod-axis reduction
  runs through ``grad_compression.compressed_pmean`` (int8 + error
  feedback). This is the §Perf 'collective' lever for multi-pod.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from . import grad_compression as gc

__all__ = ["make_train_step", "init_train_state"]


def init_train_state(model, opt, key, compress_pods=False):
    params = model.init(key)
    state = {"params": params, "opt": opt.init(params), "step": jnp.zeros((), jnp.int32)}
    if compress_pods:
        state["err"] = gc.init_error_state(params)
    return state


def make_train_step(model, opt, mesh=None, compress_pods=False, accum_steps=1):
    """Returns step(state, batch) -> (state, metrics).

    accum_steps > 1: gradient-accumulation microbatching — the global
    batch is split into `accum_steps` scanned microbatches; activation
    peak memory drops ~proportionally (the lever that fits the 480B/1T
    archs on 16 GiB HBM). Gradients accumulate in the parameter dtype.
    """

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch, mesh)
        return loss, metrics

    def grads_of(params, batch):
        if accum_steps == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
            return loss, grads

        mb = jax.tree.map(
            lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps) + x.shape[1:]),
            batch,
        )

        def body(carry, mbatch):
            g_acc, l_acc = carry
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mbatch)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g)
            return (g_acc, l_acc + loss), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
        (g_sum, l_sum), _ = jax.lax.scan(body, (g0, jnp.zeros(())), mb)
        inv = 1.0 / accum_steps
        return l_sum * inv, jax.tree.map(lambda g: g * inv, g_sum)

    def plain_step(state, batch):
        loss, grads = grads_of(state["params"], batch)
        new_params, new_opt, stats = opt.update(grads, state["opt"], state["params"])
        return (
            {"params": new_params, "opt": new_opt, "step": state["step"] + 1},
            {"loss": loss, **stats},
        )

    if not compress_pods:
        return plain_step

    assert mesh is not None and "pod" in mesh.axis_names, "compress_pods needs a pod axis"

    def pod_step(state, batch):
        loss, grads = grads_of(state["params"], batch)
        # int8 error-feedback exchange across pods
        grads, new_err = gc.compressed_pmean(grads, "pod", state["err"])
        loss = jax.lax.pmean(loss, "pod")
        new_params, new_opt, stats = opt.update(grads, state["opt"], state["params"])
        return (
            {
                "params": new_params,
                "opt": new_opt,
                "step": state["step"] + 1,
                "err": new_err,
            },
            {"loss": loss, "lr": stats.get("lr", jnp.zeros(())),
             "grad_norm": stats.get("grad_norm", jnp.zeros(()))},
        )

    # manual over 'pod' only; data/model remain automatically partitioned.
    rep = P()  # params/opt replicated across pods (sharded over data/model by SPMD)

    def step(state, batch):
        state_specs = jax.tree.map(lambda _: rep, state)
        bspecs = jax.tree.map(lambda _: P("pod"), batch)
        mspecs = {"loss": rep, "lr": rep, "grad_norm": rep}
        return shard_map(
            pod_step,
            mesh=mesh,
            in_specs=(state_specs, bspecs),
            out_specs=(state_specs, mspecs),
            axis_names={"pod"},
        )(state, batch)

    return step
