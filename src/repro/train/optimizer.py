"""Optimizers (no optax in this container): AdamW, Adafactor, schedules.

Functional API:
    opt = make_optimizer(cfg_or_name, lr_schedule)
    state = opt.init(params)
    params, state, stats = opt.update(grads, state, params)

AdamW keeps fp32 (m, v); Adafactor keeps factored second moments
(row/col vectors for matrices) — the memory-viable choice for the
480B/1T MoE archs (see DESIGN.md §6).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------


def cosine_schedule(peak_lr, warmup, total):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)

    return lr


def wsd_schedule(peak_lr, warmup, stable, decay, floor=0.1):
    """Warmup-Stable-Decay (MiniCPM, arXiv:2404.06395): linear warmup,
    long flat stage, short exponential-ish decay to floor*peak."""

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        dec_frac = jnp.clip((step - warmup - stable) / max(decay, 1), 0.0, 1.0)
        dec = peak_lr * jnp.power(floor, dec_frac)
        return jnp.where(step < warmup, warm, jnp.where(step < warmup + stable, peak_lr, dec))

    return lr


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params) -> (params, state, stats)


def adamw(lr_fn, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1, clip_norm=1.0):
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, clip_norm / (gnorm + 1e-9)) if clip_norm else 1.0
        lr = lr_fn(step)
        b1c = 1.0 - b1 ** step.astype(jnp.float32)
        b2c = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mh = m / b1c
            vh = v / b2c
            delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, {"step": step, "m": new_m, "v": new_v}, {"grad_norm": gnorm, "lr": lr}

    return Optimizer(init, update)


def adafactor(lr_fn, eps=1e-30, clip_threshold=1.0, decay_rate=0.8,
              weight_decay=0.0, min_dim_size_to_factor=32):
    """Adafactor (Shazeer & Stern): factored 2nd moments, no 1st moment."""

    def _factored(p):
        return p.ndim >= 2 and p.shape[-1] >= min_dim_size_to_factor and p.shape[-2] >= min_dim_size_to_factor

    def init(params):
        def one(p):
            if _factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"step": jnp.zeros((), jnp.int32), "v": jax.tree.map(one, params, is_leaf=lambda x: isinstance(x, jax.Array))}

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        beta2 = 1.0 - jnp.power(t, -decay_rate)
        lr = lr_fn(step)

        def upd(g, v, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(p):
                vr = beta2 * v["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * v["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = jnp.sqrt(
                    vr[..., :, None] * vc[..., None, :] / jnp.maximum(
                        jnp.mean(vr, axis=-1, keepdims=True)[..., None], eps
                    )
                )
                u = g / jnp.maximum(denom, eps)
                nv = {"vr": vr, "vc": vc}
            else:
                vv = beta2 * v["v"] + (1 - beta2) * g2
                u = g / jnp.sqrt(vv + eps)
                nv = {"v": vv}
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), nv

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_v = treedef.unflatten([o[1] for o in out])
        return new_p, {"step": step, "v": new_v}, {"lr": lr}

    return Optimizer(init, update)


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def make_optimizer(name: str, lr_fn=None):
    lr_fn = lr_fn or cosine_schedule(3e-4, 100, 10_000)
    if name == "adamw":
        return adamw(lr_fn)
    if name == "adafactor":
        return adafactor(lr_fn)
    if name == "adamw_wsd":
        return adamw(wsd_schedule(3e-4, 100, 8_000, 1_900))
    raise ValueError(name)
