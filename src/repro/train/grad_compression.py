"""Error-feedback int8 gradient compression for the cross-pod all-reduce.

Cross-pod ICI/DCN links are the scarcest bandwidth in a multi-pod mesh.
Per-pod gradients are block-quantized to int8 with an fp32 per-block
scale (8.125 bits/element vs 16 for bf16 -> ~2x wire reduction), the
codes+scales are exchanged with an ``all_gather`` over the pod axis, and
each pod dequantizes and sums locally. The quantization residual is fed
back into the next step's gradient (error feedback keeps convergence
unbiased — Karimireddy et al., ICML 2019).

Used by ``train_step.make_train_step(compress_pods=True)``: the step is
shard_mapped *manually over the pod axis only* (data/model stay under
automatic SPMD) so the compressed exchange is explicit in the HLO — the
dry-run's collective-bytes parse sees int8 all-gathers instead of fp32
all-reduces on the pod axis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def quantize(x):
    """x -> (int8 codes (nb, BLOCK), fp32 scales (nb,), residual like x)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    residual = (blocks - deq).reshape(-1)[: x.size].reshape(x.shape)
    return q, scale[:, 0], residual


def dequantize(q, scale, shape):
    deq = q.astype(jnp.float32) * scale[:, None]
    size = 1
    for s in shape:
        size *= s
    return deq.reshape(-1)[:size].reshape(shape)


def compressed_pmean(tree, axis_name, err_state):
    """Error-feedback int8 mean-reduction over ``axis_name``.

    tree: gradient pytree (local to this pod). err_state: residual pytree
    carried across steps. Returns (reduced_tree, new_err_state)."""
    npods = jax.lax.psum(1, axis_name)

    def one(g, err):
        g = g.astype(jnp.float32) + err
        q, scale, residual = quantize(g)
        q_all = jax.lax.all_gather(q, axis_name)  # (P, nb, BLOCK) int8 wire
        s_all = jax.lax.all_gather(scale, axis_name)  # (P, nb) fp32 wire
        total = jnp.einsum(
            "pbk,pb->bk", q_all.astype(jnp.float32), s_all
        )
        size = g.size
        out = total.reshape(-1)[:size].reshape(g.shape) / npods
        return out, residual

    flat, treedef = jax.tree.flatten(tree)
    flat_err = treedef.flatten_up_to(err_state)
    outs = [one(g, e) for g, e in zip(flat, flat_err)]
    return (
        treedef.unflatten([o[0] for o in outs]),
        treedef.unflatten([o[1] for o in outs]),
    )


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
