"""DB-LSH index construction (paper §IV-B), TPU-adapted.

The paper indexes each of the L K-dimensional projected spaces with a
bulk-loaded R*-tree.  Pointer-chasing trees are hostile to TPUs, so we
keep the *contract* (window queries at query-chosen widths over
un-quantized projections) and swap the *structure* for a dense
Sort-Tile-Recursive (STR) packed block index — the same bulk-loading
family the paper uses, with the tree levels flattened into dense arrays:

  * per table, points are STR-ordered (dim-0 slabs, dim-1 within a slab)
    and grouped into fixed blocks of ``B`` points;
  * each block stores its K-dim minimum bounding rectangle (MBR) in two
    dense ``(nb, K)`` arrays — the "leaf level" of the R*-tree;
  * a window query tests *all* MBRs with one vectorized compare (VPU,
    ``nb = n/B`` lanes), compacts the first ``M`` overlapping blocks with
    a fixed-capacity sort-compaction, and streams those blocks through
    the verifier.

See DESIGN.md §3 for the fidelity argument.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

from . import hashing
from .params import DBLSHParams

__all__ = ["DBLSHIndex", "build", "compute_norm_blocks", "quantize_blocks"]


def compute_norm_blocks(data: jax.Array, ids_blocks: jax.Array) -> jax.Array:
    """Per-slot squared norms ||x||^2 aligned with ``ids_blocks``.

    Padded / tombstoned slots (id >= n) get +inf so the MXU distance form
    ||x||^2 - 2<q,x> + ||q||^2 masks them without an id compare."""
    n = data.shape[0]
    norms = jnp.sum(jnp.square(data), axis=-1)  # (n,)
    return jnp.take(
        norms, ids_blocks, axis=0, mode="fill", fill_value=jnp.inf
    ).astype(jnp.float32)


def quantize_blocks(
    data: jax.Array, ids_blocks: jax.Array, quant_dtype: str
) -> tuple[jax.Array, jax.Array]:
    """Quantized per-table vector blocks for the reduced-precision dot.

    Returns ``(qvec_blocks, qvec_scale)`` slot-aligned with ``ids_blocks``:

      * ``bf16``: blocks cast to bfloat16, scale all-ones (unused);
      * ``int8``: per-slot symmetric quantization ``round(x / s)`` with
        ``s = amax(|x|) / 127`` (``s = 1`` on all-zero rows), so the
        approximate dot is ``s_slot * s_q * <qx, qq>``.

    Quantization is a pure deterministic function of ``data`` — snapshots
    persist the fp32 truth and restore paths re-derive these (same pattern
    as ``compute_norm_blocks``).  Padded / tombstoned slots (id >= n)
    gather the zero fill, contributing a zero dot; admission and the final
    re-rank mask them exactly, so no sentinel is needed here."""
    if quant_dtype not in ("bf16", "int8"):
        raise ValueError(f"quant_dtype must be 'bf16' or 'int8', got {quant_dtype!r}")
    x = jnp.take(data, ids_blocks, axis=0, mode="fill", fill_value=0.0)
    if quant_dtype == "bf16":
        q = x.astype(jnp.bfloat16)
        scale = jnp.ones(ids_blocks.shape, jnp.float32)
        return q, scale
    amax = jnp.max(jnp.abs(x), axis=-1)  # (L, nb, B)
    scale = jnp.where(amax > 0.0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scale[..., None]), -127.0, 127.0).astype(jnp.int8)
    return q, scale


def empty_quant_blocks(dtype) -> tuple[jax.Array, jax.Array]:
    """Placeholder (empty) quantized fields for quant_dtype='none'."""
    del dtype
    return jnp.zeros((0,), jnp.int8), jnp.zeros((0,), jnp.float32)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "proj_vecs",
        "proj_blocks",
        "ids_blocks",
        "mbr_lo",
        "mbr_hi",
        "data",
        "vec_blocks",
        "norm_blocks",
        "qvec_blocks",
        "qvec_scale",
    ],
    meta_fields=["params"],
)
@dataclasses.dataclass
class DBLSHIndex:
    """The (K, L)-index with dynamic bucketing support.

    Shapes (B = params.block_size, nb = ceil(n / B)):
      proj_vecs:   (L, K, d)      the LSH functions a_ij (Eq. 3)
      proj_blocks: (L, nb, B, K)  STR-ordered projections, +inf padded
      ids_blocks:  (L, nb, B)     original point ids, n-padded
      mbr_lo/hi:   (L, nb, K)     per-block K-dim bounding boxes
      data:        (n, d)         the dataset ('gather' verify layout)
      vec_blocks:  (L, nb, B, d)  optional per-table reordered vectors
                                  ('inline' streaming layout), else ()
      norm_blocks: (L, nb, B)     per-slot squared L2 norms ||x||^2,
                                  slot-aligned with ids_blocks (+inf on
                                  padded / tombstoned slots) — the MXU
                                  verify form ||x||^2 - 2<q,x> + ||q||^2
                                  reads these instead of re-reducing d
                                  diff lanes per candidate per radius
      qvec_blocks: (L, nb, B, d)  quantized per-table vectors (bf16/int8)
                                  for the reduced-precision distance path,
                                  else () when params.quant_dtype='none'
      qvec_scale:  (L, nb, B)     per-slot dequantization scales (f32),
                                  all-ones for bf16, else ()
    """

    proj_vecs: jax.Array
    proj_blocks: jax.Array
    ids_blocks: jax.Array
    mbr_lo: jax.Array
    mbr_hi: jax.Array
    data: jax.Array
    vec_blocks: jax.Array
    norm_blocks: jax.Array
    qvec_blocks: jax.Array
    qvec_scale: jax.Array
    params: DBLSHParams

    @property
    def n(self) -> int:
        return self.data.shape[0]

    @property
    def nb(self) -> int:
        return self.proj_blocks.shape[1]

    def memory_bytes(self) -> int:
        tot = 0
        for f in (
            self.proj_vecs,
            self.proj_blocks,
            self.ids_blocks,
            self.mbr_lo,
            self.mbr_hi,
            self.vec_blocks,
            self.norm_blocks,
            self.qvec_blocks,
            self.qvec_scale,
        ):
            tot += f.size * f.dtype.itemsize
        return tot


def _str_order(proj_t: jax.Array, block_size: int) -> jax.Array:
    """STR ordering for one table: sort by dim-0 into slabs, then by dim-1
    within each slab. Returns the permutation (n,) of original point ids."""
    n, K = proj_t.shape
    nb = -(-n // block_size)
    n_slabs = max(1, int(math.ceil(math.sqrt(nb))))
    slab_pts = -(-n // n_slabs)
    rank0 = jnp.argsort(jnp.argsort(proj_t[:, 0]))
    slab = rank0 // slab_pts
    key2 = proj_t[:, 1] if K > 1 else proj_t[:, 0]
    # lexsort: last key is primary.
    return jnp.lexsort((key2, slab))


def build(key: jax.Array, data: jax.Array, params: DBLSHParams) -> DBLSHIndex:
    """Indexing phase (paper §IV-B): project into L K-dim spaces (Eq. 7),
    then bulk-load one dense STR index per space."""
    params = params.resolve()
    n, d = data.shape
    assert n == params.n and d == params.d, (data.shape, params)
    B, K, L = params.block_size, params.K, params.L
    nb = -(-n // B)
    n_pad = nb * B

    proj_vecs = hashing.sample_projections(key, d, K, L)
    proj = hashing.project(data, proj_vecs)  # (L, n, K)

    orders = jax.vmap(lambda p: _str_order(p, B))(proj)  # (L, n)

    def _pack(order, proj_t):
        p_sorted = jnp.take(proj_t, order, axis=0)
        pad = jnp.full((n_pad - n, K), jnp.inf, p_sorted.dtype)
        p_sorted = jnp.concatenate([p_sorted, pad], axis=0).reshape(nb, B, K)
        ids = jnp.concatenate(
            [order.astype(jnp.int32), jnp.full((n_pad - n,), n, jnp.int32)]
        ).reshape(nb, B)
        # MBRs over real points only: padded rows are +inf so they never
        # lower `lo`; mask them out of `hi` with -inf.
        finite = jnp.isfinite(p_sorted[..., :1])
        lo = jnp.min(p_sorted, axis=1)
        hi = jnp.max(jnp.where(finite, p_sorted, -jnp.inf), axis=1)
        return p_sorted, ids, lo, hi

    proj_blocks, ids_blocks, mbr_lo, mbr_hi = jax.vmap(_pack)(orders, proj)

    if params.inline_vectors:
        def _pack_vecs(order):
            v = jnp.take(data, order, axis=0)
            pad = jnp.zeros((n_pad - n, d), v.dtype)
            return jnp.concatenate([v, pad], axis=0).reshape(nb, B, d)

        vec_blocks = jax.vmap(_pack_vecs)(orders)
    else:
        vec_blocks = jnp.zeros((0,), dtype=data.dtype)

    if params.quant_dtype != "none":
        qvec_blocks, qvec_scale = quantize_blocks(
            data, ids_blocks, params.quant_dtype
        )
    else:
        qvec_blocks, qvec_scale = empty_quant_blocks(data.dtype)

    return DBLSHIndex(
        proj_vecs=proj_vecs,
        proj_blocks=proj_blocks,
        ids_blocks=ids_blocks,
        mbr_lo=mbr_lo,
        mbr_hi=mbr_hi,
        data=data,
        vec_blocks=vec_blocks,
        norm_blocks=compute_norm_blocks(data, ids_blocks),
        qvec_blocks=qvec_blocks,
        qvec_scale=qvec_scale,
        params=params,
    )
