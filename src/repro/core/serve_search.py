"""Batched fixed-schedule DB-LSH search — the TPU serving path.

`query.search_batch` (vmapped `lax.while_loop`) is the paper-faithful
adaptive path: each query stops at its own radius. On a TPU serving a
batch of requests, data-dependent per-query schedules waste the lockstep
vector units, so production serving uses a *fixed* radius schedule: every
query runs ``steps`` probes r0, c·r0, …, c^{steps-1}·r0 with masked
updates after a query's termination condition fires (identical results
to the adaptive path whenever the adaptive path would have terminated
within ``steps``; the fixed path can only find *more*).

Three verify engines:
  * ``jnp``    — pure-XLA gather + verify (works everywhere; CPU default)
  * ``kernel`` — Pallas ``candidate_verify`` on pre-gathered candidates
  * ``inline`` — Pallas ``window_verify`` with scalar-prefetch block DMA
                 (zero-copy gather; requires params.inline_vectors)
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .index import DBLSHIndex
from .. import kernels

__all__ = ["search_batch_fixed", "search_batch_fixed_dispatch", "PendingSearch"]

_INF = jnp.inf


def _select_blocks(index: DBLSHIndex, G: jax.Array, w) -> jax.Array:
    """MINDIST-ordered fixed-capacity block selection for a query batch.

    G: (Q, L, K) query projections. Returns blk: (L, Q, M) int32 (nb =
    invalid)."""
    p = index.params
    nb = index.nb

    def per_table(mbr_lo, mbr_hi, g):
        # g: (Q, K); mbr: (nb, K)
        lo = g[:, None, :] - 0.5 * w
        hi = g[:, None, :] + 0.5 * w
        overlap = jnp.all((mbr_lo[None] <= hi) & (mbr_hi[None] >= lo), axis=-1)
        mindist = jnp.sum(
            jnp.square(
                jnp.maximum(mbr_lo[None] - g[:, None, :], 0.0)
                + jnp.maximum(g[:, None, :] - mbr_hi[None], 0.0)
            ),
            axis=-1,
        )  # (Q, nb)
        score = jnp.where(overlap, mindist, _INF)
        _, blk = jax.lax.top_k(-score, p.max_blocks)  # (Q, M)
        return jnp.where(jnp.take_along_axis(overlap, blk, 1), blk, nb).astype(jnp.int32)

    return jax.vmap(per_table)(index.mbr_lo, index.mbr_hi, jnp.swapaxes(G, 0, 1))


def _merge_dedup_topk(run_d, run_i, new_d, new_i, n, k):
    """(Q, a) + (Q, b) -> (Q, k) dedup'd ascending merge."""
    d = jnp.concatenate([run_d, new_d], axis=1)
    i = jnp.concatenate([run_i, new_i], axis=1)

    def one(dq, iq):
        order = jnp.lexsort((dq, iq))
        ids_s = jnp.take(iq, order)
        d_s = jnp.take(dq, order)
        first = jnp.concatenate([jnp.ones((1,), bool), ids_s[1:] != ids_s[:-1]])
        d_s = jnp.where(first & (ids_s < n), d_s, _INF)
        neg, idx = jax.lax.top_k(-d_s, k)
        ids = jnp.take(ids_s, idx)
        return -neg, jnp.where(jnp.isfinite(-neg), ids, n)

    return jax.vmap(one)(d, i)


@partial(jax.jit, static_argnames=("k", "steps", "engine", "interpret", "with_stats"))
def search_batch_fixed(
    index: DBLSHIndex,
    Q: jax.Array,
    k: int = 0,
    r0: float = 1.0,
    steps: int = 8,
    engine: str = "jnp",
    interpret=None,
    with_stats: bool = False,
):
    """Fixed-schedule batched (c,k)-ANN.

    Args:
      index: built DBLSHIndex (engine='inline' needs inline_vectors=True).
      Q: (Qn, d) query batch.
      k, r0, steps: top-k, initial radius, schedule length.
      engine: 'jnp' | 'kernel' | 'inline'.
      with_stats: also return per-query probe statistics.

    Returns: (Qn, k) distances ascending, (Qn, k) ids; with ``with_stats``
    a third element ``{"radius_steps": (Qn,) int32, "candidates": (Qn,)
    int32}`` — schedule steps run before the termination rule fired, and
    candidate slots fetched (selected blocks x B, all tables) while active.
    """
    if engine not in ("jnp", "kernel", "inline"):
        raise ValueError(f"unknown engine {engine!r}: use jnp | kernel | inline")
    p = index.params
    k = k or p.k
    n = index.n
    Qn = Q.shape[0]
    nb = index.nb
    B = p.block_size

    G = jnp.einsum("lkd,qd->qlk", index.proj_vecs, Q)  # (Qn, L, K)

    best_d = jnp.full((Qn, k), _INF)
    best_i = jnp.full((Qn, k), n, jnp.int32)
    done = jnp.zeros((Qn,), bool)
    radius_steps = jnp.zeros((Qn,), jnp.int32)
    candidates = jnp.zeros((Qn,), jnp.int32)

    r = jnp.asarray(r0, jnp.float32)
    for _ in range(steps):
        w = p.w0 * r
        blk = _select_blocks(index, G, w)  # (L, Qn, M)
        if with_stats:
            active = ~done
            radius_steps = radius_steps + active.astype(jnp.int32)
            n_slots = jnp.sum((blk < nb).astype(jnp.int32), axis=(0, 2)) * B
            candidates = candidates + jnp.where(active, n_slots, 0)

        step_d = jnp.full((Qn, k), _INF)
        step_i = jnp.full((Qn, k), n, jnp.int32)
        for li in range(p.L):
            g_l = G[:, li, :]
            if engine == "inline":
                d_l, i_l = kernels.window_verify(
                    blk[li],
                    index.proj_blocks[li],
                    index.vec_blocks[li],
                    index.ids_blocks[li],
                    g_l,
                    Q,
                    w,
                    n=n,
                    k=k,
                    interpret=interpret,
                )
            else:
                pb = jnp.take(index.proj_blocks[li], blk[li], axis=0,
                              mode="fill", fill_value=_INF)  # (Qn,M,B,K)
                ib = jnp.take(index.ids_blocks[li], blk[li], axis=0,
                              mode="fill", fill_value=n)
                if p.inline_vectors:
                    vb = jnp.take(index.vec_blocks[li], blk[li], axis=0,
                                  mode="fill", fill_value=0.0)
                else:
                    vb = jnp.take(index.data, ib.reshape(Qn, -1), axis=0,
                                  mode="fill", fill_value=0.0)
                M = blk.shape[-1]
                cp = pb.reshape(Qn, M * B, p.K)
                cv = vb.reshape(Qn, M * B, -1)
                ci = ib.reshape(Qn, M * B)
                if engine == "kernel":
                    d_l, i_l = kernels.candidate_verify(
                        cp, cv, ci, g_l, Q, w, n=n, k=k, interpret=interpret
                    )
                else:  # 'jnp'
                    inbox = jnp.all(
                        jnp.abs(cp - g_l[:, None, :]) <= 0.5 * w, axis=-1
                    ) & (ci < n)
                    d2 = jnp.sum(jnp.square(cv - Q[:, None, :]), axis=-1)
                    d2 = jnp.where(inbox, d2, _INF)
                    d_l, i_l = jax.lax.top_k(-d2, k)
                    d_l = -d_l
                    i_l = jnp.where(jnp.isfinite(d_l),
                                    jnp.take_along_axis(ci, i_l, 1), n)
            step_d, step_i = _merge_dedup_topk(step_d, step_i, d_l, i_l, n, k)

        # masked merge: finished queries keep their result
        nd, ni = _merge_dedup_topk(best_d, best_i, step_d, step_i, n, k)
        best_d = jnp.where(done[:, None], best_d, nd)
        best_i = jnp.where(done[:, None], best_i, ni)
        done = done | (best_d[:, k - 1] <= jnp.square(p.c * r))
        r = r * p.c

    if with_stats:
        stats = {"radius_steps": radius_steps, "candidates": candidates}
        return jnp.sqrt(best_d), best_i, stats
    return jnp.sqrt(best_d), best_i


class PendingSearch:
    """Handle for an issued-but-not-awaited ``search_batch_fixed`` call.

    JAX dispatch is asynchronous: the jitted search returns device
    futures immediately, and the host only stalls when it *reads* them.
    This handle makes the two stages explicit so a serving loop can
    issue batch i+1 (host-side padding, slicing, queue work) while the
    device still executes batch i:

        pending = search_batch_fixed_dispatch(index, Q, k=10)
        ...host work for the next batch...
        dists, ids = pending.result()        # first host sync

    ``ready()`` is a non-blocking readiness probe (used by the store
    scheduler to opportunistically retire in-flight batches).
    """

    __slots__ = ("dists", "ids", "stats")

    def __init__(self, dists, ids, stats=None):
        self.dists = dists
        self.ids = ids
        self.stats = stats

    def _leaves(self):
        leaves = [self.dists, self.ids]
        if self.stats is not None:
            leaves.extend(jax.tree_util.tree_leaves(self.stats))
        return leaves

    def ready(self) -> bool:
        """True once every output buffer has materialized (never blocks)."""
        return all(
            x.is_ready() for x in self._leaves() if hasattr(x, "is_ready")
        )

    def result(self):
        """Block until complete; returns (dists, ids[, stats])."""
        jax.block_until_ready(self._leaves())
        if self.stats is not None:
            return self.dists, self.ids, self.stats
        return self.dists, self.ids


def search_batch_fixed_dispatch(
    index: DBLSHIndex,
    Q: jax.Array,
    k: int = 0,
    r0: float = 1.0,
    steps: int = 8,
    engine: str = "jnp",
    interpret=None,
    with_stats: bool = False,
) -> PendingSearch:
    """Issue a fixed-schedule search without blocking on the device.

    Same arguments and numerics as :func:`search_batch_fixed` (it *is*
    the same compiled program — bit-equality between the overlapped and
    synchronous paths is by construction), but the return value is a
    :class:`PendingSearch` whose ``result()`` performs the only host
    sync.  This is the dispatch half of the store scheduler's two-stage
    pipeline.
    """
    out = search_batch_fixed(
        index, Q, k=k, r0=r0, steps=steps, engine=engine,
        interpret=interpret, with_stats=with_stats,
    )
    if with_stats:
        return PendingSearch(out[0], out[1], out[2])
    return PendingSearch(out[0], out[1])
