"""Batched fixed-schedule DB-LSH search — the TPU serving path.

`query.search_batch` (vmapped `lax.while_loop`) is the paper-faithful
adaptive path: each query stops at its own radius. On a TPU serving a
batch of requests, data-dependent per-query schedules waste the lockstep
vector units, so production serving uses a *fixed* radius schedule: every
query runs ``steps`` probes r0, c·r0, …, c^{steps-1}·r0 with masked
updates after a query's termination condition fires (identical results
to the adaptive path whenever the adaptive path would have terminated
within ``steps``; the fixed path can only find *more*).

**One-pass incremental probing** (DESIGN.md §7).  The paper's query
cost argument (§IV-C) rests on windows *nesting* across the schedule:
W(G(q), w0·r) ⊆ W(G(q), w0·c·r).  The serving pipeline exploits this so
each unit of work happens exactly once for the whole schedule instead
of once per radius:

  1. **Select once.**  ``_select_blocks`` runs a single MINDIST-ordered
     MBR pass at the *final* radius.  Every earlier radius' block set is
     a subset of this one, recoverable by masking on the per-block
     window-overlap halfwidth — ``steps-1`` full MBR scans + top_k
     compactions disappear.
  2. **Verify once.**  The selected blocks of all L tables are gathered
     and verified in one batched pass over a flat (Qn, L·M·B) candidate
     axis, producing per-slot exact squared distances plus the slot's
     window halfwidth ``hw = max_k |p_k - g_k|`` (the smallest half
     window that admits it).  Total verify work collapses from
     Σ_j L·M·B to L·M·B.
  3. **Merge deltas.**  Per step only the newly-admitted slice
     (w_{j-1}/2 < hw ≤ w_j/2) is merged into the running top-k — a
     streaming top-k is exact because added candidates only push ranks
     down.  The merge is the sort-free k-step vectorized selection
     (`query.merge_dedup_topk`), one call per step for all tables.
  4. **MXU distances.**  ``||x||² - 2<q,x> + ||q||²`` with per-point
     squared norms precomputed at build time (``index.norm_blocks``)
     turns verification into one dot per candidate.  ``exact=True``
     restores materialized-diff distances (the norm trick changes fp32
     rounding); results are id-set/recall equivalent either way.

Three verify engines:
  * ``jnp``    — pure-XLA gather + verify (works everywhere; CPU default)
  * ``kernel`` — Pallas ``candidate_dist`` on pre-gathered candidates
  * ``inline`` — Pallas ``window_dist`` with scalar-prefetch block DMA
                 (zero-copy gather; requires params.inline_vectors)

``search_batch_fixed_ref`` preserves the multi-pass (per-radius
re-selection) algorithm verbatim: it is the equivalence oracle for the
one-pass pipeline and the baseline of ``benchmarks/search_hotpath.py``.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .index import DBLSHIndex
from .query import merge_dedup_topk
from .. import kernels

__all__ = [
    "Termination",
    "search_batch_fixed",
    "search_batch_fixed_ref",
    "search_batch_fixed_dispatch",
    "PendingSearch",
    "validate_engine",
    "validate_dtype",
    "ENGINES",
    "DTYPES",
    "TERM_EXHAUSTED",
    "TERM_C1",
    "TERM_C2",
]

_INF = jnp.inf

ENGINES = ("jnp", "kernel", "inline")
DTYPES = ("fp32", "bf16", "int8")


def validate_engine(engine: str) -> str:
    """The engine-name check shared by the search path and the store
    layer (collection defaults, service overrides)."""
    if engine not in ENGINES:
        raise ValueError(
            f"unknown engine {engine!r}: use " + " | ".join(ENGINES)
        )
    return engine


def validate_dtype(dtype: str, params=None, exact: bool = False) -> str:
    """Distance-dtype check for the serving path.

    ``fp32`` is the default exact-arithmetic path.  ``bf16``/``int8``
    route the in-kernel dots through the quantized blocks (top-4k
    shortlist + exact fp32 re-rank) and therefore need an index built
    with the matching ``params.quant_dtype``; ``exact=True`` asserts
    bit-fidelity to the multi-pass seed, which no quantized path can
    promise, so the combination is rejected outright."""
    if dtype not in DTYPES:
        raise ValueError(
            f"unknown dtype {dtype!r}: use " + " | ".join(DTYPES)
        )
    if dtype != "fp32":
        if exact:
            raise ValueError(
                f"exact=True requires dtype='fp32' (got {dtype!r}): the "
                "quantized path is a shortlist + re-rank, not bit-exact"
            )
        if params is not None and params.quant_dtype != dtype:
            raise ValueError(
                f"dtype={dtype!r} needs an index built with "
                f"quant_dtype={dtype!r} (index has "
                f"{params.quant_dtype!r}) — rebuild or derive params "
                "with quant_dtype set"
            )
    return dtype


@dataclasses.dataclass(frozen=True)
class Termination:
    """Paper terminate conditions (§IV-B/§IV-C) as a static schedule policy.

    ``termination=None`` (the default everywhere) keeps the plain fixed
    schedule: all ``steps`` radii run unrolled, with the C2 rule freezing
    finished queries' *results* exactly as before.  Passing a
    ``Termination`` turns the schedule into a ``lax.while_loop`` whose
    per-query ``done`` masks gate every delta merge, so terminated
    queries stop gathering/verifying work:

    * **C1** (``use_c1``): a query is done once its windows have admitted
      at least ``c1_budget`` verified candidate slots — the paper's
      candidate-count termination (``βn + k``, concretely ``2tL + k``;
      ``c1_budget=0`` derives it from the index params).  The count is
      over verified candidate *slots* (cross-table duplicates included):
      that is the unit of verification work the device actually performs,
      and it is computable from the per-slot admission halfwidths the
      verify engines already emit — no extra gather.
    * **C2** (``use_c2``): a query is done once its k-th best verified
      distance is ≤ c·r — a point within ``c·r`` at radius ``r``
      certifies a c²-approximate answer (the returned top-1 is within
      ``c²·r`` of the true NN).
    * **early exit** (``early_exit``): the while_loop stops as soon as
      every query in the batch is done.  Terminated queries' state is
      frozen by the masks, so the exit is bit-invisible in the results —
      it only skips device work.

    Frozen/hashable: a Termination is a static jit argument, one compiled
    program per distinct policy.
    """

    use_c1: bool = True
    c1_budget: int = 0  # 0 -> derive the paper budget 2tL + k from params
    use_c2: bool = True
    early_exit: bool = True


def _select_blocks(index: DBLSHIndex, G: jax.Array, w):
    """MINDIST-ordered fixed-capacity block selection for a query batch.

    G: (Q, L, K) query projections. Returns (blk, bhw): blk (L, Q, M)
    int32 (nb = invalid); bhw (L, Q, M) per-block window halfwidths —
    the L∞ box distance from the query projection to the block MBR, i.e.
    the smallest half window width whose window overlaps the block
    (+inf on invalid slots)."""
    p = index.params
    nb = index.nb

    def per_table(mbr_lo, mbr_hi, g):
        # g: (Q, K); mbr: (nb, K)
        lo = g[:, None, :] - 0.5 * w
        hi = g[:, None, :] + 0.5 * w
        overlap = jnp.all((mbr_lo[None] <= hi) & (mbr_hi[None] >= lo), axis=-1)
        # per-dim box distance (at most one term is positive for a valid
        # MBR, so the sum equals the clamped max)
        pd = jnp.maximum(mbr_lo[None] - g[:, None, :], 0.0) + jnp.maximum(
            g[:, None, :] - mbr_hi[None], 0.0
        )  # (Q, nb, K)
        mindist = jnp.sum(jnp.square(pd), axis=-1)  # (Q, nb)
        score = jnp.where(overlap, mindist, _INF)
        _, blk = jax.lax.top_k(-score, p.max_blocks)  # (Q, M)
        sel_ok = jnp.take_along_axis(overlap, blk, 1)
        bhw = jnp.take_along_axis(jnp.max(pd, axis=-1), blk, 1)
        return (
            jnp.where(sel_ok, blk, nb).astype(jnp.int32),
            jnp.where(sel_ok, bhw, _INF),
        )

    return jax.vmap(per_table)(index.mbr_lo, index.mbr_hi, jnp.swapaxes(G, 0, 1))


def _gather_pool(index: DBLSHIndex, blk_q: jax.Array, G: jax.Array,
                 Q: jax.Array, engine: str, exact: bool, interpret):
    """Engine dispatch for the verify-once stage.

    blk_q: (Qn, S) flattened cross-table block ids (S = L·M, sentinel
    L·nb). Returns (d2, hw): (Qn, C) exact squared distances and window
    halfwidths over the C = S·B candidate slots, table-major. Slots are
    *not* window-masked — the schedule applies per-step masks on hw."""
    p = index.params
    nb = index.nb
    L, M, B = p.L, p.max_blocks, p.block_size
    Qn = Q.shape[0]
    S = L * M
    proj_flat = index.proj_blocks.reshape(L * nb, B, p.K)

    if engine == "inline":
        return kernels.window_dist(
            blk_q,
            proj_flat,
            index.vec_blocks.reshape(L * nb, B, -1),
            index.norm_blocks.reshape(L * nb, B),
            G,
            Q,
            M=M,
            exact=exact,
            interpret=interpret,
        )

    pb = jnp.take(proj_flat, blk_q, axis=0, mode="fill", fill_value=_INF)
    if p.inline_vectors:
        vb = jnp.take(
            index.vec_blocks.reshape(L * nb, B, -1), blk_q, axis=0,
            mode="fill", fill_value=0.0,
        )  # (Qn, S, B, d)
    else:
        ib = jnp.take(
            index.ids_blocks.reshape(L * nb, B), blk_q, axis=0,
            mode="fill", fill_value=index.n,
        )
        vb = jnp.take(
            index.data, ib.reshape(Qn, -1), axis=0, mode="fill", fill_value=0.0
        ).reshape(Qn, S, B, -1)
    nrm = jnp.take(
        index.norm_blocks.reshape(L * nb, B), blk_q, axis=0,
        mode="fill", fill_value=_INF,
    )  # (Qn, S, B)

    if engine == "kernel":
        return kernels.candidate_dist(
            pb.reshape(Qn, L, M * B, p.K),
            vb.reshape(Qn, L, M * B, -1),
            nrm.reshape(Qn, L, M * B),
            G,
            Q,
            exact=exact,
            interpret=interpret,
        )

    # 'jnp'
    g_rep = jnp.repeat(G, M, axis=1)  # (Qn, S, K)
    hw = jnp.max(jnp.abs(pb - g_rep[:, :, None, :]), axis=-1)  # (Qn, S, B)
    C = S * B
    if exact:
        d2 = jnp.sum(jnp.square(vb - Q[:, None, None, :]), axis=-1)
    else:
        q2 = jnp.sum(jnp.square(Q), axis=-1)  # (Qn,)
        # per-slot multiply + last-axis reduce (not a batched-matmul
        # einsum): the reduction order is then independent of the batch
        # shape, so the store layer's padded dispatch stays bit-identical
        # to an unpadded call.  The true MXU raising lives in the Pallas
        # engines, whose tile shapes never depend on Qn.
        dots = jnp.sum(vb * Q[:, None, None, :], axis=-1)  # (Qn, S, B)
        d2 = jnp.maximum(
            nrm - 2.0 * dots + q2[:, None, None], 0.0
        )
    return d2.reshape(Qn, C), hw.reshape(Qn, C)


def _fused_bins(index: DBLSHIndex, blk_q: jax.Array, G: jax.Array,
                Q: jax.Array, halves: jax.Array, engine: str, exact: bool,
                dtype: str, ks: int, interpret):
    """Fused verify+bin stage: one pass over the selected slots emitting
    per-(query, step) top-ks *bin* accumulators instead of the (Qn, C)
    distance pool.

    Bin j holds the ks best distinct (d2, id) pairs among candidates
    whose window halfwidth first admits them at step j — exactly the
    step-j delta slice of the schedule (windows nest), so the epilogue's
    prefix merge reproduces the flat per-step merge bit-for-bit.  ``cnt``
    (Qn, steps) counts admitted candidate slots per bin; its cumsum is
    the C1 admission count.

    Engine routing: 'inline' streams blocks via scalar-prefetch DMA
    (candidates never reach HBM); 'kernel' runs the gathered twin; 'jnp'
    lands here only for quantized dtypes and computes the same bins in
    pure XLA (the CPU-parity twin of the quantized kernels)."""
    p = index.params
    nb = index.nb
    L, M, B = p.L, p.max_blocks, p.block_size
    Qn = Q.shape[0]
    n = index.n
    S = L * M
    mode = ("exact" if exact else "norm") if dtype == "fp32" else dtype
    proj_flat = index.proj_blocks.reshape(L * nb, B, p.K)
    nrm_flat = index.norm_blocks.reshape(L * nb, B)
    ids_flat = index.ids_blocks.reshape(L * nb, B)

    if engine == "inline":
        if dtype == "fp32":
            xb, xs = index.vec_blocks.reshape(L * nb, B, -1), None
        else:
            xb = index.qvec_blocks.reshape(L * nb, B, -1)
            xs = index.qvec_scale.reshape(L * nb, B)
        return kernels.fused_window_search(
            blk_q, halves, proj_flat, xb, nrm_flat, ids_flat, G, Q,
            M=M, ks=ks, n=n, mode=mode, interpret=interpret, x_scale=xs,
        )

    pb = jnp.take(proj_flat, blk_q, axis=0, mode="fill", fill_value=_INF)
    ib = jnp.take(ids_flat, blk_q, axis=0, mode="fill", fill_value=n)
    nrm = jnp.take(nrm_flat, blk_q, axis=0, mode="fill", fill_value=_INF)
    if dtype == "fp32":
        if p.inline_vectors:
            vb = jnp.take(
                index.vec_blocks.reshape(L * nb, B, -1), blk_q, axis=0,
                mode="fill", fill_value=0.0,
            )
        else:
            vb = jnp.take(
                index.data, ib.reshape(Qn, -1), axis=0, mode="fill",
                fill_value=0.0,
            ).reshape(Qn, S, B, -1)
        sc = None
    else:
        vb = jnp.take(
            index.qvec_blocks.reshape(L * nb, B, -1), blk_q, axis=0,
            mode="fill", fill_value=0,
        )
        sc = jnp.take(
            index.qvec_scale.reshape(L * nb, B), blk_q, axis=0,
            mode="fill", fill_value=1.0,
        )

    if engine == "kernel":
        return kernels.fused_cand_search(
            pb.reshape(Qn, L, M * B, p.K),
            vb.reshape(Qn, L, M * B, -1),
            nrm.reshape(Qn, L, M * B),
            ib.reshape(Qn, L, M * B),
            halves, G, Q, ks=ks, n=n, mode=mode, interpret=interpret,
            cand_scale=None if sc is None else sc.reshape(Qn, L, M * B),
        )

    # 'jnp' + quantized: pure-XLA twin of the quantized kernels
    C = S * B
    steps = halves.shape[0]
    g_rep = jnp.repeat(G, M, axis=1)  # (Qn, S, K)
    hw = jnp.max(jnp.abs(pb - g_rep[:, :, None, :]), axis=-1).reshape(Qn, C)
    q2 = jnp.sum(jnp.square(Q), axis=-1)
    if dtype == "bf16":
        qv = Q.astype(jnp.bfloat16)
        dots = jnp.sum(
            vb.astype(jnp.float32) * qv.astype(jnp.float32)[:, None, None, :],
            axis=-1,
        )
        df = dots
    else:  # int8
        amax = jnp.max(jnp.abs(Q), axis=-1, keepdims=True)
        qs = jnp.where(amax > 0.0, amax / 127.0, 1.0)
        qq = jnp.clip(jnp.round(Q / qs), -127.0, 127.0).astype(jnp.int32)
        idot = jnp.sum(vb.astype(jnp.int32) * qq[:, None, None, :], axis=-1)
        df = sc * qs[:, :, None] * idot.astype(jnp.float32)
    d2q = jnp.maximum(
        nrm - 2.0 * df + q2[:, None, None], 0.0
    ).reshape(Qn, C)
    ci = ib.reshape(Qn, C)
    binid = jnp.sum(
        (hw[:, :, None] > halves[None, None, :]).astype(jnp.int32), axis=-1
    )  # (Qn, C)
    cnt = jnp.sum(
        binid[:, :, None] == jnp.arange(steps)[None, None, :], axis=1,
        dtype=jnp.int32,
    )  # (Qn, steps)
    bd0 = jnp.full((Qn, ks), _INF)
    bi0 = jnp.full((Qn, ks), n, jnp.int32)
    bds, bis = [], []
    for j in range(steps):
        dj = jnp.where(binid == j, d2q, _INF)
        bd_j, bi_j = merge_dedup_topk(bd0, bi0, dj, ci, n, ks)
        bds.append(bd_j)
        bis.append(bi_j)
    return jnp.stack(bds, axis=1), jnp.stack(bis, axis=1), cnt


def _rerank_bins(index: DBLSHIndex, Q: jax.Array, bins_d, bins_i):
    """Exact fp32 re-rank of the quantized shortlist bins.

    Gathers the shortlisted data rows and recomputes norm-form distances
    in fp32, so the epilogue's merges — and with them the C2
    certification ``kth <= c*r`` — run on exact distances.  The only
    quantization-induced loss left is a true neighbor falling outside
    its bin's top-4k shortlist (the documented recall band)."""
    n = index.n
    Qn, steps, ks = bins_d.shape
    ids = bins_i.reshape(Qn, steps * ks)
    x = jnp.take(
        index.data, ids, axis=0, mode="fill", fill_value=0.0
    ).reshape(Qn, steps, ks, -1)
    nrm = jnp.sum(jnp.square(x), axis=-1)
    dots = jnp.sum(x * Q[:, None, None, :], axis=-1)
    q2 = jnp.sum(jnp.square(Q), axis=-1)
    d2 = jnp.maximum(nrm - 2.0 * dots + q2[:, None, None], 0.0)
    valid = (bins_i < n) & jnp.isfinite(bins_d)
    return jnp.where(valid, d2, _INF)


def _masked_delta_merge(best_d, best_i, delta, d2, ci, done, n, k):
    """One schedule-step merge: fold the newly-admitted delta slice into
    the running top-k with finished queries frozen — skipping the whole
    merge (``lax.cond``) when the delta is empty batch-wide.  Merging an
    all-masked delta is the identity, so the skip is bit-safe; it saves
    the O(k·C) selection on every step whose windows admit nothing
    anywhere in the batch (common late in an adaptive schedule and on
    sparse regions of a fixed one)."""

    def run(bd, bi):
        nd, ni = merge_dedup_topk(bd, bi, jnp.where(delta, d2, _INF), ci, n, k)
        return (
            jnp.where(done[:, None], bd, nd),
            jnp.where(done[:, None], bi, ni),
        )

    return jax.lax.cond(
        jnp.any(delta), run, lambda bd, bi: (bd, bi), best_d, best_i
    )


#: ``explain["term_cause"]`` codes: why a query's schedule stopped
#: advancing — C2 wins ties with C1 on the same step, mirroring the
#: mask-update order of the dispatch itself.  ``repro.obs.explain``
#: renders these into the human-readable record.
TERM_EXHAUSTED, TERM_C1, TERM_C2 = 0, 1, 2


@partial(
    jax.jit,
    static_argnames=(
        "k", "steps", "engine", "interpret", "with_stats", "exact",
        "termination", "with_explain", "dtype",
    ),
)
def search_batch_fixed(
    index: DBLSHIndex,
    Q: jax.Array,
    k: int = 0,
    r0: float = 1.0,
    steps: int = 8,
    engine: str = "jnp",
    interpret=None,
    with_stats: bool = False,
    exact: bool = False,
    termination: Termination | None = None,
    with_explain: bool = False,
    dtype: str = "fp32",
):
    """Fixed-schedule batched (c,k)-ANN — one-pass incremental probing.

    Args:
      index: built DBLSHIndex (engine='inline' needs inline_vectors=True).
      Q: (Qn, d) query batch.
      k, r0, steps: top-k, initial radius, schedule length.
      engine: 'jnp' | 'kernel' | 'inline'.  The Pallas engines run the
        *fully fused* one-pass kernel: select-slot DMA, halfwidths,
        distances, schedule admission and the per-step top-k merges all
        happen in-kernel via per-step bin accumulators — candidates
        never round-trip through HBM between select and the final (k,)
        result.  Results are identical to the 'jnp' pool path (bit-equal
        under ``exact=True``).
      with_stats: also return per-query probe statistics.
      exact: use materialized-diff distances instead of the MXU norm
        form (bit-compatible with :func:`search_batch_fixed_ref`).
        Requires ``dtype='fp32'``.
      termination: ``None`` runs the plain fixed schedule; a
        :class:`Termination` enables per-query adaptive termination
        (paper C1/C2 done masks + batch-wide while_loop early exit —
        the ``repro.tune`` subsystem's serving hook).
      with_explain: additionally return the per-query *per-step* arrays
        the stats reduce away (implies ``with_stats``): the EXPLAIN
        ANALYZE feed for ``repro.obs.explain``.  The result arrays and
        the done-mask updates are computed identically — explain only
        *observes* — so distances/ids are bit-equal to the
        ``with_explain=False`` program.
      dtype: 'fp32' (default) | 'bf16' | 'int8'.  The quantized dtypes
        compute candidate dots against the index's quantized blocks
        (``params.quant_dtype`` must match), shortlist the top-4k per
        schedule bin, and re-rank the shortlist in exact fp32 before
        the merges — so the C2 certificate stays sound and the only
        loss is a neighbor falling off its bin's shortlist (recall@10
        within 0.005 of fp32 on the benchmark workload; see
        DESIGN.md §13 for the error model).

    Returns: (Qn, k) distances ascending, (Qn, k) ids; with ``with_stats``
    a third element ``{"radius_steps": (Qn,) int32, "candidates": (Qn,)
    int32}`` — schedule steps run before the termination rule fired, and
    *distinct* candidate slots fetched while active: each selected block
    (all tables) counts its B slots once, at the step its window first
    overlaps it, and never while the query is already done.  Padded
    selection slots (blk == nb) are not work and are not counted.

    With ``with_explain`` a fourth element::

        {"step_half":    (steps,)     f32  per-step window halfwidths,
         "step_slots":   (Qn, steps)  i32  admitted-delta slots per step
                                           (rows sum to ``candidates``),
         "term_cause":   (Qn,)        i32  TERM_EXHAUSTED | TERM_C1 |
                                           TERM_C2 (first rule to fire),
         "final_radius": (Qn,)        f32  radius at termination (the
                                           certified radius under C2)}
    """
    validate_engine(engine)
    p = index.params
    validate_dtype(dtype, p, exact)
    if with_explain:
        with_stats = True
    k = k or p.k
    n = index.n
    Qn = Q.shape[0]
    nb = index.nb
    B = p.block_size
    L, M = p.L, p.max_blocks
    quant = dtype != "fp32"
    # Pallas engines (and every quantized dtype) run the fused bin path;
    # 'jnp' + fp32 keeps the seed's pool path verbatim
    use_bins = engine in ("kernel", "inline") or quant
    ks = 4 * k if quant else k  # quantized: top-4k shortlist per bin

    # named_scope labels are HLO metadata only (numerics-invariant): they
    # let a jax.profiler device trace line up with the host-side
    # store.dispatch spans by stage name (repro.obs, DESIGN.md §10)
    with jax.named_scope("dblsh.project"):
        G = jnp.einsum("lkd,qd->qlk", index.proj_vecs, Q)  # (Qn, L, K)

    # -------- select once, at the final radius (windows nest: every
    # earlier step's block set is this set masked on bhw)
    r_last = jnp.asarray(r0, jnp.float32)
    for _ in range(steps - 1):
        r_last = r_last * p.c
    with jax.named_scope("dblsh.select"):
        blk, bhw = _select_blocks(index, G, p.w0 * r_last)  # (L, Qn, M) each

        # flatten the table axis: one cross-table candidate pool
        offs = (jnp.arange(L, dtype=jnp.int32) * nb)[:, None, None]
        blk_flat = jnp.where(blk < nb, blk + offs, L * nb)  # (L, Qn, M)
        blk_q = jnp.swapaxes(blk_flat, 0, 1).reshape(Qn, L * M)

    # schedule half window widths, built by the same f32 multiply chain
    # the step loop runs — the in-kernel admission compares against the
    # bit-identical values the host masks would use
    halves_list, rr = [], jnp.asarray(r0, jnp.float32)
    for _ in range(steps):
        halves_list.append(0.5 * (p.w0 * rr))
        rr = rr * p.c
    halves_sched = jnp.stack(halves_list)  # (steps,)

    # -------- verify once: either the fused bin accumulators (Pallas
    # engines / quantized dtypes — per-step deltas and counters computed
    # in-kernel) or the (Qn, C) distance pool (the 'jnp' fp32 path)
    bins_d = bins_i = cum_adm = d2 = hw = ci = None
    with jax.named_scope("dblsh.verify"):
        if use_bins:
            bins_d, bins_i, bin_cnt = _fused_bins(
                index, blk_q, G, Q, halves_sched, engine, exact, dtype,
                ks, interpret,
            )
            if quant:
                bins_d = _rerank_bins(index, Q, bins_d, bins_i)
            # C1 admission count at step j == slots in bins 0..j
            cum_adm = jnp.cumsum(bin_cnt, axis=1)
        else:
            ci = jnp.take(
                index.ids_blocks.reshape(L * nb, B), blk_q, axis=0,
                mode="fill", fill_value=n,
            ).reshape(Qn, L * M * B)
            d2, hw = _gather_pool(index, blk_q, G, Q, engine, exact,
                                  interpret)

    bhw_q = jnp.swapaxes(bhw, 0, 1).reshape(Qn, L * M)  # (Qn, S)

    best_d = jnp.full((Qn, k), _INF)
    best_i = jnp.full((Qn, k), n, jnp.int32)
    done = jnp.zeros((Qn,), bool)
    radius_steps = jnp.zeros((Qn,), jnp.int32)
    candidates = jnp.zeros((Qn,), jnp.int32)
    # explain accumulators: fixed (Qn, steps)/(Qn,) shapes so the same
    # dict threads through the unrolled loop and the while_loop carry
    # (per-step writes land via a one-hot on the step index)
    ex = None
    if with_explain:
        ex = {
            "step_slots": jnp.zeros((Qn, steps), jnp.int32),
            "term_cause": jnp.full((Qn,), TERM_EXHAUSTED, jnp.int32),
            "final_radius": jnp.zeros((Qn,), jnp.float32),
        }

    c1_thr = None
    if termination is not None and termination.use_c1:
        c1_thr = (
            termination.c1_budget if termination.c1_budget > 0 else p.budget
        )
    use_c2 = True if termination is None else termination.use_c2

    def schedule_step(j, r, prev_half, best_d, best_i, done, radius_steps,
                      candidates, ex):
        half = 0.5 * (p.w0 * r)
        if with_stats:
            active = ~done
            radius_steps = radius_steps + active.astype(jnp.int32)
            newly = (bhw_q <= half) & (bhw_q > prev_half)  # (Qn, S)
            n_slots = jnp.sum(newly.astype(jnp.int32), axis=1) * B
            candidates = candidates + jnp.where(active, n_slots, 0)
            if with_explain:
                onehot = (jnp.arange(steps) == j).astype(jnp.int32)
                ex = dict(ex, step_slots=ex["step_slots"]
                          + jnp.where(active, n_slots, 0)[:, None] * onehot)

        # newly-admitted delta slice: slots whose window first reaches
        # them at this radius (hw = +inf slots never admit); finished
        # queries keep their result through the masked merge.  On the
        # fused path the delta IS bin j (the kernel binned candidates by
        # first-admitting step), so the merge folds ks pre-reduced
        # entries instead of the whole C-slot pool.
        with jax.named_scope("dblsh.merge"):
            if use_bins:
                cd = jnp.take(bins_d, j, axis=1)  # (Qn, ks)
                cids = jnp.take(bins_i, j, axis=1)
                best_d, best_i = _masked_delta_merge(
                    best_d, best_i, jnp.isfinite(cd), cd, cids, done, n, k
                )
            else:
                delta = (hw <= half) & (hw > prev_half)
                best_d, best_i = _masked_delta_merge(
                    best_d, best_i, delta, d2, ci, done, n, k
                )
        if use_c2:
            fired = best_d[:, k - 1] <= jnp.square(p.c * r)
            if with_explain:
                newly_done = fired & ~done
                ex = dict(
                    ex,
                    term_cause=jnp.where(newly_done, TERM_C2,
                                         ex["term_cause"]),
                    final_radius=jnp.where(newly_done, r,
                                           ex["final_radius"]),
                )
            done = done | fired
        if c1_thr is not None:
            # C1 from the halfwidths the verify engines already emitted:
            # slots the current window admits whose distance is finite
            # (verified work) — no extra gather/DMA to evaluate.  The
            # fused path's per-bin counters carry the same quantity:
            # cumsum(cnt)[j] == #{hw <= w_j/2} (admitted slots are live
            # slots, whose distances are always finite).
            if use_bins:
                n_adm = jnp.take(cum_adm, j, axis=1)  # (Qn,)
            else:
                n_adm = jnp.sum(
                    ((hw <= half) & jnp.isfinite(d2)).astype(jnp.int32),
                    axis=1,
                )
            fired = n_adm >= c1_thr
            if with_explain:
                newly_done = fired & ~done
                ex = dict(
                    ex,
                    term_cause=jnp.where(newly_done, TERM_C1,
                                         ex["term_cause"]),
                    final_radius=jnp.where(newly_done, r,
                                           ex["final_radius"]),
                )
            done = done | fired
        return half, best_d, best_i, done, radius_steps, candidates, ex

    if termination is None:
        r = jnp.asarray(r0, jnp.float32)
        prev_half = -_INF
        for j in range(steps):
            prev_half, best_d, best_i, done, radius_steps, candidates, ex = (
                schedule_step(j, r, prev_half, best_d, best_i, done,
                              radius_steps, candidates, ex)
            )
            r = r * p.c
    else:
        # adaptive schedule: same per-step body in a while_loop whose
        # carry threads (r, prev_half) through the identical multiply
        # chain (bit-equal radii), exiting as soon as every query's done
        # mask fired — frozen state makes the exit result-invisible
        def cond_fn(carry):
            j, _, _, _, _, done = carry[:6]
            more = j < steps
            if termination.early_exit:
                more = more & ~jnp.all(done)
            return more

        def body_fn(carry):
            j, r, prev_half, best_d, best_i, done, radius_steps, cands, ex = (
                carry
            )
            prev_half, best_d, best_i, done, radius_steps, cands, ex = (
                schedule_step(j, r, prev_half, best_d, best_i, done,
                              radius_steps, cands, ex)
            )
            return (j + 1, r * p.c, prev_half, best_d, best_i, done,
                    radius_steps, cands, ex)

        carry = (
            jnp.asarray(0, jnp.int32),
            jnp.asarray(r0, jnp.float32),
            jnp.asarray(-_INF, jnp.float32),
            best_d, best_i, done, radius_steps, candidates, ex,
        )
        (_, _, _, best_d, best_i, done, radius_steps, candidates, ex) = (
            jax.lax.while_loop(cond_fn, body_fn, carry)
        )

    if with_explain:
        # exhausted queries (cause 0) terminated at the schedule's final
        # radius; halves_sched replayed the same multiply chain the loop
        # ran, so it matches the admission masks bit-for-bit
        ex = dict(
            ex,
            step_half=halves_sched,
            final_radius=jnp.where(
                ex["term_cause"] == TERM_EXHAUSTED, r_last,
                ex["final_radius"],
            ),
        )
        stats = {"radius_steps": radius_steps, "candidates": candidates}
        return jnp.sqrt(best_d), best_i, stats, ex
    if with_stats:
        stats = {"radius_steps": radius_steps, "candidates": candidates}
        return jnp.sqrt(best_d), best_i, stats
    return jnp.sqrt(best_d), best_i


def _merge_dedup_topk_lexsort(run_d, run_i, new_d, new_i, n, k):
    """(Q, a) + (Q, b) -> (Q, k) dedup'd ascending merge (the multi-pass
    reference's lexsort merge, kept verbatim for bit-fidelity)."""
    d = jnp.concatenate([run_d, new_d], axis=1)
    i = jnp.concatenate([run_i, new_i], axis=1)

    def one(dq, iq):
        order = jnp.lexsort((dq, iq))
        ids_s = jnp.take(iq, order)
        d_s = jnp.take(dq, order)
        first = jnp.concatenate([jnp.ones((1,), bool), ids_s[1:] != ids_s[:-1]])
        d_s = jnp.where(first & (ids_s < n), d_s, _INF)
        neg, idx = jax.lax.top_k(-d_s, k)
        ids = jnp.take(ids_s, idx)
        return -neg, jnp.where(jnp.isfinite(-neg), ids, n)

    return jax.vmap(one)(d, i)


@partial(jax.jit, static_argnames=("k", "steps", "engine", "interpret", "with_stats"))
def search_batch_fixed_ref(
    index: DBLSHIndex,
    Q: jax.Array,
    k: int = 0,
    r0: float = 1.0,
    steps: int = 8,
    engine: str = "jnp",
    interpret=None,
    with_stats: bool = False,
):
    """Multi-pass reference: re-select, re-gather, and re-verify at every
    radius (the pre-one-pass serving algorithm, preserved verbatim).

    Used by the equivalence tests as the oracle for
    :func:`search_batch_fixed` (``exact=True`` pins bit-equal distances)
    and by ``benchmarks/search_hotpath.py`` as the speedup baseline.
    ``with_stats`` keeps the old accounting: every selected block slot
    recounts at every step it remains selected.
    """
    validate_engine(engine)
    p = index.params
    k = k or p.k
    n = index.n
    Qn = Q.shape[0]
    nb = index.nb
    B = p.block_size

    G = jnp.einsum("lkd,qd->qlk", index.proj_vecs, Q)  # (Qn, L, K)

    best_d = jnp.full((Qn, k), _INF)
    best_i = jnp.full((Qn, k), n, jnp.int32)
    done = jnp.zeros((Qn,), bool)
    radius_steps = jnp.zeros((Qn,), jnp.int32)
    candidates = jnp.zeros((Qn,), jnp.int32)

    r = jnp.asarray(r0, jnp.float32)
    for _ in range(steps):
        w = p.w0 * r
        blk, _ = _select_blocks(index, G, w)  # (L, Qn, M)
        if with_stats:
            active = ~done
            radius_steps = radius_steps + active.astype(jnp.int32)
            n_slots = jnp.sum((blk < nb).astype(jnp.int32), axis=(0, 2)) * B
            candidates = candidates + jnp.where(active, n_slots, 0)

        step_d = jnp.full((Qn, k), _INF)
        step_i = jnp.full((Qn, k), n, jnp.int32)
        for li in range(p.L):
            g_l = G[:, li, :]
            if engine == "inline":
                d_l, i_l = kernels.window_verify(
                    blk[li],
                    index.proj_blocks[li],
                    index.vec_blocks[li],
                    index.ids_blocks[li],
                    g_l,
                    Q,
                    w,
                    n=n,
                    k=k,
                    interpret=interpret,
                )
            else:
                pb = jnp.take(index.proj_blocks[li], blk[li], axis=0,
                              mode="fill", fill_value=_INF)  # (Qn,M,B,K)
                ib = jnp.take(index.ids_blocks[li], blk[li], axis=0,
                              mode="fill", fill_value=n)
                if p.inline_vectors:
                    vb = jnp.take(index.vec_blocks[li], blk[li], axis=0,
                                  mode="fill", fill_value=0.0)
                else:
                    vb = jnp.take(index.data, ib.reshape(Qn, -1), axis=0,
                                  mode="fill", fill_value=0.0)
                M = blk.shape[-1]
                cp = pb.reshape(Qn, M * B, p.K)
                cv = vb.reshape(Qn, M * B, -1)
                ci = ib.reshape(Qn, M * B)
                if engine == "kernel":
                    d_l, i_l = kernels.candidate_verify(
                        cp, cv, ci, g_l, Q, w, n=n, k=k, interpret=interpret
                    )
                else:  # 'jnp'
                    inbox = jnp.all(
                        jnp.abs(cp - g_l[:, None, :]) <= 0.5 * w, axis=-1
                    ) & (ci < n)
                    d2 = jnp.sum(jnp.square(cv - Q[:, None, :]), axis=-1)
                    d2 = jnp.where(inbox, d2, _INF)
                    d_l, i_l = jax.lax.top_k(-d2, k)
                    d_l = -d_l
                    i_l = jnp.where(jnp.isfinite(d_l),
                                    jnp.take_along_axis(ci, i_l, 1), n)
            step_d, step_i = _merge_dedup_topk_lexsort(
                step_d, step_i, d_l, i_l, n, k
            )

        # masked merge: finished queries keep their result
        nd, ni = _merge_dedup_topk_lexsort(best_d, best_i, step_d, step_i, n, k)
        best_d = jnp.where(done[:, None], best_d, nd)
        best_i = jnp.where(done[:, None], best_i, ni)
        done = done | (best_d[:, k - 1] <= jnp.square(p.c * r))
        r = r * p.c

    if with_stats:
        stats = {"radius_steps": radius_steps, "candidates": candidates}
        return jnp.sqrt(best_d), best_i, stats
    return jnp.sqrt(best_d), best_i


class PendingSearch:
    """Handle for an issued-but-not-awaited ``search_batch_fixed`` call.

    JAX dispatch is asynchronous: the jitted search returns device
    futures immediately, and the host only stalls when it *reads* them.
    This handle makes the two stages explicit so a serving loop can
    issue batch i+1 (host-side padding, slicing, queue work) while the
    device still executes batch i:

        pending = search_batch_fixed_dispatch(index, Q, k=10)
        ...host work for the next batch...
        dists, ids = pending.result()        # first host sync

    ``ready()`` is a non-blocking readiness probe (used by the store
    scheduler to opportunistically retire in-flight batches).
    """

    __slots__ = ("dists", "ids", "stats", "explain")

    def __init__(self, dists, ids, stats=None, explain=None):
        self.dists = dists
        self.ids = ids
        self.stats = stats
        self.explain = explain  # device-side per-step arrays, or None

    def _leaves(self):
        leaves = [self.dists, self.ids]
        if self.stats is not None:
            leaves.extend(jax.tree_util.tree_leaves(self.stats))
        if self.explain is not None:
            leaves.extend(jax.tree_util.tree_leaves(self.explain))
        return leaves

    def ready(self) -> bool:
        """True once every output buffer has materialized (never blocks)."""
        return all(
            x.is_ready() for x in self._leaves() if hasattr(x, "is_ready")
        )

    def result(self):
        """Block until complete; returns (dists, ids[, stats])."""
        jax.block_until_ready(self._leaves())
        if self.stats is not None:
            return self.dists, self.ids, self.stats
        return self.dists, self.ids


def search_batch_fixed_dispatch(
    index: DBLSHIndex,
    Q: jax.Array,
    k: int = 0,
    r0: float = 1.0,
    steps: int = 8,
    engine: str = "jnp",
    interpret=None,
    with_stats: bool = False,
    exact: bool = False,
    termination: Termination | None = None,
    with_explain: bool = False,
    dtype: str = "fp32",
) -> PendingSearch:
    """Issue a fixed-schedule search without blocking on the device.

    Same arguments and numerics as :func:`search_batch_fixed` (it *is*
    the same compiled program — bit-equality between the overlapped and
    synchronous paths is by construction), but the return value is a
    :class:`PendingSearch` whose ``result()`` performs the only host
    sync.  This is the dispatch half of the store scheduler's two-stage
    pipeline.
    """
    out = search_batch_fixed(
        index, Q, k=k, r0=r0, steps=steps, engine=engine,
        interpret=interpret, with_stats=with_stats, exact=exact,
        termination=termination, with_explain=with_explain, dtype=dtype,
    )
    if with_explain:
        return PendingSearch(out[0], out[1], out[2], out[3])
    if with_stats:
        return PendingSearch(out[0], out[1], out[2])
    return PendingSearch(out[0], out[1])
