"""p-stable LSH hashing for DB-LSH (paper Eq. 3 / Eq. 4).

The dynamic LSH family is ``h(o) = a . o`` with ``a ~ N(0, I_d)`` (Eq. 3).
Two points collide at width ``w`` iff ``|h(o1) - h(o2)| <= w/2``; the
collision probability for points at distance ``tau`` is (Eq. 4)

    p(tau; w) = P(|N(0,1)| <= w / (2 tau)) = erf(w / (2 sqrt(2) tau)).

Observation 1 of the paper (the key to dynamic bucketing): scaling the
width with the radius keeps the family (r, cr, p(1;w0), p(c;w0))-sensitive
for *every* radius r, so one index serves the whole radius schedule.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.special import erf

__all__ = [
    "sample_projections",
    "project",
    "collision_prob",
    "normal_pdf",
    "normal_sf",
]


def sample_projections(key: jax.Array, d: int, K: int, L: int) -> jax.Array:
    """Sample L compound hashes G_i = (h_i1 .. h_iK), i.e. an (L, K, d) tensor
    of i.i.d. standard-normal projection vectors (paper Eq. 6/7)."""
    return jax.random.normal(key, (L, K, d), dtype=jnp.float32)


def project(data: jax.Array, proj: jax.Array) -> jax.Array:
    """Compute G_i(o) for every point and table.

    Args:
      data: (n, d) points.
      proj: (L, K, d) projection vectors.

    Returns:
      (L, n, K) projections — table-major so each table's K-dim space is
      contiguous (this is the layout the STR index consumes).
    """
    # (L, K, d) @ (d, n) -> (L, K, n) -> (L, n, K). One batched MXU matmul.
    return jnp.einsum("lkd,nd->lnk", proj, data, preferred_element_type=jnp.float32)


def normal_pdf(x):
    """pdf f(x) of the standard normal distribution."""
    return jnp.exp(-0.5 * jnp.square(x)) / jnp.sqrt(2.0 * jnp.pi)


def normal_sf(x):
    """Survival function ∫_x^∞ f(t) dt of the standard normal."""
    return 0.5 * (1.0 - erf(x / jnp.sqrt(2.0)))


def collision_prob(tau, w):
    """Collision probability p(tau; w) of the dynamic family (paper Eq. 4).

    p(tau; w) = ∫_{-w/(2 tau)}^{w/(2 tau)} f(t) dt = erf(w / (2 sqrt(2) tau)).
    Monotonically decreasing in tau, increasing in w.
    """
    tau = jnp.asarray(tau, dtype=jnp.float64 if jax.config.read("jax_enable_x64") else jnp.float32)
    return erf(w / (2.0 * jnp.sqrt(2.0) * tau))
