"""Distributed DB-LSH: dataset sharded over the mesh 'data' axis.

Every device builds a *local* DB-LSH index over its n/P slice using the
SAME LSH functions (one PRNG key → identical projection vectors — the
union of per-shard query-centric windows then equals the global window,
so Lemma 1/2 guarantees are unchanged). A query is replicated; each
shard answers a local (c,k)-ANN with the fixed-schedule engine; results
merge with one k-sized all_gather + local top-k (ids are globally
offset, hence disjoint across shards — no dedup needed at the merge).

Collective cost per query batch: one all_gather of (P, Q, k) pairs over
'data' — independent of n. This is the datastore behind
serve/retrieval.py at fleet scale.

The index is mutable in place at fleet scale too: ``insert_sharded`` /
``delete_sharded`` / ``compact_sharded`` are shard_map wrappers over
``core.updates`` (least-loaded insert routing, arithmetic global-id
translation, rebalancing per-shard rebuild with a gathered global id
remap — see the maintenance section below and DESIGN.md §9).

Global ids are **strided**: each shard owns the id segment
``[rank * stride, rank * stride + n_local)`` with ``stride >= n_local``,
so ``gid = rank * stride + local``.  Inserts grow ``n_local`` *within*
the stride and therefore never move an existing id; only
:func:`compact_sharded` (which already returns an id map) renumbers,
when it re-strides for the new per-shard count.  ``stride == n_local``
(the :func:`build_sharded` default) degenerates to dense ids that equal
global data-row indices.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..compat import shard_map as _shard_map

from . import updates as _updates
from .index import DBLSHIndex, build
from .params import DBLSHParams
from .serve_search import search_batch_fixed

__all__ = [
    "ShardedDBLSH",
    "id_stride",
    "build_sharded",
    "search_sharded",
    "shard_live_counts",
    "insert_sharded",
    "delete_sharded",
    "compact_sharded",
]

_INF = jnp.inf


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["index"],
    meta_fields=["axis", "n_total", "n_local", "stride"],
)
@dataclasses.dataclass
class ShardedDBLSH:
    index: DBLSHIndex  # arrays sharded over `axis` (see _index_specs)
    axis: str
    n_total: int
    n_local: int
    stride: int  # id segment width per shard: gid = rank * stride + local

    @property
    def id_space(self) -> int:
        """Exclusive upper bound of the global id space (and the merge
        sentinel for unfilled result slots): ``P * stride``."""
        return (self.n_total // self.n_local) * self.stride


def id_stride(n_local: int, headroom: float = 2.0, reserve: int = 0) -> int:
    """Pick a per-shard id stride with insert headroom.

    ``headroom`` scales the stride past the current per-shard count so
    ids stay stable across inserts until ``n_local`` reaches the stride;
    ``reserve`` additionally guarantees room for a known incoming batch.
    Always at least ``n_local + 1`` so one insert fits."""
    n_local = max(int(n_local), 1)
    return max(
        int(math.ceil(headroom * n_local)),
        n_local + 1,
        n_local + int(reserve),
    )


def _index_specs(axis: str, params) -> DBLSHIndex:
    """PartitionSpecs for each DBLSHIndex field (block dim sharded)."""
    return DBLSHIndex(
        proj_vecs=P(),          # same hash functions everywhere
        proj_blocks=P(None, axis),
        ids_blocks=P(None, axis),
        mbr_lo=P(None, axis),
        mbr_hi=P(None, axis),
        data=P(axis),
        vec_blocks=P(None, axis) if params.inline_vectors else P(),
        norm_blocks=P(None, axis),
        qvec_blocks=P(None, axis) if params.quant_dtype != "none" else P(),
        qvec_scale=P(None, axis) if params.quant_dtype != "none" else P(),
        params=params,
    )


def build_sharded(key, data, params_local: DBLSHParams, mesh,
                  axis: str = "data", *, stride: int | None = None
                  ) -> ShardedDBLSH:
    """data: (n, d) global (sharded or shardable over `axis`).

    ``stride`` sets the per-shard id segment width (default ``n_local``:
    dense ids that double as global data-row indices).  Pass
    :func:`id_stride` headroom when the index will take inserts and ids
    must survive them."""
    n, d = data.shape
    pn = mesh.shape[axis]
    assert n % pn == 0, (n, pn)
    n_local = n // pn
    stride = n_local if stride is None else int(stride)
    assert stride >= n_local, (stride, n_local)
    params_local = dataclasses.replace(params_local, n=n_local, d=d).resolve()

    def local_build(data_l):
        return build(key, data_l, params_local)

    specs = _index_specs(axis, params_local)
    idx = jax.jit(
        _shard_map(
            local_build, mesh=mesh, in_specs=P(axis), out_specs=specs,
        )
    )(data)
    return ShardedDBLSH(index=idx, axis=axis, n_total=n, n_local=n_local,
                        stride=stride)


@partial(jax.jit, static_argnames=("k", "steps", "mesh", "with_stats",
                                   "exact", "termination", "with_explain",
                                   "dtype"))
def search_sharded(s: ShardedDBLSH, Q: jax.Array, k: int = 0, r0: float = 1.0,
                   steps: int = 8, mesh=None, with_stats: bool = False,
                   exact: bool = False, termination=None,
                   with_explain: bool = False, dtype: str = "fp32"):
    """Replicated queries -> (Q, k) global distances/ids.

    Returned ids live in the strided space ``gid = rank * stride +
    local``; unfilled slots carry the sentinel ``s.id_space`` (always
    mask on the distances — +inf marks an unfilled slot).

    With ``with_stats`` the per-shard probe statistics survive the
    collective merge instead of being dropped at the boundary: a third
    return aggregates them per query — ``candidates`` is the psum over
    shards (total distinct slots fetched fleet-wide on the query's
    behalf) and ``radius_steps`` the pmax (the schedule runs lockstep,
    so the slowest shard's step count is the query's wall-clock probe
    depth).

    ``termination`` (a :class:`~repro.core.serve_search.Termination`)
    applies *per shard*: each device evaluates the C1/C2 done masks over
    its local candidates and exits its own while_loop independently (no
    collectives inside the loop).  This is sound and conservative — a
    shard's local k-th distance upper-bounds the global k-th, so local
    C2 never fires before the global condition would, and local C1 sees
    only the shard's own verified slots.

    ``with_explain`` (implies ``with_stats``) additionally returns the
    per-shard EXPLAIN arrays *before* the pmax/psum collapse — the
    ``repro.obs.explain`` attribution feed.  One extra all_gather of the
    small per-query counters (no candidate data moves):

    * ``shard_steps`` (P, Qn), ``shard_slots`` (P, Qn),
      ``shard_cause`` (P, Qn) — each shard's schedule depth, verified
      slots, and terminate cause for every query;
    * ``step_slots`` (Qn, steps) — fleet-wide admitted-delta slots per
      step (psum over shards; rows sum to ``stats['candidates']``);
    * ``step_half`` (steps,), ``term_cause`` / ``final_radius`` (Qn,) —
      the critical path's view: the cause/radius on the shard that ran
      deepest (which set the pmax'd ``radius_steps``)."""
    p = s.index.params
    k = k or p.k
    axis = s.axis
    n_local, stride = s.n_local, s.stride
    space = s.id_space  # merge sentinel: one past the last valid gid
    if with_explain:
        with_stats = True

    def local_search(idx_tree, Qr):
        out = search_batch_fixed(
            idx_tree, Qr, k=k, r0=r0, steps=steps, with_stats=with_stats,
            exact=exact, termination=termination, with_explain=with_explain,
            dtype=dtype,
        )
        d, i = out[0], out[1]
        rank = jax.lax.axis_index(axis)
        gi = jnp.where(i < n_local, i + rank * stride, space)
        d_all = jax.lax.all_gather(d, axis)  # (P, Qn, k)
        i_all = jax.lax.all_gather(gi, axis)
        Qn = Qr.shape[0]
        d_flat = jnp.moveaxis(d_all, 0, 1).reshape(Qn, -1)
        i_flat = jnp.moveaxis(i_all, 0, 1).reshape(Qn, -1)
        d2 = jnp.where(jnp.isfinite(d_flat), d_flat, _INF)
        neg, pos = jax.lax.top_k(-d2, k)
        ids = jnp.take_along_axis(i_flat, pos, axis=1)
        merged = (-neg, jnp.where(jnp.isfinite(-neg), ids, space))
        if with_stats:
            stats = {
                "radius_steps": jax.lax.pmax(out[2]["radius_steps"], axis),
                "candidates": jax.lax.psum(out[2]["candidates"], axis),
            }
            merged = merged + (stats,)
        if with_explain:
            lex = out[3]
            shard_steps = jax.lax.all_gather(out[2]["radius_steps"], axis)
            shard_slots = jax.lax.all_gather(out[2]["candidates"], axis)
            shard_cause = jax.lax.all_gather(lex["term_cause"], axis)
            shard_radius = jax.lax.all_gather(lex["final_radius"], axis)
            # critical path = the shard whose schedule ran deepest (ties
            # break to the lowest rank, matching pmax's value)
            crit = jnp.argmax(shard_steps, axis=0)  # (Qn,)
            take = lambda a: jnp.take_along_axis(a, crit[None], axis=0)[0]
            explain = {
                "step_half": lex["step_half"],  # replicated: same schedule
                "step_slots": jax.lax.psum(lex["step_slots"], axis),
                "term_cause": take(shard_cause),
                "final_radius": take(shard_radius),
                "shard_steps": shard_steps,
                "shard_slots": shard_slots,
                "shard_cause": shard_cause,
            }
            merged = merged + (explain,)
        return merged

    specs = _index_specs(axis, p)
    out_specs = (P(), P())
    if with_stats:
        out_specs = out_specs + ({"radius_steps": P(), "candidates": P()},)
    if with_explain:
        out_specs = out_specs + ({
            "step_half": P(), "step_slots": P(), "term_cause": P(),
            "final_radius": P(), "shard_steps": P(), "shard_slots": P(),
            "shard_cause": P(),
        },)
    return _shard_map(
        local_search, mesh=mesh,
        in_specs=(specs, P()), out_specs=out_specs,
    )(s.index, Q)


# --------------------------------------------------------------------------
# Sharded index maintenance: shard_map wrappers over ``core.updates``.
#
# SPMD keeps every shard's array shapes identical, so a mutation that
# logically touches one shard still runs on all of them: *insert*
# replicates the new batch to every shard and immediately tombstones the
# copies on all but the routed target; *delete* translates global ids to
# (shard, local) pairs arithmetically inside the map; *compact*
# rebalances survivors across shards (one all_to_all of rows) and
# rebuilds every shard at the balanced count (padding rows are
# tombstoned in the same trace).  Global ids are strided —
# ``gid = rank * stride + local`` with ``stride >= n_local`` — which
# keeps the disjoint-id merge invariant of :func:`search_sharded` AND
# keeps every existing id fixed across inserts: ``n_local`` grows inside
# the stride, the rank offset never moves.  Only compaction renumbers
# (it re-strides for the new count) and it returns the id map; the store
# layer (``store.lifecycle``) owns communicating that remap.
# --------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("mesh",))
def shard_live_counts(s: ShardedDBLSH, mesh=None) -> jax.Array:
    """Per-shard live (non-tombstoned) point counts, shape (P,) int32 —
    the routing signal for least-loaded insert placement."""
    p = s.index.params
    axis = s.axis

    def local_count(idx):
        return jnp.sum(idx.ids_blocks[0] < p.n, dtype=jnp.int32)[None]

    return _shard_map(
        local_count, mesh=mesh,
        in_specs=(_index_specs(axis, p),), out_specs=P(axis),
    )(s.index)


@partial(jax.jit, static_argnames=("mesh",))
def insert_sharded(
    s: ShardedDBLSH, new_points: jax.Array, target, mesh=None
) -> ShardedDBLSH:
    """Append ``new_points`` (m, d) to shard ``target``.

    Every shard appends the replicated batch (uniform SPMD shapes) and
    all but the target tombstone their copy in the same trace, so only
    the target's rows are live.  The inserted points' global ids are
    ``target * stride + n_local_old + j`` and every pre-existing id is
    untouched: ``n_local`` grows *within* the stride.  Raises when the
    batch would overflow the stride — that is the one renumbering event,
    and it belongs to :func:`compact_sharded`.  ``target`` is traced
    (not static), so routing to a different shard reuses the compiled
    program.
    """
    p = s.index.params
    m = int(new_points.shape[0])
    axis = s.axis
    n_old = s.n_local
    n_new = n_old + m
    if n_new > s.stride:
        raise ValueError(
            f"insert_sharded: id stride exhausted (n_local {n_old} + {m} "
            f"inserted > stride {s.stride}); compact_sharded() renumbers "
            "into a fresh stride with headroom"
        )
    pn = mesh.shape[axis]
    new_params = _updates.grown_params(p, n_new)

    def local_insert(idx, pts, tgt):
        idx2 = _updates.insert(idx, pts)
        rank = jax.lax.axis_index(axis)
        appended = jnp.arange(m, dtype=jnp.int32) + n_old
        # the target keeps its copy live: point its delete at the
        # sentinel id (a no-op); every other shard tombstones the batch
        del_ids = jnp.where(rank == tgt, jnp.int32(n_new), appended)
        return _updates.delete(idx2, del_ids)

    idx = _shard_map(
        local_insert, mesh=mesh,
        in_specs=(_index_specs(axis, p), P(), P()),
        out_specs=_index_specs(axis, new_params),
    )(s.index, jnp.asarray(new_points, jnp.float32),
      jnp.asarray(target, jnp.int32))
    return ShardedDBLSH(index=idx, axis=axis, n_total=pn * n_new,
                        n_local=n_new, stride=s.stride)


@partial(jax.jit, static_argnames=("mesh",))
def delete_sharded(s: ShardedDBLSH, gids: jax.Array, mesh=None) -> ShardedDBLSH:
    """Tombstone global ids: each shard translates ``gids`` to its local
    id space (``local = g % stride`` iff ``g // stride == rank``, the
    sentinel otherwise) and runs :func:`core.updates.delete` locally.
    A gid pointing into a shard's stride *headroom* (``g % stride >=
    n_local``) matches nothing — deleting an unallocated id is a no-op,
    like deleting a tombstone."""
    p = s.index.params
    axis = s.axis
    n_local, stride = s.n_local, s.stride

    def local_delete(idx, g):
        rank = jax.lax.axis_index(axis)
        local = jnp.where(g // stride == rank, g % stride, n_local)
        return _updates.delete(idx, local.astype(jnp.int32))

    specs = _index_specs(axis, p)
    idx = _shard_map(
        local_delete, mesh=mesh, in_specs=(specs, P()), out_specs=specs,
    )(s.index, jnp.atleast_1d(jnp.asarray(gids, jnp.int32)))
    return ShardedDBLSH(
        index=idx, axis=axis, n_total=s.n_total, n_local=n_local,
        stride=stride,
    )


@partial(jax.jit, static_argnames=("mesh", "n_keep", "src_pad", "bucket",
                                   "stride_new", "new_params"))
def _compact_sharded_jit(s: ShardedDBLSH, key, targets, send_start, send_cnt,
                         reasm, src_off, newgid_by_ord, mesh=None, n_keep=0,
                         src_pad=0, bucket=0, stride_new=0, new_params=None):
    """Traced half of :func:`compact_sharded`.

    All routing decisions (``targets`` … ``newgid_by_ord``) are computed
    on host from the per-shard live counts and ride in as replicated
    arrays — the trace itself is just gather, one all_to_all, rebuild,
    tombstone-pad, and the id-map scatter.  Shapes (``n_keep``,
    ``src_pad``, ``bucket``) are static so repeated compacts at the same
    geometry reuse the compiled program while the routing *values* flow.
    """
    p = s.index.params
    axis = s.axis
    n_old = s.n_local
    stride_old = s.stride
    pn = mesh.shape[axis]
    d = p.d

    def local_compact(idx, targets, send_start, send_cnt, reasm, src_off,
                      newgid_by_ord):
        rank = jax.lax.axis_index(axis)
        live_sorted = _updates.live_ids_padded(idx)  # (n_old + 1,) asc
        surv = live_sorted[:src_pad]  # local survivor ids, sentinel n_old
        rows = jnp.take(idx.data, surv, axis=0, mode="fill", fill_value=0.0)
        rows = jnp.concatenate(
            [rows, jnp.zeros((1, d), rows.dtype)]
        )  # slot src_pad: the send-padding row
        # --- migration: bucket survivors by destination shard ----------
        # survivors are globally ordered by (rank, local id); the host
        # split that order into balanced contiguous destination ranges,
        # so each (src, dst) pair exchanges one contiguous run, padded
        # to the fleet-wide max run length for the collective
        t = jnp.arange(bucket, dtype=jnp.int32)
        starts = send_start[rank]  # (P,) first survivor rank per dst
        cnts = send_cnt[rank]      # (P,) run length per dst
        send_idx = jnp.where(
            t[None, :] < cnts[:, None], starts[:, None] + t[None, :], src_pad
        )
        send = jnp.take(rows, send_idx.reshape(-1), axis=0)
        send = send.reshape(pn, bucket, d)
        recv = jax.lax.all_to_all(send, axis, 0, 0)  # (P_src, bucket, d)
        recv = jnp.concatenate(
            [recv.reshape(pn * bucket, d), jnp.zeros((1, d), recv.dtype)]
        )  # slot pn * bucket: the reassembly-padding row
        data_new = jnp.take(recv, reasm[rank], axis=0)  # (n_keep, d)
        new_idx = build(key, data_new, new_params)
        slot = jnp.arange(n_keep, dtype=jnp.int32)
        # shards under the balanced max carry padding rows: tombstone
        # them (on a full shard this degenerates to the sentinel)
        pad_ids = jnp.where(slot >= targets[rank], slot, jnp.int32(n_keep))
        new_idx = _updates.delete(new_idx, pad_ids)
        # --- old gid -> new gid over this shard's old stride segment ---
        ords = src_off[rank] + jnp.arange(src_pad, dtype=jnp.int32)
        newgid = jnp.take(newgid_by_ord, ords, mode="fill", fill_value=-1)
        id_map = jnp.full((stride_old,), -1, jnp.int32)
        id_map = id_map.at[surv].set(
            jnp.where(surv < n_old, newgid, -1).astype(jnp.int32),
            mode="drop",  # padded surv entries may fall out of range
        )
        return new_idx, id_map

    return _shard_map(
        local_compact, mesh=mesh,
        in_specs=(_index_specs(axis, p), P(), P(), P(), P(), P(), P()),
        out_specs=(_index_specs(axis, new_params.resolve()), P(axis)),
    )(s.index, targets, send_start, send_cnt, reasm, src_off, newgid_by_ord)


def compact_sharded(
    s: ShardedDBLSH, key, mesh, *, headroom: float = 1.0, reserve: int = 0
) -> tuple[ShardedDBLSH, jax.Array]:
    """Rebalancing rebuild from survivors (fresh K/L for the new n).

    Survivors — ordered by ascending old global id (shard-major, then
    local) — are re-partitioned into *balanced* contiguous runs, one per
    destination shard (counts differ by at most 1), migrated with a
    single padded all_to_all, and every shard rebuilds with the *same*
    fresh key (identical hash functions across shards, the
    :func:`build_sharded` invariant).  Shards under the balanced max pad
    with tombstoned zero rows.  ``headroom`` / ``reserve`` size the new
    id stride via :func:`id_stride` (``headroom=1.0`` keeps dense ids,
    matching the :func:`build_sharded` default).

    Returns ``(new_sharded, id_map)`` with ``id_map`` (id_space_old,)
    mapping each old global id to its new global id, or -1 if deleted
    (stride-headroom holes map to -1 too).  New ids ascend with old ids,
    so a payload scattered through the map stays aligned.
    """
    p = s.index.params
    axis = s.axis
    pn = int(mesh.shape[axis])
    counts = np.asarray(shard_live_counts(s, mesh=mesh)).astype(np.int64)
    total = int(counts.sum())
    if total == 0:
        raise ValueError("compact_sharded: no live points on any shard")
    base, rem = divmod(total, pn)
    targets = (base + (np.arange(pn) < rem)).astype(np.int64)
    n_keep = int(targets.max())
    stride_new = id_stride(n_keep, headroom, reserve)
    # contiguous survivor-ordinal ranges: src shard s owns
    # [src_off[s], src_off[s+1]), dst shard r receives [dst_off[r], ...)
    src_off = np.concatenate([[0], np.cumsum(counts)])
    dst_off = np.concatenate([[0], np.cumsum(targets)])
    lo = np.maximum(src_off[:-1, None], dst_off[None, :-1])  # (P_src, P_dst)
    hi = np.minimum(src_off[1:, None], dst_off[None, 1:])
    send_cnt = np.maximum(hi - lo, 0)
    bucket = max(int(send_cnt.max()), 1)
    send_start = lo - src_off[:-1, None]  # local survivor rank of run start
    # new gid of each global survivor ordinal (the renumbering itself)
    ords = np.arange(total)
    dst = np.clip(np.searchsorted(dst_off, ords, side="right") - 1, 0, pn - 1)
    newgid_by_ord = (dst * stride_new + (ords - dst_off[dst])).astype(np.int32)
    # reassembly: dst shard r, slot j  <-  flat row of its (P, bucket) recv
    o = dst_off[:-1, None] + np.arange(n_keep)[None, :]  # (P_dst, n_keep)
    srcs = np.clip(np.searchsorted(src_off, o, side="right") - 1, 0, pn - 1)
    pos = o - lo[srcs, np.arange(pn)[:, None]]
    valid = np.arange(n_keep)[None, :] < targets[:, None]
    reasm = np.where(valid, srcs * bucket + pos, pn * bucket).astype(np.int64)
    new_params = DBLSHParams.derive(
        n=n_keep, d=p.d, c=p.c, w0=p.w0, t=p.t, k=p.k,
        block_size=p.block_size, inline_vectors=p.inline_vectors,
    )
    idx, id_map = _compact_sharded_jit(
        s, key,
        jnp.asarray(targets, jnp.int32),
        jnp.asarray(send_start, jnp.int32),
        jnp.asarray(send_cnt, jnp.int32),
        jnp.asarray(reasm, jnp.int32),
        jnp.asarray(src_off[:-1], jnp.int32),
        jnp.asarray(newgid_by_ord),
        mesh=mesh, n_keep=n_keep, src_pad=max(int(counts.max()), 1),
        bucket=bucket, stride_new=stride_new, new_params=new_params,
    )
    return (
        ShardedDBLSH(index=idx, axis=axis, n_total=pn * n_keep,
                     n_local=n_keep, stride=stride_new),
        id_map,
    )
