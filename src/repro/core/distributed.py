"""Distributed DB-LSH: dataset sharded over the mesh 'data' axis.

Every device builds a *local* DB-LSH index over its n/P slice using the
SAME LSH functions (one PRNG key → identical projection vectors — the
union of per-shard query-centric windows then equals the global window,
so Lemma 1/2 guarantees are unchanged). A query is replicated; each
shard answers a local (c,k)-ANN with the fixed-schedule engine; results
merge with one k-sized all_gather + local top-k (ids are globally
offset, hence disjoint across shards — no dedup needed at the merge).

Collective cost per query batch: one all_gather of (P, Q, k) pairs over
'data' — independent of n. This is the datastore behind
serve/retrieval.py at fleet scale.

The index is mutable in place at fleet scale too: ``insert_sharded`` /
``delete_sharded`` / ``compact_sharded`` are shard_map wrappers over
``core.updates`` (least-loaded insert routing, arithmetic global-id
translation, per-shard rebuild with a gathered global id remap — see the
maintenance section below and DESIGN.md §9).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..compat import shard_map as _shard_map

from . import updates as _updates
from .index import DBLSHIndex, build
from .params import DBLSHParams
from .serve_search import search_batch_fixed

__all__ = [
    "ShardedDBLSH",
    "build_sharded",
    "search_sharded",
    "shard_live_counts",
    "insert_sharded",
    "delete_sharded",
    "compact_sharded",
]

_INF = jnp.inf


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["index"],
    meta_fields=["axis", "n_total", "n_local"],
)
@dataclasses.dataclass
class ShardedDBLSH:
    index: DBLSHIndex  # arrays sharded over `axis` (see _index_specs)
    axis: str
    n_total: int
    n_local: int


def _index_specs(axis: str, params) -> DBLSHIndex:
    """PartitionSpecs for each DBLSHIndex field (block dim sharded)."""
    return DBLSHIndex(
        proj_vecs=P(),          # same hash functions everywhere
        proj_blocks=P(None, axis),
        ids_blocks=P(None, axis),
        mbr_lo=P(None, axis),
        mbr_hi=P(None, axis),
        data=P(axis),
        vec_blocks=P(None, axis) if params.inline_vectors else P(),
        norm_blocks=P(None, axis),
        params=params,
    )


def build_sharded(key, data, params_local: DBLSHParams, mesh, axis: str = "data"
                  ) -> ShardedDBLSH:
    """data: (n, d) global (sharded or shardable over `axis`)."""
    n, d = data.shape
    pn = mesh.shape[axis]
    assert n % pn == 0, (n, pn)
    n_local = n // pn
    params_local = dataclasses.replace(params_local, n=n_local, d=d).resolve()

    def local_build(data_l):
        return build(key, data_l, params_local)

    specs = _index_specs(axis, params_local)
    idx = jax.jit(
        _shard_map(
            local_build, mesh=mesh, in_specs=P(axis), out_specs=specs,
        )
    )(data)
    return ShardedDBLSH(index=idx, axis=axis, n_total=n, n_local=n_local)


@partial(jax.jit, static_argnames=("k", "steps", "mesh", "with_stats",
                                   "exact", "termination"))
def search_sharded(s: ShardedDBLSH, Q: jax.Array, k: int = 0, r0: float = 1.0,
                   steps: int = 8, mesh=None, with_stats: bool = False,
                   exact: bool = False, termination=None):
    """Replicated queries -> (Q, k) global distances/ids.

    With ``with_stats`` the per-shard probe statistics survive the
    collective merge instead of being dropped at the boundary: a third
    return aggregates them per query — ``candidates`` is the psum over
    shards (total distinct slots fetched fleet-wide on the query's
    behalf) and ``radius_steps`` the pmax (the schedule runs lockstep,
    so the slowest shard's step count is the query's wall-clock probe
    depth).

    ``termination`` (a :class:`~repro.core.serve_search.Termination`)
    applies *per shard*: each device evaluates the C1/C2 done masks over
    its local candidates and exits its own while_loop independently (no
    collectives inside the loop).  This is sound and conservative — a
    shard's local k-th distance upper-bounds the global k-th, so local
    C2 never fires before the global condition would, and local C1 sees
    only the shard's own verified slots."""
    p = s.index.params
    k = k or p.k
    axis = s.axis
    n_local, n_total = s.n_local, s.n_total

    def local_search(idx_tree, Qr):
        out = search_batch_fixed(
            idx_tree, Qr, k=k, r0=r0, steps=steps, with_stats=with_stats,
            exact=exact, termination=termination,
        )
        d, i = out[0], out[1]
        rank = jax.lax.axis_index(axis)
        gi = jnp.where(i < n_local, i + rank * n_local, n_total)
        d_all = jax.lax.all_gather(d, axis)  # (P, Qn, k)
        i_all = jax.lax.all_gather(gi, axis)
        Qn = Qr.shape[0]
        d_flat = jnp.moveaxis(d_all, 0, 1).reshape(Qn, -1)
        i_flat = jnp.moveaxis(i_all, 0, 1).reshape(Qn, -1)
        d2 = jnp.where(jnp.isfinite(d_flat), d_flat, _INF)
        neg, pos = jax.lax.top_k(-d2, k)
        ids = jnp.take_along_axis(i_flat, pos, axis=1)
        merged = (-neg, jnp.where(jnp.isfinite(-neg), ids, n_total))
        if with_stats:
            stats = {
                "radius_steps": jax.lax.pmax(out[2]["radius_steps"], axis),
                "candidates": jax.lax.psum(out[2]["candidates"], axis),
            }
            return merged + (stats,)
        return merged

    specs = _index_specs(axis, p)
    out_specs = (P(), P())
    if with_stats:
        out_specs = out_specs + ({"radius_steps": P(), "candidates": P()},)
    return _shard_map(
        local_search, mesh=mesh,
        in_specs=(specs, P()), out_specs=out_specs,
    )(s.index, Q)


# --------------------------------------------------------------------------
# Sharded index maintenance: shard_map wrappers over ``core.updates``.
#
# SPMD keeps every shard's array shapes identical, so a mutation that
# logically touches one shard still runs on all of them: *insert*
# replicates the new batch to every shard and immediately tombstones the
# copies on all but the routed target; *delete* translates global ids to
# (shard, local) pairs arithmetically inside the map; *compact* rebuilds
# every shard from its own survivors, padded to the fleet-wide max live
# count (padding rows are tombstoned in the same trace).  Global ids are
# placement-relative — ``gid = rank * n_local + local`` — which keeps the
# disjoint-id merge invariant of :func:`search_sharded` intact but means
# any mutation that changes ``n_local`` re-bases existing ids; the store
# layer (``store.lifecycle``) owns communicating those remaps.
# --------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("mesh",))
def shard_live_counts(s: ShardedDBLSH, mesh=None) -> jax.Array:
    """Per-shard live (non-tombstoned) point counts, shape (P,) int32 —
    the routing signal for least-loaded insert placement."""
    p = s.index.params
    axis = s.axis

    def local_count(idx):
        return jnp.sum(idx.ids_blocks[0] < p.n, dtype=jnp.int32)[None]

    return _shard_map(
        local_count, mesh=mesh,
        in_specs=(_index_specs(axis, p),), out_specs=P(axis),
    )(s.index)


@partial(jax.jit, static_argnames=("mesh",))
def insert_sharded(
    s: ShardedDBLSH, new_points: jax.Array, target, mesh=None
) -> ShardedDBLSH:
    """Append ``new_points`` (m, d) to shard ``target``.

    Every shard appends the replicated batch (uniform SPMD shapes) and
    all but the target tombstone their copy in the same trace, so only
    the target's rows are live.  The inserted points' global ids are
    ``target * n_local_new + n_local_old + j``; because ``n_local`` grew,
    every pre-existing global id re-bases arithmetically:
    ``g -> (g // n_local_old) * n_local_new + g % n_local_old``.
    ``target`` is traced (not static), so routing to a different shard
    reuses the compiled program.
    """
    p = s.index.params
    m = int(new_points.shape[0])
    axis = s.axis
    n_old = s.n_local
    n_new = n_old + m
    pn = mesh.shape[axis]
    new_params = dataclasses.replace(p, n=n_new)

    def local_insert(idx, pts, tgt):
        idx2 = _updates.insert(idx, pts)
        rank = jax.lax.axis_index(axis)
        appended = jnp.arange(m, dtype=jnp.int32) + n_old
        # the target keeps its copy live: point its delete at the
        # sentinel id (a no-op); every other shard tombstones the batch
        del_ids = jnp.where(rank == tgt, jnp.int32(n_new), appended)
        return _updates.delete(idx2, del_ids)

    idx = _shard_map(
        local_insert, mesh=mesh,
        in_specs=(_index_specs(axis, p), P(), P()),
        out_specs=_index_specs(axis, new_params),
    )(s.index, jnp.asarray(new_points, jnp.float32),
      jnp.asarray(target, jnp.int32))
    return ShardedDBLSH(index=idx, axis=axis, n_total=pn * n_new, n_local=n_new)


@partial(jax.jit, static_argnames=("mesh",))
def delete_sharded(s: ShardedDBLSH, gids: jax.Array, mesh=None) -> ShardedDBLSH:
    """Tombstone global ids: each shard translates ``gids`` to its local
    id space (``local = g % n_local`` iff ``g // n_local == rank``, the
    sentinel otherwise) and runs :func:`core.updates.delete` locally."""
    p = s.index.params
    axis = s.axis
    n_local = s.n_local

    def local_delete(idx, g):
        rank = jax.lax.axis_index(axis)
        local = jnp.where(g // n_local == rank, g % n_local, n_local)
        return _updates.delete(idx, local.astype(jnp.int32))

    specs = _index_specs(axis, p)
    idx = _shard_map(
        local_delete, mesh=mesh, in_specs=(specs, P()), out_specs=specs,
    )(s.index, jnp.atleast_1d(jnp.asarray(gids, jnp.int32)))
    return ShardedDBLSH(
        index=idx, axis=axis, n_total=s.n_total, n_local=n_local
    )


@partial(jax.jit, static_argnames=("mesh", "n_keep", "new_params"))
def _compact_sharded_jit(s: ShardedDBLSH, key, mesh=None, n_keep=0,
                         new_params=None):
    p = s.index.params
    axis = s.axis
    n_old = s.n_local

    def local_compact(idx):
        live_sorted = _updates.live_ids_padded(idx)  # (n_old + 1,) asc
        sel = live_sorted[:n_keep]
        n_live = jnp.sum(live_sorted < n_old)
        data_new = jnp.take(
            idx.data, sel, axis=0, mode="fill", fill_value=0.0
        )
        new_idx = build(key, data_new, new_params)
        slot = jnp.arange(n_keep, dtype=jnp.int32)
        # shards under the fleet max carry padding rows: tombstone them
        # (on a full shard this degenerates to the sentinel, a no-op)
        pad_ids = jnp.where(slot >= n_live, slot, jnp.int32(n_keep))
        new_idx = _updates.delete(new_idx, pad_ids)
        rank = jax.lax.axis_index(axis)
        id_map = jnp.full((n_old,), -1, jnp.int32)
        id_map = id_map.at[sel].set(
            jnp.where(sel < n_old, slot + rank * n_keep, -1).astype(jnp.int32),
            mode="drop",  # padded sel entries (== n_old) fall out of range
        )
        return new_idx, id_map

    return _shard_map(
        local_compact, mesh=mesh,
        in_specs=(_index_specs(axis, p),),
        out_specs=(_index_specs(axis, new_params.resolve()), P(axis)),
    )(s.index)


def compact_sharded(
    s: ShardedDBLSH, key, mesh
) -> tuple[ShardedDBLSH, jax.Array]:
    """Per-shard rebuild from survivors (fresh K/L for the new n).

    Every shard gathers its live points in ascending local-id order and
    rebuilds with the *same* fresh key (identical hash functions across
    shards, the :func:`build_sharded` invariant).  Uniform SPMD shapes
    force ``n_local_new = max_shard(live)`` — shards below the max pad
    with tombstoned zero rows that the next insert/compact reclaims.
    Points never migrate between shards; least-loaded insert routing is
    what keeps the fleet balanced over time.

    Returns ``(new_sharded, id_map)`` with ``id_map`` (n_total_old,)
    mapping each old global id to its new global id, or -1 if deleted.
    New ids ascend with old ids (shard-major, then local order), so a
    payload permuted through the map stays aligned.
    """
    p = s.index.params
    pn = mesh.shape[s.axis]
    counts = np.asarray(shard_live_counts(s, mesh=mesh))
    n_keep = int(counts.max())
    if n_keep == 0:
        raise ValueError("compact_sharded: no live points on any shard")
    new_params = DBLSHParams.derive(
        n=n_keep, d=p.d, c=p.c, w0=p.w0, t=p.t, k=p.k,
        block_size=p.block_size, inline_vectors=p.inline_vectors,
    )
    idx, id_map = _compact_sharded_jit(
        s, key, mesh=mesh, n_keep=n_keep, new_params=new_params,
    )
    return (
        ShardedDBLSH(index=idx, axis=s.axis, n_total=pn * n_keep,
                     n_local=n_keep),
        id_map,
    )
