"""Distributed DB-LSH: dataset sharded over the mesh 'data' axis.

Every device builds a *local* DB-LSH index over its n/P slice using the
SAME LSH functions (one PRNG key → identical projection vectors — the
union of per-shard query-centric windows then equals the global window,
so Lemma 1/2 guarantees are unchanged). A query is replicated; each
shard answers a local (c,k)-ANN with the fixed-schedule engine; results
merge with one k-sized all_gather + local top-k (ids are globally
offset, hence disjoint across shards — no dedup needed at the merge).

Collective cost per query batch: one all_gather of (P, Q, k) pairs over
'data' — independent of n. This is the datastore behind
serve/retrieval.py at fleet scale.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map as _shard_map

from .index import DBLSHIndex, build
from .params import DBLSHParams
from .serve_search import search_batch_fixed

__all__ = ["ShardedDBLSH", "build_sharded", "search_sharded"]

_INF = jnp.inf


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["index"],
    meta_fields=["axis", "n_total", "n_local"],
)
@dataclasses.dataclass
class ShardedDBLSH:
    index: DBLSHIndex  # arrays sharded over `axis` (see _index_specs)
    axis: str
    n_total: int
    n_local: int


def _index_specs(axis: str, params) -> DBLSHIndex:
    """PartitionSpecs for each DBLSHIndex field (block dim sharded)."""
    return DBLSHIndex(
        proj_vecs=P(),          # same hash functions everywhere
        proj_blocks=P(None, axis),
        ids_blocks=P(None, axis),
        mbr_lo=P(None, axis),
        mbr_hi=P(None, axis),
        data=P(axis),
        vec_blocks=P(None, axis) if params.inline_vectors else P(),
        norm_blocks=P(None, axis),
        params=params,
    )


def build_sharded(key, data, params_local: DBLSHParams, mesh, axis: str = "data"
                  ) -> ShardedDBLSH:
    """data: (n, d) global (sharded or shardable over `axis`)."""
    n, d = data.shape
    pn = mesh.shape[axis]
    assert n % pn == 0, (n, pn)
    n_local = n // pn
    params_local = dataclasses.replace(params_local, n=n_local, d=d).resolve()

    def local_build(data_l):
        return build(key, data_l, params_local)

    specs = _index_specs(axis, params_local)
    idx = jax.jit(
        _shard_map(
            local_build, mesh=mesh, in_specs=P(axis), out_specs=specs,
        )
    )(data)
    return ShardedDBLSH(index=idx, axis=axis, n_total=n, n_local=n_local)


@partial(jax.jit, static_argnames=("k", "steps", "mesh", "with_stats",
                                   "exact", "termination"))
def search_sharded(s: ShardedDBLSH, Q: jax.Array, k: int = 0, r0: float = 1.0,
                   steps: int = 8, mesh=None, with_stats: bool = False,
                   exact: bool = False, termination=None):
    """Replicated queries -> (Q, k) global distances/ids.

    With ``with_stats`` the per-shard probe statistics survive the
    collective merge instead of being dropped at the boundary: a third
    return aggregates them per query — ``candidates`` is the psum over
    shards (total distinct slots fetched fleet-wide on the query's
    behalf) and ``radius_steps`` the pmax (the schedule runs lockstep,
    so the slowest shard's step count is the query's wall-clock probe
    depth).

    ``termination`` (a :class:`~repro.core.serve_search.Termination`)
    applies *per shard*: each device evaluates the C1/C2 done masks over
    its local candidates and exits its own while_loop independently (no
    collectives inside the loop).  This is sound and conservative — a
    shard's local k-th distance upper-bounds the global k-th, so local
    C2 never fires before the global condition would, and local C1 sees
    only the shard's own verified slots."""
    p = s.index.params
    k = k or p.k
    axis = s.axis
    n_local, n_total = s.n_local, s.n_total

    def local_search(idx_tree, Qr):
        out = search_batch_fixed(
            idx_tree, Qr, k=k, r0=r0, steps=steps, with_stats=with_stats,
            exact=exact, termination=termination,
        )
        d, i = out[0], out[1]
        rank = jax.lax.axis_index(axis)
        gi = jnp.where(i < n_local, i + rank * n_local, n_total)
        d_all = jax.lax.all_gather(d, axis)  # (P, Qn, k)
        i_all = jax.lax.all_gather(gi, axis)
        Qn = Qr.shape[0]
        d_flat = jnp.moveaxis(d_all, 0, 1).reshape(Qn, -1)
        i_flat = jnp.moveaxis(i_all, 0, 1).reshape(Qn, -1)
        d2 = jnp.where(jnp.isfinite(d_flat), d_flat, _INF)
        neg, pos = jax.lax.top_k(-d2, k)
        ids = jnp.take_along_axis(i_flat, pos, axis=1)
        merged = (-neg, jnp.where(jnp.isfinite(-neg), ids, n_total))
        if with_stats:
            stats = {
                "radius_steps": jax.lax.pmax(out[2]["radius_steps"], axis),
                "candidates": jax.lax.psum(out[2]["candidates"], axis),
            }
            return merged + (stats,)
        return merged

    specs = _index_specs(axis, p)
    out_specs = (P(), P())
    if with_stats:
        out_specs = out_specs + ({"radius_steps": P(), "candidates": P()},)
    return _shard_map(
        local_search, mesh=mesh,
        in_specs=(specs, P()), out_specs=out_specs,
    )(s.index, Q)
