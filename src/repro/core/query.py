"""DB-LSH query phase (paper §IV-C, Algorithms 1 & 2), TPU-adapted.

A (r,c)-NN probe at radius ``r`` materializes, per table i, the
query-centric hypercubic bucket  W(G_i(q), w0*r)  (Eq. 8) and verifies
the points inside it. c-ANN runs the radius schedule r = r0, c*r0, ...
(Algorithm 2) inside a ``lax.while_loop`` whose carry holds the running
top-k; (c,k)-ANN uses the generalized termination rule from §IV-C:

  * stop when the k-th best verified distance is <= c * r, or
  * when >= 2tL + k distinct points have been verified, or
  * after ``max_radius_steps`` schedule steps (safety bound).

All shapes are static: each (table, radius) probe fetches at most
``M = params.max_blocks`` STR blocks (fixed-capacity compaction) and
verifies at most M*B points; points outside the box — and block slots
beyond the capacity — are masked to +inf. This is the paper's own budget
(it never verifies more than 2tL+1 points either); see DESIGN.md §3.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import hashing
from .index import DBLSHIndex

__all__ = ["search", "search_batch", "rc_nn", "probe_radius", "merge_dedup_topk"]

_INF = jnp.inf
_IMAX = jnp.iinfo(jnp.int32).max


def merge_dedup_topk(run_d, run_i, new_d, new_i, n, k: int):
    """Batched dedup'd top-k merge via k-step vectorized selection.

    The shared merge helper of the serving path (the XLA twin of the
    in-kernel ``kernels.window_verify.merge_topk``): ``k`` rounds of
    min-reduce + one-hot select over the concatenated candidate axis.
    No sort — pure VPU min/compare/select, O(k * C) per query instead of
    the O(C log C) ``lexsort`` it replaces, and C-invariant ties resolve
    to the smallest id.

    Cross-table duplicates of one point carry identical (dist, id) pairs
    (the exact distance is a function of the id alone), so dropping every
    entry equal to the selected pair after each round performs exact
    dedup for free.

    Args:
      run_d/run_i: (Q, a) running top-k (ascending, +inf / ``n`` padded).
      new_d/new_i: (Q, b) fresh candidates (masked slots +inf).
      n: invalid-id sentinel; k: top-k.

    Returns: (Q, k) distances ascending, (Q, k) ids (``n`` when unfilled).
    """
    cd = jnp.concatenate([run_d, new_d], axis=1)  # (Q, a+b)
    ci = jnp.concatenate([run_i, new_i], axis=1).astype(jnp.int32)
    Qn = cd.shape[0]
    idxk = jax.lax.iota(jnp.int32, k)[None, :]  # (1, k)

    def body(j, carry):
        cd, nd, ni = carry
        m = jnp.min(cd, axis=1, keepdims=True)  # (Q, 1)
        finite = jnp.isfinite(m)
        eq = cd == m
        sel = jnp.min(jnp.where(eq, ci, _IMAX), axis=1, keepdims=True)
        oh = idxk == j  # (1, k)
        nd = jnp.where(oh, m, nd)
        ni = jnp.where(oh & finite, sel, ni)
        cd = jnp.where(eq & (ci == sel), _INF, cd)
        return cd, nd, ni

    init = (
        cd,
        jnp.full((Qn, k), _INF, cd.dtype),
        jnp.full((Qn, k), n, jnp.int32),
    )
    _, nd, ni = jax.lax.fori_loop(0, k, body, init)
    return nd, ni


def _scan_one_table(proj_blocks, ids_blocks, mbr_lo, mbr_hi, vec_blocks, data, g, w, params):
    """Window query W(g, w) against one table. Returns (dist2, ids) of shape
    (M*B,) with +inf / n for masked slots."""
    nb, B, K = proj_blocks.shape
    M = params.max_blocks
    n = data.shape[0]
    lo = g - 0.5 * w
    hi = g + 0.5 * w

    overlap = jnp.all((mbr_lo <= hi) & (mbr_hi >= lo), axis=-1)  # (nb,)
    # Fixed-capacity, query-centric compaction: of the overlapping blocks,
    # take the M whose MBRs are *nearest the query projection* (classic
    # R-tree MINDIST ordering). Under budget pressure this prioritizes the
    # candidates most likely to be true neighbors — the verification-order
    # analogue of the paper's query-centric bucketing.
    mindist = jnp.sum(
        jnp.square(jnp.maximum(mbr_lo - g, 0.0) + jnp.maximum(g - mbr_hi, 0.0)),
        axis=-1,
    )  # (nb,)
    score = jnp.where(overlap, mindist, _INF)
    _, blk = jax.lax.top_k(-score, M)  # (M,) best-first
    blk = jnp.where(jnp.take(overlap, blk), blk, nb)
    pb = jnp.take(proj_blocks, blk, axis=0, mode="fill", fill_value=_INF)  # (M,B,K)
    ib = jnp.take(ids_blocks, blk, axis=0, mode="fill", fill_value=n)  # (M,B)

    inbox = jnp.all((pb >= lo) & (pb <= hi), axis=-1) & (ib < n)  # (M,B)

    if params.inline_vectors:
        xb = jnp.take(vec_blocks, blk, axis=0, mode="fill", fill_value=0.0)  # (M,B,d)
    else:
        xb = jnp.take(data, ib.reshape(-1), axis=0, mode="fill", fill_value=0.0)
        xb = xb.reshape(M, B, -1)

    return inbox, xb, ib


def _verify_jnp(inbox, xb, ib, q):
    """Pure-jnp verification: exact squared L2 for in-box points."""
    d2 = jnp.sum(jnp.square(xb - q), axis=-1)  # (M,B)
    d2 = jnp.where(inbox, d2, _INF)
    return d2.reshape(-1), ib.reshape(-1)


def probe_radius(index: DBLSHIndex, q: jax.Array, g_all: jax.Array, w) -> tuple:
    """All-L-tables probe at one width ``w``: returns flat (dist2, ids) of
    shape (L*M*B,)."""
    p = index.params

    if p.inline_vectors:
        vecs = index.vec_blocks
    else:
        vecs = jnp.zeros((p.L, 0), dtype=index.data.dtype)

    def scan_i(pb, ib_, lo_, hi_, vb, g):
        inbox, xb, ib = _scan_one_table(pb, ib_, lo_, hi_, vb, index.data, g, w, p)
        return _verify_jnp(inbox, xb, ib, q)

    d2, ids = jax.vmap(scan_i)(
        index.proj_blocks, index.ids_blocks, index.mbr_lo, index.mbr_hi, vecs, g_all
    )
    return d2.reshape(-1), ids.reshape(-1)


def _dedup_merge(best_d2, best_id, new_d2, new_id, n, k):
    """Merge the running top-k with freshly verified candidates, dropping
    duplicate ids (the same point found in several tables / radii).

    Returns (top-k dist2 ascending, top-k ids, #distinct finite verified
    among `new`)."""
    d2 = jnp.concatenate([best_d2, new_d2])
    ids = jnp.concatenate([best_id, new_id])
    # lexsort: primary ids, secondary dist -> first slot of an id group is
    # its best (finite) distance.
    order = jnp.lexsort((d2, ids))
    ids_s = jnp.take(ids, order)
    d2_s = jnp.take(d2, order)
    first = jnp.concatenate([jnp.ones((1,), bool), ids_s[1:] != ids_s[:-1]])
    valid = first & (ids_s < n) & jnp.isfinite(d2_s)
    d2_s = jnp.where(valid, d2_s, _INF)
    # distinct finite among the *new* candidates only (exclude carried best):
    new_sorted = jnp.lexsort((new_d2, new_id))
    nids = jnp.take(new_id, new_sorted)
    nd2 = jnp.take(new_d2, new_sorted)
    nfirst = jnp.concatenate([jnp.ones((1,), bool), nids[1:] != nids[:-1]])
    n_verified = jnp.sum(nfirst & (nids < n) & jnp.isfinite(nd2))

    neg_top, top_idx = jax.lax.top_k(-d2_s, k)
    return -neg_top, jnp.take(ids_s, top_idx), n_verified


@partial(jax.jit, static_argnames=("k",))
def search(index: DBLSHIndex, q: jax.Array, k: int = 0, r0: float = 1.0):
    """(c,k)-ANN search for a single query (Algorithm 2 + §IV-C k-NN rules).

    Args:
      index: built DBLSHIndex.
      q: (d,) query point.
      k: number of neighbors (default params.k).
      r0: initial search radius (paper: 1; callers may pass a data-scale
          estimate).

    Returns:
      (dists, ids): (k,) ascending L2 distances and point ids. Slots that
      were never filled hold +inf / n.
    """
    p = index.params
    k = k or p.k
    n = index.n
    g_all = jnp.einsum("lkd,d->lk", index.proj_vecs, q)  # G_i(q), i=1..L

    best_d2 = jnp.full((k,), _INF)
    best_id = jnp.full((k,), n, jnp.int32)

    def cond(state):
        j, r, bd, bi, nver, done = state
        return (~done) & (j < p.max_radius_steps)

    def body(state):
        j, r, bd, bi, nver, done = state
        w = p.w0 * r
        new_d2, new_id = probe_radius(index, q, g_all, w)
        bd, bi, n_new = _dedup_merge(bd, bi, new_d2, new_id, n, k)
        # windows nest across radii: distinct-this-radius is the running
        # distinct total (see DESIGN.md §3).
        nver = jnp.maximum(nver, n_new)
        kth = bd[k - 1]
        done = (kth <= jnp.square(p.c * r)) | (nver >= p.budget)
        return j + 1, r * p.c, bd, bi, nver, done

    state = (jnp.asarray(0), jnp.asarray(r0, jnp.float32), best_d2, best_id,
             jnp.asarray(0, jnp.int32), jnp.asarray(False))
    _, _, best_d2, best_id, _, _ = jax.lax.while_loop(cond, body, state)
    return jnp.sqrt(best_d2), best_id


@partial(jax.jit, static_argnames=("k",))
def search_batch(index: DBLSHIndex, Q: jax.Array, k: int = 0, r0: float = 1.0):
    """Batched (c,k)-ANN: vmap of :func:`search` over the query axis."""
    return jax.vmap(lambda q: search(index, q, k=k or index.params.k, r0=r0))(Q)


@partial(jax.jit, static_argnames=("k",))
def rc_nn(index: DBLSHIndex, q: jax.Array, r: float, k: int = 1):
    """Single (r,c)-NN probe (Algorithm 1): one window per table at width
    w0*r; returns the best k verified points (+inf/n when none found —
    the paper's 'return nothing')."""
    p = index.params
    n = index.n
    g_all = jnp.einsum("lkd,d->lk", index.proj_vecs, q)
    d2, ids = probe_radius(index, q, g_all, p.w0 * jnp.asarray(r, jnp.float32))
    bd = jnp.full((k,), _INF)
    bi = jnp.full((k,), n, jnp.int32)
    bd, bi, _ = _dedup_merge(bd, bi, d2, ids, n, k)
    return jnp.sqrt(bd), bi
