"""Parameter derivation for DB-LSH (paper §III-C, §V).

Given (n, c, w0, t) this module derives

    p1   = p(1; w0),  p2 = p(c; w0)              (Lemma 1)
    rho* = ln(1/p1) / ln(1/p2)
    K    = ceil( log_{1/p2}(n / t) )
    L    = ceil( (n / t)^{rho*} )

and the Lemma-3 bound machinery:

    alpha(gamma) = gamma * f(gamma) / ∫_gamma^∞ f(x) dx      (= xi(gamma))
    rho* <= 1 / c^alpha  for  w0 = 2 gamma c^2.

At gamma = 2 (w0 = 4 c^2) alpha = 4.7467 — the paper's headline constant.
"""

from __future__ import annotations

import dataclasses
import math

from .hashing import collision_prob

__all__ = ["DBLSHParams", "alpha_of_gamma", "rho_star"]


def _erf(x: float) -> float:
    return math.erf(x)


def _p(tau: float, w: float) -> float:
    """Closed-form collision probability (float64 host-side twin of Eq. 4)."""
    return _erf(w / (2.0 * math.sqrt(2.0) * tau))


def _log_inv_p(tau: float, w: float) -> float:
    """ln(1/p(tau; w)) computed stably when p -> 1 (large w/tau):
    ln(1/p) = -log1p(-erfc(x)), erfc keeps precision where erf saturates."""
    x = w / (2.0 * math.sqrt(2.0) * tau)
    ec = math.erfc(x)
    if ec >= 1.0:
        return math.inf
    return -math.log1p(-ec)


def _log_erfc(x: float) -> float:
    """log(erfc(x)) without underflow (asymptotic expansion past x ~ 25)."""
    if x < 25.0:
        return math.log(math.erfc(x))
    # erfc(x) ~ exp(-x^2) / (x sqrt(pi)) * (1 - 1/(2x^2) + ...)
    return -x * x - math.log(x * math.sqrt(math.pi)) + math.log1p(-0.5 / (x * x))


def _log_log_inv_p(tau: float, w: float) -> float:
    """log( ln(1/p(tau; w)) ), stable over the entire width range."""
    x = w / (2.0 * math.sqrt(2.0) * tau)
    ec = math.erfc(x)
    if ec > 1e-8:
        return math.log(-math.log1p(-ec))
    # ln(1/p) = -log1p(-ec) ~ ec for tiny ec, so log(ln(1/p)) ~ log(ec).
    return _log_erfc(x)


def alpha_of_gamma(gamma: float) -> float:
    """xi(gamma) = gamma f(gamma) / ∫_gamma^∞ f  (Lemma 3).

    Monotonically increasing for gamma > 0; xi(2) = 4.7467.
    """
    pdf = math.exp(-0.5 * gamma * gamma) / math.sqrt(2.0 * math.pi)
    sf = 0.5 * (1.0 - math.erf(gamma / math.sqrt(2.0)))
    return gamma * pdf / sf


def rho_star(c: float, w0: float) -> float:
    """rho* = ln(1/p1)/ln(1/p2) with p1 = p(1; w0), p2 = p(c; w0).

    Computed in log space so it stays positive and accurate even when the
    collision probabilities are within 1e-300 of 1 (very wide buckets)."""
    return math.exp(log_rho_star(c, w0))


def log_rho_star(c: float, w0: float) -> float:
    """log(rho*) — usable even where rho* itself underflows float64."""
    return _log_log_inv_p(1.0, w0) - _log_log_inv_p(c, w0)


@dataclasses.dataclass(frozen=True)
class DBLSHParams:
    """Resolved DB-LSH hyper-parameters.

    Attributes mirror the paper's notation. ``block_size``/``max_blocks``/
    ``cand_per_table`` are the TPU-adaptation knobs (static shapes for the
    fixed-capacity window scan, see DESIGN.md §3); the paper's candidate
    budget 2tL + k is enforced through them.
    """

    n: int
    d: int
    c: float = 1.5
    w0: float = 4.0 * 1.5 * 1.5  # 4 c^2, i.e. gamma = 2
    t: int = 100
    k: int = 50
    K: int = 0  # 0 -> derive
    L: int = 0  # 0 -> derive
    # --- TPU static-shape knobs ---
    block_size: int = 64          # B: points per STR block (leaf MBR granularity)
    max_blocks: int = 0           # M: blocks fetched per (table, radius); 0 -> derive
    max_radius_steps: int = 24    # safety bound on the r = c^j schedule
    inline_vectors: bool = False  # 'inline' layout: per-table reordered vector copy
    use_kernel: bool = False      # route verification through the Pallas kernel
    quant_dtype: str = "none"     # 'bf16'/'int8': keep quantized vec blocks for
                                  # the reduced-precision distance path

    # --- derived (filled by .resolve()) ---
    p1: float = 0.0
    p2: float = 0.0
    rho: float = 0.0

    @staticmethod
    def derive(
        n: int,
        d: int,
        c: float = 1.5,
        w0: float | None = None,
        t: int = 100,
        k: int = 50,
        K: int = 0,
        L: int = 0,
        **kw,
    ) -> "DBLSHParams":
        if w0 is None:
            w0 = 4.0 * c * c
        p1 = _p(1.0, w0)
        p2 = _p(c, w0)
        rho = rho_star(c, w0)
        nt = max(n / max(t, 1), 2.0)
        if K <= 0:
            K = max(2, math.ceil(math.log(nt) / _log_inv_p(c, w0)))
        if L <= 0:
            L = max(1, math.ceil(nt**rho))
        params = DBLSHParams(
            n=n, d=d, c=c, w0=w0, t=t, k=k, K=K, L=L, p1=p1, p2=p2, rho=rho, **kw
        )
        return params.resolve()

    def resolve(self) -> "DBLSHParams":
        """Fill derived fields; idempotent."""
        if self.quant_dtype not in ("none", "bf16", "int8"):
            raise ValueError(
                f"quant_dtype must be 'none', 'bf16' or 'int8', "
                f"got {self.quant_dtype!r}"
            )
        upd: dict = {}
        if self.p1 == 0.0:
            upd["p1"] = _p(1.0, self.w0)
            upd["p2"] = _p(self.c, self.w0)
            upd["rho"] = rho_star(self.c, self.w0)
        if self.max_blocks <= 0:
            # Budget: per table we want to be able to verify >= 2t + k points
            # (L tables -> >= 2tL + kL >= the paper's 2tL + k budget), plus
            # slack x2 because an overlapping block is only partially in-box.
            per_table = 2 * self.t + self.k
            m = max(4, math.ceil(2.0 * per_table / self.block_size))
            upd["max_blocks"] = min(m, max(1, math.ceil(self.n / self.block_size)))
        if not upd:
            return self
        return dataclasses.replace(self, **upd)

    @property
    def cand_per_table(self) -> int:
        return self.max_blocks * self.block_size

    @property
    def budget(self) -> int:
        """The paper's termination budget 2tL + k."""
        return 2 * self.t * self.L + self.k

    def alpha(self) -> float:
        """alpha implied by w0 = 2 gamma c^2 (Lemma 3)."""
        gamma = self.w0 / (2.0 * self.c * self.c)
        return alpha_of_gamma(gamma)
