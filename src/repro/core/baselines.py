"""Competitor/baseline methods the paper compares against (§VI-A).

* ``brute_force``   — exact k-NN oracle (ground truth for recall/ratio).
* ``FBLSH``         — the paper's own ablation: identical (K,L)-index but
                      *fixed* (query-oblivious) bucketing. Isolates the
                      value of query-centric dynamic buckets.
* ``MQIndex``       — dynamic metric-query scheme (PM-LSH/SRS family):
                      one m-dim projected space, candidates = beta*n
                      nearest in the projected space, verified exactly.
* ``C2Index``       — collision-counting scheme (QALSH family): m one-dim
                      projections, candidates = points colliding on >= l
                      projections at query-centric width w.

These are compact but faithful reimplementations of the *schemes* (the
candidate-generation rules and cost profiles), which is what the paper's
comparison exercises.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from . import hashing

__all__ = ["brute_force", "FBLSH", "MQIndex", "C2Index"]

_INF = jnp.inf


@partial(jax.jit, static_argnames=("k",))
def brute_force(data: jax.Array, Q: jax.Array, k: int = 50):
    """Exact k-NN via a blocked distance matrix. Returns (dists, ids)."""
    # ||q - x||^2 = ||q||^2 - 2 q.x + ||x||^2  (MXU-friendly)
    qn = jnp.sum(jnp.square(Q), axis=-1, keepdims=True)  # (Qn,1)
    xn = jnp.sum(jnp.square(data), axis=-1)  # (n,)
    d2 = qn - 2.0 * Q @ data.T + xn  # (Qn, n)
    d2 = jnp.maximum(d2, 0.0)
    neg, ids = jax.lax.top_k(-d2, k)
    return jnp.sqrt(-neg), ids


# ---------------------------------------------------------------------------
# FB-LSH: static (K, L)-index with fixed-width buckets.
# ---------------------------------------------------------------------------


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["proj_vecs", "proj", "offsets", "data"],
    meta_fields=["K", "L", "w0", "c", "t", "max_radius_steps", "cand_cap"],
)
@dataclasses.dataclass
class FBLSH:
    """Fixed-bucketing LSH over the same (K, L) projections.

    Bucket code of point o in table i: floor((h_ij(o) + b_ij) / w). The
    query probes its *own* bucket only — reproducing the hash-boundary
    issue DB-LSH eliminates. The radius schedule is emulated by virtual
    rehashing (recomputing codes at width w0*r), as in LSB/E2LSH's
    r in {1, c, c^2, ...} suite-of-indexes semantics.
    """

    proj_vecs: jax.Array  # (L, K, d)
    proj: jax.Array  # (L, n, K)
    offsets: jax.Array  # (L, K) uniform [0, w0)
    data: jax.Array  # (n, d)
    K: int
    L: int
    w0: float
    c: float
    t: int
    max_radius_steps: int
    cand_cap: int

    @staticmethod
    def build(key, data, K, L, w0, c, t=100, max_radius_steps=24, cand_cap=0):
        kp, kb = jax.random.split(key)
        proj_vecs = hashing.sample_projections(kp, data.shape[1], K, L)
        proj = hashing.project(data, proj_vecs)
        offsets = jax.random.uniform(kb, (L, K), minval=0.0, maxval=w0)
        cand_cap = cand_cap or (2 * t + 64)
        return FBLSH(proj_vecs, proj, offsets, data, K, L, w0, c, t,
                     max_radius_steps, cand_cap)

    def _probe(self, gq, w):
        """Candidates colliding with q's bucket in >= 1 table at width w."""
        codes = jnp.floor((self.proj + self.offsets[:, None, :]) / w)  # (L,n,K)
        qcodes = jnp.floor((gq + self.offsets) / w)  # (L,K)
        hit = jnp.all(codes == qcodes[:, None, :], axis=-1)  # (L,n)
        return jnp.any(hit, axis=0)  # (n,)

    def search(self, q, k=50, r0=1.0):
        n = self.data.shape[0]
        gq = jnp.einsum("lkd,d->lk", self.proj_vecs, q)
        cap = self.cand_cap

        def body(state):
            j, r, bd, bi, done = state
            hit = self._probe(gq, self.w0 * r)
            # fixed-capacity candidate selection (budget 2tL+k analogue)
            cand = jnp.sort(jnp.where(hit, jnp.arange(n), n))[: cap * self.L]
            xb = jnp.take(self.data, cand, axis=0, mode="fill", fill_value=0.0)
            d2 = jnp.sum(jnp.square(xb - q), axis=-1)
            d2 = jnp.where(cand < n, d2, _INF)
            alld = jnp.concatenate([bd, d2])
            alli = jnp.concatenate([bi, cand.astype(jnp.int32)])
            order = jnp.lexsort((alld, alli))
            ids_s, d_s = jnp.take(alli, order), jnp.take(alld, order)
            first = jnp.concatenate([jnp.ones((1,), bool), ids_s[1:] != ids_s[:-1]])
            d_s = jnp.where(first & (ids_s < n), d_s, _INF)
            neg, ti = jax.lax.top_k(-d_s, k)
            bd, bi = -neg, jnp.take(ids_s, ti)
            nver = jnp.sum(first & (ids_s < n) & jnp.isfinite(d_s))
            done = (bd[k - 1] <= jnp.square(self.c * r)) | (
                nver >= 2 * self.t * self.L + k
            )
            return j + 1, r * self.c, bd, bi, done

        state = (
            jnp.asarray(0),
            jnp.asarray(r0, jnp.float32),
            jnp.full((k,), _INF),
            jnp.full((k,), n, jnp.int32),
            jnp.asarray(False),
        )
        state = jax.lax.while_loop(
            lambda s: (~s[4]) & (s[0] < self.max_radius_steps), body, state
        )
        return jnp.sqrt(state[2]), state[3]

    def search_batch(self, Q, k=50, r0=1.0):
        return jax.jit(
            jax.vmap(lambda q: self.search(q, k=k, r0=r0)), static_argnums=()
        )(Q)


# ---------------------------------------------------------------------------
# MQ (PM-LSH / SRS family): metric queries in one projected space.
# ---------------------------------------------------------------------------


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["proj_vecs", "proj", "data"],
    meta_fields=["m", "beta"],
)
@dataclasses.dataclass
class MQIndex:
    proj_vecs: jax.Array  # (m, d)
    proj: jax.Array  # (n, m)
    data: jax.Array
    m: int
    beta: float

    @staticmethod
    def build(key, data, m=15, beta=0.08):
        pv = jax.random.normal(key, (m, data.shape[1]), jnp.float32)
        return MQIndex(pv, data @ pv.T, data, m, beta)

    @partial(jax.jit, static_argnames=("k",))
    def search_batch(self, Q, k=50):
        n = self.data.shape[0]
        ncand = max(k, int(self.beta * n))
        gq = Q @ self.proj_vecs.T  # (Qn, m)
        # exact NN in the projected space (the 'metric query')
        d2p = (
            jnp.sum(jnp.square(gq), -1, keepdims=True)
            - 2.0 * gq @ self.proj.T
            + jnp.sum(jnp.square(self.proj), -1)
        )
        _, cand = jax.lax.top_k(-d2p, ncand)  # (Qn, ncand)
        xb = jnp.take(self.data, cand, axis=0)  # (Qn, ncand, d)
        d2 = jnp.sum(jnp.square(xb - Q[:, None, :]), axis=-1)
        neg, ti = jax.lax.top_k(-d2, k)
        return jnp.sqrt(jnp.maximum(-neg, 0.0)), jnp.take_along_axis(cand, ti, 1)


# ---------------------------------------------------------------------------
# C2 (QALSH family): collision counting over one-dim projections.
# ---------------------------------------------------------------------------


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["proj_vecs", "proj", "data"],
    meta_fields=["m", "l", "w", "cand_cap"],
)
@dataclasses.dataclass
class C2Index:
    proj_vecs: jax.Array  # (m, d)
    proj: jax.Array  # (n, m)
    data: jax.Array
    m: int
    l: int
    w: float
    cand_cap: int

    @staticmethod
    def build(key, data, m=60, collision_ratio=0.45, w=2.0, cand_cap=0):
        pv = jax.random.normal(key, (m, data.shape[1]), jnp.float32)
        l = max(1, int(collision_ratio * m))
        cand_cap = cand_cap or max(256, data.shape[0] // 20)
        return C2Index(pv, data @ pv.T, data, m, l, w, cand_cap)

    @partial(jax.jit, static_argnames=("k",))
    def search_batch(self, Q, k=50):
        n = self.data.shape[0]
        gq = Q @ self.proj_vecs.T  # (Qn, m)
        # query-centric one-dim buckets, count collisions per point
        coll = jnp.abs(self.proj[None, :, :] - gq[:, None, :]) <= 0.5 * self.w
        counts = jnp.sum(coll, axis=-1)  # (Qn, n)
        hit = counts >= self.l
        idx = jnp.argsort(~hit, axis=-1, stable=True)[:, : self.cand_cap]
        valid = jnp.take_along_axis(hit, idx, axis=1)
        xb = jnp.take(self.data, idx, axis=0)
        d2 = jnp.sum(jnp.square(xb - Q[:, None, :]), axis=-1)
        d2 = jnp.where(valid, d2, _INF)
        neg, ti = jax.lax.top_k(-d2, k)
        ids = jnp.take_along_axis(idx, ti, 1)
        ids = jnp.where(jnp.isfinite(-neg), ids, n)
        return jnp.sqrt(jnp.maximum(-neg, 0.0)), ids
