"""DB-LSH core: the paper's contribution as a composable JAX module.

Public API:

    from repro.core import DBLSHParams, build, search, search_batch

    params = DBLSHParams.derive(n=..., d=..., c=1.5)
    index  = build(jax.random.key(0), data, params)
    dists, ids = search_batch(index, queries, k=50)
"""

from .params import DBLSHParams, alpha_of_gamma, rho_star
from .hashing import collision_prob, project, sample_projections
from .index import DBLSHIndex, build, compute_norm_blocks, quantize_blocks
from .query import merge_dedup_topk, rc_nn, search, search_batch, probe_radius
from .baselines import C2Index, FBLSH, MQIndex, brute_force
from .serve_search import (
    DTYPES,
    ENGINES,
    TERM_C1,
    TERM_C2,
    TERM_EXHAUSTED,
    PendingSearch,
    Termination,
    search_batch_fixed,
    search_batch_fixed_dispatch,
    search_batch_fixed_ref,
    validate_dtype,
    validate_engine,
)
from .updates import compact, delete, insert, live_count

__all__ = [
    "DBLSHParams",
    "alpha_of_gamma",
    "rho_star",
    "collision_prob",
    "project",
    "sample_projections",
    "DBLSHIndex",
    "build",
    "compute_norm_blocks",
    "quantize_blocks",
    "search",
    "search_batch",
    "search_batch_fixed",
    "search_batch_fixed_dispatch",
    "search_batch_fixed_ref",
    "Termination",
    "PendingSearch",
    "ENGINES",
    "DTYPES",
    "TERM_EXHAUSTED",
    "TERM_C1",
    "TERM_C2",
    "validate_engine",
    "validate_dtype",
    "merge_dedup_topk",
    "rc_nn",
    "probe_radius",
    "brute_force",
    "FBLSH",
    "MQIndex",
    "C2Index",
]
