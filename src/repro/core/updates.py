"""Incremental DB-LSH index maintenance: insert / delete / compact.

The paper builds a static index; a production vector store needs online
updates. The dense STR-block structure supports them naturally:

* **insert** — project the new points with the *existing* LSH functions
  (Observation 1 keeps every guarantee intact: the hash family is fixed,
  only the point set grows), STR-order them locally, and append whole
  blocks per table. Query cost is unchanged (MBR mask covers old + new
  blocks); block quality of the appended region equals a fresh build of
  that region. K/L were sized for the build-time n — rebuild (compact)
  when n grows past ~2x, as K ~ log n.

* **delete** — tombstone the slots holding the deleted ids (+inf
  projection, sentinel id) and re-tighten the affected block MBRs.
  Deleted points can never be returned (the in-box test fails and the
  id is invalid); space is reclaimed at the next compact.

* **compact** — rebuild from the surviving points with a fresh key
  (also re-derives K/L for the current n).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from . import hashing
from .index import DBLSHIndex, _str_order, build, quantize_blocks
from .params import DBLSHParams

__all__ = ["grown_params", "insert", "delete", "compact", "live_count",
           "live_ids_padded"]

_INF = jnp.inf


def grown_params(p: DBLSHParams, n_total: int) -> DBLSHParams:
    """Params for an index grown in place to ``n_total`` points.

    ``max_blocks`` may have been capped by the *build-time* block count
    (:meth:`DBLSHParams.resolve` takes ``min(budget, ceil(n/B))``);
    appended blocks lift that cap, so it is re-derived at the new n —
    otherwise a small index could never probe past its original blocks
    and inserted points would be unreachable.  An explicitly larger
    setting is kept."""
    grown = dataclasses.replace(p, n=n_total, max_blocks=0).resolve().max_blocks
    return dataclasses.replace(p, n=n_total, max_blocks=max(p.max_blocks, grown))


def insert(index: DBLSHIndex, new_points: jax.Array) -> DBLSHIndex:
    """Append ``new_points`` (m, d) as new STR blocks per table."""
    p = index.params
    m, d = new_points.shape
    assert d == p.d, (d, p.d)
    n_old = index.n
    B = p.block_size
    nb_new = -(-m // B)
    m_pad = nb_new * B
    n_total = n_old + m

    proj = hashing.project(new_points, index.proj_vecs)  # (L, m, K)
    orders = jax.vmap(lambda pr: _str_order(pr, B))(proj)  # (L, m)

    new_norms = jnp.sum(jnp.square(new_points), axis=-1)  # (m,)

    def _pack(order, proj_t):
        ps = jnp.take(proj_t, order, axis=0)
        ps = jnp.concatenate(
            [ps, jnp.full((m_pad - m, p.K), _INF, ps.dtype)]
        ).reshape(nb_new, B, p.K)
        ids = jnp.concatenate(
            [order.astype(jnp.int32) + n_old,
             jnp.full((m_pad - m,), n_total, jnp.int32)]
        ).reshape(nb_new, B)
        nrm = jnp.concatenate(
            [jnp.take(new_norms, order), jnp.full((m_pad - m,), _INF)]
        ).reshape(nb_new, B).astype(jnp.float32)
        finite = jnp.isfinite(ps[..., :1])
        lo = jnp.min(ps, axis=1)
        hi = jnp.max(jnp.where(finite, ps, -_INF), axis=1)
        return ps, ids, nrm, lo, hi

    pb, ib, nrm_b, lo, hi = jax.vmap(_pack)(orders, proj)

    # old sentinel ids (== n_old) must move to the new sentinel n_total
    old_ids = jnp.where(index.ids_blocks >= n_old, n_total, index.ids_blocks)

    new_params = grown_params(p, n_total)
    fields = dict(
        proj_vecs=index.proj_vecs,
        proj_blocks=jnp.concatenate([index.proj_blocks, pb], axis=1),
        ids_blocks=jnp.concatenate([old_ids, ib], axis=1),
        mbr_lo=jnp.concatenate([index.mbr_lo, lo], axis=1),
        mbr_hi=jnp.concatenate([index.mbr_hi, hi], axis=1),
        data=jnp.concatenate([index.data, new_points], axis=0),
        # old padded / tombstoned slots are already +inf (fill covers
        # everything >= n_old), so a plain concat stays slot-aligned
        norm_blocks=jnp.concatenate([index.norm_blocks, nrm_b], axis=1),
        params=new_params,
    )
    if p.inline_vectors:
        def _pack_vecs(order):
            v = jnp.take(new_points, order, axis=0)
            v = jnp.concatenate([v, jnp.zeros((m_pad - m, d), v.dtype)])
            return v.reshape(nb_new, B, d)

        vb = jax.vmap(_pack_vecs)(orders)
        fields["vec_blocks"] = jnp.concatenate([index.vec_blocks, vb], axis=1)
    else:
        fields["vec_blocks"] = index.vec_blocks
    if p.quant_dtype != "none":
        # quantization is per-slot, so the appended region quantizes
        # independently of the old blocks (ids local to new_points;
        # padded slots hit the zero fill — never admitted anyway)
        qb, qs = quantize_blocks(new_points, ib - n_old, p.quant_dtype)
        fields["qvec_blocks"] = jnp.concatenate([index.qvec_blocks, qb], axis=1)
        fields["qvec_scale"] = jnp.concatenate([index.qvec_scale, qs], axis=1)
    else:
        fields["qvec_blocks"] = index.qvec_blocks
        fields["qvec_scale"] = index.qvec_scale
    return DBLSHIndex(**fields)


def delete(index: DBLSHIndex, del_ids: jax.Array) -> DBLSHIndex:
    """Tombstone ``del_ids`` (k,); re-tighten affected MBRs.

    Ids are int32 end to end (inputs are cast, matching search results
    and compaction id maps).  Values outside ``[0, n)`` are no-ops: the
    sentinel ``n`` only re-tombstones already-dead slots and anything
    else matches nothing — the sharded wrappers rely on this for
    SPMD-uniform deletes and for gids landing in stride headroom."""
    p = index.params
    n = index.n
    del_ids = jnp.asarray(del_ids, jnp.int32)
    dead = jnp.isin(index.ids_blocks, del_ids)  # (L, nb, B)
    ids = jnp.where(dead, n, index.ids_blocks)
    proj = jnp.where(dead[..., None], _INF, index.proj_blocks)
    finite = jnp.isfinite(proj[..., :1])
    lo = jnp.min(proj, axis=2)
    hi = jnp.max(jnp.where(finite, proj, -_INF), axis=2)
    return DBLSHIndex(
        proj_vecs=index.proj_vecs,
        proj_blocks=proj,
        ids_blocks=ids,
        mbr_lo=lo,
        mbr_hi=hi,
        data=index.data,
        vec_blocks=index.vec_blocks,
        norm_blocks=jnp.where(dead, _INF, index.norm_blocks),
        # quantized blocks stay as-is: tombstoned slots project to +inf,
        # so hw=inf keeps them out of every schedule bin, and the exact
        # re-rank masks their sentinel ids — no touch-up needed
        qvec_blocks=index.qvec_blocks,
        qvec_scale=index.qvec_scale,
        params=index.params,
    )


def live_count(index: DBLSHIndex) -> int:
    """Number of live (non-tombstoned) points, from table 0."""
    return int(jnp.sum(index.ids_blocks[0] < index.n))


def live_ids_padded(index: DBLSHIndex) -> jax.Array:
    """Sorted live point ids, padded with the sentinel ``n`` to the
    static length ``n + 1`` — the jit-stable form of the live scan
    (compaction's gather order), usable inside ``shard_map``."""
    n = index.n
    return jnp.sort(
        jnp.unique(
            jnp.where(index.ids_blocks[0] < n, index.ids_blocks[0], n),
            size=n + 1, fill_value=n,
        )
    )


def compact(index: DBLSHIndex, key) -> tuple[DBLSHIndex, jax.Array]:
    """Rebuild from surviving points (re-derives K/L for the live n).

    Returns (new_index, id_map) where id_map (n_old,) holds each old
    id's new id, or -1 if deleted."""
    p = index.params
    n_old = index.n
    live_ids = live_ids_padded(index)
    live_ids = live_ids[live_ids < n_old]
    n_live = int(live_ids.shape[0])
    data = jnp.take(index.data, live_ids, axis=0)
    new_params = DBLSHParams.derive(
        n=n_live, d=p.d, c=p.c, w0=p.w0, t=p.t, k=p.k,
        block_size=p.block_size, inline_vectors=p.inline_vectors,
        quant_dtype=p.quant_dtype,
    )
    id_map = jnp.full((n_old,), -1, jnp.int32)
    id_map = id_map.at[live_ids].set(jnp.arange(n_live, dtype=jnp.int32))
    return build(key, data, new_params), id_map
