from .checkpointer import Checkpointer, CorruptSnapshot

__all__ = ["Checkpointer", "CorruptSnapshot"]
