from .checkpointer import Checkpointer
