"""Sharded, atomic, async checkpointing with elastic restore.

Layout:   <dir>/step_<k>/
             manifest.json        (treedef, shapes, dtypes, step, meta)
             arr_<i>.npy          (one file per leaf; process-local shards
                                   in multi-host — full arrays here)
          <dir>/LATEST            (atomic pointer file)

Atomicity: write into step_<k>.tmp.<pid>, fsync, rename to step_<k>,
then rewrite LATEST via tmp+rename — a crash at any point leaves either
the old or the new checkpoint fully intact, never a torn one.

Async: ``save_async`` snapshots device arrays to host (blocking, cheap)
then writes in a daemon thread; ``wait()`` joins before the next save.

Elastic restore: arrays are stored unsharded; ``restore(..., shardings=)``
places them onto *any* mesh (shape-compatible), so a job can restart on
a different pod count — resharding is just device_put with the new spec.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

__all__ = ["Checkpointer"]


def _flatten_with_paths(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, meta: dict | None = None):
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._write(step, host_tree, meta or {})

    def save_async(self, step: int, tree, meta: dict | None = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # snapshot now
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree, meta or {}), daemon=True
        )
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree, meta: dict):
        leaves, treedef = _flatten_with_paths(host_tree)
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + f".tmp.{os.getpid()}"
        os.makedirs(tmp, exist_ok=True)
        manifest = {
            "step": step,
            "meta": meta,
            "treedef": jax.tree_util.tree_structure(host_tree).serialize_using_proto().hex(),
            "leaves": [
                {"file": f"arr_{i}.npy", "shape": list(a.shape), "dtype": str(a.dtype)}
                for i, a in enumerate(leaves)
            ],
        }
        for i, a in enumerate(leaves):
            np.save(os.path.join(tmp, f"arr_{i}.npy"), a)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._update_latest(step)
        self._gc()

    def _update_latest(self, step: int):
        tmp = os.path.join(self.dir, f".LATEST.tmp.{os.getpid()}")
        with open(tmp, "w") as f:
            f.write(str(step))
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, os.path.join(self.dir, "LATEST"))

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and ".tmp" not in name:
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self):
        path = os.path.join(self.dir, "LATEST")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            step = int(f.read().strip())
        return step if step in self.all_steps() else (self.all_steps() or [None])[-1]

    def read_meta(self, step: int | None = None):
        """(meta, step) from the manifest alone — no array loads.

        Lets callers dispatch on snapshot metadata cheaply (e.g. the
        store layer routing a snapshot to its placement class before
        touching the index arrays)."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}", "manifest.json")
        with open(path) as f:
            manifest = json.load(f)
        return manifest["meta"], step

    def restore(self, step: int | None = None, shardings=None):
        """Returns (tree, meta). ``shardings``: optional pytree (or single
        sharding) of jax.sharding.Sharding for elastic placement."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        treedef = jax.tree_util.tree_structure(0).__class__  # placeholder
        from jax.tree_util import PyTreeDef

        treedef = PyTreeDef.deserialize_using_proto(
            jax.tree_util.default_registry, bytes.fromhex(manifest["treedef"])
        )
        leaves = [
            np.load(os.path.join(path, spec["file"])) for spec in manifest["leaves"]
        ]
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            if not isinstance(shardings, (dict, list, tuple)):
                tree = jax.tree.map(lambda a: jax.device_put(a, shardings), tree)
            else:
                tree = jax.tree.map(jax.device_put, tree, shardings)
        return tree, manifest["meta"]
