"""Sharded, atomic, async checkpointing with verified, elastic restore.

Layout:   <dir>/step_<k>/
             manifest.json        (treedef, shapes, dtypes, crc32s, step, meta)
             arr_<i>.npy          (one file per leaf; process-local shards
                                   in multi-host — full arrays here)
          <dir>/LATEST            (atomic pointer file)

Atomicity: write into step_<k>.tmp.<pid>, fsync, rename to step_<k>,
then rewrite LATEST via tmp+rename — a crash at any point leaves either
the old or the new checkpoint fully intact, never a torn one.

Integrity: every leaf blob carries a crc32 in the manifest
(``manifest_version: 2``); ``restore`` re-hashes the bytes it reads and
raises :class:`CorruptSnapshot` on mismatch.  v1 manifests (pre-checksum)
still restore — they simply skip verification.  When no explicit step is
requested, restore walks candidates newest-first (the ``LATEST``
designee first) and falls back past corrupt or half-deleted steps to the
newest snapshot that verifies, so a torn write or a stranded ``LATEST``
degrades to "recover the previous step", never to an unhandled error.

Crash recovery: :meth:`sweep_tmp` (run at construction) salvages
orphaned ``.tmp`` dirs — a complete, verified tmp whose final dir never
appeared is committed via the same rename; torn ones are deleted.

Async: ``save_async`` snapshots device arrays to host (blocking, cheap)
then writes in a daemon thread; ``wait()`` joins before the next save
and re-raises the writer's exception (``wait(reraise=False)`` drains
without raising, for recovery paths).

Elastic restore: arrays are stored unsharded; ``restore(..., shardings=)``
places them onto *any* mesh (shape-compatible), so a job can restart on
a different pod count — resharding is just device_put with the new spec.

Fault sites (active only under an installed ``resilience.faults`` plan):
``snapshot.write.torn`` truncates a leaf file mid-write and simulates a
crash; ``snapshot.write.crash`` kills the writer between file
operations (stages: pre_manifest / pre_rename / post_rename /
post_latest); ``snapshot.read.corrupt`` flips a byte in the blob a
restore just read, which the crc check must catch.
"""

from __future__ import annotations

import io
import json
import os
import shutil
import threading
import zlib

import jax
import numpy as np

from ..resilience import faults
from ..resilience.faults import SimulatedCrash

__all__ = ["Checkpointer", "CorruptSnapshot"]

MANIFEST_VERSION = 2

# tmp dirs currently being written by any Checkpointer in this process —
# sweep_tmp must not GC a sibling instance's in-flight write
_INFLIGHT_TMP: set[str] = set()
_INFLIGHT_LOCK = threading.Lock()


class CorruptSnapshot(RuntimeError):
    """A snapshot failed integrity verification (garbled manifest,
    checksum mismatch, or missing leaf file inside an existing step
    dir).  Carries ``step`` and ``file`` so fallback layers can log
    exactly what they skipped."""

    def __init__(self, step: int | None, file: str, reason: str):
        super().__init__(
            f"corrupt snapshot at step {step!r} ({file}): {reason}"
        )
        self.step = step
        self.file = file
        self.reason = reason


def _flatten_with_paths(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _leaf_blob(a: np.ndarray) -> bytes:
    buf = io.BytesIO()
    np.save(buf, a)
    return buf.getvalue()


def _tmp_owner_pid(name: str) -> int | None:
    try:
        return int(name.rsplit(".tmp.", 1)[1])
    except (IndexError, ValueError):
        return None


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True  # exists, owned by someone else
    return True


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._async_exc: BaseException | None = None
        # steps a restore() is mid-read on — _gc must skip them
        self._reading: set[int] = set()
        self._reading_lock = threading.Lock()
        self.sweep_tmp()

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, meta: dict | None = None):
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._write(step, host_tree, meta or {})

    def save_async(self, step: int, tree, meta: dict | None = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # snapshot now

        def _run():
            try:
                self._write(step, host_tree, meta or {})
            except BaseException as e:  # surfaced at the next wait()
                self._async_exc = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self, *, reraise: bool = True):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        exc, self._async_exc = self._async_exc, None
        if exc is not None and reraise:
            raise exc

    def _write_file(self, path: str, blob: bytes, *, step: int):
        """Write one file, honouring the ``snapshot.write.torn`` site:
        when the plan fires it returns a byte offset — we persist the
        torn prefix exactly as an interrupted write would, then die."""
        name = os.path.basename(path)
        torn_at = faults.fire("snapshot.write.torn", file=name, step=step)
        with open(path, "wb") as f:
            if torn_at is not None:
                f.write(blob[: int(torn_at)])
                f.flush()
                os.fsync(f.fileno())
            else:
                f.write(blob)
                f.flush()
                os.fsync(f.fileno())
        if torn_at is not None:
            raise SimulatedCrash(
                "snapshot.write.torn",
                f"torn write of {name} at byte {int(torn_at)}",
            )

    def _write(self, step: int, host_tree, meta: dict):
        leaves, treedef = _flatten_with_paths(host_tree)
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + f".tmp.{os.getpid()}"
        with _INFLIGHT_LOCK:
            _INFLIGHT_TMP.add(tmp)
        try:
            os.makedirs(tmp, exist_ok=True)
            blobs = [_leaf_blob(a) for a in leaves]
            manifest = {
                "manifest_version": MANIFEST_VERSION,
                "step": step,
                "meta": meta,
                "treedef": jax.tree_util.tree_structure(host_tree)
                .serialize_using_proto()
                .hex(),
                "leaves": [
                    {
                        "file": f"arr_{i}.npy",
                        "shape": list(a.shape),
                        "dtype": str(a.dtype),
                        "crc32": zlib.crc32(blob),
                    }
                    for i, (a, blob) in enumerate(zip(leaves, blobs))
                ],
            }
            for i, blob in enumerate(blobs):
                self._write_file(
                    os.path.join(tmp, f"arr_{i}.npy"), blob, step=step
                )
            faults.fire("snapshot.write.crash", stage="pre_manifest", step=step)
            self._write_file(
                os.path.join(tmp, "manifest.json"),
                json.dumps(manifest).encode(),
                step=step,
            )
            faults.fire("snapshot.write.crash", stage="pre_rename", step=step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
        finally:
            with _INFLIGHT_LOCK:
                _INFLIGHT_TMP.discard(tmp)
        faults.fire("snapshot.write.crash", stage="post_rename", step=step)
        self._update_latest(step)
        faults.fire("snapshot.write.crash", stage="post_latest", step=step)
        self._gc()

    def _update_latest(self, step: int):
        tmp = os.path.join(self.dir, f".LATEST.tmp.{os.getpid()}")
        with open(tmp, "w") as f:
            f.write(str(step))
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, os.path.join(self.dir, "LATEST"))

    def _gc(self):
        steps = self.all_steps()
        with self._reading_lock:
            busy = set(self._reading)
        for s in steps[: -self.keep]:
            if s in busy:
                continue  # a concurrent restore is mid-read on this step
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # ----------------------------------------------------------- tmp salvage
    def sweep_tmp(self):
        """Recover from a writer that died mid-snapshot: salvage
        complete, verified orphan ``.tmp`` dirs by committing the
        rename the crash pre-empted; delete torn ones.  Tmp dirs with a
        write in flight (this process) are left alone; so are tmps
        owned by a *different live* process (a concurrent writer)."""
        with _INFLIGHT_LOCK:
            inflight = set(_INFLIGHT_TMP)
        for name in sorted(os.listdir(self.dir)):
            path = os.path.join(self.dir, name)
            if name.startswith(".LATEST.tmp."):
                os.unlink(path)
                continue
            if not (name.startswith("step_") and ".tmp." in name):
                continue
            if path in inflight:
                continue
            owner = _tmp_owner_pid(name)
            if owner is not None and owner != os.getpid() and _pid_alive(owner):
                continue
            final = os.path.join(self.dir, name.split(".tmp.")[0])
            if not os.path.exists(final) and self._tmp_complete(path):
                # roll forward: the write finished and verifies, so commit
                # the rename the crash pre-empted — and publish it, if it
                # is newer than whatever LATEST currently names
                os.rename(path, final)
                step = int(os.path.basename(final).split("_")[1])
                latest = self.latest_step()
                if latest is None or step > latest:
                    self._update_latest(step)
            else:
                shutil.rmtree(path, ignore_errors=True)

    def _tmp_complete(self, path: str) -> bool:
        """A tmp dir is salvageable iff its manifest parses and every
        listed leaf verifies against its checksum."""
        try:
            with open(os.path.join(path, "manifest.json")) as f:
                manifest = json.load(f)
            for spec in manifest["leaves"]:
                with open(os.path.join(path, spec["file"]), "rb") as f:
                    blob = f.read()
                if "crc32" in spec and zlib.crc32(blob) != spec["crc32"]:
                    return False
        except (OSError, ValueError, KeyError, TypeError):
            return False
        return True

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and ".tmp" not in name:
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self):
        path = os.path.join(self.dir, "LATEST")
        if not os.path.exists(path):
            return (self.all_steps() or [None])[-1]
        with open(path) as f:
            try:
                step = int(f.read().strip())
            except ValueError:
                step = None  # torn LATEST — fall back to the dirs on disk
        return step if step in self.all_steps() else (self.all_steps() or [None])[-1]

    def _candidate_steps(self, step: int | None) -> list[int]:
        """Restore order: an explicit step is tried alone (strict); with
        ``step=None`` the LATEST designee goes first, then every other
        existing step newest→oldest — the fallback chain."""
        if step is not None:
            return [step]
        latest = self.latest_step()
        if latest is None:
            return []
        rest = [s for s in reversed(self.all_steps()) if s != latest]
        return [latest, *rest]

    def _load_manifest(self, step: int):
        path = os.path.join(self.dir, f"step_{step:08d}", "manifest.json")
        if not os.path.exists(path):
            if not os.path.isdir(os.path.dirname(path)):
                raise FileNotFoundError(path)  # whole step gone (raced GC)
            raise CorruptSnapshot(step, "manifest.json", "manifest missing")
        try:
            with open(path) as f:
                return json.load(f)
        except ValueError as e:  # JSONDecodeError ⊂ ValueError
            raise CorruptSnapshot(
                step, "manifest.json", f"unparseable manifest: {e}"
            ) from e

    def read_meta(self, step: int | None = None):
        """(meta, step) from the manifest alone — no array loads.

        Lets callers dispatch on snapshot metadata cheaply (e.g. the
        store layer routing a snapshot to its placement class before
        touching the index arrays).  A truncated or garbled manifest
        raises :class:`CorruptSnapshot` naming the step and file, so
        fallback layers can catch it and try an older step."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        manifest = self._load_manifest(step)
        try:
            return manifest["meta"], step
        except (KeyError, TypeError) as e:
            raise CorruptSnapshot(
                step, "manifest.json", f"manifest missing keys: {e}"
            ) from e

    def _read_leaf(self, step: int, spec: dict) -> np.ndarray:
        """Read + verify one leaf.  Checksums are compared on the raw
        bytes (catching torn files before np.load can crash on them);
        v1 manifests carry no crc32 and skip verification."""
        path = os.path.join(self.dir, f"step_{step:08d}", spec["file"])
        try:
            with open(path, "rb") as f:
                blob = f.read()
        except FileNotFoundError as e:
            raise CorruptSnapshot(step, spec["file"], "leaf file missing") from e
        flip_at = faults.fire("snapshot.read.corrupt", file=spec["file"], step=step)
        if flip_at is not None and len(blob):
            i = int(flip_at) % len(blob)
            blob = blob[:i] + bytes([blob[i] ^ 0xFF]) + blob[i + 1 :]
        if "crc32" in spec and zlib.crc32(blob) != spec["crc32"]:
            raise CorruptSnapshot(step, spec["file"], "crc32 mismatch")
        try:
            return np.load(io.BytesIO(blob), allow_pickle=False)
        except ValueError as e:
            raise CorruptSnapshot(step, spec["file"], f"undecodable: {e}") from e

    def _restore_step(self, step: int, shardings):
        with self._reading_lock:
            self._reading.add(step)
        try:
            manifest = self._load_manifest(step)
            from jax.tree_util import PyTreeDef

            treedef = PyTreeDef.deserialize_using_proto(
                jax.tree_util.default_registry, bytes.fromhex(manifest["treedef"])
            )
            leaves = [self._read_leaf(step, spec) for spec in manifest["leaves"]]
        except (KeyError, TypeError) as e:
            raise CorruptSnapshot(
                step, "manifest.json", f"manifest missing keys: {e}"
            ) from e
        finally:
            with self._reading_lock:
                self._reading.discard(step)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            if not isinstance(shardings, (dict, list, tuple)):
                tree = jax.tree.map(lambda a: jax.device_put(a, shardings), tree)
            else:
                tree = jax.tree.map(jax.device_put, tree, shardings)
        return tree, manifest["meta"]

    def restore(self, step: int | None = None, shardings=None):
        """Returns (tree, meta). ``shardings``: optional pytree (or single
        sharding) of jax.sharding.Sharding for elastic placement.

        An explicit ``step`` is strict — corruption raises.  With
        ``step=None`` corruption (or a step deleted under us) falls
        back to the next-newest snapshot that verifies; only when every
        candidate fails does the last error propagate."""
        candidates = self._candidate_steps(step)
        if not candidates:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        last_err: Exception | None = None
        for s in candidates:
            try:
                return self._restore_step(s, shardings)
            except (CorruptSnapshot, FileNotFoundError, OSError) as e:
                last_err = e
                if step is not None:
                    raise
        raise last_err
