"""End-to-end ANN benchmark driver: DB-LSH vs the paper's competitor
families on a scaled dataset, with recall/ratio/time.

    PYTHONPATH=src:. python examples/ann_search.py [--scale 0.5]
"""

import argparse

from benchmarks.table4_query_perf import main as table4


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.25)
    args = ap.parse_args()
    table4(scale=args.scale)
