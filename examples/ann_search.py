"""ANN serving through the vector store: build a Collection, stream
single queries from two tenants through the StoreService scheduler
(overlapped dispatch + query-result cache + per-tenant quotas), mutate
the collection online (add/remove -> auto-compaction, which invalidates
the cache by version), and report recall + scheduler stats.  The final
section runs the *same* mutable lifecycle on a ShardedCollection (one
shard per visible device) — add/remove/compact at fleet scale through
the identical service path.

    PYTHONPATH=src:. python examples/ann_search.py [--scale 0.25]

For the paper-table benchmark (DB-LSH vs competitor families) run
``python benchmarks/table4_query_perf.py``; for sustained-QPS curves run
``python benchmarks/store_throughput.py``.
"""

import argparse
import json

import jax
import numpy as np

from benchmarks.common import load_dataset, recall_and_ratio
from repro.core import brute_force
from repro.store import (
    Collection,
    CompactionPolicy,
    QuotaExceeded,
    ShardedCollection,
    StoreService,
)


def main(scale: float = 0.25, dataset: str = "sift-s"):
    data, queries = load_dataset(dataset, scale=scale)
    n_hold = data.shape[0] // 4  # held back for the online-update phase
    base, extra = data[:-n_hold], data[-n_hold:]
    k = 10

    print(f"[build] {dataset} scale={scale}: n={base.shape[0]} d={base.shape[1]}")
    col = Collection.create(
        "demo",
        jax.random.key(1),
        base,
        c=1.5,
        t=64,
        k=k,
        policy=CompactionPolicy(growth_ratio=1.25),
        payload=np.arange(base.shape[0]),  # payload demo: row ids
        engine="jnp",  # per-collection default; submit/serve may override
    )
    svc = StoreService(
        batch_shapes=(1, 8, 32), default_k=k, r0=0.5, steps=8,
        inflight_depth=2,  # overlap: pad batch i+1 while the device runs i
    )
    svc.attach(col)
    # two tenants share the queue: 'web' gets 3x the batch share, 'batch'
    # is capped to a small token bucket (over-quota submits are rejected)
    svc.set_quota("web", weight=3)
    svc.set_quota("batch", rate=50.0, burst=8, weight=1)

    # --- serve a stream of single queries through the admission queue ----
    dists, ids, _ = svc.serve("demo", queries, k=k, tenant="web")
    gt_d, gt_i = brute_force(base, queries, k=k)
    rec, ratio = recall_and_ratio(dists, ids, gt_d, gt_i, k)
    print(f"[serve] recall@{k}={rec:.3f} ratio={ratio:.3f}")

    # repeats hit the query-result cache (no device dispatch at all)
    dists_c, ids_c, reqs_c = svc.serve("demo", queries, k=k, tenant="web")
    assert all(r.cached for r in reqs_c) and np.array_equal(ids_c, ids)
    rejected = 0
    for q in queries:
        try:
            svc.submit("demo", q, k=k, tenant="batch")
        except QuotaExceeded:
            rejected += 1
    svc.flush()
    print(f"[tenants] {json.dumps(svc.tenant_stats(), indent=2)}")
    print(f"[stats] {json.dumps(svc.stats('demo'), indent=2)}")
    print(f"[cache] {svc.cache_stats()} rejected={rejected}")

    # --- recall-target planning: calibrate once, then ask for outcomes ---
    # (repro.tune: the planner picks (r0, steps) off the table and C1/C2
    # adaptive termination stops easy queries before the planned budget)
    col.calibrate(queries[: min(32, len(queries))], k=k)
    t = svc.submit("demo", queries[0], k=k, tenant="web", recall_target=0.9)
    svc.flush()
    hist = svc.stats("demo")["termination_steps_hist"]
    print(f"[tune] recall_target=0.9 -> planned steps={t.plan.steps} "
          f"(r0={t.plan.r0:.3f}), took {t.radius_steps} steps; "
          f"termination histogram {hist}")

    # --- EXPLAIN ANALYZE one query: the full per-query story -------------
    # (repro.obs.explain: plan provenance, cache/queue placement, the
    # per-step half-windows + admitted slots the device measured, and
    # which termination condition fired.  Explain'd requests batch
    # separately and bypass the cache read, so results stay bit-equal
    # to a plain submit of the same query.)
    te = svc.submit("demo", queries[0], k=k, tenant="web", explain=True)
    svc.flush()
    assert np.array_equal(te.ids, ids_c[0])  # same answer, now explained
    print("[explain]")
    print(te.explain.render())

    # --- online growth: adds cross the policy threshold -> auto-compact ---
    # (every mutation bumps col.version, so cached results can't go stale)
    v0 = col.version
    col.add(extra, payload=np.arange(base.shape[0], data.shape[0]))
    print(f"[update] n={col.n} compactions={col.stats.compactions} "
          f"version {v0} -> {col.version}")
    dists, ids, reqs = svc.serve("demo", queries, k=k, tenant="web")
    assert not any(r.cached for r in reqs)  # old entries unreachable
    gt_d, gt_i = brute_force(data, queries, k=k)
    rec2, _ = recall_and_ratio(dists, ids, gt_d, gt_i, k)
    print(f"[serve] post-growth recall@{k}={rec2:.3f}")

    # --- the same lifecycle at fleet scale: ShardedCollection ------------
    # one shard per visible device (1 on a CPU host — the protocol is
    # identical at any P); the service serves it through the same queue,
    # cache, and policy path as the local collection above.
    pn = len(jax.devices())
    mesh = jax.make_mesh((pn,), ("data",))
    n_shard = (base.shape[0] // pn) * pn
    sc = ShardedCollection.create(
        "demo-sharded", jax.random.key(2), base[:n_shard], mesh,
        c=1.5, t=64, k=k, payload=np.arange(n_shard),
        policy=CompactionPolicy(auto=False),
    )
    svc.attach(sc)
    _, _, reqs_s = svc.serve("demo-sharded", queries, k=k, tenant="web")
    sv0 = sc.version
    sc.add(extra[:64], payload=np.arange(n_shard, n_shard + 64))
    # ids are stable under sharded adds (strided id space, DESIGN.md
    # §9): search results and add() handles stay valid until the next
    # compact(), whose id map reports the one renumbering event
    d_f, i_f = sc.search(queries[:4], k=k, r0=0.5, steps=8)
    sc.remove(np.unique(np.asarray(i_f)[np.isfinite(np.asarray(d_f))])[:16])
    sc.compact()
    print(f"[sharded x{pn}] live={sc.live_count()} "
          f"shard_counts={sc.shard_counts().tolist()} "
          f"compactions={sc.stats.compactions} version {sv0} -> {sc.version}")
    _, _, reqs_s2 = svc.serve("demo-sharded", queries, k=k, tenant="web")
    assert not any(r.cached for r in reqs_s2)  # mutations invalidated


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--dataset", default="sift-s")
    args = ap.parse_args()
    main(scale=args.scale, dataset=args.dataset)
