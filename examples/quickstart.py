"""Quickstart: build a DB-LSH index and run (c,k)-ANN queries.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DBLSHParams, brute_force, build, search_batch, search_batch_fixed
from repro.data import make_clustered, normalize_scale


def main():
    key = jax.random.key(0)
    n, d, k = 20_000, 64, 10

    # dataset + queries (queries drawn from the data distribution)
    pts = make_clustered(key, n + 100, d, n_clusters=32, spread=0.02)
    data, queries = pts[:n], pts[n:]
    data, queries, _ = normalize_scale(data, queries)  # NN distance ~ 1 (paper WLOG)

    # paper parameters: c=1.5, w0=4c^2; K/L derived from (n, t)
    params = DBLSHParams.derive(n=n, d=d, c=1.5, t=64, k=k, K=10, L=5)
    print(f"K={params.K} L={params.L} rho*={params.rho:.4f} "
          f"alpha={params.alpha():.3f} budget={params.budget}")

    index = build(jax.random.key(1), data, params)
    print(f"index: {index.nb} blocks/table x {params.L} tables, "
          f"{index.memory_bytes() / 2**20:.1f} MiB")

    # paper-faithful adaptive search (Algorithm 2)
    dists, ids = search_batch(index, queries, k=k, r0=0.5)
    # TPU serving path (fixed schedule)
    dists_f, ids_f = search_batch_fixed(index, queries, k=k, r0=0.5, steps=6)

    gt_d, gt_i = brute_force(data, queries, k=k)
    for name, I in [("adaptive", ids), ("fixed", ids_f)]:
        rec = np.mean([len(set(np.asarray(a)) & set(np.asarray(b))) / k
                       for a, b in zip(I, gt_i)])
        print(f"{name:<9} recall@{k} = {rec:.3f}")


if __name__ == "__main__":
    main()
