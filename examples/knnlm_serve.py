"""End-to-end retrieval-augmented serving: build a DB-LSH datastore from
an LM's own hidden states, then serve batched requests through the
continuous-batching engine with kNN-LM interpolation.

    PYTHONPATH=src python examples/knnlm_serve.py
"""

import jax
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import SyntheticTokens, make_batch_fn
from repro.models.registry import build_model
from repro.serve import Request, RetrievalLM, ServeEngine, build_datastore


def main():
    cfg = get_config("yi-9b").scaled(
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, d_ff=768,
        head_dim=32, vocab_size=8192, dtype="float32",
    )
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    # datastore: teacher-forced pass over a small corpus
    src = SyntheticTokens(cfg.vocab_size, 64, 4, seed=7)
    batches = [make_batch_fn(src)(s) for s in range(8)]
    ds = build_datastore(model, params, batches, jax.random.key(1),
                         t=64, k=8, lam=0.3)
    print(f"datastore: {ds.index.n} keys, L={ds.index.params.L} tables")

    rlm = RetrievalLM(model, ds, r0=1.0, steps=5)
    eng = ServeEngine(model, params, slots=4, cache_len=128, retrieval=rlm)

    rng = np.random.default_rng(0)
    reqs = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, size=8).astype(np.int32),
                max_new_tokens=16, temperature=0.8 if i % 2 else 0.0)
        for i in range(8)
    ]
    for r in reqs:
        eng.submit(r)
    steps = eng.run()
    print(f"served {len(reqs)} requests in {steps} engine steps "
          f"(continuous batching over {eng.slots} slots)")
    for r in reqs[:3]:
        print(f"  req {r.uid}: {r.output}")


if __name__ == "__main__":
    main()
