"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the full production stack — supervisor (checkpoint/restart), resumable
data pipeline, straggler monitor, WSD schedule.

    PYTHONPATH=src python examples/train_lm.py --steps 200 --arch minicpm-2b
(the arch config is scaled to ~100M params for CPU)
"""

import argparse
import time

import jax

from repro.configs import get_config
from repro.data.pipeline import SyntheticTokens, make_batch_fn
from repro.models.registry import build_model, param_count
from repro.runtime import TrainSupervisor
from repro.train import init_train_state, make_optimizer, make_train_step
from repro.train.optimizer import wsd_schedule


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    # ~100M-param reduction of the chosen family
    cfg = get_config(args.arch).scaled(
        n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
        head_dim=64, vocab_size=32000, dtype="float32",
        sliding_window=0, global_layers=(),
    )
    model = build_model(cfg)
    opt = make_optimizer("adamw", wsd_schedule(3e-4, 20, args.steps - 60, 40))
    state = init_train_state(model, opt, jax.random.key(0))
    print(f"{cfg.name}: {param_count(state['params']) / 1e6:.1f}M params")

    src = SyntheticTokens(cfg.vocab_size, args.seq, args.batch, seed=0)
    batch_fn = make_batch_fn(src)
    step_fn = jax.jit(make_train_step(model, opt))

    sup = TrainSupervisor(args.ckpt_dir, ckpt_every=50)
    t0 = time.time()

    def log(step, metrics, dt, slow):
        if step % 20 == 0:
            print(f"step {step:4d} loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics.get('lr', 0)):.2e} {dt * 1e3:.0f} ms"
                  + (" [STRAGGLER]" if slow else ""))

    state = sup.run(state, step_fn, batch_fn, args.steps, log=log)
    print(f"done in {time.time() - t0:.1f}s; restarts={sup.restarts}; "
          f"stragglers flagged={len(sup.monitor.flagged)}")


if __name__ == "__main__":
    main()
