"""Benchmark entry: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per the harness contract.
``--full`` raises the dataset scale (default is CPU-minutes sized).
"""

from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--skip-store", action="store_true",
                    help="skip the store-throughput sweep (figures only)")
    ap.add_argument("--skip-hotpath", action="store_true",
                    help="skip the one-pass search hot-path comparison")
    ap.add_argument("--skip-frontier", action="store_true",
                    help="skip the adaptive-vs-fixed recall frontier")
    args = ap.parse_args()

    from . import fig4_rho, fig5_effect_n, fig8_effect_k, fig9_recall_time, table4_query_perf

    print("name,us_per_call,derived")

    rows = table4_query_perf.run(scale=args.scale)
    for r in rows:
        print(f"table4/{r['dataset']}/{r['method']},{r['query_ms_per_q']*1e3:.1f},"
              f"recall={r['recall']:.3f};ratio={r['overall_ratio']:.3f};idx_s={r['index_s']:.2f}")

    for r in fig4_rho.run():
        print(f"fig4/rho_star,0,c={r['c']:.2f};rho*={r['rho_star_4c2']:.5f};"
              f"bound={r['bound_1_c_alpha']:.5f}")

    for r in fig5_effect_n.run(fractions=(0.25, 0.5, 1.0)):
        print(f"fig5/effect_n/{r['method']},{r['query_ms_per_q']*1e3:.1f},"
              f"n={r['n']};recall={r['recall']:.3f}")

    for r in fig8_effect_k.run(ks=(1, 10, 50), scale=args.scale):
        print(f"fig8/effect_k/{r['method']},{r['query_ms_per_q']*1e3:.1f},"
              f"k={r['k']};recall={r['recall']:.3f}")

    for r in fig9_recall_time.run(scale=args.scale):
        print(f"fig9/recall_time,{r['query_ms_per_q']*1e3:.1f},"
              f"c={r['c']};steps={r['steps']};recall={r['recall']:.3f}")

    if not args.skip_store:
        from . import store_throughput

        report = store_throughput.main(
            scale=args.scale, out="store_throughput.json"
        )
        for r in report["results"]:
            print(f"store/qps/{r['engine']}/bs{r['batch_size']},"
                  f"{1e6 / r['sustained_qps']:.1f},"
                  f"qps={r['sustained_qps']:.1f};p50ms={r['latency_ms_p50']:.1f};"
                  f"p99ms={r['latency_ms_p99']:.1f}")

    if not args.skip_hotpath:
        from . import search_hotpath

        rep = search_hotpath.run(
            n=max(4096, int(100_000 * args.scale)), smoke=args.scale < 1.0
        )
        for eng, r in rep["engines"].items():
            print(f"hotpath/{eng},{1e6 / r['qps_new']:.1f},"
                  f"speedup={r['speedup']};qps_ref={r['qps_ref']};"
                  f"recall={r['recall_new']:.3f}")

    if not args.skip_frontier:
        from . import recall_frontier

        rep = recall_frontier.run(
            n=max(8192, int(100_000 * args.scale)),
            d=64 if args.scale >= 1.0 else 24,
            smoke=args.scale < 1.0,
        )
        for row in rep["fixed"]:
            print(f"frontier/fixed/steps{row['steps']},"
                  f"{1e6 / row['qps']:.1f},"
                  f"recall={row['recall']:.3f};slots={row['mean_slots']}")
        for tag in ("adaptive", "planned_adaptive"):
            r = rep[tag]
            print(f"frontier/{tag},{1e6 / r['qps']:.1f},"
                  f"recall={r['recall']:.3f};slots={r['mean_slots']};"
                  f"term_step={r['mean_term_step']}")

    if not args.skip_roofline:
        from . import roofline

        for mesh in ("pod16x16", "pod2x16x16"):
            for r in roofline.run(mesh):
                if r.get("status") == "ok":
                    print(f"roofline/{mesh}/{r['arch']}/{r['shape']},0,"
                          f"dom={r['dominant']};frac={r['roofline_fraction']:.3f};"
                          f"mem={r['mem_gib_per_dev']:.1f}GiB")
                else:
                    print(f"roofline/{mesh}/{r['arch']}/{r['shape']},0,{r['status']}")


if __name__ == "__main__":
    main()
