"""Table IV reproduction: query time / overall ratio / recall / indexing
time for DB-LSH vs FB-LSH vs MQ vs C2 on the scaled datasets.

Paper claims to validate (Table IV + §VI-B):
  * DB-LSH beats FB-LSH on recall AND query time (query-centric buckets);
  * DB-LSH has the smallest indexing time;
  * DB-LSH reaches the best recall/ratio at the lowest query time.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import brute_force

from .common import DEFAULT_K, SCALED_DATASETS, load_dataset, methods_for, recall_and_ratio, timed


def run(scale: float = 1.0, datasets=None, k: int = DEFAULT_K):
    rows = []
    for name in datasets or SCALED_DATASETS:
        data, queries = load_dataset(name, scale)
        Q = jnp.asarray(queries)
        gt_d, gt_i = brute_force(jnp.asarray(data), Q, k=k)
        for method, (search, idx_time) in methods_for(data, k=k).items():
            (d, i), ms = timed(search, Q)
            rec, ratio = recall_and_ratio(d, i, gt_d, gt_i, k)
            rows.append({
                "dataset": name, "method": method,
                "query_ms_per_q": ms / queries.shape[0],
                "recall": rec, "overall_ratio": ratio,
                "index_s": idx_time,
            })
    return rows


def main(scale=0.5):
    rows = run(scale)
    hdr = f"{'dataset':<10}{'method':<12}{'q_ms':>8}{'recall':>8}{'ratio':>8}{'idx_s':>8}"
    print(hdr)
    for r in rows:
        print(f"{r['dataset']:<10}{r['method']:<12}{r['query_ms_per_q']:>8.2f}"
              f"{r['recall']:>8.3f}{r['overall_ratio']:>8.3f}{r['index_s']:>8.2f}")
    return rows


if __name__ == "__main__":
    main()
