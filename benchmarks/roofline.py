"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell, derive the three roofline terms from the
compiled dry-run:

    compute    = HLO_FLOPs_per_device / peak_FLOPs      (197 TFLOP/s bf16)
    memory     = HLO_bytes_per_device / HBM_bw          (819 GB/s)
    collective = collective_bytes_per_device / link_bw  (~50 GB/s/link ICI)

FLOPs/bytes come from the trip-count-corrected HLO walk
(repro.launch.hlo_stats — XLA's cost_analysis counts while bodies once);
collective bytes from summed operand sizes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute.

Also reports MODEL_FLOPS = 6*N*D(tokens) (dense) or 6*N_active*D (MoE)
and the usefulness ratio MODEL_FLOPS / HLO_FLOPs, the dominant term, and
one-line bottleneck advice per cell.

``--search`` instead runs the serving-path roofline: analytic
bytes-moved / FLOPs per query for the fused one-pass search kernel vs
the unfused pipeline (dist kernel + HBM candidate pool + per-step XLA
merges), across distance dtypes — emitted as
``BENCH_search_roofline.json`` (a CI artifact; see DESIGN.md §13).
"""

from __future__ import annotations

import argparse
import json
import os

from repro.configs import CONFIGS, SHAPES

PEAK_FLOPS = 197e12  # TPU v5e bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per ICI link

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results/dryrun")


def param_count(cfg, active_only=False):
    """Analytic parameter count (embedding + blocks + head)."""
    D, V, L = cfg.d_model, cfg.padded_vocab, cfg.n_layers
    total = V * D * (1 if cfg.tie_embeddings else 2)
    per_layer = 0.0
    if cfg.n_heads:
        per_layer += D * cfg.n_heads * cfg.hd + 2 * D * cfg.n_kv_heads * cfg.hd
        per_layer += cfg.n_heads * cfg.hd * D
    if cfg.family == "moe":
        nmats = 3 if cfg.ffn_kind == "swiglu" else 2
        e = cfg.n_experts if not active_only else cfg.experts_per_token
        per_layer += e * nmats * D * cfg.d_ff
        if cfg.dense_residual:
            per_layer += nmats * D * cfg.d_ff
    elif cfg.d_ff:
        nmats = 3 if cfg.ffn_kind == "swiglu" else 2
        per_layer += nmats * D * cfg.d_ff
    if cfg.ssm_state:
        from repro.models.ssm import ssm_dims

        d_inner, H, P, N, conv_dim, d_proj = ssm_dims(cfg)
        per_layer += D * d_proj + d_inner * D + 4 * conv_dim
    total += L * per_layer
    if cfg.family == "encdec":
        enc_per = 2 * (D * cfg.n_heads * cfg.hd + 2 * D * cfg.n_kv_heads * cfg.hd
                       + cfg.n_heads * cfg.hd * D) / 2 + 2 * D * cfg.d_ff
        total += cfg.n_enc_layers * enc_per
    if cfg.family == "vlm":
        G = cfg.n_layers // cfg.cross_every
        total += G * (2 * (D * cfg.n_heads * cfg.hd + D * cfg.n_kv_heads * cfg.hd)
                      + 3 * D * cfg.d_ff) + cfg.d_vision * D
    return total


def model_flops(cfg, shape):
    """6*N*D tokens (train); 2*N*D (prefill fwd); 2*N per token (decode)."""
    n_act = param_count(cfg, active_only=(cfg.family == "moe"))
    tokens = shape.global_batch * (shape.seq_len if shape.phase != "decode" else 1)
    mult = 6 if shape.phase == "train" else 2
    return mult * n_act * tokens


def analytic_memory_bytes(cfg, shape, chips):
    """Per-device HBM-traffic LOWER BOUND per step.

    The HLO-parsed byte count inherits the *CPU* backend's fusion
    granularity (many more fusion boundaries than a TPU compile), so it
    over-states HBM traffic. This analytic floor counts only
    unavoidable traffic: weights touched, optimizer state r/w, remat
    carry stack, logits, KV/SSM caches. The truth lies between the two;
    both are reported.
    """
    pd_bytes = 2 if cfg.param_dtype == "bfloat16" else 4
    n_params = param_count(cfg)
    B, S = shape.global_batch, shape.seq_len
    D, V, L = cfg.d_model, cfg.padded_vocab, cfg.n_layers
    act = 2  # bf16 activations

    if shape.phase == "train":
        opt_mult = 3.0 if cfg.optimizer == "adamw" else 0.1  # m,v fp32 vs factored
        weights = n_params * (3 * pd_bytes + 2 * opt_mult * 4) / chips
        # fwd save + bwd read of the residual carry stack (+recompute read)
        carries = 3 * L * B * S * D * act / chips
        logits = 2 * B * S * V * act / chips
        return weights + carries + logits
    if shape.phase == "prefill":
        weights = n_params * pd_bytes / chips
        stream = 2 * L * B * S * D * act / chips
        cache = 2 * L * B * S * max(cfg.n_kv_heads, 1) * cfg.hd * act / chips
        return weights + stream + cache
    # decode: weights once + cache read
    weights = n_params * pd_bytes / chips
    if cfg.ssm_state:
        from repro.models.ssm import ssm_dims

        d_inner, H, P, N, conv_dim, _ = ssm_dims(cfg)
        cache = 2 * L * B * H * N * P * act / chips
    else:
        cache = L * B * S * max(cfg.n_kv_heads, 1) * cfg.hd * 2 * act / chips
    return weights + cache


def analyze_cell(arch, shape_name, mesh_tag):
    path = os.path.join(RESULTS_DIR, mesh_tag, f"{arch}__{shape_name}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        r = json.load(f)
    if r.get("status") != "ok":
        return {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                "status": r.get("status"), "reason": r.get("reason", r.get("error", ""))[:90]}

    chips = 512 if "2x16" in mesh_tag else 256
    st = r["hlo_stats"]
    cfg = CONFIGS[arch]
    shape = SHAPES[shape_name]
    t_comp = st["flops"] / PEAK_FLOPS
    t_mem = st["hbm_bytes"] / HBM_BW  # CPU-fusion-granularity upper estimate
    t_mem_min = analytic_memory_bytes(cfg, shape, chips) / HBM_BW  # floor
    t_coll = st["collective_bytes"] / LINK_BW
    dom = max(("compute", t_comp), ("memory", t_mem), ("collective", t_coll),
              key=lambda kv: kv[1])[0]
    # corrected dominance: HLO bytes replaced by the analytic floor
    dom_corr = max(("compute", t_comp), ("memory", t_mem_min),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]

    mf = model_flops(cfg, shape)
    useful = mf / (st["flops"] * chips) if st["flops"] else 0.0
    bound = max(t_comp, t_mem_min, t_coll)
    # roofline fraction: useful model flops vs what peak compute could do
    # in the time the (corrected) dominant term needs
    frac = (mf / chips / PEAK_FLOPS) / bound if bound else 0.0
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag, "status": "ok",
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_memory_min_s": t_mem_min,
        "t_collective_s": t_coll,
        "dominant": dom, "dominant_corrected": dom_corr,
        "model_flops": mf, "useful_ratio": useful,
        "roofline_fraction": frac,
        "mem_gib_per_dev": r["memory"]["per_device_total"] / 2**30,
        "fits_16g": r["memory"]["per_device_total"] < 16 * 2**30,
    }


def run(mesh_tag="pod16x16"):
    rows = []
    for arch in sorted(CONFIGS):
        for shape in SHAPES:
            row = analyze_cell(arch, shape, mesh_tag)
            if row:
                rows.append(row)
    return rows


def advice(row):
    if row.get("status") != "ok":
        return ""
    d = row["dominant"]
    if d == "collective":
        return "cut collective bytes: int8 pod reduction / fewer reshards / EP psum->a2a"
    if d == "memory":
        return "raise arithmetic intensity: fuse verify, larger microbatch, flash attention"
    return "already compute-bound: close MODEL/HLO gap (remat waste, attention flops)"


# ---------------------------------------------------------------------------
# Serving-path roofline: fused one-pass search kernel vs unfused pipeline
# ---------------------------------------------------------------------------

#: bytes per candidate vector element by distance dtype (int8 rows also
#: read one fp32 dequant scale per slot, accounted separately)
_VEC_BYTES = {"fp32": 4, "bf16": 2, "int8": 1}


def search_cell(S, B, d, K, steps, k, dtype="fp32"):
    """Analytic per-query bytes-moved and FLOPs for the serving search.

    The unfused pipeline (pre-fusion serving path) runs the distance
    kernel, writes the (S*B,) d2/hw candidate pools to HBM, then re-reads
    the pools ``steps`` times for the per-step masked delta merges (each
    merge is a separate XLA program over the full pool).  The fused
    kernel keeps candidates in VMEM: blocks stream in once, the only HBM
    writes are the (steps, ks) bin accumulators.

    FLOPs count the arithmetic both paths share (halfwidth + norm-form
    distance) plus each path's merge work: the unfused merge runs
    ``steps`` passes of the k-round min-select over the full pool; the
    fused kernel folds each slot into one bin (k-round min-select over
    one block), so its merge work is per-slot, not per-step.

    ks is the bin accumulator width: ``k`` for fp32, ``4k`` for the
    quantized shortlist.
    """
    vb = _VEC_BYTES[dtype]
    ks = k if dtype == "fp32" else 4 * k
    slots = S * B
    # --- shared streaming reads: proj (K f32) + vec (d) + norm (f32) + id
    block_read = slots * (K * 4 + d * vb + 4 + 4)
    if dtype == "int8":
        block_read += slots * 4  # per-slot dequant scale
    # --- shared arithmetic: hw (3 ops/dim over K) + norm-form dist (2d+3)
    flops_dist = slots * (3 * K + 2 * d + 3)

    # unfused: pools to HBM, then steps x (read pool + k-round merge)
    pool_bytes = slots * (4 + 4 + 4)  # d2 + hw + ids
    unfused_bytes = (
        block_read + pool_bytes            # kernel writes the pools
        + steps * pool_bytes               # each step's merge re-reads them
        + steps * k * 8                    # running top-k read-modify-write
    )
    unfused_flops = flops_dist + steps * k * 4 * (slots + k)

    # fused: blocks stream once; bins are the only HBM traffic
    bins_bytes = steps * ks * 8 + steps * 4
    fused_bytes = block_read + bins_bytes
    # per-slot bin fold: ks-round min-select over one block + ks carry
    fused_flops = flops_dist + S * ks * 4 * (B + ks)

    def mk(bytes_, flops):
        return {
            "bytes_per_query": int(bytes_),
            "flops_per_query": int(flops),
            "arith_intensity": round(flops / bytes_, 3),
            "t_mem_us": round(bytes_ / HBM_BW * 1e6, 3),
            "t_compute_us": round(flops / PEAK_FLOPS * 1e6, 6),
            "bound": "memory" if bytes_ / HBM_BW > flops / PEAK_FLOPS
                     else "compute",
        }

    fused = mk(fused_bytes, fused_flops)
    unfused = mk(unfused_bytes, unfused_flops)
    return {
        "dtype": dtype,
        "slots": slots,
        "ks": ks,
        "unfused": unfused,
        "fused": fused,
        "bytes_ratio": round(unfused["bytes_per_query"]
                             / fused["bytes_per_query"], 3),
        "flops_ratio": round(unfused["flops_per_query"]
                             / fused["flops_per_query"], 3),
    }


def run_search(out="BENCH_search_roofline.json"):
    """The BENCH workload's cells (n=100k reference + a large-d point)."""
    cells = []
    for name, (S, B, d, K, steps, k) in {
        "ref_100k": (25, 64, 64, 10, 8, 10),     # BENCH_search_hotpath
        "wide_d960": (25, 64, 960, 10, 8, 10),   # gist-shaped vectors
        "deep_steps16": (25, 64, 64, 10, 16, 10),
    }.items():
        for dtype in ("fp32", "bf16", "int8"):
            cells.append({"workload": name,
                          "S": S, "B": B, "d": d, "K": K,
                          "steps": steps, "k": k,
                          **search_cell(S, B, d, K, steps, k, dtype)})
    report = {
        "bench": "search_roofline",
        "model": (
            "analytic per-query HBM traffic and FLOPs on the v5e "
            "roofline constants; the fused kernel's win is the removed "
            "candidate-pool round-trip (write + steps re-reads), which "
            "grows with the schedule length while its own overhead "
            "(the bin accumulators) is O(steps*ks) per query"
        ),
        "constants": {"peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW},
        "cells": cells,
    }
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"{'workload':<14}{'dtype':<6}{'bytes/q un':>12}{'bytes/q fu':>12}"
          f"{'ratio':>7}{'AI un':>7}{'AI fu':>7}{'bound':>8}")
    for c in cells:
        print(f"{c['workload']:<14}{c['dtype']:<6}"
              f"{c['unfused']['bytes_per_query']:>12}"
              f"{c['fused']['bytes_per_query']:>12}"
              f"{c['bytes_ratio']:>7}"
              f"{c['unfused']['arith_intensity']:>7}"
              f"{c['fused']['arith_intensity']:>7}"
              f"{c['fused']['bound']:>8}")
    # sanity gate: fusion must strictly cut bytes moved in every cell
    assert all(c["bytes_ratio"] > 1.0 for c in cells)
    return report


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--search", action="store_true",
                    help="serving-path roofline (fused vs unfused search)")
    ap.add_argument("--out", default="BENCH_search_roofline.json")
    args = ap.parse_args(argv)
    if args.search:
        return run_search(args.out)
    rows = []
    for mesh_tag in ("pod16x16", "pod2x16x16"):
        rows = run(mesh_tag)
        if not rows:
            continue
        print(f"\n== roofline {mesh_tag} (s/step per device) ==")
        print(f"{'arch':<22}{'shape':<12}{'compute':>9}{'mem_hlo':>9}{'mem_min':>9}"
              f"{'collect':>9}{'dom*':>11}{'useful':>7}{'frac':>7}{'mem/dev':>9}")
        for r in rows:
            if r.get("status") != "ok":
                print(f"{r['arch']:<22}{r['shape']:<12}  -- {r['status']}: {r.get('reason','')[:60]}")
                continue
            print(f"{r['arch']:<22}{r['shape']:<12}{r['t_compute_s']:>9.3f}"
                  f"{r['t_memory_s']:>9.3f}{r['t_memory_min_s']:>9.3f}"
                  f"{r['t_collective_s']:>9.3f}"
                  f"{r['dominant_corrected']:>11}{r['useful_ratio']:>7.2f}"
                  f"{r['roofline_fraction']:>7.3f}{r['mem_gib_per_dev']:>8.1f}G")
    return rows


if __name__ == "__main__":
    main()
