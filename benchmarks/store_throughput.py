"""Sustained-QPS benchmark for the store scheduler.

Streams single queries through the StoreService admission queue at each
(engine, batch-size) point in three modes — synchronous dispatch
(``inflight_depth=0``), overlapped dispatch (the in-flight ring), and
overlapped + query-result cache on a repeat-heavy stream — plus one
multi-tenant point with a quota-limited tenant.  Emits a JSON report
with per-point ``overlap_ratio`` / ``cache_hit_rate`` and per-tenant
QPS.

``--obs`` benchmarks the observability contract instead: the same
overlapped stream with ``repro.obs`` fully enabled (tracing, sample
rate 1.0) vs disabled vs EXPLAIN-sampled (per-query explain records at
the recommended 1/64 rate), interleaved best-of-rounds.  It asserts
bit-equal results across all three arms, writes the metrics registry
(JSON + Prometheus text), the trace (JSONL + Perfetto timeline), and
the sampled explains (JSON) as artifacts, verifies the timeline shows
the in-flight ring overlap, and — with ``--gate`` — hard-fails if the
tracing-enabled or explain-sampled overhead exceeds ``--max-overhead``
(default 5%).

``--sharded-updates`` benchmarks the *mutable sharded lifecycle*
instead: a ShardedCollection absorbs interleaved add / remove / compact
ops while serving queries through the StoreService, reporting mutation
throughput (points/s added and removed, compaction wall time) alongside
query QPS before and after the churn.  With ``--smoke`` the run doubles
as a correctness gate: it asserts post-churn recall against a brute
force of the surviving point set and that deleted points never
resurface (non-zero exit on violation) — the CI hook for the sharded
lifecycle.

``--chaos`` soaks the resilience layer instead: a scripted fault matrix
(transient + persistent dispatch raises, injected latency spikes under
the brownout ladder, snapshot-writer kills at every crash stage) with
hard gates — no ticket lost or hung, non-flagged results bit-equal the
fault-free reference, degraded-phase p99 within 2x the healthy
baseline, brownout heals to level 0, every snapshot crash recovers a
verified committed state.  ``--smoke`` shrinks it to CI size; the JSON
report is the chaos-soak artifact.

Caveat for CPU-only hosts: the "device" shares cores with the host, so
overlapped dispatch has nothing to hide behind and lands within noise
of sync (~0.95-1.05x) — the overlap win needs a real accelerator,
where issue returns while the TPU/GPU runs the batch.  The cache mode
is host-independent and shows its full gain everywhere.

    PYTHONPATH=src python benchmarks/store_throughput.py \
        [--scale 0.2] [--batch-sizes 8 32] [--engines jnp] \
        [--sharded-updates] [--smoke] [--out store_throughput.json]

CPU-friendly at the default scale; on an accelerator raise --scale and
add the Pallas engines (kernel / inline) to the sweep (the sharded mode
fans out over every device the host exposes).
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

try:
    # python -m benchmarks.store_throughput
    from .common import load_dataset, recall_and_ratio
except ImportError:
    # python benchmarks/store_throughput.py
    from common import load_dataset, recall_and_ratio

from repro.core import brute_force
from repro.obs import DEFAULT_EXPLAIN_SAMPLE_RATE, Observability, Tracer
from repro.obs.metrics import MetricsRegistry
from repro.store import (
    Collection,
    CompactionPolicy,
    QuotaExceeded,
    ShardedCollection,
    StoreService,
)


def _make_service(col, *, batch_size: int, engine: str, k: int, r0: float,
                  steps: int, inflight_depth: int, cache_size: int):
    svc = StoreService(
        batch_shapes=(batch_size,), max_wait_ms=1e9, default_k=k,
        r0=r0, steps=steps, engine=engine, inflight_depth=inflight_depth,
        cache_size=cache_size,
    )
    svc.attach(col)
    return svc


def _stream(svc, col_name, stream, batch_size):
    # depth 0 completes each batch inside step() (synchronous); depth > 0
    # leaves the ring full and only flush() syncs the tail.
    t0 = time.perf_counter()
    for q in stream:
        svc.submit(col_name, q)
        if svc.pending() >= batch_size:
            svc.step()
    svc.flush()
    return time.perf_counter() - t0


def _bench_modes(col, queries, *, batch_size: int, engine: str, k: int,
                 n_queries: int, r0: float, steps: int,
                 rounds: int = 3) -> dict:
    """All three modes at one (engine, batch-size) point.

    ``sync``/``overlapped`` measure dispatch on an all-unique stream
    (cache off — the tiled stream repeats queries, and serving repeats
    from the cache would measure the wrong thing); ``cached`` measures a
    repeat-heavy stream with the cache on.  The modes are measured
    *interleaved* round-robin and each keeps its best round: machine
    speed drifts on shared hosts, and interleaving keeps the drift from
    loading onto whichever mode happened to run last.
    """
    reps = -(-n_queries // queries.shape[0])
    tiled = np.tile(queries, (reps, 1))[:n_queries]
    # all-unique stream: perturb each row so no two are bit-equal
    jitter = 1e-4 * np.arange(n_queries, dtype=np.float32)[:, None]
    distinct = (tiled + jitter).astype(np.float32)
    # repeat-heavy stream for the cache point: few uniques, many repeats
    n_unique = max(1, min(queries.shape[0], n_queries // 4))
    repeats = np.tile(queries[:n_unique], (-(-n_queries // n_unique), 1))
    repeats = repeats[:n_queries].astype(np.float32)

    # depth 2 = the two-stage pipeline (pad batch i+1 while the device
    # runs batch i); much deeper rings contend on CPU.
    modes = {
        "sync": (distinct, 0, 0),
        "overlapped": (distinct, 2, 0),
        "cached": (repeats, 2, 4 * n_queries),
    }

    def run(mode):
        stream, depth, cache_size = modes[mode]
        svc = _make_service(
            col, batch_size=batch_size, engine=engine, k=k, r0=r0,
            steps=steps, inflight_depth=depth, cache_size=cache_size,
        )
        wall = _stream(svc, col.name, stream, batch_size)
        return svc, wall

    best: dict[str, tuple] = {}
    for mode in modes:
        run(mode)  # warmup: compiles the (batch_size, d) program
    for _ in range(rounds):
        for mode in modes:
            svc, wall = run(mode)
            if mode not in best or wall < best[mode][1]:
                best[mode] = (svc, wall)

    out = {}
    for mode, (svc, wall) in best.items():
        stats = svc.stats(col.name)
        out[mode] = {
            "mode": mode,
            "engine": engine,
            "batch_size": batch_size,
            "inflight_depth": modes[mode][1],
            "queries": n_queries,
            "wall_s": wall,
            "sustained_qps": n_queries / wall,
            "latency_ms_p50": stats["latency_ms_p50"],
            "latency_ms_p99": stats["latency_ms_p99"],
            "mean_radius_steps": stats["mean_radius_steps"],
            "mean_candidates": stats["mean_candidates"],
            "batches": stats["batches"],
            "overlap_ratio": stats["overlap_ratio"],
            "cache_hit_rate": stats["cache_hit_rate"],
        }
    return out


def _bench_tenants(col, queries, *, batch_size: int, engine: str, k: int,
                   n_queries: int, r0: float, steps: int) -> dict:
    """Two tenants share the queue: 'bulk' is unlimited, 'capped' has a
    small token bucket.  Reports per-tenant QPS / rejects and shows WRR
    draining keeps serving both."""
    svc = _make_service(
        col, batch_size=batch_size, engine=engine, k=k, r0=r0, steps=steps,
        inflight_depth=4, cache_size=0,
    )
    svc.set_quota("bulk", weight=3)
    svc.set_quota("capped", rate=200.0, burst=16, weight=1)
    reps = -(-n_queries // queries.shape[0])
    stream = np.tile(queries, (reps, 1))[:n_queries]
    rejected = 0
    t0 = time.perf_counter()
    for i, q in enumerate(stream):
        tenant = "capped" if i % 4 == 0 else "bulk"
        try:
            svc.submit(col.name, q, tenant=tenant)
        except QuotaExceeded:
            rejected += 1
        if svc.pending() >= batch_size:
            svc.step()
    svc.flush()
    wall = time.perf_counter() - t0
    return {
        "batch_size": batch_size,
        "engine": engine,
        "wall_s": wall,
        "rejected": rejected,
        "per_tenant": svc.tenant_stats(),
    }


def _overlap_visible(tracer: Tracer) -> bool:
    """True when the trace shows ring overlap *structurally*: some
    batch's issue span sits inside an earlier batch's pending window, on
    a different ring lane — the picture a Perfetto load should show."""
    issues = [s for s in tracer.events if s.name == "batch.issue"]
    pendings = [s for s in tracer.events if s.name == "batch.pending"]
    for p in pendings:
        for i in issues:
            if (
                i.args.get("seq", -1) > p.args.get("seq", -1)
                and i.tid != p.tid
                and p.ts <= i.ts
                and i.ts + i.dur <= p.ts + p.dur
            ):
                return True
    return False


def bench_obs(
    scale: float = 0.2,
    dataset: str = "sift-s",
    batch_size: int = 16,
    engine: str = "jnp",
    k: int = 10,
    n_queries: int = 128,
    rounds: int = 5,
    max_overhead: float = 0.05,
    gate: bool = False,
    out: str = "store_obs.json",
):
    """Observability overhead + artifact benchmark (the repro.obs gate).

    Runs the same all-unique overlapped stream three times per round —
    obs off (metrics only, tracing disabled), obs fully on (tracing
    enabled, sample_rate 1.0), and EXPLAIN-sampled (auto-explain at
    :data:`DEFAULT_EXPLAIN_SAMPLE_RATE`, which splits sampled requests
    into their own ``with_explain`` batches) — interleaved, keeping each
    arm's best round (shared hosts drift; interleaving keeps the drift
    off one arm).  Asserts all arms return **bit-equal** results, writes
    the enabled arm's metrics registry (JSON + Prometheus text), trace
    (JSONL + Perfetto ``trace_event`` timeline), and the explain arm's
    sampled-explains JSON next to ``out``, and verifies the timeline
    actually shows ring overlap (batch N+1's issue span inside batch N's
    pending window, one lane up).  With ``gate`` the ≤ ``max_overhead``
    overhead contract is a hard assert on the tracing *and* explain
    arms — the CI hook.
    """
    data, queries = load_dataset(dataset, scale=scale)
    col = Collection.create(
        "bench", jax.random.key(1), data, c=1.5, t=64, k=k,
        payload=np.arange(data.shape[0]),
    )
    reps = -(-n_queries // queries.shape[0])
    tiled = np.tile(queries, (reps, 1))[:n_queries]
    jitter = 1e-4 * np.arange(n_queries, dtype=np.float32)[:, None]
    stream = (tiled + jitter).astype(np.float32)

    def run(traced: bool, explain_rate: float = 0.0):
        # private tracer per run: the global one must stay untouched so
        # the obs-off arm is genuinely off
        obs = Observability(
            registry=MetricsRegistry(),
            tracer=Tracer(enabled=False),
            trace=traced,
            explain_sample_rate=explain_rate,
        )
        # the singleton shape is what keeps explain sampling cheap: a
        # sampled request batches separately (different compiled
        # program), and without a (1,) rung it would pad out to a full
        # batch_size dispatch — ~30% overhead instead of ~3% at 1/64
        svc = StoreService(
            batch_shapes=(1, batch_size), max_wait_ms=1e9, default_k=k,
            r0=0.5, steps=8, engine=engine, inflight_depth=2,
            cache_size=0, obs=obs,
        )
        svc.attach(col)
        tickets = []
        t0 = time.perf_counter()
        for q in stream:
            tickets.append(svc.submit("bench", q))
            if svc.pending() >= batch_size:
                svc.step()
        svc.flush()
        wall = time.perf_counter() - t0
        d = np.stack([t.dists for t in tickets])
        i = np.stack([t.ids for t in tickets])
        return svc, obs, wall, d, i

    # three arms: obs off, obs fully on (tracing), and explain sampling
    # at the recommended production rate (splits sampled requests into
    # their own with_explain batches — the cost under test)
    ARMS = {
        "off": lambda: run(False),
        "on": lambda: run(True),
        "explain": lambda: run(False,
                               explain_rate=DEFAULT_EXPLAIN_SAMPLE_RATE),
    }
    for arm in ARMS.values():  # warmup: compiles both dispatch programs
        arm()
    best = {}
    for _ in range(rounds):
        for key, arm in ARMS.items():
            svc, obs, wall, d, i = arm()
            if key not in best or wall < best[key][2]:
                best[key] = (svc, obs, wall, d, i)

    _, _, wall_off, d_off, i_off = best["off"]
    svc_on, obs_on, wall_on, d_on, i_on = best["on"]
    _, obs_ex, wall_ex, d_ex, i_ex = best["explain"]

    # contract 1: observability never changes results
    assert np.array_equal(d_off, d_on) and np.array_equal(i_off, i_on), (
        "obs-enabled results diverged from obs-off"
    )
    # contract 1b: sampled EXPLAIN never changes results either — the
    # explain'd requests run a separate compiled program but must land
    # bit-equal where the plain dispatch would have put them
    assert np.array_equal(d_off, d_ex) and np.array_equal(i_off, i_ex), (
        "explain-sampled results diverged from explain-off"
    )
    overhead = wall_on / wall_off - 1.0
    overhead_ex = wall_ex / wall_off - 1.0

    # contract 2: the exported timeline shows the ring overlap
    overlap_ok = _overlap_visible(obs_on.tracer)
    stats = svc_on.stats("bench")
    if stats["overlap_ratio"] > 0:
        assert overlap_ok, (
            "overlapped batches ran but the trace shows no nested "
            "issue-inside-pending window"
        )

    stem = out[:-5] if out.endswith(".json") else out
    obs_on.registry.export_json(f"{stem}_metrics.json")
    obs_on.registry.export_prometheus(f"{stem}_metrics.prom")
    n_spans = obs_on.tracer.export_jsonl(f"{stem}_spans.jsonl")
    n_events = obs_on.tracer.export_perfetto(f"{stem}_trace.json")
    n_explains = obs_ex.exemplars.export_json(f"{stem}_explains.json")
    assert n_explains > 0, (
        "explain arm sampled no requests — stride sampler broken?"
    )

    report = {
        "mode": "obs",
        "dataset": dataset,
        "scale": scale,
        "engine": engine,
        "batch_size": batch_size,
        "queries": n_queries,
        "rounds": rounds,
        "device": str(jax.devices()[0]),
        "qps_off": n_queries / wall_off,
        "qps_on": n_queries / wall_on,
        "qps_explain": n_queries / wall_ex,
        "overhead_frac": overhead,
        "explain_overhead_frac": overhead_ex,
        "explain_sample_rate": DEFAULT_EXPLAIN_SAMPLE_RATE,
        "sampled_explains": n_explains,
        "max_overhead": max_overhead,
        "bit_equal": True,
        "overlap_ratio": stats["overlap_ratio"],
        "overlap_visible_in_trace": overlap_ok,
        "spans": n_spans,
        "trace_events": n_events,
        "latency_ms_p50": stats["latency_ms_p50"],
        "latency_ms_p99": stats["latency_ms_p99"],
        "artifacts": [f"{stem}_metrics.json", f"{stem}_metrics.prom",
                      f"{stem}_spans.jsonl", f"{stem}_trace.json",
                      f"{stem}_explains.json"],
    }
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(
        f"[obs {engine} bs={batch_size}] off={report['qps_off']:.1f} QPS "
        f"on={report['qps_on']:.1f} QPS overhead={overhead*100:+.1f}% "
        f"(budget {max_overhead*100:.0f}%)  bit_equal=True "
        f"overlap_visible={overlap_ok}  spans={n_spans}"
    )
    print(
        f"[obs explain] qps={report['qps_explain']:.1f} "
        f"overhead={overhead_ex*100:+.1f}% at sample_rate="
        f"{DEFAULT_EXPLAIN_SAMPLE_RATE:.4f}  bit_equal=True "
        f"sampled_explains={n_explains}"
    )
    print(f"[report] -> {out}")
    if gate:
        assert overhead <= max_overhead, (
            f"obs-enabled overhead {overhead*100:.1f}% exceeds the "
            f"{max_overhead*100:.0f}% budget"
        )
        assert overhead_ex <= max_overhead, (
            f"explain-sampled overhead {overhead_ex*100:.1f}% exceeds "
            f"the {max_overhead*100:.0f}% budget"
        )
    return report


def bench_chaos(
    scale: float = 0.2,
    dataset: str = "sift-s",
    batch_size: int = 16,
    engine: str = "jnp",
    k: int = 10,
    smoke: bool = False,
    out: str = "store_chaos.json",
):
    """Chaos soak: a scripted fault matrix against the serving stack.

    Five phases over one collection:

    A. **healthy** — fault-free stream; per-query reference results and
       the healthy p99 baseline every later gate is relative to.
    B. **dispatch raises** — a transient burst (retried, bit-equal), a
       burst long enough to exhaust the retry budget, and one
       non-transient raise (both fail their batch typed).
    C. **latency spikes + brownout** — injected per-step delays breach
       the p99 SLO; the BrownoutController walks the ladder down to the
       floor schedule, which shrinks the injected delay with it (it
       scales with ``plan.steps``, like real schedule cost).  A second
       measurement stream then runs entirely degraded.
    D. **heal** — faults removed; the ladder walks back to healthy and
       results are bit-equal to the reference again.
    E. **snapshot chaos** — the writer is killed at every snapshot-lane
       site (torn leaf, torn manifest, all four crash stages);
       ``restore_collection`` must land on a committed state bit-equal
       to one the writer reached, and sweep the wreckage.

    Gates (hard, non-zero exit on violation):

    * no ticket lost or hung — every submitted ticket terminates with a
      result or a typed error, queues and ring drain to zero;
    * no wrong non-flagged result — every ticket with ``error is None``
      and ``degraded`` False bit-matches the reference for its query;
    * brownout holds the degraded-phase p99 within 2x the healthy
      baseline, and heals back to level 0 once faults stop;
    * every snapshot crash recovers a verified committed state.
    """
    import math as _math
    import os
    import tempfile

    from repro.checkpoint import Checkpointer
    from repro.resilience import BrownoutController, FaultPlan, \
        SimulatedCrash, faults
    from repro.store import restore_collection

    if smoke:
        scale = min(scale, 0.05)
    data, queries = load_dataset(dataset, scale=scale)
    col = Collection.create("chaos", jax.random.key(3), data, c=1.5,
                            t=32, k=k)
    r0, steps = 0.5, 8
    ref_d, ref_i = (np.asarray(x) for x in
                    col.search(queries, k=k, r0=r0, steps=steps,
                               engine=engine))
    nq = queries.shape[0]

    def make_svc(latency_window=64):
        svc = StoreService(
            batch_shapes=(batch_size,), max_wait_ms=1e9, default_k=k,
            r0=r0, steps=steps, engine=engine, inflight_depth=2,
            cache_size=0, latency_window=latency_window,
        )
        svc.attach(col)
        return svc

    all_tickets: list[tuple[str, int, object]] = []

    def run_stream(svc, n, phase):
        for j in range(n):
            qi = j % nq
            all_tickets.append((phase, qi, svc.submit("chaos", queries[qi])))
            if svc.pending() >= batch_size:
                svc.step()
        svc.flush()

    gates: dict[str, bool] = {}
    report: dict = {"dataset": dataset, "scale": scale,
                    "batch_size": batch_size, "engine": engine}

    # ---------------------------------------------------------- A: healthy
    svc = make_svc()
    run_stream(svc, 6 * batch_size, "healthy")
    healthy = svc.stats("chaos")
    p99_healthy = max(healthy["latency_ms_p99"], 2.0)  # sub-ms floors flake
    report["healthy"] = healthy

    # --------------------------------------------------- B: dispatch raises
    svc = make_svc()
    plan = (
        FaultPlan()
        .add("dispatch.raise", at=1, count=2, transient=True)   # retried ok
        .add("dispatch.raise", at=6, count=3, transient=True)   # exhausts
        .add("dispatch.raise", at=12, count=1, transient=False)  # immediate
    )
    n_before = len(all_tickets)
    with faults.active(plan):
        run_stream(svc, 12 * batch_size, "dispatch")
    phase_b = [r for _, _, r in all_tickets[n_before:]]
    b_failed = [r for r in phase_b if r.error is not None]
    gates["dispatch_failures_typed"] = (
        len(b_failed) == 2 * batch_size
        and all(type(r.error).__name__ == "DispatchFailed" for r in b_failed)
        and len(plan.fired) == 6
    )
    report["dispatch"] = {
        "tickets": len(phase_b), "failed_typed": len(b_failed),
        "faults_fired": len(plan.fired), "stats": svc.stats("chaos"),
    }

    # ------------------------------------- C: latency spikes under brownout
    svc = make_svc(latency_window=32)
    bc = BrownoutController(svc, floor_steps=1, heal_after=10**6)
    slo = svc.obs.watch(
        "chaos", latency_p99_ms=2.0 * p99_healthy, min_samples=8,
        check_interval_s=0.0,
    )
    bc.attach(slo)
    # per-step delay: at the full 8-step plan the spike alone is 2x the
    # healthy p99 (breach); at the floor schedule it is 0.25x (headroom)
    spike_per_step = p99_healthy / 4.0
    plan = FaultPlan().add("dispatch.delay_ms", arg=spike_per_step,
                           count=_math.inf)
    with faults.active(plan):
        run_stream(svc, 6 * batch_size, "spike_onset")
        level_engaged = bc.level
        n_before = len(all_tickets)
        run_stream(svc, 6 * batch_size, "spike_degraded")
    degraded_lat = [r.latency_ms for _, _, r in all_tickets[n_before:]]
    p99_degraded = float(np.percentile(degraded_lat, 99))
    gates["brownout_engaged"] = level_engaged >= 2
    gates["brownout_holds_p99"] = p99_degraded <= 2.0 * p99_healthy
    report["brownout"] = {
        "p99_healthy_ms": p99_healthy,
        "p99_degraded_ms": p99_degraded,
        "level_engaged": level_engaged,
        "transitions": bc.transitions,
        "stats": svc.stats("chaos"),
    }

    # ------------------------------------------------------------- D: heal
    bc.heal_after = 2  # chaos over: let the ladder walk back
    run_stream(svc, 8 * batch_size, "heal")
    gates["brownout_heals"] = bc.level == 0
    report["heal"] = {"level_final": bc.level, "transitions": bc.transitions}

    # --------------------------------------------------- E: snapshot chaos
    snap_scenarios = [
        ("torn_leaf", FaultPlan().add(
            "snapshot.write.torn", file="arr_0.npy", arg=64, step=2)),
        ("torn_manifest", FaultPlan().add(
            "snapshot.write.torn", file="manifest.json", arg=32, step=2)),
    ] + [
        (f"crash_{stage}", FaultPlan().add(
            "snapshot.write.crash", stage=stage, step=2))
        for stage in faults.SNAPSHOT_CRASH_STAGES
    ]
    n_half = data.shape[0] // 2
    snap_results = []
    for label, splan in snap_scenarios:
        sdir = tempfile.mkdtemp(prefix=f"chaos_snap_{label}_")
        scol = Collection.create("snap", jax.random.key(5), data[:n_half],
                                 c=1.5, t=16, k=k)
        sref1 = [np.asarray(x) for x in
                 scol.search(queries, k=k, r0=r0, steps=steps)]
        scol.snapshot(sdir)
        scol.add(data[n_half:])
        sref2 = [np.asarray(x) for x in
                 scol.search(queries, k=k, r0=r0, steps=steps)]
        try:
            with faults.active(splan):
                scol.snapshot(sdir)
        except SimulatedCrash:
            pass
        restored = restore_collection(sdir)
        got = [np.asarray(x) for x in
               restored.search(queries, k=k, r0=r0, steps=steps)]
        committed = (
            all(np.array_equal(g, r) for g, r in zip(got, sref1))
            or all(np.array_equal(g, r) for g, r in zip(got, sref2))
        )
        Checkpointer(sdir)  # fresh open sweeps any wreckage
        swept = not any(".tmp" in n for n in os.listdir(sdir))
        snap_results.append(
            {"scenario": label, "recovered_committed": committed,
             "tmp_swept": swept}
        )
        print(f"[snapshot {label:>18s}] committed={committed} swept={swept}")
    gates["snapshot_recovery"] = all(
        s["recovered_committed"] and s["tmp_swept"] for s in snap_results
    )
    report["snapshot"] = snap_results

    # ------------------------------------------------- global ticket gates
    terminated = all(
        r.done and (r.error is not None or r.dists is not None)
        for _, _, r in all_tickets
    )
    clean = [
        (phase, qi, r) for phase, qi, r in all_tickets
        if r.error is None and not r.degraded
    ]
    bit_ok = all(
        np.array_equal(r.dists, ref_d[qi, :k])
        and np.array_equal(r.ids, ref_i[qi, :k])
        for _, qi, r in clean
    )
    gates["no_ticket_lost_or_hung"] = terminated
    gates["non_flagged_results_exact"] = bit_ok
    report["tickets"] = {
        "total": len(all_tickets),
        "clean": len(clean),
        "degraded": sum(1 for _, _, r in all_tickets
                        if r.degraded and r.error is None),
        "failed_typed": sum(1 for _, _, r in all_tickets
                            if r.error is not None),
    }
    report["gates"] = gates
    report["ok"] = all(gates.values())

    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"[chaos] p99 healthy={p99_healthy:.1f}ms "
          f"degraded={report['brownout']['p99_degraded_ms']:.1f}ms "
          f"tickets={report['tickets']}")
    for g, ok in gates.items():
        print(f"[gate] {g}: {'ok' if ok else 'VIOLATED'}")
    print(f"[report] -> {out}")
    if not report["ok"]:
        raise SystemExit("chaos gates violated: " + ", ".join(
            g for g, ok in gates.items() if not ok))
    return report


def bench_sharded_updates(
    scale: float = 0.2,
    dataset: str = "sift-s",
    batch_size: int = 16,
    k: int = 10,
    n_queries: int = 128,
    rounds: int = 4,
    add_batch: int = 64,
    remove_batch: int = 32,
    smoke: bool = False,
    out: str = "store_throughput_sharded.json",
):
    """Mutable-sharded-lifecycle benchmark (+ smoke correctness gate).

    Builds a ShardedCollection over every device the host exposes, then
    interleaves serving with churn: per round, one ``add`` batch (routed
    to the least-loaded shard), one ``remove`` batch (victims drawn from
    live search results, so the ids are always current), and one
    ``compact``.  Mutation timings include the ``live_count`` sync that
    makes the mutation observable — the honest "visible to the next
    query" cost.  Query QPS is measured through the StoreService before
    and after the churn (cache off: mutations would invalidate it anyway,
    and serving repeats would measure the wrong thing).

    Gates: every compact must leave the fleet balanced (max/min live
    ratio <= 1.25 — compaction rebalances, it does not just rebuild in
    place); no deleted point may resurface; post-churn recall vs brute
    force must hold; and a snapshot taken on pn shards must restore
    elastically onto pn//2 with comparable recall.
    """
    if smoke:
        scale, n_queries, rounds = min(scale, 0.05), 32, 2
    data, queries = load_dataset(dataset, scale=scale)
    pn = len(jax.devices())
    mesh = jax.make_mesh((pn,), ("data",))
    n_pool = data.shape[0]
    n_base = (int(n_pool * 0.75) // pn) * pn
    base, pool = data[:n_base], data[n_base:]
    col = ShardedCollection.create(
        "fleet", jax.random.key(1), base, mesh, c=1.5, t=64, k=k,
        payload=np.arange(n_base),  # stable identity across id re-bases
        policy=CompactionPolicy(auto=False),
    )
    svc = _make_service(
        col, batch_size=batch_size, engine="jnp", k=k, r0=0.5, steps=8,
        inflight_depth=2, cache_size=0,
    )

    reps = -(-n_queries // queries.shape[0])
    stream = np.tile(queries, (reps, 1))[:n_queries]
    _stream(svc, "fleet", stream, batch_size)  # warmup compile
    qps_before = n_queries / _stream(svc, "fleet", stream, batch_size)

    alive = np.ones(n_pool, bool)
    alive[n_base:] = False
    next_tag = n_base
    add_s, remove_s, compact_s = [], [], []
    added = removed = 0
    removed_tags_all: set[int] = set()
    for _ in range(rounds):
        mb = min(add_batch, len(pool) - (next_tag - n_base))
        if mb > 0:
            t0 = time.perf_counter()
            col.add(pool[next_tag - n_base:next_tag - n_base + mb],
                    payload=np.arange(next_tag, next_tag + mb))
            col.live_count()  # sync: mutation observable
            add_s.append(time.perf_counter() - t0)
            alive[next_tag:next_tag + mb] = True
            next_tag += mb
            added += mb

        d_l, i_l = map(np.asarray, col.search(queries, k=k, r0=0.5, steps=8))
        victims = np.unique(i_l[np.isfinite(d_l)])[:remove_batch]
        victim_tags = np.asarray(col.get_payload(victims[None]))[0].astype(int)
        t0 = time.perf_counter()
        col.remove(victims.astype(np.int32))
        col.live_count()
        remove_s.append(time.perf_counter() - t0)
        alive[victim_tags] = False
        removed += len(victims)
        removed_tags_all.update(victim_tags.tolist())

        t0 = time.perf_counter()
        col.compact()
        col.live_count()
        compact_s.append(time.perf_counter() - t0)

        # gate: compaction REBALANCES — survivors migrate toward the
        # emptiest shards, so the post-compact fleet is near-uniform
        # however lopsided the preceding adds were
        cts = col.shard_counts()
        cmax, cmin = int(cts.max()), int(cts.min())
        assert cmax - cmin <= 1 or cmax <= 1.25 * max(cmin, 1), (
            f"post-compact shard imbalance {cmax}/{cmin} exceeds 1.25x: "
            f"{cts.tolist()}"
        )

        # gate: no point deleted in ANY round resurfaces after the
        # rebuild (a stale id surviving a later re-base would show up
        # here, not just in this round's victims)
        d_c, i_c = map(np.asarray, col.search(queries, k=k, r0=0.5, steps=8))
        got = np.asarray(col.get_payload(i_c))[np.isfinite(d_c)]
        leaked = set(
            np.asarray(got).reshape(-1).astype(int).tolist()
        ) & removed_tags_all
        assert not leaked, f"deleted points resurfaced: {sorted(leaked)[:8]}"

    # the churn changed n (=> new dispatch shapes): warm the recompile
    # out of the timed post-churn stream so before/after compare steady
    # states, not one-off XLA compiles
    _stream(svc, "fleet", stream, batch_size)
    qps_after = n_queries / _stream(svc, "fleet", stream, batch_size)

    # gate: post-churn recall vs brute force of the surviving point set,
    # matched through the payload tags (adds keep ids stable, but each
    # compact renumbers — tags carry identity across the rebuilds)
    alive_tags = np.flatnonzero(alive)
    _, gt_i = brute_force(data[alive_tags], queries, k=k)
    d_f, i_f = map(np.asarray, col.search(queries, k=k, r0=0.5, steps=8))
    tags_f = np.asarray(col.get_payload(i_f)).astype(int)  # one batched take
    recs = []
    for qi in range(queries.shape[0]):
        got = tags_f[qi][np.isfinite(d_f[qi])]
        want = alive_tags[np.asarray(gt_i)[qi]]
        recs.append(len(set(got.tolist()) & set(want.tolist())) / k)
    rec = float(np.mean(recs))
    assert rec > 0.5, f"post-churn sharded recall@{k} collapsed: {rec:.3f}"
    assert col.live_count() == int(alive.sum())

    # elastic-restore smoke: snapshot on pn shards, restore on pn', and
    # the migrated fleet must answer with comparable recall (identity
    # through the payload tags — the migration renumbers global ids)
    rec_elastic, pn_new, t_restore = float("nan"), 0, float("nan")
    if pn > 1:
        import tempfile

        pn_new = pn // 2
        tmpdir = tempfile.mkdtemp(prefix="sharded_bench_snap_")
        step = col.snapshot(tmpdir)
        mesh2 = jax.make_mesh((pn_new,), ("data",))
        t0 = time.perf_counter()
        col2 = ShardedCollection.restore(tmpdir, mesh=mesh2, step=step)
        col2.live_count()
        t_restore = time.perf_counter() - t0
        assert col2.live_count() == int(alive.sum())
        d_r, i_r = map(np.asarray, col2.search(queries, k=k, r0=0.5, steps=8))
        tags_r = np.asarray(col2.get_payload(i_r)).astype(int)
        recs_r = []
        for qi in range(queries.shape[0]):
            got = tags_r[qi][np.isfinite(d_r[qi])]
            want = alive_tags[np.asarray(gt_i)[qi]]
            recs_r.append(len(set(got.tolist()) & set(want.tolist())) / k)
        rec_elastic = float(np.mean(recs_r))
        assert rec_elastic > 0.5, (
            f"recall collapsed across elastic restore {pn}->{pn_new}: "
            f"{rec_elastic:.3f}"
        )
        del col2

    report = {
        "mode": "sharded_updates",
        "dataset": dataset,
        "scale": scale,
        "shards": pn,
        "n_base": int(n_base),
        "k": k,
        "rounds": rounds,
        "device": str(jax.devices()[0]),
        "query_qps_before": qps_before,
        "query_qps_after": qps_after,
        "add_points_per_s": added / sum(add_s) if add_s else float("nan"),
        "remove_points_per_s": (
            removed / sum(remove_s) if remove_s else float("nan")
        ),
        "compact_wall_s_mean": float(np.mean(compact_s)),
        "post_churn_recall_at_k": rec,
        "live_points": int(alive.sum()),
        "shard_counts": col.shard_counts().tolist(),
        "elastic_restore_shards": pn_new,
        "elastic_restore_wall_s": t_restore,
        "elastic_restore_recall_at_k": rec_elastic,
    }
    print(
        f"[sharded-updates x{pn}] add={report['add_points_per_s']:.0f} pts/s "
        f"remove={report['remove_points_per_s']:.0f} pts/s "
        f"compact={report['compact_wall_s_mean']*1e3:.0f} ms  "
        f"qps {qps_before:.1f} -> {qps_after:.1f}  recall@{k}={rec:.3f}  "
        f"elastic {pn}->{pn_new} recall={rec_elastic:.3f}"
    )
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"[report] -> {out}")
    return report


def main(
    scale: float = 0.2,
    dataset: str = "sift-s",
    batch_sizes: tuple[int, ...] = (16, 32),
    engines: tuple[str, ...] = ("jnp",),
    n_queries: int = 128,
    k: int = 10,
    out: str = "store_throughput.json",
):
    data, queries = load_dataset(dataset, scale=scale)
    col = Collection.create(
        "bench", jax.random.key(1), data, c=1.5, t=64, k=k,
        payload=np.arange(data.shape[0]),  # realistic serving: ids ride along
    )
    # sanity: the collection actually answers (recall floor, not perf)
    d_, i_ = col.search(queries, k=k, r0=0.5, steps=8)
    gt_d, gt_i = brute_force(data, queries, k=k)
    rec, _ = recall_and_ratio(d_, i_, gt_d, gt_i, k)

    results = []
    speedups = []
    for engine in engines:
        for bs in batch_sizes:
            by_mode = _bench_modes(
                col, queries, batch_size=bs, engine=engine, k=k,
                n_queries=n_queries, r0=0.5, steps=8,
            )
            for mode, pt in by_mode.items():
                results.append(pt)
                print(
                    f"[{engine} bs={bs:3d} {mode:>10s}] "
                    f"{pt['sustained_qps']:8.1f} QPS  "
                    f"p50={pt['latency_ms_p50']:.1f}ms "
                    f"p99={pt['latency_ms_p99']:.1f}ms  "
                    f"overlap={pt['overlap_ratio']:.2f} "
                    f"cache={pt['cache_hit_rate']:.2f}"
                )
            speedups.append({
                "engine": engine,
                "batch_size": bs,
                "overlapped_vs_sync": (
                    by_mode["overlapped"]["sustained_qps"]
                    / by_mode["sync"]["sustained_qps"]
                ),
                "cached_vs_sync": (
                    by_mode["cached"]["sustained_qps"]
                    / by_mode["sync"]["sustained_qps"]
                ),
            })

    tenants = _bench_tenants(
        col, queries, batch_size=batch_sizes[0], engine=engines[0], k=k,
        n_queries=n_queries, r0=0.5, steps=8,
    )
    for t, s in tenants["per_tenant"].items():
        print(f"[tenant {t:>8s}] served={s['served']} rejected={s['rejected']} "
              f"qps={s['qps']:.1f}")

    report = {
        "dataset": dataset,
        "scale": scale,
        "n": int(data.shape[0]),
        "d": int(data.shape[1]),
        "k": k,
        "recall_at_k": rec,
        "device": str(jax.devices()[0]),
        "results": results,
        "speedups": speedups,
        "tenants": tenants,
    }
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"[report] recall@{k}={rec:.3f} -> {out}")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.2)
    ap.add_argument("--dataset", default="sift-s")
    ap.add_argument("--batch-sizes", type=int, nargs="+", default=[16, 32])
    ap.add_argument("--engines", nargs="+", default=["jnp"])
    ap.add_argument("--n-queries", type=int, default=128)
    ap.add_argument("--sharded-updates", action="store_true",
                    help="benchmark the mutable sharded lifecycle "
                         "(add/remove/compact throughput + query QPS) "
                         "instead of the scheduler modes")
    ap.add_argument("--obs", action="store_true",
                    help="observability benchmark: obs-on vs obs-off QPS "
                         "with bit-equality + trace/metrics artifacts")
    ap.add_argument("--gate", action="store_true",
                    help="with --obs: hard-fail if enabled overhead "
                         "exceeds --max-overhead (CI)")
    ap.add_argument("--max-overhead", type=float, default=0.05)
    ap.add_argument("--chaos", action="store_true",
                    help="chaos soak: scripted fault matrix (dispatch "
                         "raises, latency spikes + brownout, snapshot "
                         "crashes) with hard recovery gates")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run with correctness gates (CI) — applies "
                         "to --sharded-updates and --chaos")
    ap.add_argument("--out", default="store_throughput.json")
    args = ap.parse_args()
    if args.chaos:
        bench_chaos(
            scale=args.scale,
            dataset=args.dataset,
            batch_size=args.batch_sizes[0],
            engine=args.engines[0],
            smoke=args.smoke,
            out=args.out if args.out != "store_throughput.json"
            else "store_chaos.json",
        )
    elif args.obs:
        bench_obs(
            scale=args.scale,
            dataset=args.dataset,
            batch_size=args.batch_sizes[0],
            engine=args.engines[0],
            n_queries=args.n_queries,
            max_overhead=args.max_overhead,
            gate=args.gate,
            out=args.out if args.out != "store_throughput.json"
            else "store_obs.json",
        )
    elif args.sharded_updates:
        bench_sharded_updates(
            scale=args.scale,
            dataset=args.dataset,
            batch_size=args.batch_sizes[0],
            n_queries=args.n_queries,
            smoke=args.smoke,
            out=args.out,
        )
    else:
        main(
            scale=args.scale,
            dataset=args.dataset,
            batch_sizes=tuple(args.batch_sizes),
            engines=tuple(args.engines),
            n_queries=args.n_queries,
            out=args.out,
        )
