"""Sustained-QPS benchmark for the store service layer.

Streams single queries through the StoreService admission queue at each
(engine, batch-size) point, measures sustained QPS and per-request
latency percentiles after a compile warmup, and emits a JSON report:

    PYTHONPATH=src python benchmarks/store_throughput.py \
        [--scale 0.2] [--batch-sizes 8 32] [--engines jnp] \
        [--out store_throughput.json]

CPU-friendly at the default scale; on an accelerator raise --scale and
add the Pallas engines (kernel / inline) to the sweep.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

try:
    # python -m benchmarks.store_throughput
    from .common import load_dataset, recall_and_ratio
except ImportError:
    # python benchmarks/store_throughput.py
    from common import load_dataset, recall_and_ratio

from repro.core import brute_force
from repro.store import Collection, StoreService


def _bench_point(col, queries, *, batch_size: int, engine: str, k: int,
                 n_queries: int, r0: float, steps: int) -> dict:
    reps = -(-n_queries // queries.shape[0])
    stream = np.tile(queries, (reps, 1))[:n_queries]

    def run():
        svc = StoreService(
            batch_shapes=(batch_size,), max_wait_ms=1e9, default_k=k,
            r0=r0, steps=steps, engine=engine,
        )
        svc.attach(col)
        t0 = time.perf_counter()
        for q in stream:
            svc.submit(col.name, q)
            if svc.pending() >= batch_size:
                svc.step(force=True)
        svc.flush()
        return svc, time.perf_counter() - t0

    run()  # warmup: compiles the (batch_size, d) program
    svc, wall = run()
    stats = svc.stats(col.name)
    return {
        "engine": engine,
        "batch_size": batch_size,
        "queries": n_queries,
        "wall_s": wall,
        "sustained_qps": n_queries / wall,
        "latency_ms_p50": stats["latency_ms_p50"],
        "latency_ms_p99": stats["latency_ms_p99"],
        "mean_radius_steps": stats["mean_radius_steps"],
        "mean_candidates": stats["mean_candidates"],
        "batches": stats["batches"],
    }


def main(
    scale: float = 0.2,
    dataset: str = "sift-s",
    batch_sizes: tuple[int, ...] = (8, 32),
    engines: tuple[str, ...] = ("jnp",),
    n_queries: int = 128,
    k: int = 10,
    out: str = "store_throughput.json",
):
    data, queries = load_dataset(dataset, scale=scale)
    col = Collection.create(
        "bench", jax.random.key(1), data, c=1.5, t=64, k=k
    )
    # sanity: the collection actually answers (recall floor, not perf)
    d_, i_ = col.search(queries, k=k, r0=0.5, steps=8)
    gt_d, gt_i = brute_force(data, queries, k=k)
    rec, _ = recall_and_ratio(d_, i_, gt_d, gt_i, k)

    results = []
    for engine in engines:
        for bs in batch_sizes:
            pt = _bench_point(
                col, queries, batch_size=bs, engine=engine, k=k,
                n_queries=n_queries, r0=0.5, steps=8,
            )
            results.append(pt)
            print(
                f"[{engine} bs={bs:3d}] {pt['sustained_qps']:8.1f} QPS  "
                f"p50={pt['latency_ms_p50']:.1f}ms p99={pt['latency_ms_p99']:.1f}ms"
            )

    report = {
        "dataset": dataset,
        "scale": scale,
        "n": int(data.shape[0]),
        "d": int(data.shape[1]),
        "k": k,
        "recall_at_k": rec,
        "device": str(jax.devices()[0]),
        "results": results,
    }
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"[report] recall@{k}={rec:.3f} -> {out}")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.2)
    ap.add_argument("--dataset", default="sift-s")
    ap.add_argument("--batch-sizes", type=int, nargs="+", default=[8, 32])
    ap.add_argument("--engines", nargs="+", default=["jnp"])
    ap.add_argument("--n-queries", type=int, default=128)
    ap.add_argument("--out", default="store_throughput.json")
    args = ap.parse_args()
    main(
        scale=args.scale,
        dataset=args.dataset,
        batch_sizes=tuple(args.batch_sizes),
        engines=tuple(args.engines),
        n_queries=args.n_queries,
        out=args.out,
    )
