"""Fig. 9/10 reproduction: recall-time / ratio-time trade-off curves.

The paper sweeps the approximation ratio c; here we sweep the DB-LSH
radius-schedule length (steps) and c, which spans the same trade-off —
fewer probes = faster + less accurate."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import brute_force, search_batch_fixed

from .common import DEFAULT_K, build_dblsh, load_dataset, recall_and_ratio, timed


def run(dataset="deep-s", scale=0.5, k=DEFAULT_K):
    data, queries = load_dataset(dataset, scale)
    Q = jnp.asarray(queries)
    gt = brute_force(jnp.asarray(data), Q, k=k)
    rows = []
    for c in (2.0, 1.5, 1.2):
        index, _ = build_dblsh(data, c=c, k=k)
        for steps in (2, 4, 6, 8, 10):
            (d, i), ms = timed(
                lambda Q: search_batch_fixed(index, Q, k=k, r0=0.5, steps=steps), Q,
                repeats=2,
            )
            rec, ratio = recall_and_ratio(d, i, gt[0], gt[1], k)
            rows.append({"c": c, "steps": steps, "recall": rec, "ratio": ratio,
                         "query_ms_per_q": ms / Q.shape[0]})
    return rows


def main():
    rows = run()
    print(f"{'c':>5}{'steps':>6}{'q_ms':>8}{'recall':>8}{'ratio':>8}")
    for r in rows:
        print(f"{r['c']:>5.1f}{r['steps']:>6}{r['query_ms_per_q']:>8.2f}"
              f"{r['recall']:>8.3f}{r['ratio']:>8.3f}")
    return rows


if __name__ == "__main__":
    main()
