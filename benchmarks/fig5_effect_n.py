"""Fig. 5-7 reproduction: effect of cardinality n on query time, recall,
overall ratio — plus the hardware-independent 'distance computations per
query' that carries the paper's sub-linearity claim (DB-LSH candidates
grow ~n^rho*; MQ verifies beta*n — linear)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import brute_force

from .common import DEFAULT_K, load_dataset, methods_for, recall_and_ratio, timed


def dist_comps_per_query(method: str, n: int, index_like=None, k=DEFAULT_K):
    """Analytic distance-computation counts per query."""
    if method == "DB-LSH":
        p = index_like.params
        return p.L * p.max_blocks * p.block_size  # fixed-capacity cap
    if method == "FB-LSH":
        return 5 * (2 * 64 + 64)  # cap * L analogue
    if method == "MQ(PM-LSH)":
        return max(k, int(0.08 * n)) + n  # beta*n verif + n projected dists
    if method == "C2(QALSH)":
        return max(256, n // 20) + 40 * n // 64  # cand cap + counting cost proxy
    return n


def run(fractions=(0.2, 0.4, 0.6, 0.8, 1.0), dataset="sift-s", k=DEFAULT_K):
    rows = []
    for frac in fractions:
        data, queries = load_dataset(dataset, scale=frac)
        Q = jnp.asarray(queries)
        gt = brute_force(jnp.asarray(data), Q, k=k)
        from repro.core import DBLSHParams  # for cap introspection

        for method, (search, _) in methods_for(data, k=k).items():
            (d, i), ms = timed(search, Q, repeats=2)
            rec, ratio = recall_and_ratio(d, i, gt[0], gt[1], k)
            rows.append({
                "n": data.shape[0], "method": method,
                "query_ms_per_q": ms / queries.shape[0],
                "recall": rec, "ratio": ratio,
            })
    return rows


def main(fractions=(0.25, 0.5, 1.0)):
    rows = run(fractions)
    print(f"{'n':>8}{'method':<14}{'q_ms':>8}{'recall':>8}{'ratio':>8}")
    for r in rows:
        print(f"{r['n']:>8}{r['method']:<14}{r['query_ms_per_q']:>8.2f}"
              f"{r['recall']:>8.3f}{r['ratio']:>8.3f}")
    return rows


if __name__ == "__main__":
    main()
