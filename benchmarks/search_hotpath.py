"""Serving hot-path benchmark: one-pass pipeline vs the multi-pass seed.

Measures ``search_batch_fixed`` (one-pass incremental probing) against
``search_batch_fixed_ref`` (the per-radius re-selection seed algorithm)
on a synthetic reference workload and emits ``BENCH_search_hotpath.json``
— the repo's BENCH trajectory point for the serving search core:

* per-engine QPS for both paths + the old-vs-new speedup,
* recall@10 of both paths vs brute force (parity gate: ±0.5pt),
* per-step verified-slot counts for both paths (the one-pass schedule
  admits each selected block exactly once, so its per-step counts decay
  to the fresh-block delta while the seed recounts the full selection
  every radius),
* a hard slot-accounting gate: the one-pass path must never verify
  more total slots than the seed (exit 1 otherwise — CI runs this in
  smoke mode on every push).

Full mode (default): n=100k, d=64, steps=8, L from params.  Smoke mode
(``--smoke``): tiny n, two engines, seconds on CPU.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    brute_force,
    build,
    DBLSHParams,
    search_batch_fixed,
    search_batch_fixed_ref,
)
from repro.core.serve_search import _select_blocks
from repro.data import make_clustered, normalize_scale

try:  # module run (benchmarks.run) vs script run (python benchmarks/...)
    from .common import recall_at, timed
except ImportError:
    from common import recall_at, timed


def per_step_slots(index, Q, r0: float, steps: int):
    """Hardware-level verified-slot counts per schedule step.

    seed: every selected (blk < nb) slot of every step's fresh selection;
    new:  only the slots of blocks newly admitted at that step (the
    final-radius selection masked on block halfwidths).  Both count the
    full compiled program's gather work (no done-masking), which is what
    the device actually executes."""
    p = index.params
    nb = index.nb
    B = p.block_size
    G = jnp.einsum("lkd,qd->qlk", index.proj_vecs, jnp.asarray(Q))

    seed_counts, new_counts = [], []
    r = jnp.asarray(r0, jnp.float32)
    r_last = jnp.asarray(r0, jnp.float32)
    for _ in range(steps - 1):
        r_last = r_last * p.c
    _, bhw = _select_blocks(index, G, p.w0 * r_last)
    prev_half = -np.inf
    for _ in range(steps):
        half = 0.5 * (p.w0 * r)
        blk_j, _ = _select_blocks(index, G, p.w0 * r)
        seed_counts.append(int(jnp.sum(blk_j < nb)) * B)
        newly = (bhw <= half) & (bhw > prev_half)
        new_counts.append(int(jnp.sum(newly)) * B)
        prev_half = half
        r = r * p.c
    return seed_counts, new_counts


def run(
    n: int = 100_000,
    d: int = 64,
    n_queries: int = 64,
    steps: int = 8,
    k: int = 10,
    r0: float = 0.5,
    engines: tuple[str, ...] = ("jnp",),
    repeats: int = 3,
    pallas_queries: int = 8,
    smoke: bool = False,
    seed: int = 7,
) -> dict:
    key = jax.random.key(seed)
    kd, kb = jax.random.split(key)
    allpts = make_clustered(kd, n + n_queries, d,
                            n_clusters=max(8, n // 4000), spread=0.02)
    data, queries = allpts[:n], allpts[n:]
    data, queries, _ = normalize_scale(data, queries)
    inline = any(e == "inline" for e in engines)
    params = DBLSHParams.derive(
        n=n, d=d, c=1.5, t=64, k=max(k, 10), K=10, L=5,
        inline_vectors=inline,
    )
    t0 = time.perf_counter()
    index = build(kb, jnp.asarray(data), params)
    jax.block_until_ready(index.proj_blocks)
    build_s = time.perf_counter() - t0

    _, gt_i = brute_force(jnp.asarray(data), jnp.asarray(queries), k=k)

    report = {
        "bench": "search_hotpath",
        "smoke": smoke,
        "notes": (
            "CPU host: Pallas engines (kernel/inline) run in interpret "
            "mode at a reduced query batch — their QPS reflects "
            "interpreter overhead, not the TPU compile target; the jnp "
            "engine row is the load-bearing comparison off-TPU."
        ),
        "workload": {
            "n": n, "d": d, "n_queries": n_queries, "steps": steps,
            "k": k, "r0": r0, "K": params.K, "L": params.L,
            "max_blocks": params.max_blocks, "block_size": params.block_size,
            "build_s": round(build_s, 3),
        },
        "engines": {},
    }

    for engine in engines:
        # Pallas engines run interpret-mode on CPU (the compile target is
        # TPU); keep their measured batch small so the bench stays
        # CPU-minutes sized. QPS normalizes by the measured batch.
        nq = n_queries if engine == "jnp" else min(n_queries, pallas_queries)
        Q = jnp.asarray(queries[:nq])
        rep = repeats if engine == "jnp" else 1

        _, ms_ref = timed(
            lambda: search_batch_fixed_ref(
                index, Q, k=k, r0=r0, steps=steps, engine=engine
            ),
            repeats=max(1, rep),
        )
        (d_new, i_new), ms_new = timed(
            lambda: search_batch_fixed(
                index, Q, k=k, r0=r0, steps=steps, engine=engine
            ),
            repeats=max(1, rep),
        )
        d_ref, i_ref = search_batch_fixed_ref(
            index, Q, k=k, r0=r0, steps=steps, engine=engine
        )
        rec_ref = recall_at(i_ref, gt_i[:nq], k)
        rec_new = recall_at(i_new, gt_i[:nq], k)
        report["engines"][engine] = {
            "n_queries": nq,
            "qps_ref": round(nq * 1e3 / ms_ref, 2),
            "qps_new": round(nq * 1e3 / ms_new, 2),
            "speedup": round(ms_ref / ms_new, 3),
            "recall_ref": round(rec_ref, 4),
            "recall_new": round(rec_new, 4),
        }

    seed_steps, new_steps = per_step_slots(
        index, queries[: min(n_queries, 32)], r0, steps
    )
    report["per_step_slots"] = {"ref": seed_steps, "new": new_steps}
    report["slot_check"] = {
        "total_ref": int(sum(seed_steps)),
        "total_new": int(sum(new_steps)),
        "ok": sum(new_steps) <= sum(seed_steps),
    }
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload, two engines (CI gate)")
    ap.add_argument("--out", default="BENCH_search_hotpath.json")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--engines", default=None,
                    help="comma-separated subset of jnp,kernel,inline")
    args = ap.parse_args(argv)

    if args.smoke:
        engines = ("jnp", "kernel")
        if args.engines:
            engines = tuple(args.engines.split(","))
        report = run(n=args.n or 4096, d=24, n_queries=16, repeats=1,
                     engines=engines, smoke=True)
    else:
        engines = ("jnp", "kernel", "inline")
        if args.engines:
            engines = tuple(args.engines.split(","))
        report = run(n=args.n or 100_000, engines=engines)

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    for eng, r in report["engines"].items():
        print(f"search_hotpath/{eng}: ref {r['qps_ref']} qps -> new "
              f"{r['qps_new']} qps ({r['speedup']}x), recall "
              f"{r['recall_ref']} -> {r['recall_new']}")
    print("per-step slots ref:", report["per_step_slots"]["ref"])
    print("per-step slots new:", report["per_step_slots"]["new"])

    ok = True
    sc = report["slot_check"]
    if not sc["ok"]:
        print(f"FAIL: one-pass verified {sc['total_new']} slots > seed "
              f"{sc['total_ref']}", file=sys.stderr)
        ok = False
    # per-step decay gate (the acceptance criterion): after step 0 the
    # one-pass path only verifies fresh-block deltas, so each step must
    # sit strictly below the seed's full re-selection
    ref_steps = report["per_step_slots"]["ref"]
    new_steps = report["per_step_slots"]["new"]
    for j, (rj, nj) in enumerate(zip(ref_steps, new_steps)):
        bad = nj > rj if j == 0 else (rj > 0 and nj >= rj)
        if bad:
            print(f"FAIL: step {j} one-pass verified {nj} slots vs seed "
                  f"{rj} (no per-step decay)", file=sys.stderr)
            ok = False
    for eng, r in report["engines"].items():
        if abs(r["recall_new"] - r["recall_ref"]) > 0.005 + 1e-9:
            print(f"FAIL: {eng} recall drift {r['recall_ref']} -> "
                  f"{r['recall_new']} exceeds 0.5pt", file=sys.stderr)
            ok = False
    if not report["smoke"] and report["engines"].get("jnp", {}).get(
            "speedup", 0.0) < 1.5:
        print("FAIL: jnp speedup below 1.5x", file=sys.stderr)
        ok = False
    print("slot check:", "OK" if ok else "FAILED",
          f"(new {sc['total_new']} <= ref {sc['total_ref']})")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
