"""Serving hot-path benchmark: fused one-pass pipeline vs the multi-pass seed.

Measures ``search_batch_fixed`` (one-pass incremental probing; the
Pallas engines run the fully fused select->gather->verify->bin->merge
kernel) against ``search_batch_fixed_ref`` (the per-radius re-selection
seed algorithm) on a synthetic reference workload and emits
``BENCH_search_hotpath.json`` — the repo's BENCH trajectory point for
the serving search core.

Row schema (one row per engine x dtype):

* ``engine`` / ``dtype`` / ``mode`` — ``mode`` records how the row was
  executed: ``compiled`` (XLA) or ``interpret`` (Pallas interpreter on a
  non-TPU host).  Interpret rows price every in-kernel op at
  Python-dispatch cost; they validate semantics and relative slot-work,
  not absolute device throughput.  Re-measuring on a real accelerator
  replaces the ``interpret`` rows with ``compiled`` ones under the same
  schema (ROADMAP BENCH carry-over).
* ``qps_ref`` / ``qps_new`` / ``speedup`` — seed vs one-pass wall QPS at
  the SAME ``n_queries`` (every engine measures the full batch).
* ``passes`` / ``slot_work_qps`` — the fused kernels execute
  ``1 + steps`` pipeline passes per verified slot in-kernel (distance +
  the per-step bin merges the unfused path ran as separate XLA programs
  over an HBM pool); ``slot_work_qps = qps_new * passes`` is the
  interpret-mode-normalized throughput comparable against the historical
  dist-only kernel row (1 pass).
* ``recall_ref`` / ``recall_new`` — recall@k vs brute force.
* ``parity`` — fraction of queries whose one-pass id set equals the
  multi-pass seed's.  Not exactly 1.0 by design: under block-budget
  truncation the one-pass path keeps the M best blocks of the *final*
  window rather than re-ranking per step (DESIGN.md §7), so a handful
  of queries legitimately differ (gated >= 0.95 for fp32 rows).
* ``engine_parity`` — fraction of queries whose id set equals the jnp
  row's at the same dtype: same pipeline, different engine.  This is
  the exact gate (== 1.0 for fp32 rows) pinning the fused kernels
  against the pool path at full workload scale.  Quantized rows report
  it but are gated on the recall band instead — the shortlist is
  approximate by contract.

Gates (exit 1): slot accounting (one-pass never verifies more slots than
the seed, with per-step decay), fp32 engine parity == 1.0 and seed
parity >= 0.95, recall parity ±0.5pt, quantized recall within 0.5pt of
fp32, jnp speedup >= 1.5x, and — full mode — fused-kernel slot-work
>= 2x the historical dist-only kernel row.

Full mode (default): n=100k, d=64, steps=8, all engines at n_queries=64.
Smoke mode (``--smoke``): tiny n, seconds on CPU (the CI gate).
``--large``: n=1M jnp-only point (minutes on CPU).
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    brute_force,
    build,
    DBLSHParams,
    search_batch_fixed,
    search_batch_fixed_ref,
)
from repro.core.serve_search import _select_blocks
from repro.data import make_clustered, normalize_scale

try:  # module run (benchmarks.run) vs script run (python benchmarks/...)
    from .common import recall_at, timed
except ImportError:
    from common import recall_at, timed

#: the dist-only Pallas kernel row of the pre-fusion BENCH (qps_new of
#: engine=kernel in the last committed BENCH_search_hotpath.json before
#: the fused kernel landed): 1 in-kernel pass per slot, merges in XLA.
OLD_KERNEL_DIST_ONLY_QPS = 20.44


def per_step_slots(index, Q, r0: float, steps: int):
    """Hardware-level verified-slot counts per schedule step.

    seed: every selected (blk < nb) slot of every step's fresh selection;
    new:  only the slots of blocks newly admitted at that step (the
    final-radius selection masked on block halfwidths).  Both count the
    full compiled program's gather work (no done-masking), which is what
    the device actually executes."""
    p = index.params
    nb = index.nb
    B = p.block_size
    G = jnp.einsum("lkd,qd->qlk", index.proj_vecs, jnp.asarray(Q))

    seed_counts, new_counts = [], []
    r = jnp.asarray(r0, jnp.float32)
    r_last = jnp.asarray(r0, jnp.float32)
    for _ in range(steps - 1):
        r_last = r_last * p.c
    _, bhw = _select_blocks(index, G, p.w0 * r_last)
    prev_half = -np.inf
    for _ in range(steps):
        half = 0.5 * (p.w0 * r)
        blk_j, _ = _select_blocks(index, G, p.w0 * r)
        seed_counts.append(int(jnp.sum(blk_j < nb)) * B)
        newly = (bhw <= half) & (bhw > prev_half)
        new_counts.append(int(jnp.sum(newly)) * B)
        prev_half = half
        r = r * p.c
    return seed_counts, new_counts


def _parity_frac(d_ref, i_ref, d_new, i_new):
    """Fraction of queries whose finite id set matches the seed's."""
    d_ref, i_ref, d_new, i_new = map(np.asarray, (d_ref, i_ref, d_new, i_new))
    hits = 0
    for q in range(d_ref.shape[0]):
        fr, fn = np.isfinite(d_ref[q]), np.isfinite(d_new[q])
        hits += set(i_ref[q][fr]) == set(i_new[q][fn])
    return hits / max(1, d_ref.shape[0])


def run(
    n: int = 100_000,
    d: int = 64,
    n_queries: int = 64,
    steps: int = 8,
    k: int = 10,
    r0: float = 0.5,
    rows: tuple[tuple[str, str], ...] = (("jnp", "fp32"),),
    repeats: int = 3,
    smoke: bool = False,
    seed: int = 7,
) -> dict:
    key = jax.random.key(seed)
    kd, kb = jax.random.split(key)
    allpts = make_clustered(kd, n + n_queries, d,
                            n_clusters=max(8, n // 4000), spread=0.02)
    data, queries = allpts[:n], allpts[n:]
    data, queries, _ = normalize_scale(data, queries)
    inline = any(e == "inline" for e, _ in rows)
    dtypes = {dt for _, dt in rows}
    # one index serves fp32 + one quantized dtype; a second build covers
    # the other quantized dtype (same data, same LSH key -> same layout)
    main_q = "int8" if "int8" in dtypes else (
        "bf16" if "bf16" in dtypes else "none")
    base_kw = dict(n=n, d=d, c=1.5, t=64, k=max(k, 10), K=10, L=5,
                   inline_vectors=inline)
    params = DBLSHParams.derive(quant_dtype=main_q, **base_kw)
    t0 = time.perf_counter()
    index = build(kb, jnp.asarray(data), params)
    jax.block_until_ready(index.proj_blocks)
    build_s = time.perf_counter() - t0
    indexes = {"fp32": index, main_q: index}
    for dt in dtypes - set(indexes):
        p2 = DBLSHParams.derive(quant_dtype=dt, **base_kw)
        indexes[dt] = build(kb, jnp.asarray(data), p2)

    _, gt_i = brute_force(jnp.asarray(data), jnp.asarray(queries), k=k)

    interp_host = jax.default_backend() != "tpu"
    report = {
        "bench": "search_hotpath",
        "smoke": smoke,
        "notes": (
            "Every row measures the full n_queries batch. 'interpret' "
            "rows run the Pallas interpreter on a non-TPU host: each "
            "in-kernel op costs a Python dispatch, so wall QPS tracks "
            "op count, not device throughput — slot_work_qps (qps x "
            "in-kernel passes per slot) is the comparable number. "
            "Re-measure on a TPU to replace interpret rows with "
            "compiled ones (same schema)."
        ),
        "workload": {
            "n": n, "d": d, "n_queries": n_queries, "steps": steps,
            "k": k, "r0": r0, "K": params.K, "L": params.L,
            "max_blocks": params.max_blocks, "block_size": params.block_size,
            "build_s": round(build_s, 3),
        },
        "old_kernel_dist_only_qps": OLD_KERNEL_DIST_ONLY_QPS,
        "rows": [],
    }

    Q = jnp.asarray(queries)
    ref_cache: dict[str, tuple] = {}
    base_cache: dict[str, tuple] = {}
    for engine, dtype in rows:
        idx = indexes[dtype if dtype != "fp32" else "fp32"]
        mode = "interpret" if (engine != "jnp" and interp_host) else "compiled"
        rep = repeats if mode == "compiled" else 1

        if engine not in ref_cache:
            (d_ref, i_ref), ms_ref = timed(
                lambda: search_batch_fixed_ref(
                    index, Q, k=k, r0=r0, steps=steps, engine=engine
                ),
                repeats=max(1, rep),
            )
            ref_cache[engine] = (d_ref, i_ref, ms_ref)
        d_ref, i_ref, ms_ref = ref_cache[engine]

        (d_new, i_new), ms_new = timed(
            lambda: search_batch_fixed(
                idx, Q, k=k, r0=r0, steps=steps, engine=engine, dtype=dtype
            ),
            repeats=max(1, rep),
        )
        rec_ref = recall_at(i_ref, gt_i, k)
        rec_new = recall_at(i_new, gt_i, k)
        # fused engines run 1 distance pass + `steps` bin-merge folds per
        # slot in-kernel; jnp and the seed keep merges outside the kernel
        fused = engine in ("kernel", "inline")
        passes = (1 + steps) if fused else 1
        qps_new = n_queries * 1e3 / ms_new
        # engine parity: same one-pass pipeline, different engine — the
        # jnp row at the same dtype is the baseline.  This is the gate
        # that pins the fused kernels against the pool path at full
        # workload scale; parity-vs-ref below additionally carries the
        # (documented, §7) one-pass-vs-multi-pass truncation delta.
        if engine == "jnp":
            base_cache[dtype] = (d_new, i_new)
            engine_parity = 1.0
        elif dtype in base_cache:
            bd, bi = base_cache[dtype]
            engine_parity = _parity_frac(bd, bi, d_new, i_new)
        else:
            engine_parity = None
        report["rows"].append({
            "engine": engine,
            "dtype": dtype,
            "mode": mode,
            "n_queries": n_queries,
            "qps_ref": round(n_queries * 1e3 / ms_ref, 2),
            "qps_new": round(qps_new, 2),
            "speedup": round(ms_ref / ms_new, 3),
            "passes": passes,
            "slot_work_qps": round(qps_new * passes, 2),
            "recall_ref": round(rec_ref, 4),
            "recall_new": round(rec_new, 4),
            "parity": round(_parity_frac(d_ref, i_ref, d_new, i_new), 4),
            "engine_parity": (None if engine_parity is None
                              else round(engine_parity, 4)),
        })

    seed_steps, new_steps = per_step_slots(
        index, queries[: min(n_queries, 32)], r0, steps
    )
    report["per_step_slots"] = {"ref": seed_steps, "new": new_steps}
    report["slot_check"] = {
        "total_ref": int(sum(seed_steps)),
        "total_new": int(sum(new_steps)),
        "ok": sum(new_steps) <= sum(seed_steps),
    }
    return report


def _gates(report) -> bool:
    ok = True
    sc = report["slot_check"]
    if not sc["ok"]:
        print(f"FAIL: one-pass verified {sc['total_new']} slots > seed "
              f"{sc['total_ref']}", file=sys.stderr)
        ok = False
    # per-step decay gate: after step 0 the one-pass path only verifies
    # fresh-block deltas, so each step must sit below the seed's full
    # re-selection
    ref_steps = report["per_step_slots"]["ref"]
    new_steps = report["per_step_slots"]["new"]
    for j, (rj, nj) in enumerate(zip(ref_steps, new_steps)):
        bad = nj > rj if j == 0 else (rj > 0 and nj >= rj)
        if bad:
            print(f"FAIL: step {j} one-pass verified {nj} slots vs seed "
                  f"{rj} (no per-step decay)", file=sys.stderr)
            ok = False
    fp32_recall = {r["engine"]: r["recall_new"]
                   for r in report["rows"] if r["dtype"] == "fp32"}
    for r in report["rows"]:
        tag = f"{r['engine']}/{r['dtype']}"
        if abs(r["recall_new"] - r["recall_ref"]) > 0.005 + 1e-9:
            print(f"FAIL: {tag} recall drift {r['recall_ref']} -> "
                  f"{r['recall_new']} exceeds 0.5pt", file=sys.stderr)
            ok = False
        if r["dtype"] == "fp32":
            # fused engines must match the jnp one-pass path exactly —
            # same distances, same merge semantics, different engine
            ep = r.get("engine_parity")
            if ep is not None and ep < 1.0 - 1e-9:
                print(f"FAIL: {tag} fused-vs-jnp engine parity "
                      f"{ep} < 1.0", file=sys.stderr)
                ok = False
            # vs the multi-pass seed the one-pass path keeps the M best
            # blocks of the *final* window rather than re-ranking per
            # step (DESIGN.md §7) — under truncation a handful of
            # queries legitimately differ, so this band is loose where
            # the engine-parity gate above is exact
            if r["parity"] < 0.95 - 1e-9:
                print(f"FAIL: {tag} one-pass-vs-seed id-set parity "
                      f"{r['parity']} < 0.95", file=sys.stderr)
                ok = False
        else:
            base = fp32_recall.get(r["engine"])
            if base is not None and base - r["recall_new"] > 0.005 + 1e-9:
                print(f"FAIL: {tag} quantized recall {r['recall_new']} "
                      f"more than 0.5pt below fp32 {base}", file=sys.stderr)
                ok = False
    jnp_rows = [r for r in report["rows"]
                if r["engine"] == "jnp" and r["dtype"] == "fp32"]
    if not report["smoke"] and jnp_rows and jnp_rows[0]["speedup"] < 1.5:
        print("FAIL: jnp speedup below 1.5x", file=sys.stderr)
        ok = False
    if not report["smoke"]:
        for r in report["rows"]:
            if r["engine"] == "kernel" and r["dtype"] == "fp32":
                floor = 2.0 * report["old_kernel_dist_only_qps"]
                if r["slot_work_qps"] < floor:
                    print(f"FAIL: fused kernel slot-work {r['slot_work_qps']}"
                          f" qps < 2x dist-only baseline ({floor})",
                          file=sys.stderr)
                    ok = False
    return ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload (CI gate)")
    ap.add_argument("--large", action="store_true",
                    help="n=1M jnp-only point")
    ap.add_argument("--out", default="BENCH_search_hotpath.json")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--engines", default=None,
                    help="comma-separated subset of jnp,kernel,inline")
    args = ap.parse_args(argv)

    if args.smoke:
        engines = ("jnp", "kernel")
        dt_rows = (("jnp", "int8"),)
        kw = dict(n=args.n or 4096, d=24, n_queries=16, repeats=1, smoke=True)
    elif args.large:
        engines = ("jnp",)
        dt_rows = (("jnp", "int8"),)
        kw = dict(n=args.n or 1_000_000, n_queries=64)
    else:
        engines = ("jnp", "kernel", "inline")
        dt_rows = (("jnp", "int8"), ("jnp", "bf16"), ("kernel", "int8"))
        kw = dict(n=args.n or 100_000, n_queries=64)
    if args.engines:
        engines = tuple(args.engines.split(","))
        dt_rows = tuple((e, dt) for e, dt in dt_rows if e in engines)
    rows = tuple((e, "fp32") for e in engines) + dt_rows

    report = run(rows=rows, **kw)

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    for r in report["rows"]:
        print(f"search_hotpath/{r['engine']}/{r['dtype']} [{r['mode']}]: "
              f"ref {r['qps_ref']} qps -> new {r['qps_new']} qps "
              f"({r['speedup']}x, slot-work {r['slot_work_qps']}), recall "
              f"{r['recall_ref']} -> {r['recall_new']}, parity {r['parity']}"
              f", engine-parity {r['engine_parity']}")
    print("per-step slots ref:", report["per_step_slots"]["ref"])
    print("per-step slots new:", report["per_step_slots"]["new"])

    ok = _gates(report)
    sc = report["slot_check"]
    print("gates:", "OK" if ok else "FAILED",
          f"(new {sc['total_new']} <= ref {sc['total_ref']})")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
