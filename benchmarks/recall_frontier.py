"""Recall-vs-QPS frontier: planned adaptive termination vs fixed schedule.

The fixed serving schedule forces one (r0, steps) on every query: easy
queries pay the full probe budget, hard queries stop wherever the
schedule ends.  The ``repro.tune`` subsystem replaces that with a
calibrated plan (r0 anchored to the collection's NN-distance scale) and
per-query C1/C2 termination.  This benchmark pins the trade as a BENCH
trajectory (``BENCH_recall_frontier.json``):

* the **fixed frontier** — recall@k, QPS, and mean verified slots for
  every schedule length ``1..steps`` at the calibrated r0;
* the **adaptive point** — the same budget ``steps`` with
  ``Termination()`` (C1 candidate budget + C2 certification + batch
  early exit): its recall with its mean termination step and mean
  verified slots, which must beat the fixed schedule's at equal recall;
* the **planner's answer** — the schedule ``RecallTarget`` picks off
  the calibration table for a sweep of targets.

Gates (exit 1 on failure; CI runs ``--smoke`` on every push):
  * adaptive recall within 1pt of the fixed schedule at the same length
    (equal recall band) with mean termination step strictly below it;
  * adaptive mean verified slots ≤ fixed (strict in full mode — the
    acceptance point: recall@10 ≥ 0.85 at n=100k, d=64 with strictly
    fewer verified slots than the fixed 8-step schedule).

Full mode: n=100k, d=64.  Smoke (``--smoke``): tiny n, CPU-seconds.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np
import jax.numpy as jnp

from repro.core import DBLSHParams, Termination, brute_force, build, search_batch_fixed
from repro.data import make_clustered, normalize_scale
from repro.tune import (
    RecallTarget,
    calibrate,
    plan,
    search_batch_adaptive,
    termination_step_histogram,
)

try:  # module run (benchmarks.run) vs script run (python benchmarks/...)
    from .common import recall_at, timed
except ImportError:
    from common import recall_at, timed


def run(
    n: int = 100_000,
    d: int = 64,
    n_queries: int = 64,
    n_calib: int = 32,
    steps: int = 8,
    k: int = 10,
    engine: str = "jnp",
    repeats: int = 3,
    smoke: bool = False,
    seed: int = 7,
) -> dict:
    key = jax.random.key(seed)
    kd, kb = jax.random.split(key)
    allpts = make_clustered(kd, n + n_queries + n_calib, d,
                            n_clusters=max(8, n // 4000), spread=0.02)
    data = allpts[:n]
    queries = allpts[n:n + n_queries]
    calib_q = allpts[n + n_queries:]
    data, queries, scale = normalize_scale(data, queries)
    calib_q = calib_q * scale
    # max_blocks above the derived floor: at n=100k the 2(2t+k)/B budget
    # gives M=5, and five MINDIST-best blocks per table are all admitted
    # by the first window — truncation, not the radius schedule, would
    # govern admission and the frontier would be flat.  M=16 keeps the
    # schedule the binding constraint (per-step admission actually grows
    # with the radius), which is the regime the planner exists for.
    params = DBLSHParams.derive(
        n=n, d=d, c=1.5, t=64, k=max(k, 10), K=10, L=5, max_blocks=16,
    )
    t0 = time.perf_counter()
    index = build(kb, jnp.asarray(data), params)
    jax.block_until_ready(index.proj_blocks)
    build_s = time.perf_counter() - t0

    # calibrate on the held-out sample: r0 comes off the data's
    # NN-distance scale, per-length recall/cost back the planner
    table = calibrate(index, jnp.asarray(calib_q), k=k, steps_max=steps,
                      engine=engine)
    r0 = table.r0

    _, gt_i = brute_force(jnp.asarray(data), jnp.asarray(queries), k=k)
    Q = jnp.asarray(queries)

    report = {
        "bench": "recall_frontier",
        "smoke": smoke,
        "workload": {
            "n": n, "d": d, "n_queries": n_queries, "n_calib": n_calib,
            "steps": steps, "k": k, "engine": engine,
            "K": params.K, "L": params.L, "max_blocks": params.max_blocks,
            "block_size": params.block_size, "c1_budget": params.budget,
            "r0_calibrated": round(float(r0), 6),
            "build_s": round(build_s, 3),
        },
        "calibration": {
            "recall": [round(x, 4) for x in table.recall],
            "cost_slots": [round(x, 1) for x in table.cost_slots],
        },
    }

    # ---- fixed frontier: one point per schedule length
    fixed = []
    for j in range(1, steps + 1):
        (dd, ii, ss), ms = timed(
            lambda j=j: search_batch_fixed(
                index, Q, k=k, r0=r0, steps=j, engine=engine,
                with_stats=True,
            ),
            repeats=max(1, repeats),
        )
        fixed.append({
            "steps": j,
            "recall": round(recall_at(ii, gt_i, k), 4),
            "qps": round(n_queries * 1e3 / ms, 2),
            "mean_slots": round(float(np.asarray(ss["candidates"]).mean()), 1),
            "mean_term_step": round(
                float(np.asarray(ss["radius_steps"]).mean()), 3),
        })
    report["fixed"] = fixed

    # ---- adaptive point: same budget, C1+C2 done masks + early exit
    term = Termination()
    (da, ia, sa), ms_a = timed(
        lambda: search_batch_adaptive(
            index, Q, k=k, r0=r0, steps=steps, engine=engine,
            termination=term,
        ),
        repeats=max(1, repeats),
    )
    hist = termination_step_histogram(sa, steps)
    report["adaptive"] = {
        "steps_budget": steps,
        "recall": round(recall_at(ia, gt_i, k), 4),
        "qps": round(n_queries * 1e3 / ms_a, 2),
        "mean_slots": round(float(np.asarray(sa["candidates"]).mean()), 1),
        "mean_term_step": round(
            float(np.asarray(sa["radius_steps"]).mean()), 3),
        "term_step_hist": [int(x) for x in hist],
    }

    # ---- what the planner answers for a sweep of recall targets
    report["planner"] = [
        {"target": t_, "steps": plan(table, RecallTarget(t_)).steps}
        for t_ in (0.5, 0.8, 0.85, 0.9, 0.95)
    ]

    # ---- the planned adaptive point: RecallTarget(0.85) end to end —
    # the planner picks the schedule off the calibration table, adaptive
    # termination trims easy queries inside it.  This is the acceptance
    # point: recall@k >= 0.85 with strictly fewer verified slots than
    # the full fixed schedule.
    planned = plan(table, RecallTarget(0.85, max_steps=steps))
    (dp, ip, sp), ms_p = timed(
        lambda: search_batch_adaptive(
            index, Q, k=k, r0=planned.r0, steps=planned.steps,
            engine=engine, termination=planned.termination,
        ),
        repeats=max(1, repeats),
    )
    report["planned_adaptive"] = {
        "target": 0.85,
        "steps_planned": planned.steps,
        "recall": round(recall_at(ip, gt_i, k), 4),
        "qps": round(n_queries * 1e3 / ms_p, 2),
        "mean_slots": round(float(np.asarray(sp["candidates"]).mean()), 1),
        "mean_term_step": round(
            float(np.asarray(sp["radius_steps"]).mean()), 3),
        "term_step_hist": [
            int(x) for x in termination_step_histogram(sp, planned.steps)
        ],
    }
    return report


def _gate(report: dict) -> bool:
    ok = True
    fixed_last = report["fixed"][-1]
    ad = report["adaptive"]
    steps = fixed_last["steps"]

    # equal recall band: the adaptive path may trade at most 1pt of the
    # full fixed schedule's recall for its saved work
    if ad["recall"] < fixed_last["recall"] - 0.01 - 1e-9:
        print(f"FAIL: adaptive recall {ad['recall']} more than 1pt below "
              f"fixed {fixed_last['recall']}", file=sys.stderr)
        ok = False
    # ...and inside that band it must actually save schedule steps
    if not ad["mean_term_step"] < steps:
        print(f"FAIL: adaptive mean termination step {ad['mean_term_step']} "
              f"not strictly below the fixed {steps}-step schedule",
              file=sys.stderr)
        ok = False
    if ad["mean_slots"] > fixed_last["mean_slots"] + 1e-9:
        print(f"FAIL: adaptive verified {ad['mean_slots']} mean slots > "
              f"fixed {fixed_last['mean_slots']}", file=sys.stderr)
        ok = False
    pa = report["planned_adaptive"]
    if pa["mean_term_step"] >= steps:
        print(f"FAIL: planned-adaptive mean termination step "
              f"{pa['mean_term_step']} not below the fixed {steps}-step "
              "schedule", file=sys.stderr)
        ok = False
    if not report["smoke"]:
        # the acceptance point: recall floor with strict slot savings
        if pa["recall"] < 0.85:
            print(f"FAIL: planned-adaptive recall {pa['recall']} below the "
                  "0.85 acceptance floor", file=sys.stderr)
            ok = False
        if not pa["mean_slots"] < fixed_last["mean_slots"]:
            print(f"FAIL: planned-adaptive mean slots {pa['mean_slots']} not "
                  f"strictly below fixed {fixed_last['mean_slots']}",
                  file=sys.stderr)
            ok = False
    return ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload (CI gate)")
    ap.add_argument("--out", default="BENCH_recall_frontier.json")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--engine", default="jnp")
    args = ap.parse_args(argv)

    if args.smoke:
        report = run(n=args.n or 8192, d=24, n_queries=32, n_calib=16,
                     repeats=1, engine=args.engine, smoke=True)
    else:
        report = run(n=args.n or 100_000, engine=args.engine)

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")

    for row in report["fixed"]:
        print(f"fixed/steps={row['steps']}: recall {row['recall']}, "
              f"{row['qps']} qps, {row['mean_slots']} slots")
    ad = report["adaptive"]
    print(f"adaptive/budget={ad['steps_budget']}: recall {ad['recall']}, "
          f"{ad['qps']} qps, {ad['mean_slots']} slots, mean term step "
          f"{ad['mean_term_step']}, hist {ad['term_step_hist']}")
    print("planner:", ", ".join(
        f"recall>={p['target']}→{p['steps']} steps" for p in report["planner"]
    ))
    pa = report["planned_adaptive"]
    print(f"planned-adaptive/target=0.85: {pa['steps_planned']} steps, "
          f"recall {pa['recall']}, {pa['qps']} qps, {pa['mean_slots']} "
          f"slots, mean term step {pa['mean_term_step']}")

    ok = _gate(report)
    print("frontier gates:", "OK" if ok else "FAILED")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
