"""Fig. 4 reproduction: rho* vs rho and the 1/c^alpha bound.

Validates Lemma 3 numerically: rho*(c; w0=4c^2) <= 1/c^4.746 << 1/c,
and the paper's w=0.4c^2 example where rho exceeds 1/c while rho* stays
bounded."""

from __future__ import annotations

import math

import numpy as np

from repro.core.params import alpha_of_gamma, rho_star


def run():
    rows = []
    for c in np.linspace(1.1, 3.0, 20):
        w_big = 4 * c * c  # gamma = 2
        w_small = 0.4 * c * c  # gamma = 0.2
        alpha = alpha_of_gamma(2.0)
        rows.append({
            "c": float(c),
            "rho_star_4c2": rho_star(float(c), float(w_big)),
            "bound_1_c_alpha": float(c) ** (-alpha),
            "bound_1_c": 1.0 / float(c),
            "rho_star_04c2": rho_star(float(c), float(w_small)),
        })
    return rows


def main():
    rows = run()
    print(f"{'c':>6}{'rho*(4c^2)':>12}{'1/c^a':>10}{'1/c':>8}{'rho*(0.4c^2)':>14}")
    for r in rows:
        print(f"{r['c']:>6.2f}{r['rho_star_4c2']:>12.5f}{r['bound_1_c_alpha']:>10.5f}"
              f"{r['bound_1_c']:>8.4f}{r['rho_star_04c2']:>14.5f}")
        assert r["rho_star_4c2"] <= r["bound_1_c_alpha"] + 1e-9
    print(f"alpha(gamma=2) = {alpha_of_gamma(2.0):.4f}  (paper: 4.746)")
    return rows


if __name__ == "__main__":
    main()
