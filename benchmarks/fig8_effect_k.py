"""Fig. 8 reproduction: recall / overall ratio as k varies."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import brute_force

from .common import load_dataset, methods_for, recall_and_ratio, timed


def run(ks=(1, 10, 25, 50, 100), dataset="deep-s", scale=0.5):
    data, queries = load_dataset(dataset, scale)
    Q = jnp.asarray(queries)
    rows = []
    for k in ks:
        gt = brute_force(jnp.asarray(data), Q, k=k)
        for method, (search, _) in methods_for(data, k=k).items():
            (d, i), ms = timed(search, Q, k=k, repeats=2)
            rec, ratio = recall_and_ratio(d, i, gt[0], gt[1], k)
            rows.append({"k": k, "method": method, "recall": rec,
                         "ratio": ratio, "query_ms_per_q": ms / Q.shape[0]})
    return rows


def main(ks=(1, 10, 50)):
    rows = run(ks)
    print(f"{'k':>5}{'method':<14}{'recall':>8}{'ratio':>8}{'q_ms':>8}")
    for r in rows:
        print(f"{r['k']:>5}{r['method']:<14}{r['recall']:>8.3f}"
              f"{r['ratio']:>8.3f}{r['query_ms_per_q']:>8.2f}")
    return rows


if __name__ == "__main__":
    main()
