"""Regenerate the generated sections of EXPERIMENTS.md from dry-run JSONs
and benchmark runs. Hand-written narrative lives in the template below;
tables are rebuilt from benchmarks/results/dryrun/*."""

from __future__ import annotations

import json
import os

from repro.configs import CONFIGS, SHAPES

from . import roofline as rl

OUT = os.path.join(os.path.dirname(__file__), "../EXPERIMENTS.md")


def dryrun_table(mesh_tag):
    lines = [
        "| arch | shape | status | mem/dev (GiB) | fits 16G | compile (s) | HLO TFLOPs/dev | coll GB/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    base = os.path.join(rl.RESULTS_DIR, mesh_tag)
    for arch in sorted(CONFIGS):
        for shape in SHAPES:
            p = os.path.join(base, f"{arch}__{shape}.json")
            if not os.path.exists(p):
                lines.append(f"| {arch} | {shape} | missing | | | | | |")
                continue
            r = json.load(open(p))
            if r["status"] == "skipped":
                lines.append(f"| {arch} | {shape} | skipped (full attention @500k) | | | | | |")
            elif r["status"] == "error":
                lines.append(f"| {arch} | {shape} | ERROR: {r['error'][:60]} | | | | | |")
            else:
                gb = r["memory"]["per_device_total"] / 2**30
                lines.append(
                    f"| {arch} | {shape} | ok | {gb:.1f} | {'yes' if gb < 16 else 'NO'} | "
                    f"{r['compile_s']} | {r['hlo_stats']['flops'] / 1e12:.1f} | "
                    f"{r['hlo_stats']['collective_bytes'] / 2**30:.0f} |"
                )
    return "\n".join(lines)


def _advice(r):
    d = r["dominant_corrected"]
    if d == "collective":
        return "cut wire bytes: fewer microbatches / avoid per-layer reshards / int8 cross-pod"
    if d == "memory":
        if r["shape"].startswith("decode") or r["shape"].startswith("long"):
            return "bandwidth-bound cache sweep: grow batch or quantize KV/state"
        return "raise arithmetic intensity: fuse verify/attention, bigger microbatch"
    return "compute-bound: close MODEL/HLO gap (remat waste, attention O(T^2))"


def roofline_table(mesh_tag):
    rows = rl.run(mesh_tag)
    lines = [
        "| arch | shape | compute (s) | mem_hlo (s) | mem_min (s) | collective (s) | dominant* | MODEL/HLO | roofline frac | to move the dominant term |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['status']} | | | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3f} | {r['t_memory_s']:.3f} | "
            f"{r['t_memory_min_s']:.4f} | {r['t_collective_s']:.3f} | {r['dominant_corrected']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} | {_advice(r)} |"
        )
    return "\n".join(lines)


def main():
    with open(OUT) as f:
        txt = f.read()
    for tag, gen in [
        ("DRYRUN_SINGLE", dryrun_table("pod16x16")),
        ("DRYRUN_MULTI", dryrun_table("pod2x16x16")),
        ("ROOFLINE_SINGLE", roofline_table("pod16x16")),
        ("ROOFLINE_MULTI", roofline_table("pod2x16x16")),
    ]:
        start, end = f"<!-- {tag}:BEGIN -->", f"<!-- {tag}:END -->"
        if start in txt:
            pre, rest = txt.split(start, 1)
            _, post = rest.split(end, 1)
            txt = pre + start + "\n" + gen + "\n" + end + post
    with open(OUT, "w") as f:
        f.write(txt)
    print("EXPERIMENTS.md tables regenerated")


if __name__ == "__main__":
    main()
