"""Shared benchmark substrate: scaled paper datasets, metrics, timing."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DBLSHParams, FBLSH, MQIndex, C2Index, brute_force, build
from repro.core import search_batch_fixed
from repro.data import make_clustered, normalize_scale

# paper datasets scaled to CPU-minutes (cardinality, dim, clusters).
SCALED_DATASETS = {
    "audio-s": (20_000, 96, 24),
    "deep-s": (30_000, 128, 32),
    "sift-s": (50_000, 64, 48),
}

N_QUERIES = 50
DEFAULT_K = 50


def load_dataset(name: str, scale: float = 1.0, seed: int = 0):
    n, d, nc = SCALED_DATASETS[name]
    n = int(n * scale)
    key = jax.random.key(seed)
    allpts = make_clustered(key, n + N_QUERIES, d, n_clusters=nc, spread=0.02)
    data, queries = allpts[:n], allpts[n:]
    data, queries, _ = normalize_scale(data, queries)
    return np.asarray(data), np.asarray(queries)


def recall_and_ratio(dists, ids, gt_d, gt_i, k):
    """Paper Eq. 11/12: overall ratio + recall, averaged over queries."""
    ids = np.asarray(ids)
    dists = np.asarray(dists)
    gt_d = np.maximum(np.asarray(gt_d), 1e-9)
    recs, ratios = [], []
    for q in range(ids.shape[0]):
        recs.append(len(set(ids[q][:k].tolist()) & set(np.asarray(gt_i)[q][:k].tolist())) / k)
        dq = np.where(np.isfinite(dists[q][:k]), dists[q][:k], gt_d[q][:k] * 10)
        ratios.append(float(np.mean(dq / gt_d[q][:k])))
    return float(np.mean(recs)), float(np.mean(ratios))


def recall_at(ids, gt_i, k):
    """Mean recall@k of returned ids vs brute-force ground-truth ids."""
    ids = np.asarray(ids)[:, :k]
    gt_i = np.asarray(gt_i)[:, :k]
    return float(np.mean([
        len(set(a.tolist()) & set(b.tolist())) / k
        for a, b in zip(ids, gt_i)
    ]))


def timed(fn, *args, repeats=3, **kw):
    """jit warmup + best-of wall time in ms."""
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e3


def build_dblsh(data, *, c=1.5, t=64, k=DEFAULT_K, K=10, L=5, seed=1,
                inline=False):
    params = DBLSHParams.derive(
        n=data.shape[0], d=data.shape[1], c=c, t=t, k=k, K=K, L=L,
        inline_vectors=inline,
    )
    t0 = time.perf_counter()
    index = build(jax.random.key(seed), jnp.asarray(data), params)
    jax.block_until_ready(index.proj_blocks)
    return index, (time.perf_counter() - t0)


def methods_for(data, k=DEFAULT_K, c=1.5):
    """Build every method on `data`; returns {name: (search_fn, idx_time)}."""
    n, d = data.shape
    dj = jnp.asarray(data)
    out = {}

    index, bt = build_dblsh(data, c=c, k=k)
    out["DB-LSH"] = (
        lambda Q, k=k: search_batch_fixed(index, Q, k=k, r0=0.5, steps=8),
        bt,
    )

    t0 = time.perf_counter()
    fb = FBLSH.build(jax.random.key(2), dj, K=10, L=5, w0=4 * c * c, c=c, t=64)
    jax.block_until_ready(fb.proj)
    out["FB-LSH"] = (lambda Q, k=k: fb.search_batch(Q, k=k, r0=0.5),
                     time.perf_counter() - t0)

    t0 = time.perf_counter()
    mq = MQIndex.build(jax.random.key(3), dj, m=15, beta=0.08)
    jax.block_until_ready(mq.proj)
    out["MQ(PM-LSH)"] = (lambda Q, k=k: mq.search_batch(Q, k=k),
                         time.perf_counter() - t0)

    t0 = time.perf_counter()
    c2 = C2Index.build(jax.random.key(4), dj, m=40, w=2.0)
    jax.block_until_ready(c2.proj)
    out["C2(QALSH)"] = (lambda Q, k=k: c2.search_batch(Q, k=k),
                        time.perf_counter() - t0)
    return out
