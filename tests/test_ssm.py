"""SSD correctness: chunked forward == naive sequential recurrence ==
step-by-step decode."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ssm


@dataclasses.dataclass(frozen=True)
class TinyCfg:
    d_model: int = 32
    ssm_state: int = 8
    ssm_heads: int = 4
    ssm_head_dim: int = 8
    norm_eps: float = 1e-5


def _naive_ssd(x, p, cfg):
    """Sequential reference: run ssm_decode token by token."""
    B, T, D = x.shape
    d_inner, H, P, N, conv_dim, _ = ssm.ssm_dims(cfg)
    state = jnp.zeros((B, H, N, P), x.dtype)
    conv = jnp.zeros((B, ssm.CONV_W - 1, conv_dim), x.dtype)
    ys = []
    for t in range(T):
        y, state, conv = ssm.ssm_decode(x[:, t : t + 1], p, cfg, state, conv)
        ys.append(y)
    return jnp.concatenate(ys, axis=1), state


def test_chunked_matches_sequential():
    cfg = TinyCfg()
    key = jax.random.key(0)
    p = ssm.ssm_params(jax.random.key(1), cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model)) * 0.5

    y_seq, s_seq = _naive_ssd(x, p, cfg)
    for chunk in [4, 8, 16]:
        y_chk, s_chk, _ = ssm.ssm_forward(x, p, cfg, chunk=chunk)
        np.testing.assert_allclose(
            np.asarray(y_chk), np.asarray(y_seq), rtol=2e-4, atol=2e-4,
            err_msg=f"chunk={chunk}",
        )
        np.testing.assert_allclose(
            np.asarray(s_chk), np.asarray(s_seq), rtol=2e-4, atol=2e-4
        )


def test_prefill_then_decode_continuity():
    """State handoff: prefill T tokens, then decode more — must equal the
    full-sequence forward."""
    cfg = TinyCfg()
    p = ssm.ssm_params(jax.random.key(2), cfg)
    x = jax.random.normal(jax.random.key(3), (1, 12, cfg.d_model)) * 0.5

    y_full, s_full, _ = ssm.ssm_forward(x, p, cfg, chunk=4)

    y_pre, s_pre, conv_tail = ssm.ssm_forward(x[:, :8], p, cfg, chunk=4)
    state, conv = s_pre, conv_tail
    ys = []
    for t in range(8, 12):
        y1, state, conv = ssm.ssm_decode(x[:, t : t + 1], p, cfg, state, conv)
        ys.append(y1)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_dec), np.asarray(y_full[:, 8:]), rtol=3e-4, atol=3e-4
    )
    np.testing.assert_allclose(np.asarray(state), np.asarray(s_full), rtol=3e-4, atol=3e-4)


def test_no_nans_long():
    cfg = TinyCfg()
    p = ssm.ssm_params(jax.random.key(4), cfg)
    x = jax.random.normal(jax.random.key(5), (1, 256, cfg.d_model))
    y, s, _ = ssm.ssm_forward(x, p, cfg, chunk=64)
    assert np.all(np.isfinite(np.asarray(y)))
    assert np.all(np.isfinite(np.asarray(s)))
