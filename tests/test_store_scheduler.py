"""Store scheduler v2 test harness.

Four suites over the overlapped / cached / multi-tenant StoreService:

* **Equivalence** — bit-equality of the overlapped async path vs the
  synchronous path vs a direct ``search_batch_fixed`` call, for every
  batch shape in the menu including partial-fill padding and the
  forced-timeout drain (driven by a fake clock, so the timeout branch is
  deterministic).
* **Cache freshness (property)** — interleaved add / remove / compact /
  snapshot-restore / query sequences never serve a stale cache hit:
  every served result is bit-equal to a fresh fixed-schedule search at
  the collection's current version.
* **Recall regression** — seeded (c, t, k) configs pin a recall@10 band
  vs brute force through the full scheduler path, so scheduler changes
  cannot silently trade accuracy for throughput.
* **Fake-clock units** — token-bucket refill, weighted round-robin
  draining, ``max_wait_ms`` timeout drains, deterministic QPS/latency
  percentiles, and the query-counter fix (real rows, not padded shape).

The engine matrix is env-driven: ``REPRO_STORE_TEST_ENGINES`` (space or
comma separated; default ``jnp``) — CI runs ``jnp`` and ``inline`` under
``JAX_PLATFORMS=cpu``.  Pallas engines run in interpret mode on CPU.
"""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st
from repro.core import DBLSHParams, brute_force, search_batch_fixed
from repro.data import make_clustered, normalize_scale
from repro.store import (
    Collection,
    CompactionPolicy,
    QueryResultCache,
    QuotaExceeded,
    StoreService,
)

ENGINES = os.environ.get("REPRO_STORE_TEST_ENGINES", "jnp").replace(",", " ").split()


class FakeClock:
    """Injectable monotonic clock: time only moves when told to."""

    def __init__(self, start: float = 0.0):
        self.now = start

    def __call__(self) -> float:
        return self.now

    def advance(self, seconds: float) -> float:
        self.now += seconds
        return self.now


@pytest.fixture(scope="module")
def setup():
    kd, kb = jax.random.split(jax.random.key(23))
    allpts = make_clustered(kd, 422, 16, n_clusters=8, spread=0.02)
    data, queries = allpts[:400], allpts[400:]
    data, queries, _ = normalize_scale(data, queries)
    return np.asarray(data), np.asarray(queries), kb


@pytest.fixture(scope="module")
def col(setup):
    """Read-only collection shared by the equivalence / fake-clock suites
    (inline layout so every engine can verify it)."""
    data, _, kb = setup
    params = DBLSHParams.derive(
        n=400, d=16, c=1.5, w0=3.6, t=16, k=10, inline_vectors=True
    )
    return Collection.create("sched", kb, data, params=params)


def _service(col, *, engine="jnp", depth=2, cache_size=0, clock=None, **kw):
    kw.setdefault("batch_shapes", (1, 4, 8))
    kw.setdefault("max_wait_ms", 1e9)
    svc = StoreService(
        default_k=10, r0=0.5, steps=6, engine=engine,
        interpret=True if engine != "jnp" else None,
        inflight_depth=depth, cache_size=cache_size,
        **({"clock": clock} if clock is not None else {}),
        **kw,
    )
    svc.attach(col)
    return svc


def _results(reqs):
    return np.stack([r.dists for r in reqs]), np.stack([r.ids for r in reqs])


# ---------------------------------------------------------------------------
# Equivalence: overlapped async == synchronous == direct, per batch shape
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ENGINES)
def test_async_matches_sync_all_shapes(setup, col, engine):
    """Every batch shape in the menu (exact fill and partial fill): the
    overlapped path (in-flight ring, drained by fake-clock timeouts so
    every chunk dispatches at its own shape without a forced sync) and
    the synchronous path return bit-identical results, equal to one
    direct search_batch_fixed call."""
    data, queries, _ = setup
    # chunk sizes 1, 4, 8 (exact fill per shape), then 3 -> 4 and
    # 6 -> 8 (the partial-fill padded-drain paths)
    cuts = [1, 5, 13, 16, 22]

    def run(depth, force):
        clock = FakeClock()
        svc = _service(
            col, engine=engine, depth=depth, clock=clock, max_wait_ms=5.0
        )
        reqs, start = [], 0
        for cut in cuts:
            for q in queries[start:cut]:
                reqs.append(svc.submit("sched", q))
            if force:
                svc.step(force=True)  # drain + complete: fully synchronous
            else:
                clock.advance(0.006)  # > max_wait_ms: timeout drain
                svc.step()            # issue only; ring stays in flight
            start = cut
        svc.flush()
        assert all(r.done for r in reqs)
        stats = svc.stats("sched")
        assert stats["batches"] == len(cuts)  # one batch per chunk shape
        assert stats["queries"] == len(queries)
        return (*_results(reqs), stats)

    d_sync, i_sync, stats_sync = run(depth=0, force=True)
    d_async, i_async, stats_async = run(depth=3, force=False)
    assert stats_sync["overlap_ratio"] == 0.0
    assert stats_async["overlap_ratio"] > 0.0  # the ring actually overlapped
    # same compiled program both ways -> bitwise identical
    np.testing.assert_array_equal(i_async, i_sync)
    np.testing.assert_array_equal(d_async, d_sync)

    d_direct, i_direct = search_batch_fixed(
        col.index, jnp.asarray(queries), k=10, r0=0.5, steps=6,
        engine=engine, interpret=True if engine != "jnp" else None,
    )
    np.testing.assert_array_equal(i_sync, np.asarray(i_direct))
    np.testing.assert_array_equal(d_sync, np.asarray(d_direct))


@pytest.mark.parametrize("engine", ENGINES)
def test_timeout_drain_matches_direct(setup, col, engine):
    """The forced-timeout partial drain (queue smaller than every batch
    shape when the clock runs out) pads and returns the same results as
    a direct call — and only fires once the fake clock actually passes
    ``max_wait_ms``."""
    data, queries, _ = setup
    clock = FakeClock()
    svc = _service(col, engine=engine, depth=2, clock=clock, max_wait_ms=5.0)
    reqs = [svc.submit("sched", q) for q in queries[:3]]  # < smallest useful fill
    assert svc.step() == 0  # not full, not timed out -> nothing drains
    clock.advance(0.006)  # 6 ms > max_wait_ms
    assert svc.step() == 3  # timeout drain: 3 real rows padded to shape 4
    svc.flush()
    assert all(r.done for r in reqs)
    d, i = _results(reqs)
    d_direct, i_direct = search_batch_fixed(
        col.index, jnp.asarray(queries[:3]), k=10, r0=0.5, steps=6,
        engine=engine, interpret=True if engine != "jnp" else None,
    )
    np.testing.assert_array_equal(i, np.asarray(i_direct))
    np.testing.assert_array_equal(d, np.asarray(d_direct))
    stats = svc.stats("sched")
    assert stats["batches"] == 1 and stats["queries"] == 3


# ---------------------------------------------------------------------------
# Cache freshness under interleaved updates (property test)
# ---------------------------------------------------------------------------

# Op scripts: bounded menu so the index shapes (and thus XLA compiles)
# stay closed while the interleavings vary.  'q' serves a batch through
# the scheduler and checks it against a fresh search; 'Q' re-serves the
# same batch (cache-hit path); 'a' adds 16 points; 'r' tombstones 16;
# 'c' compacts; 's' snapshot+restore (fresh version, same state).
_SCRIPTS = [
    "qQaqQrqQcqQ",
    "aqQcqQrqQsqQ",
    "qQrqQaqQsqQcqQ",
    "sqQaqQaqQcqQ",
    "qQaqrQqcqsQq",
    "rqQcqQaqQQ",
]


@given(script_i=st.integers(min_value=0, max_value=len(_SCRIPTS) - 1),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=6, deadline=None)
def test_cache_never_stale_under_updates(tmp_path_factory, script_i, seed):
    """Interleaved add/remove/compact/snapshot-restore/query sequences:
    every result the scheduler serves (cached or dispatched) is bit-equal
    to a fresh fixed-schedule search at the collection's *current*
    version — version invalidation can never serve yesterday's index."""
    rng = np.random.default_rng(seed)
    kd, kb = jax.random.split(jax.random.key(7))
    pts = np.asarray(make_clustered(kd, 160, 8, n_clusters=4, spread=0.05))
    pts, _, _ = normalize_scale(pts, pts[:1])
    pts = np.asarray(pts, np.float32)
    base, pool = pts[:120], pts[120:]
    params = DBLSHParams.derive(
        n=120, d=8, c=1.5, w0=3.6, t=8, k=5, block_size=16
    )
    col = Collection.create(
        "prop", kb, base, params=params, policy=CompactionPolicy(auto=False)
    )
    svc = StoreService(
        batch_shapes=(4,), max_wait_ms=1e9, default_k=5, r0=0.5, steps=4,
        inflight_depth=2, cache_size=256,
    )
    svc.attach(col)

    def check_batch(Q):
        reqs = [svc.submit("prop", q) for q in Q]
        svc.flush()
        got_d, got_i = _results(reqs)
        want_d, want_i = search_batch_fixed(
            col.index, jnp.asarray(Q), k=5, r0=0.5, steps=4
        )
        np.testing.assert_array_equal(got_i, np.asarray(want_i))
        np.testing.assert_array_equal(got_d, np.asarray(want_d))
        return reqs

    last_Q = pts[rng.integers(0, len(pts), 4)]
    added = 0
    for op in _SCRIPTS[script_i]:
        if op == "q":
            last_Q = pts[rng.integers(0, len(pts), 4)]
            check_batch(last_Q)
        elif op == "Q":
            reqs = check_batch(last_Q)  # repeat: exercises the hit path
            assert all(r.done for r in reqs)
        elif op == "a" and added + 16 <= len(pool):
            col.add(pool[added:added + 16])
            added += 16
        elif op == "r":
            live = col.live_count()
            ids = rng.integers(0, col.n, min(16, max(1, live // 4)))
            col.remove(np.unique(ids))
        elif op == "c":
            col.compact()
        elif op == "s":
            d = tmp_path_factory.mktemp("prop_ckpt")
            step = col.snapshot(str(d))
            restored = Collection.restore(str(d), step)
            assert restored.version > col.version  # fresh, never aliased
            col = restored
            svc.collections["prop"] = col
    # the cache did real work across the script
    assert svc.cache.hits > 0


def test_restored_collection_does_not_alias_cache(setup, tmp_path):
    """Divergent histories from one snapshot must not share cache entries:
    a restored collection under the same name in a service whose cache
    holds entries for the live collection recomputes rather than hits."""
    data, queries, kb = setup
    col = Collection.create(
        "alias", kb, data[:200], c=1.5, w0=3.6, t=8, k=5,
        policy=CompactionPolicy(auto=False),
    )
    cache = QueryResultCache(128)
    svc = StoreService(
        batch_shapes=(4,), max_wait_ms=1e9, default_k=5, r0=0.5, steps=4,
        cache=cache,
    )
    svc.attach(col)
    step = col.snapshot(str(tmp_path))
    Q = queries[:4]
    _ = [svc.submit("alias", q) for q in Q]
    svc.flush()
    hits0 = cache.hits
    # diverge the live collection, then restore the snapshot over it
    col.add(data[200:216])
    restored = Collection.restore(str(tmp_path), step)
    svc.collections["alias"] = restored
    reqs = [svc.submit("alias", q) for q in Q]
    svc.flush()
    assert cache.hits == hits0  # no hit against either old version
    want_d, want_i = search_batch_fixed(
        restored.index, jnp.asarray(Q), k=5, r0=0.5, steps=4
    )
    got_d, got_i = _results(reqs)
    np.testing.assert_array_equal(got_i, np.asarray(want_i))
    np.testing.assert_array_equal(got_d, np.asarray(want_d))


# ---------------------------------------------------------------------------
# Recall regression band
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "c,t,floor",
    [
        # floors pinned ~0.04 under the seeded measurement (0.841 / 0.973)
        (1.5, 32, 0.80),  # paper-ish approximation ratio, tighter windows
        (2.0, 16, 0.90),  # coarser c with w0=3.6: wide windows, high recall
    ],
)
def test_recall_band_through_scheduler(setup, c, t, floor):
    """Seeded (c, t, k) configs: recall@10 vs brute force through the
    overlapped scheduler stays above a pinned floor — scheduler changes
    cannot silently trade accuracy for throughput."""
    data, queries, _ = setup
    k = 10
    colr = Collection.create(
        f"rec{c}{t}", jax.random.key(42), data, c=c, w0=3.6, t=t, k=k
    )
    svc = _service(colr, depth=2, cache_size=64)
    dists, ids, _ = svc.serve(colr.name, queries, k=k)
    _, gt_i = brute_force(jnp.asarray(data), jnp.asarray(queries), k=k)
    gt_i = np.asarray(gt_i)
    recall = np.mean(
        [len(set(a.tolist()) & set(b.tolist())) / k for a, b in zip(ids, gt_i)]
    )
    assert recall >= floor, (c, t, recall)


# ---------------------------------------------------------------------------
# Fake-clock units: quotas, WRR, timeout, deterministic stats
# ---------------------------------------------------------------------------


def test_token_bucket_refill(col):
    clock = FakeClock()
    svc = _service(col, clock=clock)
    q = np.zeros(16, np.float32)
    svc.set_quota("t1", rate=1.0, burst=2)
    svc.submit("sched", q, tenant="t1")
    svc.submit("sched", q, tenant="t1")
    with pytest.raises(QuotaExceeded):
        svc.submit("sched", q, tenant="t1")  # bucket empty
    clock.advance(0.4)
    with pytest.raises(QuotaExceeded):
        svc.submit("sched", q, tenant="t1")  # only 0.4 tokens back
    clock.advance(0.6)
    svc.submit("sched", q, tenant="t1")  # refilled to exactly 1
    clock.advance(10.0)
    svc.submit("sched", q, tenant="t1")
    svc.submit("sched", q, tenant="t1")
    with pytest.raises(QuotaExceeded):
        svc.submit("sched", q, tenant="t1")  # burst caps the refill at 2
    ts = svc.tenant_stats("t1")
    assert ts["submitted"] == 5 and ts["rejected"] == 3
    svc.flush()
    assert svc.tenant_stats("t1")["served"] == 5


def test_weighted_round_robin_drain(col):
    """A hot tenant cannot take the whole batch: draining interleaves
    tenants by quota weight."""
    clock = FakeClock()
    svc = _service(col, clock=clock, batch_shapes=(8,))
    svc.set_quota("heavy", weight=3)
    svc.set_quota("light", weight=1)
    q = np.zeros(16, np.float32)
    for _ in range(12):
        svc.submit("sched", q, tenant="heavy")
    for _ in range(4):
        svc.submit("sched", q, tenant="light")
    drained = svc._drain_wrr("sched", 8)
    tenants = [r.tenant for r in drained]
    # 3:1 interleave, light is never starved out of the batch
    assert tenants.count("heavy") == 6 and tenants.count("light") == 2
    # second batch keeps alternating shares
    drained2 = svc._drain_wrr("sched", 8)
    assert [r.tenant for r in drained2].count("light") == 2
    svc.flush()


def test_timeout_and_latency_stats_deterministic(col):
    """Injected clock makes the latency percentiles and QPS exact."""
    clock = FakeClock(start=100.0)
    svc = _service(col, clock=clock, max_wait_ms=50.0, batch_shapes=(4,))
    reqs = []
    for _ in range(4):
        reqs.append(svc.submit("sched", np.zeros(16, np.float32)))
        clock.advance(0.010)
    # queue full at 4 -> drains on the next step regardless of timeout
    svc.step()
    svc.flush()
    # submit times were 100.000..100.030, completion at 100.040
    lat = sorted(r.latency_ms for r in reqs)
    np.testing.assert_allclose(lat, [10.0, 20.0, 30.0, 40.0], rtol=1e-9)
    stats = svc.stats("sched")
    want = np.percentile([40.0, 30.0, 20.0, 10.0], [50, 99])
    np.testing.assert_allclose(
        [stats["latency_ms_p50"], stats["latency_ms_p99"]], want, rtol=1e-9
    )
    # QPS span: first submit (100.000) -> completion (100.040)
    np.testing.assert_allclose(stats["qps"], 4 / 0.040, rtol=1e-9)


def test_query_counter_counts_real_rows(setup):
    """The padded dispatch counts only real rows on the collection and the
    counter can never underflow — the old path subtracted the padding
    after the fact and went negative when a collection detached
    mid-flight."""
    data, _, kb = setup
    colq = Collection.create("rows", kb, data[:200], c=1.5, w0=3.6, t=8, k=5)
    svc = StoreService(
        batch_shapes=(8,), max_wait_ms=0.0, default_k=5, r0=0.5, steps=4,
        inflight_depth=2, cache_size=0,
    )
    svc.attach(colq)
    for q in data[:3]:
        svc.submit("rows", q)
    svc.step(force=True)  # issues 3 real rows padded to 8 and completes
    assert colq.stats.queries == 3  # not 8, never negative
    # detaching with work in flight is refused instead of corrupting stats
    svc.submit("rows", data[4])
    svc.step()  # issue without completing (depth 2 ring holds it)
    if svc.in_flight():
        with pytest.raises(RuntimeError):
            svc.drop_collection("rows")
    svc.flush()
    assert colq.stats.queries == 4
    svc.drop_collection("rows")


def test_datastore_search_uses_cache(setup):
    """kNN-LM Datastore: repeated hidden-state queries hit the shared
    cache; a collection mutation invalidates by version."""
    from repro.serve.retrieval import Datastore

    data, queries, kb = setup
    colk = Collection.create(
        "knn", kb, data[:200], c=1.5, w0=3.6, t=8, k=5,
        payload=np.arange(200), policy=CompactionPolicy(auto=False),
    )
    cache = QueryResultCache(64)
    ds = Datastore(colk, temperature=10.0, lam=0.25, k=5, cache=cache)
    Q = queries[:4]
    d0, i0 = ds.search(Q, r0=0.5, steps=4)
    assert cache.misses > 0 and cache.hits == 0
    d1, i1 = ds.search(Q, r0=0.5, steps=4)  # all rows hit
    assert cache.hits == 4
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i0))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d0))
    colk.add(data[200:208], payload=np.arange(200, 208))
    d2, i2 = ds.search(Q, r0=0.5, steps=4)  # version bumped -> recompute
    assert cache.hits == 4
    want_d, want_i = colk.search(Q, k=5, r0=0.5, steps=4)
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(want_i))

    # the cache is shareable with a StoreService: a service hit on a
    # datastore-published entry must carry the payload and real stats
    svc = StoreService(
        batch_shapes=(4,), max_wait_ms=1e9, default_k=5, r0=0.5, steps=4,
        cache=cache,
    )
    svc.attach(colk)
    reqs = [svc.submit("knn", q) for q in Q]
    svc.flush()
    assert all(r.cached for r in reqs)
    np.testing.assert_array_equal(_results(reqs)[1], np.asarray(i2))
    for r in reqs:
        assert r.payload is not None and r.payload.shape == (5,)
        np.testing.assert_array_equal(
            r.payload, np.asarray(colk.get_payload(r.ids[None]))[0]
        )


def test_cache_isolated_from_ticket_mutation(setup):
    """Callers own their tickets: mutating a returned result in place must
    not corrupt the cached row (entries are copied on put and on hit)."""
    data, queries, kb = setup
    colm = Collection.create("mut", kb, data[:200], c=1.5, w0=3.6, t=8, k=5)
    svc = StoreService(
        batch_shapes=(1,), max_wait_ms=1e9, default_k=5, r0=0.5, steps=4,
        cache_size=64,
    )
    svc.attach(colm)
    r0_ = svc.submit("mut", queries[0])
    svc.flush()
    want_d, want_i = r0_.dists.copy(), r0_.ids.copy()
    # miss-path tickets view jax outputs, which numpy exposes read-only —
    # a client scribble cannot even start there
    with pytest.raises(ValueError):
        r0_.dists[:] = -1.0
    r1 = svc.submit("mut", queries[0])
    svc.flush()
    assert r1.cached
    np.testing.assert_array_equal(r1.dists, want_d)
    np.testing.assert_array_equal(r1.ids, want_i)
    r1.dists[:] = -2.0  # hit-path tickets are writable copies: scribble
    r1.ids[:] = 7
    r2 = svc.submit("mut", queries[0])
    svc.flush()
    assert r2.cached
    np.testing.assert_array_equal(r2.dists, want_d)
    np.testing.assert_array_equal(r2.ids, want_i)


def test_versionless_collection_is_never_cached(setup):
    """An attached object without a ``version`` attribute has no
    invalidation signal, so the service must bypass the cache for it
    rather than serve version-frozen results forever."""
    data, queries, kb = setup
    inner = Collection.create("nv", kb, data[:200], c=1.5, w0=3.6, t=8, k=5)

    class VersionlessView:  # v1-era attachable: search + name only
        name = "nv"
        payload = None

        def search(self, *a, **kw):
            return inner.search(*a, **kw)

    svc = StoreService(
        batch_shapes=(1,), max_wait_ms=1e9, default_k=5, r0=0.5, steps=4,
        cache_size=64,
    )
    svc.attach(VersionlessView())
    for _ in range(2):  # identical repeat: would hit if it were cached
        r = svc.submit("nv", queries[0])
        svc.flush()
        assert r.done and not r.cached
    assert svc.cache.hits == 0 and len(svc.cache) == 0


def test_serve_withdraws_queue_on_quota_rejection(col):
    """serve() is all-or-nothing under quota: a mid-matrix rejection
    leaves no orphaned tickets behind in the queue."""
    clock = FakeClock()
    svc = _service(col, clock=clock)
    svc.set_quota("t", rate=1.0, burst=2)
    Q = np.zeros((5, 16), np.float32)
    with pytest.raises(QuotaExceeded):
        svc.serve("sched", Q, tenant="t")
    assert svc.pending() == 0 and svc.in_flight() == 0
    assert svc.tenant_stats("t")["submitted"] == 0
    assert svc.tenant_stats("t")["rejected"] == 1
