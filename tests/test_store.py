"""Vector-store subsystem tests: micro-batching service equivalence,
auto-compaction policy, payload alignment, persistence round-trip, and
the sharded router surface."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import brute_force, search_batch_fixed
from repro.data import make_clustered, normalize_scale
from repro.store import (
    Collection,
    CompactionPolicy,
    ShardedCollection,
    StoreService,
    open_collection,
)


@pytest.fixture(scope="module")
def setup():
    kd, kb = jax.random.split(jax.random.key(17))
    allpts = make_clustered(kd, 1232, 16, n_clusters=10, spread=0.02)
    data, queries = allpts[:1200], allpts[1200:]
    data, queries, _ = normalize_scale(data, queries)
    return np.asarray(data), np.asarray(queries), kb


def _recall(ids, gt_i, k):
    return np.mean(
        [len(set(a.tolist()) & set(b.tolist())) / k
         for a, b in zip(np.asarray(ids), np.asarray(gt_i))]
    )


# ---------------------------------------------------------------------------
# StoreService: micro-batching equivalence (acceptance criterion)
# ---------------------------------------------------------------------------


def test_service_stream_matches_direct_batch(setup):
    """A mixed stream of single queries through the admission queue must
    return results identical to one direct search_batch_fixed call —
    padding to fixed batch shapes introduces no drift."""
    data, queries, kb = setup
    k = 10
    col = Collection.create("s", kb, data, c=1.5, w0=3.6, t=32, k=k)
    svc = StoreService(batch_shapes=(1, 4, 16), default_k=k, r0=0.5, steps=8)
    svc.attach(col)

    # mixed stream: irregular arrival chunks -> batches of size 3, 7, 1,
    # 16, 5 (each padded to the smallest fitting shape)
    reqs = []
    cuts = [3, 10, 11, 27, 32]
    start = 0
    for cut in cuts:
        for q in queries[start:cut]:
            reqs.append(svc.submit("s", q))
        svc.step(force=True)
        start = cut
    assert svc.pending() == 0
    assert all(r.done for r in reqs)

    d_direct, i_direct = search_batch_fixed(
        col.index, jnp.asarray(queries), k=k, r0=0.5, steps=8
    )
    np.testing.assert_array_equal(
        np.stack([r.ids for r in reqs]), np.asarray(i_direct)
    )
    np.testing.assert_array_equal(
        np.stack([r.dists for r in reqs]), np.asarray(d_direct)
    )

    stats = svc.stats("s")
    assert stats["queries"] == queries.shape[0]
    assert stats["batches"] == len(cuts)
    assert 0 < stats["mean_radius_steps"] <= 8
    assert stats["mean_candidates"] > 0
    assert 0 < stats["padding_efficiency"] <= 1.0


def test_service_per_request_k_sliced(setup):
    """Requests with k below the service default get a sliced prefix of
    the service-k result (no recompilation per k)."""
    data, queries, kb = setup
    col = Collection.create("s2", kb, data, c=1.5, w0=3.6, t=32, k=10)
    svc = StoreService(batch_shapes=(4,), default_k=10, r0=0.5, steps=8)
    svc.attach(col)
    r_small = svc.submit("s2", queries[0], k=3)
    r_full = svc.submit("s2", queries[0], k=10)
    svc.flush()
    assert r_small.ids.shape == (3,)
    np.testing.assert_array_equal(r_small.ids, r_full.ids[:3])
    with pytest.raises(ValueError):
        svc.submit("s2", queries[0], k=11)


# ---------------------------------------------------------------------------
# Auto-compaction policy (acceptance criterion)
# ---------------------------------------------------------------------------


def test_auto_compaction_restores_recall(setup):
    """A stream of small adds growing the collection past 2x the built n
    must trigger compact, and recall@10 vs brute force on the grown
    dataset must be >= the never-compacted recall."""
    data, queries, kb = setup
    base, extra = data[:500], data[500:1200]
    k = 10

    def make(auto):
        return Collection.create(
            "g", jax.random.key(17), base, c=1.5, w0=3.6, t=32, k=k,
            policy=CompactionPolicy(growth_ratio=2.0, auto=auto),
        )

    frozen, managed = make(False), make(True)
    for j in range(0, 700, 35):  # 20 small appends -> sparse padded blocks
        frozen.add(extra[j:j + 35])
        managed.add(extra[j:j + 35])

    assert frozen.stats.compactions == 0
    assert managed.stats.compactions >= 1
    assert managed.n == frozen.n == 1200
    assert managed.built_n >= 1000  # policy fired at the 2x threshold
    # the rebuild re-derives K for the grown n (K ~ log n)
    assert managed.index.params.K >= frozen.index.params.K
    # and packs away the per-add padding waste
    assert managed.index.nb < frozen.index.nb

    _, gt_i = brute_force(jnp.asarray(data), jnp.asarray(queries), k=k)
    _, ids_pre = frozen.search(queries, k=k, r0=0.5, steps=8)
    _, ids_post = managed.search(queries, k=k, r0=0.5, steps=8)
    rec_pre, rec_post = _recall(ids_pre, gt_i, k), _recall(ids_post, gt_i, k)
    assert rec_post >= rec_pre, (rec_pre, rec_post)
    assert rec_post > 0.85, rec_post


def test_hollowness_triggers_compaction(setup):
    """Deleting past min_live_ratio triggers a rebuild that reclaims
    tombstoned slots and remaps payload ids."""
    data, _, kb = setup
    col = Collection.create(
        "h", kb, data[:600], c=1.5, w0=3.6, t=32, k=10,
        payload=np.arange(600),
        policy=CompactionPolicy(min_live_ratio=0.5),
    )
    col.remove(np.arange(0, 301))  # live 299/600 < 0.5
    assert col.stats.compactions == 1
    assert col.n == 299
    assert col.live_count() == 299
    # payload rows followed the compaction id map
    np.testing.assert_array_equal(np.asarray(col.payload), np.arange(301, 600))


def test_payload_alignment_through_updates(setup):
    """add -> remove -> compact keeps payload aligned: querying exactly on
    a surviving point returns its original payload tag."""
    data, _, kb = setup
    base, extra = data[:500], data[500:600]
    col = Collection.create(
        "p", kb, base, c=1.5, w0=3.6, t=32, k=10,
        payload=np.arange(500), policy=CompactionPolicy(auto=False),
    )
    new_ids = col.add(extra, payload=np.arange(500, 600))
    np.testing.assert_array_equal(new_ids, np.arange(500, 600))
    col.remove(np.arange(0, 50))
    col.compact()
    assert col.stats.compactions == 1 and col.n == 550

    probe_tag = 570  # an inserted, surviving point
    d, ids = col.search(data[probe_tag:probe_tag + 1], k=1, r0=0.25, steps=8)
    assert float(d[0, 0]) < 1e-3
    tag = int(np.asarray(col.get_payload(ids))[0, 0])
    assert tag == probe_tag


# ---------------------------------------------------------------------------
# Persistence: snapshot / restore round-trip (acceptance criterion)
# ---------------------------------------------------------------------------


def test_snapshot_restore_identical_results(setup, tmp_path):
    """save -> restore -> bit-identical search results, with payload,
    policy, counters, and the compaction PRNG key preserved."""
    data, queries, kb = setup
    col = Collection.create(
        "ck", kb, data, c=1.5, w0=3.6, t=32, k=10, payload=np.arange(1200),
        policy=CompactionPolicy(growth_ratio=3.0),
    )
    d0, i0 = col.search(queries, k=10, r0=0.5, steps=8)
    step = col.snapshot(str(tmp_path))

    col2 = Collection.restore(str(tmp_path), step)
    assert col2.name == "ck"
    assert col2.index.params == col.index.params
    assert col2.policy == col.policy
    assert col2.built_n == col.built_n
    d1, i1 = col2.search(queries, k=10, r0=0.5, steps=8)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i0))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d0))
    np.testing.assert_array_equal(np.asarray(col2.payload), np.asarray(col.payload))

    # restored collections keep evolving: the preserved key makes the next
    # compaction deterministic across the save/restore boundary
    col.remove(np.arange(100))
    col2.remove(np.arange(100))
    col.compact()
    col2.compact()
    d2a, i2a = col.search(queries, k=10, r0=0.5, steps=8)
    d2b, i2b = col2.search(queries, k=10, r0=0.5, steps=8)
    np.testing.assert_array_equal(np.asarray(i2a), np.asarray(i2b))


def test_snapshot_restore_after_updates(setup, tmp_path):
    """The round-trip also holds for a mutated (inserted + tombstoned)
    index — the exact dynamic state is what persists."""
    data, queries, kb = setup
    col = Collection.create(
        "ck2", kb, data[:800], c=1.5, w0=3.6, t=32, k=10,
        policy=CompactionPolicy(auto=False),
    )
    col.add(data[800:1000])
    col.remove(np.arange(40, 80))
    d0, i0 = col.search(queries, k=10, r0=0.5, steps=8)
    col.snapshot(str(tmp_path))
    col2 = Collection.restore(str(tmp_path))
    assert col2.live_count() == col.live_count() == 960
    d1, i1 = col2.search(queries, k=10, r0=0.5, steps=8)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i0))


# ---------------------------------------------------------------------------
# Router: sharded surface + placement decision
# ---------------------------------------------------------------------------


def test_sharded_collection_matches_local(setup):
    """On a 1-shard mesh the ShardedCollection must agree exactly with a
    local index built from the same key (the merge is an identity)."""
    from repro.core import DBLSHParams, build

    data, queries, kb = setup
    mesh = jax.make_mesh((1,), ("data",))
    params = DBLSHParams.derive(n=1200, d=16, c=1.5, w0=3.6, t=32, k=10)
    sc = ShardedCollection.create(
        "sh", kb, data, mesh, params=params, payload=np.arange(1200)
    )
    assert sc.n == 1200
    # exact mode pins tight numeric parity (the norm-form dot reduction
    # is re-associated per compiled program — DESIGN.md §7); the default
    # norm path pins id parity below through the service round trip.
    d_s, i_s = sc.search(queries, k=10, r0=0.5, steps=8, exact=True)

    local = build(kb, jnp.asarray(data), params)
    d_l, i_l = search_batch_fixed(
        local, jnp.asarray(queries), k=10, r0=0.5, steps=8, exact=True
    )
    np.testing.assert_array_equal(np.asarray(i_s), np.asarray(i_l))
    np.testing.assert_allclose(np.asarray(d_s), np.asarray(d_l), rtol=1e-6)
    # norm-form ids still agree with the local norm-form search
    _, i_sn = sc.search(queries, k=10, r0=0.5, steps=8)
    _, i_ln = search_batch_fixed(local, jnp.asarray(queries), k=10, r0=0.5, steps=8)
    np.testing.assert_array_equal(np.asarray(i_sn), np.asarray(i_ln))

    # the service serves a sharded collection through the same queue
    svc = StoreService(batch_shapes=(8,), default_k=10, r0=0.5, steps=8)
    svc.attach(sc)
    dd, ii, reqs = svc.serve("sh", queries[:8], k=10)
    np.testing.assert_array_equal(ii, np.asarray(i_ln[:8]))
    assert reqs[0].payload is not None


def test_open_collection_routing(setup):
    data, _, kb = setup
    mesh = jax.make_mesh((1,), ("data",))
    col = open_collection("a", kb, data, mesh=None, c=1.5, w0=3.6, t=32, k=10)
    assert isinstance(col, Collection)
    # a 1-device mesh can never fan out
    col2 = open_collection(
        "b", kb, data, mesh=mesh, max_points_per_shard=100,
        c=1.5, w0=3.6, t=32, k=10,
    )
    assert isinstance(col2, Collection)


# ---------------------------------------------------------------------------
# Per-collection engine defaults + per-shard probe stats (ROADMAP items)
# ---------------------------------------------------------------------------


def test_collection_engine_default_resolution(setup, tmp_path):
    """Engine resolves request-override > collection default > service
    default; the default survives snapshot/restore; bad names reject."""
    data, queries, kb = setup
    col = Collection.create(
        "eng", kb, data, c=1.5, w0=3.6, t=32, k=10, engine="inline",
        inline_vectors=True,
    )
    assert col.default_engine == "inline"
    svc = StoreService(batch_shapes=(4,), default_k=10, r0=0.5, steps=8,
                       engine="jnp", interpret=True)
    svc.attach(col)

    # no override -> the collection's default engine
    r1 = svc.submit("eng", queries[0])
    assert r1.engine == "inline"
    # explicit override wins
    r2 = svc.submit("eng", queries[1], engine="jnp")
    assert r2.engine == "jnp"
    svc.flush()
    assert r1.done and r2.done

    # a collection without a default falls back to the service engine
    col2 = Collection.create("plain", kb, data, c=1.5, w0=3.6, t=32, k=10)
    assert col2.default_engine is None
    svc.attach(col2)
    assert svc.submit("plain", queries[2]).engine == "jnp"
    svc.flush()

    # mixed engines in one drained batch split into per-engine dispatches
    # but still serve every ticket
    reqs = [svc.submit("eng", q) for q in queries[3:5]]
    reqs.append(svc.submit("eng", queries[5], engine="jnp"))
    svc.flush()
    assert all(r.done for r in reqs)

    # validation reuses the core engine-name check
    with pytest.raises(ValueError):
        Collection.create("bad", kb, data, c=1.5, w0=3.6, t=32, k=10,
                          engine="vulkan")
    with pytest.raises(ValueError):
        svc.submit("eng", queries[0], engine="vulkan")
    # an inline default needs the inline layout — fail at create, not at
    # the first jitted dispatch
    with pytest.raises(ValueError):
        Collection.create("bad2", kb, data, c=1.5, w0=3.6, t=32, k=10,
                          engine="inline")

    # the default persists through snapshot/restore
    step = col.snapshot(str(tmp_path / "eng"))
    col3 = Collection.restore(str(tmp_path / "eng"), step)
    assert col3.default_engine == "inline"


def test_engine_default_results_match_explicit(setup):
    """A collection-default engine must produce the same results as the
    same engine passed explicitly (resolution changes routing only)."""
    data, queries, kb = setup
    col = Collection.create(
        "engeq", kb, data, c=1.5, w0=3.6, t=32, k=10, engine="kernel",
        inline_vectors=True,
    )
    d_def, i_def = col.search(queries[:4], k=10, r0=0.5, steps=8,
                              interpret=True)
    d_exp, i_exp = col.search(queries[:4], k=10, r0=0.5, steps=8,
                              engine="kernel", interpret=True)
    np.testing.assert_array_equal(np.asarray(d_def), np.asarray(d_exp))
    np.testing.assert_array_equal(np.asarray(i_def), np.asarray(i_exp))


def test_sharded_probe_stats_surface(setup):
    """Per-shard probe stats flow through the collective merge into
    svc.stats() instead of being dropped at the boundary: on a 1-shard
    mesh the aggregates equal the local collection's own stats."""
    data, queries, kb = setup
    mesh = jax.make_mesh((1,), ("data",))
    from repro.core import DBLSHParams, build

    params = DBLSHParams.derive(n=1200, d=16, c=1.5, w0=3.6, t=32, k=10)
    sc = ShardedCollection.create("shstats", kb, data, mesh, params=params)
    d_s, i_s, st = sc.search(queries[:8], k=10, r0=0.5, steps=8,
                             with_stats=True)
    local = build(kb, jnp.asarray(data), params)
    *_, st_l = search_batch_fixed(local, jnp.asarray(queries[:8]), k=10,
                                  r0=0.5, steps=8, with_stats=True)
    np.testing.assert_array_equal(
        np.asarray(st["candidates"]), np.asarray(st_l["candidates"])
    )
    np.testing.assert_array_equal(
        np.asarray(st["radius_steps"]), np.asarray(st_l["radius_steps"])
    )

    # ...and the service-level snapshot reports them
    svc = StoreService(batch_shapes=(8,), default_k=10, r0=0.5, steps=8)
    svc.attach(sc)
    svc.serve("shstats", queries[:8], k=10)
    snap = svc.stats("shstats")
    assert snap["mean_candidates"] > 0
    assert 1 <= snap["mean_radius_steps"] <= 8

    # the sharded path ignores engine selection, so resolution pins its
    # fixed engine: overrides share one cache key and honest tickets
    r1 = svc.submit("shstats", queries[0], engine="kernel")
    svc.flush()
    assert r1.engine == "jnp"
    r2 = svc.submit("shstats", queries[0], engine="inline")
    svc.flush()
    assert r2.cached


# ---------------------------------------------------------------------------
# Quantized distance path through the collection lifecycle
# ---------------------------------------------------------------------------


def test_quant_collection_lifecycle(setup, tmp_path):
    """A quant_dtype collection keeps its quantized blocks consistent
    through search / add / remove / compact / snapshot / restore.

    The quantized blocks are *derived* state: snapshots persist only the
    fp32 truth and restore re-quantizes, so the roundtrip must be
    bit-identical (quantization is deterministic)."""
    data, queries, kb = setup
    k = 10
    col = Collection.create("q8", kb, data, c=1.5, w0=3.6, t=32, k=k,
                            quant_dtype="int8")
    d_fp, i_fp = col.search(queries, k=k, r0=0.5, steps=8)
    d_q, i_q = col.search(queries, k=k, r0=0.5, steps=8, dtype="int8")
    # documented band: the shortlist+re-rank loses a neighbor only when
    # it falls off its bin's 4k shortlist — recall within 0.005 of fp32
    assert _recall(i_q, i_fp, k) >= 0.995

    with pytest.raises(ValueError, match="quant_dtype"):
        col.search(queries, k=k, dtype="bf16")

    # mutations keep the quantized blocks slot-aligned
    rng = np.random.default_rng(3)
    new = rng.normal(size=(48, data.shape[1])).astype(np.float32) * 0.1
    ids = col.add(new)
    col.remove(np.asarray(ids)[:8])
    assert col.index.qvec_blocks.shape == col.index.vec_blocks.shape \
        if col.index.params.inline_vectors else True
    assert col.index.qvec_blocks.shape[:2] == col.index.ids_blocks.shape[:2]
    d_q2, i_q2 = col.search(queries, k=k, r0=0.5, steps=8, dtype="int8")
    d_f2, i_f2 = col.search(queries, k=k, r0=0.5, steps=8)
    assert _recall(i_q2, i_f2, k) >= 0.99

    # compaction rebuilds with the same quant_dtype
    col.compact()
    assert col.index.params.quant_dtype == "int8"
    assert col.index.qvec_blocks.shape[:2] == col.index.ids_blocks.shape[:2]

    # snapshot -> restore: re-quantization is bit-identical
    col.snapshot(str(tmp_path / "q8"))
    col2 = Collection.restore(str(tmp_path / "q8"))
    np.testing.assert_array_equal(
        np.asarray(col2.index.qvec_blocks), np.asarray(col.index.qvec_blocks)
    )
    np.testing.assert_array_equal(
        np.asarray(col2.index.qvec_scale), np.asarray(col.index.qvec_scale)
    )
    d_q3, i_q3 = col.search(queries, k=k, r0=0.5, steps=8, dtype="int8")
    d_q4, i_q4 = col2.search(queries, k=k, r0=0.5, steps=8, dtype="int8")
    np.testing.assert_array_equal(np.asarray(i_q3), np.asarray(i_q4))
    np.testing.assert_array_equal(np.asarray(d_q3), np.asarray(d_q4))


def test_quant_sharded_roundtrip(setup, tmp_path):
    """Sharded quant collections: per-shard shortlist + re-rank, and the
    bit-identical restore path rebuilds per-shard quantized blocks (ids
    are shard-local — a global re-quantize would read the wrong rows)."""
    from jax.sharding import Mesh
    data, queries, kb = setup
    ndev = len(jax.devices())
    mesh = Mesh(np.array(jax.devices()).reshape(ndev), ("data",))
    k = 10
    sc = ShardedCollection.create("q8s", kb, data, mesh, c=1.5, w0=3.6,
                                  t=32, k=k, quant_dtype="int8")
    d_fp, i_fp = sc.search(queries, k=k, r0=0.5, steps=8)
    d_q, i_q = sc.search(queries, k=k, r0=0.5, steps=8, dtype="int8")
    assert _recall(i_q, i_fp, k) >= 0.99

    sc.snapshot(str(tmp_path / "q8s"))
    sc2 = ShardedCollection.restore(str(tmp_path / "q8s"), mesh=mesh)
    np.testing.assert_array_equal(
        np.asarray(sc2.sharded.index.qvec_blocks),
        np.asarray(sc.sharded.index.qvec_blocks),
    )
    d_q2, i_q2 = sc2.search(queries, k=k, r0=0.5, steps=8, dtype="int8")
    np.testing.assert_array_equal(np.asarray(i_q), np.asarray(i_q2))

    # migration (rebalancing-rebuild) restore keeps the quant path alive
    sc3 = ShardedCollection.restore(str(tmp_path / "q8s"), mesh=mesh,
                                    migrate=True)
    assert sc3.sharded.index.params.quant_dtype == "int8"
    d3f, i3f = sc3.search(queries, k=k, r0=0.5, steps=8)
    d3q, i3q = sc3.search(queries, k=k, r0=0.5, steps=8, dtype="int8")
    assert _recall(i3q, i3f, k) >= 0.99
