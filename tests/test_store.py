"""Vector-store subsystem tests: micro-batching service equivalence,
auto-compaction policy, payload alignment, persistence round-trip, and
the sharded router surface."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import brute_force, search_batch_fixed
from repro.data import make_clustered, normalize_scale
from repro.store import (
    Collection,
    CompactionPolicy,
    ShardedCollection,
    StoreService,
    open_collection,
)


@pytest.fixture(scope="module")
def setup():
    kd, kb = jax.random.split(jax.random.key(17))
    allpts = make_clustered(kd, 1232, 16, n_clusters=10, spread=0.02)
    data, queries = allpts[:1200], allpts[1200:]
    data, queries, _ = normalize_scale(data, queries)
    return np.asarray(data), np.asarray(queries), kb


def _recall(ids, gt_i, k):
    return np.mean(
        [len(set(a.tolist()) & set(b.tolist())) / k
         for a, b in zip(np.asarray(ids), np.asarray(gt_i))]
    )


# ---------------------------------------------------------------------------
# StoreService: micro-batching equivalence (acceptance criterion)
# ---------------------------------------------------------------------------


def test_service_stream_matches_direct_batch(setup):
    """A mixed stream of single queries through the admission queue must
    return results identical to one direct search_batch_fixed call —
    padding to fixed batch shapes introduces no drift."""
    data, queries, kb = setup
    k = 10
    col = Collection.create("s", kb, data, c=1.5, w0=3.6, t=32, k=k)
    svc = StoreService(batch_shapes=(1, 4, 16), default_k=k, r0=0.5, steps=8)
    svc.attach(col)

    # mixed stream: irregular arrival chunks -> batches of size 3, 7, 1,
    # 16, 5 (each padded to the smallest fitting shape)
    reqs = []
    cuts = [3, 10, 11, 27, 32]
    start = 0
    for cut in cuts:
        for q in queries[start:cut]:
            reqs.append(svc.submit("s", q))
        svc.step(force=True)
        start = cut
    assert svc.pending() == 0
    assert all(r.done for r in reqs)

    d_direct, i_direct = search_batch_fixed(
        col.index, jnp.asarray(queries), k=k, r0=0.5, steps=8
    )
    np.testing.assert_array_equal(
        np.stack([r.ids for r in reqs]), np.asarray(i_direct)
    )
    np.testing.assert_array_equal(
        np.stack([r.dists for r in reqs]), np.asarray(d_direct)
    )

    stats = svc.stats("s")
    assert stats["queries"] == queries.shape[0]
    assert stats["batches"] == len(cuts)
    assert 0 < stats["mean_radius_steps"] <= 8
    assert stats["mean_candidates"] > 0
    assert 0 < stats["padding_efficiency"] <= 1.0


def test_service_per_request_k_sliced(setup):
    """Requests with k below the service default get a sliced prefix of
    the service-k result (no recompilation per k)."""
    data, queries, kb = setup
    col = Collection.create("s2", kb, data, c=1.5, w0=3.6, t=32, k=10)
    svc = StoreService(batch_shapes=(4,), default_k=10, r0=0.5, steps=8)
    svc.attach(col)
    r_small = svc.submit("s2", queries[0], k=3)
    r_full = svc.submit("s2", queries[0], k=10)
    svc.flush()
    assert r_small.ids.shape == (3,)
    np.testing.assert_array_equal(r_small.ids, r_full.ids[:3])
    with pytest.raises(ValueError):
        svc.submit("s2", queries[0], k=11)


# ---------------------------------------------------------------------------
# Auto-compaction policy (acceptance criterion)
# ---------------------------------------------------------------------------


def test_auto_compaction_restores_recall(setup):
    """A stream of small adds growing the collection past 2x the built n
    must trigger compact, and recall@10 vs brute force on the grown
    dataset must be >= the never-compacted recall."""
    data, queries, kb = setup
    base, extra = data[:500], data[500:1200]
    k = 10

    def make(auto):
        return Collection.create(
            "g", jax.random.key(17), base, c=1.5, w0=3.6, t=32, k=k,
            policy=CompactionPolicy(growth_ratio=2.0, auto=auto),
        )

    frozen, managed = make(False), make(True)
    for j in range(0, 700, 35):  # 20 small appends -> sparse padded blocks
        frozen.add(extra[j:j + 35])
        managed.add(extra[j:j + 35])

    assert frozen.stats.compactions == 0
    assert managed.stats.compactions >= 1
    assert managed.n == frozen.n == 1200
    assert managed.built_n >= 1000  # policy fired at the 2x threshold
    # the rebuild re-derives K for the grown n (K ~ log n)
    assert managed.index.params.K >= frozen.index.params.K
    # and packs away the per-add padding waste
    assert managed.index.nb < frozen.index.nb

    _, gt_i = brute_force(jnp.asarray(data), jnp.asarray(queries), k=k)
    _, ids_pre = frozen.search(queries, k=k, r0=0.5, steps=8)
    _, ids_post = managed.search(queries, k=k, r0=0.5, steps=8)
    rec_pre, rec_post = _recall(ids_pre, gt_i, k), _recall(ids_post, gt_i, k)
    assert rec_post >= rec_pre, (rec_pre, rec_post)
    assert rec_post > 0.85, rec_post


def test_hollowness_triggers_compaction(setup):
    """Deleting past min_live_ratio triggers a rebuild that reclaims
    tombstoned slots and remaps payload ids."""
    data, _, kb = setup
    col = Collection.create(
        "h", kb, data[:600], c=1.5, w0=3.6, t=32, k=10,
        payload=np.arange(600),
        policy=CompactionPolicy(min_live_ratio=0.5),
    )
    col.remove(np.arange(0, 301))  # live 299/600 < 0.5
    assert col.stats.compactions == 1
    assert col.n == 299
    assert col.live_count() == 299
    # payload rows followed the compaction id map
    np.testing.assert_array_equal(np.asarray(col.payload), np.arange(301, 600))


def test_payload_alignment_through_updates(setup):
    """add -> remove -> compact keeps payload aligned: querying exactly on
    a surviving point returns its original payload tag."""
    data, _, kb = setup
    base, extra = data[:500], data[500:600]
    col = Collection.create(
        "p", kb, base, c=1.5, w0=3.6, t=32, k=10,
        payload=np.arange(500), policy=CompactionPolicy(auto=False),
    )
    new_ids = col.add(extra, payload=np.arange(500, 600))
    np.testing.assert_array_equal(new_ids, np.arange(500, 600))
    col.remove(np.arange(0, 50))
    col.compact()
    assert col.stats.compactions == 1 and col.n == 550

    probe_tag = 570  # an inserted, surviving point
    d, ids = col.search(data[probe_tag:probe_tag + 1], k=1, r0=0.25, steps=8)
    assert float(d[0, 0]) < 1e-3
    tag = int(np.asarray(col.get_payload(ids))[0, 0])
    assert tag == probe_tag


# ---------------------------------------------------------------------------
# Persistence: snapshot / restore round-trip (acceptance criterion)
# ---------------------------------------------------------------------------


def test_snapshot_restore_identical_results(setup, tmp_path):
    """save -> restore -> bit-identical search results, with payload,
    policy, counters, and the compaction PRNG key preserved."""
    data, queries, kb = setup
    col = Collection.create(
        "ck", kb, data, c=1.5, w0=3.6, t=32, k=10, payload=np.arange(1200),
        policy=CompactionPolicy(growth_ratio=3.0),
    )
    d0, i0 = col.search(queries, k=10, r0=0.5, steps=8)
    step = col.snapshot(str(tmp_path))

    col2 = Collection.restore(str(tmp_path), step)
    assert col2.name == "ck"
    assert col2.index.params == col.index.params
    assert col2.policy == col.policy
    assert col2.built_n == col.built_n
    d1, i1 = col2.search(queries, k=10, r0=0.5, steps=8)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i0))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d0))
    np.testing.assert_array_equal(np.asarray(col2.payload), np.asarray(col.payload))

    # restored collections keep evolving: the preserved key makes the next
    # compaction deterministic across the save/restore boundary
    col.remove(np.arange(100))
    col2.remove(np.arange(100))
    col.compact()
    col2.compact()
    d2a, i2a = col.search(queries, k=10, r0=0.5, steps=8)
    d2b, i2b = col2.search(queries, k=10, r0=0.5, steps=8)
    np.testing.assert_array_equal(np.asarray(i2a), np.asarray(i2b))


def test_snapshot_restore_after_updates(setup, tmp_path):
    """The round-trip also holds for a mutated (inserted + tombstoned)
    index — the exact dynamic state is what persists."""
    data, queries, kb = setup
    col = Collection.create(
        "ck2", kb, data[:800], c=1.5, w0=3.6, t=32, k=10,
        policy=CompactionPolicy(auto=False),
    )
    col.add(data[800:1000])
    col.remove(np.arange(40, 80))
    d0, i0 = col.search(queries, k=10, r0=0.5, steps=8)
    col.snapshot(str(tmp_path))
    col2 = Collection.restore(str(tmp_path))
    assert col2.live_count() == col.live_count() == 960
    d1, i1 = col2.search(queries, k=10, r0=0.5, steps=8)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i0))


# ---------------------------------------------------------------------------
# Router: sharded surface + placement decision
# ---------------------------------------------------------------------------


def test_sharded_collection_matches_local(setup):
    """On a 1-shard mesh the ShardedCollection must agree exactly with a
    local index built from the same key (the merge is an identity)."""
    from repro.core import DBLSHParams, build

    data, queries, kb = setup
    mesh = jax.make_mesh((1,), ("data",))
    params = DBLSHParams.derive(n=1200, d=16, c=1.5, w0=3.6, t=32, k=10)
    sc = ShardedCollection.create(
        "sh", kb, data, mesh, params=params, payload=np.arange(1200)
    )
    assert sc.n == 1200
    d_s, i_s = sc.search(queries, k=10, r0=0.5, steps=8)

    local = build(kb, jnp.asarray(data), params)
    d_l, i_l = search_batch_fixed(local, jnp.asarray(queries), k=10, r0=0.5, steps=8)
    np.testing.assert_array_equal(np.asarray(i_s), np.asarray(i_l))
    np.testing.assert_allclose(np.asarray(d_s), np.asarray(d_l), rtol=1e-6)

    # the service serves a sharded collection through the same queue
    svc = StoreService(batch_shapes=(8,), default_k=10, r0=0.5, steps=8)
    svc.attach(sc)
    dd, ii, reqs = svc.serve("sh", queries[:8], k=10)
    np.testing.assert_array_equal(ii, np.asarray(i_l[:8]))
    assert reqs[0].payload is not None


def test_open_collection_routing(setup):
    data, _, kb = setup
    mesh = jax.make_mesh((1,), ("data",))
    col = open_collection("a", kb, data, mesh=None, c=1.5, w0=3.6, t=32, k=10)
    assert isinstance(col, Collection)
    # a 1-device mesh can never fan out
    col2 = open_collection(
        "b", kb, data, mesh=mesh, max_points_per_shard=100,
        c=1.5, w0=3.6, t=32, k=10,
    )
    assert isinstance(col2, Collection)
