"""Serving stack tests: engine continuous batching + kNN-LM retrieval."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.registry import build_model
from repro.serve import Request, ServeEngine, build_datastore, knn_probs
from repro.data.pipeline import SyntheticTokens, make_batch_fn


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("yi-9b").smoke().scaled(n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def test_engine_continuous_batching(tiny):
    cfg, model, params = tiny
    eng = ServeEngine(model, params, slots=2, cache_len=64)
    reqs = [
        Request(uid=i, prompt=np.arange(3 + i, dtype=np.int32) % cfg.vocab_size,
                max_new_tokens=4 + i)
        for i in range(5)  # more requests than slots -> queueing
    ]
    for r in reqs:
        eng.submit(r)
    steps = eng.run()
    assert steps > 0
    for r in reqs:
        assert r.done
        assert len(r.output) == r.max_new_tokens
        assert all(0 <= t < cfg.padded_vocab for t in r.output)


def test_engine_matches_single_stream(tiny):
    """A request decoded alone == the same request decoded while another
    request shares the batch (per-slot positions + caches are isolated)."""
    cfg, model, params = tiny
    p1 = np.arange(5, dtype=np.int32)
    p2 = (np.arange(7, dtype=np.int32) * 3) % cfg.vocab_size

    solo = Request(uid=0, prompt=p1, max_new_tokens=6)
    eng1 = ServeEngine(model, params, slots=1, cache_len=64)
    eng1.submit(solo)
    eng1.run()

    a = Request(uid=1, prompt=p1, max_new_tokens=6)
    b = Request(uid=2, prompt=p2, max_new_tokens=6)
    eng2 = ServeEngine(model, params, slots=2, cache_len=64)
    eng2.submit(a)
    eng2.submit(b)
    eng2.run()

    assert solo.output == a.output


def test_engine_ssm_family():
    cfg = get_config("mamba2-1.3b").smoke().scaled(n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    eng = ServeEngine(model, params, slots=2, cache_len=32)
    reqs = [Request(uid=i, prompt=np.arange(4, dtype=np.int32), max_new_tokens=5)
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done and len(r.output) == 5 for r in reqs)


def test_knn_probs_retrieves_neighbors(tiny):
    """Keys clustered around distinct centroids with distinct values: a
    query near a centroid must put most kNN mass on that value."""
    from repro.core import DBLSHParams, build
    from repro.serve.retrieval import Datastore

    D, vocab = 16, 50
    key = jax.random.key(3)
    centers = jax.random.normal(key, (5, D)) * 10.0
    pts = (centers[:, None, :] + 0.01 * jax.random.normal(key, (5, 200, D))).reshape(-1, D)
    vals = jnp.repeat(jnp.arange(5, dtype=jnp.int32) + 10, 200)
    params_lsh = DBLSHParams.derive(n=1000, d=D, c=1.5, t=32, k=8, K=8, L=3)
    ds = Datastore.from_index(build(jax.random.key(4), pts, params_lsh), vals,
                              temperature=1.0, lam=0.5, k=8)
    q = centers[2:3] + 0.01
    probs = knn_probs(ds, q, vocab, r0=0.05, steps=10)
    assert probs.shape == (1, vocab)
    assert float(probs[0, 12]) > 0.9  # value of cluster 2
    np.testing.assert_allclose(float(jnp.sum(probs)), 1.0, rtol=1e-3)


def test_retrieval_lm_end_to_end(tiny):
    """Datastore built from the model's own hidden states; retrieval-
    augmented decode returns a valid distribution and runs in the engine."""
    from repro.serve import RetrievalLM

    cfg, model, params = tiny
    src = SyntheticTokens(cfg.vocab_size, 16, 2, seed=1)
    batches = [make_batch_fn(src)(s) for s in range(3)]
    ds = build_datastore(
        model, params, batches, jax.random.key(5), t=16, k=4, block_size=32
    )
    assert ds.index.n == 3 * 2 * 16

    rlm = RetrievalLM(model, ds, r0=0.5, steps=4)
    eng = ServeEngine(model, params, slots=2, cache_len=64, retrieval=rlm)
    req = Request(uid=0, prompt=np.arange(4, dtype=np.int32), max_new_tokens=4)
    eng.submit(req)
    eng.run()
    assert req.done and len(req.output) == 4
