"""`hypothesis` import shim for the property tests.

Uses the real library when it is installed (``pip install -r
requirements-optional.txt``). When it is missing — the default CI /
container image ships without it — a tiny deterministic fallback runs
each ``@given`` test over a fixed pseudo-random sample of the strategy
space (seeded, so failures are reproducible) instead of skipping it.

Only the surface the test-suite uses is emulated: ``st.floats``,
``st.integers``, ``@given(**kwargs)`` and ``@settings(max_examples=,
deadline=)``.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import inspect
    import random

    _FALLBACK_SEED = 0xDB15
    _DEFAULT_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def floats(min_value: float, max_value: float) -> _Strategy:
            # include the endpoints early: boundary values find most bugs
            def draw(rng, _edge=[min_value, max_value]):
                if _edge:
                    return _edge.pop(0)
                return rng.uniform(min_value, max_value)

            return _Strategy(draw)

        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            def draw(rng, _edge=[min_value, max_value]):
                if _edge:
                    return _edge.pop(0)
                return rng.randint(min_value, max_value)

            return _Strategy(draw)

    st = _Strategies()

    def settings(**kw):
        """Record max_examples on the function; other knobs are no-ops."""

        def deco(fn):
            fn._max_examples = kw.get("max_examples", _DEFAULT_EXAMPLES)
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            sig = inspect.signature(fn)
            remaining = [
                p for name, p in sig.parameters.items() if name not in strategies
            ]

            def wrapper(*args, **kwargs):
                rng = random.Random(_FALLBACK_SEED)
                n = getattr(fn, "_max_examples", _DEFAULT_EXAMPLES)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            # pytest reads __signature__ for fixture injection: the drawn
            # parameters must not look like fixtures.
            wrapper.__signature__ = sig.replace(parameters=remaining)
            return wrapper

        return deco


__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
