"""Engine-equivalence tests for the fixed-schedule batched search path."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core import DBLSHParams, brute_force, build, search_batch, search_batch_fixed
from repro.data import make_clustered, normalize_scale


@pytest.fixture(scope="module")
def setup():
    key = jax.random.key(11)
    kd, kb = jax.random.split(key)
    allpts = make_clustered(kd, 2064, 24, n_clusters=12, spread=0.02)
    data, queries = allpts[:2048], allpts[2048:]
    data, queries, _ = normalize_scale(data, queries)
    params = DBLSHParams.derive(
        n=2048, d=24, c=1.5, t=48, k=10, K=8, L=3, inline_vectors=True
    )
    index = build(kb, data, params)
    return data, queries, params, index


@pytest.mark.parametrize("exact", [True, False])
def test_engines_agree(setup, exact):
    """jnp / kernel / inline engines return matching results.

    ``exact=True`` (diff-form distances) pins tight numeric agreement;
    the MXU norm form re-associates the dot reduction per engine, so
    there the contract is id-set equality + loose distance agreement
    (DESIGN.md §7)."""
    data, queries, params, index = setup
    outs = {}
    for engine in ["jnp", "kernel", "inline"]:
        d, i = search_batch_fixed(
            index, queries, k=8, r0=0.5, steps=6, engine=engine,
            interpret=True, exact=exact,
        )
        outs[engine] = (np.asarray(d), np.asarray(i))
    tol = 1e-5 if exact else 1e-2
    for engine in ["kernel", "inline"]:
        np.testing.assert_allclose(
            outs[engine][0], outs["jnp"][0], rtol=tol, atol=tol, err_msg=engine
        )
        # id sets must match wherever distances are finite (ties may permute)
        for qq in range(outs["jnp"][0].shape[0]):
            fin = np.isfinite(outs["jnp"][0][qq])
            assert set(outs[engine][1][qq][fin]) == set(outs["jnp"][1][qq][fin])


def test_fixed_matches_adaptive_recall(setup):
    """The fixed schedule must be at least as accurate as the adaptive
    while_loop path (it can only probe more)."""
    data, queries, params, index = setup
    k = 8
    _, gt = brute_force(data, queries, k=k)
    gt = np.asarray(gt)

    _, ids_a = search_batch(index, queries, k=k, r0=0.5)
    _, ids_f = search_batch_fixed(index, queries, k=k, r0=0.5, steps=10)
    rec = lambda ids: np.mean(
        [len(set(a.tolist()) & set(b.tolist())) / k for a, b in zip(np.asarray(ids), gt)]
    )
    assert rec(ids_f) >= rec(ids_a) - 1e-9
    assert rec(ids_f) > 0.6


def test_gather_vs_inline_params(setup):
    """inline_vectors=False index + fixed search agrees with inline."""
    data, queries, params, index = setup
    p2 = dataclasses.replace(params, inline_vectors=False)
    kb = jax.random.key(5)
    ia = build(kb, data, p2)
    ib = build(kb, data, params)
    da, _ = search_batch_fixed(ia, queries[:8], k=5, r0=0.5, steps=6, engine="jnp")
    db, _ = search_batch_fixed(ib, queries[:8], k=5, r0=0.5, steps=6, engine="jnp")
    np.testing.assert_allclose(np.asarray(da), np.asarray(db), rtol=1e-5, atol=1e-5)
