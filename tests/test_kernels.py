"""Per-kernel allclose tests vs the pure-jnp oracles (interpret mode).

Sweeps shapes/dtypes per the kernel contract; hypothesis drives extra
randomized shape cases.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import (
    candidate_dist,
    candidate_verify,
    pairwise_l2,
    window_dist,
    window_verify,
)
from repro.kernels.ref import (
    candidate_dist_ref,
    candidate_verify_ref,
    pairwise_l2_ref,
    window_dist_ref,
    window_verify_ref,
)


def _mk_candidates(key, Q, C, K, d, n):
    ks = jax.random.split(key, 5)
    cand_proj = jax.random.normal(ks[0], (Q, C, K)) * 2.0
    cand_vecs = jax.random.normal(ks[1], (Q, C, d))
    cand_ids = jax.random.randint(ks[2], (Q, C), 0, n + 1)  # includes invalid n
    g = jax.random.normal(ks[3], (Q, K))
    q = jax.random.normal(ks[4], (Q, d))
    return cand_proj, cand_vecs, cand_ids, g, q


def _assert_topk_equal(got, ref, msg=""):
    """Top-k sets can permute among ties; compare distances exactly and
    ids as multisets bucketed by distance."""
    gd, gi = map(np.asarray, got)
    rd, ri = map(np.asarray, ref)
    np.testing.assert_allclose(gd, rd, rtol=1e-5, atol=1e-5, err_msg=msg)
    for qq in range(gd.shape[0]):
        finite = np.isfinite(rd[qq])
        assert set(gi[qq][finite]) == set(ri[qq][finite]), (msg, qq)


@pytest.mark.parametrize("Q,C,K,d,k", [
    (1, 64, 4, 16, 5),
    (3, 256, 12, 128, 50),
    (2, 100, 8, 33, 10),   # non-multiple C and odd d
    (4, 32, 2, 8, 32),     # k == C
])
def test_candidate_verify_matches_ref(Q, C, K, d, k):
    n = 1000
    args = _mk_candidates(jax.random.key(Q * C + d), Q, C, K, d, n)
    w = 2.5
    got = candidate_verify(*args, w, n=n, k=k, interpret=True)
    ref = candidate_verify_ref(*args, w, n, k)
    _assert_topk_equal(got, ref)


def test_candidate_verify_dedup():
    """Duplicate (id, dist) candidates must appear at most once in top-k."""
    Q, C, K, d, n, k = 1, 64, 4, 16, 100, 8
    cp, cv, ci, g, q = _mk_candidates(jax.random.key(0), Q, C, K, d, n)
    # force duplicates: same candidate repeated 8x, all guaranteed in-box
    cp = cp.at[:, :8, :].set(g[:, None, :])
    cv = cv.at[:, :8, :].set(0.5)
    ci = ci.at[:, :8].set(7)
    got_d, got_i = candidate_verify(cp, cv, ci, g, q, 100.0, n=n, k=k, interpret=True)
    ids = np.asarray(got_i)[0]
    finite = np.isfinite(np.asarray(got_d)[0])
    assert (ids[finite] == 7).sum() <= 1


def test_candidate_verify_all_masked():
    """w = 0 and far boxes -> empty result (+inf, id=n)."""
    Q, C, K, d, n, k = 2, 64, 4, 16, 50, 5
    cp, cv, ci, g, q = _mk_candidates(jax.random.key(1), Q, C, K, d, n)
    got_d, got_i = candidate_verify(cp + 100.0, cv, ci, g, q, 0.5, n=n, k=k,
                                    interpret=True)
    assert np.all(np.isinf(np.asarray(got_d)))
    assert np.all(np.asarray(got_i) == n)


@pytest.mark.parametrize("Q,M,nb,B,K,d,k", [
    (2, 4, 16, 32, 4, 16, 5),
    (1, 8, 8, 64, 12, 96, 20),  # M == nb
])
def test_window_verify_matches_ref(Q, M, nb, B, K, d, k):
    n = nb * B - 3
    ks = jax.random.split(jax.random.key(Q + M + nb), 6)
    proj_blocks = jax.random.normal(ks[0], (nb, B, K)) * 2.0
    vec_blocks = jax.random.normal(ks[1], (nb, B, d))
    # real tables hold each id at most once (ids >= n are padding slots)
    ids_blocks = jax.random.permutation(ks[2], nb * B).reshape(nb, B).astype(jnp.int32)
    # block ids include invalid sentinel nb
    blk_idx = jax.random.randint(ks[3], (Q, M), 0, nb + 1).astype(jnp.int32)
    g = jax.random.normal(ks[4], (Q, K))
    q = jax.random.normal(ks[5], (Q, d))
    w = 3.0
    got = window_verify(blk_idx, proj_blocks, vec_blocks, ids_blocks, g, q, w,
                        n=n, k=k, interpret=True)
    ref = window_verify_ref(blk_idx, proj_blocks, vec_blocks, ids_blocks, g, q,
                            w, n, k)
    # ref gathers duplicate blocks twice; kernel dedups identical pairs, so
    # compare distances only where both finite, and id-sets per query.
    _assert_topk_equal(got, ref)


@pytest.mark.parametrize("Q,L,Ct,K,d", [
    (2, 3, 64, 4, 16),
    (1, 5, 300, 12, 96),   # non-multiple Ct
    (4, 1, 32, 2, 8),
])
@pytest.mark.parametrize("exact", [False, True])
def test_candidate_dist_matches_ref(Q, L, Ct, K, d, exact):
    ks = jax.random.split(jax.random.key(Q * Ct + d), 4)
    cp = jax.random.normal(ks[0], (Q, L, Ct, K)) * 2.0
    cv = jax.random.normal(ks[1], (Q, L, Ct, d))
    cn = jnp.sum(jnp.square(cv), axis=-1)
    # sprinkle invalid slots: +inf proj / norm (padding contract)
    cp = cp.at[:, :, ::7, :].set(jnp.inf)
    cn = cn.at[:, :, ::7].set(jnp.inf)
    g = jax.random.normal(ks[2], (Q, L, K))
    q = jax.random.normal(ks[3], (Q, d))
    d2, hw = candidate_dist(cp, cv, cn, g, q, exact=exact, interpret=True)
    d2r, hwr = candidate_dist_ref(cp, cv, cn, g, q, exact=exact)
    np.testing.assert_allclose(np.asarray(hw), np.asarray(hwr), rtol=1e-6)
    # in exact mode invalid slots carry real (ignored) distances; the
    # contract masks them through hw, so compare where hw is finite
    mask = np.isfinite(np.asarray(hwr))
    np.testing.assert_allclose(
        np.asarray(d2)[mask], np.asarray(d2r)[mask], rtol=1e-4, atol=1e-4
    )
    if not exact:
        assert np.isinf(np.asarray(d2)[~np.isfinite(np.asarray(cn)).reshape(
            np.asarray(d2).shape)]).all()


@pytest.mark.parametrize("Q,L,M,nb,B,K,d", [
    (2, 2, 4, 16, 32, 4, 16),
    (1, 3, 8, 8, 64, 12, 96),   # M == nb
])
@pytest.mark.parametrize("exact", [False, True])
def test_window_dist_matches_ref(Q, L, M, nb, B, K, d, exact):
    ks = jax.random.split(jax.random.key(Q + M + nb + L), 6)
    lnb = L * nb
    proj_blocks = jax.random.normal(ks[0], (lnb, B, K)) * 2.0
    vec_blocks = jax.random.normal(ks[1], (lnb, B, d))
    norm_blocks = jnp.sum(jnp.square(vec_blocks), axis=-1)
    # tail padding: +inf proj/norm on the last block's back half
    proj_blocks = proj_blocks.at[-1, B // 2:, :].set(jnp.inf)
    norm_blocks = norm_blocks.at[-1, B // 2:].set(jnp.inf)
    # block ids include the invalid sentinel lnb
    blk_idx = jax.random.randint(ks[3], (Q, L * M), 0, lnb + 1).astype(jnp.int32)
    g = jax.random.normal(ks[4], (Q, L, K))
    q = jax.random.normal(ks[5], (Q, d))
    d2, hw = window_dist(blk_idx, proj_blocks, vec_blocks, norm_blocks, g, q,
                         M=M, exact=exact, interpret=True)
    d2r, hwr = window_dist_ref(blk_idx, proj_blocks, vec_blocks, norm_blocks,
                               g, q, M, exact=exact)
    np.testing.assert_allclose(np.asarray(hw), np.asarray(hwr), rtol=1e-6)
    mask = np.isfinite(np.asarray(hwr))
    np.testing.assert_allclose(
        np.asarray(d2)[mask], np.asarray(d2r)[mask], rtol=1e-4, atol=1e-4
    )
    # invalid block slots must be unadmittable at any radius
    invalid = np.asarray(blk_idx) >= lnb
    hw_slots = np.asarray(hw).reshape(Q, L * M, B)
    assert np.isinf(hw_slots[invalid]).all()


@pytest.mark.parametrize("nq,nn,d", [
    (8, 16, 8),
    (256, 512, 128),
    (100, 300, 65),      # ragged everything
    (1, 1000, 960),      # gist-shaped
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_l2_matches_ref(nq, nn, d, dtype):
    kq, kx = jax.random.split(jax.random.key(nq + nn))
    Q = jax.random.normal(kq, (nq, d), dtype)
    X = jax.random.normal(kx, (nn, d), dtype)
    got = pairwise_l2(Q, X, interpret=True)
    ref = pairwise_l2_ref(Q.astype(jnp.float32), X.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=tol,
                               atol=tol * d)


@given(
    nq=st.integers(1, 40),
    nn=st.integers(1, 80),
    d=st.integers(1, 70),
)
@settings(deadline=None, max_examples=10)
def test_pairwise_l2_property(nq, nn, d):
    kq, kx = jax.random.split(jax.random.key(nq * 7919 + nn * 31 + d))
    Q = jax.random.normal(kq, (nq, d))
    X = jax.random.normal(kx, (nn, d))
    got = pairwise_l2(Q, X, tile_q=16, tile_n=16, tile_d=32, interpret=True)
    ref = pairwise_l2_ref(Q, X)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-4,
                               atol=2e-3)


def test_pairwise_l2_self_distance_zero():
    X = jax.random.normal(jax.random.key(3), (64, 32))
    got = np.asarray(pairwise_l2(X, X, interpret=True))
    assert np.all(np.abs(np.diag(got)) < 1e-3)
