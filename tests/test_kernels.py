"""Per-kernel allclose tests vs the pure-jnp oracles (interpret mode).

Sweeps shapes/dtypes per the kernel contract; hypothesis drives extra
randomized shape cases.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.index import quantize_blocks
from repro.kernels import (
    candidate_dist,
    candidate_verify,
    fused_cand_search,
    fused_window_search,
    pairwise_l2,
    window_dist,
    window_verify,
)
from repro.kernels.ops import _quantize_query
from repro.kernels.ref import (
    candidate_dist_ref,
    candidate_verify_ref,
    fused_search_ref,
    pairwise_l2_ref,
    window_dist_ref,
    window_verify_ref,
)


def _mk_candidates(key, Q, C, K, d, n):
    ks = jax.random.split(key, 5)
    cand_proj = jax.random.normal(ks[0], (Q, C, K)) * 2.0
    cand_vecs = jax.random.normal(ks[1], (Q, C, d))
    cand_ids = jax.random.randint(ks[2], (Q, C), 0, n + 1)  # includes invalid n
    g = jax.random.normal(ks[3], (Q, K))
    q = jax.random.normal(ks[4], (Q, d))
    return cand_proj, cand_vecs, cand_ids, g, q


def _assert_topk_equal(got, ref, msg=""):
    """Top-k sets can permute among ties; compare distances exactly and
    ids as multisets bucketed by distance."""
    gd, gi = map(np.asarray, got)
    rd, ri = map(np.asarray, ref)
    np.testing.assert_allclose(gd, rd, rtol=1e-5, atol=1e-5, err_msg=msg)
    for qq in range(gd.shape[0]):
        finite = np.isfinite(rd[qq])
        assert set(gi[qq][finite]) == set(ri[qq][finite]), (msg, qq)


@pytest.mark.parametrize("Q,C,K,d,k", [
    (1, 64, 4, 16, 5),
    (3, 256, 12, 128, 50),
    (2, 100, 8, 33, 10),   # non-multiple C and odd d
    (4, 32, 2, 8, 32),     # k == C
])
def test_candidate_verify_matches_ref(Q, C, K, d, k):
    n = 1000
    args = _mk_candidates(jax.random.key(Q * C + d), Q, C, K, d, n)
    w = 2.5
    got = candidate_verify(*args, w, n=n, k=k, interpret=True)
    ref = candidate_verify_ref(*args, w, n, k)
    _assert_topk_equal(got, ref)


def test_candidate_verify_dedup():
    """Duplicate (id, dist) candidates must appear at most once in top-k."""
    Q, C, K, d, n, k = 1, 64, 4, 16, 100, 8
    cp, cv, ci, g, q = _mk_candidates(jax.random.key(0), Q, C, K, d, n)
    # force duplicates: same candidate repeated 8x, all guaranteed in-box
    cp = cp.at[:, :8, :].set(g[:, None, :])
    cv = cv.at[:, :8, :].set(0.5)
    ci = ci.at[:, :8].set(7)
    got_d, got_i = candidate_verify(cp, cv, ci, g, q, 100.0, n=n, k=k, interpret=True)
    ids = np.asarray(got_i)[0]
    finite = np.isfinite(np.asarray(got_d)[0])
    assert (ids[finite] == 7).sum() <= 1


def test_candidate_verify_all_masked():
    """w = 0 and far boxes -> empty result (+inf, id=n)."""
    Q, C, K, d, n, k = 2, 64, 4, 16, 50, 5
    cp, cv, ci, g, q = _mk_candidates(jax.random.key(1), Q, C, K, d, n)
    got_d, got_i = candidate_verify(cp + 100.0, cv, ci, g, q, 0.5, n=n, k=k,
                                    interpret=True)
    assert np.all(np.isinf(np.asarray(got_d)))
    assert np.all(np.asarray(got_i) == n)


@pytest.mark.parametrize("Q,M,nb,B,K,d,k", [
    (2, 4, 16, 32, 4, 16, 5),
    (1, 8, 8, 64, 12, 96, 20),  # M == nb
])
def test_window_verify_matches_ref(Q, M, nb, B, K, d, k):
    n = nb * B - 3
    ks = jax.random.split(jax.random.key(Q + M + nb), 6)
    proj_blocks = jax.random.normal(ks[0], (nb, B, K)) * 2.0
    vec_blocks = jax.random.normal(ks[1], (nb, B, d))
    # real tables hold each id at most once (ids >= n are padding slots)
    ids_blocks = jax.random.permutation(ks[2], nb * B).reshape(nb, B).astype(jnp.int32)
    # block ids include invalid sentinel nb
    blk_idx = jax.random.randint(ks[3], (Q, M), 0, nb + 1).astype(jnp.int32)
    g = jax.random.normal(ks[4], (Q, K))
    q = jax.random.normal(ks[5], (Q, d))
    w = 3.0
    got = window_verify(blk_idx, proj_blocks, vec_blocks, ids_blocks, g, q, w,
                        n=n, k=k, interpret=True)
    ref = window_verify_ref(blk_idx, proj_blocks, vec_blocks, ids_blocks, g, q,
                            w, n, k)
    # ref gathers duplicate blocks twice; kernel dedups identical pairs, so
    # compare distances only where both finite, and id-sets per query.
    _assert_topk_equal(got, ref)


@pytest.mark.parametrize("Q,L,Ct,K,d", [
    (2, 3, 64, 4, 16),
    (1, 5, 300, 12, 96),   # non-multiple Ct
    (4, 1, 32, 2, 8),
])
@pytest.mark.parametrize("exact", [False, True])
def test_candidate_dist_matches_ref(Q, L, Ct, K, d, exact):
    ks = jax.random.split(jax.random.key(Q * Ct + d), 4)
    cp = jax.random.normal(ks[0], (Q, L, Ct, K)) * 2.0
    cv = jax.random.normal(ks[1], (Q, L, Ct, d))
    cn = jnp.sum(jnp.square(cv), axis=-1)
    # sprinkle invalid slots: +inf proj / norm (padding contract)
    cp = cp.at[:, :, ::7, :].set(jnp.inf)
    cn = cn.at[:, :, ::7].set(jnp.inf)
    g = jax.random.normal(ks[2], (Q, L, K))
    q = jax.random.normal(ks[3], (Q, d))
    d2, hw = candidate_dist(cp, cv, cn, g, q, exact=exact, interpret=True)
    d2r, hwr = candidate_dist_ref(cp, cv, cn, g, q, exact=exact)
    np.testing.assert_allclose(np.asarray(hw), np.asarray(hwr), rtol=1e-6)
    # in exact mode invalid slots carry real (ignored) distances; the
    # contract masks them through hw, so compare where hw is finite
    mask = np.isfinite(np.asarray(hwr))
    np.testing.assert_allclose(
        np.asarray(d2)[mask], np.asarray(d2r)[mask], rtol=1e-4, atol=1e-4
    )
    if not exact:
        assert np.isinf(np.asarray(d2)[~np.isfinite(np.asarray(cn)).reshape(
            np.asarray(d2).shape)]).all()


@pytest.mark.parametrize("Q,L,M,nb,B,K,d", [
    (2, 2, 4, 16, 32, 4, 16),
    (1, 3, 8, 8, 64, 12, 96),   # M == nb
])
@pytest.mark.parametrize("exact", [False, True])
def test_window_dist_matches_ref(Q, L, M, nb, B, K, d, exact):
    ks = jax.random.split(jax.random.key(Q + M + nb + L), 6)
    lnb = L * nb
    proj_blocks = jax.random.normal(ks[0], (lnb, B, K)) * 2.0
    vec_blocks = jax.random.normal(ks[1], (lnb, B, d))
    norm_blocks = jnp.sum(jnp.square(vec_blocks), axis=-1)
    # tail padding: +inf proj/norm on the last block's back half
    proj_blocks = proj_blocks.at[-1, B // 2:, :].set(jnp.inf)
    norm_blocks = norm_blocks.at[-1, B // 2:].set(jnp.inf)
    # block ids include the invalid sentinel lnb
    blk_idx = jax.random.randint(ks[3], (Q, L * M), 0, lnb + 1).astype(jnp.int32)
    g = jax.random.normal(ks[4], (Q, L, K))
    q = jax.random.normal(ks[5], (Q, d))
    d2, hw = window_dist(blk_idx, proj_blocks, vec_blocks, norm_blocks, g, q,
                         M=M, exact=exact, interpret=True)
    d2r, hwr = window_dist_ref(blk_idx, proj_blocks, vec_blocks, norm_blocks,
                               g, q, M, exact=exact)
    np.testing.assert_allclose(np.asarray(hw), np.asarray(hwr), rtol=1e-6)
    mask = np.isfinite(np.asarray(hwr))
    np.testing.assert_allclose(
        np.asarray(d2)[mask], np.asarray(d2r)[mask], rtol=1e-4, atol=1e-4
    )
    # invalid block slots must be unadmittable at any radius
    invalid = np.asarray(blk_idx) >= lnb
    hw_slots = np.asarray(hw).reshape(Q, L * M, B)
    assert np.isinf(hw_slots[invalid]).all()


# --------------------------------------------------------------- fused search

def _halves(steps):
    """An ascending radius-schedule half-width ladder that straddles the
    typical hw distribution of unit-normal projections."""
    return jnp.asarray([0.4 * 1.5 ** j for j in range(steps)], jnp.float32)


def _mk_window(seed, L, M, nb, B, K, d):
    ks = jax.random.split(jax.random.key(seed), 5)
    lnb = L * nb
    n = lnb * B - 3
    data = jax.random.normal(ks[0], (n, d))
    # each table holds every slot id at most once; >= n slots are padding
    ids_blocks = jax.random.permutation(ks[1], lnb * B).reshape(lnb, B)
    ids_blocks = ids_blocks.astype(jnp.int32)
    vec_blocks = jnp.take(data, ids_blocks, axis=0, mode="fill", fill_value=0.0)
    norm_blocks = jnp.where(
        ids_blocks < n, jnp.sum(jnp.square(vec_blocks), axis=-1), jnp.inf
    )
    proj_blocks = jax.random.normal(ks[2], (lnb, B, K)) * 2.0
    proj_blocks = jnp.where(
        (ids_blocks < n)[..., None], proj_blocks, jnp.inf
    )
    return data, ids_blocks, vec_blocks, norm_blocks, proj_blocks, n, ks[3], ks[4]


def _assert_bins_equal(got, ref, n):
    """Bin accumulators: counts exact, distances allclose, ids as sets
    per (query, bin) over the finite entries (ties may permute)."""
    gd, gi, gc = map(np.asarray, got)
    rd, ri, rc = map(np.asarray, ref)
    np.testing.assert_array_equal(gc, rc)
    np.testing.assert_allclose(gd, rd, rtol=1e-5, atol=1e-5)
    Qn, steps, _ = gd.shape
    for qq in range(Qn):
        for j in range(steps):
            finite = np.isfinite(rd[qq, j])
            assert set(gi[qq, j][finite]) == set(ri[qq, j][finite]), (qq, j)


@pytest.mark.parametrize("Q,L,M,nb,B,K,d,ks", [
    (2, 2, 4, 8, 32, 4, 16, 5),
    (1, 3, 8, 8, 64, 12, 96, 20),   # M == nb
])
@pytest.mark.parametrize("steps", [1, 4, 8])
@pytest.mark.parametrize("mode", ["norm", "exact"])
def test_fused_window_search_matches_ref(Q, L, M, nb, B, K, d, ks, steps, mode):
    _, ids_blocks, vec_blocks, norm_blocks, proj_blocks, n, kb, kq = (
        _mk_window(Q + L * M + nb + steps, L, M, nb, B, K, d)
    )
    lnb = L * nb
    kb1, kb2 = jax.random.split(kb)
    # block ids include the invalid sentinel lnb
    blk_idx = jax.random.randint(kb1, (Q, L * M), 0, lnb + 1).astype(jnp.int32)
    g = jax.random.normal(kb2, (Q, L, K))
    q = jax.random.normal(kq, (Q, d))
    halves = _halves(steps)
    got = fused_window_search(
        blk_idx, halves, proj_blocks, vec_blocks, norm_blocks, ids_blocks,
        g, q, M=M, ks=ks, n=n, mode=mode, interpret=True,
    )
    d2r, hwr = window_dist_ref(blk_idx, proj_blocks, vec_blocks, norm_blocks,
                               g, q, M, exact=(mode == "exact"))
    idsr = jnp.take(ids_blocks, blk_idx, axis=0, mode="fill",
                    fill_value=n).reshape(Q, -1)
    ref = fused_search_ref(d2r, hwr, idsr, halves, n, ks)
    _assert_bins_equal(got, ref, n)


@pytest.mark.parametrize("Q,L,Ct,K,d,ks", [
    (2, 3, 64, 4, 16, 5),
    (1, 2, 300, 12, 96, 20),   # non-multiple Ct
])
@pytest.mark.parametrize("steps", [1, 6])
@pytest.mark.parametrize("mode", ["norm", "exact"])
def test_fused_cand_search_matches_ref(Q, L, Ct, K, d, ks, steps, mode):
    rks = jax.random.split(jax.random.key(Q * Ct + d + steps), 5)
    cp = jax.random.normal(rks[0], (Q, L, Ct, K)) * 2.0
    cv = jax.random.normal(rks[1], (Q, L, Ct, d))
    cn = jnp.sum(jnp.square(cv), axis=-1)
    n = 4096
    ci = jax.random.randint(rks[2], (Q, L, Ct), 0, n).astype(jnp.int32)
    # invalid slots: +inf proj / norm (gather-fill contract)
    cp = cp.at[:, :, ::7, :].set(jnp.inf)
    cn = cn.at[:, :, ::7].set(jnp.inf)
    g = jax.random.normal(rks[3], (Q, L, K))
    q = jax.random.normal(rks[4], (Q, d))
    halves = _halves(steps)
    got = fused_cand_search(cp, cv, cn, ci, halves, g, q, ks=ks, n=n,
                            mode=mode, tile_c=64, interpret=True)
    d2r, hwr = candidate_dist_ref(cp, cv, cn, g, q, exact=(mode == "exact"))
    # exact mode computes real distances on +inf-marked slots; the
    # contract masks them through hw alone, exactly like the kernel
    ref = fused_search_ref(d2r, hwr, ci.reshape(Q, -1), halves, n, ks)
    _assert_bins_equal(got, ref, n)


def test_fused_window_search_int8_matches_ref():
    """int8 mode: integer dots are exact, so the kernel must match a jnp
    oracle that replays the same quantized arithmetic (same scales, same
    dequant order) to fp32 rounding tolerance; admission counts stay
    fp32-exact."""
    Q, L, M, nb, B, K, d, ks, steps = 2, 2, 4, 8, 32, 4, 16, 8, 6
    data, ids_blocks, vec_blocks, norm_blocks, proj_blocks, n, kb, kq = (
        _mk_window(77, L, M, nb, B, K, d)
    )
    lnb = L * nb
    kb1, kb2 = jax.random.split(kb)
    blk_idx = jax.random.randint(kb1, (Q, L * M), 0, lnb + 1).astype(jnp.int32)
    g = jax.random.normal(kb2, (Q, L, K))
    q = jax.random.normal(kq, (Q, d))
    halves = _halves(steps)
    qb, qsc = quantize_blocks(data, ids_blocks, "int8")
    got = fused_window_search(
        blk_idx, halves, proj_blocks, qb, norm_blocks, ids_blocks,
        g, q, M=M, ks=ks, n=n, mode="int8", interpret=True, x_scale=qsc,
    )
    # oracle pool: same quantized dot, dequantized in the kernel's order
    qv, qqs = _quantize_query(q, "int8")
    xq = jnp.take(qb, blk_idx, axis=0, mode="fill", fill_value=0)
    xs = jnp.take(qsc, blk_idx, axis=0, mode="fill", fill_value=1.0)
    nrm = jnp.take(norm_blocks, blk_idx, axis=0, mode="fill", fill_value=jnp.inf)
    idot = jnp.einsum("qsbd,qd->qsb", xq.astype(jnp.int32),
                      qv.astype(jnp.int32)).astype(jnp.float32)
    q2 = jnp.sum(jnp.square(q), axis=-1)
    d2q = jnp.maximum(
        nrm - 2.0 * (xs * qqs[:, :, None] * idot) + q2[:, None, None], 0.0
    ).reshape(Q, -1)
    _, hwr = window_dist_ref(blk_idx, proj_blocks, vec_blocks, norm_blocks,
                             g, q, M)
    idsr = jnp.take(ids_blocks, blk_idx, axis=0, mode="fill",
                    fill_value=n).reshape(Q, -1)
    ref = fused_search_ref(d2q, hwr, idsr, halves, n, ks)
    _assert_bins_equal(got, ref, n)


def test_fused_window_search_bf16_band():
    """bf16 mode: admission counts are fp32-exact (hw never quantizes),
    and the per-bin id sets stay within the documented recall band of
    the fp32 bins — reduced precision reorders near-ties only."""
    Q, L, M, nb, B, K, d, ks, steps = 2, 2, 4, 8, 32, 4, 24, 10, 6
    data, ids_blocks, vec_blocks, norm_blocks, proj_blocks, n, kb, kq = (
        _mk_window(99, L, M, nb, B, K, d)
    )
    lnb = L * nb
    kb1, kb2 = jax.random.split(kb)
    blk_idx = jax.random.randint(kb1, (Q, L * M), 0, lnb + 1).astype(jnp.int32)
    g = jax.random.normal(kb2, (Q, L, K))
    q = jax.random.normal(kq, (Q, d))
    halves = _halves(steps)
    qb, qsc = quantize_blocks(data, ids_blocks, "bf16")
    bd_q, bi_q, cnt_q = fused_window_search(
        blk_idx, halves, proj_blocks, qb, norm_blocks, ids_blocks,
        g, q, M=M, ks=ks, n=n, mode="bf16", interpret=True, x_scale=qsc,
    )
    bd_f, bi_f, cnt_f = fused_window_search(
        blk_idx, halves, proj_blocks, vec_blocks, norm_blocks, ids_blocks,
        g, q, M=M, ks=ks, n=n, mode="norm", interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(cnt_q), np.asarray(cnt_f))
    # documented tolerance band: per-bin id-set recall >= 0.9 vs fp32
    # (bf16 keeps ~8 mantissa bits — only near-ties at the shortlist
    # boundary may swap; bit-equality is NOT part of the contract)
    bi_qn, bi_fn = np.asarray(bi_q), np.asarray(bi_f)
    bd_fn = np.asarray(bd_f)
    hits = total = 0
    for qq in range(Q):
        for j in range(steps):
            want = set(bi_fn[qq, j][np.isfinite(bd_fn[qq, j])])
            have = set(bi_qn[qq, j].tolist())
            hits += len(want & have)
            total += len(want)
    assert total == 0 or hits / total >= 0.9, hits / total


def test_invalid_slots_never_contribute():
    """Satellite bugfix pin: an invalid select slot (blk >= lnb) must
    contribute nothing, even though its DMA is routed to block 0 and
    block 0 holds perfectly admittable points.  A clamp-style route to a
    *real* block with unmasked compute would leak block 0's points into
    every query that carries a padded slot."""
    L, M, nb, B, K, d = 1, 4, 4, 8, 4, 8
    lnb = L * nb
    n = lnb * B
    key = jax.random.key(5)
    k1, k2 = jax.random.split(key)
    q = jax.random.normal(k1, (1, d))
    g = jnp.zeros((1, L, K))
    # block 0: projections exactly at the query's g => hw = 0, always
    # admitted at any radius; vectors literally the query point
    proj_blocks = jnp.zeros((lnb, B, K))
    vec_blocks = jnp.broadcast_to(q[0], (lnb, B, d)).copy()
    norm_blocks = jnp.broadcast_to(jnp.sum(jnp.square(q)), (lnb, B)).copy()
    ids_blocks = jnp.arange(lnb * B, dtype=jnp.int32).reshape(lnb, B)
    all_invalid = jnp.full((1, L * M), lnb, jnp.int32)

    # window_dist: every slot must come back unadmittable (+inf)
    d2, hw = window_dist(all_invalid, proj_blocks, vec_blocks, norm_blocks,
                         g, q, M=M, interpret=True)
    assert np.isinf(np.asarray(hw)).all()
    assert np.isinf(np.asarray(d2)).all()

    # window_verify: empty result despite block 0 matching exactly
    vd, vi = window_verify(all_invalid[:, :M], proj_blocks, vec_blocks,
                           ids_blocks, g[:, 0], q, 100.0, n=n, k=5,
                           interpret=True)
    assert np.isinf(np.asarray(vd)).all()
    assert (np.asarray(vi) == n).all()

    # fused: all bins empty, zero admitted slots
    halves = _halves(4)
    bd, bi, cnt = fused_window_search(
        all_invalid, halves, proj_blocks, vec_blocks, norm_blocks,
        ids_blocks, g, q, M=M, ks=5, n=n, mode="norm", interpret=True,
    )
    assert np.isinf(np.asarray(bd)).all()
    assert (np.asarray(bi) == n).all()
    assert (np.asarray(cnt) == 0).all()

    # mixed: one valid slot -> exactly that block's points, nothing else
    mixed = jnp.asarray([[2, lnb, lnb, lnb]], jnp.int32)
    bd, bi, cnt = fused_window_search(
        mixed, halves, proj_blocks, vec_blocks, norm_blocks,
        ids_blocks, g, q, M=M, ks=B, n=n, mode="norm", interpret=True,
    )
    got_ids = set(np.asarray(bi)[np.isfinite(np.asarray(bd))].tolist())
    assert got_ids == set(np.asarray(ids_blocks[2]).tolist())
    assert int(np.asarray(cnt).sum()) == B


# ------------------------------------------------------- merge primitives

def test_merge_topk_duplicate_id_distinct_dists():
    """Dedup is on (dist, id) *pairs*: one id at two distances is two
    distinct candidates (the serving path never produces this — exact
    distances are a function of the id — but the primitive must not
    silently collapse them)."""
    from repro.kernels.window_verify import merge_topk
    cd = jnp.asarray([1.0, 2.0, 3.0, jnp.inf])
    ci = jnp.asarray([7, 7, 9, 0], jnp.int32)
    out_d = jnp.full((3,), jnp.inf)
    out_i = jnp.full((3,), np.iinfo(np.int32).max, jnp.int32)
    nd, ni = merge_topk(cd, ci, out_d, out_i, 3)
    np.testing.assert_allclose(np.asarray(nd), [1.0, 2.0, 3.0])
    np.testing.assert_array_equal(np.asarray(ni), [7, 7, 9])


def test_merge_topk_identical_pairs_dedup():
    """Cross-table duplicates carry identical (dist, id) pairs and must
    count once."""
    from repro.kernels.window_verify import merge_topk
    cd = jnp.asarray([2.0, 2.0, 2.0, 5.0])
    ci = jnp.asarray([4, 4, 4, 8], jnp.int32)
    out_d = jnp.full((3,), jnp.inf)
    out_i = jnp.full((3,), np.iinfo(np.int32).max, jnp.int32)
    nd, ni = merge_topk(cd, ci, out_d, out_i, 3)
    np.testing.assert_allclose(np.asarray(nd), [2.0, 5.0, jnp.inf])
    assert np.asarray(ni)[:2].tolist() == [4, 8]


@given(seed=st.integers(0, 2**31 - 1), k=st.integers(1, 12),
       a=st.integers(1, 16), b=st.integers(1, 24))
@settings(deadline=None, max_examples=15)
def test_merge_dedup_topk_property(seed, k, a, b):
    """Batched merge vs a host oracle: sorted distinct (dist, id) pairs,
    ascending, +inf/n padded — under duplicates, ties and all-inf tiles."""
    from repro.core import merge_dedup_topk
    rng = np.random.default_rng(seed)
    n = 64
    Qn = 3
    # coarse distance grid => plenty of exact ties; some ids duplicated
    run_d = np.sort(rng.choice([0.5, 1.0, 2.0, np.inf], (Qn, a)), axis=1)
    run_i = np.where(np.isfinite(run_d), rng.integers(0, n, (Qn, a)), n)
    new_d = rng.choice([0.25, 0.5, 1.0, 3.0, np.inf], (Qn, b))
    new_i = np.where(np.isfinite(new_d), rng.integers(0, n, (Qn, b)), n)
    if seed % 3 == 0:
        new_d[0, :] = np.inf  # an all-inf tile must be a no-op row
    gd, gi = merge_dedup_topk(
        jnp.asarray(run_d, jnp.float32), jnp.asarray(run_i, jnp.int32),
        jnp.asarray(new_d, jnp.float32), jnp.asarray(new_i, jnp.int32),
        n, k,
    )
    gd, gi = np.asarray(gd), np.asarray(gi)
    for qq in range(Qn):
        pairs = {
            (float(dd), int(ii))
            for dd, ii in zip(
                np.concatenate([run_d[qq], new_d[qq]]),
                np.concatenate([run_i[qq], new_i[qq]]),
            )
            if np.isfinite(dd)
        }
        want = sorted(pairs)[:k]
        want_d = [p[0] for p in want] + [np.inf] * (k - len(want))
        want_i = [p[1] for p in want] + [n] * (k - len(want))
        np.testing.assert_allclose(gd[qq], want_d)
        np.testing.assert_array_equal(gi[qq], want_i)


def test_merge_dedup_topk_tie_overflow():
    """More than k candidates at one distance: the k smallest ids win,
    in id order (the lexicographic (dist, id) contract)."""
    from repro.core import merge_dedup_topk
    n, k = 100, 4
    run_d = jnp.full((1, k), jnp.inf)
    run_i = jnp.full((1, k), n, jnp.int32)
    new_d = jnp.full((1, 8), 2.0)
    new_i = jnp.asarray([[31, 3, 55, 14, 90, 2, 77, 41]], jnp.int32)
    gd, gi = merge_dedup_topk(run_d, run_i, new_d, new_i, n, k)
    np.testing.assert_allclose(np.asarray(gd)[0], [2.0] * k)
    np.testing.assert_array_equal(np.asarray(gi)[0], [2, 3, 14, 31])


@pytest.mark.parametrize("nq,nn,d", [
    (8, 16, 8),
    (256, 512, 128),
    (100, 300, 65),      # ragged everything
    (1, 1000, 960),      # gist-shaped
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_l2_matches_ref(nq, nn, d, dtype):
    kq, kx = jax.random.split(jax.random.key(nq + nn))
    Q = jax.random.normal(kq, (nq, d), dtype)
    X = jax.random.normal(kx, (nn, d), dtype)
    got = pairwise_l2(Q, X, interpret=True)
    ref = pairwise_l2_ref(Q.astype(jnp.float32), X.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=tol,
                               atol=tol * d)


@given(
    nq=st.integers(1, 40),
    nn=st.integers(1, 80),
    d=st.integers(1, 70),
)
@settings(deadline=None, max_examples=10)
def test_pairwise_l2_property(nq, nn, d):
    kq, kx = jax.random.split(jax.random.key(nq * 7919 + nn * 31 + d))
    Q = jax.random.normal(kq, (nq, d))
    X = jax.random.normal(kx, (nn, d))
    got = pairwise_l2(Q, X, tile_q=16, tile_n=16, tile_d=32, interpret=True)
    ref = pairwise_l2_ref(Q, X)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-4,
                               atol=2e-3)


def test_pairwise_l2_self_distance_zero():
    X = jax.random.normal(jax.random.key(3), (64, 32))
    got = np.asarray(pairwise_l2(X, X, interpret=True))
    assert np.all(np.abs(np.diag(got)) < 1e-3)
