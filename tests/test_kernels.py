"""Per-kernel allclose tests vs the pure-jnp oracles (interpret mode).

Sweeps shapes/dtypes per the kernel contract; hypothesis drives extra
randomized shape cases.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import candidate_verify, pairwise_l2, window_verify
from repro.kernels.ref import (
    candidate_verify_ref,
    pairwise_l2_ref,
    window_verify_ref,
)


def _mk_candidates(key, Q, C, K, d, n):
    ks = jax.random.split(key, 5)
    cand_proj = jax.random.normal(ks[0], (Q, C, K)) * 2.0
    cand_vecs = jax.random.normal(ks[1], (Q, C, d))
    cand_ids = jax.random.randint(ks[2], (Q, C), 0, n + 1)  # includes invalid n
    g = jax.random.normal(ks[3], (Q, K))
    q = jax.random.normal(ks[4], (Q, d))
    return cand_proj, cand_vecs, cand_ids, g, q


def _assert_topk_equal(got, ref, msg=""):
    """Top-k sets can permute among ties; compare distances exactly and
    ids as multisets bucketed by distance."""
    gd, gi = map(np.asarray, got)
    rd, ri = map(np.asarray, ref)
    np.testing.assert_allclose(gd, rd, rtol=1e-5, atol=1e-5, err_msg=msg)
    for qq in range(gd.shape[0]):
        finite = np.isfinite(rd[qq])
        assert set(gi[qq][finite]) == set(ri[qq][finite]), (msg, qq)


@pytest.mark.parametrize("Q,C,K,d,k", [
    (1, 64, 4, 16, 5),
    (3, 256, 12, 128, 50),
    (2, 100, 8, 33, 10),   # non-multiple C and odd d
    (4, 32, 2, 8, 32),     # k == C
])
def test_candidate_verify_matches_ref(Q, C, K, d, k):
    n = 1000
    args = _mk_candidates(jax.random.key(Q * C + d), Q, C, K, d, n)
    w = 2.5
    got = candidate_verify(*args, w, n=n, k=k, interpret=True)
    ref = candidate_verify_ref(*args, w, n, k)
    _assert_topk_equal(got, ref)


def test_candidate_verify_dedup():
    """Duplicate (id, dist) candidates must appear at most once in top-k."""
    Q, C, K, d, n, k = 1, 64, 4, 16, 100, 8
    cp, cv, ci, g, q = _mk_candidates(jax.random.key(0), Q, C, K, d, n)
    # force duplicates: same candidate repeated 8x, all guaranteed in-box
    cp = cp.at[:, :8, :].set(g[:, None, :])
    cv = cv.at[:, :8, :].set(0.5)
    ci = ci.at[:, :8].set(7)
    got_d, got_i = candidate_verify(cp, cv, ci, g, q, 100.0, n=n, k=k, interpret=True)
    ids = np.asarray(got_i)[0]
    finite = np.isfinite(np.asarray(got_d)[0])
    assert (ids[finite] == 7).sum() <= 1


def test_candidate_verify_all_masked():
    """w = 0 and far boxes -> empty result (+inf, id=n)."""
    Q, C, K, d, n, k = 2, 64, 4, 16, 50, 5
    cp, cv, ci, g, q = _mk_candidates(jax.random.key(1), Q, C, K, d, n)
    got_d, got_i = candidate_verify(cp + 100.0, cv, ci, g, q, 0.5, n=n, k=k,
                                    interpret=True)
    assert np.all(np.isinf(np.asarray(got_d)))
    assert np.all(np.asarray(got_i) == n)


@pytest.mark.parametrize("Q,M,nb,B,K,d,k", [
    (2, 4, 16, 32, 4, 16, 5),
    (1, 8, 8, 64, 12, 96, 20),  # M == nb
])
def test_window_verify_matches_ref(Q, M, nb, B, K, d, k):
    n = nb * B - 3
    ks = jax.random.split(jax.random.key(Q + M + nb), 6)
    proj_blocks = jax.random.normal(ks[0], (nb, B, K)) * 2.0
    vec_blocks = jax.random.normal(ks[1], (nb, B, d))
    # real tables hold each id at most once (ids >= n are padding slots)
    ids_blocks = jax.random.permutation(ks[2], nb * B).reshape(nb, B).astype(jnp.int32)
    # block ids include invalid sentinel nb
    blk_idx = jax.random.randint(ks[3], (Q, M), 0, nb + 1).astype(jnp.int32)
    g = jax.random.normal(ks[4], (Q, K))
    q = jax.random.normal(ks[5], (Q, d))
    w = 3.0
    got = window_verify(blk_idx, proj_blocks, vec_blocks, ids_blocks, g, q, w,
                        n=n, k=k, interpret=True)
    ref = window_verify_ref(blk_idx, proj_blocks, vec_blocks, ids_blocks, g, q,
                            w, n, k)
    # ref gathers duplicate blocks twice; kernel dedups identical pairs, so
    # compare distances only where both finite, and id-sets per query.
    _assert_topk_equal(got, ref)


@pytest.mark.parametrize("nq,nn,d", [
    (8, 16, 8),
    (256, 512, 128),
    (100, 300, 65),      # ragged everything
    (1, 1000, 960),      # gist-shaped
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_l2_matches_ref(nq, nn, d, dtype):
    kq, kx = jax.random.split(jax.random.key(nq + nn))
    Q = jax.random.normal(kq, (nq, d), dtype)
    X = jax.random.normal(kx, (nn, d), dtype)
    got = pairwise_l2(Q, X, interpret=True)
    ref = pairwise_l2_ref(Q.astype(jnp.float32), X.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=tol,
                               atol=tol * d)


@given(
    nq=st.integers(1, 40),
    nn=st.integers(1, 80),
    d=st.integers(1, 70),
)
@settings(deadline=None, max_examples=10)
def test_pairwise_l2_property(nq, nn, d):
    kq, kx = jax.random.split(jax.random.key(nq * 7919 + nn * 31 + d))
    Q = jax.random.normal(kq, (nq, d))
    X = jax.random.normal(kx, (nn, d))
    got = pairwise_l2(Q, X, tile_q=16, tile_n=16, tile_d=32, interpret=True)
    ref = pairwise_l2_ref(Q, X)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-4,
                               atol=2e-3)


def test_pairwise_l2_self_distance_zero():
    X = jax.random.normal(jax.random.key(3), (64, 32))
    got = np.asarray(pairwise_l2(X, X, interpret=True))
    assert np.all(np.abs(np.diag(got)) < 1e-3)
