"""HLO analyzer validation against analytically-known graphs."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_stats import analyze


def test_plain_matmul_flops():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    txt = jax.jit(f).lower(a, b).compile().as_text()
    st = analyze(txt)
    expect = 2 * 128 * 256 * 64
    assert abs(st.flops - expect) / expect < 0.01, (st.flops, expect)
    assert st.collective_bytes == 0


def test_scan_trip_count_multiplies():
    L, D = 7, 64

    def f(w, x):
        def body(x, wl):
            return jnp.tanh(x @ wl), None
        y, _ = jax.lax.scan(body, x, w)
        return jnp.sum(y)

    w = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((32, D), jnp.float32)
    txt = jax.jit(f).lower(w, x).compile().as_text()
    st = analyze(txt)
    expect = 2 * 32 * D * D * L
    assert abs(st.flops - expect) / expect < 0.05, (st.flops, expect)
    # HBM traffic must also scale with L (weights streamed every step)
    assert st.hbm_bytes > L * D * D * 4


def test_collective_bytes_sharded_matmul():
    # runs under the default single device: simulate with 4 via subprocess?
    # here: spot-check that an explicit psum shows up.
    from repro.compat import shard_map

    mesh = jax.make_mesh((1,), ("model",))  # axis_types default to Auto

    def f(x):
        return shard_map(
            lambda a: jax.lax.psum(a, "model"), mesh=mesh,
            in_specs=jax.sharding.PartitionSpec("model"),
            out_specs=jax.sharding.PartitionSpec(),
            check=True,
        )(x)

    x = jax.ShapeDtypeStruct((64,), jnp.float32)
    with mesh:
        txt = jax.jit(f).lower(x).compile().as_text()
    st = analyze(txt)
    # single device: XLA may elide the all-reduce; just assert no crash
    assert st.flops >= 0.0


def test_nested_scan():
    Lo, Li, D = 3, 5, 32

    def f(w, x):
        def outer(x, wo):
            def inner(x, _):
                return jnp.tanh(x @ wo), None
            x, _ = jax.lax.scan(inner, x, None, length=Li)
            return x, None
        y, _ = jax.lax.scan(outer, x, w)
        return jnp.sum(y)

    w = jax.ShapeDtypeStruct((Lo, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((8, D), jnp.float32)
    txt = jax.jit(f).lower(w, x).compile().as_text()
    st = analyze(txt)
    expect = 2 * 8 * D * D * Lo * Li
    assert abs(st.flops - expect) / expect < 0.1, (st.flops, expect)
