"""repro.tune test suite: adaptive termination, planning, policies.

Pins the subsystem's contracts (DESIGN.md §8):

* **FixedSchedule bit-equality** — the default policy resolves to
  exactly today's ``search_batch_fixed`` dispatch, bit for bit, through
  the planner and through the service;
* **C2-only / early-exit invisibility** — with C1 off, the adaptive
  while_loop path (and its batch-wide early exit) is bit-equal to the
  unrolled fixed schedule on every engine: the done masks freeze
  terminated queries' state, so adaptivity can only skip work;
* **C2 certification property** (hypothesis-style) — whenever the
  adaptive path terminates via C2 at radius r_i, the returned k-th best
  is ≤ c·r_i and the returned top-1 is within c²·r_i of the true NN
  (brute-force oracle), across the engine matrix × schedule lengths;
* **C1 candidate budget** — a tight budget terminates earlier than the
  fixed schedule, monotonically in the budget;
* **planner** — calibration-table monotonicity, RecallTarget minimality,
  LatencyBudget's measured-table requirement, uncalibrated fallbacks;
* **policy resolution** — request > collection > service, mirroring the
  engine-default resolution;
* **persistence** — search_policy + calibration survive
  snapshot/restore;
* **service integration** — recall_target routing, the per-query
  termination-step histogram in ``svc.stats()``, quantized cache keys
  (near-duplicate hits, version invalidation unchanged);
* **sharded parity** — per-shard termination on a 1-shard mesh equals
  the local adaptive path exactly.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import (
    DBLSHParams,
    Termination,
    build,
    search_batch_fixed,
)
from repro.core.distributed import build_sharded, search_sharded
from repro.data import make_clustered, normalize_scale
from repro.store import Collection, StoreService
from repro.store.cache import QueryResultCache
from repro.tune import (
    FixedSchedule,
    LatencyBudget,
    RecallTarget,
    ResolvedPlan,
    ScheduleTable,
    calibrate,
    certified_c2_mask,
    plan,
    resolve_policy,
    search_batch_adaptive,
    termination_step_histogram,
)

ENGINES = os.environ.get(
    "REPRO_STORE_TEST_ENGINES", "jnp kernel inline"
).replace(",", " ").split()

K_TEST = 8


@pytest.fixture(scope="module")
def setup():
    key = jax.random.key(31)
    kd, kb = jax.random.split(key)
    allpts = make_clustered(kd, 2096, 24, n_clusters=12, spread=0.02)
    data, queries = allpts[:2048], allpts[2048:]
    data, queries, _ = normalize_scale(data, queries)
    params = DBLSHParams.derive(
        n=2048, d=24, c=1.5, t=48, k=10, K=8, L=3,
        inline_vectors=True, max_blocks=16,
    )
    index = build(kb, data, params)
    return np.asarray(data), jnp.asarray(queries), index


def _bit_equal(a, b):
    da, ia = map(np.asarray, a[:2])
    db, ib = map(np.asarray, b[:2])
    np.testing.assert_array_equal(da, db)
    np.testing.assert_array_equal(ia, ib)


# ------------------------------------------------------------- adaptive core
@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("steps", [1, 4, 8])
def test_c2_only_adaptive_bit_equal_to_fixed(setup, engine, steps):
    """With C1 off, the while_loop adaptive path (early exit included)
    is bit-equal to the unrolled fixed schedule: C2's done mask is the
    same rule the fixed path already applies, and frozen state makes the
    early exit result-invisible."""
    data, queries, index = setup
    fixed = search_batch_fixed(
        index, queries, k=K_TEST, r0=0.3, steps=steps, engine=engine,
        interpret=True, exact=True, with_stats=True,
    )
    for early in (False, True):
        adaptive = search_batch_fixed(
            index, queries, k=K_TEST, r0=0.3, steps=steps, engine=engine,
            interpret=True, exact=True, with_stats=True,
            termination=Termination(use_c1=False, early_exit=early),
        )
        _bit_equal(fixed, adaptive)
        for key_ in ("radius_steps", "candidates"):
            np.testing.assert_array_equal(
                np.asarray(fixed[2][key_]), np.asarray(adaptive[2][key_])
            )


@pytest.mark.parametrize("engine", ENGINES)
def test_c2_certification_property(setup, engine):
    """Whenever the adaptive path terminates via C2 at radius r_i, the
    returned k-th best distance is ≤ c·r_i (the certificate) and the
    returned top-1 is within c²·r_i of the true NN (brute-force oracle),
    for every engine and schedule length."""
    data, queries, index = setup
    c = index.params.c
    # float64 diff-form oracle: core.brute_force uses the norm form,
    # whose cancellation floor at this coordinate scale exceeds the
    # bound slack the property checks
    X = np.asarray(data, np.float64)
    Qm = np.asarray(queries, np.float64)
    nn = np.sqrt(
        ((Qm[:, None, :] - X[None, :, :]) ** 2).sum(-1).min(axis=1)
    )

    checked = 0
    for steps in (4, 8, 12):
        for r0 in (0.1, 0.3):
            # exact=True: the property compares absolute distances to a
            # brute-force oracle, which sits below the norm-form fp
            # cancellation floor (DESIGN.md §7)
            d, i, stats = search_batch_adaptive(
                index, queries, k=K_TEST, r0=r0, steps=steps, engine=engine,
                interpret=True, exact=True,
                termination=Termination(use_c1=False),
            )
            d = np.asarray(d)
            mask = certified_c2_mask(
                d, stats, r0=r0, c=c, k=K_TEST, steps=steps
            )
            rs = np.asarray(stats["radius_steps"])
            r_i = r0 * np.power(c, np.maximum(rs, 1) - 1)
            tol = 1e-5
            for q in np.flatnonzero(mask):
                checked += 1
                assert d[q, K_TEST - 1] <= c * r_i[q] * (1 + tol)
                assert d[q, 0] - nn[q] <= c * c * r_i[q] * (1 + tol)
                # the certificate also bounds the answer against the
                # oracle directly: top-1 ≤ c·r_i and the true NN can
                # only be better
                assert d[q, 0] + tol >= nn[q] - tol
    assert checked > 0  # the property must actually have been exercised


@given(c1_budget=st.integers(16, 256))
@settings(deadline=None, max_examples=8)
def test_c1_budget_terminates_earlier(setup, c1_budget):
    """C1 is monotone: a candidate-count budget can only terminate
    queries no later than the fixed schedule, and per-query verified
    work / termination steps shrink monotonically as the budget
    tightens."""
    data, queries, index = setup
    fixed = search_batch_fixed(
        index, queries, k=K_TEST, r0=0.1, steps=10, with_stats=True,
    )
    adaptive = search_batch_fixed(
        index, queries, k=K_TEST, r0=0.1, steps=10, with_stats=True,
        termination=Termination(c1_budget=int(c1_budget)),
    )
    rs_f = np.asarray(fixed[2]["radius_steps"])
    rs_a = np.asarray(adaptive[2]["radius_steps"])
    assert (rs_a <= rs_f).all()
    assert (
        np.asarray(adaptive[2]["candidates"])
        <= np.asarray(fixed[2]["candidates"])
    ).all()


def test_termination_step_histogram(setup):
    data, queries, index = setup
    _, _, stats = search_batch_adaptive(
        index, queries, k=K_TEST, r0=0.1, steps=10,
    )
    hist = termination_step_histogram(stats, 10)
    assert hist.sum() == queries.shape[0]
    rs = np.asarray(stats["radius_steps"])
    assert hist[rs[0]] >= 1


# ------------------------------------------------------------------- planner
def test_calibration_table_shape_and_monotonicity(setup):
    data, queries, index = setup
    table = calibrate(index, queries[:16], k=K_TEST, steps_max=6)
    assert table.max_steps == 6
    assert table.c == index.params.c
    # windows nest: longer schedules only add candidates, so expected
    # recall and verified-slot cost are non-decreasing in steps
    assert all(
        b >= a - 1e-9 for a, b in zip(table.recall, table.recall[1:])
    )
    assert all(
        b >= a - 1e-9 for a, b in zip(table.cost_slots, table.cost_slots[1:])
    )


def test_recall_target_planning(setup):
    data, queries, index = setup
    table = calibrate(index, queries[:16], k=K_TEST, steps_max=8)
    achievable = max(table.recall)
    target = min(0.8, achievable)
    p = plan(table, RecallTarget(target))
    # minimal: meets the target, and one step fewer would miss it
    assert table.recall[p.steps - 1] >= target
    if p.steps > 1:
        assert table.recall[p.steps - 2] < target
    assert p.r0 == table.r0
    assert p.termination == Termination()
    # an unreachable target degrades to the best the table achieved,
    # capped by max_steps
    p_hi = plan(table, RecallTarget(2.0, max_steps=5))
    assert p_hi.steps == 5


def test_fixed_schedule_and_fallback_planning():
    p = plan(None, FixedSchedule(), default_r0=0.7, default_steps=6)
    assert p == ResolvedPlan(r0=0.7, steps=6, termination=None)
    p2 = plan(None, FixedSchedule(r0=0.2, steps=3))
    assert (p2.r0, p2.steps) == (0.2, 3)
    # RecallTarget without calibration: full default schedule + adaptive
    p3 = plan(None, RecallTarget(0.9), default_r0=0.7, default_steps=6)
    assert (p3.r0, p3.steps) == (0.7, 6)
    assert p3.termination is not None
    # ...still capped by the policy's max_steps latency guard
    assert plan(None, RecallTarget(0.9, max_steps=2),
                default_steps=8).steps == 2
    # LatencyBudget refuses to plan without measured milliseconds
    with pytest.raises(ValueError):
        plan(None, LatencyBudget(1.0))
    with pytest.raises(ValueError):
        plan(
            ScheduleTable(
                r0=0.5, c=1.5, k=8, recall=(1.0,), cost_slots=(10.0,),
                cost_ms=(float("nan"),), n_sample=4,
            ),
            LatencyBudget(1.0),
        )


def test_latency_budget_planning():
    table = ScheduleTable(
        r0=0.5, c=1.5, k=8,
        recall=(0.5, 0.8, 0.9, 0.95),
        cost_slots=(100.0, 200.0, 300.0, 400.0),
        cost_ms=(0.2, 0.5, 1.1, 2.4),
        n_sample=8,
    )
    assert plan(table, LatencyBudget(1.2)).steps == 3
    assert plan(table, LatencyBudget(0.1)).steps == 1   # floor: always search
    assert plan(table, LatencyBudget(10.0)).steps == 4
    assert plan(table, LatencyBudget(10.0, max_steps=2)).steps == 2


def test_policy_resolution_order():
    assert resolve_policy(None, None, None) is None
    svc_p = RecallTarget(0.5)
    col_p = FixedSchedule(steps=2)
    req_p = FixedSchedule(steps=3)
    assert resolve_policy(None, None, svc_p) is svc_p
    assert resolve_policy(None, col_p, svc_p) is col_p
    assert resolve_policy(req_p, col_p, svc_p) is req_p


# ------------------------------------------------- store / service integration
@pytest.fixture(scope="module")
def col(setup):
    data, queries, index = setup
    return Collection.from_index("tune", index, key=jax.random.key(5))


def test_fixed_schedule_policy_bit_equal_to_plain_dispatch(setup, col):
    """The satellite pin: FixedSchedule through the whole service stack
    (submit -> plan -> padded batch dispatch) returns bit-identical
    results to today's plain ``search_batch_fixed``."""
    data, queries, index = setup
    svc = StoreService(
        batch_shapes=(1, 4, 16), default_k=K_TEST, r0=0.3, steps=6,
        cache_size=0, inflight_depth=0,
    )
    svc.attach(col)
    Q = np.asarray(queries)[:16]
    d_plain, i_plain = search_batch_fixed(
        index, jnp.asarray(Q), k=K_TEST, r0=0.3, steps=6
    )
    d_pol, i_pol, reqs = svc.serve("tune", Q, policy=FixedSchedule())
    np.testing.assert_array_equal(np.asarray(d_plain), d_pol)
    np.testing.assert_array_equal(np.asarray(i_plain), i_pol)
    assert all(r.plan.termination is None for r in reqs)
    # ...and with no policy anywhere, the resolved plan is the same
    d_def, i_def, _ = svc.serve("tune", Q)
    np.testing.assert_array_equal(d_pol, d_def)
    np.testing.assert_array_equal(i_pol, i_def)


def test_service_recall_target_routes_through_planner(setup, col):
    data, queries, index = setup
    col.calibrate(queries[:16], k=K_TEST, steps_max=8)
    svc = StoreService(
        batch_shapes=(1, 4, 16), default_k=K_TEST, r0=0.3, steps=8,
        cache_size=0,
    )
    svc.attach(col)
    target = min(0.8, max(col.calibration.recall))
    expected = plan(col.calibration, RecallTarget(target))
    t = svc.submit("tune", np.asarray(queries[0]), recall_target=target)
    svc.flush()
    assert t.done
    assert t.plan == expected
    assert t.plan.r0 == col.calibration.r0
    assert 1 <= t.radius_steps <= t.plan.steps
    st_ = svc.stats("tune")
    hist = st_["termination_steps_hist"]
    assert sum(hist.values()) == st_["queries"]
    assert hist.get(t.radius_steps) >= 1
    with pytest.raises(ValueError):
        svc.submit("tune", np.asarray(queries[0]), recall_target=0.9,
                   policy=FixedSchedule())


def test_collection_policy_beats_service_default(setup):
    data, queries, index = setup
    c2 = Collection.from_index("c2", index, key=jax.random.key(6))
    c2.search_policy = FixedSchedule(steps=2)
    svc = StoreService(
        batch_shapes=(1, 4), default_k=K_TEST, r0=0.3, steps=8,
        cache_size=0, default_policy=FixedSchedule(steps=5),
    )
    svc.attach(c2)
    # collection policy wins over the service default...
    assert svc.resolve_plan("c2").steps == 2
    # ...and an explicit request policy wins over both
    assert svc.resolve_plan("c2", FixedSchedule(steps=3)).steps == 3
    t = svc.submit("c2", np.asarray(queries[0]))
    svc.flush()
    assert t.plan.steps == 2 and t.radius_steps <= 2


def test_search_policy_and_calibration_snapshot_roundtrip(setup, tmp_path):
    data, queries, index = setup
    c3 = Collection.from_index("c3", index, key=jax.random.key(7))
    c3.search_policy = RecallTarget(0.8, max_steps=9)
    table = c3.calibrate(queries[:12], k=K_TEST, steps_max=5)
    c3.snapshot(str(tmp_path))
    r = Collection.restore(str(tmp_path))
    assert r.search_policy == c3.search_policy
    assert r.calibration.r0 == table.r0
    assert r.calibration.recall == table.recall
    assert r.calibration.cost_slots == table.cost_slots
    # NaN-aware: unmeasured cost_ms round-trips as NaN
    np.testing.assert_array_equal(
        np.isnan(r.calibration.cost_ms), np.isnan(table.cost_ms)
    )
    # the restored table plans identically
    assert plan(r.calibration, r.search_policy) == plan(
        table, c3.search_policy
    )


def test_quantized_cache_keys(setup):
    """Satellite pin: opt-in eps-bucketing widens hits to near-duplicate
    queries; version invalidation semantics are untouched."""
    data, queries, index = setup
    cache = QueryResultCache(capacity=16, quantize_eps=1e-3)
    # align the probe query to eps-cell anchors so the ±1e-5 perturbation
    # below deterministically stays inside the cell
    q = (np.round(np.asarray(queries[0]) / 1e-3) * 1e-3).astype(np.float32)
    k1 = cache.key("a", 1, q, 8, "jnp", 0.5, 6)
    k2 = cache.key("a", 1, q + 1e-5, 8, "jnp", 0.5, 6)
    assert k1 == k2                       # same eps cell -> same key
    far = cache.key("a", 1, q + 1.0, 8, "jnp", 0.5, 6)
    assert far != k1
    assert cache.key("a", 2, q, 8, "jnp", 0.5, 6) != k1  # version differs
    # default (exact) keys still require bit-equality
    exact = QueryResultCache(capacity=16)
    assert exact.key("a", 1, q, 8, "jnp", 0.5, 6) != exact.key(
        "a", 1, q + 1e-5, 8, "jnp", 0.5, 6
    )
    # termination joins the key: a planned adaptive result must never be
    # served for a fixed-schedule request
    assert cache.key("a", 1, q, 8, "jnp", 0.5, 6, Termination()) != k1

    # service level: near-duplicate hit, then invalidation on mutation
    col = Collection.create(
        "qc", jax.random.key(9), data[:512], c=1.5, t=24, k=8, K=6, L=2,
    )
    svc = StoreService(
        batch_shapes=(1, 4), default_k=K_TEST, r0=0.3, steps=4,
        cache_quantize_eps=1e-3,
    )
    svc.attach(col)
    t0 = svc.submit("qc", q)
    svc.flush()
    t1 = svc.submit("qc", q + 1e-5)
    svc.flush()
    assert t1.cached
    np.testing.assert_array_equal(t0.ids, t1.ids)
    col.add(np.asarray(queries[1])[None, :])
    t2 = svc.submit("qc", q)
    svc.flush()
    assert not t2.cached


def test_sharded_termination_parity(setup):
    """Per-shard termination on a 1-shard mesh equals the local adaptive
    path exactly (the n-shard argument is monotonicity: a shard's local
    k-th ≥ the global k-th, so local C2 only fires later)."""
    data, queries, index = setup
    mesh = jax.make_mesh((1,), ("data",))
    params = index.params
    # identical hash functions on both sides: build local + sharded from
    # the same key (the fixture's index used a different split)
    kb = jax.random.key(77)
    local = build(kb, jnp.asarray(data), params)
    sharded = build_sharded(kb, jnp.asarray(data), params, mesh)
    term = Termination(c1_budget=64)
    ds, is_, ss = search_sharded(
        sharded, queries, k=K_TEST, r0=0.2, steps=6, mesh=mesh,
        with_stats=True, termination=term,
    )
    dl, il, sl = search_batch_fixed(
        local, queries, k=K_TEST, r0=0.2, steps=6, with_stats=True,
        termination=term,
    )
    np.testing.assert_array_equal(np.asarray(is_), np.asarray(il))
    np.testing.assert_array_equal(
        np.asarray(ss["radius_steps"]), np.asarray(sl["radius_steps"])
    )
    np.testing.assert_array_equal(
        np.asarray(ss["candidates"]), np.asarray(sl["candidates"])
    )
