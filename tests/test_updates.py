"""Incremental index maintenance: insert / delete / compact invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import DBLSHParams, brute_force, build, search_batch_fixed
from repro.core.updates import compact, delete, insert, live_count
from repro.data import make_clustered, normalize_scale


@pytest.fixture(scope="module")
def setup():
    kd, kb = jax.random.split(jax.random.key(21))
    allpts = make_clustered(kd, 3096, 24, n_clusters=12, spread=0.02)
    data, extra, queries = allpts[:2000], allpts[2000:3064], allpts[3064:]
    data, queries, scale = normalize_scale(data, queries)
    extra = extra * scale
    params = DBLSHParams.derive(n=2000, d=24, c=1.5, t=48, k=10, K=8, L=3)
    index = build(kb, data, params)
    return data, extra, queries, index


def _recall(index, data, queries, k=10):
    _, ids = search_batch_fixed(index, queries, k=k, r0=0.5, steps=8)
    _, gt = brute_force(data, queries, k=k)
    return np.mean(
        [len(set(a.tolist()) & set(b.tolist())) / k
         for a, b in zip(np.asarray(ids), np.asarray(gt))]
    )


def test_insert_points_found(setup):
    data, extra, queries, index = setup
    idx2 = insert(index, extra)
    assert idx2.n == 2000 + extra.shape[0]
    full = jnp.concatenate([data, extra])
    rec = _recall(idx2, full, queries)
    assert rec > 0.6, rec
    # query placed exactly on an inserted point must return it; the
    # self-distance check needs exact=True — the MXU norm form's
    # ||x||^2 - 2<q,x> + ||q||^2 cancellation floor is O(eps * ||x||^2),
    # far above 1e-3 at this coordinate scale (DESIGN.md §7)
    q = extra[7:8]
    d, i = search_batch_fixed(idx2, q, k=1, r0=0.25, steps=8)
    assert int(i[0, 0]) == 2000 + 7
    d, i = search_batch_fixed(idx2, q, k=1, r0=0.25, steps=8, exact=True)
    assert int(i[0, 0]) == 2000 + 7
    assert float(d[0, 0]) < 1e-3


def test_insert_preserves_old_points(setup):
    data, extra, queries, index = setup
    idx2 = insert(index, extra)
    rec_old = _recall(index, data, queries)
    # recall against the OLD ground truth barely moves (new points can
    # legitimately enter true top-k; compare on old-gt membership)
    _, ids2 = search_batch_fixed(idx2, queries, k=10, r0=0.5, steps=8)
    _, gt_old = brute_force(data, queries, k=10)
    # every old-gt point that idx2 misses must be displaced by a closer new point
    full = jnp.concatenate([data, extra])
    d_full, _ = brute_force(full, queries, k=10)
    rec2 = _recall(idx2, full, queries)
    assert rec2 >= rec_old - 0.15


def test_delete_never_returned(setup):
    data, extra, queries, index = setup
    _, gt = brute_force(data, queries, k=5)
    victims = jnp.unique(gt.reshape(-1))[:50]  # delete many true NNs
    idx2 = delete(index, victims)
    assert live_count(idx2) == 2000 - int(victims.shape[0])
    _, ids = search_batch_fixed(idx2, queries, k=10, r0=0.5, steps=8)
    bad = set(np.asarray(victims).tolist()) & set(np.asarray(ids).reshape(-1).tolist())
    assert not bad, bad


def test_compact_after_delete(setup):
    data, extra, queries, index = setup
    victims = jnp.arange(0, 500, dtype=jnp.int32)
    idx2 = delete(index, victims)
    idx3, id_map = compact(idx2, jax.random.key(5))
    assert idx3.n == 1500
    assert int(jnp.sum(id_map >= 0)) == 1500
    assert np.all(np.asarray(id_map[:500]) == -1)
    # surviving data rows preserved under the id map
    survivors = np.asarray(id_map[500:])
    np.testing.assert_allclose(
        np.asarray(idx3.data)[survivors], np.asarray(data)[500:], rtol=1e-6
    )
    # search works and never returns pre-compact ids >= 1500
    _, ids = search_batch_fixed(idx3, queries, k=5, r0=0.5, steps=8)
    assert np.asarray(ids).max() <= 1500


@given(seed=st.integers(0, 10_000))
@settings(deadline=None, max_examples=4)
def test_update_roundtrip_vs_brute_force(setup, seed):
    """Property: insert -> delete -> compact round-trips against a
    brute-force scan of the surviving point set — deleted ids are never
    returned, surviving inserted points stay findable under the id map,
    and live_count tracks every transition."""
    data, extra, queries, index = setup
    rng = np.random.default_rng(seed)
    n0 = 2000
    m = int(rng.integers(16, 96))
    ins = extra[:m]
    n_tot = n0 + m

    idx2 = insert(index, ins)
    assert live_count(idx2) == n_tot

    n_del = int(rng.integers(10, 200))
    del_ids = rng.choice(n_tot, size=n_del, replace=False).astype(np.int32)
    idx3 = delete(idx2, jnp.asarray(del_ids))
    assert live_count(idx3) == n_tot - n_del

    # deleted ids can never be returned, even pre-compaction
    _, ids = search_batch_fixed(idx3, queries, k=10, r0=0.5, steps=8)
    leaked = set(del_ids.tolist()) & set(np.asarray(ids).reshape(-1).tolist())
    assert not leaked, leaked

    idx4, id_map = compact(idx3, jax.random.key(seed))
    id_map = np.asarray(id_map)
    assert idx4.n == n_tot - n_del
    assert live_count(idx4) == idx4.n

    # the compacted data is exactly the brute-force surviving scan
    full = np.concatenate([np.asarray(data), np.asarray(ins)])
    live_mask = np.ones(n_tot, bool)
    live_mask[del_ids] = False
    np.testing.assert_allclose(
        np.asarray(idx4.data), full[live_mask], rtol=1e-6
    )
    assert np.all(id_map[~live_mask] == -1)
    assert np.array_equal(np.sort(id_map[live_mask]), np.arange(idx4.n))

    # a surviving inserted point is findable at its remapped id
    surviving_ins = np.flatnonzero(live_mask[n0:]) + n0
    if surviving_ins.size:
        old_id = int(surviving_ins[0])
        d, i2 = search_batch_fixed(
            idx4, jnp.asarray(full[old_id][None]), k=1, r0=0.25, steps=8,
            exact=True,  # self-distance sits below the norm-form fp floor
        )
        assert int(i2[0, 0]) == int(id_map[old_id])
        assert float(d[0, 0]) < 1e-3


@given(m=st.integers(1, 130))
@settings(deadline=None, max_examples=8)
def test_insert_partition_invariant(setup, m):
    """Every id 0..n+m-1 appears exactly once per table after insert."""
    data, extra, queries, index = setup
    idx2 = insert(index, extra[:m])
    n_total = 2000 + m
    ids = np.asarray(idx2.ids_blocks[0]).reshape(-1)
    real = ids[ids < n_total]
    assert sorted(real.tolist()) == list(range(n_total))
