"""Multi-device integration tests (8 forced host devices, subprocess —
the main test process must keep the real device count)."""

import os
import subprocess
import sys

import jax
import pytest

# jax 0.4.x lowers axis_index over a partial-manual shard_map axis to a
# PartitionId instruction its SPMD partitioner rejects; the PP schedule
# needs exactly that (stage = axis_index('pod')). Fixed upstream in the
# jax versions that ship jax.shard_map.
_OLD_JAX = not hasattr(jax, "shard_map")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT_SHARDED_ANN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import DBLSHParams, brute_force, build, search_batch_fixed
from repro.core.distributed import build_sharded, search_sharded
from repro.data import make_clustered, normalize_scale

mesh = jax.make_mesh((8,), ("data",))  # axis_types default to Auto
key = jax.random.key(3)
kd, kb = jax.random.split(key)
allpts = make_clustered(kd, 4128, 24, n_clusters=16, spread=0.02)
data, queries = allpts[:4096], allpts[4096:]
data, queries, _ = normalize_scale(data, queries)

params = DBLSHParams.derive(n=4096, d=24, c=1.5, t=48, k=10, K=8, L=3)
sh = build_sharded(kb, data, params, mesh, axis="data")
d_s, i_s = search_sharded(sh, queries, k=10, r0=0.5, steps=8, mesh=mesh)
d_s, i_s = np.asarray(d_s), np.asarray(i_s)

# ground truth + validity
gd, gi = map(np.asarray, brute_force(data, queries, k=10))
rec = np.mean([len(set(a) & set(b)) / 10 for a, b in zip(i_s, gi)])
assert rec > 0.6, f"sharded recall {rec}"
dn = np.asarray(data)
for q in range(queries.shape[0]):
    fin = np.isfinite(d_s[q])
    ids = i_s[q][fin]
    assert (ids < 4096).all()
    real = np.linalg.norm(dn[ids] - np.asarray(queries[q]), axis=-1)
    np.testing.assert_allclose(d_s[q][fin], real, rtol=3e-3, atol=3e-3)

# per-shard probe stats survive the collective merge: candidates is the
# psum over the 8 shards, radius_steps the pmax — both real per query
d_s2, i_s2, st = search_sharded(sh, queries, k=10, r0=0.5, steps=8,
                                mesh=mesh, with_stats=True)
np.testing.assert_array_equal(np.asarray(i_s2), i_s)
cand = np.asarray(st["candidates"]); steps_t = np.asarray(st["radius_steps"])
assert cand.shape == steps_t.shape == (queries.shape[0],)
assert (cand > 0).all(), "per-shard candidate counts dropped at the merge"
assert ((steps_t >= 1) & (steps_t <= 8)).all()
print("SHARDED_ANN_OK", rec)
"""

SCRIPT_SHARDED_LIFECYCLE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import DBLSHParams, brute_force
from repro.data import make_clustered, normalize_scale
from repro.store import (ShardedCollection, CompactionPolicy, StoreService,
                         open_collection, restore_collection)

mesh = jax.make_mesh((8,), ("data",))
key = jax.random.key(3)
kd, kb = jax.random.split(key)
allpts = make_clustered(kd, 4288, 24, n_clusters=16, spread=0.02)
data, extra, queries = allpts[:4096], allpts[4096:4256], allpts[4256:]
data, queries, scale = normalize_scale(data, queries)
extra = np.asarray(extra * scale)
data, queries = np.asarray(data), np.asarray(queries)

params = DBLSHParams.derive(n=512, d=24, c=1.5, t=48, k=10, K=8, L=3)
col = ShardedCollection.create("fleet", kb, data, mesh, params=params,
                               payload=np.arange(4096),
                               policy=CompactionPolicy(auto=False))
assert col.n == 4096 and col.live_count() == 4096
np.testing.assert_array_equal(col.shard_counts(), np.full(8, 512))

# open_collection routes sharded and no longer drops lifecycle options
oc = open_collection("routed", kb, data, mesh=mesh, max_points_per_shard=1024,
                     params=params,
                     policy=CompactionPolicy(growth_ratio=7.7, auto=False))
assert isinstance(oc, ShardedCollection) and oc.policy.growth_ratio == 7.7
del oc

# strided id space: stride carries insert headroom over n_local
assert col.sharded.stride == 1024 and col.id_space == 8192

# add: routed to the least-loaded shard; ids land in the target's
# stride headroom and are STABLE — later adds never re-base them
ids1 = col.add(extra[:40], payload=np.arange(4096, 4136))
assert ids1.dtype == np.int32
c1 = col.shard_counts()
assert c1.sum() == 4136 and c1.max() - c1.min() == 40, c1
q = extra[7:8]
d, i = col.search(q, k=1, r0=0.25, steps=8, exact=True)
assert float(d[0, 0]) < 1e-3, float(d[0, 0])
assert int(np.asarray(col.get_payload(i))[0, 0]) == 4096 + 7
assert int(i[0, 0]) == int(ids1[7])  # returned ids are current global ids
ids2 = col.add(extra[40:80], payload=np.arange(4136, 4176))
c2 = col.shard_counts()  # second batch lands on a different shard
assert c2.sum() == 4176 and c2.max() - c2.min() == 40, c2
assert len(set(ids1.tolist()) & set(ids2.tolist())) == 0

# id stability across >= 3 subsequent adds: the held handles from the
# first batch keep resolving with NO remap (stats.compactions == 0)
ids3 = col.add(extra[80:100], payload=np.arange(4176, 4196))
d, i = col.search(q, k=1, r0=0.25, steps=8, exact=True)
assert int(i[0, 0]) == int(ids1[7])  # three adds later, same handle
assert col.stats.compactions == 0
np.testing.assert_array_equal(
    np.asarray(col.get_payload(ids1[None]))[0], np.arange(4096, 4136))
# held ids remove cleanly: tombstone the third batch by its handles
col.remove(ids3)
d_h, i_h = map(np.asarray, col.search(extra[80:100], k=5, r0=0.5, steps=8))
leaked = set(ids3.tolist()) & set(
    i_h[np.isfinite(d_h)].reshape(-1).tolist())
assert not leaked, leaked

# remove by current global ids: tombstoned ids never return
d_s, i_s = map(np.asarray, col.search(queries, k=10, r0=0.5, steps=8))
victims = np.unique(i_s[np.isfinite(d_s)])[:64].astype(np.int32)
victim_tags = np.asarray(col.get_payload(victims[None]))[0]
col.remove(victims)
assert col.live_count() == 4176 - len(victims)
d_s2, i_s2 = map(np.asarray, col.search(queries, k=10, r0=0.5, steps=8))
leaked = set(victims.tolist()) & set(
    i_s2[np.isfinite(d_s2)].reshape(-1).tolist())
assert not leaked, leaked

# compact: REBALANCING rebuild + gathered global id remap over the old
# strided space; id-set parity vs brute force on the post-mutation
# point set, matched via payload tags (compaction is the one event
# that renumbers, so tags carry identity across it)
space_old = col.id_space
id_map = col.compact()
assert col.stats.compactions == 1
assert id_map.shape == (space_old,)
assert int((id_map >= 0).sum()) == col.live_count() == 4176 - len(victims)
cb = col.shard_counts()  # survivors migrated toward the emptiest shards
assert cb.max() - cb.min() <= 1, cb
assert cb.max() <= 1.25 * max(cb.min(), 1), cb
all_pts = np.concatenate([data, extra[:80]])
alive = np.ones(4176, bool)
alive[victim_tags.astype(int)] = False
alive_tags = np.flatnonzero(alive)
gd, gi = map(np.asarray, brute_force(jnp.asarray(all_pts[alive_tags]),
                                     jnp.asarray(queries), k=10))
d_s3, i_s3 = map(np.asarray, col.search(queries, k=10, r0=0.5, steps=8))
tags3 = np.asarray(col.get_payload(i_s3)).astype(int)  # one batched take
recs = []
for qi in range(queries.shape[0]):
    f = np.isfinite(d_s3[qi])
    got_tags = tags3[qi][f]
    want_tags = alive_tags[gi[qi]]
    recs.append(len(set(got_tags.tolist()) & set(want_tags.tolist())) / 10)
    true_d = np.linalg.norm(all_pts[got_tags] - queries[qi], axis=-1)
    np.testing.assert_allclose(d_s3[qi][f], true_d, rtol=3e-3, atol=3e-3)
rec = float(np.mean(recs))
assert rec > 0.6, rec

# snapshot / restore on the same mesh: bit-equal, fresh version
import tempfile
tmp = tempfile.mkdtemp()
col.calibrate(queries[:16], k=10)
step = col.snapshot(tmp)
col2 = restore_collection(tmp, step, mesh=mesh)
assert col2.version > col.version and col2.calibration is not None
assert col2.policy == col.policy
d_a, i_a = map(np.asarray, col.search(queries, k=10, r0=0.5, steps=8))
d_b, i_b = map(np.asarray, col2.search(queries, k=10, r0=0.5, steps=8))
np.testing.assert_array_equal(i_a, i_b)
np.testing.assert_array_equal(np.asarray(col.payload), np.asarray(col2.payload))

# elastic restore: the same snapshot placed on HALF the shards — live
# rows re-partition balanced over the new fleet, ids renumber, fitted
# calibration drops, and identity carries through the payload tags
mesh4 = jax.make_mesh((4,), ("data",))
col4 = restore_collection(tmp, step, mesh=mesh4)
n_live = col.live_count()
assert col4.live_count() == n_live and col4.n == n_live
assert col4.calibration is None and col4.version > col.version
c4 = col4.shard_counts()
assert c4.shape == (4,) and c4.max() - c4.min() <= 1, c4
d_e, i_e = map(np.asarray, col4.search(queries, k=10, r0=0.5, steps=8))
tags_e = np.asarray(col4.get_payload(i_e)).astype(int)
recs_e = []
for qi in range(queries.shape[0]):
    f = np.isfinite(d_e[qi])
    want_tags = alive_tags[gi[qi]]
    recs_e.append(
        len(set(tags_e[qi][f].tolist()) & set(want_tags.tolist())) / 10)
rec_e = float(np.mean(recs_e))
assert rec_e > 0.6, rec_e
del col4
# migrate=False demands the bit-identical path: shard-count change raises
try:
    ShardedCollection.restore(tmp, mesh=mesh4, step=step, migrate=False)
    raise SystemExit("migrate=False re-shard restore should have failed")
except ValueError:
    pass

# rebalancing compaction keeps the fleet dense: an imbalance-inducing
# add is spread back over all shards by the next compact, so the policy
# goes quiet (live == n) and a second rebuild changes nothing
small = ShardedCollection.create(
    "storm", kb, data[:1024], mesh,
    params=DBLSHParams.derive(n=128, d=24, c=1.5, t=16, k=5),
    policy=CompactionPolicy(min_live_ratio=0.95, auto=False))
small.add(extra[:120])  # one shard takes the whole batch -> imbalance
small.compact()
n_after = small.n
assert small.live_count() == small.n == 1144  # rebalanced: no hollowness
cs = small.shard_counts()
assert cs.max() - cs.min() <= 1, cs
assert not small.should_compact()
small.compact()
assert small.n == n_after

# the service serves + invalidates sharded mutations via the shared clock
svc = StoreService(batch_shapes=(8,), default_k=10, r0=0.5, steps=8,
                   cache_size=64)
svc.attach(col)
r1 = [svc.submit("fleet", qq) for qq in queries[:8]]; svc.flush()
r2 = [svc.submit("fleet", qq) for qq in queries[:8]]; svc.flush()
assert all(r.cached for r in r2)
col.add(extra[80:88], payload=np.arange(4176, 4184))
r3 = [svc.submit("fleet", qq) for qq in queries[:8]]; svc.flush()
assert not any(r.cached for r in r3)
assert all(r.engine == "jnp" for r in r3)  # fixed_engine pins resolution
print("SHARDED_LIFECYCLE_OK", rec)
"""


SCRIPT_SHARDED_EXPLAIN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.core import DBLSHParams
from repro.core.distributed import build_sharded, search_sharded
from repro.data import make_clustered, normalize_scale
from repro.obs import Observability
from repro.store import ShardedCollection, StoreService

mesh = jax.make_mesh((8,), ("data",))
key = jax.random.key(7)
kd, kb = jax.random.split(key)
allpts = make_clustered(kd, 4120, 24, n_clusters=8, spread=0.02)
data, queries = allpts[:4096], allpts[4096:]
data, queries, _ = normalize_scale(data, queries)

params = DBLSHParams.derive(n=4096, d=24, c=1.5, t=48, k=8, K=8, L=3)
sh = build_sharded(kb, data, params, mesh, axis="data")

# explain-off bit-equality on the sharded path
base = search_sharded(sh, queries, k=8, r0=0.5, steps=6, mesh=mesh,
                      with_stats=True)
d, i, st, ex = search_sharded(sh, queries, k=8, r0=0.5, steps=6, mesh=mesh,
                              with_stats=True, with_explain=True)
np.testing.assert_array_equal(np.asarray(base[0]), np.asarray(d))
np.testing.assert_array_equal(np.asarray(base[1]), np.asarray(i))
np.testing.assert_array_equal(np.asarray(base[2]["radius_steps"]),
                              np.asarray(st["radius_steps"]))
np.testing.assert_array_equal(np.asarray(base[2]["candidates"]),
                              np.asarray(st["candidates"]))
# per-shard attribution pre-collapse: slots psum to the merged total,
# the critical-path shard's steps equal the pmax'd radius_steps
slots = np.asarray(ex["shard_slots"]); steps = np.asarray(ex["shard_steps"])
assert slots.shape[0] == steps.shape[0] == 8
np.testing.assert_array_equal(slots.sum(axis=0), np.asarray(st["candidates"]))
np.testing.assert_array_equal(steps.max(axis=0), np.asarray(st["radius_steps"]))
np.testing.assert_array_equal(np.asarray(ex["step_slots"]).sum(axis=1),
                              np.asarray(st["candidates"]))

# the service fills per-shard attribution into the ticket's record
col = ShardedCollection("shx", sh, mesh)
svc = StoreService(batch_shapes=(1, 4), max_wait_ms=1e9, default_k=8,
                   r0=0.5, steps=6, obs=Observability())
svc.attach(col)
t = svc.submit("shx", np.asarray(queries[0]), explain=True)
svc.flush()
assert t.done and t.error is None, t.error
e = t.explain
assert e.shard_steps is not None and len(e.shard_steps) == 8
assert max(e.shard_steps) == t.radius_steps == e.steps_run
assert sum(e.shard_slots) == t.candidates == sum(e.step_slots)
assert "shards:" in e.render()
print("SHARDED_EXPLAIN_OK")
"""


SCRIPT_TRAIN_PARITY = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, SHAPES
from repro.models.registry import build_model
from repro.sharding import rules
from repro.train import make_optimizer, make_train_step, init_train_state
from repro.train.optimizer import cosine_schedule
from repro.data.pipeline import SyntheticTokens, make_batch_fn

cfg = get_config("yi-9b").smoke().scaled(n_layers=2, sp_residual=True)
model = build_model(cfg)
opt = make_optimizer("adamw", cosine_schedule(1e-2, 2, 100))
src = SyntheticTokens(cfg.vocab_size, 16, 8, seed=4)
batch_fn = make_batch_fn(src)

# single-device reference
state0 = init_train_state(model, opt, jax.random.key(0))
step1 = jax.jit(make_train_step(model, opt))
s, losses_ref = state0, []
for t in range(4):
    s, m = step1(s, batch_fn(t))
    losses_ref.append(float(m["loss"]))

# 2x4 mesh (data x model) distributed run
mesh = jax.make_mesh((2, 4), ("data", "model"))  # Auto axes
with mesh:
    state_shapes = jax.eval_shape(lambda k: init_train_state(model, opt, k), jax.random.key(0))
    pspecs = rules.param_specs(state_shapes["params"], mesh, fsdp_min_size=1<<10)
    sspecs = rules.state_specs(state_shapes, pspecs, mesh)
    bspecs = rules.batch_specs(jax.eval_shape(lambda: batch_fn(0)), mesh)
    stepd = jax.jit(
        make_train_step(model, opt, mesh),
        in_shardings=(rules.named(mesh, sspecs), rules.named(mesh, bspecs)),
        out_shardings=(rules.named(mesh, sspecs), None),
    )
    s2 = jax.device_put(init_train_state(model, opt, jax.random.key(0)),
                        rules.named(mesh, sspecs))
    losses_d = []
    for t in range(4):
        s2, m = stepd(s2, batch_fn(t))
        losses_d.append(float(m["loss"]))

np.testing.assert_allclose(losses_ref, losses_d, rtol=2e-3, atol=2e-3)
print("TRAIN_PARITY_OK", losses_ref, losses_d)
"""

SCRIPT_MOE_PARITY = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models.registry import build_model

cfg = get_config("arctic-480b").smoke().scaled(n_layers=2)
model = build_model(cfg)
params = model.init(jax.random.key(0))
ks = jax.random.split(jax.random.key(1), 2)
batch = {
    "tokens": jax.random.randint(ks[0], (4, 16), 0, cfg.vocab_size),
    "labels": jax.random.randint(ks[1], (4, 16), 0, cfg.vocab_size),
}
loss_1dev = float(jax.jit(lambda p, b: model.loss(p, b)[0])(params, batch))

mesh = jax.make_mesh((2, 4), ("data", "model"))  # Auto axes
with mesh:
    loss_dist = float(
        jax.jit(lambda p, b: model.loss(p, b, mesh)[0])(params, batch)
    )
# shard_map EP (capacity per shard differs from the 1-dev path) may drop
# different tokens; losses must still agree closely at this tiny scale
np.testing.assert_allclose(loss_1dev, loss_dist, rtol=5e-2)
print("MOE_PARITY_OK", loss_1dev, loss_dist)
"""


def _run(script, tag):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True, text=True,
        timeout=600,
    )
    assert tag in proc.stdout, f"stdout:\n{proc.stdout[-2000:]}\nstderr:\n{proc.stderr[-4000:]}"


@pytest.mark.slow
def test_sharded_ann_8dev():
    _run(SCRIPT_SHARDED_ANN, "SHARDED_ANN_OK")


@pytest.mark.slow
def test_sharded_explain_8dev():
    """EXPLAIN on the sharded placement: with_explain is bit-equal off,
    per-shard attribution survives to the ticket's record."""
    _run(SCRIPT_SHARDED_EXPLAIN, "SHARDED_EXPLAIN_OK")


@pytest.mark.slow
def test_sharded_lifecycle_8dev():
    """The mutable sharded lifecycle at real shard count: least-loaded
    insert routing into stride headroom (ids stable across adds),
    global-id delete translation, rebalancing compaction with the
    gathered strided remap, payload integrity across the one renumber,
    snapshot/restore plus elastic re-shard onto a smaller mesh, and
    service cache invalidation."""
    _run(SCRIPT_SHARDED_LIFECYCLE, "SHARDED_LIFECYCLE_OK")


@pytest.mark.slow
def test_train_parity_8dev():
    _run(SCRIPT_TRAIN_PARITY, "TRAIN_PARITY_OK")


@pytest.mark.slow
def test_moe_ep_parity_8dev():
    _run(SCRIPT_MOE_PARITY, "MOE_PARITY_OK")


SCRIPT_PP_PARITY = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models.registry import build_model
from repro.sharding.pp import pp_loss_fn

cfg = get_config("yi-9b").smoke().scaled(n_layers=4)
model = build_model(cfg)
params = model.init(jax.random.key(0))
ks = jax.random.split(jax.random.key(1), 2)
batch = {
    "tokens": jax.random.randint(ks[0], (8, 16), 0, cfg.vocab_size),
    "labels": jax.random.randint(ks[1], (8, 16), 0, cfg.vocab_size),
}
ref = float(jax.jit(lambda p, b: model.loss(p, b)[0])(params, batch))

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))  # Auto axes
with mesh:
    pp = float(jax.jit(
        lambda p, b: pp_loss_fn(p, b, cfg, mesh, microbatches=4)
    )(params, batch))
np.testing.assert_allclose(ref, pp, rtol=2e-3)

# gradients flow through ppermute: grad wrt embed must match
g_ref = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))(params, batch)
with mesh:
    g_pp = jax.jit(jax.grad(lambda p, b: pp_loss_fn(p, b, cfg, mesh, microbatches=4)))(params, batch)
np.testing.assert_allclose(
    np.asarray(g_ref["embed"], np.float32),
    np.asarray(g_pp["embed"], np.float32), rtol=5e-2, atol=1e-4)
print("PP_PARITY_OK", ref, pp)
"""


@pytest.mark.slow
@pytest.mark.xfail(
    _OLD_JAX, reason="partial-manual axis_index -> PartitionId, "
    "unsupported by jax 0.4.x SPMD partitioning", strict=False,
)
def test_pp_parity_8dev():
    _run(SCRIPT_PP_PARITY, "PP_PARITY_OK")
