"""Multi-device integration tests (8 forced host devices, subprocess —
the main test process must keep the real device count)."""

import os
import subprocess
import sys

import jax
import pytest

# jax 0.4.x lowers axis_index over a partial-manual shard_map axis to a
# PartitionId instruction its SPMD partitioner rejects; the PP schedule
# needs exactly that (stage = axis_index('pod')). Fixed upstream in the
# jax versions that ship jax.shard_map.
_OLD_JAX = not hasattr(jax, "shard_map")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT_SHARDED_ANN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import DBLSHParams, brute_force, build, search_batch_fixed
from repro.core.distributed import build_sharded, search_sharded
from repro.data import make_clustered, normalize_scale

mesh = jax.make_mesh((8,), ("data",))  # axis_types default to Auto
key = jax.random.key(3)
kd, kb = jax.random.split(key)
allpts = make_clustered(kd, 4128, 24, n_clusters=16, spread=0.02)
data, queries = allpts[:4096], allpts[4096:]
data, queries, _ = normalize_scale(data, queries)

params = DBLSHParams.derive(n=4096, d=24, c=1.5, t=48, k=10, K=8, L=3)
sh = build_sharded(kb, data, params, mesh, axis="data")
d_s, i_s = search_sharded(sh, queries, k=10, r0=0.5, steps=8, mesh=mesh)
d_s, i_s = np.asarray(d_s), np.asarray(i_s)

# ground truth + validity
gd, gi = map(np.asarray, brute_force(data, queries, k=10))
rec = np.mean([len(set(a) & set(b)) / 10 for a, b in zip(i_s, gi)])
assert rec > 0.6, f"sharded recall {rec}"
dn = np.asarray(data)
for q in range(queries.shape[0]):
    fin = np.isfinite(d_s[q])
    ids = i_s[q][fin]
    assert (ids < 4096).all()
    real = np.linalg.norm(dn[ids] - np.asarray(queries[q]), axis=-1)
    np.testing.assert_allclose(d_s[q][fin], real, rtol=3e-3, atol=3e-3)

# per-shard probe stats survive the collective merge: candidates is the
# psum over the 8 shards, radius_steps the pmax — both real per query
d_s2, i_s2, st = search_sharded(sh, queries, k=10, r0=0.5, steps=8,
                                mesh=mesh, with_stats=True)
np.testing.assert_array_equal(np.asarray(i_s2), i_s)
cand = np.asarray(st["candidates"]); steps_t = np.asarray(st["radius_steps"])
assert cand.shape == steps_t.shape == (queries.shape[0],)
assert (cand > 0).all(), "per-shard candidate counts dropped at the merge"
assert ((steps_t >= 1) & (steps_t <= 8)).all()
print("SHARDED_ANN_OK", rec)
"""

SCRIPT_TRAIN_PARITY = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, SHAPES
from repro.models.registry import build_model
from repro.sharding import rules
from repro.train import make_optimizer, make_train_step, init_train_state
from repro.train.optimizer import cosine_schedule
from repro.data.pipeline import SyntheticTokens, make_batch_fn

cfg = get_config("yi-9b").smoke().scaled(n_layers=2, sp_residual=True)
model = build_model(cfg)
opt = make_optimizer("adamw", cosine_schedule(1e-2, 2, 100))
src = SyntheticTokens(cfg.vocab_size, 16, 8, seed=4)
batch_fn = make_batch_fn(src)

# single-device reference
state0 = init_train_state(model, opt, jax.random.key(0))
step1 = jax.jit(make_train_step(model, opt))
s, losses_ref = state0, []
for t in range(4):
    s, m = step1(s, batch_fn(t))
    losses_ref.append(float(m["loss"]))

# 2x4 mesh (data x model) distributed run
mesh = jax.make_mesh((2, 4), ("data", "model"))  # Auto axes
with mesh:
    state_shapes = jax.eval_shape(lambda k: init_train_state(model, opt, k), jax.random.key(0))
    pspecs = rules.param_specs(state_shapes["params"], mesh, fsdp_min_size=1<<10)
    sspecs = rules.state_specs(state_shapes, pspecs, mesh)
    bspecs = rules.batch_specs(jax.eval_shape(lambda: batch_fn(0)), mesh)
    stepd = jax.jit(
        make_train_step(model, opt, mesh),
        in_shardings=(rules.named(mesh, sspecs), rules.named(mesh, bspecs)),
        out_shardings=(rules.named(mesh, sspecs), None),
    )
    s2 = jax.device_put(init_train_state(model, opt, jax.random.key(0)),
                        rules.named(mesh, sspecs))
    losses_d = []
    for t in range(4):
        s2, m = stepd(s2, batch_fn(t))
        losses_d.append(float(m["loss"]))

np.testing.assert_allclose(losses_ref, losses_d, rtol=2e-3, atol=2e-3)
print("TRAIN_PARITY_OK", losses_ref, losses_d)
"""

SCRIPT_MOE_PARITY = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models.registry import build_model

cfg = get_config("arctic-480b").smoke().scaled(n_layers=2)
model = build_model(cfg)
params = model.init(jax.random.key(0))
ks = jax.random.split(jax.random.key(1), 2)
batch = {
    "tokens": jax.random.randint(ks[0], (4, 16), 0, cfg.vocab_size),
    "labels": jax.random.randint(ks[1], (4, 16), 0, cfg.vocab_size),
}
loss_1dev = float(jax.jit(lambda p, b: model.loss(p, b)[0])(params, batch))

mesh = jax.make_mesh((2, 4), ("data", "model"))  # Auto axes
with mesh:
    loss_dist = float(
        jax.jit(lambda p, b: model.loss(p, b, mesh)[0])(params, batch)
    )
# shard_map EP (capacity per shard differs from the 1-dev path) may drop
# different tokens; losses must still agree closely at this tiny scale
np.testing.assert_allclose(loss_1dev, loss_dist, rtol=5e-2)
print("MOE_PARITY_OK", loss_1dev, loss_dist)
"""


def _run(script, tag):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True, text=True,
        timeout=520,
    )
    assert tag in proc.stdout, f"stdout:\n{proc.stdout[-2000:]}\nstderr:\n{proc.stderr[-4000:]}"


@pytest.mark.slow
def test_sharded_ann_8dev():
    _run(SCRIPT_SHARDED_ANN, "SHARDED_ANN_OK")


@pytest.mark.slow
def test_train_parity_8dev():
    _run(SCRIPT_TRAIN_PARITY, "TRAIN_PARITY_OK")


@pytest.mark.slow
def test_moe_ep_parity_8dev():
    _run(SCRIPT_MOE_PARITY, "MOE_PARITY_OK")


SCRIPT_PP_PARITY = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models.registry import build_model
from repro.sharding.pp import pp_loss_fn

cfg = get_config("yi-9b").smoke().scaled(n_layers=4)
model = build_model(cfg)
params = model.init(jax.random.key(0))
ks = jax.random.split(jax.random.key(1), 2)
batch = {
    "tokens": jax.random.randint(ks[0], (8, 16), 0, cfg.vocab_size),
    "labels": jax.random.randint(ks[1], (8, 16), 0, cfg.vocab_size),
}
ref = float(jax.jit(lambda p, b: model.loss(p, b)[0])(params, batch))

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))  # Auto axes
with mesh:
    pp = float(jax.jit(
        lambda p, b: pp_loss_fn(p, b, cfg, mesh, microbatches=4)
    )(params, batch))
np.testing.assert_allclose(ref, pp, rtol=2e-3)

# gradients flow through ppermute: grad wrt embed must match
g_ref = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))(params, batch)
with mesh:
    g_pp = jax.jit(jax.grad(lambda p, b: pp_loss_fn(p, b, cfg, mesh, microbatches=4)))(params, batch)
np.testing.assert_allclose(
    np.asarray(g_ref["embed"], np.float32),
    np.asarray(g_pp["embed"], np.float32), rtol=5e-2, atol=1e-4)
print("PP_PARITY_OK", ref, pp)
"""


@pytest.mark.slow
@pytest.mark.xfail(
    _OLD_JAX, reason="partial-manual axis_index -> PartitionId, "
    "unsupported by jax 0.4.x SPMD partitioning", strict=False,
)
def test_pp_parity_8dev():
    _run(SCRIPT_PP_PARITY, "PP_PARITY_OK")
