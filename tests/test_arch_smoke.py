"""Per-architecture smoke tests: instantiate a REDUCED config of the same
family, run forward + one train step + prefill->decode on CPU, assert
output shapes and no NaNs. The FULL configs are exercised only via the
dry-run (ShapeDtypeStructs, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CONFIGS, get_config
from repro.models.registry import build_model, param_count

ARCHS = sorted(CONFIGS)

B, T = 2, 16


def _batch(cfg, key):
    ks = jax.random.split(key, 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, T), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (B, T), 0, cfg.vocab_size),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(ks[2], (B, cfg.enc_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["images"] = jax.random.normal(ks[3], (B, cfg.n_img_tokens, cfg.d_vision))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_loss_and_grad(arch):
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    assert param_count(params) > 0
    batch = _batch(cfg, jax.random.key(1))

    def loss_of(p):
        return model.loss(p, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_of))(params)
    assert np.isfinite(float(loss)), arch
    # a sensible CE at init: ~log(vocab)
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < 3.0 * np.log(cfg.vocab_size)
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0.0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode(arch):
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    pre_batch = {k: v for k, v in batch.items() if k != "labels"}

    cache_len = T + 4
    logits, hidden, caches = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len=cache_len)
    )(params, pre_batch)
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    step = jax.jit(lambda p, t, c, pos: model.decode(p, t, c, pos))
    for i in range(3):
        logits, hid, caches = step(params, token, caches, jnp.asarray(T + i, jnp.int32))
        assert logits.shape == (B, cfg.padded_vocab)
        assert hid.shape == (B, cfg.d_model)
        assert np.all(np.isfinite(np.asarray(logits, np.float32))), (arch, i)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["yi-9b", "mamba2-1.3b", "hymba-1.5b", "whisper-medium"])
def test_decode_consistent_with_prefill(arch):
    """Greedy decode after prefilling T tokens == argmax of teacher-forced
    forward at the same position."""
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    pre = {k: v for k, v in batch.items() if k != "labels"}

    # teacher-forced full forward over T tokens: logits at last position
    logits_full, _, _ = jax.jit(lambda p, b: model.prefill(p, b, cache_len=T))(params, pre)

    # prefill T-1, then decode token T-1
    pre_short = dict(pre)
    pre_short["tokens"] = pre["tokens"][:, : T - 1]
    _, _, caches = jax.jit(lambda p, b: model.prefill(p, b, cache_len=T))(params, pre_short)
    logits_dec, _, _ = jax.jit(lambda p, t, c: model.decode(p, t, c, jnp.asarray(T - 1, jnp.int32)))(
        params, pre["tokens"][:, T - 1], caches
    )
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_full, np.float32),
        rtol=2e-3, atol=2e-3,
    )
