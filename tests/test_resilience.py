"""repro.resilience test harness.

Five suites over the fault-injection / degradation / recovery layer:

* **FaultPlan units** — deterministic firing windows (``at`` / ``count``
  / ctx match), the installed-plan lifecycle (``active`` nesting,
  no-op default), delay sites through an injectable sleep.
* **Checkpointer integrity** — crc32 verify-on-restore raising typed
  :class:`CorruptSnapshot` (naming step + file), fallback to the newest
  *verified* step, garbled-manifest ``read_meta``, stranded-``LATEST``
  recovery, GC skipping a step a concurrent restore is mid-read on,
  orphan ``.tmp`` salvage vs torn-tmp GC, ``save_async`` error
  surfacing at ``wait()``, and v1 (pre-checksum) manifest back-compat.
* **Crash consistency (property)** — kill the snapshot writer at every
  fault site in the snapshot lane (hypothesis over sites × torn byte
  offsets); ``restore_collection`` must always land on a committed
  snapshot whose search results are bit-equal to one the writer
  actually reached, and the directory must sweep clean of tmp dirs.
* **Degraded serving** — ``deadline_ms`` expiry (typed
  ``DeadlineExceeded``), deadline re-planning through a measured
  calibration table (flagged ``degraded``), transient dispatch retry
  with capped backoff (bit-equal results), persistent dispatch failure
  terminating every ticket typed (never hung), and the brownout ladder
  (escalate on SLO breach / heal on clean windows / shed by quota
  weight) — plus the acceptance pin: with no faults installed (or an
  installed-but-empty plan) the service is bit-equal to the plain
  stack, across the engine matrix.
* **Stragglers** — the EWMA monitor (shared with
  ``runtime.fault_tolerance``, re-export identity pinned), its service
  wiring (slow batch flagged into ``stats()['straggler_batches']``),
  and the ``shard.straggle`` site firing in sharded search.

Engine matrix: ``REPRO_STORE_TEST_ENGINES`` (default ``jnp``), same
convention as the scheduler harness.
"""

import math
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st
from repro.checkpoint import Checkpointer, CorruptSnapshot
from repro.core import DBLSHParams
from repro.data import make_clustered, normalize_scale
from repro.obs.slo import SLOWatch
from repro.resilience import (
    SNAPSHOT_CRASH_STAGES,
    BrownoutController,
    FaultPlan,
    SimulatedCrash,
    StragglerMonitor,
    faults,
)
from repro.store import (
    BrownoutShed,
    Collection,
    DeadlineExceeded,
    DispatchFailed,
    StoreService,
    restore_collection,
)
from repro.tune.planner import ScheduleTable

ENGINES = os.environ.get("REPRO_STORE_TEST_ENGINES", "jnp").replace(",", " ").split()


class FakeClock:
    """Injectable monotonic clock: time only moves when told to."""

    def __init__(self, start: float = 0.0):
        self.now = start

    def __call__(self) -> float:
        return self.now

    def advance(self, seconds: float) -> float:
        self.now += seconds
        return self.now


@pytest.fixture(scope="module")
def setup():
    kd, kb = jax.random.split(jax.random.key(31))
    allpts = make_clustered(kd, 280, 12, n_clusters=6, spread=0.02)
    data, queries = allpts[:240], allpts[240:]
    data, queries, _ = normalize_scale(data, queries)
    return np.asarray(data), np.asarray(queries), kb


@pytest.fixture(scope="module")
def col(setup):
    data, _, kb = setup
    params = DBLSHParams.derive(
        n=240, d=12, c=1.5, w0=3.6, t=16, k=10, inline_vectors=True
    )
    return Collection.create("res", kb, data, params=params)


def _service(col, *, engine="jnp", depth=2, clock=None, **kw):
    kw.setdefault("batch_shapes", (1, 4, 8))
    kw.setdefault("max_wait_ms", 1e9)
    kw.setdefault("cache_size", 0)
    svc = StoreService(
        default_k=10, r0=0.5, steps=6, engine=engine,
        interpret=True if engine != "jnp" else None,
        inflight_depth=depth,
        **({"clock": clock} if clock is not None else {}),
        **kw,
    )
    svc.attach(col)
    return svc


@pytest.fixture(autouse=True)
def _no_leaked_plan():
    """Every test starts and ends with no installed fault plan."""
    faults.uninstall()
    yield
    faults.uninstall()


# ---------------------------------------------------------------------------
# FaultPlan units
# ---------------------------------------------------------------------------


class TestFaultPlan:
    def test_noop_without_install(self):
        assert faults.fire("dispatch.raise") is None
        assert faults.fire("snapshot.write.torn", file="arr_0.npy") is None

    def test_at_count_window(self):
        plan = FaultPlan().add("dispatch.raise", at=2, count=2)
        with faults.active(plan):
            faults.fire("dispatch.raise")  # hit 0: before window
            faults.fire("dispatch.raise")  # hit 1
            for _ in range(2):             # hits 2, 3: inside
                with pytest.raises(faults.FaultError):
                    faults.fire("dispatch.raise")
            faults.fire("dispatch.raise")  # hit 4: past window
        assert len(plan.fired) == 2

    def test_ctx_match_filters_hits(self):
        plan = FaultPlan().add(
            "snapshot.write.torn", arg=7, file="arr_1.npy", count=math.inf
        )
        with faults.active(plan):
            assert faults.fire("snapshot.write.torn", file="arr_0.npy") is None
            assert faults.fire("snapshot.write.torn", file="arr_1.npy") == 7
        # non-matching hits never consumed the window
        assert [c["file"] for _, c in plan.fired] == ["arr_1.npy"]

    def test_transient_flag_travels(self):
        plan = FaultPlan().add("dispatch.raise", transient=False)
        with faults.active(plan), pytest.raises(faults.FaultError) as ei:
            faults.fire("dispatch.raise")
        assert ei.value.transient is False
        assert isinstance(SimulatedCrash("x"), faults.FaultError)
        assert SimulatedCrash("x").transient is False

    def test_delay_site_uses_injected_sleep_and_scale(self):
        slept = []
        plan = FaultPlan(sleep=slept.append).add(
            "dispatch.delay_ms", arg=20.0, count=math.inf
        )
        with faults.active(plan):
            assert faults.fire("dispatch.delay_ms", scale=3) == 60.0
        assert slept == [0.06]

    def test_active_nesting_restores_previous(self):
        outer, inner = FaultPlan(), FaultPlan()
        with faults.active(outer):
            with faults.active(inner):
                assert faults._ACTIVE is inner
            assert faults._ACTIVE is outer
        assert faults._ACTIVE is None

    def test_reset_rewinds_counters(self):
        plan = FaultPlan().add("dispatch.raise")
        with faults.active(plan):
            with pytest.raises(faults.FaultError):
                faults.fire("dispatch.raise")
            faults.fire("dispatch.raise")  # window spent
            plan.reset()
            with pytest.raises(faults.FaultError):
                faults.fire("dispatch.raise")


# ---------------------------------------------------------------------------
# Checkpointer integrity + recovery
# ---------------------------------------------------------------------------


def _tree(seed: int):
    rng = np.random.default_rng(seed)
    return {
        "a": rng.standard_normal(32).astype(np.float32),
        "b": rng.integers(0, 100, (4, 4)),
    }


class TestCheckpointerIntegrity:
    def test_crc_roundtrip_and_manifest_v2(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(1, _tree(1), meta={"k": 1})
        manifest = ck._load_manifest(1)
        assert manifest["manifest_version"] == 2
        assert all("crc32" in spec for spec in manifest["leaves"])
        tree, meta = ck.restore()
        np.testing.assert_array_equal(tree["a"], _tree(1)["a"])
        assert meta == {"k": 1}

    def test_corrupt_leaf_raises_typed_and_falls_back(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(1, _tree(1), meta={"k": 1})
        ck.save(2, _tree(2), meta={"k": 2})
        p = tmp_path / "step_00000002" / "arr_0.npy"
        blob = p.read_bytes()
        p.write_bytes(blob[:-3] + b"zzz")
        # explicit step: strict, typed, names the step and file
        with pytest.raises(CorruptSnapshot) as ei:
            ck.restore(step=2)
        assert ei.value.step == 2 and ei.value.file == "arr_0.npy"
        # step=None: falls back to the newest step that verifies
        tree, meta = ck.restore()
        assert meta == {"k": 1}
        np.testing.assert_array_equal(tree["a"], _tree(1)["a"])

    def test_injected_read_corruption_caught_by_crc(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(1, _tree(1), meta={"k": 1})
        ck.save(2, _tree(2), meta={"k": 2})
        plan = FaultPlan().add(
            "snapshot.read.corrupt", arg=10, count=math.inf, step=2
        )
        with faults.active(plan):
            tree, meta = ck.restore()
        assert meta == {"k": 1}  # step 2's flipped byte failed its crc
        assert plan.fired

    def test_garbled_manifest_read_meta_typed(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(3, _tree(3), meta={"k": 3})
        (tmp_path / "step_00000003" / "manifest.json").write_text("{tor")
        with pytest.raises(CorruptSnapshot) as ei:
            ck.read_meta(3)
        assert ei.value.step == 3 and "manifest.json" in ei.value.file

    def test_stranded_latest_falls_back(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(1, _tree(1), meta={"k": 1})
        ck.save(2, _tree(2), meta={"k": 2})
        # LATEST names a step whose dir is gone (crash-between-rename-
        # and-LATEST's mirror image: GC'd dir, stale pointer)
        (tmp_path / "LATEST").write_text("7")
        assert ck.latest_step() == 2
        _, meta = ck.restore()
        assert meta == {"k": 2}
        # torn LATEST content
        (tmp_path / "LATEST").write_text("st")
        assert ck.latest_step() == 2
        _, meta = ck.restore()
        assert meta == {"k": 2}

    def test_missing_latest_file_falls_back(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(1, _tree(1), meta={"k": 1})
        (tmp_path / "LATEST").unlink()
        assert ck.latest_step() == 1
        _, meta = ck.restore()
        assert meta == {"k": 1}

    def test_gc_skips_step_mid_restore(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=1)
        ck.save(1, _tree(1), meta={"k": 1})
        with ck._reading_lock:
            ck._reading.add(1)  # a concurrent restore() holds step 1
        ck.save(2, _tree(2), meta={"k": 2})
        assert (tmp_path / "step_00000001").exists()
        with ck._reading_lock:
            ck._reading.discard(1)
        ck.save(3, _tree(3), meta={"k": 3})
        assert not (tmp_path / "step_00000001").exists()

    def test_tmp_salvage_and_torn_tmp_gc(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(1, _tree(1), meta={"k": 1})
        # crash after the tmp dir is complete but before the rename:
        # the next Checkpointer salvages it into a real step
        plan = FaultPlan().add("snapshot.write.crash", stage="pre_rename")
        with faults.active(plan), pytest.raises(SimulatedCrash):
            ck.save(2, _tree(2), meta={"k": 2})
        ck2 = Checkpointer(str(tmp_path))
        assert not [n for n in os.listdir(tmp_path) if ".tmp." in n]
        _, meta = ck2.restore()
        assert meta == {"k": 2}
        # a torn leaf leaves an unverifiable tmp: swept, not salvaged
        plan = FaultPlan().add("snapshot.write.torn", file="arr_0.npy", arg=9)
        with faults.active(plan), pytest.raises(SimulatedCrash):
            ck2.save(3, _tree(3), meta={"k": 3})
        ck3 = Checkpointer(str(tmp_path))
        assert not [n for n in os.listdir(tmp_path) if ".tmp" in n]
        _, meta = ck3.restore()
        assert meta == {"k": 2}

    def test_save_async_error_surfaces_at_wait(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        faults.install(
            FaultPlan().add("snapshot.write.crash", stage="pre_manifest")
        )
        try:
            ck.save_async(1, _tree(1), meta={"k": 1})
            with pytest.raises(SimulatedCrash):
                ck.wait()
        finally:
            faults.uninstall()
        # the recovery path drains without re-raising
        faults.install(
            FaultPlan().add("snapshot.write.crash", stage="pre_manifest")
        )
        try:
            ck.save_async(2, _tree(2), meta={"k": 2})
            ck.wait(reraise=False)
        finally:
            faults.uninstall()

    def test_v1_manifest_backward_compat(self, tmp_path):
        """A PR-7 (pre-checksum) manifest restores: verification is
        simply skipped for leaves with no crc32."""
        import json

        ck = Checkpointer(str(tmp_path))
        ck.save(1, _tree(1), meta={"k": 1})
        mpath = tmp_path / "step_00000001" / "manifest.json"
        manifest = json.loads(mpath.read_text())
        manifest.pop("manifest_version")
        for spec in manifest["leaves"]:
            spec.pop("crc32")
        mpath.write_text(json.dumps(manifest))
        tree, meta = ck.restore()
        assert meta == {"k": 1}
        np.testing.assert_array_equal(tree["a"], _tree(1)["a"])


# ---------------------------------------------------------------------------
# Crash-consistency property: kill the writer at every snapshot-lane site
# ---------------------------------------------------------------------------

# scenario space: 4 crash stages, torn leaf, torn manifest, read corruption
_N_SCENARIOS = len(SNAPSHOT_CRASH_STAGES) + 3


def _snapshot_fault_plan(scenario: int, byte: int, step: int) -> FaultPlan:
    plan = FaultPlan()
    if scenario < len(SNAPSHOT_CRASH_STAGES):
        plan.add(
            "snapshot.write.crash",
            stage=SNAPSHOT_CRASH_STAGES[scenario], step=step,
        )
    elif scenario == len(SNAPSHOT_CRASH_STAGES):
        plan.add("snapshot.write.torn", file="arr_0.npy", arg=byte, step=step)
    elif scenario == len(SNAPSHOT_CRASH_STAGES) + 1:
        plan.add(
            "snapshot.write.torn", file="manifest.json", arg=byte, step=step
        )
    # scenario _N_SCENARIOS-1: no write fault — bit-rot at restore time
    return plan


class TestCrashConsistency:
    @given(
        scenario=st.integers(min_value=0, max_value=_N_SCENARIOS - 1),
        byte=st.integers(min_value=1, max_value=160),
    )
    @settings(max_examples=8, deadline=None)
    def test_restore_always_lands_on_committed_state(
        self, setup, tmp_path_factory, scenario, byte
    ):
        """Whatever site the writer dies at, ``restore_collection`` must
        recover a committed snapshot: its search results are bit-equal
        to the state at one of the snapshots the writer attempted (recall
        parity with a fresh build of that state is implied — the arrays
        are bit-identical), and the directory sweeps clean of tmp dirs."""
        data, queries, kb = setup
        directory = str(tmp_path_factory.mktemp(f"crash_{scenario}_{byte}"))
        params = DBLSHParams.derive(
            n=200, d=12, c=1.5, w0=3.6, t=16, k=10, inline_vectors=True
        )
        col = Collection.create("cc", kb, data[:200], params=params)
        kw = dict(k=10, r0=0.5, steps=6, engine="jnp")
        ref1 = [np.asarray(x) for x in col.search(queries, **kw)]
        step1 = col.snapshot(directory)
        col.add(data[200:240])
        ref2 = [np.asarray(x) for x in col.search(queries, **kw)]

        read_fault = scenario == _N_SCENARIOS - 1
        step2 = step1 + 1
        plan = _snapshot_fault_plan(scenario, byte, step2)
        try:
            with faults.active(plan):
                col.snapshot(directory)
        except SimulatedCrash:
            pass

        if read_fault:
            # the write committed clean; rot step2's bytes at read time
            faults.install(FaultPlan().add(
                "snapshot.read.corrupt", arg=byte, count=math.inf, step=step2,
            ))
        try:
            restored = restore_collection(directory)
        finally:
            faults.uninstall()
        got = [np.asarray(x) for x in restored.search(queries, **kw)]
        matches_1 = all(np.array_equal(g, r) for g, r in zip(got, ref1))
        matches_2 = all(np.array_equal(g, r) for g, r in zip(got, ref2))
        assert matches_1 or matches_2, (
            f"scenario={scenario} byte={byte}: restored state matches "
            "neither attempted snapshot"
        )
        if read_fault:
            assert matches_1  # step2 failed its crc: fell back to step1
        # a fresh Checkpointer sweeps the wreckage
        Checkpointer(directory)
        assert not [n for n in os.listdir(directory) if ".tmp" in n]


# ---------------------------------------------------------------------------
# Degraded serving: deadlines, retries, typed failure, brownout
# ---------------------------------------------------------------------------


def _measured_table() -> ScheduleTable:
    # schedule length j+1 costs 2^j ms; recall climbs toward 1
    return ScheduleTable(
        r0=0.5, c=1.5, k=10,
        recall=(0.55, 0.7, 0.82, 0.9, 0.95, 0.98),
        cost_slots=(8.0, 16.0, 32.0, 64.0, 128.0, 256.0),
        cost_ms=(1.0, 2.0, 4.0, 8.0, 16.0, 32.0),
        n_sample=64,
    )


class TestDeadlines:
    def test_expired_deadline_fails_typed(self, setup, col):
        _, queries, _ = setup
        clk = FakeClock()
        svc = _service(col, clock=clk)
        r = svc.submit("res", queries[0], deadline_ms=10.0)
        clk.advance(0.02)  # 20ms in the queue
        svc.step(force=True)
        assert r.done and isinstance(r.error, DeadlineExceeded)
        assert r.dists is None
        s = svc.stats("res")
        assert s["failed"] == 1 and s["queries"] == 0
        assert svc.tenant_stats("default")["failed"] == 1
        assert svc.pending() == 0 and svc.in_flight() == 0

    def test_deadline_replans_through_measured_table(self, setup, col):
        """A ticket whose remaining budget cannot fit the resolved plan
        is re-planned via LatencyBudget over the measured calibration
        table — shorter schedule, flagged degraded — instead of either
        blowing the deadline or failing outright."""
        _, queries, _ = setup
        clk = FakeClock()
        svc = _service(col, clock=clk)
        old_table = col.calibration
        col.calibration = _measured_table()
        try:
            r = svc.submit("res", queries[0], deadline_ms=10.0)
            assert r.plan.steps == 6  # service default at submit
            clk.advance(0.005)  # 5ms gone -> ~5ms budget -> 3 steps (4ms)
            svc.step(force=True)
        finally:
            col.calibration = old_table
        assert r.done and r.error is None
        assert r.degraded and r.plan.steps == 3
        assert r.dists is not None
        assert svc.stats("res")["degraded"] == 1

    def test_late_completion_flags_degraded(self, setup, col):
        """No calibration: the plan cannot shrink, but a result landing
        past its deadline is still flagged, never silently on-time."""
        _, queries, _ = setup
        clk = FakeClock()
        svc = _service(col, clock=clk, depth=1, max_wait_ms=0.0)
        old_table = col.calibration
        col.calibration = None
        try:
            r = svc.submit("res", queries[0], deadline_ms=10.0)
            svc.step()          # issued within budget
            clk.advance(0.05)   # device "takes" 50ms
            svc.flush()
        finally:
            col.calibration = old_table
        assert r.done and r.error is None and r.degraded
        assert r.plan.steps == 6  # plan untouched — only the flag


class TestDispatchFailure:
    @pytest.mark.parametrize("engine", ENGINES)
    def test_transient_raise_retried_bit_equal(self, setup, col, engine):
        _, queries, _ = setup
        ref = _service(col, engine=engine).serve("res", queries[:4])
        svc = _service(col, engine=engine, sleep=lambda s: None)
        plan = FaultPlan().add("dispatch.raise", count=2, transient=True)
        with faults.active(plan):
            d, i, reqs = svc.serve("res", queries[:4])
        assert len(plan.fired) == 2  # both transient raises were consumed
        np.testing.assert_array_equal(d, ref[0])
        np.testing.assert_array_equal(i, ref[1])
        assert all(r.error is None and not r.degraded for r in reqs)

    def test_backoff_is_capped_exponential(self, setup, col):
        _, queries, _ = setup
        slept = []
        svc = _service(
            col, sleep=slept.append, retry_limit=3,
            retry_backoff_ms=4.0, retry_backoff_cap_ms=10.0,
        )
        plan = FaultPlan().add("dispatch.raise", count=3, transient=True)
        with faults.active(plan):
            svc.serve("res", queries[:1])
        assert slept == [0.004, 0.008, 0.010]  # 4, 8, min(16, cap=10) ms

    def test_persistent_raise_fails_every_ticket_typed(self, setup, col):
        _, queries, _ = setup
        svc = _service(col, sleep=lambda s: None)
        reqs = [svc.submit("res", q) for q in queries[:4]]
        plan = FaultPlan().add(
            "dispatch.raise", count=math.inf, transient=True
        )
        with faults.active(plan):
            svc.flush()
        assert all(r.done for r in reqs)
        assert all(isinstance(r.error, DispatchFailed) for r in reqs)
        assert svc.pending() == 0 and svc.in_flight() == 0
        assert svc.stats("res")["failed"] == 4
        # serve() surfaces the typed error to synchronous callers
        with faults.active(plan.reset()), pytest.raises(DispatchFailed):
            svc.serve("res", queries[:2])

    def test_nontransient_raise_fails_without_retry(self, setup, col):
        _, queries, _ = setup
        slept = []
        svc = _service(col, sleep=slept.append)
        plan = FaultPlan().add("dispatch.raise", transient=False)
        r = svc.submit("res", queries[0])
        with faults.active(plan):
            svc.flush()
        assert isinstance(r.error, DispatchFailed)
        assert slept == []  # no backoff spent on a non-transient error
        assert len(plan.fired) == 1

    @pytest.mark.parametrize("engine", ENGINES)
    def test_no_faults_bit_equal_pin(self, setup, col, engine):
        """Acceptance pin: with faults disabled — no plan installed, or
        an installed-but-empty plan — the stack serves bit-identically
        to the plain pre-resilience dispatch (a direct collection
        search), across the engine matrix."""
        _, queries, _ = setup
        direct = col.search(
            queries[:8], k=10, r0=0.5, steps=6, engine=engine,
            interpret=True if engine != "jnp" else None,
        )
        d0, i0, reqs = _service(col, engine=engine).serve("res", queries[:8])
        with faults.active(FaultPlan()):  # installed, but scripts nothing
            d1, i1, _ = _service(col, engine=engine).serve("res", queries[:8])
        np.testing.assert_array_equal(d0, np.asarray(direct[0])[:, :10])
        np.testing.assert_array_equal(i0, np.asarray(direct[1])[:, :10])
        np.testing.assert_array_equal(d0, d1)
        np.testing.assert_array_equal(i0, i1)
        assert all(
            r.done and r.error is None and not r.degraded for r in reqs
        )


class TestBrownout:
    def _svc_with_bc(self, col, clk, **bc_kw):
        svc = _service(col, clock=clk, latency_window=4)
        bc = BrownoutController(svc, **bc_kw)
        assert svc.brownout is bc
        return svc, bc

    def test_ladder_escalates_and_heals(self, col):
        clk = FakeClock()
        svc, bc = self._svc_with_bc(col, clk, heal_after=2)
        breach = ["b"]  # any non-empty event list
        bc.observe(breach, clk.advance(1))
        assert bc.level == 1
        bc.observe(breach, clk.advance(1))
        bc.observe(breach, clk.advance(1))
        bc.observe(breach, clk.advance(1))
        assert bc.level == 3  # capped at max_level
        for _ in range(2):
            bc.observe([], clk.advance(1))
        assert bc.level == 2  # one rung per heal_after clean checks
        for _ in range(4):
            bc.observe([], clk.advance(1))
        assert bc.level == 0
        assert svc.registry.get("repro_store_brownout_level").value() == 0

    def test_hold_rate_limits_escalation(self, col):
        clk = FakeClock()
        _, bc = self._svc_with_bc(col, clk, hold_s=10.0)
        bc.observe(["b"], clk.advance(1))
        bc.observe(["b"], clk.advance(1))  # only 1s after the last rung
        assert bc.level == 1
        bc.observe(["b"], clk.advance(20))
        assert bc.level == 2

    def test_plans_degrade_per_rung(self, setup, col):
        _, queries, _ = setup
        clk = FakeClock()
        svc, bc = self._svc_with_bc(col, clk, step_cap_frac=0.5)
        r0 = svc.submit("res", queries[0])
        assert r0.plan.steps == 6 and not r0.degraded
        bc.observe(["b"], clk.advance(1))           # level 1: cap steps
        r1 = svc.submit("res", queries[1])
        assert r1.plan.steps == 3 and r1.degraded
        bc.observe(["b"], clk.advance(1))           # level 2: fixed floor
        r2 = svc.submit("res", queries[2])
        assert r2.plan.steps == 1 and r2.plan.termination is None
        assert r2.degraded
        svc.flush()
        assert all(r.done and r.error is None for r in (r0, r1, r2))
        assert svc.stats("res")["degraded"] == 2

    def test_shed_by_quota_weight(self, setup, col):
        _, queries, _ = setup
        clk = FakeClock()
        svc, bc = self._svc_with_bc(col, clk)
        svc.set_quota("gold", weight=5)
        svc.set_quota("bronze", weight=1)
        for _ in range(3):
            bc.observe(["b"], clk.advance(1))
        assert bc.level == 3
        with pytest.raises(BrownoutShed):
            svc.submit("res", queries[0], tenant="bronze")
        r = svc.submit("res", queries[0], tenant="gold")  # kept, degraded
        assert r.degraded
        assert svc.tenant_stats("bronze")["rejected"] == 1
        # equal weights shed nobody
        svc.set_quota("gold", weight=1)
        svc.submit("res", queries[1], tenant="bronze")
        svc.flush()

    def test_slo_watch_integration_escalates_then_heals(self, setup, col):
        """End to end: slow served traffic breaches the p99 ceiling via
        SLOWatch.check -> on_check -> escalate; once the (small) latency
        window refills with fast queries, clean checks heal the ladder
        back to healthy."""
        _, queries, _ = setup
        clk = FakeClock()
        svc, bc = self._svc_with_bc(col, clk, heal_after=2)
        slo = SLOWatch(
            svc.registry, "res", latency_p99_ms=10.0, min_samples=2,
            clock=clk,
        )
        bc.attach(slo)
        for q in queries[:4]:
            svc.submit("res", q)
        clk.advance(0.05)  # 50ms in queue -> p99 ~50ms
        svc.flush()
        assert slo.check(clk()) and bc.level == 1
        # traffic fast again: the 4-sample window forgets the spike
        for q in queries[:4]:
            svc.submit("res", q)
            svc.step(force=True)
        for _ in range(2):
            assert slo.check(clk.advance(1)) == []
        assert bc.level == 0


# ---------------------------------------------------------------------------
# Stragglers
# ---------------------------------------------------------------------------


class TestStragglers:
    def test_runtime_reexport_identity(self):
        from repro.runtime.fault_tolerance import (
            StragglerMonitor as RuntimeMonitor,
        )

        assert RuntimeMonitor is StragglerMonitor

    def test_monitor_flags_outlier_without_folding_it(self):
        mon = StragglerMonitor(alpha=0.5, threshold=2.0, warmup=3)
        assert not any(mon.record(i, 1.0) for i in range(4))
        assert mon.record(4, 10.0)
        assert mon.flagged == [(4, 10.0)]
        assert mon.ewma == 1.0  # the outlier never polluted the baseline

    def test_service_flags_slow_batch(self, setup, col):
        """Issue->complete wall time feeds the per-collection monitor: a
        batch 10x the EWMA baseline lands in straggler_batches."""
        _, queries, _ = setup
        clk = FakeClock()
        svc = _service(col, clock=clk, depth=1, max_wait_ms=0.0)
        for i in range(5):
            svc.submit("res", queries[i % len(queries)])
            svc.step()  # issues batch i; poll() completes batch i-1
            clk.advance(10.0 if i == 4 else 1.0)
        svc.flush()
        assert svc.stats("res")["straggler_batches"] == 1

    def test_shard_straggle_site_fires_in_sharded_search(self, setup):
        data, queries, kb = setup
        from repro.store import ShardedCollection

        mesh = jax.make_mesh((1,), ("data",))
        scol = ShardedCollection.create(
            "straggle", kb, data[:64], mesh, c=1.5, w0=3.6, t=8, k=10
        )
        slept = []
        plan = FaultPlan(sleep=slept.append).add(
            "shard.straggle", arg=100.0, collection="straggle"
        )
        with faults.active(plan):
            scol.search(queries[:2], k=10, r0=0.5, steps=4)
        assert plan.fired and plan.fired[0][0] == "shard.straggle"
        assert slept == [pytest.approx(0.4)]  # 100ms * steps(4) scale
