"""repro.obs test harness — deterministic, injected-clock coverage.

Six suites over the observability stack:

* **Tracer units** — span ordering, context-manager nesting (parent
  ids), two-phase ``add_span`` intervals, instants, deterministic
  sampling, and the bounded ring, all on a fake clock.
* **Metrics units** — counter monotonicity, gauge set/inc, histogram
  bucket math (Prometheus ``le`` ≤-semantics, cumulative counts, +inf
  tail), exact window percentiles, and 0-safe empty reads.
* **Export round-trips** — Prometheus text, registry JSON, span JSONL,
  and the Chrome/Perfetto ``trace_event`` timeline (async request
  pairs, ring-lane metadata, µs timestamps).
* **Service integration** — ``svc.stats()`` / ``tenant_stats()`` keep
  their contract keys but read the registry; the new p90/mean keys;
  0.0-safe empty snapshots; queue/ring gauges; quota-withdrawal
  accounting through monotonic counters.
* **Bit-equality** — obs fully enabled (trace, sample 1.0) vs disabled
  returns identical results through the overlapped scheduler, per
  engine (``REPRO_STORE_TEST_ENGINES`` matrix).
* **SLO watch** — latency breaches on scripted slow windows and
  termination-step drift breaches on scripted divergence from a
  synthetic ``ScheduleTable``, with the rolling window and rate limit
  driven by the fake clock.
"""

import json
import os

import numpy as np
import pytest

import jax

from repro.core import DBLSHParams, Termination, search_batch_fixed
from repro.data import make_clustered, normalize_scale
from repro.obs import (
    BreachEvent,
    ExemplarReservoir,
    MetricsRegistry,
    Observability,
    QueryExplain,
    SLOWatch,
    Tracer,
    expected_step_pmf,
    get_tracer,
)
from repro.obs.trace import TID_LIFECYCLE, TID_RING0, TID_SCHEDULER
from repro.store import (
    Collection,
    DeadlineExceeded,
    QuotaExceeded,
    StoreService,
)
from repro.tune import ScheduleTable

ENGINES = os.environ.get("REPRO_STORE_TEST_ENGINES", "jnp").replace(",", " ").split()


class FakeClock:
    """Injectable monotonic clock: time only moves when told to."""

    def __init__(self, start: float = 0.0):
        self.now = start

    def __call__(self) -> float:
        return self.now

    def advance(self, seconds: float) -> float:
        self.now += seconds
        return self.now


@pytest.fixture(scope="module")
def setup():
    kd, kb = jax.random.split(jax.random.key(31))
    allpts = make_clustered(kd, 280, 12, n_clusters=6, spread=0.02)
    data, queries = allpts[:256], allpts[256:]
    data, queries, _ = normalize_scale(data, queries)
    return np.asarray(data), np.asarray(queries), kb


@pytest.fixture(scope="module")
def col(setup):
    data, _, kb = setup
    params = DBLSHParams.derive(
        n=256, d=12, c=1.5, w0=3.6, t=12, k=8, inline_vectors=True
    )
    return Collection.create("obscol", kb, data, params=params)


@pytest.fixture(autouse=True)
def _global_tracer_clean():
    """Tests that enable the process-global tracer must not leak state
    into each other (or into the scheduler suite)."""
    tr = get_tracer()
    yield
    tr.disable()
    tr.clear()


# --------------------------------------------------------------- tracer units
class TestTracer:
    def test_disabled_records_nothing(self):
        clk = FakeClock()
        tr = Tracer(clock=clk)
        tr.add_span("a", 0.0, 1.0)
        tr.instant("b")
        with tr.span("c") as sp:
            sp.set(x=1)  # nop handle
        assert not tr.events
        assert not tr.should_sample()

    def test_two_phase_spans_and_ordering(self):
        clk = FakeClock()
        tr = Tracer(enabled=True, clock=clk)
        tr.add_span("late", 5.0, 7.0, tid=TID_RING0 + 1, seq=2)
        tr.add_span("early", 1.0, 6.0, tid=TID_RING0, seq=1)
        # export order is by start time, not insertion order
        names = [s.name for s in sorted(tr.events, key=lambda s: s.ts)]
        assert names == ["early", "late"]
        early = next(s for s in tr.events if s.name == "early")
        assert early.dur == pytest.approx(5.0)
        assert early.args["seq"] == 1

    def test_nesting_parents(self):
        clk = FakeClock()
        tr = Tracer(enabled=True, clock=clk)
        with tr.span("outer"):
            clk.advance(1.0)
            with tr.span("inner") as sp:
                clk.advance(0.5)
                sp.set(rows=3)
        inner = next(s for s in tr.events if s.name == "inner")
        outer = next(s for s in tr.events if s.name == "outer")
        assert inner.parent == outer.sid
        assert outer.parent is None
        assert inner.args == {"rows": 3}
        assert inner.dur == pytest.approx(0.5)
        assert outer.dur == pytest.approx(1.5)

    def test_deterministic_sampling(self):
        tr = Tracer(enabled=True, sample_rate=0.5)
        fired = [tr.should_sample() for _ in range(10)]
        assert sum(fired) == 5
        # counter-based, not random: a fresh tracer fires identically
        tr_again = Tracer(enabled=True, sample_rate=0.5)
        assert [tr_again.should_sample() for _ in range(10)] == fired
        tr2 = Tracer(enabled=True, sample_rate=1.0)
        assert all(tr2.should_sample() for _ in range(5))

    def test_bounded_ring(self):
        tr = Tracer(enabled=True, maxlen=4)
        for i in range(10):
            tr.add_span(f"s{i}", float(i), float(i) + 0.5)
        assert len(tr.events) == 4
        assert [s.name for s in tr.events] == ["s6", "s7", "s8", "s9"]


# -------------------------------------------------------------- metrics units
class TestMetrics:
    def test_counter_and_gauge(self):
        reg = MetricsRegistry()
        c = reg.counter("t_total", "help")
        c.inc(tenant="a")
        c.inc(2.0, tenant="a")
        c.inc(tenant="b")
        assert c.value(tenant="a") == 3.0
        assert c.value(tenant="b") == 1.0
        assert c.value(tenant="zzz") == 0.0
        g = reg.gauge("depth")
        g.set(4.0)
        g.inc(-1.0)
        assert g.value() == 3.0
        # get-or-create returns the same family; kind mismatch raises
        assert reg.counter("t_total") is c
        with pytest.raises(TypeError):
            reg.gauge("t_total")

    def test_histogram_bucket_math(self):
        reg = MetricsRegistry()
        h = reg.histogram("lat", buckets=(1.0, 5.0, 10.0), window=16)
        for v in (0.5, 1.0, 1.01, 7.0, 100.0):
            h.observe(v)
        # Prometheus le (≤) semantics: 1.0 lands in the le="1" bucket
        cum = h.cumulative_buckets()
        assert [(ub, n) for ub, n in cum] == [
            (1.0, 2), (5.0, 3), (10.0, 4), (float("inf"), 5),
        ]
        assert h.count() == 5
        assert h.sum() == pytest.approx(109.51)
        assert h.mean() == pytest.approx(109.51 / 5)

    def test_exact_window_percentiles(self):
        reg = MetricsRegistry()
        h = reg.histogram("lat", window=8)
        vals = [40.0, 30.0, 20.0, 10.0]
        for v in vals:
            h.observe(v, collection="c")
        p50, p99 = h.percentile([50.0, 99.0], collection="c")
        np.testing.assert_allclose(
            [p50, p99], np.percentile(vals, [50, 99])
        )
        # window is a ring: old observations age out
        for v in [1.0] * 8:
            h.observe(v, collection="c")
        assert h.percentile(99.0, collection="c") == pytest.approx(1.0)

    def test_empty_reads_are_zero(self):
        reg = MetricsRegistry()
        h = reg.histogram("lat", window=8)
        assert h.percentile(50.0) == 0.0
        assert list(h.percentile([50.0, 99.0])) == [0.0, 0.0]
        assert h.mean() == 0.0
        assert h.count() == 0


# ------------------------------------------------------------------- exports
class TestExports:
    def test_prometheus_text(self):
        reg = MetricsRegistry()
        reg.counter("repro_q_total", "queries").inc(3, collection="a")
        h = reg.histogram("repro_lat", "ms", buckets=(1.0, 10.0))
        h.observe(0.5, collection="a")
        h.observe(5.0, collection="a")
        text = reg.to_prometheus()
        assert "# TYPE repro_q_total counter" in text
        assert 'repro_q_total{collection="a"} 3' in text
        assert '# TYPE repro_lat histogram' in text
        assert 'repro_lat_bucket{collection="a",le="1"} 1' in text
        assert 'repro_lat_bucket{collection="a",le="10"} 2' in text
        assert 'repro_lat_bucket{collection="a",le="+Inf"} 2' in text
        assert 'repro_lat_sum{collection="a"} 5.5' in text
        assert 'repro_lat_count{collection="a"} 2' in text

    def test_registry_json_roundtrip(self, tmp_path):
        reg = MetricsRegistry()
        reg.counter("c_total").inc(7, tenant="t")
        reg.histogram("h", buckets=(1.0,)).observe(2.0)
        path = tmp_path / "metrics.json"
        reg.export_json(str(path))
        blob = json.loads(path.read_text())
        assert blob["c_total"]["type"] == "counter"
        assert blob["c_total"]["series"][0] == {
            "labels": {"tenant": "t"}, "value": 7.0,
        }
        hist = blob["h"]["series"][0]
        assert hist["count"] == 1
        assert hist["buckets"][-1] == {"le": "+Inf", "count": 1}

    def test_jsonl_roundtrip(self, tmp_path):
        clk = FakeClock()
        tr = Tracer(enabled=True, clock=clk)
        tr.add_span("b", 2.0, 3.0, cat="batch", seq=1)
        tr.add_span("a", 0.0, 1.0, cat="batch", seq=0)
        path = tmp_path / "spans.jsonl"
        assert tr.export_jsonl(str(path)) == 2
        rows = [json.loads(l) for l in path.read_text().splitlines()]
        assert [r["name"] for r in rows] == ["a", "b"]  # time-sorted
        assert rows[0]["dur"] == pytest.approx(1.0)
        assert rows[1]["args"]["seq"] == 1

    def test_perfetto_timeline(self, tmp_path):
        clk = FakeClock()
        tr = Tracer(enabled=True, clock=clk)
        # overlapping request spans -> async pairs; batch span on a ring
        # lane; one instant
        tr.add_span("request.queue_wait", 0.0, 2.0, cat="request", uid=1)
        tr.add_span("request.queue_wait", 1.0, 3.0, cat="request", uid=2)
        tr.add_span("batch.pending", 1.0, 2.5, cat="batch",
                    tid=TID_RING0, seq=0)
        tr.instant("cache.put", t=2.5, entries=4)
        path = tmp_path / "trace.json"
        tr.export_perfetto(str(path))
        blob = json.loads(path.read_text())
        ev = blob["traceEvents"]
        # ring lane got a thread_name metadata record
        meta = [e for e in ev if e["ph"] == "M"]
        assert any(e["tid"] == TID_RING0 and "ring slot 0" in
                   e["args"]["name"] for e in meta)
        # request spans became b/e async pairs keyed on uid
        pairs = [e for e in ev if e["ph"] in ("b", "e")]
        assert len(pairs) == 4
        b1 = next(e for e in pairs if e["ph"] == "b" and e["id"] == "1")
        e1 = next(e for e in pairs if e["ph"] == "e" and e["id"] == "1")
        assert b1["ts"] == pytest.approx(0.0)
        assert e1["ts"] == pytest.approx(2.0 * 1e6)  # µs
        # the batch span is a complete X slice with µs duration
        x = next(e for e in ev if e["ph"] == "X")
        assert x["dur"] == pytest.approx(1.5 * 1e6)
        assert any(e["ph"] == "i" and e["name"] == "cache.put" for e in ev)


# ------------------------------------------------------- service integration
EXPECTED_STATS_KEYS = {
    "queries", "batches", "qps", "latency_ms_p50", "latency_ms_p90",
    "latency_ms_p99", "latency_ms_mean", "mean_radius_steps",
    "mean_candidates", "termination_steps_hist", "padding_efficiency",
    "cache_hits", "cache_hit_rate", "overlap_ratio",
    "failed", "degraded", "straggler_batches",
}


def _service(col, clk, **kw):
    kw.setdefault("batch_shapes", (1, 4, 8))
    kw.setdefault("default_k", 8)
    kw.setdefault("steps", 4)
    svc = StoreService(clock=clk, **kw)
    svc.attach(col)
    return svc


class TestServiceIntegration:
    def test_stats_keys_and_registry_backing(self, setup, col):
        _, queries, _ = setup
        clk = FakeClock()
        svc = _service(col, clk)
        svc.serve("obscol", queries[:4])
        s = svc.stats("obscol")
        assert set(s.keys()) == EXPECTED_STATS_KEYS
        reg = svc.registry
        assert reg.get("repro_store_queries_served_total").value(
            collection="obscol"
        ) == s["queries"] == 4
        assert reg.get("repro_store_latency_ms").count(
            collection="obscol"
        ) == 4
        # p90/mean agree with exact numpy over the same window
        lat = reg.get("repro_store_latency_ms")
        win = np.asarray(
            lat._series[(("collection", "obscol"),)].window, np.float64
        )
        np.testing.assert_allclose(s["latency_ms_p90"], np.percentile(win, 90))
        np.testing.assert_allclose(s["latency_ms_mean"], win.mean())

    def test_empty_snapshot_is_zero_safe(self, col):
        svc = _service(col, FakeClock())
        s = svc.stats("obscol")
        for key, v in s.items():
            if key == "termination_steps_hist":
                assert v == {}
            else:
                assert v == 0 or v == 0.0, (key, v)
        t = StoreService(batch_shapes=(1,), default_k=8)
        # no tenants served yet -> no entries, and cache stats are 0-safe
        assert t.cache_stats()["hit_rate"] == 0.0

    def test_gauges_track_queue_and_ring(self, setup, col):
        _, queries, _ = setup
        clk = FakeClock()
        svc = _service(col, clk, inflight_depth=2, max_wait_ms=1e9)
        for q in queries[:3]:
            svc.submit("obscol", q)
        assert svc.registry.get("repro_store_queue_depth").value() == 3
        svc.flush()
        assert svc.registry.get("repro_store_queue_depth").value() == 0
        assert svc.registry.get("repro_store_inflight_batches").value() == 0

    def test_quota_withdrawal_counters(self, setup, col):
        _, queries, _ = setup
        clk = FakeClock()
        svc = _service(col, clk)
        svc.set_quota("t", rate=1.0, burst=2)
        with pytest.raises(QuotaExceeded):
            svc.serve("obscol", queries[:4], tenant="t")
        ts = svc.tenant_stats("t")
        assert ts["submitted"] == 0          # snapshot: submitted - withdrawn
        assert ts["rejected"] == 1
        reg = svc.registry
        assert reg.get("repro_store_tenant_submitted_total").value(
            tenant="t"
        ) == 2                               # the raw counter stays monotonic
        assert reg.get("repro_store_tenant_withdrawn_total").value(
            tenant="t"
        ) == 2
        assert reg.get("repro_store_quota_rejections_total").value(
            tenant="t"
        ) == 1

    def test_cache_metrics_bound(self, setup, col):
        _, queries, _ = setup
        svc = _service(col, FakeClock(), cache_size=64)
        svc.serve("obscol", queries[:2])
        svc.serve("obscol", queries[:2])
        reg = svc.registry
        assert reg.get("repro_store_result_cache_hits_total").value() == 2
        assert reg.get("repro_store_result_cache_misses_total").value() == 2
        assert reg.get("repro_store_result_cache_size").value() == 2
        assert svc.stats("obscol")["cache_hit_rate"] == pytest.approx(0.5)

    def test_request_and_batch_spans(self, setup, col):
        _, queries, _ = setup
        clk = FakeClock()
        obs = Observability(tracer=Tracer(enabled=True, clock=clk))
        svc = _service(col, clk, obs=obs)
        svc.serve("obscol", queries[:4])
        names = {s.name for s in obs.tracer.events}
        assert {"request.queue_wait", "batch.assemble", "batch.issue",
                "batch.pending", "batch.complete"} <= names
        issue = next(s for s in obs.tracer.events if s.name == "batch.issue")
        assert issue.tid >= TID_RING0
        assemble = next(
            s for s in obs.tracer.events if s.name == "batch.assemble"
        )
        assert assemble.tid == TID_SCHEDULER

    def test_lifecycle_spans_on_global_tracer(self, setup):
        data, _, kb = setup
        params = DBLSHParams.derive(
            n=256, d=12, c=1.5, w0=3.6, t=12, k=8, inline_vectors=True
        )
        c2 = Collection.create("mut", kb, data, params=params)
        tr = get_tracer()
        tr.enable()
        try:
            ids = c2.add(data[:3] + 0.5)
            c2.remove(ids[:1])
            c2.compact()
        finally:
            tr.disable()
        by_name = {s.name: s for s in tr.events}
        assert {"lifecycle.add", "lifecycle.remove",
                "lifecycle.compact"} <= set(by_name)
        add = by_name["lifecycle.add"]
        assert add.tid == TID_LIFECYCLE
        assert add.args["rows"] == 3 and "version" in add.args
        assert by_name["lifecycle.compact"].args["n_after"] > 0


# ---------------------------------------------------------------- bit-equality
@pytest.mark.parametrize("engine", ENGINES)
def test_obs_on_off_bit_equal(setup, col, engine):
    """The whole observability stack enabled (tracing, sampling 1.0)
    must not change a single output bit vs obs-off, per engine."""
    _, queries, _ = setup
    interpret = True if engine != "jnp" else None

    def run(obs):
        svc = StoreService(
            batch_shapes=(1, 4, 8), default_k=8, steps=4, engine=engine,
            interpret=interpret, inflight_depth=2, obs=obs,
        )
        svc.attach(col)
        d, i, _ = svc.serve("obscol", queries[:8])
        return np.asarray(d), np.asarray(i)

    d_off, i_off = run(None)
    obs = Observability(tracer=Tracer(enabled=True))
    d_on, i_on = run(obs)
    assert obs.tracer.events  # it really traced
    np.testing.assert_array_equal(d_off, d_on)
    np.testing.assert_array_equal(i_off, i_on)


# ------------------------------------------------------------------ SLO watch
def _feed_latency(reg, values, collection="c"):
    h = reg.histogram(
        "repro_store_latency_ms", window=8192
    )
    for v in values:
        h.observe(v, collection=collection)


def _feed_steps(reg, pmf_counts, collection="c"):
    c = reg.counter("repro_store_termination_steps_total")
    for step, n in pmf_counts.items():
        c.inc(n, collection=collection, step=step)


class TestSLOWatch:
    def test_expected_pmf_from_table(self):
        table = ScheduleTable(
            r0=1.0, c=1.5, k=8, recall=(0.5, 0.8, 0.9),
            cost_slots=(1.0, 2.0, 3.0),
            cost_ms=(float("nan"),) * 3, n_sample=64,
        )
        pmf = expected_step_pmf(table)
        # recall increments normalized by final recall; residual
        # (never-certified) mass folds into the tail bin
        np.testing.assert_allclose(
            [pmf[1], pmf[2], pmf[3]],
            [0.5 / 0.9, 0.3 / 0.9, 0.1 / 0.9 + 0.0],
        )
        assert sum(pmf.values()) == pytest.approx(1.0)
        # plan_steps caps the support
        pmf2 = expected_step_pmf(table, steps=2)
        assert set(pmf2) == {1, 2}
        assert sum(pmf2.values()) == pytest.approx(1.0)

    def test_latency_breach_fires(self):
        clk = FakeClock()
        reg = MetricsRegistry()
        _feed_latency(reg, [1.0] * 40 + [50.0] * 24)
        seen = []
        watch = SLOWatch(
            reg, "c", latency_p99_ms=20.0, latency_p50_ms=100.0,
            min_samples=32, clock=clk, on_breach=seen.append,
        )
        events = watch.check()
        assert [e.kind for e in events] == ["latency_p99"]
        assert isinstance(events[0], BreachEvent)
        assert events[0].observed > 20.0
        assert seen == events
        assert reg.get("repro_store_slo_breaches_total").value(
            collection="c", kind="latency_p99"
        ) == 1
        # below min_samples: silent
        reg2 = MetricsRegistry()
        _feed_latency(reg2, [50.0] * 10)
        assert not SLOWatch(
            reg2, "c", latency_p99_ms=20.0, min_samples=32, clock=clk
        ).check()

    def test_scripted_drift_breach(self):
        clk = FakeClock()
        reg = MetricsRegistry()
        table = ScheduleTable(
            r0=1.0, c=1.5, k=8, recall=(0.6, 0.85, 0.95),
            cost_slots=(1.0, 2.0, 3.0),
            cost_ms=(float("nan"),) * 3, n_sample=64,
        )
        watch = SLOWatch(
            reg, "c", table=table, drift_threshold=0.25, min_samples=32,
            window_s=60.0, clock=clk,
        )
        # phase 1: traffic matches the calibrated prediction -> no breach
        exp = expected_step_pmf(table)
        _feed_steps(reg, {s: int(round(p * 200)) for s, p in exp.items()})
        assert watch.check(clk.advance(1.0)) == []
        drift0 = reg.get("repro_store_termination_drift").value(
            collection="c"
        )
        assert drift0 < 0.25
        # phase 2: the workload hardens — everything terminates at the
        # final step, far from the prediction -> drift breach
        _feed_steps(reg, {3: 400})
        events = watch.check(clk.advance(1.0))
        assert [e.kind for e in events] == ["termination_drift"]
        ev = events[0]
        assert ev.observed > 0.25
        assert ev.detail["expected_pmf"] == exp
        assert "re-calibrate" in ev.message
        # the rolling window forgets: after window_s of healthy traffic
        # the drift clears
        clk.advance(120.0)
        _feed_steps(reg, {s: int(round(p * 400)) for s, p in exp.items()})
        assert watch.check(clk.advance(1.0)) == []

    def test_maybe_check_rate_limits(self):
        clk = FakeClock()
        reg = MetricsRegistry()
        _feed_latency(reg, [50.0] * 64)
        watch = SLOWatch(
            reg, "c", latency_p99_ms=1.0, min_samples=32,
            check_interval_s=1.0, clock=clk,
        )
        assert watch.maybe_check()          # first call evaluates
        assert watch.maybe_check() == []    # inside the interval: skipped
        clk.advance(1.5)
        assert watch.maybe_check()          # interval elapsed: breach again
        assert len(watch.events) == 2

    def test_service_drives_slo_from_step(self, setup, col):
        _, queries, _ = setup
        clk = FakeClock()
        svc = _service(col, clk, cache_size=0, max_wait_ms=1e9)
        seen = []
        svc.obs.watch(
            "obscol", latency_p99_ms=0.5, min_samples=1,
            check_interval_s=0.0, clock=clk, on_breach=seen.append,
        )
        for q in queries[:4]:
            svc.submit("obscol", q)
        clk.advance(0.01)  # 10 ms of queue wait: p99 >> the 0.5 ms objective
        svc.step(force=True)
        assert seen and seen[0].kind == "latency_p99"


# --------------------------------------------------------- explain / exemplars
class TestExplainDevice:
    """Device-side with_explain: the off path must be bit-equal (it is
    the same compiled program), and the per-step arrays must agree with
    the with_stats accounting they refine."""

    @pytest.mark.parametrize("engine", ENGINES)
    def test_explain_off_bit_equal(self, setup, col, engine):
        _, queries, _ = setup
        interpret = True if engine != "jnp" else None
        for term in (None, Termination()):
            kw = dict(k=8, r0=0.5, steps=4, engine=engine,
                      interpret=interpret, with_stats=True, termination=term)
            d0, i0, s0 = search_batch_fixed(col.index, queries[:8], **kw)
            d1, i1, s1, ex = search_batch_fixed(
                col.index, queries[:8], with_explain=True, **kw
            )
            np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))
            np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
            np.testing.assert_array_equal(
                np.asarray(s0["radius_steps"]), np.asarray(s1["radius_steps"])
            )
            np.testing.assert_array_equal(
                np.asarray(s0["candidates"]), np.asarray(s1["candidates"])
            )
            # contract: per-step admitted deltas partition the total
            # verified slots, causes are in vocabulary, the halfwidth
            # schedule is the geometric ladder
            slots = np.asarray(ex["step_slots"])
            np.testing.assert_array_equal(
                slots.sum(axis=1), np.asarray(s0["candidates"])
            )
            assert set(np.asarray(ex["term_cause"]).tolist()) <= {0, 1, 2}
            half = np.asarray(ex["step_half"])
            assert half.shape == (4,)
            np.testing.assert_allclose(half[1:] / half[:-1], 1.5, rtol=1e-5)


class TestExplainService:
    def test_ticket_contract_and_render(self, setup, col):
        """submit(explain=True): the record's accounting matches the
        ticket's with_stats numbers, the cache read is a bypass, and the
        rendered text names the termination condition."""
        _, queries, _ = setup
        svc = _service(col, FakeClock(), max_wait_ms=1e9)
        t = svc.submit("obscol", queries[0], explain=True)
        plain = [svc.submit("obscol", q) for q in queries[1:4]]
        svc.flush()
        assert t.done and t.error is None
        e = t.explain
        assert e is not None
        assert all(p.explain is None for p in plain)
        # device accounting agrees with the ticket
        assert e.steps_run == t.radius_steps
        assert e.candidates == t.candidates == sum(e.step_slots)
        assert e.cum_slots[-1] == t.candidates
        assert len(e.step_half) == len(e.step_slots) == e.plan_steps == 4
        assert e.term_cause in (
            "schedule_exhausted", "c1_budget", "c2_certified"
        )
        # provenance: no policy anywhere -> the service's own schedule
        assert e.plan_source == "default" and e.plan_policy is None
        assert e.cache_outcome == "bypass" and "obscol@v" in e.cache_key
        assert e.queue_wait_ms >= 0.0 and e.batch_seq >= 0
        text = e.render()
        assert f"uid={t.uid}" in text
        assert "terminated: " + e.term_cause in text
        assert "admitted_slots" in text and "cache: bypass" in text
        json.dumps(e.to_dict())  # artifact shape is JSON-able

    def test_explain_dispatch_bit_equal(self, setup, col):
        """A fully-explained serve returns bit-identical results to a
        plain serve of the same queries."""
        _, queries, _ = setup

        def run(explain):
            svc = _service(col, FakeClock(), cache_size=0,
                           inflight_depth=2)
            d, i, _ = svc.serve("obscol", queries[:6], explain=explain)
            return np.asarray(d), np.asarray(i)

        d0, i0 = run(False)
        d1, i1 = run(True)
        np.testing.assert_array_equal(d0, d1)
        np.testing.assert_array_equal(i0, i1)

    def test_plan_provenance_names_request_rung(self, setup, col):
        from repro.tune import FixedSchedule

        _, queries, _ = setup
        svc = _service(col, FakeClock())
        t = svc.submit("obscol", queries[0], explain=True,
                       policy=FixedSchedule(r0=0.5, steps=2))
        svc.flush()
        assert t.explain.plan_source == "request"
        assert "FixedSchedule" in t.explain.plan_policy
        assert t.explain.plan_steps == 2 and len(t.explain.step_half) == 2

    def test_auto_sampling_stride(self, setup, col):
        _, queries, _ = setup
        obs = Observability(explain_sample_rate=0.5)  # stride 2
        svc = _service(col, FakeClock(), obs=obs, cache_size=0)
        tickets = [svc.submit("obscol", queries[i % 8]) for i in range(4)]
        svc.flush()
        flags = [t.explain is not None for t in tickets]
        assert flags == [True, False, True, False]
        # explicit flags override the sampler in both directions
        assert svc.submit("obscol", queries[0], explain=True).explain
        assert svc.submit("obscol", queries[0], explain=False).explain is None
        # default bundle: sampling off, nothing explained implicitly
        svc2 = _service(col, FakeClock(), cache_size=0)
        t2 = svc2.submit("obscol", queries[0])
        svc2.flush()
        assert t2.explain is None

    def test_tenant_degraded_and_deadline_counters(self, setup, col):
        """Satellite: per-tenant degraded / deadline_exceeded surfaced
        from labeled registry series."""
        _, queries, _ = setup
        clk = FakeClock()
        svc = _service(col, clk, max_wait_ms=0.0, inflight_depth=2)
        # served past its budget: issued at t=0, completed 10ms later
        t1 = svc.submit("obscol", queries[0], deadline_ms=5.0, tenant="acme")
        svc.step()
        clk.advance(0.010)
        svc.flush()
        assert t1.done and t1.error is None and t1.degraded
        # expired while queued: typed deadline failure
        t2 = svc.submit("obscol", queries[1], deadline_ms=5.0, tenant="acme")
        clk.advance(0.010)
        svc.step()
        assert isinstance(t2.error, DeadlineExceeded) and t2.done
        ts = svc.tenant_stats("acme")
        assert ts["degraded"] == 1
        assert ts["deadline_exceeded"] == 1
        assert ts["failed"] == 1
        assert ts["served"] == 1

    def test_breach_event_carries_rendered_exemplar(self, setup, col):
        """Acceptance: a scripted p99 breach names actual queries — the
        worst exemplar's rendered explain includes the termination
        condition and per-step admitted slots."""
        _, queries, _ = setup
        clk = FakeClock()
        svc = _service(col, clk, max_wait_ms=1e9)
        t = svc.submit("obscol", queries[0], explain=True)
        clk.advance(0.050)  # 50 ms in queue: the latency tail
        svc.flush()
        assert t.done and t.explain is not None
        watch = svc.obs.watch(
            "obscol", latency_p99_ms=1.0, min_samples=1, clock=clk,
        )
        events = watch.check(clk.now)
        assert events and events[0].kind in ("latency_p50", "latency_p99")
        exs = events[0].detail["exemplars"]
        assert exs, "breach carried no exemplars"
        best = exs[0]
        assert best["uid"] == t.uid
        assert best["explain"]["term_cause"] == t.explain.term_cause
        assert "terminated: " + t.explain.term_cause in best["rendered"]
        assert "admitted_slots" in best["rendered"]
        # the event (exemplars included) survives JSON export
        json.dumps(events[0].to_dict())


class TestExemplarReservoir:
    def test_worst_walks_tail_first(self):
        res = ExemplarReservoir(buckets=(1.0, 10.0), per_bucket=4)
        for uid, lat in enumerate([0.5, 5.0, 50.0, 2.0]):
            res.record(lat, uid, "c")
        worst = res.worst(3)
        assert [w["uid"] for w in worst] == [2, 1, 3]
        assert worst[0]["latency_ms"] == 50.0
        # collection filter
        res.record(99.0, 7, "other")
        assert [w["uid"] for w in res.worst(1, collection="c")] == [2]

    def test_explain_store_is_bounded(self):
        res = ExemplarReservoir(buckets=(1.0,), per_bucket=2, max_explains=3)
        for uid in range(6):
            res.record(0.5, uid, "c", QueryExplain(uid=uid, collection="c"))
        assert len(res.explains()) == 3
        assert res.explain_for(5) is not None  # newest kept
        assert res.explain_for(0) is None      # oldest evicted
        # rings are bounded too
        blob = res.to_json()
        assert len(blob["exemplars"]) <= 2 * 2  # per_bucket x (buckets+inf)

    def test_export_json(self, tmp_path):
        res = ExemplarReservoir()
        res.record(3.0, 1, "c", QueryExplain(uid=1, collection="c"))
        path = str(tmp_path / "explains.json")
        assert res.export_json(path) == 1
        blob = json.loads(open(path).read())
        assert blob["explains"][0]["uid"] == 1
        assert blob["exemplars"][0]["latency_ms"] == 3.0


class TestPrometheusHardening:
    def test_label_values_escaped(self):
        """Satellite: text-format escaping for quotes, backslashes, and
        newlines in label values."""
        reg = MetricsRegistry()
        c = reg.counter("esc_total", "escaping")
        c.inc(path='say "hi"\\now', msg="line1\nline2")
        text = reg.to_prometheus()
        assert 'path="say \\"hi\\"\\\\now"' in text
        assert 'msg="line1\\nline2"' in text
        # round-trip sanity: exactly one sample line, parseable shape
        sample = [l for l in text.splitlines() if l.startswith("esc_total{")]
        assert len(sample) == 1 and sample[0].endswith(" 1")

    def test_empty_registry_exports_valid_empty_text(self):
        assert MetricsRegistry().to_prometheus() == ""

    def test_json_export_unaffected(self):
        reg = MetricsRegistry()
        reg.counter("c_total").inc(v='a"b')
        blob = reg.to_json()
        assert blob["c_total"]["series"][0]["labels"] == {"v": 'a"b'}
