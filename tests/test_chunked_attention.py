"""KV-chunked (online-softmax) attention == full attention."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import _gqa_out, _gqa_scores, _kv_chunked_context, NEG


@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 17)])
@pytest.mark.parametrize("B,T,S,H,KV,hd,ck", [
    (2, 32, 32, 8, 2, 16, 8),
    (1, 48, 48, 4, 4, 8, 16),   # MHA, non-multiple handled by pad
    (1, 40, 40, 6, 2, 8, 16),   # S % ck != 0
])
def test_chunked_matches_full(causal, window, B, T, S, H, KV, hd, ck):
    ks = jax.random.split(jax.random.key(B * T + H), 3)
    q = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))

    ctx_chunked = _kv_chunked_context(q, k, v, causal=causal, window=window, ck=ck)

    scores = _gqa_scores(q, k)
    i = jax.lax.broadcasted_iota(jnp.int32, (T, S), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (T, S), 1)
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= i >= j
    if window:
        mask &= (i - j) < window
    scores = jnp.where(mask, scores, NEG)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(v.dtype)
    G = H // KV
    ctx_full = jnp.einsum("bkgts,bskh->btkgh", probs, v).reshape(B, T, H, hd)

    np.testing.assert_allclose(
        np.asarray(ctx_chunked), np.asarray(ctx_full), rtol=2e-4, atol=2e-4
    )


def test_chunked_handles_fully_masked_rows():
    """window smaller than chunk stride must not produce NaNs."""
    q = jax.random.normal(jax.random.key(0), (1, 16, 2, 8))
    k = jax.random.normal(jax.random.key(1), (1, 16, 2, 8))
    v = jax.random.normal(jax.random.key(2), (1, 16, 2, 8))
    ctx = _kv_chunked_context(q, k, v, causal=True, window=1, ck=4)
    assert np.all(np.isfinite(np.asarray(ctx)))
